"""Rootdir pytest bootstrap: make the src-layout package importable.

The repo is src-layout (``src/repro``) without an installed distribution,
so a bare ``python -m pytest`` from the repo root used to die at
collection (``ModuleNotFoundError: repro``) unless the caller remembered
``PYTHONPATH=src``.  Pytest imports the rootdir ``conftest.py`` before
collecting anything, so inserting ``src`` here makes both invocations
work identically; the explicit ``PYTHONPATH=src`` tier-1 command keeps
working unchanged (the path is simply already present).
"""
import os
import sys

_SRC = os.path.join(os.path.dirname(os.path.abspath(__file__)), "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)
