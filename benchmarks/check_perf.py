"""Perf-trajectory regression check for the CI perf-smoke lane.

Compares a freshly produced ``BENCH_engine.json`` against the committed
baseline (saved aside before the bench overwrote it) and emits
**non-gating** GitHub warning annotations when the trajectory regresses:

  * warm per-cell wall-clock worse by more than ``--threshold`` (default
    20% — shared runners are noisy; this flags trends, not blips);
  * any retraces during warm cells (that one is a hard perf bug: the
    prediction programs must never recompile in steady state);
  * predict overhead per interval worse by more than the threshold.

Wall-clock comparisons only happen between matching hosts: both files
carry a coarse hardware fingerprint (``host`` — machine arch + cpu
count + platform, written by ``engine_bench.py``), and on mismatch the
regression compare is skipped with an informative note instead of
emitting spurious warnings against numbers from different hardware.  A
baseline predating the fingerprint (no ``host`` key) is treated as
unknown hardware and likewise skipped.  The retrace check is
machine-independent and always runs.

Always exits 0 — the lane's job is a visible warning on the PR, not a
red build.

    python benchmarks/check_perf.py --baseline /tmp/BENCH_engine.base.json \
        --fresh BENCH_engine.json [--threshold 0.2]
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def warn(msg: str) -> None:
    # GitHub Actions annotation; plain stderr elsewhere
    print(f"::warning title=perf-smoke::{msg}")
    print(msg, file=sys.stderr)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", required=True,
                    help="committed BENCH_engine.json (pre-bench copy)")
    ap.add_argument("--fresh", default="BENCH_engine.json")
    ap.add_argument("--threshold", type=float, default=0.2,
                    help="fractional wall-clock regression that warns")
    args = ap.parse_args(argv)

    if not os.path.exists(args.baseline):
        print(f"no baseline at {args.baseline}; nothing to compare")
        return 0
    with open(args.baseline) as f:
        base = json.load(f)
    with open(args.fresh) as f:
        fresh = json.load(f)

    # machine-independent check first — it must run regardless of sizing
    rt = fresh.get("retraces_during_warm_cells")
    if rt:
        warn(f"retraces_during_warm_cells = {rt} (must be 0: a warm "
             f"sweep worker recompiled a prediction program)")
    else:
        print("retraces_during_warm_cells: 0 ok")

    b_host, f_host = base.get("host"), fresh.get("host")
    if b_host != f_host or b_host is None:
        print(f"baseline host fingerprint ({b_host or 'unknown'}) does "
              f"not match this runner ({f_host or 'unknown'}); wall-clock "
              f"numbers are not comparable across hardware — skipping "
              f"the regression compare (retrace check above still ran)")
        return 0

    if (base.get("n_hosts"), base.get("n_intervals")) != \
            (fresh.get("n_hosts"), fresh.get("n_intervals")):
        print("baseline and fresh bench use different cell sizings; "
              "skipping wall-clock comparison")
        return 0

    checked = 0
    for key in ("warm_wall_s", "predict_ms_per_interval"):
        b, f_ = base.get(key), fresh.get(key)
        if not b or not f_:
            continue
        checked += 1
        ratio = f_ / b
        if ratio > 1.0 + args.threshold:
            warn(f"{key} regressed {ratio:.2f}x vs committed baseline "
                 f"({b} -> {f_})")
        else:
            print(f"{key}: {b} -> {f_} ({ratio:.2f}x) ok")
    print(f"checked {checked} wall metrics against {args.baseline}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
