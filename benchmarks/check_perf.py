"""Perf-trajectory regression check for the CI perf-smoke lane.

Compares a freshly produced ``BENCH_engine.json`` against the committed
baseline (saved aside before the bench overwrote it) and emits
**non-gating** GitHub warning annotations when the trajectory regresses:

  * warm per-cell wall-clock worse by more than ``--threshold`` (default
    20% — shared runners are noisy; this flags trends, not blips);
  * any retraces during warm cells (that one is a hard perf bug: the
    prediction programs must never recompile in steady state);
  * predict overhead per interval worse by more than the threshold.

Wall-clock comparisons only happen between matching hosts: both files
carry a coarse hardware fingerprint (``host`` — machine arch + cpu
count + platform, written by ``engine_bench.py``), and on mismatch the
regression compare is skipped with an informative note instead of
emitting spurious warnings against numbers from different hardware.  A
baseline predating the fingerprint (no ``host`` key) is treated as
unknown hardware and likewise skipped.  The retrace check is
machine-independent and always runs.

When ``--serve-baseline`` / ``--serve-fresh`` are given, the same
treatment covers the serving daemon's ``BENCH_serve.json``: any
``warm_retraces`` is a hard (machine-independent) warning, and the p99
answer-latency SLO plus p50/throughput are compared between matching
hosts at matching sizing.

The checker also knows the **Tier-1 determinism contract** (see
tests/tolerance.py and README "Performance"): retrace checks stay hard,
wall-clock predict-path numbers compare under ``--threshold`` as before,
and the recorded fused-vs-unfused drift (``tier1_drift`` in
``BENCH_engine.json``) is compared against the committed artifact — a
non-gating warning fires when the drift trajectory *grows* (the hard
``TIER1_REL`` gate lives in the test suite; this surfaces creep long
before that gate would fail).

When ``--sweep-baseline`` / ``--sweep-fresh`` are given, the sweep
subsystem's ``BENCH_sweep.json`` gets the same treatment:
``bitwise_equal: false`` (or ``fabric_bitwise_equal: false``) is a
**hard failure** — the checker exits nonzero, because a parallel or
fabric grid diverging from serial breaks the Tier-0 determinism
contract, never a "noisy runner" — while ``speedup_warm`` /
``fabric_speedup_warm`` regressions beyond the threshold emit the usual
non-gating warnings, keyed on matching host fingerprints.

With ``--history`` each run appends one JSON line (host, engine, serve,
sweep/fabric and — via ``--kernel-fresh`` — Pallas-kernel numbers) to
``BENCH_history.jsonl`` so the perf trajectory is visible across PRs.

Exits 0 unless a determinism contract broke (sweep bitwise mismatch) —
wall-clock regressions stay visible warnings on the PR, not red builds.

    python benchmarks/check_perf.py --baseline /tmp/BENCH_engine.base.json \
        --fresh BENCH_engine.json [--threshold 0.2] \
        [--serve-baseline /tmp/BENCH_serve.base.json \
         --serve-fresh BENCH_serve.json] \
        [--kernel-fresh BENCH_kernel.json] [--history BENCH_history.jsonl]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time


def warn(msg: str) -> None:
    # GitHub Actions annotation; plain stderr elsewhere
    print(f"::warning title=perf-smoke::{msg}")
    print(msg, file=sys.stderr)


def _load_pair(baseline: str, fresh: str):
    if not os.path.exists(baseline):
        print(f"no baseline at {baseline}; nothing to compare")
        return None, None
    with open(baseline) as f:
        base = json.load(f)
    with open(fresh) as f:
        fresh_d = json.load(f)
    return base, fresh_d


def _hosts_match(base: dict, fresh: dict, what: str) -> bool:
    b_host, f_host = base.get("host"), fresh.get("host")
    if b_host != f_host or b_host is None:
        print(f"{what}: baseline host fingerprint "
              f"({b_host or 'unknown'}) does not match this runner "
              f"({f_host or 'unknown'}); wall-clock numbers are not "
              f"comparable across hardware — skipping the regression "
              f"compare (machine-independent checks above still ran)")
        return False
    return True


def check_serve(baseline: str, fresh_path: str,
                threshold: float) -> None:
    """Serving-daemon trajectory: retraces are a hard warning, p99 SLO
    (plus p50 and throughput) compare only between matching hosts at
    matching tenants x rounds sizing."""
    base, fresh = _load_pair(baseline, fresh_path)
    if base is None:
        return

    rt = fresh.get("warm_retraces")
    if rt:
        warn(f"serve warm_retraces = {rt} (must be 0: a warm serving "
             f"daemon recompiled a prediction program mid-load)")
    else:
        print("serve warm_retraces: 0 ok")

    if not _hosts_match(base, fresh, "serve"):
        return
    sizing = ("tenants", "rounds", "n_hosts", "max_tasks",
              "batch_window_ms")
    if any(base.get(k) != fresh.get(k) for k in sizing):
        print("serve baseline and fresh bench use different sizings; "
              "skipping the latency comparison")
        return

    for key in ("p50_ms", "p99_ms"):
        b, f_ = base.get(key), fresh.get(key)
        if not b or not f_:
            continue
        ratio = f_ / b
        if ratio > 1.0 + threshold:
            warn(f"serve {key} regressed {ratio:.2f}x vs committed "
                 f"baseline ({b} -> {f_} ms): answer-latency SLO "
                 f"trajectory is slipping")
        else:
            print(f"serve {key}: {b} -> {f_} ms ({ratio:.2f}x) ok")
    b, f_ = base.get("answers_per_s"), fresh.get("answers_per_s")
    if b and f_:
        ratio = b / f_   # higher is better
        if ratio > 1.0 + threshold:
            warn(f"serve answers_per_s regressed {ratio:.2f}x vs "
                 f"committed baseline ({b} -> {f_})")
        else:
            print(f"serve answers_per_s: {b} -> {f_} "
                  f"({ratio:.2f}x) ok")


def fail(msg: str) -> None:
    # GitHub Actions error annotation; unlike warn() this gates the lane
    print(f"::error title=perf-smoke::{msg}")
    print(msg, file=sys.stderr)


def check_sweep(baseline: str, fresh_path: str, threshold: float) -> int:
    """Sweep/fabric trajectory.  Returns the number of HARD failures:
    a bitwise mismatch between serial and parallel/fabric grids is a
    broken determinism contract (machine-independent, gates the lane);
    throughput regressions are non-gating warnings between matching
    hosts at matching grid sizing."""
    hard = 0
    if not os.path.exists(fresh_path):
        print(f"no fresh sweep bench at {fresh_path}; skipping")
        return 0
    with open(fresh_path) as f:
        fresh = json.load(f)

    for key in ("bitwise_equal", "fabric_bitwise_equal"):
        v = fresh.get(key)
        if v is False:
            fail(f"sweep {key} is FALSE: a "
                 f"{'fabric' if 'fabric' in key else 'parallel'} grid "
                 f"diverged from serial — the Tier-0 determinism "
                 f"contract is broken, not a perf blip")
            hard += 1
        elif v:
            print(f"sweep {key}: true ok")

    base, fresh = _load_pair(baseline, fresh_path)
    if base is None:
        return hard
    if not _hosts_match(base, fresh, "sweep"):
        return hard
    sizing = ("cells", "workers", "fabric_nodes")
    if any(base.get(k) != fresh.get(k) for k in sizing):
        print("sweep baseline and fresh bench use different grid "
              "sizings; skipping the speedup comparison")
        return hard
    for key in ("speedup_warm", "fabric_speedup_warm"):
        b, f_ = base.get(key), fresh.get(key)
        if not b or not f_:
            continue
        ratio = b / f_   # higher is better
        if ratio > 1.0 + threshold:
            warn(f"sweep {key} regressed {ratio:.2f}x vs committed "
                 f"baseline ({b} -> {f_})")
        else:
            print(f"sweep {key}: {b} -> {f_} ({ratio:.2f}x) ok")
    return hard


def check_tier1_drift(base: dict, fresh: dict) -> None:
    """Non-gating drift-trajectory compare: warn when the recorded
    fused-vs-unfused drift grew versus the committed artifact.  The
    drift is deterministic per (platform, shape, unroll) — growth means
    a rewrite moved the numerics, which must be a conscious re-bless of
    the Tier-1 trajectory, never an accident."""
    b, f_ = base.get("tier1_drift"), fresh.get("tier1_drift")
    if not f_:
        print("tier1_drift: not recorded in fresh bench; skipping")
        return
    bound = f_.get("bound_rel")
    if f_.get("max_rel", 0.0) > (bound or float("inf")):
        warn(f"tier1 drift max_rel {f_['max_rel']:.3e} EXCEEDS the "
             f"documented bound {bound:.1e} — the test suite's hard "
             f"gate will fail; the fused path no longer honors the "
             f"Tier-1 contract")
        return
    if not b:
        print("tier1_drift: no committed baseline to compare; "
              f"fresh max_rel {f_.get('max_rel', 0.0):.3e} within "
              f"bound {bound:.1e}")
        return
    grew = []
    if f_.get("max_ulp", 0) > b.get("max_ulp", 0):
        grew.append(f"max_ulp {b.get('max_ulp', 0)} -> {f_['max_ulp']}")
    if f_.get("max_rel", 0.0) > b.get("max_rel", 0.0) * 1.5:
        grew.append(f"max_rel {b.get('max_rel', 0.0):.3e} -> "
                    f"{f_['max_rel']:.3e}")
    if grew:
        warn("tier1 drift trajectory grew vs committed baseline "
             f"({'; '.join(grew)}; hosts {base.get('host')} -> "
             f"{fresh.get('host')}): still within the documented bound "
             f"({bound:.1e}), but drift growth should be a conscious "
             f"re-bless, not a side effect")
    else:
        print(f"tier1_drift: max_rel {f_.get('max_rel', 0.0):.3e}, "
              f"max_ulp {f_.get('max_ulp', 0)} — no growth vs committed "
              f"baseline, within bound {bound:.1e}")


def append_history(path: str, engine: dict | None, serve: dict | None,
                   kernel: dict | None,
                   sweep: dict | None = None) -> None:
    """Append this run's headline numbers as one JSON line — the
    cross-PR perf trajectory (uploaded as a CI artifact)."""
    entry = {"ts": round(time.time(), 1),
             "sha": os.environ.get("GITHUB_SHA"),
             "host": (engine or serve or sweep or kernel or {}).get("host")}
    if engine:
        entry["engine"] = {
            k: engine.get(k) for k in
            ("warm_wall_s", "predict_ms_per_interval",
             "retraces_during_warm_cells", "n_hosts", "n_intervals",
             "tier1_drift") if engine.get(k) is not None}
    if serve:
        entry["serve"] = {
            k: serve.get(k) for k in
            ("p50_ms", "p99_ms", "answers_per_s", "warm_retraces",
             "tenants", "rounds") if serve.get(k) is not None}
    if kernel:
        entry["kernel"] = {k: kernel.get(k) for k in
                           ("mode", "backend", "cells")
                           if kernel.get(k) is not None}
    if sweep:
        entry["sweep"] = {
            k: sweep.get(k) for k in
            ("cells", "workers", "serial_wall_s", "parallel_warm_wall_s",
             "speedup", "speedup_warm", "bitwise_equal", "fabric_nodes",
             "fabric_wall_s", "fabric_warm_wall_s", "fabric_speedup_warm",
             "fabric_bitwise_equal") if sweep.get(k) is not None}
    with open(path, "a") as f:
        f.write(json.dumps(entry, sort_keys=True) + "\n")
    print(f"appended run to {path}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", required=True,
                    help="committed BENCH_engine.json (pre-bench copy)")
    ap.add_argument("--fresh", default="BENCH_engine.json")
    ap.add_argument("--threshold", type=float, default=0.2,
                    help="fractional wall-clock regression that warns")
    ap.add_argument("--serve-baseline", default=None,
                    help="committed BENCH_serve.json (pre-bench copy)")
    ap.add_argument("--serve-fresh", default="BENCH_serve.json")
    ap.add_argument("--kernel-fresh", default=None,
                    help="fresh BENCH_kernel.json (history/trajectory "
                         "recording only)")
    ap.add_argument("--sweep-baseline", default=None,
                    help="committed BENCH_sweep.json (pre-bench copy)")
    ap.add_argument("--sweep-fresh", default="BENCH_sweep.json")
    ap.add_argument("--history", default=None,
                    help="append this run's numbers to this JSONL "
                         "trajectory file")
    args = ap.parse_args(argv)

    if args.serve_baseline:
        check_serve(args.serve_baseline, args.serve_fresh,
                    args.threshold)

    hard_failures = 0
    if args.sweep_baseline:
        hard_failures += check_sweep(args.sweep_baseline,
                                     args.sweep_fresh, args.threshold)

    base, fresh = _load_pair(args.baseline, args.fresh)

    if args.history:
        def _maybe(path):
            if path and os.path.exists(path):
                with open(path) as f:
                    return json.load(f)
            return None
        # the fresh engine artifact records even with no baseline to
        # compare against (first run on a new host)
        append_history(args.history, fresh or _maybe(args.fresh),
                       _maybe(args.serve_fresh),
                       _maybe(args.kernel_fresh),
                       sweep=_maybe(args.sweep_fresh)
                       if args.sweep_baseline else None)

    if base is None:
        return hard_failures

    # machine-independent checks first — they run regardless of sizing
    rt = fresh.get("retraces_during_warm_cells")
    if rt:
        warn(f"retraces_during_warm_cells = {rt} (must be 0: a warm "
             f"sweep worker recompiled a prediction program)")
    else:
        print("retraces_during_warm_cells: 0 ok")

    check_tier1_drift(base, fresh)

    if not _hosts_match(base, fresh, "engine"):
        return hard_failures

    if (base.get("n_hosts"), base.get("n_intervals")) != \
            (fresh.get("n_hosts"), fresh.get("n_intervals")):
        print("baseline and fresh bench use different cell sizings; "
              "skipping wall-clock comparison")
        return hard_failures

    checked = 0
    for key in ("warm_wall_s", "predict_ms_per_interval"):
        b, f_ = base.get(key), fresh.get(key)
        if not b or not f_:
            continue
        checked += 1
        ratio = f_ / b
        if ratio > 1.0 + args.threshold:
            warn(f"{key} regressed {ratio:.2f}x vs committed baseline "
                 f"({b} -> {f_})")
        else:
            print(f"{key}: {b} -> {f_} ({ratio:.2f}x) ok")
    print(f"checked {checked} wall metrics against {args.baseline}")
    return hard_failures


if __name__ == "__main__":
    sys.exit(main())
