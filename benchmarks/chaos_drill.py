"""Chaos drill driver: the acceptance scenario, outside pytest.

Runs the seeded fault-injection drills against both distributed
surfaces and asserts the recovery invariants:

  * **fabric**: a 2-node 24-cell grid through the :class:`ChaosProxy`
    with ``REPRO_FABRIC_KEY`` set — scripted frame corruption (rejected
    at the MAC check before unpickling), a mid-frame RST, a stall
    longer than the lease (a live node is reclaimed and re-admitted),
    and one node SIGKILLed mid-unit — must produce summaries
    **bitwise-equal** to serial ``run()``;
  * **service**: a tenant streamed through the proxy with reply
    corruption and RSTs — after the proxy quiesces, the server must
    hold exactly one application of every interval and answer the
    final snapshot bitwise-equal to a clean in-process predictor.

Every run's *realized* fault schedule (stream, chunk, fault, detail) is
written to ``benchmarks/artifacts/chaos/`` — the nightly chaos lane
uploads these, so a red run ships its own reproduction recipe.

    PYTHONPATH=src python benchmarks/chaos_drill.py [--seeds 0,1,2]
"""
from __future__ import annotations

import argparse
import json
import multiprocessing
import os
import sys
import tempfile
import time

import numpy as np

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

from repro.chaos import ChaosProxy, FaultPlan  # noqa: E402
from repro.core import features  # noqa: E402
from repro.policy import wire  # noqa: E402
from repro.service import (Profile, ServiceConfig,  # noqa: E402
                           ServiceDaemon)
from repro.service.daemon import ServiceClient  # noqa: E402
from repro.sim.fabric import (FabricCoordinator,  # noqa: E402
                              worker_main)
from repro.sim.sweep import (SweepSpec,  # noqa: E402
                             deterministic_summary as det, run)

ART_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                       "artifacts", "chaos")


def _drill_spec() -> SweepSpec:
    return SweepSpec(techniques=("none", "sgc"),
                     scenarios=("planetlab", "fault-storm"),
                     seeds=(0, 1, 2, 3, 4, 5), n_hosts=10,
                     n_intervals=20, arrival_rate=0.8, max_workers=1)


def fabric_drill(seed: int, serial) -> dict:
    spec = _drill_spec()
    marker = os.path.join(tempfile.mkdtemp(prefix="chaos-"), "killed")
    os.environ["REPRO_TEST_KILL_CELL"] = f"fault-storm:sgc:1:{marker}"
    os.environ["REPRO_FABRIC_KEY"] = f"drill-{seed}"
    c2s = FaultPlan(corrupt=0.01, skip_first=4, max_faults=2,
                    script={5: ("corrupt", 1234), 9: ("reset", None)},
                    stall_after=12, stall_s=5.0)
    s2c = FaultPlan(corrupt=0.01, skip_first=4, max_faults=2,
                    script={6: ("corrupt", 999)})
    t0 = time.perf_counter()
    try:
        with FabricCoordinator(lease_s=3.0) as coord:
            with ChaosProxy((coord.host, coord.port), seed=seed,
                            c2s=c2s, s2c=s2c) as px:
                ctx = multiprocessing.get_context("spawn")
                procs = [ctx.Process(
                    target=worker_main, args=(px.host, px.port),
                    kwargs=dict(node=f"chaos{i}", lanes=1),
                    daemon=True) for i in range(2)]
                for p in procs:
                    p.start()
                try:
                    res = run(spec, fabric=coord)
                finally:
                    for p in procs:
                        p.join(timeout=120)
                        if p.is_alive():
                            p.kill()
                px.dump_artifact(os.path.join(
                    ART_DIR, f"fabric-drill-seed{seed}.json"))
    finally:
        os.environ.pop("REPRO_TEST_KILL_CELL", None)
        os.environ.pop("REPRO_FABRIC_KEY", None)
    bitwise = (
        [(c.scenario, c.technique, c.seed) for c in res.cells]
        == spec.cells()
        and all(det(a.summary) == det(b.summary)
                for a, b in zip(serial.cells, res.cells)))
    return {"seed": seed, "wall_s": round(time.perf_counter() - t0, 3),
            "cells": len(res.cells), "bitwise_equal": bitwise,
            "node_killed": os.path.exists(marker),
            "faults": {e["fault"] for e in px.events} != set(),
            "fault_kinds": sorted({e["fault"] for e in px.events})}


N_HOSTS, MAX_TASKS, HORIZON = 3, 4, 5


def _snap(tenant, seq, m_h, m_t, q=3):
    tasks = [(100 + i, i % N_HOSTS, i) for i in range(q)]
    return wire.snapshot_to_wire(
        tenant, seq, m_h, jobs=[wire.job_to_wire(1, q, m_t,
                                                 tasks=tasks)],
        done=[])


def service_smoke(seed: int) -> dict:
    prof = Profile(n_hosts=N_HOSTS, max_tasks=MAX_TASKS,
                   horizon=HORIZON)
    rng = np.random.default_rng(2)
    m_t = np.zeros((MAX_TASKS, features.TASK_FEATURES), np.float32)
    m_t[:3] = rng.random((3, features.TASK_FEATURES))
    m_hs = [rng.random((N_HOSTS, features.HOST_FEATURES))
            .astype(np.float32) for _ in range(8)]
    t0 = time.perf_counter()
    with ServiceDaemon(ServiceConfig(profile=prof)) as d:
        c2s = FaultPlan(reset=0.05, skip_first=2, max_faults=2)
        s2c = FaultPlan(corrupt=0.10, reset=0.05, skip_first=2,
                        max_faults=3)
        with ChaosProxy(("127.0.0.1", d.port), seed=seed, c2s=c2s,
                        s2c=s2c) as px:
            c = ServiceClient(px.host, px.port, "t0", retries=8,
                              backoff_s=0.05, timeout=5.0)
            assert c.hello(prof)["ok"]
            for i, m_h in enumerate(m_hs[:-1]):
                for _ in range(6):
                    try:
                        r = c.snapshot(_snap("t0", i, m_h, m_t))
                    except (ConnectionError, TimeoutError):
                        continue
                    if isinstance(r, dict) and r.get("ok"):
                        break
            px.quiesce()
            r = c.snapshot(_snap("t0", len(m_hs) - 1, m_hs[-1], m_t))
            st = d.service.stats()
            px.dump_artifact(os.path.join(
                ART_DIR, f"service-smoke-seed{seed}.json"))
            c.bye()
    from repro.core.predictor import StragglerPredictor
    pred = StragglerPredictor(n_hosts=N_HOSTS, max_tasks=MAX_TASKS,
                              horizon=HORIZON)
    for m_h in m_hs:
        pred.push_host_row(m_h)
        ref = pred.predict_interval(m_t[None],
                                    np.array([3.0], np.float32))
    return {"seed": seed, "wall_s": round(time.perf_counter() - t0, 3),
            "applied_once": st["snapshots"] == len(m_hs),
            "resends": st["resends"],
            "final_bitwise": r["jobs"][0]["e_s"]
            == float(np.asarray(ref)[0])}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="seeded chaos drills over fabric + service")
    ap.add_argument("--seeds", default="0",
                    help="comma-separated chaos seeds")
    args = ap.parse_args(argv)
    seeds = [int(s) for s in args.seeds.split(",") if s.strip()]
    os.makedirs(ART_DIR, exist_ok=True)
    spec = _drill_spec()
    print(f"serial reference: {len(spec.cells())} cells", flush=True)
    serial = run(spec)
    report, ok = [], True
    for seed in seeds:
        f = fabric_drill(seed, serial)
        s = service_smoke(seed)
        ok &= (f["bitwise_equal"] and f["node_killed"]
               and s["applied_once"] and s["final_bitwise"])
        report.append({"fabric": f, "service": s})
        print(f"seed {seed}: fabric bitwise={f['bitwise_equal']} "
              f"killed={f['node_killed']} faults={f['fault_kinds']} "
              f"({f['wall_s']}s) | service applied_once="
              f"{s['applied_once']} bitwise={s['final_bitwise']} "
              f"resends={s['resends']} ({s['wall_s']}s)", flush=True)
    digest = os.path.join(ART_DIR, "chaos_digest.json")
    with open(digest, "w") as fp:
        json.dump({"seeds": seeds, "ok": ok, "runs": report}, fp,
                  indent=1, default=str)
    print(f"digest -> {digest}")
    if not ok:
        print("CHAOS DRILL FAILED: see artifacts for the realized "
              "fault schedules", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
