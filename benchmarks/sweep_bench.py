"""Serial-vs-parallel benchmark for the scenario-sweep subsystem.

Reports grid *throughput* with one-time costs split out, so steady-state
scaling is no longer conflated with pool bring-up (the old headline
"0.24x cold speedup" was almost entirely worker spawn + per-worker
duplicate pretraining):

  * ``spawn_s``        — bringing up the worker pool (fresh processes,
    jax + simulator imports), measured by ``sweep.warm_pool``;
  * ``warmup_s``       — per-worker jit-cache warmup (each worker runs
    one cell per technique so the XLA compiles of the prediction
    programs happen once at bring-up, not inside the first grid);
  * ``pretrain_s``     — parent-side pretraining of every (scenario,
    technique) that declares it (broadcast to workers as pickled bytes;
    paid once per process, not once per worker);
  * ``serial_wall_s``  — the grid run with ``max_workers=1`` after
    pretraining is cached (pure cell throughput, one lane);
  * ``parallel_wall_s``      — the first grid over the brought-up pool
    (grid-cold: none of its cells have run; infra-warm: spawn/warmup/
    pretrain already paid and reported above);
  * ``parallel_warm_wall_s`` — and again (what every later figure sweep
    in the same process pays);
  * ``parallel_cold_total_s`` — derived worst case for a one-shot cold
    process: spawn_s + warmup_s + parallel_wall_s;
  * ``per_cell_warm_s``      — mean/p95 per-cell wall inside the warm
    parallel run.

With ``--fabric-nodes N`` (default 2; 0 disables) the same grid also
runs over the **distributed sweep fabric** on localhost: a
``FabricCoordinator`` in this process serves units to N spawned node
agents over TCP, twice (``fabric_wall_s`` — fresh agents, cold caches —
then ``fabric_warm_wall_s``), and the fabric cells are asserted
bitwise-equal to serial too (``fabric_bitwise_equal``).  On a 1-cpu
container this measures fabric *overhead*, not speedup — the numbers
exist so a real multi-host run has a committed localhost reference.

Serial and parallel cell summaries are asserted bitwise-equal.  Host
context (``host``, ``host_cpus``, ``lanes``) is recorded because the
attainable speedup at W workers is capped by physical cores — the
scheduler adds the parent as an extra lane only when cores exceed
workers, and ``check_perf.py`` only compares matching fingerprints.

    PYTHONPATH=src python benchmarks/sweep_bench.py [--quick] [--workers N]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import multiprocessing
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from common import host_fingerprint, write_csv  # noqa: E402

from repro.sim import scenarios, sweep  # noqa: E402
from repro.sim.fabric import FabricCoordinator, worker_main  # noqa: E402
from repro.sim.sweep import SweepSpec, deterministic_summary, run  # noqa: E402

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def bench_spec(quick: bool) -> SweepSpec:
    # `start` is in the grid deliberately: it is the paper's technique and
    # the one that exercises pretraining, so the parent-train-and-broadcast
    # path is measured rather than benchmarked around
    return SweepSpec(
        techniques=("none", "sgc", "dolly", "start") if quick
        else ("none", "sgc", "dolly", "grass", "nearestfit", "start"),
        seeds=(0, 1) if quick else (0, 1, 2, 3),
        scenarios=tuple(scenarios.names())[:4] if quick
        else tuple(scenarios.names()),
        n_hosts=32 if quick else 64,
        n_intervals=72 if quick else 288,
        arrival_rate=0.8 if quick else 1.0,
        pretrain_epochs=8,
    )


def bench_fabric(spec: SweepSpec, serial, n_nodes: int) -> dict:
    """Run the grid over a localhost fabric (coordinator here, ``n_nodes``
    spawned node agents), twice: fresh agents pay jax import + compiles
    in the first grid, the second is the steady state."""
    ctx = multiprocessing.get_context("spawn")
    with FabricCoordinator(lease_s=120.0) as coord:
        procs = [ctx.Process(target=worker_main,
                             args=(coord.host, coord.port),
                             kwargs=dict(node=f"bench-node{i}", lanes=1,
                                         exit_on_drain=False),
                             daemon=True)
                 for i in range(n_nodes)]
        for p in procs:
            p.start()
        try:
            first = run(spec, fabric=coord)
            warm = run(spec, fabric=coord)
        finally:
            for p in procs:
                p.terminate()
            for p in procs:
                p.join(timeout=10)
    equal = all(deterministic_summary(a.summary)
                == deterministic_summary(b.summary)
                for res in (first, warm)
                for a, b in zip(serial.cells, res.cells))
    return {
        "fabric_nodes": n_nodes,
        "fabric_wall_s": round(first.wall_s, 3),
        "fabric_warm_wall_s": round(warm.wall_s, 3),
        "fabric_speedup_warm": round(
            serial.wall_s / max(warm.wall_s, 1e-9), 2),
        "fabric_bitwise_equal": bool(equal),
    }


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--workers", type=int, default=None,
                    help="parallel worker count (default: cpu count)")
    ap.add_argument("--fabric-nodes", type=int, default=2,
                    help="localhost fabric node agents (0 disables the "
                         "fabric leg)")
    args = ap.parse_args(argv)

    spec = bench_spec(args.quick)
    n_workers = args.workers or (os.cpu_count() or 1)

    # one-time costs, measured on their own
    t0 = time.perf_counter()
    sweep._build_payloads(spec)
    pretrain_s = time.perf_counter() - t0
    sweep.shutdown_pool()
    spawn_s = sweep.warm_pool(n_workers)
    # per-worker jit-cache warmup (XLA-compiling the prediction programs
    # per batch bucket is seconds per worker — one-time, like spawn)
    warmup_s = sweep.warm_pool_caches(spec, n_workers)

    # grid throughput: serial (one lane, pretrain cached; best of two so
    # the parent's one-time jit compiles land in the first, discarded run
    # and shared-runner noise is damped) ...
    serial = min((run(dataclasses.replace(spec, max_workers=1))
                  for _ in range(2)), key=lambda r: r.wall_s)
    # ... vs the fresh pool (worker caches cold) and the warm pool
    parallel = run(dataclasses.replace(spec, max_workers=n_workers))
    warm = min((run(dataclasses.replace(spec, max_workers=n_workers))
                for _ in range(2)), key=lambda r: r.wall_s)

    equal = all(deterministic_summary(a.summary)
                == deterministic_summary(b.summary)
                for a, b in zip(serial.cells, parallel.cells))
    equal_warm = all(deterministic_summary(a.summary)
                     == deterministic_summary(b.summary)
                     for a, b in zip(serial.cells, warm.cells))
    speedup = serial.wall_s / max(parallel.wall_s, 1e-9)
    speedup_warm = serial.wall_s / max(warm.wall_s, 1e-9)
    cell_s = np.array([c.wall_s for c in warm.cells])
    cpus = os.cpu_count() or 1
    lanes = n_workers + (1 if cpus > n_workers else 0)

    fabric = {}
    if args.fabric_nodes > 0:
        # free the pool's workers first: fabric agents are their own
        # processes and a 1-cpu container can't host both fleets
        sweep.shutdown_pool()
        fabric = bench_fabric(spec, serial, args.fabric_nodes)

    rows = [
        ["cells", len(serial.cells), ""],
        ["host_cpus", cpus, ""],
        ["lanes", lanes, "workers + parent when cores allow"],
        ["spawn_s", round(spawn_s, 2), "one-time pool bring-up"],
        ["warmup_s", round(warmup_s, 2),
         "one-time per-worker jit-cache warmup"],
        ["pretrain_s", round(pretrain_s, 2),
         "parent-side, broadcast to workers"],
        ["serial_wall_s", round(serial.wall_s, 2), ""],
        [f"parallel_wall_s (x{parallel.n_workers})",
         round(parallel.wall_s, 2),
         "first grid after bring-up (one-time costs above)"],
        [f"parallel_warm_wall_s (x{warm.n_workers})",
         round(warm.wall_s, 2), "persistent pool, caches resident"],
        ["parallel_cold_total_s",
         round(spawn_s + warmup_s + parallel.wall_s, 2),
         "derived: one-shot cold process incl. bring-up"],
        ["speedup", round(speedup, 2), ""],
        ["speedup_warm", round(speedup_warm, 2), ""],
        ["bitwise_equal", int(equal and equal_warm), ""],
        ["per_cell_warm_s_mean", round(float(cell_s.mean()), 3), ""],
        ["per_cell_warm_s_p95",
         round(float(np.percentile(cell_s, 95)), 3), ""],
    ]
    for k in sorted(fabric):
        rows.append([k, fabric[k] if not isinstance(fabric[k], bool)
                     else int(fabric[k]),
                     "localhost 2-node fabric" if k == "fabric_nodes"
                     else ""])
    write_csv("sweep_bench.csv", ["metric", "value", "note"], rows)
    bench = {
        "cells": len(serial.cells),
        "host": host_fingerprint(),
        "workers": parallel.n_workers,
        "host_cpus": cpus,
        "lanes": lanes,
        "spawn_s": round(spawn_s, 3),
        "warmup_s": round(warmup_s, 3),
        "pretrain_s": round(pretrain_s, 3),
        "serial_wall_s": round(serial.wall_s, 3),
        "parallel_wall_s": round(parallel.wall_s, 3),
        "parallel_warm_wall_s": round(warm.wall_s, 3),
        "parallel_cold_total_s": round(
            spawn_s + warmup_s + parallel.wall_s, 3),
        "speedup": round(speedup, 2),
        "speedup_warm": round(speedup_warm, 2),
        "bitwise_equal": bool(equal and equal_warm),
        "per_cell_warm_s": round(float(cell_s.mean()), 4),
        "per_cell_warm_s_p95": round(float(np.percentile(cell_s, 95)), 4),
        **fabric,
    }
    path = os.path.join(REPO_ROOT, "BENCH_sweep.json")
    with open(path, "w") as f:
        json.dump(bench, f, indent=1, sort_keys=True)
        f.write("\n")

    print(f"{len(serial.cells)} cells "
          f"({len(spec.scenarios)} scenarios x {len(spec.techniques)} "
          f"techniques x {len(spec.seeds)} seeds) on {cpus} cpus")
    print(f"spawn:         {spawn_s:7.2f}s  (one-time)")
    print(f"warmup:        {warmup_s:7.2f}s  (one-time, per-worker jit)")
    print(f"pretrain:      {pretrain_s:7.2f}s  (one-time, parent)")
    print(f"serial:        {serial.wall_s:7.2f}s")
    print(f"parallel:      {parallel.wall_s:7.2f}s  ({parallel.n_workers} "
          f"workers, first grid after bring-up, speedup {speedup:.2f}x)")
    print(f"parallel-warm: {warm.wall_s:7.2f}s  (persistent pool, "
          f"speedup {speedup_warm:.2f}x)")
    if fabric:
        print(f"fabric:        {fabric['fabric_wall_s']:7.2f}s  "
              f"({fabric['fabric_nodes']} localhost nodes, first grid "
              f"incl. agent bring-up)")
        print(f"fabric-warm:   {fabric['fabric_warm_wall_s']:7.2f}s  "
              f"(speedup {fabric['fabric_speedup_warm']:.2f}x, "
              f"bitwise-equal {fabric['fabric_bitwise_equal']})")
    print(f"bitwise-equal results: {equal and equal_warm}")
    print(f"wrote {path}")
    assert equal, "parallel sweep diverged from serial"
    assert equal_warm, "warm-pool sweep diverged from serial"
    if fabric:
        assert fabric["fabric_bitwise_equal"], \
            "fabric sweep diverged from serial"
    return {"speedup": speedup, "speedup_warm": speedup_warm,
            "equal": equal and equal_warm, "cells": len(serial.cells),
            **fabric}


if __name__ == "__main__":
    main()
