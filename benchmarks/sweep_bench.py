"""Serial-vs-parallel benchmark for the scenario-sweep subsystem.

Runs the same SweepSpec grid three times — once with max_workers=1 (the
old hand-rolled-loop execution model), once over a cold process pool, and
once more over the now-warm persistent pool (per-worker pretrain/jit
caches resident) — checks serial and parallel results are bitwise-equal,
and reports wall-clock speedups plus per-cell engine throughput. Writes
artifacts/sweep_bench.csv and the repo-root perf-trajectory artifact
``BENCH_sweep.json``.

    PYTHONPATH=src python benchmarks/sweep_bench.py [--quick] [--workers N]

On a 4-core runner the full grid shows >= 2x speedup; --quick shrinks the
grid for smoke runs.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys

import numpy as np

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from common import write_csv  # noqa: E402

from repro.sim import scenarios  # noqa: E402
from repro.sim.sweep import (SweepSpec, deterministic_summary,  # noqa: E402
                             run)

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def bench_spec(quick: bool) -> SweepSpec:
    return SweepSpec(
        techniques=("none", "sgc", "dolly") if quick
        else ("none", "sgc", "dolly", "grass", "nearestfit"),
        seeds=(0, 1) if quick else (0, 1, 2, 3),
        scenarios=tuple(scenarios.names())[:4] if quick
        else tuple(scenarios.names()),
        n_hosts=32 if quick else 64,
        n_intervals=72 if quick else 288,
        arrival_rate=0.8 if quick else 1.0,
    )


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--workers", type=int, default=None,
                    help="parallel worker count (default: cpu count)")
    args = ap.parse_args(argv)

    spec = bench_spec(args.quick)
    n_workers = args.workers or (os.cpu_count() or 1)

    serial = run(dataclasses.replace(spec, max_workers=1))
    parallel = run(dataclasses.replace(spec, max_workers=n_workers))
    # the persistent pool keeps workers (and their pretrain/jit caches)
    # alive between run() calls — the second parallel sweep is what every
    # later figure sweep in the same process pays
    warm = run(dataclasses.replace(spec, max_workers=n_workers))

    equal = all(deterministic_summary(a.summary)
                == deterministic_summary(b.summary)
                for a, b in zip(serial.cells, parallel.cells))
    equal_warm = all(deterministic_summary(a.summary)
                     == deterministic_summary(b.summary)
                     for a, b in zip(serial.cells, warm.cells))
    speedup = serial.wall_s / max(parallel.wall_s, 1e-9)
    speedup_warm = serial.wall_s / max(warm.wall_s, 1e-9)
    cell_s = np.array([c.wall_s for c in serial.cells])

    rows = [
        ["cells", len(serial.cells), ""],
        ["serial_wall_s", round(serial.wall_s, 2), ""],
        [f"parallel_wall_s (x{parallel.n_workers})",
         round(parallel.wall_s, 2), ""],
        [f"parallel_warm_wall_s (x{warm.n_workers})",
         round(warm.wall_s, 2), "persistent pool, caches resident"],
        ["speedup", round(speedup, 2), ""],
        ["speedup_warm", round(speedup_warm, 2), ""],
        ["bitwise_equal", int(equal and equal_warm), ""],
        ["cell_wall_s_mean", round(float(cell_s.mean()), 3), ""],
        ["cell_wall_s_p95", round(float(np.percentile(cell_s, 95)), 3), ""],
    ]
    write_csv("sweep_bench.csv", ["metric", "value", "note"], rows)
    bench = {
        "cells": len(serial.cells),
        "workers": parallel.n_workers,
        "serial_wall_s": round(serial.wall_s, 3),
        "parallel_wall_s": round(parallel.wall_s, 3),
        "parallel_warm_wall_s": round(warm.wall_s, 3),
        "speedup": round(speedup, 2),
        "speedup_warm": round(speedup_warm, 2),
        "bitwise_equal": bool(equal and equal_warm),
        "cell_wall_s_mean": round(float(cell_s.mean()), 4),
        "cell_wall_s_p95": round(float(np.percentile(cell_s, 95)), 4),
    }
    path = os.path.join(REPO_ROOT, "BENCH_sweep.json")
    with open(path, "w") as f:
        json.dump(bench, f, indent=1, sort_keys=True)
        f.write("\n")

    print(f"{len(serial.cells)} cells "
          f"({len(spec.scenarios)} scenarios x {len(spec.techniques)} "
          f"techniques x {len(spec.seeds)} seeds)")
    print(f"serial:        {serial.wall_s:7.2f}s")
    print(f"parallel:      {parallel.wall_s:7.2f}s  ({parallel.n_workers} "
          f"workers, speedup {speedup:.2f}x)")
    print(f"parallel-warm: {warm.wall_s:7.2f}s  (persistent pool, "
          f"speedup {speedup_warm:.2f}x)")
    print(f"bitwise-equal results: {equal and equal_warm}")
    print(f"wrote {path}")
    assert equal, "parallel sweep diverged from serial"
    assert equal_warm, "warm-pool sweep diverged from serial"
    return {"speedup": speedup, "speedup_warm": speedup_warm,
            "equal": equal and equal_warm, "cells": len(serial.cells)}


if __name__ == "__main__":
    main()
