"""Nightly Table-4-scale sweep over the full technique field.

The fast CI lane runs small grids; this script is the nightly
(non-gating) counterpart: every registered simulator technique x every
scenario x several seeds at a Table-4-like cluster size, executed over
the persistent worker pool, with the aggregate/per-cell CSVs written to
``benchmarks/artifacts`` for upload.  ``--quick`` shrinks the grid for
smoke-testing the lane itself.

    PYTHONPATH=src python benchmarks/nightly_grid.py [--quick] [--workers N]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))

from repro.sim import scenarios, sweep  # noqa: E402
import repro.sim.techniques as T  # noqa: E402

HERE = os.path.dirname(os.path.abspath(__file__))
ART = os.path.join(HERE, "artifacts")

FIELD = T.FIELD


def nightly_spec(quick: bool, workers: int | None) -> sweep.SweepSpec:
    return sweep.SweepSpec(
        techniques=FIELD,
        seeds=(0,) if quick else (0, 1, 2),
        scenarios=tuple(scenarios.names()),
        # Table 4 simulates 400 VMs over 288 intervals; the nightly grid
        # runs the largest size a shared runner sustains across the full
        # field, scaled down from that shape
        n_hosts=16 if quick else 100,
        n_intervals=24 if quick else 144,
        arrival_rate=1.0,
        pretrain_epochs=2 if quick else 8,
        igru_epochs=10 if quick else 40,
        max_workers=workers,
        out_dir=ART, csv_prefix="nightly")


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--workers", type=int, default=None)
    args = ap.parse_args(argv)

    spec = nightly_spec(args.quick, args.workers)
    t0 = time.perf_counter()
    res = sweep.run(spec)
    wall = time.perf_counter() - t0
    agg = res.aggregate()
    sweep.shutdown_pool()

    # one-line-per-(scenario, technique) digest for the job log
    key_metric = "sla_violation_rate"
    print(f"{len(res.cells)} cells in {wall:.1f}s "
          f"({res.n_workers} workers); CSVs in {ART}")
    for sc in spec.scenarios:
        ranked = sorted((agg[(sc, tech)][key_metric]["mean"], tech)
                        for tech in spec.techniques)
        best = ", ".join(f"{t}={v:.3f}" for v, t in ranked[:3])
        print(f"  {sc:13s} best {key_metric}: {best}")

    # the late-trigger-gap comparison cell (PR 6): in the saturated
    # `overload` regime legacy start's completion-milestone trigger fires
    # rarely and late, so it historically tied `none`; start-eager's
    # per-task trigger must keep strictly improving on both.  Tracked in
    # the digest so the gap stays closed rather than silently re-opening.
    trigger_gap = {}
    if "overload" in spec.scenarios:
        for tech in ("start", "start-eager", "none"):
            if tech in spec.techniques:
                cell = agg[("overload", tech)]
                trigger_gap[tech] = {
                    "sla_violation_rate":
                        round(cell["sla_violation_rate"]["mean"], 4),
                    "avg_execution_time_s":
                        round(cell["avg_execution_time_s"]["mean"], 1),
                }
        if {"start", "start-eager", "none"} <= trigger_gap.keys():
            e = trigger_gap["start-eager"]
            trigger_gap["eager_closes_gap"] = all(
                e[m] < trigger_gap[o][m]
                for m in ("sla_violation_rate", "avg_execution_time_s")
                for o in ("start", "none"))
            print(f"  overload trigger-gap cell: {trigger_gap}")

    digest = {
        "cells": len(res.cells),
        "wall_s": round(wall, 1),
        "workers": res.n_workers,
        "techniques": list(spec.techniques),
        "scenarios": list(spec.scenarios),
        "overload_trigger_gap": trigger_gap,
    }
    path = os.path.join(ART, "nightly_digest.json")
    os.makedirs(ART, exist_ok=True)
    with open(path, "w") as f:
        json.dump(digest, f, indent=1, sort_keys=True)
        f.write("\n")
    return digest


if __name__ == "__main__":
    main()
