"""Prediction-service benchmark: concurrent multi-tenant TCP serving.

Measures what a tenant pays per answered snapshot when ``repro.service``
is under concurrent load — the serving twin of ``engine_bench.py`` — and
writes a perf-trajectory artifact to the repo root (``BENCH_serve.json``):

  * ``p50_ms`` / ``p99_ms`` — per-answer round-trip latency (client
    ``snapshot()`` call to decoded response, JSON-lines over loopback
    TCP) across all tenants in steady state;
  * ``answers_per_s`` — aggregate steady-state throughput;
  * ``mean_batch_rows`` — how many tenant jobs each device dispatch
    actually coalesced (``batch_rows / ticks`` over the measured phase;
    the whole point of the shared batcher is that this is > 1 under
    concurrent load);
  * ``warm_retraces`` — compile-counter delta across the measured phase.
    Every power-of-two bucket is pre-warmed in-process before the TCP
    phase starts, so this **must be 0**: a warm serving daemon never
    recompiles a prediction program no matter how tenants interleave;
  * sizing (``tenants``, ``rounds``, ``n_hosts``, ``max_tasks``,
    ``batch_window_ms``) and the host fingerprint gating wall-clock
    comparisons in ``check_perf.py``.

    PYTHONPATH=src python benchmarks/serve_bench.py [--quick]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import threading
import time

import numpy as np

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from common import host_fingerprint, write_csv  # noqa: E402

from repro.core import encoder_lstm as net  # noqa: E402
from repro.core import features  # noqa: E402
from repro.core.predictor import fused_compile_count  # noqa: E402
from repro.policy import wire  # noqa: E402
from repro.service import (Profile, ServiceConfig,  # noqa: E402
                           ServiceDaemon)

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _compiles() -> int:
    return net.predict_sequence._cache_size() + fused_compile_count()


def _payloads(tenant: str, n: int, n_hosts: int, max_tasks: int,
              seed: int) -> list[dict]:
    """Pre-build every snapshot a tenant will send so the measured loop
    pays only transport + service time, not feature synthesis."""
    rng = np.random.default_rng(seed)
    q = max_tasks
    out = []
    for seq in range(n):
        m_h = rng.random((n_hosts, features.HOST_FEATURES),
                         dtype=np.float32)
        m_t = rng.random((max_tasks, features.TASK_FEATURES),
                         dtype=np.float32)
        tasks = [(100 + i, i % n_hosts, i) for i in range(q)]
        out.append(wire.snapshot_to_wire(
            tenant, seq, m_h,
            jobs=[wire.job_to_wire(seq, q, m_t, tasks=tasks)]))
    return out


def _prewarm(daemon: ServiceDaemon, prof: Profile, tenants: list[str],
             n_hosts: int, max_tasks: int) -> tuple[float, int]:
    """Warm every bucket pattern in-process BEFORE the daemon's worker
    starts: k concurrent tenants for k = 1..n covers each power-of-two
    batch bucket plus the fused single-tenant path, deterministically
    (no batch-window races).  The trailing solo / full-group / solo
    rounds hit the fused path with a short idle backlog, compiling the
    ``_ring_roll`` catch-up program — the one pattern the k-ramp alone
    misses.  Returns (elapsed_s, warm_rounds)."""
    svc = daemon.service
    t0 = time.perf_counter()
    for t in tenants:
        r = svc.hello(t, prof.to_wire())
        assert r["ok"], r
    groups = [tenants[:k] for k in range(1, len(tenants) + 1)]
    groups += [[tenants[0]], list(tenants), [tenants[0]]]
    warm = {t: _payloads(t, len(groups), n_hosts, max_tasks, seed=999)
            for t in tenants}
    for seq, group in enumerate(groups):
        ps = []
        for t in group:
            snap = dict(warm[t][seq])
            snap["seq"] = seq
            ps.append(svc.submit(t, snap))
        while svc.tick():
            pass
        for p in ps:
            assert p.result and p.result["ok"], p.result
    return time.perf_counter() - t0, len(groups)


def bench_serve(tenants: int, rounds: int, n_hosts: int,
                max_tasks: int, batch_window: float = 0.002) -> dict:
    # the daemon is started only after _prewarm: its batch worker would
    # otherwise race the deterministic per-pattern warm ticks

    prof = Profile(n_hosts=n_hosts, max_tasks=max_tasks, horizon=5)
    cfg = ServiceConfig(profile=prof, max_tenants=tenants,
                        queue_depth=8, sanitize="clamp")
    names = [f"bench{i}" for i in range(tenants)]
    daemon = ServiceDaemon(cfg, port=0, batch_window=batch_window)
    warm_s, warm_rounds = _prewarm(daemon, prof, names, n_hosts,
                                   max_tasks)
    daemon.start()
    try:
        payloads = {t: _payloads(t, rounds, n_hosts, max_tasks, seed=i)
                    for i, t in enumerate(names)}
        # tenant seqs continued past the warm phase's
        for t in names:
            for s, snap in enumerate(payloads[t]):
                snap["seq"] = warm_rounds + s
        before_stats = daemon.service.stats()
        before_compiles = _compiles()
        lats: dict[str, list[float]] = {t: [] for t in names}
        errors: list[dict] = []
        barrier = threading.Barrier(tenants + 1)

        def run(tenant: str) -> None:
            client = daemon.tcp_client(tenant)
            try:
                barrier.wait()
                for snap in payloads[tenant]:
                    t0 = time.perf_counter()
                    resp = client.request(snap)
                    lats[tenant].append(time.perf_counter() - t0)
                    if not resp.get("ok"):
                        errors.append(resp)
            finally:
                client.close()

        threads = [threading.Thread(target=run, args=(t,), daemon=True)
                   for t in names]
        for th in threads:
            th.start()
        barrier.wait()
        t0 = time.perf_counter()
        for th in threads:
            th.join()
        wall_s = time.perf_counter() - t0
        after_stats = daemon.service.stats()
        warm_retraces = _compiles() - before_compiles
    finally:
        daemon.stop()

    assert not errors, errors[:3]
    all_lat = np.array([x for ls in lats.values() for x in ls])
    ticks = after_stats["ticks"] - before_stats["ticks"]
    rows = after_stats["batch_rows"] - before_stats["batch_rows"]
    return dict(
        bench="serve-concurrent-tcp",
        host=host_fingerprint(),
        tenants=tenants, rounds=rounds,
        n_hosts=n_hosts, max_tasks=max_tasks,
        batch_window_ms=round(batch_window * 1e3, 3),
        warm_s=round(warm_s, 3),
        wall_s=round(wall_s, 3),
        answers=int(all_lat.size),
        answers_per_s=round(all_lat.size / wall_s, 1),
        p50_ms=round(float(np.percentile(all_lat, 50)) * 1e3, 3),
        p99_ms=round(float(np.percentile(all_lat, 99)) * 1e3, 3),
        mean_ms=round(float(all_lat.mean()) * 1e3, 3),
        mean_batch_rows=round(rows / max(ticks, 1), 2),
        ticks=int(ticks),
        warm_retraces=int(warm_retraces),
        sheds=int(after_stats["sheds"] - before_stats["sheds"]),
    )


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="fewer rounds for CI smoke runs")
    ap.add_argument("--tenants", type=int, default=8)
    ap.add_argument("--rounds", type=int, default=None,
                    help="snapshots per tenant in the measured phase")
    ap.add_argument("--hosts", type=int, default=16)
    ap.add_argument("--max-tasks", type=int, default=16)
    ap.add_argument("--batch-window-ms", type=float, default=2.0)
    args = ap.parse_args(argv)

    rounds = args.rounds or (25 if args.quick else 100)
    out = bench_serve(args.tenants, rounds, args.hosts, args.max_tasks,
                      batch_window=args.batch_window_ms / 1e3)

    path = os.path.join(REPO_ROOT, "BENCH_serve.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=1, sort_keys=True)
        f.write("\n")
    write_csv("serve_bench.csv", ["metric", "value"],
              [[k, json.dumps(v)] for k, v in out.items()])

    print(json.dumps(out, indent=1, sort_keys=True))
    print(f"wrote {path}")
    return out


if __name__ == "__main__":
    main()
