"""Regenerate the determinism golden fixture (tests/data/).

The fixture pins the deterministic summary of every registered simulator
technique across every scenario in the registry, at a fixed small grid
size.  ``tests/test_policy_api.py`` re-runs the same grid and compares
bitwise — any engine/policy change that shifts a number must either be
fixed or *intentionally re-blessed* by re-running this script and
committing the diff:

    PYTHONPATH=src python benchmarks/regen_golden.py [--workers N]

The grid definition lives here (and is embedded in the fixture under
``_grid``, which the test replays), so the blessing path and the
checking path can never drift apart.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))

from repro.sim import scenarios, sweep  # noqa: E402
import repro.sim.techniques as T  # noqa: E402

FIXTURE = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "tests", "data",
    "determinism_golden.json")

#: the blessed grid — every registered sim technique x every scenario
GRID = dict(
    techniques=T.FIELD,
    scenarios=tuple(scenarios.names()),
    seeds=(0,),
    n_hosts=12, n_intervals=40, arrival_rate=0.8,
    pretrain_epochs=4, igru_epochs=20,
)


def golden_spec(max_workers: int | None = 1) -> sweep.SweepSpec:
    return sweep.SweepSpec(max_workers=max_workers, **GRID)


def main(argv=None) -> str:
    ap = argparse.ArgumentParser()
    ap.add_argument("--workers", type=int, default=os.cpu_count(),
                    help="parallel workers (parallel == serial bitwise)")
    args = ap.parse_args(argv)

    spec = golden_spec(max_workers=args.workers)
    res = sweep.run(spec)
    cells = {f"{c.scenario}|{c.technique}|{c.seed}":
             sweep.deterministic_summary(c.summary) for c in res.cells}
    grid = {k: (list(v) if isinstance(v, tuple) else v)
            for k, v in GRID.items()}
    with open(FIXTURE, "w") as f:
        json.dump({"_grid": grid, "cells": cells}, f, indent=1,
                  sort_keys=True)
        f.write("\n")
    sweep.shutdown_pool()
    print(f"blessed {len(cells)} cells "
          f"({len(spec.techniques)} techniques x "
          f"{len(spec.scenarios)} scenarios) -> {FIXTURE}")
    return FIXTURE


if __name__ == "__main__":
    main()
