"""Paper figure reproductions (Figs. 2, 6-10) on the cloud simulator.

Figures 6-7 are QoS grids and run on the scenario-sweep subsystem
(``repro.sim.sweep``): one declarative SweepSpec per sweep point, optional
process-pool parallelism via ``workers``. Figures 8-10 need per-run sim
internals (completion-time distributions, per-interval predictions) and use
``sweep.make_technique`` + a direct Simulation, sharing the same pretrain
cache. Scaled-down defaults (hosts/intervals) keep CPU wall-clock sane;
pass --full for Table-4-scale runs. Every figure writes
artifacts/figN*.csv and returns headline deltas that EXPERIMENTS.md
compares against the paper.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import write_csv
from repro.core import pareto
from repro.sim import Simulation, scenarios, sweep
from repro.sim.metrics import mape
from repro.sim.sweep import QOS_KEYS
from repro.sim.techniques import BASELINES

ALL_TECHNIQUES = ["start"] + BASELINES + ["none"]


def _sizing(full: bool) -> dict:
    """--full = paper scale (Table 4). Default is a scaled-down cluster;
    arrival_rate is scaled with host count so per-host load matches the
    paper's regime (400 VMs at lambda=1.2 is ~7-15% busy; keeping
    lambda=1.2 on 32 hosts would be ~10x the paper's load and puts every
    technique in a contention spiral — DESIGN.md deviations)."""
    return dict(n_hosts=400 if full else 32,
                n_intervals=288 if full else 72,
                arrival_rate=1.2 if full else 0.6)


def _cfg(full: bool, seed: int = 0, **kw):
    s = _sizing(full)
    s.update(kw)
    return scenarios.make_config("planetlab", seed=seed, **s)


def _epochs(full: bool) -> dict:
    return dict(pretrain_epochs=30 if full else 8, igru_epochs=60)


def prep(full: bool) -> None:
    """Pretrain START/IGRU-SD/Wrangler once on the base config; later
    figure runs (serial path) hit the in-process sweep cache."""
    cfg = _cfg(full)
    for name in ("start", "igru-sd", "wrangler"):
        sweep.make_technique(name, cfg, **_epochs(full))


def _make_technique(full: bool, name: str):
    """Cell technique; pretraining always happens on the base config
    (figure-wide shared cache), never the per-cell override config."""
    return sweep.make_technique(name, _cfg(full), **_epochs(full))


def _run_grid(full: bool, techniques, seeds=(0,), overrides=None,
              workers: int | None = 1) -> dict:
    """One sweep point: techniques x seeds on the planetlab scenario with
    ``overrides`` applied, aggregated to {technique: {metric: mean}}.

    ``workers`` defaults to serial: the pretrain cache warmed by
    ``prep()`` lives in this process, while every spawned worker of every
    sweep point re-pretrains START/IGRU-SD/Wrangler from scratch — only
    raise ``workers`` for technique lists that skip pretraining, or when
    per-worker pretraining is an acceptable price."""
    spec = sweep.SweepSpec(
        techniques=tuple(techniques), seeds=tuple(seeds),
        scenarios=("planetlab",), overrides=tuple((overrides or {}).items()),
        max_workers=workers, **_sizing(full), **_epochs(full))
    agg = sweep.run(spec).aggregate()
    return {t: {k: agg[("planetlab", t)][k]["mean"] for k in QOS_KEYS}
            for t in techniques}


def fig6_utilization(full: bool = False, workers: int | None = 1) -> dict:
    """QoS vs reserved utilization (20-80%)."""
    rows = []
    results = {}
    for res in (0.2, 0.4, 0.6, 0.8):
        r = _run_grid(full, ALL_TECHNIQUES,
                      overrides=dict(reserved_utilization=res),
                      workers=workers)
        results[res] = r
        for name, qos in r.items():
            rows.append([res, name] + [qos[k] for k in QOS_KEYS])
    write_csv("fig6_utilization.csv", ["reserved", "technique"]
              + list(QOS_KEYS), rows)
    return _headline(results)


def fig7_workloads(full: bool = False, workers: int | None = 1) -> dict:
    """QoS vs number of workloads (arrival-rate sweep)."""
    rows = []
    results = {}
    for lam in (0.8, 1.2, 1.8, 2.4):
        r = _run_grid(full, ALL_TECHNIQUES,
                      overrides=dict(arrival_rate=lam), workers=workers)
        results[lam] = r
        for name, qos in r.items():
            rows.append([lam, name] + [qos[k] for k in QOS_KEYS])
    write_csv("fig7_workloads.csv", ["arrival_rate", "technique"]
              + list(QOS_KEYS), rows)
    return _headline(results)


def fig8_completion_variance(full: bool = False) -> dict:
    """Completion-time variance across workloads per technique."""
    rows = []
    out = {}
    for name in ["start"] + BASELINES:
        for res in (0.2, 0.8):
            cfg = _cfg(full, seed=3, reserved_utilization=res)
            sim = Simulation(cfg, technique=_make_technique(full, name))
            sim.run()
            times = np.concatenate(
                [r["times"] for r in sim.completed_jobs]) \
                if sim.completed_jobs else np.zeros(1)
            rows.append([name, res, float(times.mean()),
                         float(times.std()), float(np.percentile(times,
                                                                 99))])
            out[(name, res)] = float(times.std())
    write_csv("fig8_completion.csv",
              ["technique", "reserved", "mean_s", "std_s", "p99_s"], rows)
    start_std = np.mean([v for (n, _), v in out.items() if n == "start"])
    base_std = np.mean([v for (n, _), v in out.items() if n != "start"])
    return {"start_std": start_std, "baseline_std": base_std}


def fig9_mape(full: bool = False) -> dict:
    """Prediction accuracy: MAPE of START vs IGRU-SD vs RPPS."""
    rows = []
    out = {}
    for name in ("start", "igru-sd", "rpps"):
        vals = []
        for seed in (0, 1, 2):
            cfg = _cfg(full, seed=seed)
            sim = Simulation(cfg, technique=_make_technique(full, name))
            sim.run()
            actual = sim.actual_stragglers_per_interval()
            pred = np.array(sim.log.predicted_stragglers, float)
            m = mape(actual, pred)
            if np.isfinite(m):
                vals.append(m)
        out[name] = float(np.mean(vals)) if vals else float("nan")
        rows.append([name, out[name]])
    write_csv("fig9_mape.csv", ["technique", "mape_pct"], rows)
    return out


def fig10_overhead(full: bool = False) -> dict:
    """Decision overhead per technique amortized over task exec time."""
    rows = []
    out = {}
    for name in ["start"] + BASELINES:
        cfg = _cfg(full, seed=4)
        sim = Simulation(cfg, technique=_make_technique(full, name))
        s = sim.run()
        oh = s["avg_overhead_s"]
        rel = oh / max(s["avg_execution_time_s"], 1e-9) * 100
        rows.append([name, oh * 1e3, rel])
        out[name] = rel
    write_csv("fig10_overhead.csv",
              ["technique", "overhead_ms_per_interval",
               "pct_of_exec_time"], rows)
    return out


def fig2_grid_search(full: bool = False) -> dict:
    """k / I / T grid (paper Fig. 2): F1 of straggler classification on
    held-out jobs using MLE-fit Pareto + threshold k."""
    cfg = _cfg(full, seed=11)
    sim = Simulation(cfg)
    sim.run()
    jobs = sim.completed_jobs
    rows = []
    best = (None, -1.0)
    for k in (1.1, 1.3, 1.5, 1.7, 2.0):
        tp = fp = fn = 0
        for rec in jobs:
            times = rec["times"]
            a, b = pareto.fit_pareto_np(times)
            thr = float(pareto.straggler_threshold_np(a, b, k))
            pred = times > thr
            truth = rec["straggler"]  # ground truth at k=1.5 (paper's def)
            tp += int((pred & truth).sum())
            fp += int((pred & ~truth).sum())
            fn += int((~pred & truth).sum())
        f1 = tp / max(tp + 0.5 * (fp + fn), 1e-9)
        rows.append([k, f1])
        if f1 > best[1]:
            best = (k, f1)
    write_csv("fig2_grid.csv", ["k", "f1"], rows)
    return {"best_k": best[0], "best_f1": best[1]}


def _headline(results: dict) -> dict:
    """START's % improvement vs best/worst baseline, averaged over the
    sweep variable (the paper's Figs. 6-7 headline numbers)."""
    gains: dict = {}
    for k in ("avg_execution_time_s", "resource_contention", "energy_kwh",
              "sla_violation_rate"):
        deltas_best, deltas_worst = [], []
        for _, r in results.items():
            s = r["start"][k]
            base = [r[n][k] for n in BASELINES]
            if min(base) > 0:
                deltas_best.append(100 * (min(base) - s) / min(base))
                deltas_worst.append(100 * (max(base) - s) / max(base))
        gains[k] = {"vs_best_baseline_pct": float(np.mean(deltas_best)),
                    "vs_worst_baseline_pct": float(np.mean(deltas_worst))}
    return gains
