"""Paper figure reproductions (Figs. 2, 6-10) on the cloud simulator.

Scaled-down defaults (hosts/intervals) keep CPU wall-clock sane; pass
--full for Table-4-scale runs. Every figure writes artifacts/figN*.csv and
returns headline deltas that EXPERIMENTS.md compares against the paper.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import write_csv
from repro.core import pareto
from repro.sim import SimConfig, Simulation
from repro.sim.metrics import mape
from repro.sim.techniques import BASELINES, START, make
from repro.sim.techniques.baselines import (IGRUSD, Wrangler, pretrain_igru,
                                            pretrain_wrangler)
from repro.sim.techniques.start_tech import pretrain

QOS_KEYS = ["avg_execution_time_s", "resource_contention", "energy_kwh",
            "sla_violation_rate", "cpu_util_pct", "ram_util_pct",
            "disk_util_pct", "bw_util_pct"]


def _cfg(full: bool, **kw) -> SimConfig:
    """--full = paper scale (Table 4). Default is a scaled-down cluster;
    arrival_rate is scaled with host count so per-host load matches the
    paper's regime (400 VMs at lambda=1.2 is ~7-15% busy; keeping
    lambda=1.2 on 32 hosts would be ~10x the paper's load and puts every
    technique in a contention spiral — DESIGN.md deviations)."""
    base = dict(n_hosts=400 if full else 32,
                n_intervals=288 if full else 72,
                arrival_rate=1.2 if full else 0.6,
                seed=kw.pop("seed", 0))
    base.update(kw)
    return SimConfig(**base)


def _make_technique(name: str, ctrl, warmup_sim):
    if name == "start":
        return START(controller=ctrl)
    t = make(name)
    if isinstance(t, IGRUSD):
        pretrain_igru(t, warmup_sim, epochs=60)
    if isinstance(t, Wrangler):
        pretrain_wrangler(t, warmup_sim)
    return t


def _run_all(cfg_fn, techniques, ctrl, warmup_sim, seeds=(0,)):
    out = {}
    for name in techniques:
        sums = []
        for seed in seeds:
            cfg = cfg_fn(seed)
            sim = Simulation(cfg, technique=_make_technique(
                name, ctrl, warmup_sim))
            sums.append(sim.run())
        out[name] = {k: float(np.mean([s[k] for s in sums]))
                     for k in QOS_KEYS}
    return out


def _prep(full: bool):
    """Train START + warmup sim once, reused by every figure."""
    train_cfg = _cfg(full, seed=7)
    ctrl = pretrain(train_cfg, epochs=8 if not full else 30, lr=1e-3)
    warm = Simulation(_cfg(full, seed=9))
    warm.run()
    return ctrl, warm


def fig6_utilization(full: bool = False, ctrl=None, warm=None) -> dict:
    """QoS vs reserved utilization (20-80%)."""
    if ctrl is None:
        ctrl, warm = _prep(full)
    techniques = ["start"] + BASELINES + ["none"]
    rows = []
    results = {}
    for res in (0.2, 0.4, 0.6, 0.8):
        r = _run_all(lambda seed: _cfg(full, reserved_utilization=res,
                                       seed=seed),
                     techniques, ctrl, warm)
        results[res] = r
        for name, qos in r.items():
            rows.append([res, name] + [qos[k] for k in QOS_KEYS])
    write_csv("fig6_utilization.csv", ["reserved", "technique"] + QOS_KEYS,
              rows)
    return _headline(results)


def fig7_workloads(full: bool = False, ctrl=None, warm=None) -> dict:
    """QoS vs number of workloads (arrival-rate sweep)."""
    if ctrl is None:
        ctrl, warm = _prep(full)
    techniques = ["start"] + BASELINES + ["none"]
    rows = []
    results = {}
    for lam in (0.8, 1.2, 1.8, 2.4):
        r = _run_all(lambda seed: _cfg(full, arrival_rate=lam, seed=seed),
                     techniques, ctrl, warm)
        results[lam] = r
        for name, qos in r.items():
            rows.append([lam, name] + [qos[k] for k in QOS_KEYS])
    write_csv("fig7_workloads.csv", ["arrival_rate", "technique"]
              + QOS_KEYS, rows)
    return _headline(results)


def fig8_completion_variance(full: bool = False, ctrl=None,
                             warm=None) -> dict:
    """Completion-time variance across workloads per technique."""
    if ctrl is None:
        ctrl, warm = _prep(full)
    rows = []
    out = {}
    for name in ["start"] + BASELINES:
        for res in (0.2, 0.8):
            sim = Simulation(_cfg(full, reserved_utilization=res, seed=3),
                             technique=_make_technique(name, ctrl, warm))
            sim.run()
            times = np.concatenate(
                [r["times"] for r in sim.completed_jobs]) \
                if sim.completed_jobs else np.zeros(1)
            rows.append([name, res, float(times.mean()),
                         float(times.std()), float(np.percentile(times,
                                                                 99))])
            out[(name, res)] = float(times.std())
    write_csv("fig8_completion.csv",
              ["technique", "reserved", "mean_s", "std_s", "p99_s"], rows)
    start_std = np.mean([v for (n, _), v in out.items() if n == "start"])
    base_std = np.mean([v for (n, _), v in out.items() if n != "start"])
    return {"start_std": start_std, "baseline_std": base_std}


def fig9_mape(full: bool = False, ctrl=None, warm=None) -> dict:
    """Prediction accuracy: MAPE of START vs IGRU-SD vs RPPS."""
    if ctrl is None:
        ctrl, warm = _prep(full)
    rows = []
    out = {}
    for name in ("start", "igru-sd", "rpps"):
        vals = []
        for seed in (0, 1, 2):
            sim = Simulation(_cfg(full, seed=seed),
                             technique=_make_technique(name, ctrl, warm))
            sim.run()
            actual = sim.actual_stragglers_per_interval()
            pred = np.array(sim.log.predicted_stragglers, float)
            m = mape(actual, pred)
            if np.isfinite(m):
                vals.append(m)
        out[name] = float(np.mean(vals)) if vals else float("nan")
        rows.append([name, out[name]])
    write_csv("fig9_mape.csv", ["technique", "mape_pct"], rows)
    return out


def fig10_overhead(full: bool = False, ctrl=None, warm=None) -> dict:
    """Decision overhead per technique amortized over task exec time."""
    if ctrl is None:
        ctrl, warm = _prep(full)
    rows = []
    out = {}
    for name in ["start"] + BASELINES:
        sim = Simulation(_cfg(full, seed=4),
                         technique=_make_technique(name, ctrl, warm))
        s = sim.run()
        oh = s["avg_overhead_s"]
        rel = oh / max(s["avg_execution_time_s"], 1e-9) * 100
        rows.append([name, oh * 1e3, rel])
        out[name] = rel
    write_csv("fig10_overhead.csv",
              ["technique", "overhead_ms_per_interval",
               "pct_of_exec_time"], rows)
    return out


def fig2_grid_search(full: bool = False) -> dict:
    """k / I / T grid (paper Fig. 2): F1 of straggler classification on
    held-out jobs using MLE-fit Pareto + threshold k."""
    cfg = _cfg(full, seed=11)
    sim = Simulation(cfg)
    sim.run()
    jobs = sim.completed_jobs
    rows = []
    best = (None, -1.0)
    import jax.numpy as jnp
    for k in (1.1, 1.3, 1.5, 1.7, 2.0):
        tp = fp = fn = 0
        for rec in jobs:
            times = rec["times"]
            a, b = pareto.fit_pareto(jnp.asarray(times))
            thr = float(pareto.straggler_threshold(a, b, k))
            pred = times > thr
            truth = rec["straggler"]  # ground truth at k=1.5 (paper's def)
            tp += int((pred & truth).sum())
            fp += int((pred & ~truth).sum())
            fn += int((~pred & truth).sum())
        f1 = tp / max(tp + 0.5 * (fp + fn), 1e-9)
        rows.append([k, f1])
        if f1 > best[1]:
            best = (k, f1)
    write_csv("fig2_grid.csv", ["k", "f1"], rows)
    return {"best_k": best[0], "best_f1": best[1]}


def _headline(results: dict) -> dict:
    """START's % improvement vs best/worst baseline, averaged over the
    sweep variable (the paper's Figs. 6-7 headline numbers)."""
    gains: dict = {}
    for k in ("avg_execution_time_s", "resource_contention", "energy_kwh",
              "sla_violation_rate"):
        deltas_best, deltas_worst = [], []
        for _, r in results.items():
            s = r["start"][k]
            base = [r[n][k] for n in BASELINES]
            if min(base) > 0:
                deltas_best.append(100 * (min(base) - s) / min(base))
                deltas_worst.append(100 * (max(base) - s) / max(base))
        gains[k] = {"vs_best_baseline_pct": float(np.mean(deltas_best)),
                    "vs_worst_baseline_pct": float(np.mean(deltas_worst))}
    return gains
