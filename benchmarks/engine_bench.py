"""Single-cell engine benchmark: the `planetlab x start` sweep cell.

Measures what the sweep subsystem pays per grid cell — the quantity that
multiplies every Table-4-style experiment — and writes a perf-trajectory
artifact to the repo root (``BENCH_engine.json``):

  * ``cold_wall_s``   — first cell in a fresh process (includes the XLA
    compiles for the predict-path batch buckets);
  * ``warm_wall_s``   — steady-state cell (what a persistent sweep worker
    pays from its second cell on);
  * ``intervals_per_s`` (warm), ``predict_ms_per_interval`` (policy
    decision overhead, dominated by Encoder-LSTM inference);
  * ``retraces_during_cell`` + ``buckets`` — ``predict_sequence`` must
    compile at most once per power-of-two job-batch bucket;
  * speedups vs the pre-vectorization mainline (constants measured on the
    same container at the branch point; override with ``--baseline-cold``/
    ``--baseline-warm`` when re-baselining on other hardware).

    PYTHONPATH=src python benchmarks/engine_bench.py [--quick]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from common import write_csv  # noqa: E402

from repro.core import encoder_lstm as net  # noqa: E402
from repro.sim import sweep  # noqa: E402
from repro.sim.engine import Simulation  # noqa: E402
from repro.sim.sweep import SweepSpec  # noqa: E402

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# mainline (pre-array-native hot path) reference, measured on the CI
# container with this exact sizing: per-task placement loop, dict job
# bookkeeping, per-job jnp feature assembly, eager pareto tail.
BASELINE_MAIN = {"cold_wall_s": 3.978, "warm_wall_s": 0.561}


def bench_cell(n_hosts: int, n_intervals: int):
    spec = SweepSpec(techniques=("start",), seeds=(0,),
                     scenarios=("planetlab",), n_hosts=n_hosts,
                     n_intervals=n_intervals, arrival_rate=0.6,
                     max_workers=1, pretrain_epochs=8)
    cfg = spec.cell_config("planetlab", 0)

    t0 = time.perf_counter()
    tech = sweep.make_technique("start", cfg, pretrain_epochs=8)
    pretrain_s = time.perf_counter() - t0

    compiles_before = net.predict_sequence._cache_size()
    t0 = time.perf_counter()
    sim = Simulation(cfg, technique=tech)
    sim.run()
    cold_wall_s = time.perf_counter() - t0
    retraces = net.predict_sequence._cache_size() - compiles_before

    # steady state: what a persistent sweep worker pays per cell once the
    # jit caches are warm (fresh technique instance, same trained params)
    warm_walls = []
    for _ in range(3):
        tech = sweep.make_technique("start", cfg, pretrain_epochs=8)
        t0 = time.perf_counter()
        sim = Simulation(cfg, technique=tech)
        sim.run()
        warm_walls.append(time.perf_counter() - t0)
    warm_wall_s = float(min(warm_walls))
    warm_retraces = (net.predict_sequence._cache_size()
                     - compiles_before - retraces)

    predict_ms = float(np.mean(sim.log.overhead_s) * 1e3)
    buckets = sorted(tech._controller.predictor.buckets_used)
    return dict(
        bench="planetlab-x-start",
        n_hosts=n_hosts, n_intervals=n_intervals, arrival_rate=0.6,
        pretrain_s=round(pretrain_s, 3),
        cold_wall_s=round(cold_wall_s, 3),
        warm_wall_s=round(warm_wall_s, 3),
        intervals_per_s=round(n_intervals / warm_wall_s, 2),
        predict_ms_per_interval=round(predict_ms, 3),
        retraces_during_cell=int(retraces),
        retraces_during_warm_cells=int(warm_retraces),
        buckets=buckets,
    )


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller cell for CI smoke runs")
    ap.add_argument("--hosts", type=int, default=None)
    ap.add_argument("--intervals", type=int, default=None)
    ap.add_argument("--baseline-cold", type=float,
                    default=BASELINE_MAIN["cold_wall_s"])
    ap.add_argument("--baseline-warm", type=float,
                    default=BASELINE_MAIN["warm_wall_s"])
    args = ap.parse_args(argv)

    n_hosts = args.hosts or (16 if args.quick else 32)
    n_intervals = args.intervals or (36 if args.quick else 72)
    out = bench_cell(n_hosts, n_intervals)
    default_sizing = n_hosts == 32 and n_intervals == 72
    out["baseline_main"] = ({"cold_wall_s": args.baseline_cold,
                             "warm_wall_s": args.baseline_warm}
                            if default_sizing else None)
    if default_sizing:  # speedups only comparable at the measured sizing
        out["speedup_cold"] = round(args.baseline_cold
                                    / out["cold_wall_s"], 2)
        out["speedup_warm"] = round(args.baseline_warm
                                    / out["warm_wall_s"], 2)

    path = os.path.join(REPO_ROOT, "BENCH_engine.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=1, sort_keys=True)
        f.write("\n")
    write_csv("engine_bench.csv", ["metric", "value"],
              [[k, json.dumps(v)] for k, v in out.items()])

    print(json.dumps(out, indent=1, sort_keys=True))
    print(f"wrote {path}")
    return out


if __name__ == "__main__":
    main()
