"""Single-cell engine benchmark: the `planetlab x start` sweep cell.

Measures what the sweep subsystem pays per grid cell — the quantity that
multiplies every Table-4-style experiment — and writes a perf-trajectory
artifact to the repo root (``BENCH_engine.json``):

  * ``cold_wall_s``   — first cell in a fresh process (includes the XLA
    compiles for the predict-path batch buckets);
  * ``warm_wall_s``   — steady-state cell (what a persistent sweep worker
    pays from its second cell on; best of several runs — shared runners
    are noisy);
  * ``intervals_per_s`` (warm), ``predict_ms_per_interval`` (policy
    decision overhead: the fused device step + feature assembly + the
    Algorithm-1 trigger logic);
  * ``retraces_during_cell`` + ``buckets`` — the prediction programs
    (fused step + unfused network) must compile at most once per
    power-of-two job-batch bucket;
  * ``fused_step`` — whether the fused per-interval device program was
    active (the default; ``--no-fused`` measures the historical unfused
    path — the Tier-0 bitwise reference, which re-uploads the M_H
    history and pays extra dispatches per interval);
  * ``tier1_drift`` — worst observed fused-vs-unfused drift across a
    job-count sweep at this sizing, with the documented Tier-1 bound
    (tests/tolerance.py) alongside — ``check_perf.py`` warns when the
    drift trajectory grows versus the committed artifact;
  * speedups vs two baselines measured on the same container at their
    branch points: ``baseline_main`` (pre-vectorization mainline) and
    ``baseline_pr3`` (the PR 3/4 array-native path).  Committed-
    trajectory numbers from other hardware are kept in the file for
    cross-reference, speedups are computed against the same-host ones.

    PYTHONPATH=src python benchmarks/engine_bench.py [--quick]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from common import host_fingerprint, write_csv  # noqa: E402

from repro.core import features  # noqa: E402
from repro.core import predictor as P  # noqa: E402
from repro.core import encoder_lstm as net  # noqa: E402
from repro.sim import sweep  # noqa: E402
from repro.sim.engine import Simulation  # noqa: E402
from repro.sim.sweep import SweepSpec  # noqa: E402

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO_ROOT, "tests"))
from tolerance import TIER1_MAX_ULP, TIER1_REL, drift  # noqa: E402

# mainline (pre-array-native hot path) reference, measured on the CI
# container with this exact sizing: per-task placement loop, dict job
# bookkeeping, per-job jnp feature assembly, eager pareto tail.
BASELINE_MAIN = {"cold_wall_s": 3.978, "warm_wall_s": 0.561}

# the PR 3/4 array-native path (bucketed jitted inference, host-side
# feature assembly re-uploaded per interval), re-measured on THIS
# container at the PR 5 branch point (best-of interleaved runs; the
# committed trajectory from the PR 3 container read 0.168 s / 2.09 ms).
BASELINE_PR3 = {"warm_wall_s": 0.149, "predict_ms_per_interval": 1.681,
                "committed": {"cold_wall_s": 2.061, "warm_wall_s": 0.168,
                              "predict_ms_per_interval": 2.091}}


def _compiles() -> int:
    return net.predict_sequence._cache_size() + P.fused_compile_count()


def measure_tier1_drift(n_hosts: int, max_tasks: int = 10,
                        counts=(1, 2, 3, 5, 8, 9, 12, 16)) -> dict:
    """Worst observed fused-vs-unfused drift across a job-count sweep at
    the bench sizing — the Tier-1 determinism contract's trajectory
    number.  Recorded in ``BENCH_engine.json`` so ``check_perf.py`` can
    warn (non-gating) when a rewrite pushes the drift up, before the
    hard TIER1_REL gate in the test suite ever fires."""
    pred = P.StragglerPredictor(n_hosts=n_hosts, max_tasks=max_tasks)
    rng = np.random.default_rng(0)
    t = pred.horizon
    rows = [rng.uniform(0, 1, (n_hosts, features.HOST_FEATURES))
            .astype(np.float32) for _ in range(t)]
    for r in rows:
        pred.push_host_row(r)
    worst = {"max_rel": 0.0, "max_abs": 0.0, "max_ulp": 0}
    for n in counts:
        m_t = rng.uniform(0, 1, (n, max_tasks, features.TASK_FEATURES)) \
            .astype(np.float32)
        q = rng.integers(1, max_tasks + 1, n).astype(np.float32)
        got = pred.predict_interval(m_t, q)
        ref = pred.predict_features(np.stack(rows[-t:]), m_t, q)
        d = drift(got, np.asarray(ref.e_s))
        for k in worst:
            worst[k] = max(worst[k], d[k])
        rows.append(rng.uniform(0, 1, (n_hosts, features.HOST_FEATURES))
                    .astype(np.float32))
        pred.push_host_row(rows[-1])
    return {"bound_rel": TIER1_REL, "max_ulp_pin": TIER1_MAX_ULP,
            "counts": list(counts), **worst}


def bench_cell(n_hosts: int, n_intervals: int, fused: bool = True):
    spec = SweepSpec(techniques=("start",), seeds=(0,),
                     scenarios=("planetlab",), n_hosts=n_hosts,
                     n_intervals=n_intervals, arrival_rate=0.6,
                     max_workers=1, pretrain_epochs=8)
    cfg = spec.cell_config("planetlab", 0)
    tkw = {} if fused else {"use_fused_step": False}

    def make():
        return sweep.make_technique("start", cfg, pretrain_epochs=8,
                                    technique_kwargs=tkw)

    t0 = time.perf_counter()
    tech = make()
    pretrain_s = time.perf_counter() - t0

    compiles_before = _compiles()
    t0 = time.perf_counter()
    sim = Simulation(cfg, technique=tech)
    sim.run()
    cold_wall_s = time.perf_counter() - t0
    retraces = _compiles() - compiles_before

    # steady state: what a persistent sweep worker pays per cell once the
    # jit caches are warm (fresh technique instance, same trained params)
    warm_walls, predict_ms_runs = [], []
    for _ in range(4):
        tech = make()
        t0 = time.perf_counter()
        sim = Simulation(cfg, technique=tech)
        sim.run()
        warm_walls.append(time.perf_counter() - t0)
        predict_ms_runs.append(float(np.mean(sim.log.overhead_s)) * 1e3)
    warm_wall_s = float(min(warm_walls))
    predict_ms = float(min(predict_ms_runs))
    warm_retraces = _compiles() - compiles_before - retraces

    buckets = sorted(tech._controller.predictor.buckets_used)
    tier1 = measure_tier1_drift(
        n_hosts, max_tasks=cfg.max_tasks) if fused else None
    return dict(
        tier1_drift=tier1,
        bench="planetlab-x-start",
        host=host_fingerprint(),
        n_hosts=n_hosts, n_intervals=n_intervals, arrival_rate=0.6,
        fused_step=fused,
        pretrain_s=round(pretrain_s, 3),
        cold_wall_s=round(cold_wall_s, 3),
        warm_wall_s=round(warm_wall_s, 3),
        intervals_per_s=round(n_intervals / warm_wall_s, 2),
        predict_ms_per_interval=round(predict_ms, 3),
        retraces_during_cell=int(retraces),
        retraces_during_warm_cells=int(warm_retraces),
        buckets=buckets,
    )


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller cell for CI smoke runs")
    ap.add_argument("--hosts", type=int, default=None)
    ap.add_argument("--intervals", type=int, default=None)
    ap.add_argument("--no-fused", action="store_true",
                    help="measure the historical (unfused) predict path")
    ap.add_argument("--baseline-cold", type=float,
                    default=BASELINE_MAIN["cold_wall_s"])
    ap.add_argument("--baseline-warm", type=float,
                    default=BASELINE_MAIN["warm_wall_s"])
    ap.add_argument("--baseline-pr3-warm", type=float,
                    default=BASELINE_PR3["warm_wall_s"],
                    help="re-baseline when benching on other hardware")
    args = ap.parse_args(argv)

    n_hosts = args.hosts or (16 if args.quick else 32)
    n_intervals = args.intervals or (36 if args.quick else 72)
    out = bench_cell(n_hosts, n_intervals, fused=not args.no_fused)
    default_sizing = n_hosts == 32 and n_intervals == 72
    out["baseline_main"] = ({"cold_wall_s": args.baseline_cold,
                             "warm_wall_s": args.baseline_warm}
                            if default_sizing else None)
    if default_sizing:  # speedups only comparable at the measured sizing
        out["baseline_pr3"] = dict(BASELINE_PR3,
                                   warm_wall_s=args.baseline_pr3_warm)
        out["speedup_cold"] = round(args.baseline_cold
                                    / out["cold_wall_s"], 2)
        out["speedup_warm"] = round(args.baseline_warm
                                    / out["warm_wall_s"], 2)
        out["speedup_warm_vs_pr3"] = round(args.baseline_pr3_warm
                                           / out["warm_wall_s"], 2)
        out["predict_speedup_vs_pr3"] = round(
            BASELINE_PR3["predict_ms_per_interval"]
            / out["predict_ms_per_interval"], 2)

    path = os.path.join(REPO_ROOT, "BENCH_engine.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=1, sort_keys=True)
        f.write("\n")
    write_csv("engine_bench.csv", ["metric", "value"],
              [[k, json.dumps(v)] for k, v in out.items()])

    print(json.dumps(out, indent=1, sort_keys=True))
    print(f"wrote {path}")
    return out


if __name__ == "__main__":
    main()
