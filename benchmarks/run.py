"""Benchmark entry point: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (scaffold contract), followed
by the figure headline summaries and — when dry-run artifacts exist — the
roofline table. ``--full`` switches the simulator to Table-4 scale.

  PYTHONPATH=src python -m benchmarks.run [--full] [--skip-sim]
"""
from __future__ import annotations

import argparse
import json
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale simulation (400 hosts, 288 ivals)")
    ap.add_argument("--skip-sim", action="store_true")
    args, _ = ap.parse_known_args()

    print("name,us_per_call,derived")

    from benchmarks.kernel_bench import rows as kernel_rows
    for r in kernel_rows():
        print(",".join(str(x) for x in r))

    if not args.skip_sim:
        from benchmarks import sim_experiments as S
        t0 = time.time()
        S.prep(args.full)  # warm the sweep pretrain cache once
        print(f"prep_start_training,{(time.time() - t0) * 1e6:.0f},"
              f"epochs+warmup")

        for name, fn in (("fig2_grid", S.fig2_grid_search),
                         ("fig6_utilization", S.fig6_utilization),
                         ("fig7_workloads", S.fig7_workloads),
                         ("fig8_completion", S.fig8_completion_variance),
                         ("fig9_mape", S.fig9_mape),
                         ("fig10_overhead", S.fig10_overhead)):
            t0 = time.time()
            out = fn(args.full)
            us = (time.time() - t0) * 1e6
            print(f"{name},{us:.0f},{json.dumps(out)}")

    try:
        from benchmarks.roofline import table
        t = table()
        if t.count("\n") > 1:
            print("\n=== Roofline (from dry-run artifacts) ===")
            print(t)
    except Exception as e:  # artifacts may not exist yet
        print(f"roofline_table,0,unavailable: {e}")


if __name__ == "__main__":
    main()
