"""Kernel microbenchmarks.

Two surfaces:

  * :func:`rows` — jitted XLA-oracle wall time on CPU for the scaffold's
    CSV contract (the Pallas kernels are TPU-targeted; interpret mode is
    a correctness harness, not a timing one — see DESIGN.md), consumed
    by ``benchmarks/run.py``;
  * :func:`main` — the fused Pallas **LSTM cell** benchmark (forward +
    custom-VJP backward, vs the jnp reference cell), written to
    ``BENCH_kernel.json`` for the CI perf-smoke lane.  On this container
    it runs the kernel in **interpret mode** (Pallas emulated op by op —
    the number is a correctness-path cost, expected to be much slower
    than the XLA reference); on a TPU host the same entry point times
    the compiled Mosaic kernel (``interpret=False``) with no code
    change.  The artifact carries a host fingerprint and the backend, so
    ``check_perf.py``-style consumers never compare across hardware.

    PYTHONPATH=src python benchmarks/kernel_bench.py [--repeats N]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from common import host_fingerprint  # noqa: E402

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import encoder_lstm as net
from repro.kernels.decode_attention import decode_attention_xla
from repro.kernels.flash_attention import attention_xla
from repro.kernels.lstm_cell import lstm_cell, lstm_cell_ref
from repro.kernels.lstm_cell.lstm_cell import lstm_cell_pallas
from repro.kernels.mamba_scan import mamba_scan_xla
from repro.kernels.moe_router import moe_router_xla

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _time(fn, *args, repeats=5, **kw):
    out = fn(*args, **kw)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn(*args, **kw)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / repeats * 1e6


def rows() -> list[list]:
    ks = jax.random.split(jax.random.PRNGKey(0), 8)
    out = []

    b, h, hkv, s, d = 1, 8, 2, 512, 64
    q = jax.random.normal(ks[0], (b, h, s, d), jnp.bfloat16)
    k = jax.random.normal(ks[1], (b, hkv, s, d), jnp.bfloat16)
    v = jax.random.normal(ks[2], (b, hkv, s, d), jnp.bfloat16)
    us = _time(attention_xla, q, k, v)
    flops = 4 * b * h * s * s * d
    out.append(["flash_attention_xla_512", round(us, 1),
                f"{flops / us * 1e-3:.1f}GF/s"])

    qd = jax.random.normal(ks[3], (4, h, d), jnp.bfloat16)
    kc = jax.random.normal(ks[4], (4, hkv, 2048, d), jnp.bfloat16)
    us = _time(decode_attention_xla, qd, kc, kc)
    out.append(["decode_attention_xla_2k", round(us, 1),
                f"kv_bytes={kc.nbytes * 2}"])

    bl, ell, dm, n = 1, 256, 256, 16
    u = jax.random.normal(ks[5], (bl, ell, dm), jnp.bfloat16)
    delta = jax.nn.softplus(jax.random.normal(ks[6], (bl, ell, dm),
                                              jnp.bfloat16))
    a = -jnp.exp(jax.random.normal(ks[7], (dm, n)))
    bm = jax.random.normal(ks[5], (bl, ell, n), jnp.bfloat16)
    cm = jax.random.normal(ks[6], (bl, ell, n), jnp.bfloat16)
    us = _time(mamba_scan_xla, u, delta, a, bm, cm, jnp.ones(dm))
    out.append(["mamba_scan_xla_256", round(us, 1), f"L={ell} D={dm}"])

    logits = jax.random.normal(ks[0], (2048, 128))
    us = _time(moe_router_xla, logits, 8)
    out.append(["moe_router_xla_2k_128e", round(us, 1), "top8"])

    # the paper's own hot loop: batched encoder-LSTM inference
    params = net.init_params(jax.random.PRNGKey(0), input_dim=490)
    xs = jax.random.normal(jax.random.PRNGKey(1), (5, 256, 490))
    us = _time(net.predict_sequence, params, xs)
    out.append(["encoder_lstm_predict_256jobs", round(us, 1), "T=5"])
    return out


# ------------------- fused Pallas LSTM cell -> BENCH_kernel.json ------------


def _median_us(fn, *args, repeats: int = 20) -> float:
    out = fn(*args)
    jax.block_until_ready(out)        # compile outside the timed region
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)) * 1e6


def _cell_args(batch: int, hidden: int, n_in: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(batch, n_in)), jnp.float32)
    h = jnp.asarray(rng.normal(size=(batch, hidden)), jnp.float32)
    c = jnp.asarray(rng.normal(size=(batch, hidden)), jnp.float32)
    layer = net._lstm_init(jax.random.PRNGKey(seed), n_in, hidden)
    return x, h, c, layer["wx"], layer["wh"], layer["b"]


def bench_lstm_cell(repeats: int = 20, interpret: bool | None = None
                    ) -> dict:
    """Time the fused LSTM cell (forward + custom-VJP backward) against
    the jnp reference at model-relevant shapes.

    ``interpret=None`` resolves from the backend: interpret mode on CPU
    (this container), compiled Mosaic on TPU — the TPU path is the same
    call with ``interpret=False``.
    """
    backend = jax.default_backend()
    if interpret is None:
        interpret = backend != "tpu"

    def pallas_fwd(x, h, c, wx, wh, b):
        # the public custom_vjp op (interpret hardcoded in ops.py) when
        # emulating; the raw pallas_call when compiled for real hardware
        if interpret:
            return lstm_cell(x, h, c, wx, wh, b)
        return lstm_cell_pallas(x, h, c, wx, wh, b, interpret=False)

    def grad_of(cell):
        def loss(x, h, c, wx, wh, b):
            h2, c2 = cell(x, h, c, wx, wh, b)
            return (h2 * h2 + c2).sum()
        return jax.grad(loss, argnums=(3, 4, 5))

    results = []
    # (batch, hidden) — hidden 32 is the model's LSTM_HIDDEN; 128 the
    # block-padded serving shape; 64/256 headroom points
    for batch, hidden in ((128, 32), (256, 32), (256, 64)):
        n_in = hidden  # encoder output feeds the cell at ENC_OUT == H
        args = _cell_args(batch, hidden, n_in)
        row = {"batch": batch, "hidden": hidden, "n_in": n_in}
        row["ref_fwd_us"] = round(_median_us(
            jax.jit(lstm_cell_ref), *args, repeats=repeats), 1)
        row["pallas_fwd_us"] = round(_median_us(
            jax.jit(pallas_fwd), *args, repeats=repeats), 1)
        row["ref_vjp_us"] = round(_median_us(
            jax.jit(grad_of(lstm_cell_ref)), *args, repeats=repeats), 1)
        row["pallas_vjp_us"] = round(_median_us(
            jax.jit(grad_of(lstm_cell)), *args, repeats=repeats), 1)
        # correctness cross-check rides along: the kernel is bitwise vs
        # the reference (tested), so any drift here is a bench bug
        h_ref, c_ref = jax.jit(lstm_cell_ref)(*args)
        h_pal, c_pal = jax.jit(pallas_fwd)(*args)
        row["bitwise_fwd"] = bool(
            np.array_equal(np.asarray(h_ref), np.asarray(h_pal))
            and np.array_equal(np.asarray(c_ref), np.asarray(c_pal)))
        results.append(row)

    return {
        "host": host_fingerprint(),
        "backend": backend,
        "interpret": bool(interpret),
        "mode": "interpret" if interpret else "compiled",
        "repeats": repeats,
        "jax": jax.__version__,
        "cells": results,
    }


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--repeats", type=int, default=20)
    ap.add_argument("--out", default=os.path.join(REPO_ROOT,
                                                  "BENCH_kernel.json"))
    args = ap.parse_args(argv)
    out = bench_lstm_cell(repeats=args.repeats)
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1, sort_keys=True)
        f.write("\n")
    print(json.dumps(out, indent=1, sort_keys=True))
    return out


if __name__ == "__main__":
    sys.exit(0 if main() else 1)
