"""Kernel microbenchmarks: jitted XLA-oracle wall time on CPU (the Pallas
kernels are TPU-targeted; interpret mode is a correctness harness, not a
timing one — see DESIGN.md). Emits name,us_per_call,derived rows."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import encoder_lstm as net
from repro.kernels.decode_attention import decode_attention_xla
from repro.kernels.flash_attention import attention_xla
from repro.kernels.mamba_scan import mamba_scan_xla
from repro.kernels.moe_router import moe_router_xla


def _time(fn, *args, repeats=5, **kw):
    out = fn(*args, **kw)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn(*args, **kw)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / repeats * 1e6


def rows() -> list[list]:
    ks = jax.random.split(jax.random.PRNGKey(0), 8)
    out = []

    b, h, hkv, s, d = 1, 8, 2, 512, 64
    q = jax.random.normal(ks[0], (b, h, s, d), jnp.bfloat16)
    k = jax.random.normal(ks[1], (b, hkv, s, d), jnp.bfloat16)
    v = jax.random.normal(ks[2], (b, hkv, s, d), jnp.bfloat16)
    us = _time(attention_xla, q, k, v)
    flops = 4 * b * h * s * s * d
    out.append(["flash_attention_xla_512", round(us, 1),
                f"{flops / us * 1e-3:.1f}GF/s"])

    qd = jax.random.normal(ks[3], (4, h, d), jnp.bfloat16)
    kc = jax.random.normal(ks[4], (4, hkv, 2048, d), jnp.bfloat16)
    us = _time(decode_attention_xla, qd, kc, kc)
    out.append(["decode_attention_xla_2k", round(us, 1),
                f"kv_bytes={kc.nbytes * 2}"])

    bl, ell, dm, n = 1, 256, 256, 16
    u = jax.random.normal(ks[5], (bl, ell, dm), jnp.bfloat16)
    delta = jax.nn.softplus(jax.random.normal(ks[6], (bl, ell, dm),
                                              jnp.bfloat16))
    a = -jnp.exp(jax.random.normal(ks[7], (dm, n)))
    bm = jax.random.normal(ks[5], (bl, ell, n), jnp.bfloat16)
    cm = jax.random.normal(ks[6], (bl, ell, n), jnp.bfloat16)
    us = _time(mamba_scan_xla, u, delta, a, bm, cm, jnp.ones(dm))
    out.append(["mamba_scan_xla_256", round(us, 1), f"L={ell} D={dm}"])

    logits = jax.random.normal(ks[0], (2048, 128))
    us = _time(moe_router_xla, logits, 8)
    out.append(["moe_router_xla_2k_128e", round(us, 1), "top8"])

    # the paper's own hot loop: batched encoder-LSTM inference
    params = net.init_params(jax.random.PRNGKey(0), input_dim=490)
    xs = jax.random.normal(jax.random.PRNGKey(1), (5, 256, 490))
    us = _time(net.predict_sequence, params, xs)
    out.append(["encoder_lstm_predict_256jobs", round(us, 1), "T=5"])
    return out
