"""Shared benchmark utilities: CSV/artifact emission, technique runners."""
from __future__ import annotations

import csv
import os
import platform
import time

ARTIFACTS = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                         "artifacts")


def host_fingerprint() -> str:
    """Coarse hardware identity for the perf artifacts: wall-clock
    numbers are only comparable between benches run on matching
    fingerprints (``check_perf.py`` skips the regression compare on
    mismatch)."""
    return f"{platform.machine()}-{os.cpu_count()}cpu-{platform.system()}"


def write_csv(name: str, header: list[str], rows: list[list]) -> str:
    os.makedirs(ARTIFACTS, exist_ok=True)
    path = os.path.join(ARTIFACTS, name)
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(header)
        w.writerows(rows)
    return path


def bench_row(name: str, fn, *args, derived="", repeats: int = 1,
              **kw) -> list:
    """name,us_per_call,derived CSV row (scaffold contract)."""
    t0 = time.perf_counter()
    out = None
    for _ in range(repeats):
        out = fn(*args, **kw)
    us = (time.perf_counter() - t0) / repeats * 1e6
    return [name, round(us, 1), derived if derived else out]
