"""Roofline analysis from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch x shape x mesh) JSON artifact:
  compute_s    = HLO_FLOPs_per_device / 197e12        (v5e bf16 peak)
  memory_s     = HLO_bytes_per_device / 819e9          (HBM bw)
  collective_s = collective_bytes_per_device / 50e9    (ICI per link)
  bound        = argmax of the three
  model_flops  = 6*N*D (dense) or 6*N_active*D (MoE) per step
  ratio        = model_flops / (HLO_FLOPs * n_devices)

For train cells D = tokens/step; for prefill D = prompt tokens; for decode
D = batch (1 new token per sequence).
"""
from __future__ import annotations

import glob
import json
import os

PEAK_FLOPS = 197e12       # TPU v5e bf16 per chip
HBM_BW = 819e9            # bytes/s per chip
ICI_BW = 50e9             # bytes/s per link

DRYRUN_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                          "artifacts", "dryrun")


def tokens_for(rec: dict) -> float:
    from repro.configs.shapes import SHAPES
    shape = SHAPES[rec["shape"]]
    if shape.kind in ("train", "prefill"):
        return shape.global_batch * shape.seq_len
    return shape.global_batch  # decode: one token per sequence


def analyze(rec: dict) -> dict:
    """Three-term roofline with a two-sided memory estimate.

    memory_floor_s: per-step working set (argument+temp bytes from
    memory_analysis) / HBM bw — the fused-TPU behaviour where kernel
    state stays in VMEM and each resident byte is touched O(1) times.
    memory_ceil_s: the loop-aware per-op operand+result bytes — a
    zero-fusion upper bound (wildly pessimistic for recurrent scans).
    The bound classification and MFU use the floor; both are reported.
    """
    if rec.get("status") != "ok":
        return dict(rec)
    compute_s = rec["flops_per_device"] / PEAK_FLOPS
    ws = rec["memory"]["argument_bytes"] + rec["memory"]["temp_bytes"]
    memory_floor_s = ws / HBM_BW
    memory_ceil_s = rec["bytes_per_device"] / HBM_BW
    coll_s = rec["collective_bytes_per_device"]["total"] / ICI_BW
    terms = {"compute": compute_s, "memory": memory_floor_s,
             "collective": coll_s}
    bound = max(terms, key=terms.get)
    n_active = rec.get("active_params") or rec.get("params")
    toks = tokens_for(rec)
    grad_mult = 3.0 if rec["shape"].startswith("train") else 1.0
    model_flops = 2.0 * grad_mult * n_active * toks
    hlo_total = rec["flops_per_device"] * rec["n_devices"]
    ratio = model_flops / hlo_total if hlo_total else 0.0
    # roofline fraction: useful model flops per second at the bottleneck
    step_s = max(terms.values())
    mfu = model_flops / (rec["n_devices"] * PEAK_FLOPS * step_s) \
        if step_s > 0 else 0.0
    return dict(
        rec,
        compute_s=compute_s, memory_s=memory_floor_s,
        memory_ceil_s=memory_ceil_s, collective_s=coll_s,
        bound=bound, model_flops=model_flops, useful_ratio=ratio,
        roofline_mfu=mfu, step_s=step_s,
    )


def load_all(tag: str = "") -> list[dict]:
    out = []
    for p in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(p) as f:
            rec = json.load(f)
        if rec.get("tag", "") != tag:
            continue
        out.append(analyze(rec))
    return out


def table(tag: str = "") -> str:
    rows = load_all(tag)
    hdr = (f"{'arch':<22} {'shape':<12} {'mesh':<9} {'bound':<10} "
           f"{'compute_s':>10} {'mem_floor':>10} {'mem_ceil':>10} "
           f"{'coll_s':>10} {'MFU':>6} {'useful':>7} {'temp GiB':>9}")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        if r.get("status") == "skipped":
            lines.append(f"{r['arch']:<22} {r['shape']:<12} "
                         f"{r['mesh']:<9} SKIP: {r['reason'][:60]}")
            continue
        if r.get("status") != "ok":
            lines.append(f"{r['arch']:<22} {r['shape']:<12} "
                         f"{r['mesh']:<9} ERROR: {r.get('error', '')[:60]}")
            continue
        lines.append(
            f"{r['arch']:<22} {r['shape']:<12} {r['mesh']:<9} "
            f"{r['bound']:<10} {r['compute_s']:>10.4f} "
            f"{r['memory_s']:>10.4f} {r['memory_ceil_s']:>10.4f} "
            f"{r['collective_s']:>10.4f} "
            f"{r['roofline_mfu']:>6.1%} {r['useful_ratio']:>7.2f} "
            f"{r['memory']['temp_bytes'] / 2**30:>9.2f}")
    return "\n".join(lines)


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--tag", default="")
    args = ap.parse_args()
    print(table(args.tag))


if __name__ == "__main__":
    main()
