# Tier-1 test lanes + lint + benchmark entry points.

PY := python

.PHONY: test test-all lint sweep-bench engine-bench kernel-bench bench \
	regen-golden nightly-grid serve serve-bench chaos chaos-drill

test:  ## fast lane: what CI runs (slow-marked distributed tests excluded)
	PYTHONPATH=src $(PY) -m pytest -q -m "not slow"

lint:  ## ruff lane (configured in ruff.toml; pip install ruff)
	$(PY) -m ruff check src tests benchmarks examples

test-all:  ## full tier-1 suite (ROADMAP verify command)
	PYTHONPATH=src $(PY) -m pytest -x -q

chaos:  ## full chaos suite: fault-injection drills + codec property tests
	PYTHONPATH=src $(PY) -m pytest -q tests/test_chaos.py tests/test_codecs.py

chaos-drill:  ## seeded acceptance drills outside pytest -> artifacts/chaos/
	PYTHONPATH=src $(PY) benchmarks/chaos_drill.py --seeds $${REPRO_CHAOS_SEEDS:-0}

sweep-bench:  ## serial vs cold/warm-pool sweep benchmark -> BENCH_sweep.json
	PYTHONPATH=src $(PY) benchmarks/sweep_bench.py

engine-bench:  ## single-cell (planetlab x start) benchmark -> BENCH_engine.json
	PYTHONPATH=src $(PY) benchmarks/engine_bench.py

kernel-bench:  ## fused Pallas LSTM cell fwd+VJP benchmark -> BENCH_kernel.json
	PYTHONPATH=src $(PY) benchmarks/kernel_bench.py

serve:  ## prediction-service demo: daemon + TCP tenants + retrain cycle
	PYTHONPATH=src $(PY) examples/predict_service.py

serve-bench:  ## concurrent multi-tenant serving benchmark -> BENCH_serve.json
	PYTHONPATH=src $(PY) benchmarks/serve_bench.py

bench:  ## paper figure reproductions (scaled-down)
	PYTHONPATH=src $(PY) -m benchmarks.run

regen-golden:  ## re-bless tests/data/determinism_golden.json (intentional!)
	PYTHONPATH=src $(PY) benchmarks/regen_golden.py

nightly-grid:  ## Table-4-scale full-field sweep (what the nightly lane runs)
	PYTHONPATH=src $(PY) benchmarks/nightly_grid.py
