"""Explicit data-parallel training with error-feedback int8 gradient
compression — the cross-pod reduce trick from DESIGN.md §3, demonstrated
on 8 fake devices.

    PYTHONPATH=src python examples/compressed_dp.py
"""
import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import jax                                                   # noqa: E402
import jax.numpy as jnp                                      # noqa: E402
import numpy as np                                           # noqa: E402
from jax.sharding import PartitionSpec as P                  # noqa: E402

from jax.experimental.shard_map import shard_map             # noqa: E402

from repro.configs import get_reduced                        # noqa: E402
from repro.distributed import compression as C               # noqa: E402
from repro.launch.mesh import make_host_mesh                 # noqa: E402
from repro.models.lm import Model                            # noqa: E402
from repro.train.data import DataConfig, SyntheticLM         # noqa: E402
from repro.train.optimizer import OptConfig, init, update    # noqa: E402

mesh = make_host_mesh(n_data=8, n_model=1)
cfg = get_reduced("demo-100m")
model = Model(cfg)
params = model.init(jax.random.PRNGKey(0))
ocfg = OptConfig(lr=1e-2, warmup_steps=2, total_steps=60)
opt = init(ocfg, params)
residual = C.zero_residual(params)
data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=32,
                              global_batch=16))


def local_grads(params, batch):
    """Per-shard loss/grad + EF-int8 all-reduce over the data axis."""

    def f(p, b, r):
        loss, g = jax.value_and_grad(model.loss_fn)(p, b)
        red, new_r = C.ef_int8_reduce(g, r, "data")
        loss = jax.lax.pmean(loss, "data")
        return loss, red, new_r

    return shard_map(
        f, mesh=mesh,
        in_specs=(P(), P("data"), P()),
        out_specs=(P(), P(), P()))(params, batch, residual)


@jax.jit
def step(params, opt, residual, batch):
    loss, grads, residual = local_grads(params, batch)
    params, opt, m = update(ocfg, grads, opt, params)
    return params, opt, residual, loss


losses = []
for i in range(40):
    batch = data.batch(i)
    params, opt, residual, loss = step(params, opt, residual, batch)
    losses.append(float(loss))
    if i % 10 == 0:
        print(f"step {i} loss {losses[-1]:.4f} (int8-compressed reduce)")
print(f"loss {losses[0]:.3f} -> {losses[-1]:.3f} with 4x smaller "
      f"gradient payloads")
assert losses[-1] < losses[0]
