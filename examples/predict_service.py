"""Prediction as a service: the fused START decision step behind a
long-running daemon.

Two tenants stream telemetry snapshots to one ``ServiceDaemon`` over
its stdlib JSON-lines TCP transport.  The daemon's batch worker
coalesces concurrent tenants into a single device dispatch against one
shared Encoder-LSTM, answers each with its E_S / per-task straggler
scores / mitigation actions, and feeds completed-job durations into the
continuous-retraining replay buffer.  The demo then runs one
retrain -> shadow-eval -> promote cycle and an instant rollback, and
finally shows the pod runtime driving the same service as a client
(``start-pod-service``).

    PYTHONPATH=src python examples/predict_service.py
"""
import numpy as np

from repro.core import features
from repro.distributed.straggler_runtime import (RuntimeConfig,
                                                 ServiceBackedPodPolicy,
                                                 StragglerRuntime)
from repro.policy import wire
from repro.service import Profile, ServiceConfig, ServiceDaemon

N_HOSTS, MAX_TASKS, HORIZON = 4, 6, 5
HOT = 2            # chronically overloaded host


def snapshot(rng, tenant, seq, job_id, q, finished=None):
    """One interval of synthetic tenant telemetry (hot host planted)."""
    m_h = rng.random((N_HOSTS, features.HOST_FEATURES)) \
        .astype(np.float32)
    m_h[HOT, :3] *= 1.8
    m_t = np.zeros((MAX_TASKS, features.TASK_FEATURES), np.float32)
    m_t[:q] = rng.random((q, features.TASK_FEATURES))
    tasks = [(100 * job_id + i, (HOT + i) % N_HOSTS, i)
             for i in range(q)]
    done = []
    if finished is not None:
        times = 1.0 + rng.pareto(2.2, 3 * q).astype(np.float32)
        done = [{"id": finished, "times": times.tolist()}]
    return wire.snapshot_to_wire(
        tenant, seq, m_h,
        jobs=[wire.job_to_wire(job_id, q, m_t, tasks=tasks)],
        done=done)


def main() -> None:
    profile = Profile(n_hosts=N_HOSTS, max_tasks=MAX_TASKS,
                      horizon=HORIZON, trigger="per_task")
    cfg = ServiceConfig(profile=profile, min_train_pairs=6,
                        eval_holdback=3, train_epochs=15)
    rng = np.random.default_rng(0)

    with ServiceDaemon(cfg, port=0) as daemon:
        print(f"daemon listening on {daemon.host}:{daemon.port}")
        clients = {t: daemon.tcp_client(t) for t in ("etl", "web")}
        for t, c in clients.items():
            print(f"hello[{t}]: {c.hello(profile)}")

        # stream telemetry; each job completes after three intervals and
        # its durations land in the retraining replay buffer
        for seq in range(12):
            for t, c in clients.items():
                job = seq // 3
                fin = job - 1 if seq % 3 == 0 and job > 0 else None
                snap = snapshot(rng, t, seq, job, q=3, finished=fin)
                if t == "web" and seq == 5:   # a buggy exporter...
                    snap["m_h"][0] = float("nan")
                r = c.snapshot(snap)
                jobs = r["jobs"][0]
                note = f" sanitized={r['sanitized']}" \
                    if r["sanitized"] else ""
                acts = [a["kind"] for a in jobs["actions"]]
                print(f"seq {seq:2d} [{t}] E_S={jobs['e_s']:.3f} "
                      f"scores={np.round(jobs['scores'], 3).tolist()}"
                      f"{' actions=' + str(acts) if acts else ''}{note}")

        # continuous retraining: fit a candidate on the buffered pairs,
        # shadow-evaluate it on the held-back newest telemetry, promote
        # only if it does not regress — then roll straight back
        rep = clients["etl"].retrain()
        print(f"retrain: promoted={rep['promoted']} "
              f"version={rep.get('version')} "
              f"champion_loss={rep.get('champion_loss'):.4f} "
              f"candidate_loss={rep.get('candidate_loss'):.4f}")
        print(f"rollback: {clients['etl'].rollback()}")
        stats = clients["etl"].stats()
        print(f"stats: tenants={stats['tenants']} "
              f"ticks={stats['ticks']} batch_rows={stats['batch_rows']} "
              f"buffer_pairs={stats['buffer_pairs']} "
              f"promotions={stats['promotions']}")
        for c in clients.values():
            c.bye()

    # the pod runtime as a service tenant: same wire format, zero
    # infrastructure (a private in-process service on first use)
    pol = ServiceBackedPodPolicy()
    rt = StragglerRuntime(RuntimeConfig(n_hosts=6, horizon=HORIZON),
                          policy=pol)
    rng = np.random.default_rng(1)
    for _ in range(12):
        st = 1.0 + 0.1 * rng.random(6)
        st[4] *= 2.5
        rt.observe_step(st)
        rt.decide()
    resp = pol.last_response
    print(f"pod tenant: E_S={resp['jobs'][0]['e_s']:.3f} "
          f"actions={rt.action_counts} "
          f"buffered_pairs={len(pol.client.service.buffer)}")


if __name__ == "__main__":
    main()
