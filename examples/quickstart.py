"""Quickstart: the START pipeline end-to-end in ~60 lines.

1. Fit a Pareto tail to task times (Eq. 3) and get E_S (Eq. 4).
2. Train the Encoder-LSTM to predict (alpha, beta) from cluster state.
3. Run the cloud simulator with START mitigating stragglers and compare
   against no mitigation.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.core import pareto
from repro.sim import Simulation, small
from repro.sim.techniques import START, make
from repro.sim.techniques.start_tech import pretrain

# --- 1. the Pareto straggler model -----------------------------------------
key = jax.random.PRNGKey(0)
times = pareto.sample_pareto(key, alpha=2.0, beta=60.0, shape=(500,))
a, b = pareto.fit_pareto(times)
es = pareto.expected_stragglers(500.0, a, b, k=1.5)
print(f"fitted alpha={float(a):.2f} beta={float(b):.1f}s "
      f"-> E_S={float(es):.1f} expected stragglers / 500 tasks")

# --- 2. train the Encoder-LSTM predictor (paper §4.4) ----------------------
cfg = small(n_hosts=16, n_intervals=60, seed=7)
controller = pretrain(cfg, epochs=10, lr=1e-3)
print(f"predictor trained; final MSE loss "
      f"{controller.predictor.losses[-1]:.4f}")

# --- 3. mitigate stragglers in the simulator -------------------------------
results = {}
for name, tech in (("none", make("none")),
                   ("START", START(controller=controller))):
    sim = Simulation(small(n_hosts=16, n_intervals=80, seed=21),
                     technique=tech)
    results[name] = sim.run()

for name, s in results.items():
    print(f"{name:>6}: exec={s['avg_execution_time_s']:7.1f}s "
          f"sla_viol={s['sla_violation_rate']:.3f} "
          f"energy={s['energy_kwh']:.2f}kWh")
gain = 100 * (1 - results["START"]["avg_execution_time_s"]
              / results["none"]["avg_execution_time_s"])
print(f"START reduces mean execution time by {gain:.1f}%")
