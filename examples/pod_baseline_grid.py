"""Baseline grid on the pod substrate: the full portable technique field
mitigating stragglers on one (simulated) distributed training pod.

Every policy registered for the ``pod`` substrate — START's pod port,
the paper's IGRU-SD baseline, and the replication-timing /
redundancy-level families (Wang et al., Aktas & Soljanin) — runs over
the *same* seeded step-time trace; the runtime translates the shared
action vocabulary (speculate -> backup shard, rerun -> evict) and the
grid compares what each policy bought: backups issued, hosts dropped,
and the synchronization barrier the pod actually paid (max step time
over surviving hosts, with a backed-up shard finishing at its backup
host's pace).

    PYTHONPATH=src python examples/pod_baseline_grid.py
"""
import numpy as np

from repro import policy
from repro.distributed.straggler_runtime import (RuntimeConfig,
                                                 StragglerRuntime,
                                                 pretrain_igru_pod)
from repro.sim.techniques.baselines import IGRUSD

import repro.sim.techniques  # noqa: F401  (registers the sim+pod field)

N_HOSTS = 16
SLOW = 5            # chronically slow host (e.g. thermal throttling)
STEPS = 60

GRID = ("start-pod", "start-eager", "igru-sd", "single-fork",
        "fork-relaunch", "redundancy-fixed", "redundancy-adaptive")


def make_trace(steps: int, seed: int = 0) -> np.ndarray:
    """(steps, N_HOSTS) step times: mild Pareto noise + one slow host."""
    rng = np.random.default_rng(seed)
    t = 1.0 + 0.05 * rng.pareto(2.0, (steps, N_HOSTS))
    t[:, SLOW] *= 2.5
    return t


def make_policy(name: str) -> policy.Policy:
    if name == "igru-sd":   # needs its GRU fitted on pod windows first
        warm = StragglerRuntime(RuntimeConfig(n_hosts=N_HOSTS))
        for times in make_trace(15, seed=1):
            warm.observe_step(times)
        tech = IGRUSD(seed=0)
        pretrain_igru_pod(tech, warm, epochs=150)
        return tech
    return policy.make(name)


def run_policy(name: str, trace: np.ndarray) -> dict:
    rt = StragglerRuntime(RuntimeConfig(n_hosts=N_HOSTS),
                          policy=make_policy(name))
    for times in trace:      # the runtime itself credits backup shards
        rt.observe_step(times)     # and excludes evicted hosts in its
        rt.decide()                # sync-barrier accounting
    return rt.summary()


def main() -> None:
    trace = make_trace(STEPS)
    none_barrier = float(trace.max(axis=1).mean())
    print(f"{N_HOSTS}-host pod, {STEPS} steps, host {SLOW} runs 2.5x slow")
    print(f"no mitigation: mean sync barrier {none_barrier:.3f}s\n")
    hdr = (f"{'policy':20s} {'backups':>7s} {'evicts':>6s} "
           f"{'dropped':>8s} {'barrier_s':>9s} {'vs none':>8s}")
    print(hdr)
    print("-" * len(hdr))
    for name in GRID:
        s = run_policy(name, trace)
        gain = none_barrier / max(s["mean_sync_barrier_s"], 1e-9)
        print(f"{name:20s} {s['backup_shards']:7d} "
              f"{s['evictions']:6d} {str(s['evicted_hosts']):>8s} "
              f"{s['mean_sync_barrier_s']:9.3f} {gain:7.2f}x")


if __name__ == "__main__":
    main()
