"""Paper-scenario example: full technique comparison on the cloud
simulator (a fast version of benchmarks Figs. 6-10).

    PYTHONPATH=src python examples/cloud_straggler_sim.py
"""
import numpy as np

from repro.sim import SimConfig, Simulation
from repro.sim.techniques import BASELINES, START, make
from repro.sim.techniques.baselines import (IGRUSD, Wrangler, pretrain_igru,
                                            pretrain_wrangler)
from repro.sim.techniques.start_tech import pretrain

cfg_train = SimConfig(n_hosts=24, n_intervals=60, seed=7)
print("pretraining START's Encoder-LSTM on a random-scheduler run...")
ctrl = pretrain(cfg_train, epochs=8, lr=1e-3)
warm = Simulation(SimConfig(n_hosts=24, n_intervals=60, seed=9))
warm.run()

print(f"{'technique':>12} {'exec_s':>8} {'contention':>10} "
      f"{'energy_kwh':>10} {'sla_viol':>8}")
for name in ["none"] + BASELINES + ["start"]:
    if name == "start":
        tech = START(controller=ctrl)
    else:
        tech = make(name)
        if isinstance(tech, IGRUSD):
            pretrain_igru(tech, warm, epochs=40)
        if isinstance(tech, Wrangler):
            pretrain_wrangler(tech, warm)
    vals = []
    for seed in (1, 2):
        sim = Simulation(SimConfig(n_hosts=24, n_intervals=80, seed=seed),
                         technique=tech if seed == 1 else tech)
        vals.append(sim.run())
    s = {k: float(np.mean([v[k] for v in vals])) for k in vals[0]
         if isinstance(vals[0][k], (int, float))}
    print(f"{name:>12} {s['avg_execution_time_s']:8.1f} "
          f"{s['resource_contention']:10.2f} {s['energy_kwh']:10.2f} "
          f"{s['sla_violation_rate']:8.3f}")
