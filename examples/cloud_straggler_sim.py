"""Paper-scenario example: full technique comparison on the cloud
simulator, run through the scenario-sweep subsystem — a fast version of
benchmarks Figs. 6-10 that also shows how conclusions shift across
workload regimes (planetlab vs heavy-tail).

    PYTHONPATH=src python examples/cloud_straggler_sim.py
"""
from repro.sim import scenarios, sweep
from repro.sim.techniques import BASELINES


def main() -> None:
    spec = sweep.SweepSpec(
        techniques=("none", *BASELINES, "start"),
        seeds=(1, 2),
        scenarios=("planetlab", "heavy-tail"),
        n_hosts=24, n_intervals=60, arrival_rate=0.6,
        max_workers=1,  # bump for a process-pool run
    )
    print(f"sweep: {len(spec.cells())} cells "
          f"({len(spec.scenarios)} scenarios x {len(spec.techniques)} "
          f"techniques x {len(spec.seeds)} seeds); START/IGRU-SD/Wrangler "
          f"pretrain per scenario on first use...")
    result = sweep.run(spec)
    agg = result.aggregate()

    for sc in spec.scenarios:
        print(f"\n=== scenario: {sc} — {scenarios.get(sc).stresses} ===")
        print(f"{'technique':>12} {'exec_s':>8} {'contention':>10} "
              f"{'energy_kwh':>10} {'sla_viol':>8}")
        for name in spec.techniques:
            s = agg[(sc, name)]
            print(f"{name:>12} {s['avg_execution_time_s']['mean']:8.1f} "
                  f"{s['resource_contention']['mean']:10.2f} "
                  f"{s['energy_kwh']['mean']:10.2f} "
                  f"{s['sla_violation_rate']['mean']:8.3f}")
    print(f"\ntotal wall: {result.wall_s:.1f}s "
          f"({result.n_workers} worker(s))")


if __name__ == "__main__":
    main()
