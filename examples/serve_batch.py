"""Batched serving example: continuous batching over a reduced model with
START replica re-dispatch telemetry.

    PYTHONPATH=src python examples/serve_batch.py
"""
import sys

from repro.launch.serve import main as serve_main

if __name__ == "__main__":
    out = serve_main(["--arch", "demo-100m", "--reduced",
                      "--requests", "8", "--max-new", "10",
                      "--slots", "3", "--replicas", "3"])
    sys.exit(0 if out["requests_done"] == 8 else 1)
