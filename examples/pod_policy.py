"""One policy, two substrates: the paper's IGRU-SD baseline mitigating
stragglers on a (simulated) distributed training pod.

The unified policy API (``repro.policy``) means a technique written for
the cloud simulator runs unchanged on the training-pod runtime: the
runtime publishes the same TelemetryView geometry (per-host shard windows
as tasks) and translates the simulator action vocabulary — speculate
becomes a backup shard, rerun becomes an eviction.

    PYTHONPATH=src python examples/pod_policy.py
"""
import numpy as np

from repro.distributed.straggler_runtime import (RuntimeConfig,
                                                 StragglerRuntime,
                                                 backup_mask,
                                                 pretrain_igru_pod)
from repro.sim.techniques.baselines import IGRUSD

N_HOSTS = 16
SLOW = 5          # chronically slow host (e.g. thermal throttling)


def step_times(rng: np.random.Generator) -> np.ndarray:
    t = 1.0 + 0.05 * rng.pareto(2.0, N_HOSTS)
    t[SLOW] *= 2.5
    return t


def main() -> None:
    rng = np.random.default_rng(0)

    # 1. warmup: observe a few windows to build pod training pairs
    warm = StragglerRuntime(RuntimeConfig(n_hosts=N_HOSTS))
    for _ in range(15):
        warm.observe_step(step_times(rng))
    tech = IGRUSD(seed=0)
    pretrain_igru_pod(tech, warm, epochs=150)
    print(f"pretrained IGRU-SD on {len(warm.completed_windows)} "
          f"pod windows ({N_HOSTS} hosts each)")

    # 2. the same policy object drives pod mitigation
    rt = StragglerRuntime(RuntimeConfig(n_hosts=N_HOSTS), policy=tech)
    for step in range(18):
        times = step_times(rng)
        rt.observe_step(times)
        for act in rt.decide():
            print(f"step {step:2d}: {act.kind} host={act.host} "
                  f"backup={act.backup}")
            on_time = times < 2.0
            w = backup_mask(N_HOSTS, [act], on_time)
            print(f"          gradient combine weights: {w.astype(int)}")


if __name__ == "__main__":
    main()
