"""End-to-end driver: train the ~126M-param demo LM for a few hundred
steps with checkpointing and the START straggler runtime enabled
(simulated host telemetry).

    PYTHONPATH=src python examples/train_e2e.py [--steps 300]

Expected: loss falls from ~9.0 (ln 8192) toward ~2-3 as the model learns
the synthetic affine-recurrence language. NOTE: on this CPU container a
step takes ~15-20 s (the model is real); pass --steps 20 for a smoke run,
or --reduced for the small variant the tests drill (seconds/step).
"""
import argparse
import sys

from repro.launch.train import main as train_main

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--ckpt", default="/tmp/repro_e2e_ckpt")
    args = ap.parse_args()
    argv = ["--arch", "demo-100m", "--steps", str(args.steps),
            "--batch", "8", "--seq", "64", "--lr", "1e-3",
            "--ckpt", args.ckpt, "--ckpt-every", "50", "--resume",
            "--simulate-stragglers", "--n-hosts", "16",
            "--log-every", "5"]
    if args.reduced:
        argv.append("--reduced")
    sys.exit(0 if train_main(argv) else 1)
