"""The Tier-1 determinism contract: tolerance bounds + comparison helpers.

The repo's determinism guarantees are tiered (README "Performance"):

  * **Tier-0 (bitwise)** — the engine, sweep serial == parallel, and the
    golden determinism fixture.  Nothing in this module applies there;
    Tier-0 comparisons use ``np.testing.assert_array_equal`` and the
    golden-fixture path must never import this file (guarded by
    ``test_tolerance.py::test_tier0_path_never_imports_tolerance``).

  * **Tier-1 (tolerance-bounded)** — the fused interval step and the
    serving batch path.  They restructure the Encoder-LSTM emission for
    speed (encoder hoisted out of the scan, scan unrolled, Pareto tail
    fused into the same program, exact-shape batches), which shifts
    float32 rounding by a few ulps at some shapes.  Tier-1 paths must
    agree with the bitwise reference within the bounds below at EVERY
    shape; each path is still fully deterministic run-to-run for a fixed
    (shape, unroll, platform).

The bounds are deliberately tight: the shape sweep in
``test_tolerance.py`` pins the *observed* drift per optimization at
roughly 5e-7 relative (~4 float32 ulps); ``TIER1_REL`` leaves ~20x
headroom for platform variation without ever accepting a real numeric
bug (a wrong sign, a dropped term, a swapped operand all blow past 1e-5
immediately).
"""
from __future__ import annotations

import numpy as np

#: Maximum relative error |a - b| / max(|b|, TIER1_ABS_FLOOR) a Tier-1
#: path may show against the Tier-0 reference, at any shape.
TIER1_REL = 1e-5

#: Denominator floor for the relative error: below this magnitude the
#: comparison degrades to an absolute bound of TIER1_REL * TIER1_ABS_FLOOR
#: (E_S values this small are zero for every downstream decision).
TIER1_ABS_FLOOR = 1e-6

#: Maximum float32 ulp distance observed across the committed shape
#: sweeps, re-pinned whenever a new Tier-1 optimization lands.  This is a
#: *trajectory* number (benchmarks/check_perf.py warns when it grows),
#: not a gate — the gate is TIER1_REL.
TIER1_MAX_ULP = 64


def ulp_diff(a, b) -> np.ndarray:
    """Elementwise distance in float32 ulps (units in the last place).

    Implemented as the difference of the IEEE-754 bit patterns mapped to
    a monotonic integer line (sign-magnitude -> offset binary), so 0 ulp
    means bitwise-equal, 1 ulp means adjacent representable floats, and
    the measure is well-defined across the zero crossing.
    """
    a = np.asarray(a, np.float32)
    b = np.asarray(b, np.float32)

    def key(x):
        bits = x.view(np.int32).astype(np.int64)
        return np.where(bits < 0, np.int64(-0x80000000) - bits, bits)

    return np.abs(key(a) - key(b))


def drift(actual, desired) -> dict:
    """Max drift of ``actual`` vs ``desired`` as a dict of scalars:
    ``{"max_rel", "max_abs", "max_ulp"}``.  Used by the shape-sweep
    tests and by ``benchmarks/engine_bench.py`` to record the Tier-1
    drift trajectory into ``BENCH_engine.json``."""
    actual = np.asarray(actual, np.float32)
    desired = np.asarray(desired, np.float32)
    if actual.shape != desired.shape:
        raise AssertionError(
            f"shape mismatch: {actual.shape} vs {desired.shape}")
    abs_err = np.abs(actual.astype(np.float64) - desired.astype(np.float64))
    denom = np.maximum(np.abs(desired.astype(np.float64)), TIER1_ABS_FLOOR)
    # ulp distance is only meaningful above the absolute floor — below it
    # the contract is an absolute bound and ulp counts at denormal scale
    # are astronomically large for negligible absolute error
    ulp = ulp_diff(actual, desired)
    ulp = ulp[np.abs(desired) >= TIER1_ABS_FLOOR]
    return {
        "max_rel": float((abs_err / denom).max()) if actual.size else 0.0,
        "max_abs": float(abs_err.max()) if actual.size else 0.0,
        "max_ulp": int(ulp.max()) if ulp.size else 0,
    }


def assert_tier1(actual, desired, rel: float = TIER1_REL,
                 context: str = "") -> dict:
    """Assert a Tier-1 path agrees with the Tier-0 reference within the
    contract bound; returns the measured :func:`drift` so sweeps can
    aggregate it.  Non-finite values must match exactly (a NaN in one
    path but not the other is a real bug, not rounding)."""
    actual = np.asarray(actual, np.float32)
    desired = np.asarray(desired, np.float32)
    fin_a, fin_d = np.isfinite(actual), np.isfinite(desired)
    nf_ok = (fin_a == fin_d).all()
    if nf_ok and (~fin_a).any():
        a_nf, d_nf = actual[~fin_a], desired[~fin_a]
        nf_ok = bool(((np.isnan(a_nf) & np.isnan(d_nf))
                      | (a_nf == d_nf)).all())
    if not nf_ok:
        raise AssertionError(
            f"Tier-1 {context or 'comparison'}: non-finite mismatch "
            f"(actual finite {fin_a.sum()}/{fin_a.size}, "
            f"desired finite {fin_d.sum()}/{fin_d.size})")
    d = drift(np.where(fin_a, actual, 0), np.where(fin_d, desired, 0))
    if d["max_rel"] > rel:
        raise AssertionError(
            f"Tier-1 {context or 'comparison'} out of tolerance: "
            f"max_rel {d['max_rel']:.3e} > bound {rel:.1e} "
            f"(max_abs {d['max_abs']:.3e}, max_ulp {d['max_ulp']})")
    return d


def sweep_drift(pairs) -> dict:
    """Aggregate :func:`assert_tier1` over ``(actual, desired)`` pairs —
    the shape-sweep harness: every pair must individually pass, and the
    worst drift across the sweep comes back for pinning/recording."""
    worst = {"max_rel": 0.0, "max_abs": 0.0, "max_ulp": 0}
    for i, (actual, desired) in enumerate(pairs):
        d = assert_tier1(actual, desired, context=f"sweep pair {i}")
        for k in worst:
            worst[k] = max(worst[k], d[k])
    return worst
