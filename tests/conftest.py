"""Shared pytest configuration for the tier-1 suite.

Two jobs:
  * register the ``slow`` marker (used by the distributed tests and the CI
    fast lane's ``-m "not slow"`` filter);
  * make ``hypothesis`` optional: when the real package is missing (it is a
    dev-only dependency, see requirements-dev.txt), install a minimal stub
    into ``sys.modules`` BEFORE test modules import it, so collection never
    hard-errors and the property tests still run as fixed-example
    parametrizations instead of being skipped wholesale.
"""
from __future__ import annotations

import sys
import types

import pytest


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: long-running test, excluded from the fast CI lane "
        "(deselect with -m \"not slow\")")


def _install_hypothesis_stub() -> None:
    """Degraded-mode ``hypothesis``: @given draws a handful of boundary +
    midpoint examples per strategy and parametrizes over them."""

    class _Strategy:
        def __init__(self, examples):
            self.examples = list(examples)

    def floats(lo, hi):
        return _Strategy([lo, hi, (lo + hi) / 2.0])

    def integers(lo, hi):
        mid = (lo + hi) // 2
        return _Strategy([lo, hi, mid])

    def sampled_from(xs):
        return _Strategy(list(xs))

    def settings(*a, **kw):
        def deco(fn):
            return fn
        return deco

    def given(**kw):
        keys = sorted(kw)
        n = max(len(kw[k].examples) for k in keys)
        cases = [tuple(kw[k].examples[i % len(kw[k].examples)]
                       for k in keys) for i in range(n)]
        if len(keys) == 1:  # parametrize wants scalars for one argname
            cases = [c[0] for c in cases]

        def deco(fn):
            return pytest.mark.parametrize(",".join(keys), cases)(fn)
        return deco

    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    mod.__is_stub__ = True
    st_mod = types.ModuleType("hypothesis.strategies")
    st_mod.floats = floats
    st_mod.integers = integers
    st_mod.sampled_from = sampled_from
    mod.strategies = st_mod
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st_mod


try:
    import hypothesis  # noqa: F401
except ImportError:
    _install_hypothesis_stub()
