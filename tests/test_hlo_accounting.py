"""Loop-aware HLO accounting walker: validated against unrolled ground
truth (scan bodies must be multiplied by known_trip_count)."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_accounting import account

jax.config.update("jax_platform_name", "cpu")

W = jnp.zeros((256, 256))
X = jnp.zeros((64, 256))
MM_FLOPS = 2 * 64 * 256 * 256


def _account(fn, *args):
    return account(jax.jit(fn).lower(*args).compile().as_text())


def test_single_matmul():
    t = _account(lambda x, w: x @ w, X, W)
    assert t.flops == pytest.approx(MM_FLOPS, rel=0.01)


def test_scan_multiplies_trip_count():
    def scan10(x, w):
        def body(c, _):
            return c @ w, None
        out, _ = jax.lax.scan(body, x, None, length=10)
        return out

    t = _account(scan10, X, W)
    assert t.flops == pytest.approx(10 * MM_FLOPS, rel=0.02)
    assert t.unknown_trip_loops == 0


def test_nested_scans_multiply():
    def nested(x, w):
        def outer(c, _):
            def inner(c2, _):
                return c2 @ w, None
            c2, _ = jax.lax.scan(inner, c, None, length=5)
            return c2, None
        out, _ = jax.lax.scan(outer, x, None, length=4)
        return out

    t = _account(nested, X, W)
    assert t.flops == pytest.approx(20 * MM_FLOPS, rel=0.02)


def test_scan_matches_unrolled():
    def scan8(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        out, _ = jax.lax.scan(body, x, None, length=8)
        return out

    def unroll8(x, w):
        for _ in range(8):
            x = jnp.tanh(x @ w)
        return x

    ts = _account(scan8, X, W)
    tu = _account(unroll8, X, W)
    assert ts.flops == pytest.approx(tu.flops, rel=0.05)


def test_collectives_counted_with_trips():
    import os
    import subprocess
    import sys
    import textwrap
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH="src")
    code = textwrap.dedent("""
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.hlo_accounting import account
        mesh = jax.make_mesh((8,), ('d',))
        w = jnp.zeros((256, 256))
        x = jnp.zeros((64, 256))

        def f(x, w):
            def body(c, _):
                return c @ w, None
            out, _ = jax.lax.scan(body, x, None, length=4)
            return out.sum()

        lowered = jax.jit(f, in_shardings=(
            NamedSharding(mesh, P('d', None)),
            NamedSharding(mesh, P(None, 'd')))).lower(x, w)
        t = account(lowered.compile().as_text())
        # the weight all-gather happens inside the loop (or hoisted);
        # either way total collective bytes must be > 0
        assert t.collective_bytes > 0, t.collectives
        print('OK', t.collective_bytes)
    """)
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=300)
    assert r.returncode == 0, r.stderr


def test_bytes_and_transcendentals_positive():
    t = _account(lambda x, w: jnp.tanh(x @ w), X, W)
    assert t.bytes > 0
    assert t.transcendentals >= 64 * 256
