"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, shape + finiteness asserts, and prefill/decode cache equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced, list_archs
from repro.models.lm import Model
from repro.models.specs import batch_specs

jax.config.update("jax_platform_name", "cpu")

ALL_ARCHS = list_archs()


def make_batch(cfg, b=2, s=16, with_labels=True, seed=0):
    rng = np.random.default_rng(seed)
    specs = batch_specs(cfg, b, s, with_labels)
    out = {}
    for k, v in specs.items():
        if v.dtype == jnp.int32:
            out[k] = jnp.asarray(
                rng.integers(0, cfg.vocab, v.shape), jnp.int32)
        else:
            out[k] = jnp.asarray(rng.normal(0, 1, v.shape), v.dtype)
    return out


@pytest.fixture(scope="module")
def built():
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = get_reduced(arch)
            model = Model(cfg)
            params = model.init(jax.random.PRNGKey(0))
            cache[arch] = (cfg, model, params)
        return cache[arch]

    return get


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_train_step(arch, built):
    """One forward + backward on the reduced config: finite loss + grads."""
    cfg, model, params = built(arch)
    batch = make_batch(cfg)
    loss, grads = jax.value_and_grad(model.loss_fn)(params, batch)
    assert np.isfinite(float(loss)), arch
    leaves = jax.tree_util.tree_leaves(grads)
    assert all(bool(jnp.isfinite(g).all()) for g in leaves), arch
    # loss should be near log(vocab) at init (calibrated logits)
    assert float(loss) < np.log(cfg.vocab) * 3


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_prefill_shapes(arch, built):
    cfg, model, params = built(arch)
    batch = make_batch(cfg, with_labels=False)
    logits, caches = model.prefill(params, batch)
    assert logits.shape[0] == 2 and logits.shape[1] == 1
    assert logits.shape[2] == cfg.padded_vocab
    assert np.isfinite(np.asarray(logits)).all(), arch
    assert len(caches) == len(model.groups)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_decode_matches_prefill(arch, built):
    """prefill(S) last-token logits == prefill(S-1) then decode(token S-1).

    This exercises every cache variant: GQA KV, MLA latent, mamba
    recurrent state, hybrid mixed, enc-dec cross. fp32 so the only
    difference is the code path, not bf16 accumulation order."""
    import dataclasses
    cfg = dataclasses.replace(get_reduced(arch), param_dtype="float32")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    b, s = 2, 16
    batch = make_batch(cfg, b=b, s=s, with_labels=False, seed=3)
    full_logits, _ = model.prefill(params, batch)

    toks = batch["tokens"]
    batch_m1 = dict(batch)
    batch_m1["tokens"] = toks[:, :-1]
    _, caches = model.prefill(params, batch_m1)
    # grow caches to length S where needed (pad along the seq axis)
    caches = _pad_caches(model, caches, 1)
    pos0 = toks.shape[1] - 1
    if cfg.family == "vlm":
        pos0 += cfg.frontend_tokens
    logits, _ = model.decode_step(params, caches, toks[:, -1:],
                                  jnp.asarray(pos0, jnp.int32))
    np.testing.assert_allclose(
        np.asarray(logits, np.float32), np.asarray(full_logits, np.float32),
        rtol=2e-4, atol=2e-4)


def _pad_caches(model, caches, extra):
    """Pad attention KV caches by `extra` along seq so decode can write.

    Cache leaves are layer-stacked: k/v are (L, B, Hkv, S, hd) — pad axis 3;
    MLA latents c_kv/k_rope are (L, B, S, d) — pad axis 2. Recurrent mamba
    state needs no padding."""
    out = []
    for c in caches:
        def walk(node):
            if isinstance(node, dict):
                new = {}
                for k, v in node.items():
                    if k in ("k", "v") and hasattr(v, "ndim"):
                        ax = v.ndim - 2
                        w = [(0, 0)] * v.ndim
                        w[ax] = (0, extra)
                        new[k] = jnp.pad(v, w)
                    elif k in ("c_kv", "k_rope") and hasattr(v, "ndim"):
                        ax = v.ndim - 2
                        w = [(0, 0)] * v.ndim
                        w[ax] = (0, extra)
                        new[k] = jnp.pad(v, w)
                    else:
                        new[k] = walk(v)
                return new
            return node
        out.append(walk(c))
    return out


def test_vlm_uses_patches():
    cfg = get_reduced("internvl2-26b")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, seed=1)
    l1 = model.loss_fn(params, batch)
    batch2 = dict(batch)
    batch2["patch_embeds"] = batch["patch_embeds"] + 1.0
    l2 = model.loss_fn(params, batch2)
    assert float(l1) != float(l2)


def test_encdec_uses_frames():
    cfg = get_reduced("seamless-m4t-large-v2")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, seed=2)
    l1 = model.loss_fn(params, batch)
    batch2 = dict(batch)
    batch2["frame_embeds"] = batch["frame_embeds"] * 2.0
    l2 = model.loss_fn(params, batch2)
    assert float(l1) != float(l2)


def test_param_counts_match_full_configs():
    """Analytic param_count ~ the known model sizes (sanity, +-25%)."""
    from repro.configs import get_config
    expect = {
        "yi-6b": 6e9, "minitron-4b": 4.2e9, "phi4-mini-3.8b": 3.8e9,
        "deepseek-67b": 67e9, "internvl2-26b": 20e9,
        "deepseek-v3-671b": 671e9, "qwen3-moe-30b-a3b": 30e9,
        "falcon-mamba-7b": 7e9, "jamba-1.5-large-398b": 398e9,
        "seamless-m4t-large-v2": 2.3e9,
    }
    for arch, n in expect.items():
        got = get_config(arch).param_count()
        assert 0.6 * n < got < 1.6 * n, (arch, got, n)


def test_moe_active_params():
    from repro.configs import get_config
    cfg = get_config("qwen3-moe-30b-a3b")
    active = cfg.active_param_count()
    assert 1.5e9 < active < 5e9  # "a3b" = ~3B active
    cfg = get_config("deepseek-v3-671b")
    active = cfg.active_param_count()
    assert 20e9 < active < 55e9  # ~37B active
