"""Scenario-registry + sweep-subsystem tests, and engine invariants the
sweep relies on (first-result-wins, no lost tasks, incremental job
accounting, parallel == serial)."""
import csv
import dataclasses
import os

import numpy as np
import pytest

from repro.core import pareto
from repro.sim import SimConfig, Simulation, scenarios, small, sweep
from repro.sim import engine as E
from repro.sim.sweep import (CellResult, SweepResult, SweepSpec,
                             deterministic_summary as _det, run, run_cell)

REQUIRED_SCENARIOS = ("planetlab", "flash-crowd", "heavy-tail",
                      "hetero-fleet", "overload", "fault-storm")


# ------------------------------ scenarios ----------------------------------

def test_registry_contains_required_regimes():
    names = scenarios.names()
    for n in REQUIRED_SCENARIOS:
        assert n in names, n
    with pytest.raises(KeyError):
        scenarios.get("nope")


@pytest.mark.parametrize("name", REQUIRED_SCENARIOS)
def test_each_scenario_runs_end_to_end_with_finite_qos(name):
    cfg = scenarios.make_config(name, seed=0, n_hosts=12, n_intervals=30,
                                arrival_rate=0.8)
    sim = Simulation(cfg)
    s = sim.run()
    assert s["tasks_done"] > 0, name
    for k in sweep.QOS_KEYS:
        assert np.isfinite(s[k]), (name, k)


def test_hetero_fleet_has_mixed_per_host_ips():
    cfg = scenarios.make_config("hetero-fleet", n_hosts=9, n_intervals=5)
    sim = Simulation(cfg)
    assert len(np.unique(sim.host_ips)) == 3
    # scalar configs stay homogeneous
    assert len(np.unique(Simulation(small(n_hosts=9)).host_ips)) == 1


def test_host_ips_mean_averages_tiled_fleet():
    # 32 hosts over a 3-value tuple tile 11/11/10 — the fleet mean is NOT
    # the tuple mean
    cfg = SimConfig(n_hosts=32, host_ips=(4.17, 8.33, 16.66))
    assert cfg.host_ips_mean == pytest.approx(
        float(cfg.host_ips_array().mean()))
    assert cfg.host_ips_mean != pytest.approx(np.mean((4.17, 8.33, 16.66)))
    assert SimConfig(n_hosts=5).host_ips_mean == pytest.approx(8.33)


def test_straggler_counts_ignore_unplaced_hosts():
    """Originals that finish via a copy while unplaced (host == -1) must
    not credit a straggler to the last host via index wrap-around."""
    cfg = small(n_hosts=10, n_intervals=50, seed=1, fault_host_rate=0.15)
    sim = Simulation(cfg, technique=CloneStorm())
    sim.run()
    total_placed = sum(
        int((np.asarray(rec["straggler"]) & (np.asarray(rec["hosts"]) >= 0)
             ).sum()) for rec in sim.completed_jobs)
    assert sim.host_straggler_counts.sum() == total_placed


def test_flash_crowd_bursts_increase_load():
    base = scenarios.make_config("planetlab", n_hosts=12, n_intervals=48,
                                 arrival_rate=0.8)
    burst = scenarios.make_config("flash-crowd", n_hosts=12, n_intervals=48,
                                  arrival_rate=0.8)
    s_base = Simulation(base)
    s_burst = Simulation(burst)
    fac = [s_burst.workload.burst_factor(t) for t in range(48)]
    assert max(fac) == burst.burst_multiplier and min(fac) == 1.0
    s_base.run()
    s_burst.run()
    assert (s_burst.summary()["tasks_total"]
            > s_base.summary()["tasks_total"])


def test_overload_scenario_scales_arrivals():
    cfg = scenarios.make_config("overload", arrival_rate=0.6)
    assert cfg.arrival_rate == pytest.approx(0.6 * 2.5)
    assert cfg.reserved_utilization == 0.4


# ------------------------------- sweep -------------------------------------

def _tiny_spec(**kw) -> SweepSpec:
    base = dict(techniques=("none", "sgc"), seeds=(0, 1),
                scenarios=("planetlab", "fault-storm"),
                n_hosts=10, n_intervals=20, arrival_rate=0.8,
                max_workers=1)
    base.update(kw)
    return SweepSpec(**base)


def test_sweep_cell_grid_and_lookup():
    spec = _tiny_spec()
    assert len(spec.cells()) == 2 * 2 * 2
    res = run(spec)
    c = res.cell("fault-storm", "sgc", 1)
    assert c.summary["tasks_done"] >= 0 and c.wall_s > 0


def test_sweep_parallel_bitwise_equals_serial():
    spec = _tiny_spec()
    serial = run(spec)
    parallel = run(dataclasses.replace(spec, max_workers=2))
    assert parallel.n_workers == 2
    assert len(serial.cells) == len(parallel.cells)
    for a, b in zip(serial.cells, parallel.cells):
        assert (a.scenario, a.technique, a.seed) == (b.scenario,
                                                     b.technique, b.seed)
        assert _det(a.summary) == _det(b.summary), (a.scenario, a.technique)


def test_sweep_parallel_equals_serial_with_pretrained_technique():
    """The per-process pretrain cache is exactly where serial (one shared
    cache) and parallel (each worker pretrains independently) runs could
    diverge — cover it with the cheapest pretrained technique."""
    spec = SweepSpec(techniques=("wrangler",), seeds=(0, 1),
                     scenarios=("planetlab",), n_hosts=10, n_intervals=20,
                     arrival_rate=0.8, max_workers=1)
    serial = run(spec)
    parallel = run(dataclasses.replace(spec, max_workers=2))
    for a, b in zip(serial.cells, parallel.cells):
        assert _det(a.summary) == _det(b.summary)


def test_sweep_csv_artifacts(tmp_path):
    spec = _tiny_spec(out_dir=str(tmp_path), csv_prefix="t")
    res = run(spec)
    cells_csv = os.path.join(str(tmp_path), "t_cells.csv")
    agg_csv = os.path.join(str(tmp_path), "t_agg.csv")
    assert os.path.exists(cells_csv) and os.path.exists(agg_csv)
    with open(cells_csv) as f:
        rows = list(csv.reader(f))
    assert len(rows) == 1 + len(res.cells)
    assert rows[0][:4] == ["scenario", "technique", "seed", "wall_s"]
    with open(agg_csv) as f:
        arows = list(csv.reader(f))
    assert len(arows) == 1 + len(spec.scenarios) * len(spec.techniques)


def test_aggregate_mean_and_ci():
    spec = SweepSpec(techniques=("none",), seeds=(0, 1, 2),
                     scenarios=("planetlab",), metrics=("m",))
    cells = [CellResult("planetlab", "none", i, {"m": v}, 0.0)
             for i, v in enumerate((1.0, 2.0, 3.0))]
    res = SweepResult(spec=spec, cells=cells, wall_s=0.0, n_workers=1)
    st = res.aggregate()[("planetlab", "none")]["m"]
    assert st["mean"] == pytest.approx(2.0)
    assert st["n"] == 3
    assert st["ci95"] == pytest.approx(1.96 * 1.0 / np.sqrt(3))


def test_overrides_may_replace_base_sizing_keys():
    # fig7-style sweep: arrival_rate comes through overrides without
    # colliding with the spec's explicit base sizing
    spec = _tiny_spec(overrides=(("arrival_rate", 1.8), ("n_hosts", 6)))
    cfg = spec.cell_config("planetlab", 0)
    assert cfg.arrival_rate == pytest.approx(1.8)
    assert cfg.n_hosts == 6
    # scenario arrival scaling still applies on top of the override
    cfg2 = spec.cell_config("overload", 0)
    assert cfg2.arrival_rate == pytest.approx(1.8 * 2.5)


def test_unknown_technique_and_scenario_raise():
    # unknown techniques raise ValueError naming the registered set (and
    # are caught at SweepSpec construction, before any worker spawns)
    with pytest.raises(ValueError, match="registered techniques"):
        run_cell(_tiny_spec(), "planetlab", "bogus", 0)
    with pytest.raises(ValueError, match="registered techniques"):
        _tiny_spec(techniques=("bogus",))
    with pytest.raises(KeyError):
        run_cell(_tiny_spec(), "bogus", "none", 0)


def test_make_technique_returns_fresh_pretrained_instances():
    cfg = small(n_hosts=10, n_intervals=20)
    t1 = sweep.make_technique("wrangler", cfg)
    t2 = sweep.make_technique("wrangler", cfg)
    assert t1 is not t2
    assert t1.w is not None  # pretrained on the cached warmup sim
    np.testing.assert_array_equal(t1.w, t2.w)


# -------------------------- engine invariants ------------------------------

class CloneStorm(E.Technique):
    """Clones every new original task 3x — stresses first-result-wins."""

    name = "clone-storm"

    def on_submit(self, new_idx):
        return [E.SimAction("clone", int(i), n_clones=3) for i in new_idx]


def test_first_result_wins_cancels_all_sibling_copies():
    cfg = small(n_hosts=10, n_intervals=40, seed=2)
    sim = Simulation(cfg, technique=CloneStorm())
    sim.run()
    tt = sim.tasks
    copies = np.nonzero(tt.view("is_copy"))[0]
    assert len(copies) > 0
    groups: dict = {}
    for c in copies:
        groups.setdefault(int(tt.orig[c]), []).append(int(c))
    checked_done = 0
    for orig, group in groups.items():
        if tt.state[orig] == E.DONE:
            checked_done += 1
            done_copies = [c for c in group if tt.state[c] == E.DONE]
            # at most one copy can win, and then it shares the original's
            # finish stamp; every other sibling must be cancelled
            assert len(done_copies) <= 1
            for c in done_copies:
                assert tt.finish_s[c] == tt.finish_s[orig]
            for c in group:
                if tt.state[c] != E.DONE:
                    assert tt.state[c] == E.CANCELLED, (orig, c)
    assert checked_done > 0


def test_no_original_task_lost_across_restarts_and_bounces():
    """Faults (host downtime, cloudlet restarts, VM-creation bounces) must
    never drop an original task: it stays pending/running/done forever."""
    cfg = small(n_hosts=10, n_intervals=60, seed=3, fault_host_rate=0.15,
                fault_task_rate=0.08, fault_vm_creation_rate=0.1)
    sim = Simulation(cfg)
    sim.run()
    tt = sim.tasks
    assert tt.view("restarts").sum() > 0  # the drill actually fired
    orig = ~tt.view("is_copy")
    states = tt.view("state")[orig]
    assert set(np.unique(states)) <= {E.PENDING, E.RUNNING, E.DONE}
    # incremental per-job open counts agree with the task table
    for job in range(sim.jobs.n):
        tids = sim.jobs.task_ids(job)
        open_n = int(np.isin(tt.state[tids],
                             [E.PENDING, E.RUNNING]).sum())
        assert sim.jobs.open_count[job] == open_n, job
        if sim.jobs.done[job]:
            assert open_n == 0
    # every accounted job's tasks are all terminal-done
    for rec in sim.completed_jobs:
        tids = sim.jobs.task_ids(rec["job"])
        assert (tt.state[tids] == E.DONE).all()
        assert (rec["times"] > 0).all()


class CopyChainer(E.Technique):
    """Speculates on running COPIES too (copy-of-a-copy chains), like the
    reactive baselines that scan active_mask without an is_copy filter."""

    name = "copy-chainer"

    def on_interval(self):
        tt = self.sim.tasks
        acts = []
        for i in np.nonzero(tt.active_mask())[0][:6]:
            acts.append(E.SimAction("speculate", int(i), target=0))
        return acts


def test_copy_of_copy_speculation_keeps_job_accounting_sound():
    cfg = small(n_hosts=10, n_intervals=50, seed=4)
    sim = Simulation(cfg, technique=CopyChainer())
    sim.run()
    tt = sim.tasks
    # the drill actually produced copy-of-copy chains
    copies = np.nonzero(tt.view("is_copy"))[0]
    assert any(tt.is_copy[int(tt.orig[c])] for c in copies)
    # per-job open counts never go negative and match the task table
    for job in range(sim.jobs.n):
        open_n = int(np.isin(tt.state[sim.jobs.task_ids(job)],
                             [E.PENDING, E.RUNNING]).sum())
        assert sim.jobs.open_count[job] == open_n, job
    # no job was accounted while an original was still incomplete
    for rec in sim.completed_jobs:
        tids = sim.jobs.task_ids(rec["job"])
        assert (tt.state[tids] == E.DONE).all()
        assert (tt.finish_s[tids] >= 0).all()


def test_actual_stragglers_matches_naive_reference():
    sim = Simulation(small(n_hosts=12, n_intervals=50, seed=1))
    sim.run()
    fast = sim.actual_stragglers_per_interval()
    # naive per-task reference (the pre-vectorization implementation)
    ref = np.zeros(sim.t)
    dt = sim.cfg.interval_seconds
    tt = sim.tasks
    for rec in sim.completed_jobs:
        for i, is_s in zip(sim.jobs.task_ids(rec["job"]), rec["straggler"]):
            if not is_s:
                continue
            lo = int(tt.submit_s[i] // dt)
            hi = int(max(tt.finish_s[i], tt.submit_s[i]) // dt)
            ref[lo:min(hi + 1, sim.t)] += 1
    np.testing.assert_array_equal(fast, ref)
    assert fast.sum() > 0


def test_fit_pareto_np_matches_jax_twin():
    rng = np.random.default_rng(0)
    for q in (2, 5, 10, 64):
        times = rng.pareto(2.0, q).astype(np.float32) + 1.0
        a_np, b_np = pareto.fit_pareto_np(times)
        a_j, b_j = pareto.fit_pareto(times)
        assert float(a_np) == pytest.approx(float(a_j), rel=1e-5)
        assert float(b_np) == pytest.approx(float(b_j), rel=1e-6)


# --------------------- grid validation + pool hardening ---------------------

@pytest.mark.parametrize("field", ["techniques", "seeds", "scenarios"])
def test_empty_grid_axis_rejected_at_construction(field):
    """An empty axis used to surface as a bare IndexError deep inside
    warm_pool_caches (spec.seeds[0]); now it's a ValueError naming the
    field, raised before any worker spawns."""
    kw = dict(techniques=("none",), seeds=(0,), scenarios=("planetlab",))
    kw[field] = ()
    with pytest.raises(ValueError, match=field):
        SweepSpec(**kw)


def test_ready_lanes_counts_only_successful_warmups(monkeypatch):
    """A warmup future that raised or was cancelled is ``done()`` too —
    the readiness gate must not count it as a live lane (it used to,
    over-submitting to lanes that never primed).  Failures surface as a
    one-time RuntimeWarning."""
    import concurrent.futures as cf
    import warnings

    monkeypatch.setattr(sweep, "_WARMUP_WARNED", False)
    ok = cf.Future()
    ok.set_result(True)
    bad = cf.Future()
    bad.set_exception(RuntimeError("warmup exploded"))
    cancelled = cf.Future()
    cancelled.cancel()
    pending = cf.Future()
    with pytest.warns(RuntimeWarning, match="warmup"):
        assert sweep._ready_lanes([ok, bad, cancelled, pending]) == 1
    with warnings.catch_warnings():      # warned once, not per poll
        warnings.simplefilter("error")
        assert sweep._ready_lanes([ok, bad, cancelled, pending]) == 1


def test_all_warmups_failed_falls_back_to_parent(monkeypatch):
    """Every lane's warmup raising (REPRO_TEST_FAIL_WARMUP) must leave
    the parallel path degraded-but-correct: the parent runs the whole
    grid itself, warns once, and stays bitwise-equal to serial."""
    import concurrent.futures as cf

    spec = _tiny_spec()
    serial = run(spec)
    monkeypatch.setenv("REPRO_TEST_FAIL_WARMUP", "1")
    sweep.shutdown_pool()                # fresh pool inherits the env
    try:
        sweep._pool(2)
        # warmups must have *resolved* (failed) before run() for the
        # warning to fire deterministically — tiny cells beat spawn
        cf.wait(sweep._POOL_READY, timeout=120)
        with pytest.warns(RuntimeWarning, match="warmup"):
            parallel = run(dataclasses.replace(spec, max_workers=2))
    finally:
        sweep.shutdown_pool()            # don't leak poisoned workers
    assert len(parallel.cells) == len(spec.cells())
    for a, b in zip(serial.cells, parallel.cells):
        assert _det(a.summary) == _det(b.summary)


def test_worker_killed_mid_grid_recovers_bitwise(tmp_path, monkeypatch):
    """SIGKILL a pool worker mid-cell (harvest-time BrokenProcessPool,
    the sweep twin of the fabric node-kill test): the parent reruns the
    lost unit, respawns the pool, and the full grid still lands
    bitwise-equal to serial."""
    spec = _tiny_spec()
    serial = run(spec)
    marker = tmp_path / "pool-killed-once"
    # target the FIRST unit submitted: warm idle workers pick it up
    # immediately, so the parent can neither run it inline nor steal it
    # back (running futures refuse cancel) — the kill is deterministic
    monkeypatch.setenv("REPRO_TEST_KILL_CELL",
                       f"planetlab:none:0:{marker}")
    sweep.shutdown_pool()                # fresh pool inherits the env
    try:
        # pre-warm so every unit goes to workers (a cold 1-cpu box would
        # otherwise run the kill cell in the parent, which never kills)
        sweep.warm_pool(2)
        parallel = run(dataclasses.replace(spec, max_workers=2))
    finally:
        sweep.shutdown_pool()            # recycle the armed workers
    assert marker.exists(), "the kill drill never fired in a worker"
    assert len(parallel.cells) == len(spec.cells())
    for a, b in zip(serial.cells, parallel.cells):
        assert (a.scenario, a.technique, a.seed) == (b.scenario,
                                                     b.technique, b.seed)
        assert _det(a.summary) == _det(b.summary), (a.scenario,
                                                    a.technique, a.seed)


def test_submit_time_broken_pool_recovers(monkeypatch):
    """Force ``pool.submit`` itself to raise BrokenProcessPool (the pool
    broke while the parent was busy elsewhere): the unit runs in the
    parent, the pool respawns, and the grid completes bitwise-equal."""
    import concurrent.futures as cf

    spec = _tiny_spec()
    serial = run(spec)
    sweep.shutdown_pool()
    real_pool = sweep._pool
    tripped = {"n": 0}

    class _Brittle:
        def __init__(self, p):
            self._p = p

        def submit(self, *a, **kw):
            if tripped["n"] == 0:
                tripped["n"] = 1
                raise cf.process.BrokenProcessPool("forced submit failure")
            return self._p.submit(*a, **kw)

    monkeypatch.setattr(sweep, "_pool",
                        lambda n: _Brittle(real_pool(n)))
    try:
        # warm first so the readiness gate reaches submit() at all on a
        # 1-cpu box (ready == 0 would keep the parent running inline)
        sweep.warm_pool(2)
        parallel = run(dataclasses.replace(spec, max_workers=2))
    finally:
        sweep.shutdown_pool()
    assert tripped["n"] == 1, "submit-time recovery never exercised"
    assert len(parallel.cells) == len(spec.cells())
    for a, b in zip(serial.cells, parallel.cells):
        assert _det(a.summary) == _det(b.summary)
