"""Property tests for both wire codecs (hypothesis; the conftest stub
degrades these to boundary-example parametrizations when the real
package is absent).

Covered for the fabric pickle-frame codec and the service JSON-lines
codec:

  * roundtrip identity over drawn payloads (ints at the struct
    boundaries, floats including the values JSON treats specially);
  * truncated header / truncated payload rejection;
  * oversized declared length rejection (``MAX_FRAME`` / ``MAX_LINE``);
  * garbage-byte rejection at every drawn offset;
  * MAC-tampered frames rejected before the payload is deserialized.
"""
import io
import struct

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.service import protocol
from repro.sim import fabric
from repro.sim.fabric import ProtocolError, recv_frame, send_frame

_HDR = struct.Struct(">Q")


def _framed(obj, key=None) -> bytes:
    buf = io.BytesIO()
    send_frame(buf, obj, key=key)
    return buf.getvalue()


# ------------------------------ fabric frames ------------------------------

@settings(max_examples=50, deadline=None)
@given(n=st.integers(-(2 ** 62), 2 ** 62), x=st.floats(-1e300, 1e300))
def test_fabric_frame_roundtrip_identity(n, x):
    obj = {"op": "t", "n": n, "x": x, "blob": b"\x00\xff" * 4,
           "nest": {"seq": [n, x]}}
    assert recv_frame(io.BytesIO(_framed(obj))) == obj
    assert recv_frame(io.BytesIO(_framed(obj, key=b"k")),
                      key=b"k") == obj


@settings(max_examples=30, deadline=None)
@given(cut=st.integers(1, 7))
def test_fabric_truncated_header_is_clean_eof_or_error(cut):
    raw = _framed({"op": "t"})
    # a header cut anywhere yields clean EOF (None): the peer closed
    # between frames as far as the reader can prove
    assert recv_frame(io.BytesIO(raw[:cut])) is None


@settings(max_examples=30, deadline=None)
@given(cut=st.integers(1, 20))
def test_fabric_truncated_payload_rejected(cut):
    raw = _framed({"op": "t", "pad": b"x" * 64})
    assert len(raw) - _HDR.size > cut
    with pytest.raises(ProtocolError, match="mid-frame"):
        recv_frame(io.BytesIO(raw[:-cut]))


@settings(max_examples=20, deadline=None)
@given(excess=st.integers(1, 2 ** 30))
def test_fabric_oversized_length_rejected(excess):
    hdr = _HDR.pack(fabric.MAX_FRAME + excess)
    with pytest.raises(ProtocolError, match="MAX_FRAME"):
        recv_frame(io.BytesIO(hdr + b"x" * 16))


@settings(max_examples=50, deadline=None)
@given(offset=st.integers(0, 200), flip=st.integers(1, 255))
def test_fabric_garbage_byte_never_escapes_as_data(offset, flip):
    """Flipping any payload byte must surface as ProtocolError or a
    changed-but-valid dict — never an unhandled unpickler crash."""
    obj = {"op": "t", "pad": b"p" * 128, "v": 7}
    raw = _framed(obj)
    i = _HDR.size + offset % (len(raw) - _HDR.size)
    bad = raw[:i] + bytes([raw[i] ^ flip]) + raw[i + 1:]
    try:
        out = recv_frame(io.BytesIO(bad))
    except ProtocolError:
        return                       # rejected: the hardened path
    assert isinstance(out, dict) and "op" in out


@settings(max_examples=50, deadline=None)
@given(offset=st.integers(0, 500), flip=st.integers(1, 255))
def test_fabric_mac_tamper_always_rejected(offset, flip):
    """With a key, any single-byte tamper of tag or payload is refused
    at the MAC check — there is no changed-but-valid outcome."""
    obj = {"op": "t", "pad": b"p" * 128, "v": 7}
    raw = _framed(obj, key=b"kk")
    i = _HDR.size + offset % (len(raw) - _HDR.size)
    bad = raw[:i] + bytes([raw[i] ^ flip]) + raw[i + 1:]
    with pytest.raises(ProtocolError, match="MAC"):
        recv_frame(io.BytesIO(bad), key=b"kk")


# ------------------------------ service lines ------------------------------

@settings(max_examples=50, deadline=None)
@given(n=st.integers(-(2 ** 53), 2 ** 53), x=st.floats(-1e15, 1e15))
def test_service_line_roundtrip_identity(n, x):
    obj = {"op": "t", "n": n, "x": x, "s": "π ≤ ∞",
           "seq": [n, {"y": x}]}
    line = protocol.encode(obj)
    assert line.endswith(b"\n")
    assert protocol.decode(line) == obj


@settings(max_examples=20, deadline=None)
@given(kind=st.sampled_from(["array", "number", "string", "null"]))
def test_service_decode_rejects_non_objects(kind):
    payload = {"array": b"[1,2]", "number": b"3", "string": b'"x"',
               "null": b"null"}[kind]
    with pytest.raises(ValueError):
        protocol.decode(payload)


@settings(max_examples=30, deadline=None)
@given(offset=st.integers(0, 100), flip=st.integers(1, 255))
def test_service_garbage_line_yields_none_never_raises(offset, flip):
    line = protocol.encode({"op": "t", "pad": "p" * 64})
    i = offset % (len(line) - 1)         # keep the newline intact
    bad = line[:i] + bytes([line[i] ^ flip]) + line[i + 1:]
    got = list(protocol.recv_lines(io.BytesIO(bad)))
    assert len(got) <= 1
    for item in got:
        assert item is None or isinstance(item, dict)


@settings(max_examples=10, deadline=None)
@given(excess=st.integers(1, 4096))
def test_service_oversize_line_yields_sentinel_and_stops(excess):
    good = protocol.encode({"op": "ok"})
    blob = good + b"y" * (protocol.MAX_LINE + excess)  # no newline
    got = list(protocol.recv_lines(io.BytesIO(blob)))
    assert got[0] == {"op": "ok"}
    assert got[-1] is protocol.OVERSIZE
    assert len(got) == 2                 # generator stopped after it


def test_service_oversize_line_with_newline_still_rejected():
    # even a terminated line past the cap is refused: readline returned
    # max_line+1 bytes without the newline first
    blob = b"z" * (protocol.MAX_LINE + 10) + b"\n"
    got = list(protocol.recv_lines(io.BytesIO(blob)))
    assert got == [protocol.OVERSIZE]
