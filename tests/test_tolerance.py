"""Tests of the Tier-1 tolerance contract itself (tests/tolerance.py).

Three jobs:
  * the helper's semantics: ulp arithmetic, non-finite handling, and —
    property-tested — that the bound is *tight*: perturbations beyond
    ``TIER1_REL`` fail, perturbations comfortably inside pass;
  * the shape-sweep regression: every Tier-1 optimization (batched
    encoder, scan unroll, split-encoder hoisting + fused Pareto tail,
    exact-shape batches) pinned against the Tier-0 reference at every
    swept shape, with the worst observed ulp drift pinned so growth is
    visible in review;
  * the Tier-0 firewall: the bitwise path (engine, sweep, golden
    fixture) must never import the tolerance helper — Tier-0 has no
    tolerances.
"""
import os
import subprocess
import sys

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import encoder_lstm as net
from repro.core import features
from repro.core.predictor import StragglerPredictor

from tolerance import (TIER1_MAX_ULP, TIER1_REL, assert_tier1, drift,
                       sweep_drift, ulp_diff)

# ------------------------------ helper semantics ----------------------------


def test_ulp_diff_basics():
    a = np.float32(1.0)
    assert ulp_diff(a, a) == 0
    assert ulp_diff(a, np.nextafter(a, np.float32(2.0), dtype=np.float32)) \
        == 1
    # well-defined across the zero crossing: -min_denormal and
    # +min_denormal are 2 ulps apart (one step to each side of 0)
    tiny = np.float32(1e-45)
    assert ulp_diff(-tiny, tiny) == 2
    assert ulp_diff(np.float32(0.0), tiny) == 1


def test_assert_tier1_passes_bitwise_and_returns_drift():
    x = np.linspace(0.1, 5.0, 64, dtype=np.float32)
    d = assert_tier1(x, x)
    assert d == {"max_rel": 0.0, "max_abs": 0.0, "max_ulp": 0}


def test_assert_tier1_nonfinite_must_match_exactly():
    x = np.array([1.0, np.inf, np.nan], np.float32)
    assert_tier1(x, x.copy())  # matching non-finites pass
    y = x.copy()
    y[1] = -np.inf
    with pytest.raises(AssertionError, match="non-finite"):
        assert_tier1(y, x)
    z = x.copy()
    z[2] = 1.0
    with pytest.raises(AssertionError, match="non-finite"):
        assert_tier1(z, x)


def test_drift_shape_mismatch_is_an_error():
    with pytest.raises(AssertionError, match="shape"):
        drift(np.zeros(3, np.float32), np.zeros(4, np.float32))


@settings(max_examples=50, deadline=None)
@given(scale=st.floats(1e-3, 1e3), factor=st.floats(2.0, 100.0))
def test_tier1_bound_is_tight(scale, factor):
    """Property: the bound rejects anything beyond TIER1_REL and accepts
    anything comfortably within it — there is no dead zone where a real
    regression could hide inside the tolerance."""
    x = (np.linspace(0.5, 2.0, 32) * scale).astype(np.float32)
    # beyond the bound: relative error = factor * TIER1_REL > TIER1_REL
    bad = (x.astype(np.float64) * (1.0 + factor * TIER1_REL)).astype(
        np.float32)
    with pytest.raises(AssertionError, match="out of tolerance"):
        assert_tier1(bad, x)
    # comfortably inside: factor/10 >= 0.2, <= 10 -> rel error well under
    # the bound after float32 rounding at these magnitudes
    good = (x.astype(np.float64) * (1.0 + TIER1_REL / 20.0)).astype(
        np.float32)
    assert_tier1(good, x)


def test_sweep_drift_aggregates_worst_pair():
    x = np.ones(8, np.float32)
    y = x.copy()
    y[0] = np.nextafter(y[0], np.float32(2.0), dtype=np.float32)
    worst = sweep_drift([(x, x), (y, x)])
    assert worst["max_ulp"] == 1
    assert worst["max_rel"] > 0


# --------------------------- shape-sweep regression -------------------------
#
# Every Tier-1 optimization vs the Tier-0 reference, across a job-count
# sweep covering exact-shape hits (5, 9), padded buckets and bucket
# boundaries.  The asserts are two-level: assert_tier1 gates at TIER1_REL
# (a real bug fails loudly), and the final ulp pin keeps the observed
# drift trajectory visible — if a future rewrite pushes past it, the pin
# must be consciously re-blessed alongside TIER1_MAX_ULP.

_COUNTS = (1, 2, 3, 5, 8, 9, 12, 16)


def _ref_and_opt_network(n_hosts=6, max_tasks=5, seed=0):
    pred = StragglerPredictor(n_hosts=n_hosts, max_tasks=max_tasks,
                              seed=seed)
    rng = np.random.default_rng(seed)
    t = pred.horizon
    mh = rng.uniform(0, 1, (t, n_hosts, features.HOST_FEATURES)) \
        .astype(np.float32)
    return pred, rng, mh


def _xs_batch(pred, rng, mh, n):
    xs = np.zeros((pred.horizon, n, pred.input_dim), np.float32)
    xs[:, :, :pred.host_dim] = mh.reshape(pred.horizon, 1, -1)
    xs[:, :, pred.host_dim:] = rng.uniform(
        0, 1, (n, pred.task_dim)).astype(np.float32)[None]
    return xs


def test_shape_sweep_batched_encoder_within_bound():
    """predict_sequence_opt(unroll=1) vs predict_sequence isolates the
    batched-encoder fusion (encoder applied over (T, nb) at once instead
    of per scan step)."""
    pred, rng, mh = _ref_and_opt_network()
    pairs = []
    for n in _COUNTS:
        xs = _xs_batch(pred, rng, mh, n)
        ref = np.asarray(net.predict_sequence(pred.params, xs))
        opt = np.asarray(net.predict_sequence_opt(pred.params, xs,
                                                  unroll=1))
        pairs.append((opt, ref))
    worst = sweep_drift(pairs)
    assert worst["max_ulp"] <= TIER1_MAX_ULP


def test_shape_sweep_unroll_within_bound():
    """Full unroll vs unroll=1 of the same decode isolates the scan
    unrolling (loop fusion changes FMA grouping at some shapes)."""
    pred, rng, mh = _ref_and_opt_network()
    pairs = []
    for n in _COUNTS:
        xs = _xs_batch(pred, rng, mh, n)
        u1 = np.asarray(net.predict_sequence_opt(pred.params, xs,
                                                 unroll=1))
        uT = np.asarray(net.predict_sequence_opt(pred.params, xs,
                                                 unroll=pred.horizon))
        pairs.append((uT, u1))
    worst = sweep_drift(pairs)
    assert worst["max_ulp"] <= TIER1_MAX_ULP


def _fused_vs_reference(exact_shapes: bool):
    """Warm fused intervals vs predict_features at every swept count;
    ``exact_shapes`` toggles the exact-shape batch policy so its drift
    contribution is isolated from the hoisting + fused-tail rewrite."""
    n_hosts, max_tasks = 6, 5
    pred = StragglerPredictor(
        n_hosts=n_hosts, max_tasks=max_tasks,
        exact_shape_waste=0.25 if exact_shapes else 1.0)
    rng = np.random.default_rng(7)
    t = pred.horizon
    rows = [rng.uniform(0, 1, (n_hosts, features.HOST_FEATURES))
            .astype(np.float32) for _ in range(t)]
    for r in rows:
        pred.push_host_row(r)
    pairs = []
    for n in _COUNTS:
        mt = rng.uniform(0, 1, (n, max_tasks, features.TASK_FEATURES)) \
            .astype(np.float32)
        q = rng.integers(1, max_tasks + 1, n).astype(np.float32)
        e_fused = pred.predict_interval(mt, q)
        ref = pred.predict_features(np.stack(rows[-t:]), mt, q)
        pairs.append((e_fused, np.asarray(ref.e_s)))
        # per-task head drifts identically or less (same upstream math)
        rows.append(rng.uniform(0, 1, (n_hosts, features.HOST_FEATURES))
                    .astype(np.float32))
        pred.push_host_row(rows[-1])
        e_pt, scores = pred.predict_interval(mt, q, per_task=True)
        ref_es, ref_scores = pred.predict_features(
            np.stack(rows[-t:]), mt, q, per_task=True)
        pairs.append((e_pt, ref_es))
        pairs.append((scores.ravel(), ref_scores.ravel()))
        rows.append(rng.uniform(0, 1, (n_hosts, features.HOST_FEATURES))
                    .astype(np.float32))
        pred.push_host_row(rows[-1])
    return sweep_drift(pairs)


def test_shape_sweep_fused_step_within_bound():
    """The full fused program (split-encoder hoisting + unroll + fused
    Pareto tail, padding disabled from the exact-shape policy) vs the
    Tier-0 reference at every swept shape — the acceptance criterion's
    fused == unfused proof."""
    worst = _fused_vs_reference(exact_shapes=False)
    assert worst["max_ulp"] <= TIER1_MAX_ULP


def test_shape_sweep_exact_shapes_within_bound():
    """Same sweep with exact-shape batches enabled: counts 5 and 9 run at
    their exact widths instead of buckets 8/16, exercising the
    batch-width drift source on top of the fused rewrite."""
    worst = _fused_vs_reference(exact_shapes=True)
    assert worst["max_ulp"] <= TIER1_MAX_ULP


def test_shape_sweep_tenant_batch_within_bound():
    """The serving batch path (predict_sequence_opt behind
    predict_tenants) vs per-tenant reference predictions."""
    n_hosts, max_tasks = 6, 5
    pred = StragglerPredictor(n_hosts=n_hosts, max_tasks=max_tasks)
    rng = np.random.default_rng(11)
    t = pred.horizon
    seqs, mts, qs = [], [], []
    for n in (3, 1, 4, 2):
        seqs.append(rng.uniform(
            0, 1, (t, n_hosts, features.HOST_FEATURES)).astype(np.float32))
        mts.append(rng.uniform(
            0, 1, (n, max_tasks, features.TASK_FEATURES)).astype(np.float32))
        qs.append(rng.integers(1, max_tasks + 1, n).astype(np.float32))
    outs = pred.predict_tenants(seqs, mts, qs)
    pairs = [(e, np.asarray(pred.predict_features(s, m, q).e_s))
             for e, s, m, q in zip(outs, seqs, mts, qs)]
    worst = sweep_drift(pairs)
    assert worst["max_ulp"] <= TIER1_MAX_ULP


# ------------------------------ Tier-0 firewall -----------------------------


def test_tier0_path_never_imports_tolerance():
    """The golden-fixture import closure (engine, sweep, techniques,
    START controller, predictor) must not pull in the tolerance helper:
    Tier-0 is bitwise and has no tolerances to consult.  Run in a clean
    subprocess so this test's own imports don't contaminate the check."""
    code = (
        "import sys\n"
        "import repro.sim.sweep, repro.sim.engine, repro.sim.techniques\n"
        "import repro.core.start, repro.core.predictor\n"
        "bad = [m for m in sys.modules if 'tolerance' in m.lower()]\n"
        "assert not bad, f'Tier-0 closure imported {bad}'\n"
    )
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    env["PYTHONPATH"] = src
    subprocess.run([sys.executable, "-c", code], env=env, check=True,
                   timeout=120)
