"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs
pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import encoder_lstm as net
from repro.kernels.decode_attention import (decode_attention,
                                            decode_attention_ref)
from repro.kernels.flash_attention import attention_ref, flash_attention
from repro.kernels.lstm_cell import lstm_cell, lstm_cell_ref
from repro.kernels.mamba_scan import mamba_scan, mamba_scan_ref
from repro.kernels.moe_router import moe_router, moe_router_ref

jax.config.update("jax_platform_name", "cpu")


def tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


# ------------------------------ flash attention ---------------------------

FLASH_SWEEP = [
    # (b, h, hkv, s, d, causal)
    (1, 4, 4, 128, 64, True),     # MHA
    (1, 4, 2, 256, 64, True),     # GQA 2:1
    (2, 8, 1, 128, 128, True),    # MQA
    (1, 2, 2, 192, 64, False),    # non-causal, non-pow2 seq
    (1, 4, 2, 100, 128, True),    # padding path
]


@pytest.mark.parametrize("b,h,hkv,s,d,causal", FLASH_SWEEP)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(b, h, hkv, s, d, causal, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, h, s, d), dtype)
    k = jax.random.normal(ks[1], (b, hkv, s, d), dtype)
    v = jax.random.normal(ks[2], (b, hkv, s, d), dtype)
    out = flash_attention(q, k, v, causal)
    ref = attention_ref(q, k, v, causal=causal)
    assert out.dtype == q.dtype
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **tol(dtype))


def test_flash_attention_grad_matches_ref():
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 4, 128, 64))
    k = jax.random.normal(ks[1], (1, 2, 128, 64))
    v = jax.random.normal(ks[2], (1, 2, 128, 64))
    g1 = jax.grad(lambda q_: flash_attention(q_, k, v, True).sum())(q)
    g2 = jax.grad(lambda q_: attention_ref(q_, k, v, causal=True).sum())(q)
    np.testing.assert_allclose(g1, g2, rtol=1e-4, atol=1e-4)


# ------------------------------ decode attention --------------------------

DECODE_SWEEP = [
    # (b, h, hkv, s, d, kv_len)
    (1, 4, 4, 512, 64, 512),
    (2, 8, 2, 1024, 128, 700),    # masked tail
    (1, 16, 2, 512, 128, 512),
    (1, 4, 1, 300, 64, 300),      # padding path
]


@pytest.mark.parametrize("b,h,hkv,s,d,kvlen", DECODE_SWEEP)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_sweep(b, h, hkv, s, d, kvlen, dtype):
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (b, h, d), dtype)
    k = jax.random.normal(ks[1], (b, hkv, s, d), dtype)
    v = jax.random.normal(ks[2], (b, hkv, s, d), dtype)
    out = decode_attention(q, k, v, kv_len=kvlen)
    ref = decode_attention_ref(q, k, v, kv_len=kvlen)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **tol(dtype))


def test_decode_matches_flash_last_row():
    """Decode of the last position == causal flash attention's last row."""
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    b, h, hkv, s, d = 1, 4, 2, 128, 64
    q = jax.random.normal(ks[0], (b, h, s, d))
    k = jax.random.normal(ks[1], (b, hkv, s, d))
    v = jax.random.normal(ks[2], (b, hkv, s, d))
    full = flash_attention(q, k, v, True)
    dec = decode_attention(q[:, :, -1], k, v, kv_len=s)
    np.testing.assert_allclose(dec, full[:, :, -1], rtol=1e-5, atol=1e-5)


# -------------------------------- mamba scan ------------------------------

MAMBA_SWEEP = [
    # (b, l, d, n)
    (1, 64, 128, 16),
    (2, 128, 64, 16),     # d below block -> padding path
    (1, 96, 256, 8),      # non-pow2 length
]


@pytest.mark.parametrize("b,l,d,n", MAMBA_SWEEP)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_mamba_scan_sweep(b, l, d, n, dtype):
    ks = jax.random.split(jax.random.PRNGKey(4), 6)
    u = jax.random.normal(ks[0], (b, l, d), dtype)
    delta = jax.nn.softplus(jax.random.normal(ks[1], (b, l, d), dtype))
    a = -jnp.exp(jax.random.normal(ks[2], (d, n)))
    bmat = jax.random.normal(ks[3], (b, l, n), dtype)
    cmat = jax.random.normal(ks[4], (b, l, n), dtype)
    skip = jax.random.normal(ks[5], (d,))
    out = mamba_scan(u, delta, a, bmat, cmat, skip)
    ref = mamba_scan_ref(u, delta, a, bmat, cmat, skip)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               **(dict(rtol=5e-2, atol=5e-2)
                                  if dtype == jnp.bfloat16 else
                                  dict(rtol=1e-4, atol=1e-4)))


def test_mamba_scan_grad_finite():
    b, l, d, n = 1, 32, 64, 8
    ks = jax.random.split(jax.random.PRNGKey(5), 6)
    u = jax.random.normal(ks[0], (b, l, d))
    delta = jax.nn.softplus(jax.random.normal(ks[1], (b, l, d)))
    a = -jnp.exp(jax.random.normal(ks[2], (d, n)))
    bmat = jax.random.normal(ks[3], (b, l, n))
    cmat = jax.random.normal(ks[4], (b, l, n))
    skip = jax.random.normal(ks[5], (d,))
    g = jax.grad(lambda u_: mamba_scan(u_, delta, a, bmat, cmat,
                                       skip).sum())(u)
    assert bool(jnp.isfinite(g).all())


# --------------------------------- lstm cell ------------------------------

LSTM_SWEEP = [
    # (batch, n_in, hidden)
    (8, 32, 32),      # the paper's encoder-LSTM geometry
    (130, 32, 32),    # padding path
    (64, 128, 64),
]


@pytest.mark.parametrize("bsz,nin,hid", LSTM_SWEEP)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_lstm_cell_sweep(bsz, nin, hid, dtype):
    ks = jax.random.split(jax.random.PRNGKey(6), 6)
    x = jax.random.normal(ks[0], (bsz, nin), dtype)
    h = jax.random.normal(ks[1], (bsz, hid), dtype)
    c = jax.random.normal(ks[2], (bsz, hid), dtype)
    wx = jax.random.normal(ks[3], (nin, 4 * hid), dtype) * 0.2
    wh = jax.random.normal(ks[4], (hid, 4 * hid), dtype) * 0.2
    b = jax.random.normal(ks[5], (4 * hid,), dtype) * 0.1
    h2, c2 = lstm_cell(x, h, c, wx, wh, b)
    hr, cr = lstm_cell_ref(x, h, c, wx, wh, b)
    np.testing.assert_allclose(np.asarray(h2, np.float32),
                               np.asarray(hr, np.float32), **tol(dtype))
    np.testing.assert_allclose(np.asarray(c2, np.float32),
                               np.asarray(cr, np.float32), **tol(dtype))


def test_lstm_kernel_matches_core_network_cell():
    """The kernel implements exactly the core encoder_lstm cell."""
    layer = net._lstm_init(jax.random.PRNGKey(7), 32, 32)
    x = jax.random.normal(jax.random.PRNGKey(8), (16, 32))
    h = jnp.zeros((16, 32))
    c = jnp.zeros((16, 32))
    h1, c1 = net.lstm_cell_apply(layer, h, c, x)
    h2, c2 = lstm_cell(x, h, c, layer["wx"], layer["wh"], layer["b"])
    np.testing.assert_allclose(h1, h2, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(c1, c2, rtol=1e-5, atol=1e-6)


# --------------------------------- moe router -----------------------------

ROUTER_SWEEP = [
    # (tokens, experts, k)
    (256, 8, 2),
    (512, 128, 8),     # qwen3-moe geometry
    (300, 256, 8),     # deepseek-v3 geometry + padding path
    (64, 16, 2),       # jamba geometry
]


@pytest.mark.parametrize("t,e,k", ROUTER_SWEEP)
def test_moe_router_sweep(t, e, k):
    logits = jax.random.normal(jax.random.PRNGKey(9), (t, e))
    w, idx = moe_router(logits, k)
    wr, idxr = moe_router_ref(logits, k)
    # weight sets must match (order may differ on ties; none expected with
    # random floats)
    np.testing.assert_array_equal(np.sort(idx, -1), np.sort(idxr, -1))
    np.testing.assert_allclose(np.sort(w, -1), np.sort(wr, -1),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(w.sum(-1), 1.0, rtol=1e-5)


def test_moe_router_weights_positive_topk():
    logits = jax.random.normal(jax.random.PRNGKey(10), (128, 32))
    w, idx = moe_router(logits, 4)
    assert (np.asarray(w) > 0).all()
    assert (np.asarray(idx) >= 0).all() and (np.asarray(idx) < 32).all()
    # indices unique per row
    assert all(len(set(row)) == 4 for row in np.asarray(idx))
