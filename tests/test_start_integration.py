"""End-to-end START-in-simulator tests (paper Alg. 1 + §4.4 training)."""
import numpy as np
import pytest

from repro.sim import Simulation, small
from repro.sim.metrics import mape
from repro.sim.techniques import START, make
from repro.sim.techniques.start_tech import (collect_training_data,
                                             pretrain)


@pytest.fixture(scope="module")
def trained_controller():
    cfg = small(n_hosts=12, n_intervals=50, seed=7)
    return pretrain(cfg, epochs=10, lr=1e-3), cfg


def test_collect_training_data_shapes():
    cfg = small(n_hosts=8, n_intervals=40, seed=1)
    xs, ys = collect_training_data(cfg)
    assert xs.ndim == 3 and xs.shape[0] == 5  # (T, jobs, dim)
    assert ys.shape == (xs.shape[1], 2)
    assert np.isfinite(xs).all() and np.isfinite(ys).all()
    assert (ys[:, 0] >= 1.0).all()  # alpha clipped for defined mean
    assert (ys[:, 1] > 0.0).all()


def test_start_runs_and_mitigates(trained_controller):
    ctrl, cfg = trained_controller
    sim = Simulation(small(n_hosts=12, n_intervals=60, seed=11),
                     technique=START(controller=ctrl))
    s = sim.run()
    assert s["tasks_done"] > 0
    # mitigation machinery exercised: either copies were made (speculate)
    # or tasks were re-run on a new host
    tt = sim.tasks
    mitigated = tt.view("is_copy").sum() + (tt.view("restarts") > 0).sum()
    assert mitigated > 0


def test_start_predictions_logged(trained_controller):
    ctrl, _ = trained_controller
    sim = Simulation(small(n_hosts=12, n_intervals=40, seed=2),
                     technique=START(controller=ctrl))
    sim.run()
    preds = np.array(sim.log.predicted_stragglers, float)
    assert np.isfinite(preds).any()
    assert (preds[np.isfinite(preds)] >= 0).all()


def test_mape_comparison_runs(trained_controller):
    """Fig. 9 machinery: MAPE of START vs IGRU-SD vs RPPS is computable."""
    ctrl, _ = trained_controller
    out = {}
    for name, tech in (("start", START(controller=ctrl)),
                       ("igru-sd", make("igru-sd")),
                       ("rpps", make("rpps"))):
        sim = Simulation(small(n_hosts=12, n_intervals=50, seed=5),
                         technique=tech)
        sim.run()
        actual = sim.actual_stragglers_per_interval()
        pred = np.array(sim.log.predicted_stragglers, float)
        out[name] = mape(actual, pred)
    assert all(np.isfinite(v) or np.isnan(v) for v in out.values())


def test_start_beats_no_mitigation(trained_controller):
    """Core paper claim, statistically: lower exec time + SLA violations
    than running with no straggler management (averaged over seeds)."""
    ctrl, _ = trained_controller

    def avg(technique_factory):
        es, svs = [], []
        for seed in (21, 22, 23):
            cfg = small(n_hosts=12, n_intervals=70, seed=seed,
                        fault_host_rate=0.03)
            sim = Simulation(cfg, technique=technique_factory())
            s = sim.run()
            es.append(s["avg_execution_time_s"])
            svs.append(s["sla_violation_rate"])
        return np.mean(es), np.mean(svs)

    e_none, sla_none = avg(lambda: make("none"))
    e_start, sla_start = avg(lambda: START(controller=ctrl))
    assert e_start <= e_none * 1.05  # at worst on par, typically better
    assert sla_start <= sla_none + 0.05
