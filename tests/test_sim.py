"""Simulator behaviour + invariant tests (engine, cluster, faults, metrics)."""
import numpy as np
# hypothesis is optional: conftest.py installs a fixed-example fallback stub
# when the real package is absent, so collection never hard-errors
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.sim import Simulation, small
from repro.sim import engine as E
from repro.sim.scheduler import RandomScheduler, UtilizationAwareScheduler
from repro.sim.techniques import SGC, make


def run_small(tech=None, **kw):
    cfg = small(**kw)
    sim = Simulation(cfg, technique=tech)
    sim.run()
    return sim


def test_sim_runs_and_completes_jobs():
    sim = run_small()
    s = sim.summary()
    assert s["tasks_done"] > 0
    assert s["jobs_done"] > 0
    assert s["avg_execution_time_s"] > 0
    assert s["energy_kwh"] > 0


def test_determinism():
    s1 = run_small().summary()
    s2 = run_small().summary()
    for k in ("tasks_done", "avg_execution_time_s", "energy_kwh",
              "sla_violation_rate"):
        assert s1[k] == s2[k], k


def test_task_state_conservation():
    """Every original task is pending, running, done or cancelled; copies
    only exist with a valid original."""
    sim = run_small()
    tt = sim.tasks
    states = tt.view("state")
    assert set(np.unique(states)) <= {E.PENDING, E.RUNNING, E.DONE,
                                      E.CANCELLED}
    copies = np.nonzero(tt.view("is_copy"))[0]
    for c in copies:
        assert 0 <= tt.orig[c] < tt.n
    # a DONE original has finish >= submit
    done = (states == E.DONE) & ~tt.view("is_copy")
    assert (tt.view("finish_s")[done] >= tt.view("submit_s")[done]).all()


def test_completed_job_accounting():
    sim = run_small()
    for rec in sim.completed_jobs:
        assert (rec["times"] > 0).all()
        assert rec["straggler"].shape == rec["times"].shape
        assert len(sim.jobs.task_ids(rec["job"])) == len(rec["times"])


def test_heterogeneous_hosts_exist():
    sim = run_small(n_hosts=50)
    assert len(np.unique(sim.cluster.speed)) > 1
    assert len(np.unique(sim.cluster.type_names)) > 1


def test_reserved_utilization_increases_exec_time():
    base = run_small(n_intervals=80).summary()
    loaded = run_small(n_intervals=80,
                       reserved_utilization=0.6).summary()
    assert loaded["avg_execution_time_s"] > base["avg_execution_time_s"]
    assert loaded["energy_kwh"] > base["energy_kwh"]


def test_faults_cause_restarts():
    cfg = small(fault_host_rate=0.2, fault_task_rate=0.1, n_intervals=60)
    sim = Simulation(cfg)
    sim.run()
    assert sim.tasks.view("restarts").sum() > 0


def test_no_faults_no_restarts():
    cfg = small(fault_host_rate=0.0, fault_task_rate=0.0,
                fault_vm_creation_rate=0.0, n_intervals=40)
    sim = Simulation(cfg)
    sim.run()
    assert sim.tasks.view("restarts").sum() == 0


def test_speculation_first_wins_cancels_losers():
    cfg = small(n_intervals=50)

    class SpecEverything(E.Technique):
        name = "spec-all"

        def on_interval(self):
            tt = self.sim.tasks
            acts = []
            for i in np.nonzero(tt.active_mask())[0][:5]:
                if not tt.is_copy[i]:
                    acts.append(E.SimAction("speculate", int(i), target=0))
            return acts

    sim = Simulation(cfg, technique=SpecEverything())
    sim.run()
    tt = sim.tasks
    assert tt.view("is_copy").sum() > 0
    # no task group has two DONE members
    for c in np.nonzero(tt.view("is_copy"))[0]:
        o = int(tt.orig[c])
        group_done = int(tt.state[c] == E.DONE) + int(tt.state[o] == E.DONE)
        if tt.state[o] == E.DONE and tt.state[c] == E.DONE:
            # same finish stamp = copy won and completed the original
            assert tt.finish_s[o] == tt.finish_s[c]


def test_baseline_techniques_run():
    for name in ("nearestfit", "dolly", "grass", "sgc", "wrangler",
                 "igru-sd", "rpps"):
        cfg = small(n_intervals=40, n_hosts=12, seed=3)
        sim = Simulation(cfg, technique=make(name))
        s = sim.run()
        assert s["tasks_done"] > 0, name


def test_sgc_creates_clones():
    cfg = small(n_intervals=40, seed=1)
    sim = Simulation(cfg, technique=SGC(p=1.0))
    sim.run()
    assert sim.tasks.view("is_copy").sum() > 0


def test_random_vs_util_scheduler_differ():
    cfg = small(n_intervals=50)
    s1 = Simulation(cfg, scheduler=UtilizationAwareScheduler()).run()
    cfg2 = small(n_intervals=50)
    s2 = Simulation(cfg2, scheduler=RandomScheduler()).run()
    assert s1["avg_execution_time_s"] != s2["avg_execution_time_s"]


def test_actual_stragglers_per_interval():
    sim = run_small()
    actual = sim.actual_stragglers_per_interval()
    assert len(actual) == sim.t
    total = sum(rec["straggler"].sum() for rec in sim.completed_jobs)
    if total > 0:
        assert actual.sum() > 0


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 1000), res=st.sampled_from([0.0, 0.3, 0.6]))
def test_property_engine_invariants(seed, res):
    cfg = small(n_intervals=30, n_hosts=10, seed=seed,
                reserved_utilization=res)
    sim = Simulation(cfg)
    sim.run()
    tt = sim.tasks
    # progress never exceeds work by more than one interval of top speed
    run_or_done = np.isin(tt.view("state"), [E.RUNNING, E.DONE])
    assert (tt.view("progress")[run_or_done] >= 0).all()
    # all finish times within horizon
    done = tt.view("state") == E.DONE
    horizon = (cfg.n_intervals + 1) * cfg.interval_seconds
    assert (tt.view("finish_s")[done] <= horizon).all()
    # energy positive each interval, bounded by sum(power_max)
    e = np.array(sim.log.energy_w)
    assert (e > 0).all()
    assert (e <= sim.cluster.power_max.sum() + 1e-6).all()
    # utilization non-negative
    assert (np.array(sim.log.util_cpu) >= 0).all()
