"""Serving-core tests: bitwise equivalence, multi-tenant batching with
zero warm retraces, the boundary sanitizer, backpressure, and the
versioned retrain/shadow-eval/rollback lifecycle."""
import json
import os

import jax
import numpy as np
import pytest

from repro.core import encoder_lstm as net
from repro.core import features
from repro.core.predictor import StragglerPredictor, fused_compile_count
from repro.policy import wire
from repro.policy.actions import Action, ActionKind
from repro.service import (LocalClient, PredictionService, Profile,
                           ServiceConfig, ServiceDaemon, TelemetryError,
                           sanitize_snapshot)
from repro.service import retrain as svc_retrain
from repro.train.checkpoint import VersionStore

N_HOSTS, MAX_TASKS, HORIZON = 3, 4, 5


def profile(**kw) -> Profile:
    return Profile(n_hosts=N_HOSTS, max_tasks=MAX_TASKS,
                   horizon=HORIZON, **kw)


def rand_mh(rng):
    return rng.random((N_HOSTS, features.HOST_FEATURES)) \
        .astype(np.float32)


def rand_mt(rng, q=3):
    m_t = np.zeros((MAX_TASKS, features.TASK_FEATURES), np.float32)
    m_t[:q] = rng.random((q, features.TASK_FEATURES))
    return m_t


def mk_snap(tenant, seq, m_h, m_t, q=3, job_id=1, done=None):
    tasks = [(100 + i, i % N_HOSTS, i) for i in range(q)]
    return wire.snapshot_to_wire(
        tenant, seq, m_h,
        jobs=[wire.job_to_wire(job_id, q, m_t, tasks=tasks)],
        done=done or [])


def compile_counters():
    return net.predict_sequence._cache_size() + fused_compile_count()


# ------------------------------ wire format ------------------------------

def test_action_wire_roundtrip():
    a = Action(kind=ActionKind.SPECULATE, task=7, target=2, host=5)
    b = wire.action_from_wire(wire.action_to_wire(a))
    assert b == a
    # defaults are omitted on the wire and restored on parse
    small = wire.action_to_wire(Action(kind=ActionKind.RERUN, task=1))
    assert set(small) == {"kind", "task"}
    assert wire.action_from_wire(small).n_clones == 1
    with pytest.raises(ValueError, match="unknown Action wire"):
        wire.action_from_wire({"kind": "rerun", "task": 1, "zap": 2})


def test_profile_wire_roundtrip_and_compat():
    p = profile(trigger="per_task", score_on=0.1)
    assert Profile.from_wire(p.to_wire()) == p
    assert p.compatible(profile(trigger="per_task", score_on=0.1))
    assert not p.compatible(profile())              # trigger differs
    assert not profile().compatible(
        Profile(n_hosts=N_HOSTS + 1, max_tasks=MAX_TASKS))
    with pytest.raises(ValueError, match="unknown Profile"):
        Profile.from_wire({"n_hosts": 2, "max_tasks": 2, "zap": 1})


# ------------------------------ sanitizer --------------------------------

def test_sanitizer_clamps_nonfinite_features():
    rng = np.random.default_rng(0)
    m_h = rand_mh(rng)
    m_h[0, 0] = np.nan
    m_h[1, 2] = np.inf
    snap = mk_snap("t", 0, m_h, rand_mt(rng))
    clean = sanitize_snapshot(snap, profile(), -1.0, mode="clamp")
    assert np.isfinite(clean["m_h"]).all()
    assert clean["m_h"][0, 0] == 0.0
    assert any("non-finite" in s for s in clean["issues"])


def test_sanitizer_reject_mode_raises_on_nonfinite():
    rng = np.random.default_rng(0)
    m_h = rand_mh(rng)
    m_h[0, 0] = np.nan
    snap = mk_snap("t", 0, m_h, rand_mt(rng))
    with pytest.raises(TelemetryError) as e:
        sanitize_snapshot(snap, profile(), -1.0, mode="reject")
    assert e.value.code == "bad-telemetry"


def test_sanitizer_drops_bad_durations():
    rng = np.random.default_rng(0)
    snap = mk_snap("t", 0, rand_mh(rng), rand_mt(rng),
                   done=[{"id": 4, "times": [1.0, -3.0, np.nan, 2.0]}])
    clean = sanitize_snapshot(snap, profile(), -1.0, mode="clamp")
    np.testing.assert_array_equal(clean["done"][0]["times"],
                                  np.float32([1.0, 2.0]))
    with pytest.raises(TelemetryError):
        sanitize_snapshot(snap, profile(), -1.0, mode="reject")


def test_sanitizer_rejects_out_of_order_and_structural():
    rng = np.random.default_rng(0)
    snap = mk_snap("t", 3, rand_mh(rng), rand_mt(rng))
    with pytest.raises(TelemetryError) as e:
        sanitize_snapshot(snap, profile(), 3.0)  # seq replay
    assert e.value.code == "out-of-order"
    bad = mk_snap("t", 9, rand_mh(rng)[:, :-1], rand_mt(rng))
    with pytest.raises(TelemetryError) as e:
        sanitize_snapshot(bad, profile(), -1.0)  # wrong M_H shape
    assert e.value.code == "bad-shape"
    bad_q = mk_snap("t", 9, rand_mh(rng), rand_mt(rng))
    bad_q["jobs"][0]["q"] = MAX_TASKS + 3
    with pytest.raises(TelemetryError) as e:
        sanitize_snapshot(bad_q, profile(), -1.0)
    assert e.value.code == "bad-job"


# --------------------------- admission / queues --------------------------

def test_admission_control():
    svc = PredictionService(ServiceConfig(profile=profile(),
                                          max_tenants=2))
    assert svc.hello("a", profile().to_wire())["ok"]
    assert svc.hello("a", profile().to_wire())["rejoined"]
    bad = svc.hello("b", profile(k=9.9).to_wire())
    assert not bad["ok"] and bad["error"] == "incompatible-profile"
    assert svc.hello("b", profile().to_wire())["ok"]
    full = svc.hello("c", profile().to_wire())
    assert not full["ok"] and full["error"] == "at-capacity"
    # snapshots from a tenant that never said hello are refused
    p = svc.submit("ghost", {"seq": 0})
    assert p.result["error"] == "not-admitted"


def test_backpressure_sheds_oldest():
    svc = PredictionService(ServiceConfig(profile=profile(),
                                          queue_depth=2))
    svc.hello("a", profile().to_wire())
    rng = np.random.default_rng(0)
    ps = [svc.submit("a", mk_snap("a", i, rand_mh(rng), rand_mt(rng)))
          for i in range(3)]
    assert ps[0].result["error"] == "overload"    # shed, not dropped
    assert ps[1].result is None and ps[2].result is None
    svc.tick()                                     # one per tenant/tick
    svc.tick()
    assert ps[1].result["ok"] and ps[2].result["ok"]
    assert svc.stats()["sheds"] == 1


# --------------------------- bitwise equivalence -------------------------

def _reference_run(m_hs, m_t, q, per_task=False):
    """Drive a bare predictor exactly as the service tenant would."""
    pred = StragglerPredictor(n_hosts=N_HOSTS, max_tasks=MAX_TASKS,
                              horizon=HORIZON)
    out = None
    for m_h in m_hs:
        pred.push_host_row(m_h)
        out = pred.predict_interval(
            m_t[None], np.array([float(q)], np.float32),
            per_task=per_task)
    return out


def test_single_tenant_bitwise_equals_predict_interval():
    rng = np.random.default_rng(7)
    m_hs = [rand_mh(rng) for _ in range(3)]
    m_t = rand_mt(rng)
    svc = PredictionService(ServiceConfig(profile=profile()))
    c = LocalClient(svc, "t0")
    assert c.hello(profile())["ok"]
    for i, m_h in enumerate(m_hs):
        r = c.snapshot(mk_snap("t0", i, m_h, m_t))
    ref = _reference_run(m_hs, m_t, 3)
    assert r["jobs"][0]["e_s"] == float(np.asarray(ref)[0])


def test_single_tenant_bitwise_per_task_scores():
    rng = np.random.default_rng(8)
    m_hs = [rand_mh(rng) for _ in range(3)]
    m_t = rand_mt(rng)
    prof = profile(trigger="per_task")
    svc = PredictionService(ServiceConfig(profile=prof))
    c = LocalClient(svc, "t0")
    assert c.hello(prof)["ok"]
    for i, m_h in enumerate(m_hs):
        r = c.snapshot(mk_snap("t0", i, m_h, m_t))
    e_ref, s_ref = _reference_run(m_hs, m_t, 3, per_task=True)
    assert r["jobs"][0]["e_s"] == float(np.asarray(e_ref)[0])
    np.testing.assert_array_equal(
        np.float64(r["jobs"][0]["scores"]),
        np.float64(np.asarray(s_ref)[0, :3]))


def test_tcp_roundtrip_bitwise_and_json_lossless():
    """The acceptance criterion: telemetry in over TCP -> answers out,
    bitwise-equal to the in-process fused step (finite float32 survives
    the float64 JSON round trip losslessly)."""
    rng = np.random.default_rng(9)
    m_hs = [rand_mh(rng) for _ in range(3)]
    m_t = rand_mt(rng)
    with ServiceDaemon(ServiceConfig(profile=profile())) as d:
        c = d.tcp_client("tcp0")
        assert c.hello(profile())["ok"]
        for i, m_h in enumerate(m_hs):
            r = c.snapshot(mk_snap("tcp0", i, m_h, m_t))
        c.bye()
    ref = _reference_run(m_hs, m_t, 3)
    assert r["jobs"][0]["e_s"] == float(np.asarray(ref)[0])


def test_malformed_tenant_never_poisons_healthy_tenant():
    """A tenant streaming garbage is rejected at the boundary; the
    healthy tenant's answers stay bitwise-identical to a run where the
    malformed tenant never existed, and the service stays up."""
    rng = np.random.default_rng(10)
    m_hs = [rand_mh(rng) for _ in range(3)]
    m_t = rand_mt(rng)
    svc = PredictionService(ServiceConfig(profile=profile(),
                                          sanitize="reject"))
    good = LocalClient(svc, "good")
    evil = LocalClient(svc, "evil")
    assert good.hello(profile())["ok"] and evil.hello(profile())["ok"]
    for i, m_h in enumerate(m_hs):
        bad = mk_snap("evil", i, np.full_like(m_h, np.nan), m_t)
        rb = evil.snapshot(bad)
        assert not rb["ok"] and rb["error"] == "bad-telemetry"
        shape = evil.snapshot(mk_snap("evil", i + 100,
                                      m_h[:, :-1], m_t))
        assert not shape["ok"] and shape["error"] == "bad-shape"
        r = good.snapshot(mk_snap("good", i, m_h, m_t))
        assert r["ok"]
    ref = _reference_run(m_hs, m_t, 3)
    assert r["jobs"][0]["e_s"] == float(np.asarray(ref)[0])
    st = svc.stats()
    assert st["ok"] and st["rejected"] == 6


# ----------------------- multi-tenant batch serving ----------------------

def _round(svc, tenants, rng, seq, m_t):
    """Submit one snapshot per tenant, then one batch tick for all."""
    ps = [svc.submit(t, mk_snap(t, seq, rand_mh(rng), m_t))
          for t in tenants]
    svc.tick()
    for p in ps:
        assert p.result is not None and p.result["ok"], p.result
    return ps


def test_interleaved_tenants_zero_warm_retraces(monkeypatch):
    """Interleaved multi-tenant traffic must reuse the power-of-two
    bucket cache: after each tenant-count pattern has run once, further
    ticks compile nothing and upload only through ``_stage`` — pinned
    under ``transfer_guard('disallow')`` exactly like the fused-step
    test."""
    svc = PredictionService(ServiceConfig(profile=profile()))
    rng = np.random.default_rng(11)
    tenants = [f"t{i}" for i in range(4)]
    for t in tenants:
        assert svc.hello(t, profile().to_wire())["ok"]
    m_t = rand_mt(rng)
    seq = 0
    # warm every pattern: single-tenant (fused), 2-, 3- and 4-tenant
    for group in ([tenants[0]], tenants[:2], tenants[:3], tenants):
        _round(svc, group, rng, seq, m_t)
        seq += 1

    orig = StragglerPredictor._stage

    def sanctioned(self, arr):
        with jax.transfer_guard_host_to_device("allow"):
            return orig(self, arr)

    monkeypatch.setattr(StragglerPredictor, "_stage", sanctioned)
    before = compile_counters()
    with jax.transfer_guard_host_to_device("disallow"):
        for group in (tenants[:3], [tenants[1]], tenants, tenants[:2],
                      [tenants[3]], tenants[:3]):
            _round(svc, group, rng, seq, m_t)
            seq += 1
    assert compile_counters() - before == 0, \
        "warm multi-tenant tick retraced a prediction program"


def test_multi_tenant_matches_single_tenant_answers():
    """The combined dispatch answers each tenant with the same E_S the
    unfused single-tenant path computes from identical features (same
    math at a wider batch shape -> allclose, not bitwise)."""
    rng = np.random.default_rng(12)
    svc = PredictionService(ServiceConfig(profile=profile()))
    tenants = ["a", "b", "c"]
    for t in tenants:
        assert svc.hello(t, profile().to_wire())["ok"]
    snaps = {t: (rand_mh(rng), rand_mt(rng)) for t in tenants}
    ps = [svc.submit(t, mk_snap(t, 0, mh, mt))
          for t, (mh, mt) in snaps.items()]
    svc.tick()
    for t, p in zip(tenants, ps):
        m_h, m_t = snaps[t]
        pred = StragglerPredictor(n_hosts=N_HOSTS, max_tasks=MAX_TASKS,
                                  horizon=HORIZON)
        seq = np.stack([m_h] * HORIZON)
        ref = pred.predict_features(seq, m_t[None],
                                    np.array([3.0], np.float32))
        np.testing.assert_allclose(p.result["jobs"][0]["e_s"],
                                   float(np.asarray(ref.e_s)[0]),
                                   rtol=1e-5)


# ------------------------ versioning / shadow eval -----------------------

def test_version_store_promote_rollback_retention(tmp_path):
    pred = StragglerPredictor(n_hosts=2, max_tasks=2)
    store = VersionStore(str(tmp_path), keep=2)
    store.save_version(0, pred.params)
    store.promote(0)
    for v in (1, 2):
        store.save_version(v, pred.params)
    store.promote(2)
    for v in (3, 4):
        store.save_version(v, pred.params)
    # retention dropped 1 but pinned the promotion trail {0, 2}
    assert 1 not in store.versions()
    assert {0, 2}.issubset(store.versions())
    assert store.current() == 2 and store.history() == [0]
    assert store.rollback() == 0
    assert store.current() == 0 and store.history() == []
    assert store.rollback() is None
    loaded = store.load_version(0, pred.params)
    for a, b in zip(jax.tree_util.tree_leaves(loaded),
                    jax.tree_util.tree_leaves(pred.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def _drive_pairs(svc, client, rng, steps, start_seq=0):
    """Stream snapshots whose done records fill the replay buffer."""
    m_t = rand_mt(rng)
    for i in range(steps):
        done = ([{"id": start_seq + i - 1,
                  "times": (1.0 + rng.random(3)).tolist()}]
                if i or start_seq else [])
        r = client.snapshot(mk_snap(client.tenant, start_seq + i,
                                    rand_mh(rng), m_t,
                                    job_id=start_seq + i, done=done))
        assert r["ok"]


def test_shadow_eval_blocks_bad_candidate_then_promotes_and_rolls_back(
        tmp_path, monkeypatch):
    """The acceptance criterion: a corrupted candidate is never
    promoted (champion keeps serving, CURRENT unchanged); a good one is;
    rollback restores the previous version bitwise."""
    cfg = ServiceConfig(profile=profile(), ckpt_dir=str(tmp_path),
                        min_train_pairs=6, eval_holdback=3,
                        train_epochs=2, train_lr=1e-4)
    svc = PredictionService(cfg)
    c = LocalClient(svc, "t0")
    assert c.hello(profile())["ok"]
    rng = np.random.default_rng(13)
    _drive_pairs(svc, c, rng, steps=10)
    assert len(svc.buffer) >= cfg.min_train_pairs
    v0_leaves = [np.asarray(jax.device_get(x))
                 for x in jax.tree_util.tree_leaves(svc.params)]

    real_fit = svc_retrain.fit_candidate
    corrupt = {"on": True}

    def maybe_corrupt(champion, tx, ty, epochs=1, lr=1e-4):
        params, losses = real_fit(champion, tx, ty, epochs=1, lr=lr)
        if corrupt["on"]:
            params = jax.tree_util.tree_map(
                lambda a: a * np.float32("nan"), params)
        return params, losses

    monkeypatch.setattr(svc_retrain, "fit_candidate", maybe_corrupt)
    rej = c.retrain()
    assert rej["ok"] and rej["promoted"] is False
    assert not np.isfinite(rej["candidate_loss"])
    assert svc.model_version == 0 and svc.store.current() == 0
    assert svc.stats()["candidates_rejected"] == 1
    # champion params untouched by the rejected candidate
    for a, b in zip(jax.tree_util.tree_leaves(svc.params), v0_leaves):
        np.testing.assert_array_equal(np.asarray(a), b)

    corrupt["on"] = False
    ok = c.retrain()
    assert ok["promoted"] is True and ok["version"] == 1
    assert svc.store.current() == 1 and svc.model_version == 1
    assert np.isfinite(ok["candidate_loss"])
    changed = any(
        not np.array_equal(np.asarray(jax.device_get(a)), b)
        for a, b in zip(jax.tree_util.tree_leaves(svc.params),
                        v0_leaves))
    assert changed, "promotion did not swap the serving params"
    # every tenant predictor serves the promoted pytree
    assert svc.tenants["t0"].predictor.params is svc.params

    rb = c.rollback()
    assert rb["ok"] and rb["version"] == 0
    assert svc.store.current() == 0 and svc.model_version == 0
    for a, b in zip(jax.tree_util.tree_leaves(svc.params), v0_leaves):
        np.testing.assert_array_equal(np.asarray(jax.device_get(a)), b)


def test_degraded_mode_when_model_fails_to_load(tmp_path):
    """CURRENT pointing at a version that cannot load -> the service
    still answers, from the jitted Pareto tail over the tenant's own
    completed durations, flagged degraded."""
    with open(os.path.join(str(tmp_path), "CURRENT"), "w") as f:
        json.dump({"current": 7, "history": []}, f)
    svc = PredictionService(ServiceConfig(profile=profile(),
                                          ckpt_dir=str(tmp_path)))
    assert svc.degraded
    c = LocalClient(svc, "t0")
    assert c.hello(profile())["ok"]
    rng = np.random.default_rng(14)
    m_t = rand_mt(rng)
    r = c.snapshot(mk_snap(
        "t0", 0, rand_mh(rng), m_t,
        done=[{"id": 99, "times": [1.1, 1.4, 2.0, 5.0, 1.2, 1.3]}]))
    assert r["ok"] and r["degraded"] is True
    e_s = r["jobs"][0]["e_s"]
    assert np.isfinite(e_s) and 0.0 <= e_s <= 3.0
    assert svc.stats()["degraded_answers"] == 1


# --------------------- wall-clock retrain scheduling ---------------------

def test_retrain_scheduler_fires_per_period_and_coalesces():
    """The monotonic scheduler fires exactly once per elapsed period,
    re-arms from *now* (missed periods coalesce into one firing, never a
    catch-up burst), and 0 disables it."""
    from repro.service.daemon import RetrainScheduler
    t = {"now": 100.0}
    s = RetrainScheduler(10.0, clock=lambda: t["now"])
    assert s.enabled
    assert not s.due()                 # nothing elapsed
    t["now"] = 109.9
    assert not s.due()
    t["now"] = 110.0
    assert s.due()                     # one period elapsed
    assert not s.due()                 # latched: fired once, re-armed
    t["now"] = 145.0                   # 3.5 periods swallowed
    assert s.due()                     # single coalesced firing
    assert not s.due()
    t["now"] = 154.9
    assert not s.due()                 # re-armed from 145, not from 110
    t["now"] = 155.0
    assert s.due()

    off = RetrainScheduler(0.0, clock=lambda: t["now"])
    assert not off.enabled
    assert not off.due()


def test_wall_clock_retrain_trigger_end_to_end(tmp_path):
    """A daemon with ``retrain_interval_s`` set (and the snapshot-count
    trigger OFF) retrains and promotes when the injected monotonic clock
    crosses the period — and not before."""
    import time as _time
    t = {"now": 0.0}
    cfg = ServiceConfig(profile=profile(), ckpt_dir=str(tmp_path),
                        min_train_pairs=6, eval_holdback=3,
                        train_epochs=2, train_lr=1e-4,
                        retrain_every=0, retrain_interval_s=30.0)
    with ServiceDaemon(cfg, port=None,
                       retrain_clock=lambda: t["now"]) as d:
        svc = d.service
        assert d.retrain_scheduler.enabled
        c = LocalClient(svc, "t0")
        assert c.hello(profile())["ok"]
        rng = np.random.default_rng(21)
        _drive_pairs(svc, c, rng, steps=10)
        assert len(svc.buffer) >= cfg.min_train_pairs
        # clock has not advanced: the retrainer thread polls but must
        # not fire (snapshot trigger is off and the period is untouched)
        _time.sleep(0.3)
        assert svc.stats()["retrains"] == 0 and svc.model_version == 0
        t["now"] = 31.0                # cross the period on the fake clock
        deadline = _time.monotonic() + 10.0
        while svc.model_version == 0 and _time.monotonic() < deadline:
            _time.sleep(0.05)
        assert svc.stats()["retrains"] >= 1
        assert svc.model_version == 1, "wall-clock trigger never promoted"


def test_retrain_failure_counted_and_retrainer_survives(tmp_path,
                                                        monkeypatch):
    """A retrain that raises must not kill the retrainer thread or
    vanish silently: stats() grows ``retrain_failures`` and
    ``last_retrain_error``, the due-flag clears (no hot spin on a
    poisoned buffer), and the *next* period still fires."""
    import time as _time
    t = {"now": 0.0}
    cfg = ServiceConfig(profile=profile(), ckpt_dir=str(tmp_path),
                        min_train_pairs=6, eval_holdback=3,
                        train_epochs=2, train_lr=1e-4,
                        retrain_every=0, retrain_interval_s=30.0)
    with ServiceDaemon(cfg, port=None,
                       retrain_clock=lambda: t["now"]) as d:
        svc = d.service
        assert svc.stats()["retrain_failures"] == 0
        assert svc.stats()["last_retrain_error"] is None

        def boom():
            raise RuntimeError("forced retrain failure")
        monkeypatch.setattr(svc, "retrain_now", boom)
        t["now"] = 31.0                # cross the first period
        deadline = _time.monotonic() + 10.0
        while (svc.stats()["retrain_failures"] == 0
               and _time.monotonic() < deadline):
            _time.sleep(0.05)
        st = svc.stats()
        assert st["retrain_failures"] >= 1
        assert "forced retrain failure" in st["last_retrain_error"]
        assert not svc._retrain_due    # cleared: no hot retry spin
        assert d._retrainer.is_alive(), "retrainer thread died"
        seen = st["retrain_failures"]
        t["now"] = 62.0                # next period: thread still serving
        deadline = _time.monotonic() + 10.0
        while (svc.stats()["retrain_failures"] <= seen
               and _time.monotonic() < deadline):
            _time.sleep(0.05)
        assert svc.stats()["retrain_failures"] > seen
