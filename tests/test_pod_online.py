"""Pod-substrate satellites: online Encoder-LSTM training for the pod
policy (predictions sharpen after updates) and the pod runtime driving
the prediction service as a client."""
import math

import numpy as np

from repro.core import encoder_lstm as net
from repro.core.predictor import StragglerPredictor
from repro.distributed.straggler_runtime import (OnlineStartPodPolicy,
                                                 RuntimeConfig,
                                                 ServiceBackedPodPolicy,
                                                 StragglerRuntime)
from repro.policy import registry


def drive(policy, steps=25, n=6, slow_host=4, seed=3):
    cfg = RuntimeConfig(n_hosts=n, horizon=5)
    rt = StragglerRuntime(cfg, policy=policy)
    rng = np.random.default_rng(seed)
    for _ in range(steps):
        st = 1.0 + 0.1 * rng.random(n)
        st[slow_host] *= 2.5
        rt.observe_step(st)
        rt.decide()
    return rt


def test_pod_policies_registered():
    assert "start-pod-online" in registry.names("pod")
    assert "start-pod-service" in registry.names("pod")


def test_online_pod_predictions_sharpen():
    """The ROADMAP sub-item's test: after online fit() updates on
    completed windows, the network's (alpha, beta) head fits the pod's
    observed window statistics better than the untrained net — pod
    predictions sharpen."""
    pol = OnlineStartPodPolicy(epochs_per_update=25, lr=1e-3,
                               min_windows=1, seed=0)
    rt = drive(pol, steps=30)
    assert pol.trained_pairs >= 5          # 30 steps / horizon 5
    xs = np.stack(pol._xs, axis=1)
    ys = np.array(pol._ys, np.float32)
    fresh = StragglerPredictor(
        n_hosts=rt.cfg.n_hosts, max_tasks=rt.cfg.n_hosts, k=rt.cfg.k,
        horizon=rt.cfg.horizon, seed=pol.seed, beta_scale=1.0)
    loss_untrained = float(net.mse_loss(fresh.params, xs, ys))
    loss_trained = float(net.mse_loss(pol.predictor.params, xs, ys))
    assert math.isfinite(loss_trained)
    assert loss_trained < loss_untrained, \
        (loss_trained, loss_untrained)


def test_online_pod_falls_back_to_mle_before_training():
    """Before ``min_windows`` pairs exist the seam must answer with the
    base policy's MLE tail, not a random network."""
    pol = OnlineStartPodPolicy(min_windows=10 ** 6)
    rt = drive(pol, steps=12)
    view = rt.snapshot()
    base = super(OnlineStartPodPolicy, pol)._expected_stragglers(view)
    assert pol._expected_stragglers(view) == base


def test_online_pod_e_s_finite_and_bounded():
    pol = OnlineStartPodPolicy(epochs_per_update=5, min_windows=1)
    rt = drive(pol, steps=15)
    e_s = pol._expected_stragglers(rt.snapshot())
    assert math.isfinite(e_s) and 0.0 <= e_s <= rt.cfg.n_hosts


def test_service_backed_pod_policy_round_trips():
    """The pod substrate as a service tenant: snapshots stream to an
    in-process daemon, responses parse back into runtime actions, and
    completed windows feed the service's replay buffer."""
    pol = ServiceBackedPodPolicy()
    rt = drive(pol, steps=16)
    resp = pol.last_response
    assert resp is not None and resp["ok"]
    assert resp["degraded"] is False
    job = resp["jobs"][0]
    assert math.isfinite(job["e_s"])
    assert len(job["scores"]) == rt.cfg.n_hosts
    svc = pol.client.service
    # 16 steps / horizon 5 -> 3 completed windows became training pairs
    assert len(svc.buffer) == 3
    assert svc.stats()["snapshots"] == 16


def test_service_backed_pod_actions_translate():
    """Wire actions fire the runtime's backup-shard translation when the
    service's per-task trigger trips (forced by a tiny hysteresis and a
    pre-trained-enough streak on a persistent straggler)."""
    pol = ServiceBackedPodPolicy(hysteresis=1, cooldown=1)
    rt = drive(pol, steps=20, slow_host=2)
    # actions (if any fired on the untrained model) were translated,
    # never crashed the runtime, and eviction bookkeeping stayed sound
    assert rt.t == 20
    assert set(rt.action_counts) == {"backup_shard", "evict"}
