"""Tests for the Encoder-LSTM network (paper §3.2) and its training loop."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import encoder_lstm as net
from repro.core import features, pareto
from repro.core.predictor import StragglerPredictor

jax.config.update("jax_platform_name", "cpu")


def test_architecture_shapes():
    """Paper: encoder input->128->128->32 softplus; 2-layer LSTM(32); FC(2)."""
    p = net.init_params(jax.random.PRNGKey(0), input_dim=55)
    assert p["enc"][0]["w"].shape == (55, 128)
    assert p["enc"][1]["w"].shape == (128, 128)
    assert p["enc"][2]["w"].shape == (128, 128)
    assert p["enc"][3]["w"].shape == (128, 32)
    assert len(p["lstm"]) == 2
    assert p["lstm"][0]["wx"].shape == (32, 128)  # 4 gates * 32
    assert p["lstm"][1]["wx"].shape == (32, 128)
    assert p["head"]["w"].shape == (32, 2)


def test_output_constraints():
    """alpha >= 1 (mean defined), beta > 0, for arbitrary inputs."""
    p = net.init_params(jax.random.PRNGKey(1), input_dim=20)
    xs = jax.random.normal(jax.random.PRNGKey(2), (5, 7, 20)) * 10.0
    ab = net.predict_sequence(p, xs)
    assert ab.shape == (7, 2)
    assert bool((ab[:, 0] >= 1.0).all())
    assert bool((ab[:, 1] > 0.0).all())
    assert bool(jnp.isfinite(ab).all())


def test_ema_smooth():
    seq = jnp.array([[1.0], [2.0], [3.0]])
    out = net.ema_smooth(seq, w=0.8)
    np.testing.assert_allclose(out[0], [1.0])
    np.testing.assert_allclose(out[1], [0.8 * 2 + 0.2 * 1.0])
    np.testing.assert_allclose(out[2], [0.8 * 3 + 0.2 * (0.8 * 2 + 0.2)])


def test_lstm_cell_matches_manual():
    layer = net._lstm_init(jax.random.PRNGKey(3), 4, 8)
    h = jnp.zeros((8,))
    c = jnp.zeros((8,))
    x = jnp.ones((4,))
    h2, c2 = net.lstm_cell_apply(layer, h, c, x)
    z = x @ layer["wx"] + layer["b"]
    i, f, g, o = jnp.split(z, 4)
    c_ref = jax.nn.sigmoid(i) * jnp.tanh(g)
    h_ref = jax.nn.sigmoid(o) * jnp.tanh(c_ref)
    np.testing.assert_allclose(h2, h_ref, rtol=1e-6)
    np.testing.assert_allclose(c2, c_ref, rtol=1e-6)


def test_training_reduces_loss():
    """Network learns to regress (alpha, beta) from synthetic features."""
    key = jax.random.PRNGKey(0)
    dim = 16
    n = 128
    k1, k2, k3 = jax.random.split(key, 3)
    # targets correlated with a linear readout of inputs
    base = jax.random.uniform(k1, (n, dim))
    targets = jnp.stack([1.5 + base[:, 0] * 2.0, 0.5 + base[:, 1]], -1)
    xs = jnp.broadcast_to(base[None], (5, n, dim))
    params = net.init_params(k2, dim)
    opt = net.adam_init(params)
    loss0 = float(net.mse_loss(params, xs, targets))
    for _ in range(800):
        params, opt, loss = net.train_step(params, opt, xs, targets, lr=3e-3)
    assert float(loss) < loss0 * 0.5


def test_predictor_end_to_end():
    """StragglerPredictor: features -> (alpha, beta, K, E_S) batched."""
    n_hosts, max_tasks, jobs, horizon = 4, 6, 3, 5
    pred = StragglerPredictor(n_hosts=n_hosts, max_tasks=max_tasks,
                              horizon=horizon, seed=0)
    m_h = features.host_matrix(
        util=jnp.full((n_hosts, 4), 0.5), cap=jnp.ones((n_hosts, 4)),
        cost=jnp.ones(n_hosts), power_max=jnp.ones(n_hosts),
        n_tasks=jnp.arange(n_hosts))
    m_h_seq = jnp.broadcast_to(m_h[None], (horizon, *m_h.shape))
    m_t = jnp.zeros((horizon, jobs, max_tasks, features.TASK_FEATURES))
    q = jnp.array([6.0, 3.0, 2.0])
    out = pred.predict(m_h_seq, m_t, q)
    assert out.e_s.shape == (jobs,)
    assert bool((out.alpha >= 1.0).all())
    assert bool((out.e_s >= 0.0).all())
    assert bool((out.e_s <= q).all())


def test_predictor_fit_targets_match_mle():
    times = pareto.sample_pareto(jax.random.PRNGKey(9), 2.0, 1.0, (4, 32))
    pred = StragglerPredictor(n_hosts=2, max_tasks=4)
    t = pred.make_targets(times)
    a, b = pareto.fit_pareto(times)
    np.testing.assert_allclose(t[:, 0], a)
    np.testing.assert_allclose(t[:, 1], b)


def test_feature_matrices():
    m_h = features.host_matrix(
        util=jnp.full((3, 4), 0.25), cap=jnp.ones((3, 4)) * 8,
        cost=jnp.array([1.0, 2.0, 4.0]),
        power_max=jnp.array([100., 200., 50.]),
        n_tasks=jnp.array([0, 5, 10]))
    assert m_h.shape == (3, features.HOST_FEATURES)
    assert float(m_h[:, 4:8].max()) == pytest.approx(1.0)  # caps normalized
    m_t = features.task_matrix(req=jnp.ones((2, 4)) * 0.5,
                               prev_host=jnp.array([0, -1]),
                               n_hosts=3, max_tasks=5)
    assert m_t.shape == (5, features.TASK_FEATURES)
    np.testing.assert_allclose(m_t[2:], 0.0)  # padding
    flat = features.flatten_inputs(m_h, m_t)
    assert flat.shape == (features.input_dim(3, 5),)
