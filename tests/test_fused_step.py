"""Fused per-interval decision step + sweep scheduling tests.

Pins the fused path's contracts under the tiered determinism model:

  * the fused device program (ring-buffer M_H history + on-device feature
    assembly + hoisted-encoder Encoder-LSTM + in-program Pareto tail in
    one donated-buffer jit) is **Tier-1**: it agrees with the unfused
    Tier-0 reference within the documented tolerance bound
    (tests/tolerance.py) at every batch shape, and is itself fully
    deterministic — a full planetlab x start cell reproduces bitwise
    across runs and across pickling;
  * a warm interval performs **zero XLA retraces and zero host->device
    transfers** beyond its single staged upload (that guarantee is hard,
    not toleranced);
  * the sweep's parent-pretrain broadcast and the parent-participating
    scheduler preserve serial == parallel bitwise while removing the
    per-worker duplicate pretraining.
"""
import dataclasses
import pickle

import jax
import numpy as np
import pytest

from repro.core import encoder_lstm as net
from repro.core import features
from repro.core.predictor import StragglerPredictor, fused_compile_count
from repro.core.start import STARTController
from repro.sim import sweep
from repro.sim.engine import Simulation
from repro.sim.sweep import SweepSpec, deterministic_summary

from tolerance import assert_tier1

jax.config.update("jax_platform_name", "cpu")


def _cell_spec(**kw):
    base = dict(techniques=("start",), seeds=(0,), scenarios=("planetlab",),
                n_hosts=16, n_intervals=30, arrival_rate=0.8,
                max_workers=1, pretrain_epochs=2)
    base.update(kw)
    return SweepSpec(**base)


@pytest.fixture(scope="module")
def trained_start_bytes():
    spec = _cell_spec()
    cfg = spec.cell_config("planetlab", 0)
    return pickle.dumps(
        sweep.make_technique("start", cfg, pretrain_epochs=2)), cfg


# ---------------------- fused == unfused within Tier-1 ----------------------

def test_fused_cell_is_deterministic_across_runs(trained_start_bytes):
    """Tier-1 relaxes cross-path bitwise equality, NOT determinism: the
    whole planetlab x start cell must reproduce bitwise when the fused
    pipeline runs twice from the same pretrained bytes."""
    tech_bytes, cfg = trained_start_bytes
    a = pickle.loads(tech_bytes)
    assert a._controller.use_fused_step   # the default
    s_a = Simulation(cfg, technique=a).run()
    b = pickle.loads(tech_bytes)
    s_b = Simulation(cfg, technique=b).run()

    assert deterministic_summary(s_a) == deterministic_summary(s_b)
    # and the fused path actually ran: one staged upload per predicted
    # interval, nothing else
    pred = a._controller.predictor
    assert pred.h2d_stages > 0
    # the unfused route still works end to end (service degraded mode,
    # cold second-predicts) — no equality demanded at cell granularity:
    # per-interval ulp drift compounds through placement decisions
    c = pickle.loads(tech_bytes)
    c.use_fused_step = False      # forwards to the controller
    assert not c._controller.use_fused_step
    s_c = Simulation(cfg, technique=c).run()
    assert deterministic_summary(s_c)["tasks_total"] > 0


def test_fused_predict_interval_matches_predict_features():
    """Direct predictor-level equivalence across batch sizes within the
    Tier-1 bound, including the idle-interval catch-up roll (observe
    without predict).  The fused program restructures the emission
    (hoisted split encoder, unrolled scan, in-program Pareto tail, exact
    shapes for counts 5 and 9), so agreement is toleranced, not bitwise."""
    rng = np.random.default_rng(0)
    n_hosts, max_tasks = 6, 5
    pred_f = StragglerPredictor(n_hosts=n_hosts, max_tasks=max_tasks)
    pred_u = StragglerPredictor(n_hosts=n_hosts, max_tasks=max_tasks)
    hist = []
    for step, n in enumerate([1, 3, 0, 0, 2, 8, 5, 0, 9]):
        row = rng.uniform(0, 1, (n_hosts, features.HOST_FEATURES)) \
            .astype(np.float32)
        hist.append(row)
        pred_f.push_host_row(row)
        if n == 0:
            continue  # idle interval: history advances, no prediction
        m_t = rng.uniform(0, 1, (n, max_tasks, features.TASK_FEATURES)) \
            .astype(np.float32)
        q = rng.integers(1, max_tasks, n).astype(np.float32)
        # unfused reference uses the deque semantics (last horizon rows,
        # left-padded with the oldest)
        seq = list(hist[-pred_u.horizon:])
        while len(seq) < pred_u.horizon:
            seq.insert(0, seq[0])
        want = np.asarray(
            pred_u.predict_features(np.stack(seq), m_t, q).e_s)
        got = pred_f.predict_interval(m_t, q)
        assert_tier1(got, want, context=f"step {step}")


def test_fused_predictor_survives_pickling_mid_run():
    """The device ring is a cache: pickling drops it and the next predict
    rebuilds from the staged host rows with identical results."""
    rng = np.random.default_rng(1)
    n_hosts, max_tasks = 4, 4
    ctrl = STARTController(n_hosts=n_hosts, max_tasks=max_tasks)
    assert ctrl.use_fused_step
    for _ in range(3):
        ctrl.observe_hosts(rng.uniform(
            0, 1, (n_hosts, features.HOST_FEATURES)).astype(np.float32))
        m_t = rng.uniform(0, 1, (2, max_tasks, features.TASK_FEATURES))
        ctrl.predictor.predict_interval(
            np.asarray(m_t, np.float32), np.full(2, 4.0, np.float32))
    clone = pickle.loads(pickle.dumps(ctrl))
    row = rng.uniform(0, 1, (n_hosts, features.HOST_FEATURES)) \
        .astype(np.float32)
    m_t = np.asarray(rng.uniform(
        0, 1, (3, max_tasks, features.TASK_FEATURES)), np.float32)
    q = np.full(3, 4.0, np.float32)
    ctrl.observe_hosts(row)
    clone.observe_hosts(row)
    np.testing.assert_array_equal(
        clone.predictor.predict_interval(m_t, q),
        ctrl.predictor.predict_interval(m_t, q))


# ------------------- zero retraces / zero transfers warm -------------------

def test_warm_intervals_zero_retraces_and_zero_transfers(
        trained_start_bytes, monkeypatch):
    """After a cell has warmed every bucket, further cells must (a) never
    recompile a prediction program and (b) perform no host->device
    transfer per interval beyond the fused step's single staged upload —
    pinned by running a whole warm cell under
    ``jax.transfer_guard_host_to_device('disallow')`` with only the
    predictor's ``_stage`` uploads exempted."""
    tech_bytes, cfg = trained_start_bytes
    warm = pickle.loads(tech_bytes)
    Simulation(cfg, technique=warm).run()          # warm all buckets

    orig_stage = StragglerPredictor._stage

    def sanctioned_stage(self, arr):
        with jax.transfer_guard_host_to_device("allow"):
            return orig_stage(self, arr)

    monkeypatch.setattr(StragglerPredictor, "_stage", sanctioned_stage)
    tech = pickle.loads(tech_bytes)
    compiles_before = (net.predict_sequence._cache_size()
                       + fused_compile_count())
    sim = Simulation(cfg, technique=tech)
    with jax.transfer_guard_host_to_device("disallow"):
        sim.run()
    grew = (net.predict_sequence._cache_size() + fused_compile_count()
            - compiles_before)
    assert grew == 0, "warm cell retraced a prediction program"
    pred = tech._controller.predictor
    # one staged upload per predicted interval (ring rebuilds after
    # unpickling add their one-time upload through the same funnel)
    assert pred.h2d_stages <= cfg.n_intervals + 1
    assert pred.h2d_stages > 0


# --------------------- pallas-cell training route exact ---------------------

def test_lstm_cell_gradients_exact_match_reference():
    """The fused Pallas cell is differentiable (custom VJP: kernel
    forward, rematerialized-reference backward) and under jit — the only
    way training ever runs — its gradients are bitwise-identical to
    differentiating the reference cell.  (Eager per-op dispatch compiles
    slightly different transpose sequences and lands within an ulp; the
    jitted whole-graph comparison is the contract.)"""
    from repro.kernels.lstm_cell import lstm_cell, lstm_cell_ref
    rng = np.random.default_rng(3)
    layer = net._lstm_init(jax.random.PRNGKey(3), 32, 32)
    x, h, c = (np.asarray(rng.normal(size=(8, 32)), np.float32)
               for _ in range(3))

    def loss(cell_fn, layer):
        h2, c2 = cell_fn(x, h, c, layer["wx"], layer["wh"], layer["b"])
        return (h2 * h2 + c2).sum()

    g_ref = jax.jit(jax.grad(lambda p: loss(lstm_cell_ref, p)))(layer)
    g_pal = jax.jit(jax.grad(lambda p: loss(lstm_cell, p)))(layer)
    for k in g_ref:
        np.testing.assert_array_equal(np.asarray(g_ref[k]),
                                      np.asarray(g_pal[k]), err_msg=k)


def test_fit_through_pallas_cell_reproduces_reference_training():
    """StragglerPredictor.fit(use_pallas_cell=True) routes every train
    step through the fused cell.  The isolated cell gradient is bitwise
    exact (test above); inside the full train-step graph XLA may fuse
    the surrounding network differently per path, so whole-training
    params are pinned to ulp-level agreement rather than bit equality."""
    rng = np.random.default_rng(0)
    ref = StragglerPredictor(n_hosts=2, max_tasks=3)
    pal = StragglerPredictor(n_hosts=2, max_tasks=3)
    dim = ref.input_dim
    xs = rng.normal(size=(5, 8, dim)).astype(np.float32)
    ys = np.abs(rng.normal(size=(8, 2))).astype(np.float32) + 1.0
    l_ref = ref.fit(xs, ys, epochs=2, lr=1e-3)
    l_pal = pal.fit(xs, ys, epochs=2, lr=1e-3, use_pallas_cell=True)
    np.testing.assert_allclose(l_ref, l_pal, rtol=1e-6)
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=2e-5, atol=1e-8),
        ref.params, pal.params)


# ----------------------- sweep scheduling / broadcast -----------------------

def test_pretrain_payload_broadcast_matches_local_training():
    """A technique built from the parent's broadcast bytes must equal one
    the worker would have trained locally (same fixed seeds)."""
    spec = _cell_spec()
    cfg = spec.cell_config("planetlab", 0)
    payload = sweep.pretrain_payload(spec, "planetlab", "start")
    assert payload is not None
    via_payload = sweep.make_technique("start", cfg, pretrain_epochs=2,
                                       pretrained=payload)
    local = sweep.make_technique("start", cfg, pretrain_epochs=2)
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b)),
        via_payload._controller.predictor.params,
        local._controller.predictor.params)
    # techniques that do not pretrain have no payload
    assert sweep.pretrain_payload(spec, "planetlab", "none") is None


def test_schedule_units_group_by_technique_and_cover_grid():
    spec = SweepSpec(techniques=("none", "sgc"), seeds=(0, 1, 2),
                     scenarios=("planetlab", "heavy-tail"),
                     n_hosts=8, n_intervals=10)
    units = sweep._schedule_units(spec, n_workers=2)
    flat = [c for u in units for c in u]
    assert sorted(flat) == sorted(spec.cells())      # exact cover
    for u in units:  # affinity: one (technique, scenario) per unit
        assert len({(c[1], c[0]) for c in u}) == 1


def test_parallel_run_with_pretrained_technique_bitwise_equals_serial():
    spec = _cell_spec(seeds=(0, 1), scenarios=("planetlab", "heavy-tail"),
                      n_hosts=8, n_intervals=12, max_workers=2)
    serial = sweep.run(dataclasses.replace(spec, max_workers=1))
    parallel = sweep.run(spec)
    assert [(c.scenario, c.technique, c.seed) for c in parallel.cells] \
        == spec.cells()
    for a, b in zip(serial.cells, parallel.cells):
        assert deterministic_summary(a.summary) \
            == deterministic_summary(b.summary)
    sweep.shutdown_pool()


def test_warm_pool_reports_spawn_and_pool_is_ready():
    sweep.shutdown_pool()
    spawn_s = sweep.warm_pool(2)
    assert spawn_s > 0
    assert all(f.done() for f in sweep._POOL_READY)
    # warming an already-warm pool is ~free
    assert sweep.warm_pool(2) < spawn_s
    sweep.shutdown_pool()
