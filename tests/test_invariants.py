"""Property-based simulator invariants over random SweepSpec cells.

The 2-scenario golden fixture pins *determinism*; this harness pins
*correctness* across the whole spec space: for randomly drawn
(scenario, technique, seed, load) cells the engine must conserve tasks
(every submitted original completes at most once, copy groups are
first-result-wins), keep the CSR job index consistent (``jobs.active()``
and the done flags partition the JobTable), produce sane QoS numbers
(finite, non-negative, SLA rate in [0, 1]), and execute a parallel sweep
bitwise-equal to a serial one.

CI runs the real ``hypothesis`` (requirements-dev.txt); offline the
conftest stub degrades each property to fixed boundary/midpoint
examples, so the suite never needs the dependency to collect.

The nightly lane additionally runs the ``slow``-marked full-field grid
at Table-4-like scale (see ``benchmarks/nightly_grid.py``).
"""
import dataclasses

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro import policy
from repro.sim import Simulation, scenarios, sweep
from repro.sim.engine import CANCELLED, DONE, PENDING, RUNNING

import repro.sim.techniques  # noqa: F401  (populates the registry)

#: techniques cheap enough to instantiate untrained inside a property
#: (the pretrained ones are covered by the golden fixture + their own
#: tests; a hypothesis example must stay sub-second)
CHEAP_TECHNIQUES = ("none", "sgc", "dolly", "grass", "nearestfit", "rpps",
                    "single-fork", "fork-relaunch", "redundancy-fixed",
                    "redundancy-adaptive")
ALL_SCENARIOS = tuple(scenarios.names())


def _run_cell(scenario: str, technique: str, seed: int,
              arrival_rate: float, n_intervals: int = 14,
              n_hosts: int = 8):
    cfg = scenarios.make_config(scenario, seed=seed, n_hosts=n_hosts,
                                n_intervals=n_intervals,
                                arrival_rate=arrival_rate)
    sim = Simulation(cfg, technique=policy.make(technique))
    summary = sim.run()
    return sim, summary


def assert_engine_invariants(sim: Simulation, summary: dict) -> None:
    """The properties every finished simulation must satisfy."""
    tt, jobs = sim.tasks, sim.jobs
    state = tt.view("state")
    is_copy = tt.view("is_copy")
    orig_mask = ~is_copy

    # -- task conservation: originals are never cancelled, and every task
    # is in exactly one lifecycle state
    assert set(np.unique(state[orig_mask])) <= {PENDING, RUNNING, DONE}
    assert set(np.unique(state)) <= {PENDING, RUNNING, DONE, CANCELLED}

    # -- copy groups are first-result-wins: at most one copy finishes,
    # winners share the original's finish stamp, a finished original
    # leaves no sibling running
    copies = np.nonzero(is_copy)[0]
    groups: dict = {}
    for c in copies:
        groups.setdefault(int(tt.orig[c]), []).append(int(c))
    for orig, group in groups.items():
        done_copies = [c for c in group if state[c] == DONE]
        assert len(done_copies) <= 1, (orig, group)
        if state[orig] == DONE:
            for c in done_copies:
                assert tt.finish_s[c] == tt.finish_s[orig]
            assert all(state[c] in (DONE, CANCELLED) for c in group)

    # -- CSR job index: open counts match the task table; active() and
    # the done flag partition the JobTable
    for job in range(jobs.n):
        tids = jobs.task_ids(job)
        open_n = int(np.isin(tt.state[tids], [PENDING, RUNNING]).sum())
        assert jobs.open_count[job] == open_n, job
        assert jobs.done[job] == (open_n == 0), job
    active = set(int(j) for j in jobs.active())
    done_jobs = set(int(j) for j in np.nonzero(jobs.view("done"))[0])
    assert active.isdisjoint(done_jobs)
    assert active | done_jobs == set(range(jobs.n))

    # -- every accounted (ground-truth) job is fully terminal, exactly
    # once per job
    accounted = [rec["job"] for rec in sim.completed_jobs]
    assert len(accounted) == len(set(accounted))
    assert set(accounted) == done_jobs
    for rec in sim.completed_jobs:
        tids = jobs.task_ids(rec["job"])
        assert (tt.state[tids] == DONE).all()
        assert (np.asarray(rec["times"]) > 0).all()

    # -- QoS sanity
    for k in sweep.QOS_KEYS:
        assert np.isfinite(summary[k]), k
    assert summary["avg_execution_time_s"] >= 0.0
    assert summary["energy_kwh"] >= 0.0
    assert 0.0 <= summary["sla_violation_rate"] <= 1.0
    assert 0 <= summary["tasks_done"] <= summary["tasks_total"]


@settings(max_examples=20, deadline=None)
@given(technique=st.sampled_from(CHEAP_TECHNIQUES),
       scenario=st.sampled_from(ALL_SCENARIOS),
       seed=st.integers(0, 2 ** 16),
       arrival_rate=st.floats(0.2, 1.6))
def test_engine_invariants_hold_across_the_spec_space(
        technique, scenario, seed, arrival_rate):
    sim, summary = _run_cell(scenario, technique, seed, arrival_rate)
    assert_engine_invariants(sim, summary)


@settings(max_examples=5, deadline=None)
@given(technique=st.sampled_from(("none", "sgc", "redundancy-adaptive")),
       scenario=st.sampled_from(ALL_SCENARIOS),
       seed=st.integers(0, 999))
def test_serial_equals_parallel_for_random_specs(technique, scenario,
                                                 seed):
    """Parallel execution over the persistent spawned pool is bitwise
    identical to in-process serial execution for arbitrary cells (two
    seeds so the parallel path doesn't short-circuit to serial)."""
    spec = sweep.SweepSpec(techniques=("none", technique),
                           seeds=(seed, seed + 1),
                           scenarios=(scenario,), n_hosts=8,
                           n_intervals=12, arrival_rate=0.8,
                           max_workers=1)
    serial = sweep.run(spec)
    parallel = sweep.run(dataclasses.replace(spec, max_workers=2))
    assert parallel.n_workers == 2
    for a, b in zip(serial.cells, parallel.cells):
        assert (a.scenario, a.technique, a.seed) \
            == (b.scenario, b.technique, b.seed)
        assert sweep.deterministic_summary(a.summary) \
            == sweep.deterministic_summary(b.summary)


# --------------------- nightly full-field grid (slow) -----------------------

@pytest.mark.slow
def test_full_technique_field_grid_slow():
    """Every registered sim technique x every scenario at a moderate
    grid size, each cell checked against the engine invariants — the
    gating counterpart of the nightly Table-4-scale sweep."""
    from repro.sim import techniques as T
    # arrival 0.8 x 40 intervals keeps the overload scenario completing
    # enough warmup jobs for START's offline pretraining at this size
    spec = sweep.SweepSpec(techniques=T.FIELD,
                           seeds=(0,), scenarios=ALL_SCENARIOS,
                           n_hosts=16, n_intervals=40, arrival_rate=0.8,
                           pretrain_epochs=2, igru_epochs=10,
                           max_workers=1)
    for sc, tech, seed in spec.cells():
        cfg = spec.cell_config(sc, seed)
        instance = sweep.make_technique(
            tech, cfg, pretrain_epochs=spec.pretrain_epochs,
            igru_epochs=spec.igru_epochs)
        sim = Simulation(cfg, technique=instance)
        assert_engine_invariants(sim, sim.run())
