"""Serving engine tests: continuous batching, slot reuse, START
replica re-dispatch."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models.lm import Model
from repro.serve.engine import Engine, EngineConfig, ReplicaDispatcher, \
    Request
from repro.serve.kv_cache import SlotManager

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def served():
    cfg = dataclasses.replace(get_reduced("demo-100m"),
                              param_dtype="float32")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def test_engine_completes_requests(served):
    cfg, model, params = served
    eng = Engine(model, params, EngineConfig(n_slots=2, max_len=64))
    rng = np.random.default_rng(0)
    for i in range(5):
        eng.submit(Request(req_id=i,
                           tokens=rng.integers(0, cfg.vocab, 6),
                           max_new=8))
    done = eng.run()
    assert len(done) == 5
    for r in done:
        assert len(r.out) >= 8
        assert all(0 <= t < cfg.padded_vocab for t in r.out)


def test_engine_continuous_batching_reuses_slots(served):
    cfg, model, params = served
    eng = Engine(model, params, EngineConfig(n_slots=1, max_len=64))
    rng = np.random.default_rng(1)
    for i in range(3):
        eng.submit(Request(req_id=i,
                           tokens=rng.integers(0, cfg.vocab, 4),
                           max_new=4))
    done = eng.run()
    assert len(done) == 3  # 3 requests through 1 slot


def test_engine_greedy_matches_manual_decode(served):
    """Engine output == hand-rolled prefill+decode loop (greedy)."""
    import jax.numpy as jnp
    from repro.serve.kv_cache import pad_to_length
    cfg, model, params = served
    prompt = np.array([5, 9, 2, 7])
    eng = Engine(model, params, EngineConfig(n_slots=1, max_len=32))
    eng.submit(Request(req_id=0, tokens=prompt, max_new=5))
    out = eng.run()[0].out

    logits, caches = model.prefill(
        params, {"tokens": jnp.asarray(prompt, jnp.int32)[None]})
    caches = pad_to_length(caches, 32)
    toks = [int(jnp.argmax(logits[0, -1]))]
    pos = len(prompt)
    for _ in range(4):
        logits, caches = model.decode_step(
            params, caches, jnp.asarray([[toks[-1]]], jnp.int32),
            jnp.asarray(pos, jnp.int32))
        toks.append(int(jnp.argmax(logits[0, -1])))
        pos += 1
    assert out[:5] == toks


def test_slot_manager():
    sm = SlotManager(2)
    a = sm.assign(10)
    b = sm.assign(11)
    assert sm.free_slots() == []
    sm.release(a)
    assert sm.free_slots() == [a]
    c = sm.assign(12)
    assert c == a
    assert sm.active() == {b: 11, c: 12}


def test_replica_dispatcher_redispatches_slow_replica():
    disp = ReplicaDispatcher(n_replicas=3)
    for i in range(6):
        disp.assign(i)
    rng = np.random.default_rng(0)
    for _ in range(16):
        disp.observe(0, 0.01 + 0.001 * rng.random())
        disp.observe(1, 0.01 + 0.001 * rng.random())
        disp.observe(2, 0.30 + 0.05 * rng.random())   # straggler replica
    dup = disp.decide_redispatch()
    assert dup, "straggler replica should trigger re-dispatch"
    reqs = {r for r, _ in dup}
    assert all(disp.assignments[r] == 2 for r in reqs)
    targets = {t for _, t in dup}
    assert 2 not in targets
    # idempotent: second call doesn't re-duplicate
    assert disp.decide_redispatch() == []
