"""Per-task predicted-straggler trigger (the late-trigger-gap fix).

Pins the PR's contracts:

  * the predictor's per-task score head agrees between the fused device
    step and the historical unfused path within the Tier-1 tolerance
    bound (tests/tolerance.py) at every batch shape — the fused program
    restructures the emission, so cross-path equality is toleranced —
    and scores decompose the job-level E_S exactly;
  * with the per-task head enabled the fused warm path still performs
    zero XLA retraces and zero host->device transfers beyond its single
    staged upload;
  * non-finite E_S from the network can neither crash the controller
    nor force-fire its trigger (clamped to [0, q], non-finite -> 0);
  * on a seeded ``overload`` cell, legacy ``start`` emits zero
    mitigation actions before the first job-completion milestone while
    ``start-eager`` acts strictly earlier, and over >= 5 seeds
    ``start-eager`` improves both SLA-violation rate and execution time
    over legacy ``start`` AND ``none``;
  * the eager technique exists on both substrates (sim registry entry +
    the pod policy translating to backup-shard/evict).
"""
import dataclasses
import pickle
import types

import jax
import numpy as np
import pytest

from repro.core import encoder_lstm as net
from repro.core import features
from repro.core.predictor import StragglerPredictor, fused_compile_count
from repro.core.start import JobView, STARTController
from repro.sim.engine import Simulation
from repro.sim.sweep import SweepSpec
from repro.sim.techniques.start_tech import START, STARTEager, pretrain
from repro.sim import sweep

from tolerance import assert_tier1

jax.config.update("jax_platform_name", "cpu")

OVERLOAD = dict(scenarios=("overload",), n_hosts=16, n_intervals=40,
                arrival_rate=0.8, max_workers=1, pretrain_epochs=2)


@pytest.fixture(scope="module")
def overload_ctrl_bytes():
    """One pretrained controller for the overload cells — START and
    STARTEager pretrain identically (same seed-7 warmup, same fit), so
    both techniques are built from clones of this single controller."""
    spec = SweepSpec(techniques=("start",), seeds=(0,), **OVERLOAD)
    cfg = spec.cell_config("overload", 0)
    return pickle.dumps(
        pretrain(dataclasses.replace(cfg, seed=7), epochs=2, lr=1e-3)), spec


# ----------------------- per-task score head: equality ----------------------

def test_per_task_scores_fused_equals_unfused_per_shape():
    """(e_s, scores) must agree within the Tier-1 bound between the fused
    device step and the unfused path across batch shapes, including idle
    intervals (observe without predict)."""
    rng = np.random.default_rng(0)
    n_hosts, max_tasks = 6, 5
    pred_f = StragglerPredictor(n_hosts=n_hosts, max_tasks=max_tasks)
    pred_u = StragglerPredictor(n_hosts=n_hosts, max_tasks=max_tasks)
    hist = []
    for step, n in enumerate([1, 3, 0, 0, 2, 8, 5, 0, 9]):
        row = rng.uniform(0, 1, (n_hosts, features.HOST_FEATURES)) \
            .astype(np.float32)
        hist.append(row)
        pred_f.push_host_row(row)
        if n == 0:
            continue
        m_t = rng.uniform(0, 1, (n, max_tasks, features.TASK_FEATURES)) \
            .astype(np.float32)
        q = rng.integers(1, max_tasks, n).astype(np.float32)
        seq = list(hist[-pred_u.horizon:])
        while len(seq) < pred_u.horizon:
            seq.insert(0, seq[0])
        want_es, want_s = pred_u.predict_features(
            np.stack(seq), m_t, q, per_task=True)
        got_es, got_s = pred_f.predict_interval(m_t, q, per_task=True)
        assert_tier1(got_es, want_es, context=f"e_s step {step}")
        assert_tier1(got_s, want_s, context=f"scores step {step}")
        assert got_s.shape == (n, max_tasks)


def test_per_task_scores_decompose_es():
    """Scores are the demand-share decomposition of E_S: non-negative,
    summing to the job's E_S over its real tasks, zero on padded slots;
    an all-zero-demand job falls back to uniform E_S / q."""
    rng = np.random.default_rng(1)
    n_hosts, max_tasks = 4, 6
    pred = StragglerPredictor(n_hosts=n_hosts, max_tasks=max_tasks)
    pred.push_host_row(rng.uniform(
        0, 1, (n_hosts, features.HOST_FEATURES)).astype(np.float32))
    m_t = rng.uniform(0, 1, (3, max_tasks, features.TASK_FEATURES)) \
        .astype(np.float32)
    q = np.array([6, 3, 4], np.float32)
    m_t[1, 3:] = 0.0          # job 1: only 3 real tasks, rest padded
    m_t[2, :, :4] = 0.0       # job 2: zero resource demand everywhere
    e_s, scores = pred.predict_interval(m_t, q, per_task=True)
    assert np.all(scores >= 0.0)
    np.testing.assert_allclose(scores.sum(axis=1), e_s, rtol=1e-5)
    assert np.all(scores[1, 3:] == 0.0)          # padded slots score 0
    np.testing.assert_allclose(                  # uniform fallback
        scores[2, :4], np.full(4, e_s[2] / 4.0), rtol=1e-5)
    assert np.all(scores[2, 4:] == 0.0)


# ------------------- warm path: zero retraces / zero H2D --------------------

def test_warm_per_task_cell_zero_retraces_and_zero_transfers(
        overload_ctrl_bytes, monkeypatch):
    """A warm start-eager cell — the per-task head enabled on every
    predicted interval — must never recompile a prediction program and
    must perform no host->device transfer beyond the fused step's single
    staged upload."""
    ctrl_bytes, spec = overload_ctrl_bytes
    cfg = spec.cell_config("overload", 0)
    warm = STARTEager(controller=pickle.loads(ctrl_bytes))
    Simulation(cfg, technique=warm).run()          # warm all buckets

    orig_stage = StragglerPredictor._stage

    def sanctioned_stage(self, arr):
        with jax.transfer_guard_host_to_device("allow"):
            return orig_stage(self, arr)

    monkeypatch.setattr(StragglerPredictor, "_stage", sanctioned_stage)
    tech = STARTEager(controller=pickle.loads(ctrl_bytes))
    compiles_before = (net.predict_sequence._cache_size()
                       + fused_compile_count())
    sim = Simulation(cfg, technique=tech)
    with jax.transfer_guard_host_to_device("disallow"):
        sim.run()
    grew = (net.predict_sequence._cache_size() + fused_compile_count()
            - compiles_before)
    assert grew == 0, "warm per-task cell retraced a prediction program"
    pred = tech._controller.predictor
    assert pred.h2d_stages > 0
    assert pred.h2d_stages <= cfg.n_intervals + 1


# ------------------------- non-finite E_S guard -----------------------------

def test_sanitize_es_clamps_and_zeroes_nonfinite():
    got = STARTController._sanitize_es(
        np.array([np.nan, np.inf, -np.inf, -1.0, 2.5, 99.0]),
        np.array([4.0, 4.0, 4.0, 4.0, 4.0, 4.0]))
    np.testing.assert_array_equal(got, [0.0, 0.0, 0.0, 0.0, 2.5, 4.0])


@pytest.mark.parametrize("bad", [np.nan, np.inf])
def test_nonfinite_es_cannot_fire_or_crash_either_trigger(bad):
    """A NaN/inf network output used to flow into np.floor and either
    crash ``decide`` or permanently force-fire ``decide_arrays``; it
    must now read as 'no predicted stragglers' on both paths."""
    for trigger in ("milestone", "per_task"):
        ctrl = STARTController(n_hosts=4, max_tasks=3, trigger=trigger,
                               hysteresis=1, use_fused_step=False)
        ctrl.observe_hosts(np.zeros((4, features.HOST_FEATURES),
                                    np.float32))
        ctrl.predictor.predict_features = types.MethodType(
            lambda self, *a, **kw:
            (np.full(2, bad), np.full((2, 3), bad)) if kw.get("per_task")
            else types.SimpleNamespace(e_s=np.full(2, bad)),
            ctrl.predictor)
        m_t = np.zeros((2, 3, features.TASK_FEATURES), np.float32)
        acts = ctrl.decide_arrays(
            np.array([0, 1]), m_t, np.array([3.0, 3.0]),
            np.array([1, 1]), np.array([True, False]),
            lambda job: ([0], [0], [0]))
        assert acts == []
        assert ctrl.es_total([0, 1]) == 0.0
    # JobView path: int(np.floor(nan)) used to raise ValueError
    ctrl = STARTController(n_hosts=4, max_tasks=3, use_fused_step=False)
    ctrl.observe_hosts(np.zeros((4, features.HOST_FEATURES), np.float32))
    ctrl.predictor.predict_features = types.MethodType(
        lambda self, *a, **kw: types.SimpleNamespace(e_s=np.full(1, bad)),
        ctrl.predictor)
    jv = JobView(job_id=0, q=3, deadline_oriented=True,
                 incomplete_task_ids=[0], task_hosts=[0],
                 task_matrix=np.zeros((3, features.TASK_FEATURES),
                                      np.float32))
    assert ctrl.decide([jv]) == []


# ----------------------- per-task trigger unit behavior ---------------------

def _scripted_controller(es_value, n_tasks=3, **kw):
    """Controller whose prediction is scripted: E_S fixed, scores
    concentrated on slot 0."""
    ctrl = STARTController(n_hosts=4, max_tasks=n_tasks,
                           trigger="per_task", use_fused_step=False, **kw)
    ctrl.observe_hosts(np.zeros((4, features.HOST_FEATURES), np.float32))
    scores = np.zeros((1, n_tasks))
    scores[0, 0] = es_value

    def scripted(self, *a, **kwargs):
        if kwargs.get("per_task"):
            return np.full(1, es_value), scores
        return types.SimpleNamespace(e_s=np.full(1, es_value))

    ctrl.predictor.predict_features = types.MethodType(
        scripted, ctrl.predictor)
    return ctrl


def _step(ctrl):
    ctrl.observe_hosts(np.zeros((4, features.HOST_FEATURES), np.float32))
    return ctrl.decide_arrays(
        np.array([7]), np.zeros((1, 3, features.TASK_FEATURES),
                                np.float32),
        np.array([3.0]), np.array([3]), np.array([True]),
        lambda job: ([10, 11, 12], [0, 1, 2], [0, 1, 2]))


def test_per_task_hysteresis_then_cooldown():
    """The top-scored task fires exactly after ``hysteresis``
    consecutive in-set intervals, then not again until ``cooldown``
    intervals passed."""
    ctrl = _scripted_controller(1.4, hysteresis=3, cooldown=4,
                                score_on=0.1)
    fired = [len(_step(ctrl)) for _ in range(10)]
    # fires on the 3rd interval (hysteresis=3); the streak keeps
    # building through the cooldown, so the re-fire lands exactly
    # ``cooldown`` intervals later, then cools again
    assert fired == [0, 0, 1, 0, 0, 0, 1, 0, 0, 0]
    acts = []
    ctrl2 = _scripted_controller(1.4, hysteresis=3, cooldown=4,
                                 score_on=0.1)
    for _ in range(3):
        acts = _step(ctrl2)
    assert [a.task_id for a in acts] == [10]     # the top-scored task


def test_per_task_streak_resets_when_set_empties():
    ctrl = _scripted_controller(1.4, hysteresis=3, cooldown=4,
                                score_on=0.1)
    assert _step(ctrl) == [] and _step(ctrl) == []
    ctrl.score_on = 10.0                         # set goes empty
    assert _step(ctrl) == []
    ctrl.score_on = 0.1                          # streak must restart
    assert [len(_step(ctrl)) for _ in range(3)] == [0, 0, 1]


def test_per_task_load_gate_defers_fire_on_idle_host():
    """With host_load given, a set member on a below-median-load host
    defers its fire until its host is contended (streak preserved)."""
    ctrl = _scripted_controller(1.4, hysteresis=2, cooldown=4,
                                score_on=0.1)
    idle = np.array([0.0, 1.0, 1.0, 1.0])       # task 10 lives on host 0
    busy = np.array([2.0, 1.0, 1.0, 1.0])

    def step(load):
        ctrl.observe_hosts(np.zeros((4, features.HOST_FEATURES),
                                    np.float32))
        return ctrl.decide_arrays(
            np.array([7]), np.zeros((1, 3, features.TASK_FEATURES),
                                    np.float32),
            np.array([3.0]), np.array([3]), np.array([True]),
            lambda job: ([10, 11, 12], [0, 1, 2], [0, 1, 2]),
            host_load=load)

    assert step(idle) == [] and step(idle) == [] and step(idle) == []
    assert [a.task_id for a in step(busy)] == [10]


def test_milestone_trigger_unchanged_by_extended_incomplete_fn():
    """Legacy milestone controllers accept (and ignore) the per-task
    slot element, so one policy-side callback serves both modes."""
    ctrl = STARTController(n_hosts=4, max_tasks=3, use_fused_step=False)
    ctrl.observe_hosts(np.zeros((4, features.HOST_FEATURES), np.float32))
    ctrl.predictor.predict_features = types.MethodType(
        lambda self, *a, **kw: types.SimpleNamespace(
            e_s=np.full(1, 2.0)), ctrl.predictor)
    acts = ctrl.decide_arrays(
        np.array([7]), np.zeros((1, 3, features.TASK_FEATURES),
                                np.float32),
        np.array([3.0]), np.array([2]), np.array([True]),
        lambda job: ([10, 11], [0, 1], [0, 1]))
    assert sorted(a.task_id for a in acts) == [10, 11]


# ------------------------ the late-trigger gap itself -----------------------

@pytest.mark.slow
def test_start_waits_for_milestone_while_eager_acts_before_it(
        overload_ctrl_bytes):
    """The seeded overload cell: legacy start emits zero mitigation
    actions before the first job-completion milestone (on this cell it
    never fires at all), while start-eager emits its first action
    strictly earlier than the first completion."""
    ctrl_bytes, spec = overload_ctrl_bytes
    cfg = spec.cell_config("overload", 0)

    def run(cls):
        tech = cls(controller=pickle.loads(ctrl_bytes))
        fires = []
        orig = type(tech).decide

        def wrapped(self, view):
            acts = orig(self, view)
            if acts:
                fires.append(int(view.t))
            return acts

        tech.decide = types.MethodType(wrapped, tech)
        sim = Simulation(cfg, technique=tech)
        sim.run()
        done_ts = [r["t"] for r in sim.snapshot().completed_jobs]
        return fires, (min(done_ts) if done_ts else None)

    start_fires, start_done = run(START)
    eager_fires, eager_done = run(STARTEager)
    assert start_done is not None and eager_done is not None
    # legacy start: nothing before the first completion milestone
    assert not [t for t in start_fires if t < start_done]
    # eager: first action strictly before any job completed
    assert eager_fires and eager_fires[0] < eager_done
    # and strictly before legacy start's first action (if it ever fired)
    if start_fires:
        assert eager_fires[0] < start_fires[0]


@pytest.mark.slow
def test_eager_strictly_improves_overload_over_start_and_none(
        overload_ctrl_bytes):
    """The PR's acceptance cell: mean SLA-violation rate AND mean
    execution time over 5 seeds, start-eager < start and < none."""
    ctrl_bytes, _ = overload_ctrl_bytes
    spec = SweepSpec(techniques=("none", "start", "start-eager"),
                     seeds=(0, 1, 2, 3, 4), **OVERLOAD)

    def run_cells(make_tech):
        sla, ex = [], []
        for seed in spec.seeds:
            cfg = spec.cell_config("overload", seed)
            s = Simulation(cfg, technique=make_tech(cfg)).run()
            sla.append(s["sla_violation_rate"])
            ex.append(s["avg_execution_time_s"])
        return float(np.mean(sla)), float(np.mean(ex))

    res = {
        "none": run_cells(lambda cfg: sweep.make_technique("none", cfg)),
        "start": run_cells(
            lambda cfg: START(controller=pickle.loads(ctrl_bytes))),
        "start-eager": run_cells(
            lambda cfg: STARTEager(controller=pickle.loads(ctrl_bytes))),
    }
    eager = res["start-eager"]
    for other in ("start", "none"):
        assert eager[0] < res[other][0], \
            f"sla_violation_rate: eager {eager[0]} vs {other} " \
            f"{res[other][0]}"
        assert eager[1] < res[other][1], \
            f"avg_execution_time_s: eager {eager[1]} vs {other} " \
            f"{res[other][1]}"


# ----------------------------- both substrates ------------------------------

def test_eager_registered_on_both_substrates():
    from repro import policy
    import repro.distributed.straggler_runtime  # noqa: F401  (registers)
    import repro.sim.techniques as T
    assert "start-eager" in policy.names("sim")
    assert "start-eager" in policy.names("pod")
    assert "start-eager-pod" in policy.names("pod")
    assert "start-eager-pod" not in policy.names("sim")
    assert "start-eager" in T.FIELD


def test_eager_pod_policy_backups_after_hysteresis_with_cooldown():
    """One chronically slow host: the eager pod policy backs up its
    shard only after ``hysteresis`` consecutive straggler steps, then
    rests ``cooldown`` steps; the runtime translates and picks a backup
    host."""
    from repro.distributed.straggler_runtime import (
        ActionKind, RuntimeConfig, StartEagerPodPolicy, StragglerRuntime)
    rt = StragglerRuntime(
        RuntimeConfig(n_hosts=8, evict_after=100),
        policy=StartEagerPodPolicy(hysteresis=3, cooldown=4))
    backups = []
    for t in range(10):
        times = np.full(8, 1.0)
        times[5] = 4.0                       # persistent straggler
        rt.observe_step(times)
        acts = rt.decide()
        backups.append([a.host for a in acts
                        if ActionKind(a.kind) is ActionKind.BACKUP_SHARD])
        for a in acts:
            assert ActionKind(a.kind) is ActionKind.BACKUP_SHARD
            assert a.backup is not None and a.backup != a.host
    fired = [t for t, b in enumerate(backups) if b == [5]]
    assert fired and fired[0] == 2           # 3rd straggler step
    assert all(not b for t, b in enumerate(backups) if t not in fired)
    assert len(fired) >= 2 and fired[1] - fired[0] == 4  # cooldown held
