"""Trainer, optimizer, data pipeline, checkpoint tests (single device)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models.lm import Model
from repro.train import checkpoint as ckpt
from repro.train.data import DataConfig, SyntheticLM
from repro.train.optimizer import OptConfig, init, schedule, update
from repro.train.trainer import TrainConfig, Trainer, auto_n_micro, \
    make_train_step

jax.config.update("jax_platform_name", "cpu")


def small_setup(n_micro=1, opt_kind="adamw"):
    cfg = get_reduced("demo-100m")
    model = Model(cfg)
    ocfg = OptConfig(kind=opt_kind, lr=1e-2, warmup_steps=2,
                     total_steps=100)
    trainer = Trainer(model, mesh=None, opt_cfg=ocfg,
                      tcfg=TrainConfig(n_micro=n_micro))
    params, opt_state = trainer.init_state()
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=32,
                                  global_batch=8))
    return cfg, model, trainer, params, opt_state, data


def test_loss_decreases():
    """End-to-end learning check: structured synthetic data is learnable."""
    _, _, trainer, params, opt_state, data = small_setup()
    step = trainer.compile_step()
    losses = []
    for i in range(60):
        params, opt_state, m = step(params, opt_state, data.batch(i))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.8, (losses[0], losses[-1])


def test_microbatch_equivalence():
    """n_micro=4 gradient == n_micro=1 gradient (same global batch)."""
    cfg, model, _, params, _, data = small_setup()
    ocfg = OptConfig(lr=0.0, warmup_steps=1, total_steps=10)
    batch = data.batch(0)
    s1 = make_train_step(model, ocfg, TrainConfig(n_micro=1))
    s4 = make_train_step(model, ocfg, TrainConfig(n_micro=4))
    o1 = init(ocfg, params)
    o4 = init(ocfg, params)
    p1, o1b, m1 = jax.jit(s1)(params, o1, batch)
    p4, o4b, m4 = jax.jit(s4)(params, o4, batch)
    # with lr=0 params unchanged; compare first moments (grad estimate)
    g1 = jax.tree_util.tree_leaves(o1b.m)
    g4 = jax.tree_util.tree_leaves(o4b.m)
    for a, b in zip(g1, g4):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=2e-5)


def test_adafactor_runs_and_learns():
    _, _, trainer, params, opt_state, data = small_setup(
        opt_kind="adafactor")
    step = trainer.compile_step()
    losses = []
    for i in range(40):
        params, opt_state, m = step(params, opt_state, data.batch(i))
        losses.append(float(m["loss"]))
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0]


def test_adafactor_state_smaller_than_adam():
    cfg, model, *_ = small_setup()
    params = model.init(jax.random.PRNGKey(0))
    a = init(OptConfig(kind="adamw"), params)
    f = init(OptConfig(kind="adafactor"), params)
    size = lambda t: sum(x.size * x.dtype.itemsize  # noqa: E731
                         for x in jax.tree_util.tree_leaves(t))
    assert size(f) < size(a) * 0.6


def test_schedule_warmup_cosine():
    c = OptConfig(lr=1.0, warmup_steps=10, total_steps=110,
                  min_lr_frac=0.1)
    assert float(schedule(c, jnp.asarray(0))) == pytest.approx(0.0)
    assert float(schedule(c, jnp.asarray(10))) == pytest.approx(1.0,
                                                                abs=1e-3)
    assert float(schedule(c, jnp.asarray(110))) == pytest.approx(0.1,
                                                                 abs=1e-3)


def test_auto_n_micro_respects_dp_cap():
    # huge vocab wants many microbatches, but per-micro batch must cover
    # every data shard
    assert auto_n_micro(256, 4096, 256000, 16) <= 16
    assert auto_n_micro(256, 4096, 256000, 32) <= 8
    assert auto_n_micro(8, 128, 1000, 1) == 1
    # vocab sharding reduces the pressure -> fewer microbatches
    n_sharded = auto_n_micro(256, 4096, 256000, 16, n_model=16,
                             n_layers=32, d_model=4096)
    n_flat = auto_n_micro(256, 4096, 256000, 16, n_model=1,
                          n_layers=32, d_model=4096)
    assert n_sharded <= n_flat


def test_data_determinism_and_sharding():
    c = DataConfig(vocab=97, seq_len=16, global_batch=8, seed=3)
    full = SyntheticLM(c).batch(5)
    sh0 = SyntheticLM(c, shard_index=0, shard_count=2).batch(5)
    sh1 = SyntheticLM(c, shard_index=1, shard_count=2).batch(5)
    again = SyntheticLM(c).batch(5)
    np.testing.assert_array_equal(full["tokens"], again["tokens"])
    assert sh0["tokens"].shape == (4, 16)
    assert not np.array_equal(sh0["tokens"], sh1["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(full["labels"][:, :-1],
                                  full["tokens"][:, 1:])


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(10, dtype=jnp.float32),
            "b": {"c": jnp.ones((3, 4), jnp.bfloat16)}}
    ckpt.save(str(tmp_path), 7, tree)
    assert ckpt.latest_step(str(tmp_path)) == 7
    back = ckpt.restore(str(tmp_path), 7, tree)
    for x, y in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(back)):
        np.testing.assert_array_equal(np.asarray(x, np.float32),
                                      np.asarray(y, np.float32))


def test_checkpoint_retention_and_async(tmp_path):
    tree = {"w": jnp.zeros(4)}
    w = ckpt.AsyncCheckpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        w.submit(s, tree)
    w.close()
    steps = sorted(d for d in os.listdir(tmp_path)
                   if d.startswith("step_"))
    assert len(steps) <= 2
    assert ckpt.latest_step(str(tmp_path)) == 4


def test_train_driver_resume(tmp_path):
    """Fault drill: kill mid-run, resume from checkpoint, finish."""
    from repro.launch.train import main
    ck = str(tmp_path / "ck")
    with pytest.raises(SystemExit):
        main(["--arch", "demo-100m", "--reduced", "--steps", "30",
              "--batch", "4", "--seq", "16", "--ckpt", ck,
              "--ckpt-every", "5", "--kill-at", "12"])
    assert ckpt.latest_step(ck) is not None
    out = main(["--arch", "demo-100m", "--reduced", "--steps", "30",
                "--batch", "4", "--seq", "16", "--ckpt", ck, "--resume"])
    assert out["steps"] < 30  # resumed partway, not from scratch
    assert out["last_loss"] is not None
