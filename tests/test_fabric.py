"""Remote elastic sweep fabric: protocol, scheduling, fault tolerance.

Three layers:

  * wire + scheduler unit tests drive ``FabricCoordinator._dispatch``
    directly with a fake clock (lease reclaim, work stealing,
    duplicate-result dedupe, failure poisoning, partial results);
  * an in-thread full-stack test runs ``run(spec, fabric=...)`` against
    a worker living in this process (fast, no spawn cost);
  * real multi-process tests spawn 2 node agents and assert the
    acceptance criteria: a >=24-cell grid bitwise-equal to serial, and
    grid completion after one node is SIGKILLed mid-unit (reusing the
    same ``REPRO_TEST_KILL_CELL`` harness as the broken-pool tests).
"""
import io
import json
import multiprocessing
import os
import threading
import time

import pytest

from repro.sim import fabric, sweep
from repro.sim.fabric import (FabricCoordinator, FabricWorker,
                              ProtocolError, recv_frame, send_frame,
                              worker_main)
from repro.sim.sweep import (CellResult, SweepSpec,
                             deterministic_summary as _det, run)


def _spec(**kw) -> SweepSpec:
    base = dict(techniques=("none", "sgc"), seeds=(0, 1),
                scenarios=("planetlab",), n_hosts=10, n_intervals=20,
                arrival_rate=0.8, max_workers=1)
    base.update(kw)
    return SweepSpec(**base)


# ------------------------------ wire frames --------------------------------

def test_frame_roundtrip_and_eof():
    buf = io.BytesIO()
    send_frame(buf, {"op": "hello", "node": "n1", "blob": b"\x00\xff"})
    send_frame(buf, {"op": "bye"})
    buf.seek(0)
    assert recv_frame(buf)["blob"] == b"\x00\xff"
    assert recv_frame(buf)["op"] == "bye"
    assert recv_frame(buf) is None          # clean EOF


def test_frame_rejects_oversize_and_truncation():
    import struct
    buf = io.BytesIO(struct.pack(">Q", fabric.MAX_FRAME + 1))
    with pytest.raises(ProtocolError, match="MAX_FRAME"):
        recv_frame(buf)
    buf = io.BytesIO()
    send_frame(buf, {"op": "x"})
    truncated = io.BytesIO(buf.getvalue()[:-2])
    with pytest.raises(ProtocolError, match="mid-frame"):
        recv_frame(truncated)


# --------------------------- scheduler internals ---------------------------

class _Clock:
    def __init__(self):
        self.t = 100.0

    def __call__(self):
        return self.t


@pytest.fixture
def coord():
    clock = _Clock()
    c = FabricCoordinator(lease_s=30.0, clock=clock)
    c.clock = clock                       # test handle
    yield c
    c.stop()


def _join(c, node):
    c._dispatch({"op": "hello", "node": node, "lanes": 1})
    resp = c._dispatch({"op": "request", "node": node, "epoch": -1})
    assert resp["op"] == "grid"
    return resp["epoch"]


def _pull(c, node, epoch):
    return c._dispatch({"op": "request", "node": node, "epoch": epoch})


def _results_for(cells):
    return [CellResult(sc, tech, seed, {"tasks_done": 1}, 0.0)
            for sc, tech, seed in cells]


def test_lease_reclaim_requeues_stalled_nodes_units(coord):
    coord._load_grid(_spec(seeds=(0,), techniques=("none",)))
    ep = _join(coord, "a")
    got = _pull(coord, "a", ep)
    assert got["op"] == "unit"
    # node a goes silent past its lease; node b joins and inherits the
    # reclaimed unit
    coord.clock.t += coord.lease_s + 1.0
    ep_b = _join(coord, "b")
    got_b = _pull(coord, "b", ep_b)
    assert got_b["op"] == "unit" and got_b["uid"] == got["uid"]
    assert "a" not in coord._nodes        # reaped
    coord._dispatch({"op": "result", "node": "b", "uid": got_b["uid"],
                     "results": _results_for(got_b["cells"])})
    assert coord._grid_done.is_set()


def test_disconnect_requeues_inflight_units(coord):
    coord._load_grid(_spec(seeds=(0,), techniques=("none",)))
    ep = _join(coord, "a")
    got = _pull(coord, "a", ep)
    assert got["op"] == "unit"
    coord._disconnect("a")                # abrupt socket drop
    assert "a" not in coord._nodes
    assert got["uid"] in coord._queue


def test_work_stealing_and_duplicate_result_dropped(coord):
    coord._load_grid(_spec(seeds=(0, 1), techniques=("none",)))
    ep = _join(coord, "a")
    u1 = _pull(coord, "a", ep)
    u2 = _pull(coord, "a", ep)
    assert {u1["op"], u2["op"]} == {"unit"}
    # queue drained: b steals a speculative copy of a's oldest unit
    ep_b = _join(coord, "b")
    stolen = _pull(coord, "b", ep_b)
    assert stolen["op"] == "unit" and stolen["uid"] == u1["uid"]
    # b finishes first; a's duplicate result for the same unit is
    # dropped (first result wins — identical anyway, cells are pure)
    coord._dispatch({"op": "result", "node": "b", "uid": stolen["uid"],
                     "results": _results_for(stolen["cells"])})
    done_before = len(coord._done_cells)
    coord._dispatch({"op": "result", "node": "a", "uid": u1["uid"],
                     "results": _results_for(u1["cells"])})
    assert len(coord._done_cells) == done_before
    coord._dispatch({"op": "result", "node": "a", "uid": u2["uid"],
                     "results": _results_for(u2["cells"])})
    assert coord._grid_done.is_set()


def test_stealing_disabled_yields_wait(coord):
    coord.max_speculate = 0
    coord._load_grid(_spec(seeds=(0,), techniques=("none",)))
    ep = _join(coord, "a")
    assert _pull(coord, "a", ep)["op"] == "unit"
    ep_b = _join(coord, "b")
    assert _pull(coord, "b", ep_b)["op"] == "wait"


def test_partial_result_streams_incrementally(coord):
    spec = _spec(seeds=(0, 1), techniques=("none",))
    coord._load_grid(spec)
    ep = _join(coord, "a")
    got = _pull(coord, "a", ep)
    coord._dispatch({"op": "result", "node": "a", "uid": got["uid"],
                     "results": _results_for(got["cells"])})
    part = coord.partial_result()
    assert 0 < len(part.cells) < len(spec.cells())
    keys = [(c.scenario, c.technique, c.seed) for c in part.cells]
    assert keys == [c for c in spec.cells() if c in set(keys)]  # order


def test_failed_unit_requeues_then_poisons_grid(coord):
    coord._load_grid(_spec(seeds=(0,), techniques=("none",)))
    ep = _join(coord, "a")
    for attempt in range(coord.max_unit_failures):
        got = _pull(coord, "a", ep)
        assert got["op"] == "unit", attempt
        coord._dispatch({"op": "failed", "node": "a", "uid": got["uid"],
                         "detail": "ValueError: boom"})
    assert coord._grid_done.is_set()
    assert "boom" in coord._grid_error


def test_drain_only_after_grid_completes(coord):
    coord._load_grid(_spec(seeds=(0,), techniques=("none",)))
    ep = _join(coord, "a")
    got = _pull(coord, "a", ep)
    coord._dispatch({"op": "result", "node": "a", "uid": got["uid"],
                     "results": _results_for(got["cells"])})
    assert _pull(coord, "a", ep)["op"] == "drain"


# ------------------------------ cache shipping -----------------------------

def test_cache_shipping_roundtrip(tmp_path, monkeypatch):
    # keep the test from pointing the process-wide jax cache at tmp_path
    monkeypatch.setattr(sweep, "enable_compile_cache", lambda: None)
    src = tmp_path / "src-cache"
    src.mkdir()
    (src / "prog_a.bin").write_bytes(b"exec-a")
    sub = src / "sub"
    sub.mkdir()
    (sub / "prog_b.bin").write_bytes(b"exec-b")
    monkeypatch.setenv("REPRO_JAX_CACHE_DIR", str(src))
    files = fabric.collect_cache_files()
    assert files == {"prog_a.bin": b"exec-a",
                     os.path.join("sub", "prog_b.bin"): b"exec-b"}
    # worker side: no local cache dir -> temp dir materialized
    dst = tmp_path / "dst-cache"
    dst.mkdir()
    (dst / "prog_a.bin").write_bytes(b"local-wins")
    monkeypatch.setenv("REPRO_JAX_CACHE_DIR", str(dst))
    path = fabric.install_cache_files(files)
    assert path == str(dst)
    # existing files never overwritten; missing ones shipped in
    assert (dst / "prog_a.bin").read_bytes() == b"local-wins"
    assert (dst / "sub" / "prog_b.bin").read_bytes() == b"exec-b"


def test_collect_cache_files_empty_when_unset(monkeypatch):
    monkeypatch.delenv("REPRO_JAX_CACHE_DIR", raising=False)
    assert fabric.collect_cache_files() == {}
    assert fabric.install_cache_files({}) is None


# ------------------------------ CLI helpers --------------------------------

def test_spec_from_json_roundtrip(tmp_path):
    path = tmp_path / "grid.json"
    path.write_text(json.dumps({
        "techniques": ["none", "sgc"], "seeds": [0, 1],
        "scenarios": ["planetlab"], "n_hosts": 10, "n_intervals": 20}))
    spec = fabric._spec_from_json(str(path))
    assert spec.techniques == ("none", "sgc") and spec.n_hosts == 10
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps({"nope": 1}))
    with pytest.raises(ValueError, match="nope"):
        fabric._spec_from_json(str(bad))
    assert fabric._parse_bind(":0") == ("127.0.0.1", 0)
    assert fabric._parse_bind("10.0.0.2:9999") == ("10.0.0.2", 9999)


# ------------------------- full stack, in-thread ---------------------------

def test_fabric_run_in_thread_bitwise_equals_serial():
    spec = _spec()
    serial = run(spec)
    with FabricCoordinator(lease_s=30.0) as coord:
        w = FabricWorker(coord.host, coord.port, node="t1",
                         exit_on_drain=False)
        th = threading.Thread(target=w.run, daemon=True)
        th.start()
        try:
            res = run(spec, fabric=coord)
        finally:
            w.stop()
    assert [(c.scenario, c.technique, c.seed) for c in res.cells] == \
        spec.cells()
    for a, b in zip(serial.cells, res.cells):
        assert _det(a.summary) == _det(b.summary)
    th.join(timeout=10)


def test_run_grid_timeout_keeps_partial(coord):
    spec = _spec(seeds=(0,), techniques=("none",))
    with pytest.raises(TimeoutError, match="partial_result"):
        coord.run_grid(spec, timeout=0.5)    # no workers ever join
    assert coord.partial_result().cells == []


# ------------------------- full stack, multi-process -----------------------

def _spawn_workers(coord, n, **kw):
    ctx = multiprocessing.get_context("spawn")
    procs = [ctx.Process(target=worker_main,
                         args=(coord.host, coord.port),
                         kwargs=dict(node=f"node{i}", lanes=1, **kw),
                         daemon=True)
             for i in range(n)]
    for p in procs:
        p.start()
    return procs


def _reap_workers(procs, timeout=60):
    for p in procs:
        p.join(timeout=timeout)
        if p.is_alive():
            p.kill()
            p.join(timeout=5)


def test_fabric_two_nodes_bitwise_equals_serial_24_cells():
    """Acceptance: a localhost 2-node fabric run of a >=24-cell grid is
    bitwise-identical to serial ``run()`` on deterministic_summary."""
    spec = _spec(techniques=("none", "sgc"),
                 scenarios=("planetlab", "fault-storm"),
                 seeds=(0, 1, 2, 3, 4, 5))
    assert len(spec.cells()) >= 24
    serial = run(spec)
    with FabricCoordinator(lease_s=60.0) as coord:
        procs = _spawn_workers(coord, 2)
        try:
            res = run(spec, fabric=coord)
        finally:
            _reap_workers(procs)
    assert [(c.scenario, c.technique, c.seed) for c in res.cells] == \
        spec.cells()
    for a, b in zip(serial.cells, res.cells):
        assert _det(a.summary) == _det(b.summary), (a.scenario,
                                                    a.technique, a.seed)


def test_fabric_completes_after_node_killed_mid_grid(tmp_path,
                                                     monkeypatch):
    """Acceptance: SIGKILL one node mid-grid; the lease/disconnect
    reclaim requeues its in-flight unit and the surviving node finishes
    every cell, still bitwise-equal to serial.  Reuses the same
    ``REPRO_TEST_KILL_CELL`` harness as the broken-pool tests."""
    spec = _spec(techniques=("none", "sgc"),
                 scenarios=("planetlab", "fault-storm"),
                 seeds=(0, 1, 2))
    serial = run(spec)                    # env not armed yet: no kill
    marker = tmp_path / "killed-once"
    monkeypatch.setenv("REPRO_TEST_KILL_CELL",
                       f"fault-storm:sgc:1:{marker}")
    with FabricCoordinator(lease_s=60.0) as coord:
        procs = _spawn_workers(coord, 2)
        try:
            res = run(spec, fabric=coord)
        finally:
            _reap_workers(procs)
    assert marker.exists(), "the kill drill never fired"
    assert any(p.exitcode not in (0, None) for p in procs), \
        "no node actually died"
    assert [(c.scenario, c.technique, c.seed) for c in res.cells] == \
        spec.cells()
    for a, b in zip(serial.cells, res.cells):
        assert _det(a.summary) == _det(b.summary), (a.scenario,
                                                    a.technique, a.seed)


def test_worker_gives_up_when_coordinator_gone():
    coord = FabricCoordinator().start()
    w = FabricWorker(coord.host, coord.port, node="w",
                     reconnect_tries=2, reconnect_delay_s=0.05)
    w._connect()
    coord.stop()
    w._file = None                        # socket dropped with the server
    t0 = time.perf_counter()
    with pytest.raises(ConnectionError, match="unreachable"):
        w._request({"op": "request", "node": "w", "epoch": -1})
    assert time.perf_counter() - t0 < 30  # bounded, not an endless retry


def test_two_sequential_grids_same_fabric(coord):
    """The coordinator outlives a grid: epoch bumps and the same node
    serves the next one (the persistent-pool analogue)."""
    for seeds in ((0,), (1,)):
        coord._load_grid(_spec(seeds=seeds, techniques=("none",)))
        ep = _join(coord, "a")
        while True:
            got = _pull(coord, "a", ep)
            if got["op"] == "drain":
                break
            assert got["op"] == "unit"
            coord._dispatch({"op": "result", "node": "a",
                             "uid": got["uid"],
                             "results": _results_for(got["cells"])})
        assert coord._grid_done.is_set()
    assert coord._epoch == 2


def test_run_cell_pure_across_processes_spot_check():
    """One cell run here vs in a fabric unit must agree exactly — the
    purity every reclaim/steal/duplicate decision rests on."""
    spec = _spec(seeds=(0,), techniques=("none",))
    a = sweep.run_cell(spec, "planetlab", "none", 0)
    b = sweep._run_unit(spec, (("planetlab", "none", 0),), {})[0]
    assert _det(a.summary) == _det(b.summary)
