"""Unit + property tests for the Pareto straggler model (paper §3.1)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
# hypothesis is optional: conftest.py installs a fixed-example fallback stub
# when the real package is absent, so collection never hard-errors
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import pareto

jax.config.update("jax_platform_name", "cpu")


def test_cdf_basic():
    a, b = 2.0, 1.0
    assert float(pareto.pareto_cdf(0.5, a, b)) == 0.0
    assert float(pareto.pareto_cdf(1.0, a, b)) == pytest.approx(0.0)
    assert float(pareto.pareto_cdf(2.0, a, b)) == pytest.approx(0.75)
    assert float(pareto.pareto_cdf(1e6, a, b)) == pytest.approx(1.0, abs=1e-6)


def test_mle_recovers_parameters():
    key = jax.random.PRNGKey(0)
    a_true, b_true = 2.5, 3.0
    x = pareto.sample_pareto(key, a_true, b_true, (20000,))
    a, b = pareto.fit_pareto(x)
    assert float(b) == pytest.approx(b_true, rel=0.01)
    assert float(a) == pytest.approx(a_true, rel=0.05)


def test_mle_masked_matches_unmasked():
    key = jax.random.PRNGKey(1)
    x = pareto.sample_pareto(key, 2.0, 1.0, (64,))
    xp = jnp.concatenate([x, jnp.zeros(16)])
    mask = jnp.concatenate([jnp.ones(64), jnp.zeros(16)])
    a1, b1 = pareto.fit_pareto(x)
    a2, b2 = pareto.fit_pareto(xp, mask)
    np.testing.assert_allclose(a1, a2, rtol=1e-6)
    np.testing.assert_allclose(b1, b2, rtol=1e-6)


def test_expected_stragglers_formula():
    # E_S = q * (k*alpha/(alpha-1))^(-alpha): beta-free, in (0, q)
    q, a, b = 10.0, 2.0, 5.0
    es = float(pareto.expected_stragglers(q, a, b, k=1.5))
    assert es == pytest.approx(10.0 * (1.5 * 2.0 / 1.0) ** -2.0)
    es_other_beta = float(pareto.expected_stragglers(q, a, 50.0, k=1.5))
    assert es == pytest.approx(es_other_beta)


def test_es_monotone_in_k():
    # larger threshold multiple -> fewer expected stragglers
    q, a, b = 20.0, 1.8, 2.0
    es = [float(pareto.expected_stragglers(q, a, b, k=k))
          for k in (1.1, 1.5, 2.0, 3.0)]
    assert all(x > y for x, y in zip(es, es[1:]))


@settings(max_examples=50, deadline=None)
@given(alpha=st.floats(1.1, 8.0), beta=st.floats(0.1, 100.0),
       seed=st.integers(0, 2**30))
def test_property_mle_minimizes_nll(alpha, beta, seed):
    """The MLE must have NLL <= nearby (alpha, beta) perturbations."""
    key = jax.random.PRNGKey(seed)
    x = pareto.sample_pareto(key, alpha, beta, (256,))
    a_hat, b_hat = pareto.fit_pareto(x)
    nll_hat = float(pareto.pareto_nll(x, a_hat, b_hat))
    for da in (-0.2, 0.2):
        a_pert = jnp.clip(a_hat * (1 + da), 1.001, 1e4)
        assert nll_hat <= float(pareto.pareto_nll(x, a_pert, b_hat)) + 1e-4


@settings(max_examples=50, deadline=None)
@given(alpha=st.floats(1.1, 6.0), q=st.integers(1, 500))
def test_property_es_bounds(alpha, q):
    """0 < E_S < q for any valid tail index (k=1.5 > 1)."""
    es = float(pareto.expected_stragglers(float(q), alpha, 1.0))
    assert 0.0 < es < q


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2**30), alpha=st.floats(1.2, 5.0),
       beta=st.floats(0.5, 20.0))
def test_property_empirical_straggler_fraction(seed, alpha, beta):
    """Fraction of samples above K approximates E_S/q."""
    key = jax.random.PRNGKey(seed)
    n = 20000
    x = pareto.sample_pareto(key, alpha, beta, (n,))
    kthr = pareto.straggler_threshold(alpha, beta)
    frac = float((x > kthr).mean())
    expect = float(pareto.expected_stragglers(1.0, alpha, beta))
    assert frac == pytest.approx(expect, abs=0.02)


def test_f1_scores():
    pred = jnp.array([1, 1, 0, 0, 1.0])
    truth = jnp.array([1, 0, 0, 1, 1.0])
    f1 = float(pareto.f1_score(pred, truth))
    # tp=2 fp=1 fn=1 -> f1 = 2/(2+1) = 0.666..
    assert f1 == pytest.approx(2 / 3, rel=1e-5)
    assert 0.0 <= float(pareto.f1_score_paper(2.0, 1.0)) <= 1.0


def test_degenerate_all_equal_times():
    x = jnp.full((16,), 3.0)
    a, b = pareto.fit_pareto(x)
    assert np.isfinite(float(a)) and float(b) == pytest.approx(3.0)
    es = float(pareto.expected_stragglers(16.0, a, b))
    assert np.isfinite(es) and es >= 0.0
