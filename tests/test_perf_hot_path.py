"""Array-native hot-path tests: batched placement bitwise-equals the
sequential scheduler loop (including edge cases), task-matrix features use
the previous-host field, the jitted predictor compiles at most once per
batch bucket, the Pallas LSTM-cell route is exact, predictor.fit keeps one
minibatch shape, and sweep-result lookups are indexed."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import encoder_lstm as net
from repro.core import features
from repro.core.predictor import StragglerPredictor, bucket_size
from repro.sim import Simulation, small, sweep
from repro.sim.cluster import Cluster
from repro.sim.scheduler import RandomScheduler, UtilizationAwareScheduler
from repro.sim.sweep import CellResult, SweepResult, SweepSpec
from repro.sim.techniques.start_tech import _task_matrix

jax.config.update("jax_platform_name", "cpu")


def _cluster(n_hosts=12, seed=0, **kw):
    cfg = small(n_hosts=n_hosts, **kw)
    rng = np.random.default_rng(seed)
    c = Cluster(cfg, rng)
    # a non-trivial utilization/task profile for the scorer
    c.util = np.abs(np.random.default_rng(seed + 1)
                    .normal(0.3, 0.2, c.util.shape))
    c.n_tasks = np.random.default_rng(seed + 2).integers(
        0, 7, n_hosts).astype(np.int64)
    return c


def _sequential_reference(sched, cluster, reqs, rng, exclude):
    """The engine's historical per-task loop: place with exclusion, then
    re-place without it if the chosen host is down."""
    out = np.empty(len(reqs), np.int64)
    for i, req in enumerate(reqs):
        ex = int(exclude[i]) if exclude[i] >= 0 else None
        h = sched.place(cluster, req, rng, exclude=ex)
        if cluster.downtime[h] > 0:
            h = sched.place(cluster, req, rng)
        out[i] = h
    return out


# --------------------------- place_batch ≡ place ----------------------------

@pytest.mark.parametrize("sched_cls", [UtilizationAwareScheduler,
                                       RandomScheduler])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_place_batch_bitwise_equals_sequential_place(sched_cls, seed):
    """Randomized workload: batched placement must reproduce the
    sequential loop exactly (hosts AND rng stream)."""
    c = _cluster(n_hosts=14, seed=seed)
    rng = np.random.default_rng(seed + 10)
    n = 64
    reqs = rng.uniform(0.02, 0.6, (n, 4))
    exclude = rng.integers(-1, c.n, n)
    c.downtime[rng.integers(0, c.n, 4)] = 2  # some hosts down

    sched = sched_cls()
    ref_rng = np.random.default_rng(99)
    got_rng = np.random.default_rng(99)
    want = _sequential_reference(sched, c, reqs, ref_rng, exclude)
    got = sched.place_batch(c, reqs, got_rng, exclude=exclude)
    np.testing.assert_array_equal(got, want)
    # randomized schedulers must leave the rng stream in the same state
    assert ref_rng.integers(0, 1 << 30) == got_rng.integers(0, 1 << 30)


def test_place_batch_all_hosts_offline():
    """Every host down: placement still returns a host (the engine keeps
    the task nominally placed; progress is zero while the host is down)."""
    c = _cluster(n_hosts=6)
    c.downtime[:] = 3
    reqs = np.full((5, 4), 0.2)
    exclude = np.array([-1, 2, 0, -1, 5])
    sched = UtilizationAwareScheduler()
    rng = np.random.default_rng(0)
    want = _sequential_reference(sched, c, reqs, rng, exclude)
    got = sched.place_batch(c, reqs, rng, exclude=exclude)
    np.testing.assert_array_equal(got, want)
    assert ((got >= 0) & (got < c.n)).all()


def test_place_batch_exclude_with_single_online_host():
    """One host online and it's the excluded one: the exclusion is waived
    (exclusions only apply with >1 online host) and the task lands there."""
    c = _cluster(n_hosts=5)
    c.downtime[:] = 2
    c.downtime[3] = 0
    reqs = np.full((3, 4), 0.1)
    exclude = np.array([3, 3, -1])
    sched = UtilizationAwareScheduler()
    rng = np.random.default_rng(0)
    got = sched.place_batch(c, reqs, rng, exclude=exclude)
    np.testing.assert_array_equal(got, [3, 3, 3])
    np.testing.assert_array_equal(
        got, _sequential_reference(sched, c, reqs, rng, exclude))


def test_engine_survives_all_hosts_offline_interval():
    cfg = small(n_hosts=6, n_intervals=10, fault_host_rate=0.0)
    sim = Simulation(cfg)
    sim.step()
    sim.cluster.downtime[:] = 4  # blackout: every later placement is forced
    for _ in range(4):
        sim.step()
    s = sim.summary()
    assert s["tasks_total"] >= 0  # no crash, bookkeeping intact
    for job in range(sim.jobs.n):
        tids = sim.jobs.task_ids(job)
        open_n = int((sim.tasks.state[tids] <= 1).sum())
        assert sim.jobs.open_count[job] == open_n


# ------------------------- feature-matrix twins -----------------------------

def test_host_matrix_np_matches_jax_twin_bitwise():
    rng = np.random.default_rng(3)
    n = 9
    util = rng.uniform(0, 1.4, (n, 4))
    cap = rng.uniform(1, 8, (n, 4))
    cost = rng.uniform(1, 5, n)
    pmax = rng.uniform(100, 300, n)
    ntasks = rng.integers(0, 9, n)
    a = features.host_matrix_np(util, cap, cost, pmax, ntasks)
    b = np.asarray(features.host_matrix(util, cap, cost, pmax, ntasks))
    assert a.dtype == np.float32
    np.testing.assert_array_equal(a, b)


def test_task_matrix_batch_np_matches_jax_twin_bitwise():
    rng = np.random.default_rng(4)
    n_hosts, max_tasks = 7, 10
    counts = np.array([2, 10, 5])
    rows = np.repeat(np.arange(3), counts)
    cols = np.concatenate([np.arange(c) for c in counts])
    req = rng.uniform(0.02, 0.9, (counts.sum(), 4))
    prev = rng.integers(-1, n_hosts, counts.sum())
    batch = features.task_matrix_batch_np(req, prev, rows, cols, 3,
                                          n_hosts, max_tasks)
    assert batch.shape == (3, max_tasks, features.TASK_FEATURES)
    off = 0
    for j, c in enumerate(counts):
        want = np.asarray(features.task_matrix(
            req[off:off + c], prev[off:off + c], n_hosts, max_tasks))
        np.testing.assert_array_equal(batch[j], want)
        off += c


def test_task_matrix_prev_host_feature_uses_previous_host_for_restarts():
    """Regression: a restarted (unplaced) task must report the host it ran
    on before the restart, not -1/'never placed'."""
    cfg = small(n_hosts=8, n_intervals=6, fault_host_rate=0.0,
                fault_task_rate=0.0, fault_vm_creation_rate=0.0)
    sim = Simulation(cfg)
    for _ in range(3):
        sim.step()
    tt = sim.tasks
    run = np.nonzero(tt.active_mask())[0]
    assert run.size > 0
    i = int(run[0])
    old_host = int(tt.host[i])
    sim._restart(i)          # fault-style restart: pending, unplaced
    assert tt.host[i] == -1 and tt.prev_host[i] == old_host
    mt = _task_matrix(sim.snapshot(), [i])
    expected = np.float32(old_host + 1.0) / np.float32(cfg.n_hosts)
    assert mt[0, 4] == expected
    # never-restarted running tasks keep reporting their current host
    j = int(run[1])
    mt_j = _task_matrix(sim.snapshot(), [j])
    assert mt_j[0, 4] == np.float32(int(tt.host[j]) + 1.0) \
        / np.float32(cfg.n_hosts)


# ----------------------- bucketed jit, no retraces --------------------------

def test_predict_sequence_compiles_once_per_bucket():
    """Sweeping the active-job count must not retrace per count: the
    predictor pads to power-of-two buckets, so the jit cache grows by at
    most one entry per distinct bucket and not at all on repeats."""
    pred = StragglerPredictor(n_hosts=3, max_tasks=4)
    rng = np.random.default_rng(0)
    mh = rng.uniform(0, 1, (5, 3, features.HOST_FEATURES)).astype(np.float32)

    def run_counts(counts):
        for n in counts:
            mt = rng.uniform(0, 1, (n, 4, features.TASK_FEATURES)) \
                .astype(np.float32)
            out = pred.predict_features(mh, mt, np.full(n, 4.0, np.float32))
            assert out.e_s.shape == (n,)

    before = net.predict_sequence._cache_size()
    run_counts([1, 2, 3, 4, 5, 6, 7, 8, 9, 12, 16])
    grew = net.predict_sequence._cache_size() - before
    assert pred.buckets_used == {1, 2, 4, 8, 16}
    assert grew <= len(pred.buckets_used)
    # repeats of already-seen counts (and new counts in seen buckets)
    # compile nothing
    mid = net.predict_sequence._cache_size()
    run_counts([1, 3, 5, 7, 9, 11, 13, 15, 16, 2, 10])
    assert net.predict_sequence._cache_size() == mid


def test_bucket_size():
    assert [bucket_size(n) for n in (0, 1, 2, 3, 4, 5, 8, 9, 17)] \
        == [1, 1, 2, 4, 4, 8, 8, 16, 32]


def test_batch_size_exact_shape_policy_and_budget():
    """The Tier-1 exact-shape policy: counts whose power-of-two bucket
    wastes more than ``exact_shape_waste`` run at their exact width, up
    to ``exact_shape_budget`` distinct shapes; decisions replay
    deterministically and the budget bounds the steady-state compile
    count of a long-lived process."""
    pred = StragglerPredictor(n_hosts=3, max_tasks=4)
    assert pred.batch_size(3) == 4    # waste 1/4 == threshold: pads
    assert pred.batch_size(6) == 8    # waste 2/8 == threshold: pads
    assert pred.batch_size(5) == 5    # waste 3/8 > threshold: exact
    assert pred.batch_size(9) == 9    # waste 7/16: exact
    assert pred.batch_size(8) == 8    # exact power of two: unchanged
    assert pred.batch_size(5) == 5    # replay is deterministic

    tight = StragglerPredictor(n_hosts=3, max_tasks=4,
                               exact_shape_budget=2)
    assert tight.batch_size(5) == 5
    assert tight.batch_size(9) == 9
    assert tight.batch_size(17) == 32   # budget spent: new counts pad
    assert tight.batch_size(5) == 5     # seen exact shapes stay exact

    off = StragglerPredictor(n_hosts=3, max_tasks=4,
                             exact_shape_waste=1.0)
    assert off.batch_size(5) == 8       # policy disabled: pure po2

    # the Tier-0 reference path is NOT subject to the policy: its batch
    # shaping stays pure power-of-two bucketing (bucket_size above)
    rng = np.random.default_rng(0)
    mh = rng.uniform(0, 1, (5, 3, features.HOST_FEATURES)) \
        .astype(np.float32)
    mt = rng.uniform(0, 1, (5, 4, features.TASK_FEATURES)) \
        .astype(np.float32)
    off2 = StragglerPredictor(n_hosts=3, max_tasks=4)
    out = off2.predict_features(mh, mt, np.full(5, 4.0, np.float32))
    assert out.e_s.shape == (5,)
    assert off2.buckets_used == {8}     # padded, not exact


def test_start_cell_run_stays_within_bucket_compiles():
    """End to end: a multi-interval START run retraces at most once per
    bucket the run actually used."""
    from repro.sim.techniques.start_tech import START
    before = net.predict_sequence._cache_size()
    sim = Simulation(small(n_hosts=10, n_intervals=25, seed=3),
                     technique=START())
    sim.run()
    tech = sim.technique
    grew = net.predict_sequence._cache_size() - before
    assert grew <= len(tech._controller.predictor.buckets_used)


# ------------------------- Pallas cell route exact --------------------------

def test_predict_sequence_pallas_route_is_exact():
    """The fused Pallas LSTM cell behind ``use_pallas`` must reproduce the
    jnp cell bit-for-bit through the full network."""
    params = net.init_params(jax.random.PRNGKey(0), input_dim=24)
    xs = jax.random.normal(jax.random.PRNGKey(1), (5, 6, 24), jnp.float32)
    ref = net.predict_sequence(params, xs)
    pal = net.predict_sequence(params, xs, use_pallas=True)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(pal))
    # and via the predictor flag
    pred = StragglerPredictor(n_hosts=2, max_tasks=4, use_pallas_cell=True)
    mh = np.zeros((5, 2, features.HOST_FEATURES), np.float32)
    mt = np.zeros((3, 4, features.TASK_FEATURES), np.float32)
    out = pred.predict_features(mh, mt, np.full(3, 4.0, np.float32))
    assert np.isfinite(out.e_s).all()


# --------------------------- predictor.fit shapes ---------------------------

def test_fit_drops_partial_batch_and_records_epoch_mean_loss():
    rng = np.random.default_rng(0)
    pred = StragglerPredictor(n_hosts=2, max_tasks=3)
    dim = pred.input_dim
    xs = rng.normal(size=(5, 10, dim)).astype(np.float32)
    ys = np.abs(rng.normal(size=(10, 2))).astype(np.float32) + 1.0
    before = net.train_step._cache_size()
    losses = pred.fit(xs, ys, epochs=3, lr=1e-3, batch=4)
    # n=10, batch=4 -> two full batches per epoch, partial batch dropped:
    # exactly one train_step shape, so at most one new compile
    assert net.train_step._cache_size() - before <= 1
    assert len(losses) == 3
    assert all(np.isfinite(v) for v in losses)
    # n <= batch keeps the whole set as the single batch
    pred2 = StragglerPredictor(n_hosts=2, max_tasks=3)
    losses2 = pred2.fit(xs, ys, epochs=2, lr=1e-3, batch=64)
    assert len(losses2) == 2 and all(np.isfinite(v) for v in losses2)


# --------------------------- sweep result index -----------------------------

def test_sweep_result_cell_lookup_is_indexed():
    spec = SweepSpec(techniques=("none",), seeds=(0, 1),
                     scenarios=("planetlab",), metrics=("m",))
    cells = [CellResult("planetlab", "none", s, {"m": float(s)}, 0.0)
             for s in (0, 1)]
    res = SweepResult(spec=spec, cells=cells, wall_s=0.0, n_workers=1)
    assert res.cell("planetlab", "none", 1).summary["m"] == 1.0
    assert "_index" in res.__dict__          # built lazily, then reused
    assert res.cell("planetlab", "none", 0) is cells[0]
    with pytest.raises(KeyError):
        res.cell("planetlab", "none", 7)
    # the index tracks late-appended cells instead of going stale
    res.cells.append(CellResult("planetlab", "none", 7, {"m": 7.0}, 0.0))
    assert res.cell("planetlab", "none", 7).summary["m"] == 7.0


# ------------------------ persistent pool plumbing --------------------------

def test_persistent_pool_is_reused_across_runs():
    spec = SweepSpec(techniques=("none", "sgc"), seeds=(0,),
                     scenarios=("planetlab",), n_hosts=8, n_intervals=10,
                     arrival_rate=0.8, max_workers=2)
    r1 = sweep.run(spec)
    pool1 = sweep._POOL
    assert pool1 is not None
    r2 = sweep.run(dataclasses.replace(spec, seeds=(1,)))
    assert sweep._POOL is pool1              # same workers, caches warm
    assert len(r1.cells) == len(r2.cells) == 2
    sweep.shutdown_pool()
    assert sweep._POOL is None
