"""Distributed runtime tests.

Multi-device tests run in subprocesses with
XLA_FLAGS=--xla_force_host_platform_device_count=8 so the main pytest
process keeps exactly one device (per the dry-run isolation rule).
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.distributed.straggler_runtime import (ActionKind, RuntimeConfig,
                                                 StragglerRuntime,
                                                 backup_mask)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_subprocess(code: str) -> str:
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=os.path.join(REPO, "src"),
               JAX_PLATFORMS="cpu")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env,
                       timeout=600)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


# ------------------------- straggler runtime (START) ------------------------


def make_runtime(n=8, **kw):
    return StragglerRuntime(RuntimeConfig(n_hosts=n, **kw))


def test_runtime_no_actions_when_uniform():
    rt = make_runtime()
    for _ in range(6):
        rt.observe_step(np.full(8, 1.0))
    assert rt.decide() == []


def test_runtime_backup_on_heavy_tail():
    # E_S scales with host count (Eq. 4): use a pod-scale host set
    rt = make_runtime(n=64)
    rng = np.random.default_rng(0)
    acted = False
    for t in range(12):
        times = 1.0 + 1.0 * rng.pareto(1.5, 64)  # heavy tail
        times[3] *= 3.0                          # clear straggler
        rt.observe_step(times)
        for a in rt.decide():
            acted = True
            assert a.kind in (ActionKind.BACKUP_SHARD, ActionKind.EVICT)
            if a.kind is ActionKind.BACKUP_SHARD:
                assert a.backup != a.host
    assert acted


def test_runtime_evicts_chronic_straggler():
    rt = make_runtime(evict_after=3)
    rng = np.random.default_rng(1)
    evicted = set()
    for t in range(15):
        times = 1.0 + 0.05 * rng.pareto(1.5, 8)
        times[5] = 4.0  # chronically slow every step
        rt.observe_step(times)
        for a in rt.decide():
            if a.kind is ActionKind.EVICT:
                evicted.add(a.host)
    assert 5 in evicted


def test_backup_mask_exactly_one_contribution():
    from repro.distributed.straggler_runtime import HostAction
    actions = [HostAction(ActionKind.BACKUP_SHARD, 2, backup=0)]
    # host 2 missed the deadline -> backup host 0 owns shard 2
    w = backup_mask(4, actions, np.array([1, 1, 0, 1], bool))
    np.testing.assert_array_equal(w, [1, 1, 0, 1])
    # host 2 made it -> owner keeps the shard
    w = backup_mask(4, actions, np.array([1, 1, 1, 1], bool))
    np.testing.assert_array_equal(w, [1, 1, 1, 1])


def test_runtime_es_tracks_tail_mass():
    """Heavier tails -> larger expected straggler count (Eq. 4 behaviour)."""
    rng = np.random.default_rng(2)
    light = make_runtime()
    heavy = make_runtime()
    for _ in range(8):
        light.observe_step(1.0 + 0.01 * rng.pareto(6.0, 8))
        heavy.observe_step(1.0 + 1.0 * rng.pareto(1.2, 8))
    assert heavy.expected_stragglers() > light.expected_stragglers()


# ----------------------------- multi-device tests ---------------------------


@pytest.mark.slow
def test_sharded_training_8dev():
    """FSDP+TP training on a (4,2) mesh: loss finite, params sharded."""
    run_subprocess("""
        import jax, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_reduced
        from repro.models.lm import Model, ShardCtx
        from repro.distributed import sharding as Sh
        from repro.launch.mesh import make_host_mesh
        from repro.train.trainer import Trainer, TrainConfig
        from repro.train.optimizer import OptConfig
        from repro.train.data import SyntheticLM, DataConfig

        assert len(jax.devices()) == 8
        mesh = make_host_mesh(n_data=4, n_model=2)
        cfg = get_reduced('demo-100m')
        model = Model(cfg, shard_ctx=ShardCtx(mesh, Sh.dp_axes(mesh)))
        tr = Trainer(model, mesh, opt_cfg=OptConfig(lr=1e-2,
                     warmup_steps=2, total_steps=50))
        params, opt = tr.init_state()
        data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=32,
                                      global_batch=8))
        import repro.train.optimizer as Opt
        from repro.train.trainer import make_train_step
        step = jax.jit(make_train_step(model, tr.opt_cfg, TrainConfig(),
                                       mesh=mesh))
        losses = []
        for i in range(10):
            params, opt, m = step(params, opt, data.batch(i))
            losses.append(float(m['loss']))
        assert all(np.isfinite(losses)), losses
        assert losses[-1] < losses[0]
        # at least one param leaf is actually sharded across devices
        sharded = any(
            not leaf.sharding.is_fully_replicated
            for leaf in jax.tree_util.tree_leaves(params))
        assert sharded
        print('OK', losses[0], losses[-1])
    """)


@pytest.mark.slow
def test_compression_ef_int8_8dev():
    """EF-int8 all-reduce ~ plain mean; error feedback shrinks the bias."""
    run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.distributed import compression as C
        from repro.launch.mesh import make_host_mesh

        mesh = make_host_mesh(n_data=8, n_model=1)
        g = jax.random.normal(jax.random.PRNGKey(0), (8, 64, 32))

        def f(gl, res):
            red, new_res = C.ef_int8_reduce({'w': gl[0]}, {'w': res[0]},
                                            'data')
            return red['w'][None], new_res['w'][None]

        fn = shard_map(f, mesh=mesh,
                       in_specs=(P('data', None, None),
                                 P('data', None, None)),
                       out_specs=(P('data', None, None),
                                  P('data', None, None)))
        res = jnp.zeros_like(g)
        red, res = fn(g, res)
        true_mean = g.mean(0)
        got = np.asarray(red[0])
        err = np.abs(got - np.asarray(true_mean)).max()
        scale = float(np.abs(np.asarray(true_mean)).max())
        assert err < 0.1 * scale + 0.05, (err, scale)
        # residual carries the quantization error
        assert float(jnp.abs(res).max()) > 0
        print('OK', err)
    """)


@pytest.mark.slow
def test_elastic_remesh_8dev():
    """Drop 2 devices, rebuild the mesh, reshard params, keep training."""
    run_subprocess("""
        import jax, numpy as np
        from repro.configs import get_reduced
        from repro.models.lm import Model
        from repro.distributed import elastic, sharding as Sh
        from repro.launch.mesh import make_host_mesh
        from repro.train.trainer import Trainer
        from repro.train.optimizer import OptConfig
        from repro.train.data import SyntheticLM, DataConfig

        mesh = make_host_mesh(n_data=4, n_model=2)
        cfg = get_reduced('demo-100m')
        model = Model(cfg)
        tr = Trainer(model, mesh, opt_cfg=OptConfig(lr=1e-2,
                     warmup_steps=1, total_steps=50))
        params, opt = tr.init_state()
        st = elastic.ElasticState(mesh=mesh)
        # hosts 6,7 fail (START eviction or hardware)
        lost = [d.id for d in mesh.devices.flatten()[-2:]]
        st2 = elastic.remesh(st, lost, model_parallel=2)
        assert st2.mesh.shape['data'] == 3
        params2 = elastic.reshard(params, mesh, st2.mesh,
                                  lambda t, m: Sh.param_specs(t, m))
        data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=32,
                                      global_batch=6))
        from repro.train.trainer import make_train_step, TrainConfig
        import repro.train.optimizer as Opt
        opt2 = Opt.init(tr.opt_cfg, params2)
        step = jax.jit(make_train_step(model, tr.opt_cfg, TrainConfig(),
                                       mesh=st2.mesh))
        p, o, m = step(params2, opt2, data.batch(0))
        assert np.isfinite(float(m['loss']))
        print('OK gen', st2.generation, float(m['loss']))
    """)


@pytest.mark.slow
def test_checkpoint_cross_mesh_restore_8dev(tmp_path):
    """Checkpoint written on a (4,2) mesh restores onto a (2,2) mesh."""
    run_subprocess(f"""
        import jax, numpy as np
        from jax.sharding import NamedSharding
        from repro.configs import get_reduced
        from repro.models.lm import Model
        from repro.distributed import sharding as Sh
        from repro.launch.mesh import make_host_mesh
        from repro.train import checkpoint as ckpt

        cfg = get_reduced('demo-100m')
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        mesh1 = make_host_mesh(n_data=4, n_model=2)
        s1 = Sh.param_specs(params, mesh1)
        p1 = jax.tree_util.tree_map(
            lambda x, s: jax.device_put(x, NamedSharding(mesh1, s)),
            params, s1)
        ckpt.save({str(tmp_path)!r}, 3, p1)
        import jax.numpy as jnp
        devs = np.array(jax.devices()[:4]).reshape(2, 2)
        from jax.sharding import Mesh
        mesh2 = Mesh(devs, ('data', 'model'))
        s2 = Sh.param_specs(params, mesh2)
        sh2 = jax.tree_util.tree_map(
            lambda s: NamedSharding(mesh2, s), s2,
            is_leaf=lambda x: hasattr(x, '_normalized_spec') or
            type(x).__name__ == 'PartitionSpec')
        p2 = ckpt.restore({str(tmp_path)!r}, 3, params, shardings=sh2)
        a = jax.tree_util.tree_leaves(p1)[0]
        b = jax.tree_util.tree_leaves(p2)[0]
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32))
        print('OK')
    """)
