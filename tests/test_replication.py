"""Replication-timing (Wang et al.) and redundancy-level (Aktas &
Soljanin) policy families: registry wiring, the tail-adaptive fork-point
model, end-to-end behavior on the simulator, per-technique knob plumbing
through SweepSpec, and the action translation on the pod substrate."""
import dataclasses
import types

import numpy as np
import pytest

from repro import policy
from repro.core import pareto
from repro.sim import Simulation, small, sweep
from repro.sim.techniques.replication import (MIN_TAIL_SAMPLES, P_GRID,
                                              AdaptiveRedundancy,
                                              FixedRedundancy,
                                              ForkRelaunch, SingleFork,
                                              fork_fraction,
                                              fork_objective)

NEW_POLICIES = ("single-fork", "fork-relaunch", "redundancy-fixed",
                "redundancy-adaptive")


def _faultless(**kw):
    base = dict(n_hosts=10, n_intervals=40, fault_host_rate=0.0,
                fault_task_rate=0.0, fault_vm_creation_rate=0.0)
    base.update(kw)
    return small(**base)


# ------------------------------ registry -----------------------------------

def test_families_are_registered_for_both_substrates():
    import repro.sim.techniques  # noqa: F401  (registers built-ins)
    for name in NEW_POLICIES:
        entry = policy.get(name)
        assert entry.substrates == ("sim", "pod"), name
        assert entry.description, name
    # the fork policies seed their tail estimate offline; the upfront
    # redundancy policies have nothing to train
    assert policy.get("single-fork").pretrain is not None
    assert policy.get("fork-relaunch").pretrain is not None
    assert policy.get("redundancy-fixed").pretrain is None
    assert policy.get("redundancy-adaptive").pretrain is None


# ------------------------ fork-point quantile model -------------------------

def test_pareto_quantile_np_matches_jax_twin_and_inverts_cdf():
    rng = np.random.default_rng(0)
    for alpha, beta in ((1.3, 2.0), (2.2, 300.0)):
        q = rng.uniform(0.05, 0.95, 16)
        x_np = pareto.pareto_quantile_np(alpha, beta, q)
        x_j = np.asarray(pareto.pareto_quantile(alpha, beta, q))
        np.testing.assert_allclose(x_np, x_j, rtol=1e-5)
        # F(F^-1(q)) == q
        np.testing.assert_allclose(
            np.asarray(pareto.pareto_cdf(x_np, alpha, beta)), q,
            rtol=1e-5)
        assert (x_np >= beta).all()


def test_fork_fraction_tracks_the_latency_vs_cost_knob():
    for alpha in (1.2, 2.0, 4.0):
        ps = [fork_fraction(alpha, w, kill=False)
              for w in (0.0, 0.5, 1.0, 3.0)]
        # paying more for cost replicates later, never earlier
        assert ps == sorted(ps), (alpha, ps)
        assert all(P_GRID[0] <= p <= P_GRID[-1] for p in ps)
        # killing forfeits progress, so the kill variant forks later (or
        # at worst at the same point)
        assert fork_fraction(alpha, 0.5, kill=True) >= \
            fork_fraction(alpha, 0.5, kill=False)
    # the objective is finite everywhere on the grid
    assert np.isfinite(fork_objective(1.2, P_GRID, 3.0, True)).all()
    assert np.isfinite(fork_objective(4.0, P_GRID, 0.0, False)).all()


# ------------------------------ simulator ----------------------------------

def test_single_fork_speculates_and_latches_once_per_job():
    tech = SingleFork(p=0.5)
    sim = Simulation(_faultless(), technique=tech)
    s = sim.run()
    tt = sim.tasks
    assert s["tasks_done"] > 0
    assert tt.view("is_copy").sum() > 0          # tail tasks were raced
    assert tt.view("restarts").sum() == 0        # no-kill variant
    assert len(tech._forked) > 0
    # the single-fork latch: no job's original tasks gained more than one
    # copy generation (each original has at most 1 speculative copy)
    orig_of_copies = tt.view("orig")[tt.view("is_copy")]
    uniq, cnt = np.unique(orig_of_copies, return_counts=True)
    assert (cnt == 1).all()


def test_fork_relaunch_kills_instead_of_racing():
    tech = ForkRelaunch(p=0.5)
    sim = Simulation(_faultless(), technique=tech)
    s = sim.run()
    tt = sim.tasks
    assert s["tasks_done"] > 0
    assert tt.view("is_copy").sum() == 0         # never clones
    assert tt.view("restarts").sum() > 0         # relaunched the tail
    assert len(tech._forked) > 0


def test_fork_waits_for_tail_evidence():
    """With no pinned p, no pretrained tail and no completions yet, the
    policy must not fork blind."""
    tech = SingleFork()
    sim = Simulation(_faultless(n_intervals=1), technique=tech)
    sim.run()
    assert sim.tasks.view("is_copy").sum() == 0
    assert tech._tail(sim.snapshot()) is None or \
        int((sim.tasks.view("state") == 2).sum()) >= MIN_TAIL_SAMPLES


def test_redundancy_fixed_clones_every_task_upfront():
    sim = Simulation(_faultless(), technique=FixedRedundancy(r=2))
    sim.run()
    tt = sim.tasks
    n_orig = int((~tt.view("is_copy")).sum())
    n_copy = int(tt.view("is_copy").sum())
    assert n_copy == n_orig                      # r=2 -> one clone each
    # clones are born at submit time with their original
    copies = np.nonzero(tt.view("is_copy"))[0]
    origs = tt.view("orig")[copies]
    np.testing.assert_array_equal(tt.view("submit_s")[copies],
                                  tt.view("submit_s")[origs])


def test_adaptive_redundancy_backs_off_with_utilization():
    tech = AdaptiveRedundancy(r_max=3.0, util_knee=0.7)
    hosts = types.SimpleNamespace(util=np.zeros((8, 4)))
    cfg = types.SimpleNamespace(reserved_utilization=0.0)
    view = types.SimpleNamespace(hosts=hosts, config=cfg)
    assert tech._level(view) == pytest.approx(3.0)          # idle: r_max
    hosts.util = np.full((8, 4), 0.35)
    assert tech._level(view) == pytest.approx(2.0)          # half knee
    hosts.util = np.full((8, 4), 0.9)
    assert tech._level(view) == pytest.approx(1.0)          # saturated
    # the reserved floor is subtracted (task-attributable utilization)
    cfg.reserved_utilization = 0.35
    assert tech._level(view) > 1.0
    hosts.util = np.full((8, 4), 0.35)
    assert tech._level(view) == pytest.approx(3.0)


def test_adaptive_redundancy_clones_less_than_fixed_under_load():
    cfg = _faultless(arrival_rate=1.6)
    fixed = Simulation(dataclasses.replace(cfg),
                       technique=FixedRedundancy(r=3))
    fixed.run()
    adaptive = Simulation(dataclasses.replace(cfg),
                          technique=AdaptiveRedundancy(r_max=3.0))
    adaptive.run()
    assert adaptive.tasks.view("is_copy").sum() \
        < fixed.tasks.view("is_copy").sum()


# --------------------------- sweep integration ------------------------------

def test_all_four_run_through_sweepspec():
    spec = sweep.SweepSpec(techniques=("none",) + NEW_POLICIES,
                           seeds=(0,), scenarios=("heavy-tail",),
                           n_hosts=10, n_intervals=20, arrival_rate=0.8,
                           max_workers=1)
    res = sweep.run(spec)
    for name in NEW_POLICIES:
        c = res.cell("heavy-tail", name, 0)
        assert c.summary["tasks_done"] > 0, name
        assert 0.0 <= c.summary["sla_violation_rate"] <= 1.0, name


def test_technique_kwargs_flow_through_spec_and_pretrain():
    cfg = small(n_hosts=10, n_intervals=20)
    # pretrained path: kwargs reach the built instance AND the warmup
    # seeds the tail estimate
    t = sweep.make_technique("single-fork", cfg,
                             technique_kwargs={"p": 0.6,
                                               "cost_weight": 2.0})
    assert t.p == 0.6 and t.cost_weight == 2.0
    assert t.alpha0 is not None and t.beta0 is not None
    # distinct kwargs get distinct cache entries, same kwargs share one
    t2 = sweep.make_technique("single-fork", cfg,
                              technique_kwargs={"p": 0.6,
                                                "cost_weight": 2.0})
    assert t2 is not t and t2.alpha0 == t.alpha0 and t2.p == 0.6
    t3 = sweep.make_technique("single-fork", cfg,
                              technique_kwargs={"p": 0.9})
    assert t3.p == 0.9
    # untrained path + declarative spec spelling
    spec = sweep.SweepSpec(
        techniques=("redundancy-fixed",), seeds=(0,),
        scenarios=("planetlab",), n_hosts=10, n_intervals=15,
        arrival_rate=0.8, max_workers=1,
        technique_kwargs={"redundancy-fixed": {"r": 3}})
    assert spec.kwargs_for("redundancy-fixed") == {"r": 3}
    assert spec.kwargs_for("none") == {}
    res = sweep.run(spec)
    assert res.cells[0].summary["tasks_done"] >= 0
    # unknown technique names in the kwargs map fail fast
    with pytest.raises(ValueError, match="registered techniques"):
        sweep.SweepSpec(technique_kwargs={"bogus": {"r": 2}})


def test_technique_kwargs_reach_start_via_pretrain_context():
    cfg = small(n_hosts=10, n_intervals=20)
    t = sweep.make_technique("start", cfg, pretrain_epochs=2,
                             technique_kwargs={"margin": 0.25})
    assert t.margin == 0.25
    assert t._controller is not None


# ------------------------------ pod substrate -------------------------------

def _pod_trace(n=8, slow=3, factor=2.5, seed=0):
    rng = np.random.default_rng(seed)

    def step():
        t = 1.0 + 0.05 * rng.pareto(2.0, n)
        t[slow] *= factor
        return t

    return step


def _drive(name, steps=25, n=8, **kw):
    from repro.distributed.straggler_runtime import (RuntimeConfig,
                                                     StragglerRuntime)
    rt = StragglerRuntime(RuntimeConfig(n_hosts=n),
                          policy=policy.make(name, **kw))
    step = _pod_trace(n=n)
    acts = []
    for _ in range(steps):
        rt.observe_step(step())
        acts += rt.decide()
    return rt, acts


@pytest.mark.parametrize("name,kind,host_field", [
    ("single-fork", "backup_shard", "backup_shards"),
    # the kill variant's adaptive fork point sits above a pod window's
    # maximum progress fraction — covered by the policy's pod clamp
    ("fork-relaunch", "evict", "evictions"),
])
def test_fork_family_translates_to_pod_verbs(name, kind, host_field):
    from repro.policy import ActionKind
    rt, acts = _drive(name)
    assert acts, name
    assert {ActionKind(a.kind) for a in acts} == {ActionKind(kind)}
    assert rt.summary()[host_field] == len(acts)
    # the chronically slow host is acted on (an occasional Pareto spike
    # on another host may legitimately cross the fork quantile too)
    assert 3 in {a.host for a in acts}


def test_redundancy_family_backs_up_slowest_hosts_on_pod():
    rt, acts = _drive("redundancy-fixed")
    assert acts
    # r=2 -> exactly one backup per step once telemetry exists
    assert all(a.kind == "backup_shard" for a in acts)
    assert rt.summary()["backup_shards"] == rt.t
    # the slow host dominates the backup set
    hosts = np.array([a.host for a in acts])
    assert (hosts == 3).mean() > 0.5
    rt2, acts2 = _drive("redundancy-adaptive")
    assert acts2 and all(a.kind == "backup_shard" for a in acts2)
    assert all(a.backup not in (None, a.host) for a in acts2)
