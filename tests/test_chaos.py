"""Chaos harness drills: deterministic fault injection against both
distributed stacks, plus the hardening it motivated.

Layers:

  * unit tests for the harness itself (``FaultPlan`` replay + budget,
    ``ChaosProxy`` pumps, ``SkewClock`` driving lease reclaim and the
    wall-clock retrain scheduler);
  * frame-auth tests proving an invalid-MAC fabric frame is rejected
    **before** ``pickle.loads`` runs (a ``__reduce__`` canary would
    flip a flag if untrusted bytes ever reached the unpickler);
  * service hardening: per-request timeouts actually applied, the
    ``MAX_LINE`` cap dropping a newline-less peer, seq-deduped snapshot
    resend, admission-token auth, daemon kill+restart mid-stream with a
    reconnecting client and no double-applied snapshot;
  * ``VersionStore`` crash recovery from a torn/garbage ``CURRENT``;
  * the headline slow drill: a 2-node 24-cell grid pushed through the
    chaos proxy (scripted corruption, mid-frame RST, a stall longer
    than the lease, one node SIGKILLed) with ``REPRO_FABRIC_KEY`` set —
    still bitwise-equal to serial ``run()``.

``REPRO_CHAOS_SEEDS`` (comma-separated ints, default ``0``) fans the
seeded drills out — the nightly chaos lane sweeps several seeds and
uploads each run's realized fault schedule as a JSON artifact
(``REPRO_CHAOS_ARTIFACT_DIR``).
"""
import io
import json
import multiprocessing
import os
import pickle
import socket
import struct
import threading
import time

import numpy as np
import pytest

from repro.chaos import ChaosProxy, FaultPlan, SkewClock
from repro.core import features
from repro.policy import wire
from repro.service import (LocalClient, PredictionService, Profile,
                           ServiceConfig, ServiceDaemon)
from repro.service import protocol
from repro.service.daemon import RetrainScheduler, ServiceClient
from repro.sim import fabric
from repro.sim.fabric import (FabricCoordinator, ProtocolError,
                              recv_frame, send_frame, worker_main)
from repro.sim.sweep import (SweepSpec, deterministic_summary as _det,
                             run)
from repro.train.checkpoint import VersionStore


def pytest_generate_tests(metafunc):
    if "chaos_seed" in metafunc.fixturenames:
        raw = os.environ.get("REPRO_CHAOS_SEEDS", "0")
        seeds = [int(s) for s in raw.split(",") if s.strip()]
        metafunc.parametrize("chaos_seed", seeds or [0])


def _artifact_path(tmp_path, name: str) -> str:
    d = os.environ.get("REPRO_CHAOS_ARTIFACT_DIR")
    if d:
        os.makedirs(d, exist_ok=True)
        return os.path.join(d, name)
    return str(tmp_path / name)


# ------------------------------ SkewClock ---------------------------------

def test_skewclock_advance_freeze_thaw_monotonic():
    clk = SkewClock()
    t0 = clk()
    clk.advance(10.0)
    assert clk() >= t0 + 10.0
    with pytest.raises(ValueError, match="monotonic"):
        clk.advance(-1.0)
    clk.freeze()
    a = clk()
    time.sleep(0.01)
    assert clk() == a                     # pinned
    clk.advance(5.0)
    assert clk() == a + 5.0               # skew applies while frozen
    clk.thaw()
    assert clk() >= a + 5.0               # never goes backwards
    clk.thaw()                            # idempotent


def test_skewclock_triggers_lease_reclaim():
    clk = SkewClock()
    spec = SweepSpec(techniques=("none",), seeds=(0,),
                     scenarios=("planetlab",), n_hosts=10,
                     n_intervals=20, arrival_rate=0.8, max_workers=1)
    with FabricCoordinator(lease_s=30.0, clock=clk) as coord:
        coord._load_grid(spec)
        coord._dispatch({"op": "hello", "node": "a", "lanes": 1})
        ep = coord._dispatch({"op": "request", "node": "a",
                              "epoch": -1})["epoch"]
        got = coord._dispatch({"op": "request", "node": "a",
                               "epoch": ep})
        assert got["op"] == "unit"
        clk.advance(coord.lease_s + 1.0)  # a goes silent past its lease
        coord._dispatch({"op": "hello", "node": "b", "lanes": 1})
        ep_b = coord._dispatch({"op": "request", "node": "b",
                                "epoch": -1})["epoch"]
        got_b = coord._dispatch({"op": "request", "node": "b",
                                 "epoch": ep_b})
        assert got_b["op"] == "unit" and got_b["uid"] == got["uid"]
        assert "a" not in coord._nodes


def test_skewclock_triggers_wall_clock_retrain():
    clk = SkewClock()
    sched = RetrainScheduler(60.0, clock=clk)
    assert not sched.due()
    clk.advance(61.0)
    assert sched.due()
    assert not sched.due()                # re-armed, fires once
    clk.freeze()
    clk.advance(200.0)                    # three missed periods coalesce
    assert sched.due() and not sched.due()


# ------------------------------ FaultPlan ---------------------------------

def _decisions(plan, seed, n=200):
    import random
    rng = random.Random(f"{seed}/0/c2s")
    return [plan.decide(rng, i) for i in range(n)]


def test_fault_plan_replays_for_a_seed():
    mk = lambda: FaultPlan(drop=0.05, delay=0.05, duplicate=0.05,  # noqa: E731
                           truncate=0.05, corrupt=0.05, reset=0.0)
    a, b = _decisions(mk(), 7), _decisions(mk(), 7)
    assert a == b                         # same seed: identical schedule
    assert a != _decisions(mk(), 8)       # different seed: different one
    assert any(k != "pass" for k, _ in a)


def test_fault_plan_budget_and_one_shot_script():
    plan = FaultPlan(corrupt=1.0, max_faults=3)
    _decisions(plan, 0, n=50)
    assert plan.faults_injected() == 3    # budget caps injection
    plan = FaultPlan(script={2: ("reset", None)})
    got = _decisions(plan, 0, n=5)
    assert got[2] == ("reset", None)
    # one-shot: a second stream reaching chunk 2 passes through
    assert _decisions(plan, 0, n=5)[2] == ("pass", None)


def test_fault_plan_stall_claimed_once():
    plan = FaultPlan(stall_after=1, stall_s=0.5)
    a = _decisions(plan, 0, n=3)
    assert ("stall", 0.5) in a
    assert all(k == "pass" for k, _ in _decisions(plan, 0, n=3))


# ------------------------------ ChaosProxy --------------------------------

def _echo_server():
    srv = socket.create_server(("127.0.0.1", 0))
    host, port = srv.getsockname()

    def serve():
        while True:
            try:
                conn, _ = srv.accept()
            except OSError:
                return
            def pump(c):
                try:
                    while True:
                        d = c.recv(65536)
                        if not d:
                            return
                        c.sendall(d)
                except OSError:
                    pass
                finally:
                    c.close()
            threading.Thread(target=pump, args=(conn,),
                             daemon=True).start()
    threading.Thread(target=serve, daemon=True).start()
    return srv, host, port


def test_proxy_passthrough_preserves_bytes(tmp_path):
    srv, host, port = _echo_server()
    try:
        with ChaosProxy((host, port), seed=0) as px:
            c = socket.create_connection((px.host, px.port), timeout=5)
            payload = bytes(range(256)) * 16
            c.sendall(payload)
            got = b""
            while len(got) < len(payload):
                got += c.recv(65536)
            assert got == payload
            c.close()
            assert px.events == []        # nothing injected
            p = px.dump_artifact(str(tmp_path / "a.json"))
            art = json.load(open(p))
            assert art["connections"] == 1 and art["seed"] == 0
    finally:
        srv.close()


def test_proxy_scripted_corrupt_and_duplicate():
    srv, host, port = _echo_server()
    try:
        plan = FaultPlan(script={0: ("corrupt", 1234),
                                 1: ("duplicate", None)})
        with ChaosProxy((host, port), seed=0, c2s=plan) as px:
            c = socket.create_connection((px.host, px.port), timeout=5)
            c.sendall(b"A" * 64)          # chunk 0: corrupted
            got = c.recv(65536)
            assert len(got) == 64 and got != b"A" * 64
            c.sendall(b"B" * 8)           # chunk 1: duplicated
            got = b""
            deadline = time.monotonic() + 5
            while len(got) < 16 and time.monotonic() < deadline:
                got += c.recv(65536)
            assert got == b"B" * 16
            c.close()
        kinds = {e["fault"] for e in px.events}
        assert kinds == {"corrupt", "duplicate"}
    finally:
        srv.close()


def test_proxy_reset_mid_chunk_gives_connreset():
    srv, host, port = _echo_server()
    try:
        plan = FaultPlan(script={0: ("reset", None)})
        with ChaosProxy((host, port), seed=0, c2s=plan) as px:
            c = socket.create_connection((px.host, px.port), timeout=5)
            with pytest.raises(OSError):   # RST mid-frame, not clean FIN
                c.sendall(b"X" * (1 << 16))
                for _ in range(50):
                    if c.recv(65536) == b"":
                        raise ConnectionResetError("EOF after reset")
                    time.sleep(0.01)
            c.close()
        assert [e["fault"] for e in px.events] == ["reset"]
    finally:
        srv.close()


def test_proxy_quiesce_freezes_injection():
    srv, host, port = _echo_server()
    try:
        plan = FaultPlan(corrupt=1.0)
        with ChaosProxy((host, port), seed=0, c2s=plan) as px:
            px.quiesce()
            c = socket.create_connection((px.host, px.port), timeout=5)
            c.sendall(b"hello")
            assert c.recv(65536) == b"hello"
            c.close()
        assert px.events == []
    finally:
        srv.close()


# --------------------------- fabric frame auth ----------------------------

class _Canary:
    """Flips a module-level flag if its pickle is ever executed."""
    unpickled = False


def _trip_canary():
    _Canary.unpickled = True
    return "tripped"


class _Bomb:
    def __reduce__(self):
        return (_trip_canary, ())


def _framed(obj, key=None) -> bytes:
    buf = io.BytesIO()
    send_frame(buf, obj, key=key)
    return buf.getvalue()


def test_mac_roundtrip_and_wrong_key_rejected():
    raw = _framed({"op": "x", "n": 1}, key=b"k1")
    assert recv_frame(io.BytesIO(raw), key=b"k1") == {"op": "x", "n": 1}
    with pytest.raises(ProtocolError, match="MAC"):
        recv_frame(io.BytesIO(raw), key=b"k2")
    # an unauthenticated frame on an authenticated port is also refused
    plain = _framed({"op": "x"})
    with pytest.raises(ProtocolError, match="MAC|too short"):
        recv_frame(io.BytesIO(plain), key=b"k1")


def test_tampered_frame_rejected_before_unpickle(chaos_seed):
    _Canary.unpickled = False
    raw = _framed({"op": "x", "payload": _Bomb()}, key=b"secret")
    # flip one payload byte per drawn position: every tamper must die
    # at the MAC check, never in the unpickler
    import random
    rng = random.Random(chaos_seed)
    for _ in range(32):
        i = 8 + rng.randrange(len(raw) - 8)   # anywhere past the header
        bad = raw[:i] + bytes([raw[i] ^ 0xFF]) + raw[i + 1:]
        with pytest.raises(ProtocolError, match="MAC"):
            recv_frame(io.BytesIO(bad), key=b"secret")
    assert not _Canary.unpickled, \
        "tampered bytes reached pickle.loads before MAC verification"
    # the canary itself works: a valid frame does unpickle
    assert recv_frame(io.BytesIO(raw), key=b"secret")["payload"] == \
        "tripped"
    assert _Canary.unpickled


def test_short_frame_cannot_carry_mac():
    body = b"tiny"
    raw = struct.pack(">Q", len(body)) + body
    with pytest.raises(ProtocolError, match="too short"):
        recv_frame(io.BytesIO(raw), key=b"k")


def test_unauthenticated_corrupt_frame_is_protocol_error():
    raw = _framed({"op": "x"})
    bad = raw[:8] + bytes([raw[8] ^ 0xFF]) + raw[9:]  # break the opcode
    with pytest.raises(ProtocolError, match="undecodable"):
        recv_frame(io.BytesIO(bad))


def test_coordinator_rejects_bad_mac_frame_live(monkeypatch):
    """End to end: with REPRO_FABRIC_KEY set, a MAC-less canary frame
    sent at the live coordinator port is answered with an error and the
    canary never unpickles in the server."""
    monkeypatch.setenv("REPRO_FABRIC_KEY", "live-key")
    _Canary.unpickled = False
    with FabricCoordinator() as coord:
        sock = socket.create_connection((coord.host, coord.port),
                                        timeout=5)
        f = sock.makefile("rwb")
        body = pickle.dumps({"op": "hello", "node": "evil",
                             "x": _Bomb()})
        f.write(struct.pack(">Q", len(body)) + body)   # no MAC tag
        f.flush()
        resp = recv_frame(f)              # env key authenticates this
        assert resp["op"] == "error" and "MAC" in resp["detail"]
        assert f.read(1) == b""           # and the connection is closed
        sock.close()
    assert not _Canary.unpickled


# --------------------------- service helpers ------------------------------

N_HOSTS, MAX_TASKS, HORIZON = 3, 4, 5


def profile(**kw) -> Profile:
    return Profile(n_hosts=N_HOSTS, max_tasks=MAX_TASKS,
                   horizon=HORIZON, **kw)


def rand_mh(rng):
    return rng.random((N_HOSTS, features.HOST_FEATURES)) \
        .astype(np.float32)


def rand_mt(rng, q=3):
    m_t = np.zeros((MAX_TASKS, features.TASK_FEATURES), np.float32)
    m_t[:q] = rng.random((q, features.TASK_FEATURES))
    return m_t


def mk_snap(tenant, seq, m_h, m_t, q=3, job_id=1):
    tasks = [(100 + i, i % N_HOSTS, i) for i in range(q)]
    return wire.snapshot_to_wire(
        tenant, seq, m_h,
        jobs=[wire.job_to_wire(job_id, q, m_t, tasks=tasks)], done=[])


def _reference_run(m_hs, m_t, q):
    from repro.core.predictor import StragglerPredictor
    pred = StragglerPredictor(n_hosts=N_HOSTS, max_tasks=MAX_TASKS,
                              horizon=HORIZON)
    out = None
    for m_h in m_hs:
        pred.push_host_row(m_h)
        out = pred.predict_interval(
            m_t[None], np.array([float(q)], np.float32))
    return out


# --------------------------- service hardening ----------------------------

def test_service_client_timeout_is_applied():
    """Satellite 1: ``request(timeout=...)`` used to be silently
    ignored; against a stalled server it must now raise TimeoutError
    within the bound and drop the (desynced) connection."""
    srv = socket.create_server(("127.0.0.1", 0))
    host, port = srv.getsockname()
    conns = []
    threading.Thread(
        target=lambda: conns.append(srv.accept()),  # accept, never reply
        daemon=True).start()
    c = ServiceClient(host, port, "t0", retries=1)
    t0 = time.perf_counter()
    with pytest.raises(TimeoutError):
        c.request({"op": "stats"}, timeout=0.4)
    assert time.perf_counter() - t0 < 5.0
    assert c._file is None                # connection dropped, not reused
    c.close()
    srv.close()


def test_max_line_peer_answered_then_dropped():
    """Satellite 2: a peer that never sends a newline is answered with
    ``frame-too-long`` once the MAX_LINE cap trips, then disconnected —
    the JSON-lines mirror of the fabric's MAX_FRAME discipline."""
    with ServiceDaemon(ServiceConfig(profile=profile())) as d:
        sock = socket.create_connection(("127.0.0.1", d.port),
                                        timeout=10)
        sock.sendall(b"x" * (protocol.MAX_LINE + 16))
        f = sock.makefile("rb")
        resp = protocol.decode(f.readline())
        assert not resp["ok"] and resp["error"] == "frame-too-long"
        assert f.readline() == b""        # server dropped the connection
        sock.close()


def test_snapshot_resend_is_deduped_not_reapplied():
    svc = PredictionService(ServiceConfig(profile=profile()))
    c = LocalClient(svc, "t0")
    assert c.hello(profile())["ok"]
    rng = np.random.default_rng(3)
    snap = mk_snap("t0", 0, rand_mh(rng), rand_mt(rng))
    r1 = c.snapshot(snap)
    assert r1["ok"] and "resent" not in r1
    r2 = c.snapshot(snap)                 # client retried after a "loss"
    assert r2["ok"] and r2["resent"] is True
    assert r2["jobs"] == r1["jobs"]       # same cached answer
    st = svc.stats()
    assert st["snapshots"] == 1           # applied exactly once
    assert st["resends"] == 1
    # a later interval still flows normally
    assert c.snapshot(mk_snap("t0", 1, rand_mh(rng), rand_mt(rng)))["ok"]
    assert svc.stats()["snapshots"] == 2


def test_hello_token_auth(monkeypatch):
    monkeypatch.delenv("REPRO_SERVICE_TOKEN", raising=False)
    cfg = ServiceConfig(profile=profile(), auth_token="s3cret")
    with ServiceDaemon(cfg) as d:
        bad = ServiceClient("127.0.0.1", d.port, "t0", token="nope")
        r = bad.request({"op": "hello", "tenant": "t0",
                         "profile": profile().to_wire(),
                         "token": "nope"})
        assert not r["ok"] and r["error"] == "auth-failed"
        bad.close()
        good = ServiceClient("127.0.0.1", d.port, "t0", token="s3cret")
        assert good.hello(profile())["ok"]
        st = good.stats()
        assert st["auth_failures"] == 1 and st["tenants"] == 1
        good.bye()


def test_daemon_kill_restart_mid_stream(tmp_path):
    """Acceptance: a ServiceClient tenant survives a daemon stop +
    restart on the same port mid-stream — the client reconnects,
    replays its hello, resends the in-flight snapshot, and the restarted
    server applies each interval exactly once."""
    prof = profile()
    ckpt = str(tmp_path / "ckpt")
    d1 = ServiceDaemon(ServiceConfig(profile=prof,
                                     ckpt_dir=ckpt)).start()
    port = d1.port
    c = ServiceClient("127.0.0.1", port, "t0", retries=8,
                      backoff_s=0.05)
    assert c.hello(prof)["ok"]
    rng = np.random.default_rng(11)
    m_t = rand_mt(rng)
    m_hs = [rand_mh(rng) for _ in range(6)]
    for i in range(3):
        assert c.snapshot(mk_snap("t0", i, m_hs[i], m_t))["ok"]
    d1.stop()                             # daemon dies mid-stream
    d2 = None
    for _ in range(20):                   # rebinding the same port
        try:
            d2 = ServiceDaemon(ServiceConfig(profile=prof,
                                             ckpt_dir=ckpt),
                               port=port).start()
            break
        except OSError:
            time.sleep(0.1)
    assert d2 is not None, "could not rebind the daemon port"
    try:
        last = None
        for i in range(3, 6):             # client heals transparently
            last = c.snapshot(mk_snap("t0", i, m_hs[i], m_t))
            assert last["ok"], last
        # the restarted daemon admitted a fresh tenant on re-hello: its
        # answers must be bitwise those of a predictor fed exactly the
        # post-restart rows — nothing lost, nothing double-applied
        ref = _reference_run(m_hs[3:], m_t, 3)
        assert last["jobs"][0]["e_s"] == float(np.asarray(ref)[0])
        st = d2.service.stats()
        assert st["snapshots"] == 3
        c.bye()
    finally:
        d2.stop()


def test_service_chaos_smoke_state_never_corrupted(chaos_seed,
                                                   tmp_path):
    """Drive a tenant through the chaos proxy (reply corruption + RSTs),
    then quiesce and prove the server state is exactly what a clean run
    would have produced: every interval applied once, the final answer
    bitwise-equal to the reference predictor fed every row."""
    prof = profile()
    with ServiceDaemon(ServiceConfig(profile=prof)) as d:
        c2s = FaultPlan(reset=0.05, skip_first=2, max_faults=2)
        s2c = FaultPlan(corrupt=0.10, reset=0.05, skip_first=2,
                        max_faults=3)
        with ChaosProxy(("127.0.0.1", d.port), seed=chaos_seed,
                        c2s=c2s, s2c=s2c) as px:
            c = ServiceClient(px.host, px.port, "t0", retries=8,
                              backoff_s=0.05, timeout=5.0)
            assert c.hello(prof)["ok"]
            rng = np.random.default_rng(2)
            m_t = rand_mt(rng)
            m_hs = [rand_mh(rng) for _ in range(8)]
            for i, m_h in enumerate(m_hs[:-1]):
                r = None
                for _ in range(6):        # resends dedupe server-side
                    try:
                        r = c.snapshot(mk_snap("t0", i, m_h, m_t))
                    except (ConnectionError, TimeoutError):
                        continue
                    if isinstance(r, dict) and r.get("ok"):
                        break
                assert isinstance(r, dict) and r.get("ok"), r
            px.quiesce()                  # no more injection: assert
            r = c.snapshot(mk_snap("t0", len(m_hs) - 1, m_hs[-1], m_t))
            assert r["ok"]
            ref = _reference_run(m_hs, m_t, 3)
            assert r["jobs"][0]["e_s"] == float(np.asarray(ref)[0])
            st = d.service.stats()
            assert st["snapshots"] == len(m_hs), \
                "an interval was lost or double-applied under chaos"
            px.dump_artifact(_artifact_path(
                tmp_path, f"service-smoke-seed{chaos_seed}.json"))
            c.bye()


# --------------------------- VersionStore recovery ------------------------

def _tree(v: float):
    return {"w": np.full((3, 3), v, np.float32),
            "b": np.arange(3, dtype=np.float32)}


def test_version_store_recovers_from_torn_pointer(tmp_path):
    path = str(tmp_path / "store")
    vs = VersionStore(path)
    for v in (0, 1, 2):
        vs.save_version(v, _tree(float(v)))
    vs.promote(0)
    vs.promote(1)
    cur = os.path.join(path, "CURRENT")
    # torn write: pointer truncated mid-json
    with open(cur, "w") as f:
        f.write('{"current": 1, "hist')
    vs2 = VersionStore(path)
    assert vs2.current() == 2             # newest intact version wins
    loaded = vs2.load_version(vs2.current(), _tree(0.0))
    np.testing.assert_array_equal(loaded["w"], _tree(2.0)["w"])
    # garbage pointer + newest version torn: fall back one further
    with open(cur, "w") as f:
        f.write("\x00\xff not json")
    with open(os.path.join(path, "step_00000002",
                           "manifest.json"), "w") as f:
        f.write("{broken")
    assert VersionStore(path).current() == 1
    # a read never persists the recovered pointer; promote rewrites it
    vs3 = VersionStore(path)
    vs3.promote(1)
    assert json.load(open(cur))["current"] == 1


def test_version_store_recovery_with_no_intact_versions(tmp_path):
    path = str(tmp_path / "empty")
    vs = VersionStore(path)
    with open(os.path.join(path, "CURRENT"), "w") as f:
        f.write("")                       # zero-length torn pointer
    assert vs.current() is None
    assert vs.history() == []


def test_version_store_recovery_rejects_torn_leaf(tmp_path):
    path = str(tmp_path / "store")
    vs = VersionStore(path)
    vs.save_version(0, _tree(0.0))
    vs.save_version(1, _tree(1.0))
    vs.promote(0)
    # version 1's leaf loses its .npy header (torn at the block layer)
    leaf = os.path.join(path, "step_00000001", "leaf_00000.npy")
    with open(leaf, "wb") as f:
        f.write(b"\x00\x01\x02")
    with open(os.path.join(path, "CURRENT"), "w") as f:
        f.write("garbage")
    assert VersionStore(path).current() == 0


def test_service_restart_survives_torn_pointer(tmp_path):
    """End to end: the daemon's VersionStore pointer is torn between
    runs; the restarted service still comes up serving (not degraded)
    on the newest intact version."""
    prof = profile()
    ckpt = str(tmp_path / "ckpt")
    svc = PredictionService(ServiceConfig(profile=prof, ckpt_dir=ckpt))
    assert svc.model_version == 0 and not svc.degraded
    with open(os.path.join(ckpt, "CURRENT"), "w") as f:
        f.write('{"curr')                 # torn mid-write
    svc2 = PredictionService(ServiceConfig(profile=prof, ckpt_dir=ckpt))
    assert not svc2.degraded and svc2.model_version == 0


# --------------------------- headline fabric drill ------------------------

def _drill_spec() -> SweepSpec:
    return SweepSpec(techniques=("none", "sgc"),
                     scenarios=("planetlab", "fault-storm"),
                     seeds=(0, 1, 2, 3, 4, 5), n_hosts=10,
                     n_intervals=20, arrival_rate=0.8, max_workers=1)


def _spawn_via(host, port, n):
    ctx = multiprocessing.get_context("spawn")
    procs = [ctx.Process(target=worker_main, args=(host, port),
                         kwargs=dict(node=f"chaos{i}", lanes=1),
                         daemon=True)
             for i in range(n)]
    for p in procs:
        p.start()
    return procs


def _reap(procs, timeout=120):
    for p in procs:
        p.join(timeout=timeout)
        if p.is_alive():
            p.kill()
            p.join(timeout=5)


@pytest.mark.slow
def test_fabric_chaos_drill_bitwise_equals_serial(chaos_seed, tmp_path,
                                                  monkeypatch):
    """Acceptance drill: a 2-node 24-cell grid through the chaos proxy
    with authenticated frames — scripted frame corruption (MAC-rejected
    before unpickling), a mid-frame RST, a stall longer than the lease
    (reclaim of a live node), and one node SIGKILLed mid-unit — still
    returns bitwise-identical summaries to serial ``run()``."""
    spec = _drill_spec()
    assert len(spec.cells()) >= 24
    serial = run(spec)                    # chaos env not armed yet
    marker = tmp_path / "killed-once"
    monkeypatch.setenv("REPRO_TEST_KILL_CELL",
                       f"fault-storm:sgc:1:{marker}")
    monkeypatch.setenv("REPRO_FABRIC_KEY", f"drill-{chaos_seed}")
    c2s = FaultPlan(corrupt=0.01, skip_first=4, max_faults=2,
                    script={5: ("corrupt", 1234), 9: ("reset", None)},
                    stall_after=12, stall_s=5.0)
    s2c = FaultPlan(corrupt=0.01, skip_first=4, max_faults=2,
                    script={6: ("corrupt", 999)})
    with FabricCoordinator(lease_s=3.0) as coord:
        with ChaosProxy((coord.host, coord.port), seed=chaos_seed,
                        c2s=c2s, s2c=s2c) as px:
            procs = _spawn_via(px.host, px.port, 2)
            try:
                res = run(spec, fabric=coord)
            finally:
                _reap(procs)
            px.dump_artifact(_artifact_path(
                tmp_path, f"fabric-drill-seed{chaos_seed}.json"))
    assert marker.exists(), "the SIGKILL drill never fired"
    assert any(p.exitcode not in (0, None) for p in procs), \
        "no node actually died"
    kinds = {e["fault"] for e in px.events}
    assert {"corrupt", "reset", "stall"} <= kinds, kinds
    assert [(c.scenario, c.technique, c.seed) for c in res.cells] == \
        spec.cells()
    for a, b in zip(serial.cells, res.cells):
        assert _det(a.summary) == _det(b.summary), (a.scenario,
                                                    a.technique, a.seed)
