"""Unified policy API tests: telemetry snapshots, the shared action
vocabulary, the self-describing registry (including third-party policies
flowing end-to-end through a sweep), the determinism regression over the
technique port, and a cloud baseline running on the pod substrate."""
import json
import os

import numpy as np
import pytest

from repro import policy
from repro.policy import Action, ActionKind, Policy
from repro.sim import Simulation, engine as E, scenarios, small, sweep
from repro.sim.techniques.start_tech import START

HERE = os.path.dirname(os.path.abspath(__file__))
SRC = os.path.join(os.path.dirname(HERE), "src", "repro")


# --------------------------- action vocabulary ------------------------------

def test_action_vocabulary_is_unified():
    from repro.distributed import straggler_runtime as rt
    # one Action type across substrates; SimAction is an alias of it
    assert E.SimAction is Action
    a = E.SimAction("clone", 3, n_clones=2)
    assert a.task == 3 and a.n_clones == 2
    # str-enum: engine-style string comparisons keep working
    assert ActionKind.SPECULATE == "speculate"
    assert ActionKind("backup_shard") is ActionKind.BACKUP_SHARD
    # the distributed runtime's HostAction constructor builds Actions
    h = rt.HostAction(ActionKind.BACKUP_SHARD, 2, backup=0)
    assert isinstance(h, Action)
    assert h.host == 2 and h.backup == 0 and h.target == 0


def test_sim_ignores_host_vocabulary_actions():
    class PodSpeaker(E.Technique):
        name = "pod-speaker"

        def on_interval(self):
            return [policy.host_action(ActionKind.EVICT, 0)]

    sim = Simulation(small(n_hosts=8, n_intervals=10),
                     technique=PodSpeaker())
    s = sim.run()  # must not crash; EVICT has no task semantics
    assert s["tasks_done"] >= 0


# ----------------------------- telemetry view -------------------------------

def test_snapshot_is_zero_copy_and_readonly():
    sim = Simulation(small(n_hosts=8, n_intervals=12))
    sim.run()
    v = sim.snapshot()
    # zero-copy: views share memory with the engine's live buffers
    assert np.shares_memory(v.tasks.state, sim.tasks.state)
    assert np.shares_memory(v.hosts.util, sim.cluster.util)
    assert np.shares_memory(v.tasks.req, sim.tasks.req)
    # ...but policies cannot write through them
    with pytest.raises(ValueError):
        v.tasks.progress[0] = 1e9
    with pytest.raises(ValueError):
        v.hosts.util[0, 0] = 2.0
    # derived quantities agree with the engine's own
    np.testing.assert_array_equal(v.hosts.effective_speed(),
                                  sim.cluster.effective_speed())
    np.testing.assert_array_equal(v.hosts.online(), sim.cluster.online())
    assert v.n_hosts == sim.cfg.n_hosts
    assert v.t == sim.t and v.now_s == sim.now_s


def test_snapshot_job_index_matches_engine():
    sim = Simulation(small(n_hosts=8, n_intervals=20))
    sim.run()
    v = sim.snapshot()
    np.testing.assert_array_equal(v.jobs.active(), sim.active_jobs())
    assert v.jobs.n_jobs == sim.jobs.n
    for job in v.jobs.active():
        job = int(job)
        np.testing.assert_array_equal(v.jobs.incomplete_tasks(job),
                                      sim.job_incomplete_tasks(job))
        np.testing.assert_array_equal(v.jobs.task_ids(job),
                                      sim.jobs.task_ids(job))


def test_no_engine_internals_in_policy_modules():
    """Acceptance: no module under sim/techniques or distributed reaches
    into ``sim.tasks`` / ``sim.cluster`` — policies consume only
    repro.policy types."""
    roots = [os.path.join(SRC, "sim", "techniques"),
             os.path.join(SRC, "distributed")]
    offenders = []
    for root in roots:
        for dirpath, _, files in os.walk(root):
            for f in files:
                if not f.endswith(".py"):
                    continue
                path = os.path.join(dirpath, f)
                with open(path) as fh:
                    src = fh.read()
                if "sim.tasks." in src or "sim.cluster." in src:
                    offenders.append(path)
    assert not offenders, offenders


# ------------------------------- registry -----------------------------------

def test_unknown_technique_error_lists_registered_names():
    from repro.sim import techniques
    with pytest.raises(ValueError, match="start"):
        techniques.make("bogus")
    with pytest.raises(ValueError, match="registered techniques"):
        policy.make("not-a-technique")
    assert issubclass(policy.UnknownPolicyError, ValueError)


def test_sweepspec_fails_fast_on_unknown_names():
    with pytest.raises(ValueError, match="wrangler"):
        sweep.SweepSpec(techniques=("none", "wranglr"))
    with pytest.raises(KeyError):
        sweep.SweepSpec(scenarios=("planet-lab",))
    # pod-only policies are rejected for simulator sweeps
    import repro.distributed.straggler_runtime  # noqa: F401  (registers)
    with pytest.raises(ValueError, match="substrate"):
        sweep.SweepSpec(techniques=("start-pod",))


def test_registry_entries_are_self_describing():
    from repro.sim import techniques  # noqa: F401  (registers built-ins)
    start = policy.get("start")
    assert start.pretrain is not None
    assert start.pretrain.epochs_knob == "pretrain_epochs"
    assert policy.get("igru-sd").pretrain.epochs_knob == "igru_epochs"
    assert policy.get("wrangler").pretrain.epochs_knob is None
    assert policy.get("none").pretrain is None
    assert "pod" in policy.get("igru-sd").substrates
    for name in ("start", "igru-sd", "wrangler", "none"):
        assert policy.get(name).description


# ------------------- third-party policies, end to end -----------------------

@policy.register("test-tail-clone",
                 description="test-only: clone the first task of every job")
class TailClone(Policy):
    """Minimal third-party policy: acts at submit time only."""

    def __init__(self):
        self.cloned = 0

    def decide(self, view):
        if view.event != policy.EVENT_SUBMIT:
            return []
        acts = []
        seen = set()
        for i in view.new_tasks:
            j = int(view.tasks.job_id[i])
            if j not in seen:
                seen.add(j)
                acts.append(Action("clone", int(i), n_clones=1))
                self.cloned += 1
        return acts


@policy.register("test-thresh", epochs_knob="pretrain_epochs",
                 description="test-only: pretrained threshold policy")
class ThresholdPolicy(Policy):
    """Minimal Pretrainable policy: learns a scalar from the warmup."""

    def __init__(self, threshold=None, epochs=None):
        self.threshold = threshold
        self.epochs = epochs

    @classmethod
    def pretrain(cls, ctx):
        warm = ctx.warmup()   # finished warmup run's TelemetryView
        times = np.concatenate([r["times"] for r in warm.completed_jobs])
        return cls(threshold=float(np.median(times)), epochs=ctx.epochs)

    def decide(self, view):
        if view.event != policy.EVENT_INTERVAL or self.threshold is None:
            return []
        tt = view.tasks
        acts = []
        for i in np.nonzero(tt.active_mask())[0][:2]:
            if view.now_s - tt.start_s[i] > 4 * self.threshold:
                acts.append(Action("rerun", int(i)))
        return acts


def test_custom_policy_flows_through_sweep_end_to_end():
    spec = sweep.SweepSpec(techniques=("none", "test-tail-clone"),
                           seeds=(0,), scenarios=("planetlab",),
                           n_hosts=8, n_intervals=15, arrival_rate=0.8,
                           max_workers=1)
    res = sweep.run(spec)
    assert res.cell("planetlab", "test-tail-clone", 0) \
              .summary["tasks_done"] > 0
    # the policy's actions actually execute: clones exist in a direct run
    cfg = spec.cell_config("planetlab", 0)
    sim = Simulation(cfg, technique=policy.make("test-tail-clone"))
    sim.run()
    assert sim.tasks.view("is_copy").sum() > 0


def test_custom_pretrainable_policy_uses_shared_cache():
    cfg = small(n_hosts=10, n_intervals=20)
    t1 = sweep.make_technique("test-thresh", cfg, pretrain_epochs=3)
    t2 = sweep.make_technique("test-thresh", cfg, pretrain_epochs=3)
    assert t1 is not t2                     # fresh instance per cell
    assert t1.threshold == t2.threshold     # from the cached pretrain
    assert t1.threshold > 0
    assert t1.epochs == 3                   # knob reached the context
    # and the full sweep path runs it
    res = sweep.run(sweep.SweepSpec(
        techniques=("test-thresh",), seeds=(0,), scenarios=("planetlab",),
        n_hosts=10, n_intervals=20, arrival_rate=0.8, max_workers=1,
        pretrain_epochs=3))
    assert res.cells[0].summary["tasks_done"] > 0


@policy.register("test-custom-knob", epochs_knob="my_epochs",
                 description="test-only: custom epochs knob")
class CustomKnobPolicy(Policy):
    def __init__(self, epochs=None):
        self.epochs = epochs

    @classmethod
    def pretrain(cls, ctx):
        return cls(epochs=ctx.epochs)


def test_custom_epochs_knob_is_explicit_not_silently_dropped():
    cfg = small(n_hosts=8, n_intervals=10)
    # undeclared knob: loud error pointing at pretrain_knobs, not a
    # silent ctx.epochs=None
    with pytest.raises(ValueError, match="my_epochs"):
        sweep.make_technique("test-custom-knob", cfg)
    t = sweep.make_technique("test-custom-knob", cfg,
                             extra_knobs={"my_epochs": 11})
    assert t.epochs == 11
    # and through the declarative spec
    res = sweep.run(sweep.SweepSpec(
        techniques=("test-custom-knob",), seeds=(0,),
        scenarios=("planetlab",), n_hosts=8, n_intervals=10,
        arrival_rate=0.8, max_workers=1,
        pretrain_knobs={"my_epochs": 7}))
    assert res.cells[0].summary["tasks_done"] >= 0


# ------------------------ determinism regression ----------------------------

GOLDEN = os.path.join(HERE, "data", "determinism_golden.json")


def test_all_techniques_match_golden_summaries_on_all_scenarios():
    """Determinism regression over the full registered technique field x
    every scenario: each cell must reproduce the blessed deterministic
    summary bitwise.  The fixture embeds its own grid (``_grid``), which
    this test replays verbatim, so checking and blessing can never drift
    — an intentional behavior change is re-blessed by running
    ``benchmarks/regen_golden.py`` and committing the fixture diff."""
    with open(GOLDEN) as f:
        golden = json.load(f)
    grid = {k: (tuple(v) if isinstance(v, list) else v)
            for k, v in golden["_grid"].items()}
    spec = sweep.SweepSpec(max_workers=1, **grid)
    cells = golden["cells"]
    assert len(cells) == len(spec.cells())
    # the fixture covers every technique currently registered for the
    # simulator that ships with the package (test-registered policies in
    # this module are exempt)
    shipped = [n for n in policy.names("sim") if not n.startswith("test-")]
    assert sorted(shipped) == sorted(spec.techniques)
    assert sorted(spec.scenarios) == sorted(scenarios.names())
    for sc, name, seed in spec.cells():
        want = cells[f"{sc}|{name}|{seed}"]
        got = sweep.deterministic_summary(
            sweep.run_cell(spec, sc, name, seed).summary)
        assert got == want, (sc, name)


# ------------------------- START margin parameter ---------------------------

def test_start_benefit_margin_scales_with_utilization():
    st = START(margin_lo=-0.4, margin_hi=0.6)
    st._util = 0.0          # idle: optimistic speculation ...
    assert st.benefit_margin("speculate") == pytest.approx(-0.4)
    # ... but reruns never go optimistic (they forfeit progress)
    assert st.benefit_margin("rerun") == pytest.approx(0.1)
    st._util = 1.0          # saturated: strictly conservative
    assert st.benefit_margin("speculate") == pytest.approx(0.6)
    assert st.benefit_margin("rerun") == pytest.approx(0.6)
    st._util = 0.5
    assert st.benefit_margin("speculate") == pytest.approx(0.1)
    # a pinned margin applies to both kinds (the legacy fixed guard)
    pinned = START(margin=0.25)
    assert pinned.benefit_margin("speculate") == pytest.approx(0.25)
    assert pinned.benefit_margin("rerun") == pytest.approx(0.25)


def test_start_observes_task_attributable_utilization():
    sim = Simulation(small(n_hosts=8, n_intervals=10,
                           reserved_utilization=0.5))
    sim.run()
    st = START()
    st.observe(sim.snapshot())
    raw = float(np.clip(sim.cluster.util[:, 0].mean(), 0.0, 1.0))
    # the static reserved floor is subtracted: the guard responds to the
    # load mitigation competes with, not to reserved capacity
    assert st._util == pytest.approx(max(raw - 0.5, 0.0))
    assert raw >= 0.5
    # and the adaptive k tracks it within [k_lo, k_hi]
    assert st.k_lo <= st.controller.predictor.k <= st.k_hi


# --------------------- cloud baseline on the pod substrate ------------------

def test_igru_sd_runs_on_pod_substrate():
    """Acceptance: a cloud baseline (IGRU-SD) runs on the distributed
    training substrate through the unified API — its speculate actions
    translate to backup shards for the chronically slow host."""
    from repro.distributed.straggler_runtime import (
        RuntimeConfig, StragglerRuntime, backup_mask, pretrain_igru_pod)
    from repro.sim.techniques.baselines import IGRUSD

    rng = np.random.default_rng(0)
    n = 8

    def step_times():
        t = 1.0 + 0.05 * rng.pareto(2.0, n)
        t[3] *= 2.5   # host 3 is chronically slow
        return t

    warm = StragglerRuntime(RuntimeConfig(n_hosts=n))
    for _ in range(15):
        warm.observe_step(step_times())
    tech = IGRUSD(seed=0)
    pretrain_igru_pod(tech, warm, epochs=150)

    rt = StragglerRuntime(RuntimeConfig(n_hosts=n), policy=tech)
    backups = []
    for _ in range(18):
        rt.observe_step(step_times())
        for a in rt.decide():
            assert a.kind is ActionKind.BACKUP_SHARD
            backups.append(a)
    assert backups, "IGRU-SD never fired on the pod"
    assert {a.host for a in backups} == {3}
    assert all(a.backup != a.host for a in backups)
    # a CHRONIC straggler is re-mitigated across horizon windows (the
    # runtime retires per-task policy state at every window boundary,
    # so once-only flags don't silence it forever) ...
    assert len(backups) >= 2
    # ... and per-task history stays bounded (last HIST entries only)
    assert max(len(h) for h in tech.hist.values()) <= IGRUSD.HIST
    # the translated actions drive the gradient combine mask as usual
    on_time = np.ones(n, bool)
    on_time[3] = False
    w = backup_mask(n, backups, on_time)
    assert w[3] == 0.0 and w.sum() == n - 1
