"""Encoder-LSTM straggler-prediction network (paper §3.2, Fig. 4) in pure JAX.

Architecture (faithful to the paper):
  - Encoder: 4 fully-connected layers, softplus activations:
        input(|M_H| + |M_T|) -> 128 -> 128 -> 32
    (the first "layer" in the paper is the input layer with softplus applied;
    we apply softplus after each of the four affine maps).
  - LSTM: 2 layers, hidden size 32. eta_0 = 0.
  - Head: FC(2); alpha = relu(o0) + 1 (so the Pareto mean exists),
    beta = relu(o1) + BETA_EPS (strictly positive scale).
  - Inputs are EMA-smoothed with weight EMA_W = 0.8 on the newest matrices
    (paper cites [36]); the cell is iterated every I seconds for T seconds.

Params are plain dict pytrees; everything is jit/vmap-friendly. The fused
Pallas kernel in ``repro.kernels.lstm_cell`` implements the same cell; tests
assert exact agreement with ``lstm_cell_apply`` below.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

EMA_W = 0.8          # weight of the *latest* resource matrix (paper §3.2)
BETA_EPS = 1e-3      # strictly-positive Pareto scale
ENC_HIDDEN = 128
ENC_OUT = 32
LSTM_HIDDEN = 32
LSTM_LAYERS = 2

Params = dict  # pytree


def _dense_init(key, n_in, n_out, scale=None):
    scale = scale if scale is not None else (1.0 / jnp.sqrt(n_in))
    wkey, _ = jax.random.split(key)
    return {
        "w": jax.random.normal(wkey, (n_in, n_out), jnp.float32) * scale,
        "b": jnp.zeros((n_out,), jnp.float32),
    }


def _lstm_init(key, n_in, hidden):
    k1, k2 = jax.random.split(key)
    s_in = 1.0 / jnp.sqrt(n_in)
    s_h = 1.0 / jnp.sqrt(hidden)
    return {
        # gates packed as [i, f, g, o] along the last dim
        "wx": jax.random.normal(k1, (n_in, 4 * hidden), jnp.float32) * s_in,
        "wh": jax.random.normal(k2, (hidden, 4 * hidden), jnp.float32) * s_h,
        "b": jnp.zeros((4 * hidden,), jnp.float32),
    }


def init_params(key: jax.Array, input_dim: int,
                enc_hidden: int = ENC_HIDDEN, enc_out: int = ENC_OUT,
                lstm_hidden: int = LSTM_HIDDEN,
                lstm_layers: int = LSTM_LAYERS) -> Params:
    keys = jax.random.split(key, 4 + lstm_layers + 1)
    enc = [
        _dense_init(keys[0], input_dim, enc_hidden),
        _dense_init(keys[1], enc_hidden, enc_hidden),
        _dense_init(keys[2], enc_hidden, enc_hidden),
        _dense_init(keys[3], enc_hidden, enc_out),
    ]
    lstm = []
    n_in = enc_out
    for i in range(lstm_layers):
        lstm.append(_lstm_init(keys[4 + i], n_in, lstm_hidden))
        n_in = lstm_hidden
    head = _dense_init(keys[4 + lstm_layers], lstm_hidden, 2)
    return {"enc": enc, "lstm": lstm, "head": head}


def encoder_apply(params: Params, x: jax.Array) -> jax.Array:
    """4-layer softplus MLP (paper's Encoder network)."""
    h = x
    for layer in params["enc"]:
        h = jax.nn.softplus(h @ layer["w"] + layer["b"])
    return h


def lstm_cell_apply(layer: Params, h: jax.Array, c: jax.Array,
                    x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """One LSTM cell step; gates packed [i, f, g, o]."""
    z = x @ layer["wx"] + h @ layer["wh"] + layer["b"]
    i, f, g, o = jnp.split(z, 4, axis=-1)
    c_new = jax.nn.sigmoid(f) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
    h_new = jax.nn.sigmoid(o) * jnp.tanh(c_new)
    return h_new, c_new


def _cell_apply(layer: Params, h: jax.Array, c: jax.Array, x: jax.Array,
                use_pallas: bool = False) -> tuple[jax.Array, jax.Array]:
    """Dispatch one cell step to the jnp cell or the fused Pallas kernel
    (``repro.kernels.lstm_cell``; exact-match tested against
    :func:`lstm_cell_apply`)."""
    if not use_pallas:
        return lstm_cell_apply(layer, h, c, x)
    from repro.kernels.lstm_cell import lstm_cell
    batch = h.shape[:-1]
    hid = h.shape[-1]
    h2, c2 = lstm_cell(x.reshape(-1, x.shape[-1]), h.reshape(-1, hid),
                       c.reshape(-1, hid), layer["wx"], layer["wh"],
                       layer["b"])
    return h2.reshape(*batch, hid), c2.reshape(*batch, hid)


class LSTMState(NamedTuple):
    h: jax.Array  # (layers, ..., hidden)
    c: jax.Array


def init_state(params: Params, batch_shape: tuple = ()) -> LSTMState:
    layers = len(params["lstm"])
    hidden = params["lstm"][0]["wh"].shape[0]
    z = jnp.zeros((layers, *batch_shape, hidden), jnp.float32)
    return LSTMState(h=z, c=z)


def step_decoded(params: Params, state: LSTMState, lam: jax.Array,
                 use_pallas: bool = False) -> tuple[LSTMState, jax.Array]:
    """LSTM + head over an already-encoded input (the recurrent half of
    :func:`step`).  Factored out so Tier-1 callers can hoist the encoder
    out of the scan entirely (:func:`decode_sequence`) — the op graph
    here is byte-identical to the tail of the historical ``step``."""
    hs, cs = [], []
    inp = lam
    for li, layer in enumerate(params["lstm"]):
        h_new, c_new = _cell_apply(layer, state.h[li], state.c[li], inp,
                                   use_pallas=use_pallas)
        hs.append(h_new)
        cs.append(c_new)
        inp = h_new
    new_state = LSTMState(h=jnp.stack(hs), c=jnp.stack(cs))
    out = inp @ params["head"]["w"] + params["head"]["b"]
    # positivity head: the paper uses ReLU (+1 on alpha); we use softplus —
    # same constraint, but a ReLU alpha-head that initializes negative is
    # DEAD (alpha pinned to 1.0 -> E_S ~ 0 -> START never mitigates).
    # Deviation noted in DESIGN.md.
    alpha = jax.nn.softplus(out[..., 0]) + 1.0
    beta = jax.nn.softplus(out[..., 1]) + BETA_EPS
    return new_state, jnp.stack([alpha, beta], axis=-1)


def step(params: Params, state: LSTMState, x: jax.Array,
         use_pallas: bool = False) -> tuple[LSTMState, jax.Array]:
    """One inference step: encoder -> stacked LSTM -> (alpha, beta) head."""
    return step_decoded(params, state, encoder_apply(params, x),
                        use_pallas=use_pallas)


def ema_smooth(seq: jax.Array, w: float = EMA_W) -> jax.Array:
    """Exponential moving average along axis 0 with weight w on the newest
    element (paper §3.2): s_t = w*x_t + (1-w)*s_{t-1}, s_0 = x_0."""

    def f(carry, x):
        s = w * x + (1.0 - w) * carry
        return s, s

    _, out = jax.lax.scan(f, seq[0], seq)
    return out.at[0].set(seq[0])


# --------------------------- Tier-1 fast path ------------------------------
#
# The functions below restructure the emission for speed and are governed
# by the repo's Tier-1 determinism contract (documented relative/ulp
# tolerance vs the bitwise reference path; see README "Performance" and
# tests/tolerance.py).  ``predict_sequence`` below stays the bitwise
# Tier-0-compatible reference — do not restructure it.


def encoder_hoisted(params: Params, mh_ema: jax.Array,
                    mt: jax.Array) -> jax.Array:
    """Encoder over a (T, host_dim) shared host block + (nb, task_dim)
    per-job task block, hoisted out of the recurrent scan.

    Two restructurings relative to ``encoder_apply`` over the assembled
    (T, nb, input_dim) batch, both Tier-1 (ulp-level drift, never
    bitwise-pinned):

      * the first layer's matmul is split at the host/task column
        boundary — the shared host product ``mh_ema @ W[:host_dim]`` is
        computed once per step instead of once per job (host_dim
        dominates input_dim for real cluster sizes), and the task
        product once per job instead of once per (step, job).  Summing
        two partial dots changes the reduction order of the full-width
        dot by a few ulps.
      * ``mt`` is used raw instead of EMA-smoothed: the task block is
        constant across the horizon, and the EMA of a constant sequence
        is the constant itself (s_t = w*x + (1-w)*x = x, exactly in
        real arithmetic, within 1 ulp in float32).

    Returns the (T, nb, ENC_OUT) encodings for :func:`decode_sequence`.
    """
    l0 = params["enc"][0]
    host_dim = mh_ema.shape[-1]
    lam_h = mh_ema @ l0["w"][:host_dim]             # (T, E) — once per step
    lam_t = mt @ l0["w"][host_dim:] + l0["b"]       # (nb, E) — once per job
    h = jax.nn.softplus(lam_h[:, None, :] + lam_t[None, :, :])
    for layer in params["enc"][1:]:
        h = jax.nn.softplus(h @ layer["w"] + layer["b"])
    return h


def decode_sequence(params: Params, lam: jax.Array, unroll: int = 1,
                    use_pallas: bool = False) -> jax.Array:
    """Scan the LSTM + head over precomputed (T, ..., ENC_OUT) encodings.

    ``unroll`` forwards to ``lax.scan`` — unrolling the (tiny, typically
    T=5) emission loop lets XLA fuse across steps instead of paying the
    while-loop machinery per step.  Different unroll factors compile
    different fusions whose rounding may differ by ulps: Tier-1.
    Callers embed this in their own jitted programs (it is not jitted
    here), so each (shape, unroll) pair is one cache entry there.
    """
    state = init_state(params, lam.shape[1:-1])

    def f(state, x):
        return step_decoded(params, state, x, use_pallas=use_pallas)

    _, outs = jax.lax.scan(f, state, lam, unroll=unroll)
    return outs[-1]


@functools.partial(jax.jit, static_argnames=("unroll", "use_pallas"))
def predict_sequence_opt(params: Params, xs: jax.Array, unroll: int = 1,
                         use_pallas: bool = False) -> jax.Array:
    """Tier-1 twin of :func:`predict_sequence` for callers whose host
    blocks vary per row (the multi-tenant serving batch): the encoder
    runs batched over the whole (T, nb) grid — one matmul chain instead
    of one per scan step — and the LSTM scan unrolls.  No host/task
    split (rows carry different host blocks), so the only drift sources
    are batched-encoder fusion and ``unroll``."""
    xs = ema_smooth(xs)
    lam = xs
    for layer in params["enc"]:
        lam = jax.nn.softplus(lam @ layer["w"] + layer["b"])
    return decode_sequence(params, lam, unroll=unroll,
                           use_pallas=use_pallas)


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def predict_sequence(params: Params, xs: jax.Array,
                     use_pallas: bool = False) -> jax.Array:
    """Run the net over a (T, ..., input_dim) EMA-smoothed feature sequence.

    Returns the final-step (alpha, beta), shape (..., 2). This is the paper's
    "send matrices for T seconds every I seconds; read (alpha, beta) at the
    end" loop, with T = xs.shape[0] steps.

    Compiles once per (shape, use_pallas) signature — callers in the
    simulator hot path pad the batch axis to power-of-two buckets
    (``repro.core.predictor``) so the compile count is bounded by the
    bucket set, not the number of distinct job counts.
    """
    xs = ema_smooth(xs)
    batch_shape = xs.shape[1:-1]
    state = init_state(params, batch_shape)

    def f(state, x):
        state, out = step(params, state, x, use_pallas=use_pallas)
        return state, out

    _, outs = jax.lax.scan(f, state, xs)
    return outs[-1]


# ------------------------------- training ---------------------------------


def mse_loss(params: Params, xs: jax.Array, targets: jax.Array,
             use_pallas: bool = False) -> jax.Array:
    """MSE between predicted (alpha, beta) and MLE-fitted targets (paper §4.4:
    'trained using Mean-Square-Error Loss between the values based on the
    predicted distribution and the actual data')."""
    pred = predict_sequence(params, xs, use_pallas=use_pallas)
    return jnp.mean((pred - targets) ** 2)


class AdamState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


def adam_init(params: Params) -> AdamState:
    z = jax.tree_util.tree_map(jnp.zeros_like, params)
    return AdamState(step=jnp.zeros((), jnp.int32), mu=z,
                     nu=jax.tree_util.tree_map(jnp.zeros_like, params))


def adam_update(params: Params, grads: Params, state: AdamState,
                lr: float = 1e-5, b1: float = 0.9, b2: float = 0.999,
                eps: float = 1e-8) -> tuple[Params, AdamState]:
    """Adam (paper §4.4 uses Adam with lr 1e-5)."""
    t = state.step + 1
    mu = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g,
                                state.mu, grads)
    nu = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g,
                                state.nu, grads)
    tf = t.astype(jnp.float32)
    bc1 = 1 - b1 ** tf
    bc2 = 1 - b2 ** tf
    params = jax.tree_util.tree_map(
        lambda p, m, v: p - lr * (m / bc1) / (jnp.sqrt(v / bc2) + eps),
        params, mu, nu)
    return params, AdamState(step=t, mu=mu, nu=nu)


@functools.partial(jax.jit, static_argnames=("lr", "use_pallas"))
def train_step(params: Params, opt: AdamState, xs: jax.Array,
               targets: jax.Array, lr: float = 1e-5,
               use_pallas: bool = False
               ) -> tuple[Params, AdamState, jax.Array]:
    loss, grads = jax.value_and_grad(mse_loss)(params, xs, targets,
                                               use_pallas)
    params, opt = adam_update(params, grads, opt, lr=lr)
    return params, opt, loss
