"""Pareto distribution model of task execution times (paper §3.1, Eqs. 1-5).

Task execution times X_1..X_q of a job are modelled as Pareto(alpha, beta):
    F_X(x) = 1 - (x/beta)^(-alpha)   for x >= beta,   else 0.

MLE (Eqs. 2-3):  beta = min_i X_i,   alpha = q / (sum_i log X_i - q log beta).

Straggler threshold (paper keeps it a multiple of the Pareto mean):
    K = k * alpha * beta / (alpha - 1),     k = 1.5 by default.

Expected number of stragglers (Eq. 4):  E_S = q * (K / beta)^(-alpha).

All functions are pure jnp, jit-able, and batched variants support padded
task arrays via masks (the paper pads jobs with q < q' tasks with zero rows).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

DEFAULT_K = 1.5  # paper §3.1: empirically best F1 (Fig. 2)
_EPS = 1e-8
_ALPHA_MIN = 1.0 + 1e-3  # mean of Pareto only defined for alpha > 1
_ALPHA_MAX = 1e4


def pareto_cdf(x: jax.Array, alpha: jax.Array, beta: jax.Array) -> jax.Array:
    """Eq. 1. CDF of Pareto(alpha, beta)."""
    x = jnp.asarray(x)
    safe = jnp.maximum(x, beta)
    cdf = 1.0 - (safe / beta) ** (-alpha)
    return jnp.where(x >= beta, cdf, 0.0)


def pareto_mean(alpha: jax.Array, beta: jax.Array) -> jax.Array:
    """Mean of Pareto(alpha, beta); defined for alpha > 1."""
    return alpha * beta / (alpha - 1.0)


def pareto_quantile(alpha: jax.Array, beta: jax.Array,
                    q: jax.Array) -> jax.Array:
    """Inverse CDF: the time by which a fraction ``q`` of tasks complete.

    F^{-1}(q) = beta * (1 - q)^(-1/alpha).  This is the fork-point clock
    of the replication-timing policies (Wang et al.): "launch replicas
    once a fraction p of the job is done" happens, in distribution, at
    the p-quantile of the fitted execution-time tail.
    """
    q = jnp.clip(jnp.asarray(q), 0.0, 1.0 - _EPS)
    return beta * (1.0 - q) ** (-1.0 / alpha)


def pareto_quantile_np(alpha, beta, q):
    """NumPy twin of :func:`pareto_quantile` for per-interval hot loops."""
    q = np.clip(np.asarray(q, np.float64), 0.0, 1.0 - _EPS)
    return beta * (1.0 - q) ** (-1.0 / alpha)


def sample_pareto(key: jax.Array, alpha: jax.Array, beta: jax.Array,
                  shape: tuple) -> jax.Array:
    """Inverse-CDF sampling: X = beta * U^(-1/alpha)."""
    u = jax.random.uniform(key, shape, minval=_EPS, maxval=1.0)
    return beta * u ** (-1.0 / alpha)


def fit_pareto(times: jax.Array, mask: jax.Array | None = None
               ) -> tuple[jax.Array, jax.Array]:
    """MLE fit of (alpha, beta) from task times (Eq. 3).

    Args:
        times: (..., q) positive task execution times. Padded entries allowed
            when ``mask`` marks them 0.
        mask: optional (..., q) in {0,1}; 1 = real task.

    Returns:
        (alpha, beta) with shapes (...,). alpha clipped to
        [1+1e-3, 1e4] so the distribution mean exists (paper adds +1 to the
        network's alpha output for the same reason).
    """
    times = jnp.asarray(times, jnp.float32)
    if mask is None:
        mask = jnp.ones_like(times)
    mask = mask.astype(jnp.float32)
    q = jnp.maximum(mask.sum(-1), 1.0)
    # beta = min over real tasks (paper: largest beta s.t. X_i >= beta)
    big = jnp.where(mask > 0, times, jnp.inf)
    beta = jnp.clip(jnp.min(big, axis=-1), _EPS, None)
    logs = jnp.where(mask > 0, jnp.log(jnp.maximum(times, _EPS)), 0.0)
    denom = logs.sum(-1) - q * jnp.log(beta)
    alpha = q / jnp.maximum(denom, _EPS)
    return jnp.clip(alpha, _ALPHA_MIN, _ALPHA_MAX), beta


def fit_pareto_np(times, mask=None):
    """NumPy twin of ``fit_pareto`` for per-job hot loops.

    The simulator fits thousands of tiny (q = 2-10) jobs per run; routing
    those through jnp pays an XLA compile per distinct shape plus device
    dispatch per op. Same float32 formula, returns numpy scalars/arrays.
    """
    t = np.asarray(times, np.float32)
    if mask is None:
        m = np.ones_like(t)
    else:
        m = np.asarray(mask, np.float32)
    q = np.maximum(m.sum(-1), np.float32(1.0))
    big = np.where(m > 0, t, np.float32(np.inf))
    beta = np.clip(big.min(axis=-1), _EPS, None).astype(np.float32)
    logs = np.where(m > 0, np.log(np.maximum(t, np.float32(_EPS))),
                    np.float32(0.0))
    denom = logs.sum(-1) - q * np.log(beta)
    alpha = q / np.maximum(denom, np.float32(_EPS))
    return np.clip(alpha, _ALPHA_MIN, _ALPHA_MAX), beta


def straggler_threshold_np(alpha, beta, k: float = DEFAULT_K):
    """NumPy twin of ``straggler_threshold``."""
    return k * alpha * beta / (alpha - 1.0)


def straggler_threshold(alpha: jax.Array, beta: jax.Array,
                        k: float = DEFAULT_K) -> jax.Array:
    """K = k * mean = k * alpha*beta/(alpha-1)  (paper §3.1)."""
    return k * pareto_mean(alpha, beta)


def expected_stragglers(q: jax.Array, alpha: jax.Array, beta: jax.Array,
                        k: float = DEFAULT_K) -> jax.Array:
    """E_S = q * (K/beta)^(-alpha)  (Eq. 4).

    Note K/beta = k*alpha/(alpha-1) is beta-free: the *count* of expected
    stragglers depends only on the tail index; beta sets the scale of K.
    """
    kk = straggler_threshold(alpha, beta, k) / beta
    return q * kk ** (-alpha)


def straggler_labels(times: jax.Array, alpha: jax.Array, beta: jax.Array,
                     k: float = DEFAULT_K) -> jax.Array:
    """Ground-truth straggler flags: completion time > K (paper §3.1)."""
    kthr = straggler_threshold(alpha, beta, k)
    return (times > kthr[..., None]).astype(jnp.float32)


def f1_score_paper(tp: jax.Array, fp: jax.Array) -> jax.Array:
    """Eq. 5 as literally printed: tp / (tp + 0.5*(fp + tp)).

    The paper counts correct class labels as tp and incorrect as fp (so fp
    absorbs fn); its Eq. 5 is the standard F1 with that convention.
    """
    return tp / jnp.maximum(tp + 0.5 * (fp + tp), _EPS)


def f1_score(pred: jax.Array, truth: jax.Array,
             mask: jax.Array | None = None) -> jax.Array:
    """Standard binary F1 over (possibly masked) flags, used for Fig. 2."""
    if mask is None:
        mask = jnp.ones_like(pred)
    pred = pred.astype(jnp.float32) * mask
    truth = truth.astype(jnp.float32) * mask
    tp = (pred * truth).sum()
    fp = (pred * (1 - truth) * mask).sum()
    fn = ((1 - pred) * mask * truth).sum()
    return tp / jnp.maximum(tp + 0.5 * (fp + fn), _EPS)


def pareto_nll(times: jax.Array, alpha: jax.Array, beta: jax.Array,
               mask: jax.Array | None = None) -> jax.Array:
    """Negative log-likelihood (Eq. 2, negated, masked mean).

    Used as an alternative (differentiable in alpha) training target and in
    property tests: MLE from ``fit_pareto`` must minimize this.
    """
    times = jnp.asarray(times, jnp.float32)
    if mask is None:
        mask = jnp.ones_like(times)
    mask = mask.astype(jnp.float32)
    q = jnp.maximum(mask.sum(-1), 1.0)
    logs = jnp.where(mask > 0, jnp.log(jnp.maximum(times, _EPS)), 0.0).sum(-1)
    ll = q * jnp.log(alpha) + q * alpha * jnp.log(beta) - (alpha + 1.0) * logs
    return -(ll / q)
