"""START core: Pareto straggler model + Encoder-LSTM predictor + mitigation."""
from repro.core import encoder_lstm, features, mitigation, pareto
from repro.core.predictor import Prediction, StragglerPredictor
from repro.core.start import JobView, STARTController

__all__ = [
    "encoder_lstm", "features", "mitigation", "pareto",
    "Prediction", "StragglerPredictor", "JobView", "STARTController",
]
