"""Feature extractor (paper §3.2, Fig. 3): host matrix M_H and task matrix M_T.

Host features (m = 11 per host): utilization and capacity of CPU, RAM, disk
and network bandwidth, plus cost, (max) power and the number of tasks
currently allocated — exactly the set listed in the paper.

Task features (p = 5 per task): CPU, RAM, disk and bandwidth *requirements*
plus the host assigned in the previous interval (normalized index; -1 -> 0
for unassigned). Jobs with q < q' tasks are padded with zero rows (paper:
"if less than q' tasks then rest q'-q rows are 0").
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

HOST_FEATURES = 11
TASK_FEATURES = 5


def host_matrix(util: jax.Array, cap: jax.Array, cost: jax.Array,
                power_max: jax.Array, n_tasks: jax.Array) -> jax.Array:
    """Build M_H.

    Args:
        util: (n, 4) utilization in [0,1] for cpu/ram/disk/bw.
        cap:  (n, 4) capacities (absolute units).
        cost: (n,) price per interval.
        power_max: (n,) watts at full load.
        n_tasks: (n,) tasks currently placed on each host.

    Returns: (n, HOST_FEATURES) float32, capacities normalized per column.
    """
    cap = jnp.asarray(cap, jnp.float32)
    cap_n = cap / jnp.maximum(cap.max(axis=0, keepdims=True), 1e-8)
    cost = jnp.asarray(cost, jnp.float32)
    cost_n = cost / jnp.maximum(cost.max(), 1e-8)
    p = jnp.asarray(power_max, jnp.float32)
    p_n = p / jnp.maximum(p.max(), 1e-8)
    nt = jnp.asarray(n_tasks, jnp.float32)
    nt_n = nt / jnp.maximum(nt.max(), 1.0)
    return jnp.concatenate(
        [jnp.asarray(util, jnp.float32), cap_n,
         cost_n[:, None], p_n[:, None], nt_n[:, None]], axis=-1)


def host_matrix_np(util: np.ndarray, cap: np.ndarray, cost: np.ndarray,
                   power_max: np.ndarray, n_tasks: np.ndarray) -> np.ndarray:
    """NumPy twin of :func:`host_matrix` for the simulator's per-interval
    hot path: bitwise-identical float32 arithmetic (every op is an exact
    IEEE elementwise op or reduction), no per-call XLA dispatch."""
    util = np.asarray(util, np.float32)
    cap = np.asarray(cap, np.float32)
    cap_n = cap / np.maximum(cap.max(axis=0, keepdims=True),
                             np.float32(1e-8))
    cost = np.asarray(cost, np.float32)
    cost_n = cost / np.maximum(cost.max(), np.float32(1e-8))
    p = np.asarray(power_max, np.float32)
    p_n = p / np.maximum(p.max(), np.float32(1e-8))
    nt = np.asarray(n_tasks, np.float32)
    nt_n = nt / np.maximum(nt.max(), np.float32(1.0))
    return np.concatenate(
        [util, cap_n, cost_n[:, None], p_n[:, None], nt_n[:, None]],
        axis=-1)


def task_matrix_batch_np(req: np.ndarray, prev_host: np.ndarray,
                         rows: np.ndarray, cols: np.ndarray, n_jobs: int,
                         n_hosts: int, max_tasks: int) -> np.ndarray:
    """Batched NumPy twin of :func:`task_matrix`: one scatter builds every
    job's (max_tasks, TASK_FEATURES) matrix.

    Args:
        req: (total_tasks, 4) requirement rows, all jobs concatenated.
        prev_host: (total_tasks,) previous-interval host per row, -1 none.
        rows: (total_tasks,) destination job index of each row.
        cols: (total_tasks,) destination row within the job (0..q-1).
        n_jobs: number of output matrices.
        n_hosts, max_tasks: normalization / padding as in `task_matrix`.
    """
    mt = np.zeros((n_jobs, max_tasks, TASK_FEATURES), np.float32)
    if len(rows):
        mt[rows, cols, :4] = np.asarray(req, np.float32)
        mt[rows, cols, 4] = ((np.asarray(prev_host, np.float32)
                              + np.float32(1.0)) / np.float32(n_hosts))
    return mt


def task_matrix(req: jax.Array, prev_host: jax.Array, n_hosts: int,
                max_tasks: int) -> jax.Array:
    """Build M_T for one job, padded to (max_tasks, TASK_FEATURES).

    Args:
        req: (q, 4) resource requirements (cpu/ram/disk/bw) in [0,1].
        prev_host: (q,) host index of the previous interval, -1 if none.
        n_hosts: for normalizing the host index.
        max_tasks: q' — pad rows beyond q with zeros.
    """
    req = jnp.asarray(req, jnp.float32)
    q = req.shape[0]
    ph = (jnp.asarray(prev_host, jnp.float32) + 1.0) / float(n_hosts)
    mt = jnp.concatenate([req, ph[:, None]], axis=-1)
    pad = max(0, max_tasks - q)
    mt = jnp.pad(mt, ((0, pad), (0, 0)))[:max_tasks]
    return mt


def flatten_inputs(m_h: jax.Array, m_t: jax.Array) -> jax.Array:
    """Flatten + concatenate (M_H, M_T) into the encoder input vector.

    Supports leading batch/time dims on either matrix as long as they match.
    """
    lead_h = m_h.shape[:-2]
    lead_t = m_t.shape[:-2]
    assert lead_h == lead_t, (lead_h, lead_t)
    h = m_h.reshape(*lead_h, -1)
    t = m_t.reshape(*lead_t, -1)
    return jnp.concatenate([h, t], axis=-1)


def input_dim(n_hosts: int, max_tasks: int) -> int:
    return n_hosts * HOST_FEATURES + max_tasks * TASK_FEATURES
