"""Straggler Mitigation (paper §3.3 + Algorithm 1).

Two strategies:
  * SPECULATE — run a copy of the task on a separate node, first result wins
    (for deadline-driven jobs).
  * RERUN — kill and restart the task on a new node (non-deadline jobs).

Target-node selection: "the new node that has the lowest moving average of
the number of straggler tasks for the current time-step" (§3.3). Cloning is
deliberately not implemented (paper: too much overhead at scale [40]).
"""
from __future__ import annotations

import dataclasses
import enum

import numpy as np


class Kind(enum.Enum):
    SPECULATE = "speculate"
    RERUN = "rerun"


@dataclasses.dataclass(frozen=True)
class Action:
    job_id: int
    task_id: int
    kind: Kind
    target_host: int
    source_host: int


class StragglerMovingAverage:
    """Per-host exponential moving average of observed straggler counts."""

    def __init__(self, n_hosts: int, decay: float = 0.8):
        self.ma = np.zeros(n_hosts, np.float64)
        self.decay = decay

    def update(self, counts: np.ndarray) -> None:
        self.ma = self.decay * self.ma + (1.0 - self.decay) * np.asarray(
            counts, np.float64)

    def pick_targets(self, n: int, exclude: set[int] | None = None,
                     load: np.ndarray | None = None) -> list[int]:
        """Lowest-MA hosts first; ties broken by current load then index."""
        exclude = exclude or set()
        order = sorted(
            (i for i in range(len(self.ma)) if i not in exclude),
            key=lambda i: (self.ma[i],
                           float(load[i]) if load is not None else 0.0, i))
        if not order:
            order = list(range(len(self.ma)))
        return [order[i % len(order)] for i in range(n)]


def plan_mitigation(job_id: int, task_ids: list[int], task_hosts: list[int],
                    deadline_oriented: bool, ma: StragglerMovingAverage,
                    load: np.ndarray | None = None) -> list[Action]:
    """Algorithm 1 lines 26-32: mitigate the remaining tasks of a job.

    Deadline-oriented jobs get SPECULATE; others RERUN. Each task goes to a
    distinct low-straggler host when possible, avoiding its current host.
    """
    kind = Kind.SPECULATE if deadline_oriented else Kind.RERUN
    actions = []
    targets = ma.pick_targets(len(task_ids), exclude=set(task_hosts),
                              load=load)
    for t, (tid, src) in enumerate(zip(task_ids, task_hosts)):
        actions.append(Action(job_id=job_id, task_id=tid, kind=kind,
                              target_host=targets[t], source_host=src))
    return actions
