"""Straggler Prediction module (paper Fig. 1 / Fig. 4): Encoder-LSTM -> Pareto.

Ties together feature extraction, the Encoder-LSTM network and the Pareto
expected-straggler computation, and owns network training (MSE against
MLE-fitted (alpha, beta) targets — paper §4.4).

Inference is shape-disciplined: ``predict_features`` pads the job batch to
a power-of-two bucket before entering the jitted network, so a sweep cell
compiles **once per bucket size**, never once per active-job count (the
silent-retrace failure mode: every new job count is a new batch shape and
a full XLA retrace).  ``buckets_used`` records the bucket set for
retrace-accounting tests and benchmarks.

The per-interval hot path is the **fused step** (``_fused_step``): the
M_H history lives in a device-resident ring buffer that is rolled
*inside* a single donated-buffer jitted program which also assembles the
feature batch on device, runs the Encoder-LSTM and reduces straight to
E_S (the Pareto tail included).  A warm interval therefore uploads one
small packed staging vector (new M_H row + M_T batch + q + scalars) and
downloads one (bucket,) E_S vector — the full history matrix never
crosses the host/device boundary again, and the ~10 small eager
dispatches of the historical path collapse into one.

Determinism is **tiered** (see README "Performance"):

  * Tier-0 (bitwise): the engine, sweep serial == parallel, and the
    golden determinism fixture.  The *unfused* path here
    (``predict_features`` -> ``predict_sequence`` -> ``_pareto_tail``)
    is the bitwise reference the fixture was blessed against and is
    never restructured.
  * Tier-1 (tolerance-bounded): the fused step and the serving batch
    path.  They restructure the emission for speed — encoder hoisted
    out of the scan with the shared host block encoded once per step
    (``net.encoder_hoisted``), the scan unrolled (``unroll``), the
    Pareto tail fused into the same program, exact-shape batches — and
    agree with the reference within the documented bound in
    ``tests/tolerance.py`` at every shape (tested by shape sweep).
    Every Tier-1 path is still fully deterministic run-to-run on one
    machine; only cross-path bitwise equality is relaxed.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import encoder_lstm as net
from repro.core import features, pareto


class Prediction(NamedTuple):
    alpha: jax.Array      # (...,)
    beta: jax.Array       # (...,)
    threshold: jax.Array  # K  (...,)
    e_s: jax.Array        # expected straggler count (...,)


def bucket_size(n: int) -> int:
    """Smallest power of two >= n (the jit batch-shape bucket)."""
    return max(1 << (int(n) - 1).bit_length(), 1) if n else 1


# staged uploads ride the pjit fast path (see StragglerPredictor._stage)
_stage_put = jax.jit(lambda x: x)


# --------------------------- fused interval step ---------------------------
#
# Packed staging layout (one float32 vector, one host->device transfer per
# interval): [k, beta_scale, new_mh_row(host_dim), q(nb), m_t(nb*task_dim)].
_N_SCALARS = 2


@functools.partial(jax.jit, donate_argnums=(1,),
                   static_argnames=("nb", "task_dim", "use_pallas",
                                    "per_task", "unroll"))
def _fused_step(params, ring, packed, *, nb: int, task_dim: int,
                use_pallas: bool = False, per_task: bool = False,
                unroll: int = 1):
    """One whole START decision step as a single device program (Tier-1).

    Rolls the donated M_H ring buffer by the staged row, then runs the
    restructured emission the tiered determinism contract unblocked:

      * the encoder is hoisted out of the recurrent scan and the shared
        host block is encoded once per step instead of once per (step,
        job) (``net.encoder_hoisted`` — the task block's constant-EMA
        is dropped there too);
      * the LSTM scan unrolls by the static ``unroll`` factor
        (autotuned per bucket via
        :meth:`StragglerPredictor.autotune_unroll`);
      * the Pareto tail — and with ``per_task=True`` the per-task score
        decomposition — is fused INTO this program, so a warm interval
        is exactly one dispatch and one readback (historically the tail
        was a second dispatch, split out to preserve bitwiseness).

    Each restructuring shifts float rounding by ulps at some shapes, so
    the program agrees with the unfused reference within the documented
    Tier-1 bound (tests/tolerance.py) rather than bitwise; it is still
    fully deterministic for a fixed (shape, unroll, platform).

    Returns ``(new_ring, e_s)`` — or ``(new_ring, packed_out)`` with
    ``packed_out = [E_S | per-task scores]`` of shape
    ``(nb, 1 + max_tasks)`` when ``per_task`` (same packing as
    :func:`_pareto_tail_per_task`).
    """
    host_dim = ring.shape[1]
    k = packed[0]
    beta_scale = packed[1]
    row = packed[_N_SCALARS:_N_SCALARS + host_dim]
    q = packed[_N_SCALARS + host_dim:_N_SCALARS + host_dim + nb]
    mt = packed[_N_SCALARS + host_dim + nb:].reshape(nb, task_dim)
    ring2 = jnp.concatenate([ring[1:], row[None]], axis=0)
    mh_ema = net.ema_smooth(ring2)                        # (T, host_dim)
    lam = net.encoder_hoisted(params, mh_ema, mt)         # (T, nb, E)
    ab = net.decode_sequence(params, lam, unroll=unroll,
                             use_pallas=use_pallas)
    alpha = ab[..., 0]
    beta = ab[..., 1] * beta_scale
    thr = k * (alpha * beta / (alpha - 1.0))
    kk = thr / beta
    e_s = q * kk ** (-alpha)
    if per_task:
        max_tasks = task_dim // features.TASK_FEATURES
        mt3 = mt.reshape(nb, max_tasks, features.TASK_FEATURES)
        demand = mt3[..., :4].sum(axis=-1)              # (nb, max_tasks)
        total = demand.sum(axis=-1, keepdims=True)
        real = jnp.arange(max_tasks)[None, :] < q[:, None]
        uniform = real / jnp.maximum(q, 1.0)[:, None]
        share = jnp.where(total > 0.0,
                          demand / jnp.where(total > 0.0, total, 1.0),
                          uniform)
        scores = e_s[:, None] * share
        return ring2, jnp.concatenate([e_s[:, None], scores], axis=1)
    return ring2, e_s


@functools.partial(jax.jit, donate_argnums=(0,))
def _ring_roll(ring, row):
    """Catch-up roll for intervals that observed hosts but ran no predict
    (idle intervals): absorb one pending M_H row into the device ring."""
    return jnp.concatenate([ring[1:], row[None]], axis=0)


def fused_compile_count() -> int:
    """Cumulative XLA compiles of the fused-step programs (process-wide):
    the fused step itself (Pareto tail and per-task head now live inside
    it), the ring catch-up roll, the serving batch path's optimized
    sequence program, and the unfused per-task tail — the zero-retrace
    warm accounting covers every Tier-1 entry point."""
    return (_fused_step._cache_size() + _ring_roll._cache_size()
            + net.predict_sequence_opt._cache_size()
            + _pareto_tail_per_task._cache_size())


@jax.jit
def _pareto_tail(ab: jax.Array, q: jax.Array, k: jax.Array,
                 beta_scale: jax.Array):
    """(alpha, beta) head outputs -> (alpha, beta, K, E_S), fused.

    Kept op-for-op identical to the historical eager chain
    (``straggler_threshold`` + ``expected_stragglers``) so results are
    bitwise-stable; jitting it replaces ~10 per-interval eager dispatches
    (each a compile per batch bucket) with one cached call.
    """
    alpha = ab[..., 0]
    beta = ab[..., 1] * beta_scale
    thr = k * (alpha * beta / (alpha - 1.0))
    kk = thr / beta
    e_s = q * kk ** (-alpha)
    return alpha, beta, thr, e_s


@jax.jit
def _pareto_tail_per_task(ab: jax.Array, q: jax.Array, k: jax.Array,
                          beta_scale: jax.Array, mt: jax.Array):
    """Per-task score tail: (alpha, beta) head + the (nb, task_dim) M_T
    batch -> one packed (nb, 1 + max_tasks) array ``[E_S | scores]``.

    The per-task straggler score decomposes the job-level expected
    straggler count across the job's M_T rows by relative resource
    demand: ``score[j, i] = E_S_j * demand_ji / sum_i demand_ji`` (the
    four requirement columns; the prev-host column is placement, not
    demand).  Scores over a job's real tasks sum exactly to E_S_j —
    with homogeneous demand each task scores the per-task straggler
    probability ``(K/beta)^(-alpha)`` — and zero-padded slots (demand
    0) score 0.  Jobs whose every task reports zero demand fall back to
    a uniform ``E_S / q`` split over their first q slots.

    One jitted program, one packed output: the fused warm path stays a
    single dispatch plus a single readback with the per-task head
    enabled.  Kept separate from ``_pareto_tail`` so the legacy
    E_S-only path keeps its exact cache entry.
    """
    alpha = ab[..., 0]
    beta = ab[..., 1] * beta_scale
    thr = k * (alpha * beta / (alpha - 1.0))
    kk = thr / beta
    e_s = q * kk ** (-alpha)
    nb = mt.shape[0]
    max_tasks = mt.shape[1] // features.TASK_FEATURES
    mt3 = mt.reshape(nb, max_tasks, features.TASK_FEATURES)
    demand = mt3[..., :4].sum(axis=-1)                  # (nb, max_tasks)
    total = demand.sum(axis=-1, keepdims=True)
    real = jnp.arange(max_tasks)[None, :] < q[:, None]  # unpadded slots
    uniform = real / jnp.maximum(q, 1.0)[:, None]
    share = jnp.where(total > 0.0, demand / jnp.where(total > 0.0, total,
                                                      1.0), uniform)
    scores = e_s[:, None] * share
    return jnp.concatenate([e_s[:, None], scores], axis=1)


@dataclasses.dataclass
class StragglerPredictor:
    """Owns Encoder-LSTM params + the (I, T, k) hyper-parameters.

    ``horizon`` is T/I — the number of LSTM iterations per prediction
    (paper: I = 1 s, T = 5 s -> 5 steps).
    """

    n_hosts: int
    max_tasks: int
    k: float = pareto.DEFAULT_K
    horizon: int = 5
    interval: float = 1.0
    seed: int = 0
    # beta (the Pareto scale, in seconds) is regressed in units of
    # beta_scale so the MSE loss is O(1); alpha is O(1) already
    beta_scale: float = 1.0
    # route the LSTM cell through the fused Pallas kernel
    # (repro.kernels.lstm_cell); exact-match tested against the jnp cell.
    # Applies to inference AND training (fit routes train_step through the
    # same cell; gradients exact-match the reference — tested).
    use_pallas_cell: bool = False
    # ----- Tier-1 knobs (fused step + serving batch path only) -----
    #: ``lax.scan`` unroll factor for the emission loop.  ``None`` = auto
    #: (full unroll while the horizon is small — deterministic, no
    #: timing involved); per-bucket autotuned overrides land in
    #: ``_unroll_for_bucket`` via :meth:`autotune_unroll`.
    unroll: int | None = None
    #: skip power-of-two padding when the padded bucket would waste more
    #: than this fraction of its rows (0.44 of a 16-bucket for a 9-job
    #: batch); 1.0 disables exact shapes entirely.
    exact_shape_waste: float = 0.25
    #: at most this many distinct exact shapes ever compile — once spent,
    #: new job counts fall back to their power-of-two bucket, so the
    #: steady-state compile count stays bounded by
    #: ``len(buckets) + exact_shape_budget`` however long the process
    #: serves (the retrace guarantee the padding existed for).
    exact_shape_budget: int = 8

    def __post_init__(self):
        self.input_dim = features.input_dim(self.n_hosts, self.max_tasks)
        self.host_dim = self.n_hosts * features.HOST_FEATURES
        self.task_dim = self.max_tasks * features.TASK_FEATURES
        self._exact_shapes: set[int] = set()
        self._unroll_for_bucket: dict[int, int] = {}
        # params live on device for their whole lifetime — predictions
        # upload only the per-interval feature batch
        self.params = jax.device_put(
            net.init_params(jax.random.PRNGKey(self.seed), self.input_dim))
        self.opt = net.adam_init(self.params)
        self._losses: list[float] = []
        self.buckets_used: set[int] = set()
        self._init_fused_state()

    # ----------------------- fused interval hot path -----------------------

    def _init_fused_state(self) -> None:
        import collections
        self._ring = None          # device-resident (horizon, host_dim) M_H
        self._ring_rows = 0        # host rows the ring has absorbed
        self._host_rows = 0        # host rows observed so far
        #: host-side copy of the last ``horizon`` rows — the source of
        #: truth the device ring is rebuilt from (cold start, unpickling,
        #: error recovery)
        self._row_hist = collections.deque(maxlen=self.horizon)
        self._stage_bufs: dict[int, np.ndarray] = {}  # per-bucket staging
        self._scalar_cache = None  # device (k, beta_scale) for serving
        self.h2d_stages = 0        # host->device staging uploads performed

    def __getstate__(self):
        # the device ring is a pure cache of `_row_hist`; drop it so
        # pickled predictors (the sweep's pretrain broadcast) carry no
        # live device buffers — the clone rebuilds on first predict
        d = dict(self.__dict__)
        d["_ring"] = None
        d["_ring_rows"] = 0
        d["_stage_bufs"] = {}
        d["_scalar_cache"] = None
        return d

    def push_host_row(self, m_h: np.ndarray) -> None:
        """Feed one observed host matrix into the fused ring (called every
        interval; the device ring absorbs rows lazily at predict time)."""
        self._row_hist.append(
            np.ascontiguousarray(m_h, np.float32).reshape(-1))
        self._host_rows += 1

    def _stage(self, arr: np.ndarray) -> jax.Array:
        """The fused path's single sanctioned host->device upload per warm
        interval.  Centralised so the zero-transfer test can (a) count
        staging events and (b) wrap this one call in a scoped
        ``jax.transfer_guard_host_to_device('allow')`` while pinning the
        rest of the interval under ``'disallow'`` — the guard context is
        deliberately NOT entered here in production: it costs ~0.2 ms per
        entry, an order of magnitude more than the upload itself.

        The upload goes through a jitted identity rather than
        ``jax.device_put``: the transfer itself is identical (and happens
        here, inside the sanctioned scope, at dispatch), but the pjit C++
        fast path skips ~0.1 ms of Python ``device_put`` API overhead per
        interval on this container — pure dispatch cost, zero numeric
        difference.  The identity compiles once per staged shape, which
        only ever happens alongside the fused step's own per-bucket
        compile, so warm retrace accounting is unaffected."""
        self.h2d_stages += 1
        return _stage_put(arr)

    # ------------------------- Tier-1 batch shaping ------------------------

    def batch_size(self, n: int) -> int:
        """The batch axis the jitted programs see for ``n`` real jobs.

        Power-of-two bucketing keeps the compile count bounded; when the
        bucket would waste more than ``exact_shape_waste`` of its rows
        the exact count is used instead — up to ``exact_shape_budget``
        distinct exact shapes, after which new counts pad again (a
        long-lived process must not compile without bound).  Decisions
        are a pure function of the call sequence, so replaying a
        workload replays the shapes — serial == parallel sweeps and
        warm-cell zero-retrace accounting survive."""
        n = int(n)
        nb = bucket_size(n)
        if n and nb > n and (nb - n) / nb > self.exact_shape_waste:
            if n in self._exact_shapes \
                    or len(self._exact_shapes) < self.exact_shape_budget:
                self._exact_shapes.add(n)
                return n
        return nb

    def _unroll(self, nb: int) -> int:
        """Scan-unroll factor for a batch bucket: the autotuned choice
        when :meth:`autotune_unroll` recorded one, else the ``unroll``
        knob, else 2 — measured fastest across the small-batch range on
        CPU (unroll=1 pays scan while-loop machinery per step; full
        unroll at T=5 inflates the program enough that dispatch gets
        slower, not faster).  The default is a fixed constant, never
        timing-derived, so every process runs identical programs."""
        u = self._unroll_for_bucket.get(nb)
        if u:
            return u
        if self.unroll:
            return int(self.unroll)
        return min(2, self.horizon)

    def autotune_unroll(self, buckets=None, candidates=(1, 2, 0),
                        repeats: int = 10) -> dict[int, int]:
        """Time the fused step per bucket across unroll candidates and pin
        the fastest (0 in ``candidates`` means "full horizon").

        Meant for warmup (benchmarks, the serving daemon's bring-up):
        each (bucket, unroll) pair compiles once here, so steady state
        pays nothing new.  The choice is stored per bucket in
        ``_unroll_for_bucket`` — plain host state that survives
        pickling, so a pretrained technique broadcast to sweep workers
        carries its tuning and every process runs identical programs
        (numerics depend on the unroll factor, Tier-1)."""
        import time as _time
        buckets = sorted(buckets or self.buckets_used or
                         {1, 4, 16})
        cands = [self.horizon if c == 0 else int(c) for c in candidates]
        rng = np.random.default_rng(0)
        for nb in buckets:
            size = _N_SCALARS + self.host_dim + nb * (1 + self.task_dim)
            packed = rng.uniform(0.1, 1.0, size).astype(np.float32)
            packed[0], packed[1] = self.k, self.beta_scale
            best, best_t = None, None
            for u in dict.fromkeys(cands):
                ring = jax.device_put(np.zeros(
                    (self.horizon, self.host_dim), np.float32))
                out = None
                ts = []
                for _ in range(repeats + 1):
                    t0 = _time.perf_counter()
                    ring, out = _fused_step(
                        self.params, ring, jax.device_put(packed),
                        nb=nb, task_dim=self.task_dim,
                        use_pallas=self.use_pallas_cell, unroll=u)
                    jax.block_until_ready(out)
                    ts.append(_time.perf_counter() - t0)
                med = float(np.median(ts[1:]))  # drop the compile call
                if best_t is None or med < best_t:
                    best, best_t = u, med
            self._unroll_for_bucket[nb] = best
        return dict(self._unroll_for_bucket)

    @property
    def fused_ready(self) -> bool:
        """True when a fresh (unconsumed) host row is staged — the fused
        step rolls exactly one new row per call, so a second predict in
        the same interval must take the unfused path instead."""
        return self._host_rows > self._ring_rows

    def _sync_ring(self) -> np.ndarray:
        """Absorb unconsumed host rows into the device ring, leaving
        exactly one (the newest) for the fused step itself to roll in.
        Returns that last row.  Rebuilds from the host history (one
        upload) when the ring is cold, was dropped by pickling, or fell
        behind by a full horizon."""
        t = self.horizon
        lag = self._host_rows - self._ring_rows
        if lag <= 0 or not self._row_hist:
            raise RuntimeError("no fresh host row to predict from")
        rows = list(self._row_hist)
        if self._ring is None or lag > len(rows):
            # cold start / fell behind: rebuild the ring at "all but the
            # newest row", replaying the host deque's
            # left-pad-with-oldest semantics
            hist = rows[:-1] or rows[:1]
            while len(hist) < t:
                hist.insert(0, hist[0])
            self._ring = self._stage(np.stack(hist[-t:]))
        else:
            # roll in every lagging row but the newest (idle-interval
            # catch-up; the common warm interval has exactly one).  The
            # ring is donated into each roll, so detach it first: if a
            # roll fails mid-way the attribute is None and the next call
            # rebuilds instead of re-using a donated-invalid buffer.
            ring, self._ring = self._ring, None
            for row in rows[-lag:-1]:
                ring = _ring_roll(ring, self._stage(row))
            self._ring = ring
        self._ring_rows = self._host_rows - 1
        return rows[-1]

    def predict_interval(self, m_t: np.ndarray, q: np.ndarray,
                         per_task: bool = False):
        """Fused per-interval prediction (Tier-1): one staged upload, ONE
        jitted device program — Pareto tail included — one download.

        Args:
            m_t: (n, max_tasks, TASK_FEATURES) current task matrices.
            q: (n,) true task counts.
            per_task: also compute the per-task straggler scores.
                Returns ``(e_s, scores)`` with ``scores`` of shape
                ``(n, max_tasks)`` from the fused program's packed
                ``[E_S | scores]`` output; still one staged upload, one
                dispatch and one readback — the zero-H2D guarantee is
                unchanged.
        """
        n = m_t.shape[0]
        nb = self.batch_size(n)
        self.buckets_used.add(nb)
        row = self._sync_ring()
        host_dim = self.host_dim
        task_dim = self.task_dim
        size = _N_SCALARS + host_dim + nb * (1 + task_dim)
        buf = self._stage_bufs.get(nb)
        if buf is None or buf.shape[0] != size:
            buf = self._stage_bufs[nb] = np.zeros(size, np.float32)
        buf[0] = np.float32(self.k)
        buf[1] = np.float32(self.beta_scale)
        buf[_N_SCALARS:_N_SCALARS + host_dim] = row
        qs = buf[_N_SCALARS + host_dim:_N_SCALARS + host_dim + nb]
        qs[:n] = np.asarray(q, np.float32)
        qs[n:] = 1.0
        mt = buf[_N_SCALARS + host_dim + nb:]
        mt[:n * task_dim] = np.asarray(m_t, np.float32).reshape(-1)
        mt[n * task_dim:] = 0.0
        ring, self._ring = self._ring, None   # donated: invalid on failure
        try:
            ring2, out = _fused_step(
                self.params, ring, self._stage(buf), nb=nb,
                task_dim=task_dim, use_pallas=self.use_pallas_cell,
                per_task=per_task, unroll=self._unroll(nb))
        except Exception:
            self._ring_rows = 0               # next call rebuilds the ring
            raise
        self._ring = ring2
        self._ring_rows += 1
        if per_task:
            # packed [E_S | scores] computed inside the fused program —
            # one readback, no second dispatch
            out = np.asarray(out)
            return out[:n, 0], out[:n, 1:]
        return np.asarray(out)[:n]

    # ------------------------ multi-tenant serving -------------------------

    def _scalars_dev(self) -> tuple[jax.Array, jax.Array]:
        """Device-resident (k, beta_scale), cached per value — the
        serving batch path must not re-upload scalar hyper-parameters
        every tick (the transfer-guard accounting pins it)."""
        key = (float(self.k), float(self.beta_scale))
        cached = getattr(self, "_scalar_cache", None)
        if cached is None or cached[0] != key:
            cached = (key, (self._stage(np.float32(self.k)),
                            self._stage(np.float32(self.beta_scale))))
            self._scalar_cache = cached
        return cached[1]

    def predict_tenants(self, host_seqs: list, mt_list: list,
                        q_list: list, per_task: bool = False) -> list:
        """Multi-tenant batched prediction (the serving daemon's batch
        tick): many small clusters share one device-resident model and
        one network dispatch.

        Args:
            host_seqs: per-tenant ``(T, n_hosts, HOST_FEATURES)`` (or
                pre-flattened ``(T, host_dim)``) host history windows,
                ``T == horizon`` for every tenant.
            mt_list: per-tenant ``(n_i, max_tasks, TASK_FEATURES)``
                current task matrices.
            q_list: per-tenant ``(n_i,)`` true task counts.
            per_task: also return per-task scores.

        The tenants' job axes are concatenated, each job row carries its
        own tenant's host block, and the combined batch goes through
        :meth:`batch_size` (power-of-two bucket, or the exact count when
        padding would waste too much) — so the jitted network compiles
        once per batch shape regardless of how tenants interleave, and a
        warm tick is one dispatch.  Padded rows replicate the last
        tenant's host block.  All uploads go through :meth:`_stage`.

        This is a **Tier-1** path: it runs the restructured
        ``net.predict_sequence_opt`` emission (batched encoder, unrolled
        scan), so results agree with the unfused reference within the
        documented tolerance bound rather than bitwise — still fully
        deterministic per (shape, unroll, platform).

        Returns a list with one ``e_s`` array per tenant, or one
        ``(e_s, scores)`` pair per tenant when ``per_task``.
        """
        t = self.horizon
        host_dim = self.host_dim
        ns = [int(m.shape[0]) for m in mt_list]
        total = int(sum(ns))
        nb = self.batch_size(total)
        self.buckets_used.add(nb)
        xs = np.zeros((t, nb, self.input_dim), np.float32)
        qp = np.ones(nb, np.float32)
        lo = 0
        for seq, mt, q, n in zip(host_seqs, mt_list, q_list, ns):
            hi = lo + n
            mh_flat = np.asarray(seq, np.float32).reshape(t, 1, host_dim)
            xs[:, lo:hi, :host_dim] = mh_flat
            xs[:, lo:hi, host_dim:] = \
                np.asarray(mt, np.float32).reshape(1, n, -1)
            qp[lo:hi] = np.asarray(q, np.float32)
            lo = hi
        if total < nb and host_seqs:
            xs[:, total:, :host_dim] = np.asarray(
                host_seqs[-1], np.float32).reshape(t, 1, host_dim)
        kd, bsd = self._scalars_dev()
        ab = net.predict_sequence_opt(self.params, self._stage(xs),
                                      unroll=self._unroll(nb),
                                      use_pallas=self.use_pallas_cell)
        if per_task:
            out = np.asarray(_pareto_tail_per_task(
                ab, self._stage(qp), kd, bsd,
                self._stage(np.ascontiguousarray(
                    xs[-1, :, host_dim:]))))
            return [(out[lo:lo + n, 0], out[lo:lo + n, 1:])
                    for lo, n in zip(np.cumsum([0] + ns[:-1]), ns)]
        _, _, _, e_s = _pareto_tail(ab, self._stage(qp), kd, bsd)
        e_s = np.asarray(e_s)
        return [e_s[lo:lo + n]
                for lo, n in zip(np.cumsum([0] + ns[:-1]), ns)]

    # ---------------------------- inference -------------------------------

    def predict_features(self, m_h_seq: np.ndarray, m_t: np.ndarray,
                         q: np.ndarray, per_task: bool = False):
        """Predict (alpha, beta, K, E_S) for a batch of jobs from numpy
        feature matrices (the simulator hot path).

        Args:
            m_h_seq: (T, n_hosts, HOST_FEATURES) shared host history.
            m_t: (jobs, max_tasks, TASK_FEATURES) current task matrices
                (broadcast across T — the engine publishes one M_T per
                decision point).
            q: (jobs,) true task counts.
            per_task: return ``(e_s, scores)`` from the per-task score
                tail instead of a :class:`Prediction` — the unfused
                mirror of ``predict_interval(..., per_task=True)``.  Both
                paths feed bitwise-identical (ab, q, k, beta_scale, M_T)
                into the same ``_pareto_tail_per_task`` cache entry, so
                their outputs are bitwise-equal (tested per shape).

        The job axis is zero-padded to a power-of-two bucket before the
        jitted network; padded rows are masked off the returned arrays.
        """
        n = m_t.shape[0]
        return self._predict_bucketed(
            m_h_seq, np.asarray(m_t, np.float32).reshape(1, n, -1), n, q,
            per_task=per_task)

    def predict(self, m_h_seq: jax.Array, m_t_seq: jax.Array,
                q: jax.Array) -> Prediction:
        """Predict from full (T, jobs, ...) matrix sequences (general API;
        tolerates time-varying task matrices).

        Args:
            m_h_seq: (T, n_hosts, HOST_FEATURES) shared host history.
            m_t_seq: (T, jobs, max_tasks, TASK_FEATURES) per-job history.
            q: (jobs,) true task counts.
        """
        t, jobs = m_t_seq.shape[0], m_t_seq.shape[1]
        return self._predict_bucketed(
            m_h_seq, np.asarray(m_t_seq, np.float32).reshape(t, jobs, -1),
            jobs, q)

    def _predict_bucketed(self, m_h_seq: np.ndarray, mt_flat: np.ndarray,
                          n: int, q: np.ndarray, per_task: bool = False):
        """Shared bucketing contract: assemble the (T, bucket, input_dim)
        batch — host features on every row, task features zero-padded
        past ``n``, q padded with 1.0 — run the jitted network, and mask
        the padded rows off the outputs.  ``mt_flat`` is (1|T, n, -1)
        flattened task features (broadcast across T when 1)."""
        t = m_h_seq.shape[0]
        nb = bucket_size(n)
        self.buckets_used.add(nb)
        mh_flat = np.asarray(m_h_seq, np.float32).reshape(t, 1, -1)
        host_dim = mh_flat.shape[-1]
        xs = np.zeros((t, nb, self.input_dim), np.float32)
        xs[:, :, :host_dim] = mh_flat
        xs[:, :n, host_dim:] = mt_flat
        qp = np.ones(nb, np.float32)
        qp[:n] = np.asarray(q, np.float32)
        if per_task:
            # the padded task block of the last step IS the fused path's
            # staged M_T batch (raw features, zero past n), so the shared
            # tail sees bitwise-identical inputs on both paths
            ab = net.predict_sequence(self.params, jnp.asarray(xs),
                                      use_pallas=self.use_pallas_cell)
            out = np.asarray(_pareto_tail_per_task(
                ab, jnp.asarray(qp), jnp.float32(self.k),
                jnp.float32(self.beta_scale),
                jnp.asarray(xs[-1, :, host_dim:])))
            return out[:n, 0], out[:n, 1:]
        pred = self._predict_xs(xs, qp)
        return Prediction(*(np.asarray(f)[:n] for f in pred))

    def _predict_xs(self, xs: np.ndarray, q: np.ndarray) -> Prediction:
        ab = net.predict_sequence(self.params, jnp.asarray(xs),
                                  use_pallas=self.use_pallas_cell)
        alpha, beta, thr, e_s = _pareto_tail(
            ab, jnp.asarray(q), jnp.float32(self.k),
            jnp.float32(self.beta_scale))
        return Prediction(alpha=alpha, beta=beta, threshold=thr, e_s=e_s)

    @property
    def compile_count(self) -> int:
        """Cumulative XLA compiles of the jitted prediction programs in
        this process — the unfused network plus the fused interval step
        (spanning every predictor instance — jit caches are global)."""
        return net.predict_sequence._cache_size() + fused_compile_count()

    # ---------------------------- training --------------------------------

    def make_targets(self, times: jax.Array, mask: jax.Array | None = None
                     ) -> jax.Array:
        """MLE-fit (alpha, beta/beta_scale) targets from response times."""
        a, b = pareto.fit_pareto(times, mask)
        return jnp.stack([a, b / self.beta_scale], axis=-1)

    def fit(self, xs: jax.Array, targets: jax.Array, epochs: int = 50,
            lr: float = 1e-5, batch: int = 64,
            use_pallas_cell: bool | None = None) -> list[float]:
        """Train on (T, N, input_dim) sequences vs (N, 2) targets.

        Minibatches keep one shape: when N > batch the trailing partial
        batch is dropped (each epoch re-permutes, so all data is seen
        across epochs) instead of retracing ``train_step`` on a second
        shape; when N <= batch the single batch is the whole set.
        Records the epoch-mean loss, not the last batch's.

        ``use_pallas_cell`` routes the forward (and, through autodiff,
        the backward) pass of every ``train_step`` through the fused
        Pallas LSTM cell; ``None`` follows the predictor's flag.
        Gradients exact-match the reference cell (tested).
        """
        n = xs.shape[1]
        use_pallas = (self.use_pallas_cell if use_pallas_cell is None
                      else use_pallas_cell)
        rng = np.random.default_rng(self.seed)
        xs = jnp.asarray(xs)           # resident on device across epochs
        targets = jnp.asarray(targets)
        for _ in range(epochs):
            order = rng.permutation(n)
            if n > batch:
                order = order[:n - (n % batch)]
            losses = []
            for s in range(0, len(order), batch):
                idx = order[s:s + batch]
                self.params, self.opt, loss = net.train_step(
                    self.params, self.opt, xs[:, idx], targets[idx], lr=lr,
                    use_pallas=use_pallas)
                losses.append(float(loss))
            self._losses.append(float(np.mean(losses)))
        return self._losses

    @property
    def losses(self) -> list[float]:
        return self._losses
