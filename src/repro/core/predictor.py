"""Straggler Prediction module (paper Fig. 1 / Fig. 4): Encoder-LSTM -> Pareto.

Ties together feature extraction, the Encoder-LSTM network and the Pareto
expected-straggler computation, and owns network training (MSE against
MLE-fitted (alpha, beta) targets — paper §4.4).

Inference is shape-disciplined: ``predict_features`` pads the job batch to
a power-of-two bucket before entering the jitted network, so a sweep cell
compiles **once per bucket size**, never once per active-job count (the
silent-retrace failure mode: every new job count is a new batch shape and
a full XLA retrace).  ``buckets_used`` records the bucket set for
retrace-accounting tests and benchmarks.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import encoder_lstm as net
from repro.core import features, pareto


class Prediction(NamedTuple):
    alpha: jax.Array      # (...,)
    beta: jax.Array       # (...,)
    threshold: jax.Array  # K  (...,)
    e_s: jax.Array        # expected straggler count (...,)


def bucket_size(n: int) -> int:
    """Smallest power of two >= n (the jit batch-shape bucket)."""
    return max(1 << (int(n) - 1).bit_length(), 1) if n else 1


@jax.jit
def _pareto_tail(ab: jax.Array, q: jax.Array, k: jax.Array,
                 beta_scale: jax.Array):
    """(alpha, beta) head outputs -> (alpha, beta, K, E_S), fused.

    Kept op-for-op identical to the historical eager chain
    (``straggler_threshold`` + ``expected_stragglers``) so results are
    bitwise-stable; jitting it replaces ~10 per-interval eager dispatches
    (each a compile per batch bucket) with one cached call.
    """
    alpha = ab[..., 0]
    beta = ab[..., 1] * beta_scale
    thr = k * (alpha * beta / (alpha - 1.0))
    kk = thr / beta
    e_s = q * kk ** (-alpha)
    return alpha, beta, thr, e_s


@dataclasses.dataclass
class StragglerPredictor:
    """Owns Encoder-LSTM params + the (I, T, k) hyper-parameters.

    ``horizon`` is T/I — the number of LSTM iterations per prediction
    (paper: I = 1 s, T = 5 s -> 5 steps).
    """

    n_hosts: int
    max_tasks: int
    k: float = pareto.DEFAULT_K
    horizon: int = 5
    interval: float = 1.0
    seed: int = 0
    # beta (the Pareto scale, in seconds) is regressed in units of
    # beta_scale so the MSE loss is O(1); alpha is O(1) already
    beta_scale: float = 1.0
    # route the LSTM cell through the fused Pallas kernel
    # (repro.kernels.lstm_cell); exact-match tested against the jnp cell
    use_pallas_cell: bool = False

    def __post_init__(self):
        self.input_dim = features.input_dim(self.n_hosts, self.max_tasks)
        # params live on device for their whole lifetime — predictions
        # upload only the per-interval feature batch
        self.params = jax.device_put(
            net.init_params(jax.random.PRNGKey(self.seed), self.input_dim))
        self.opt = net.adam_init(self.params)
        self._losses: list[float] = []
        self.buckets_used: set[int] = set()

    # ---------------------------- inference -------------------------------

    def predict_features(self, m_h_seq: np.ndarray, m_t: np.ndarray,
                         q: np.ndarray) -> Prediction:
        """Predict (alpha, beta, K, E_S) for a batch of jobs from numpy
        feature matrices (the simulator hot path).

        Args:
            m_h_seq: (T, n_hosts, HOST_FEATURES) shared host history.
            m_t: (jobs, max_tasks, TASK_FEATURES) current task matrices
                (broadcast across T — the engine publishes one M_T per
                decision point).
            q: (jobs,) true task counts.

        The job axis is zero-padded to a power-of-two bucket before the
        jitted network; padded rows are masked off the returned arrays.
        """
        n = m_t.shape[0]
        return self._predict_bucketed(
            m_h_seq, np.asarray(m_t, np.float32).reshape(1, n, -1), n, q)

    def predict(self, m_h_seq: jax.Array, m_t_seq: jax.Array,
                q: jax.Array) -> Prediction:
        """Predict from full (T, jobs, ...) matrix sequences (general API;
        tolerates time-varying task matrices).

        Args:
            m_h_seq: (T, n_hosts, HOST_FEATURES) shared host history.
            m_t_seq: (T, jobs, max_tasks, TASK_FEATURES) per-job history.
            q: (jobs,) true task counts.
        """
        t, jobs = m_t_seq.shape[0], m_t_seq.shape[1]
        return self._predict_bucketed(
            m_h_seq, np.asarray(m_t_seq, np.float32).reshape(t, jobs, -1),
            jobs, q)

    def _predict_bucketed(self, m_h_seq: np.ndarray, mt_flat: np.ndarray,
                          n: int, q: np.ndarray) -> Prediction:
        """Shared bucketing contract: assemble the (T, bucket, input_dim)
        batch — host features on every row, task features zero-padded
        past ``n``, q padded with 1.0 — run the jitted network, and mask
        the padded rows off the outputs.  ``mt_flat`` is (1|T, n, -1)
        flattened task features (broadcast across T when 1)."""
        t = m_h_seq.shape[0]
        nb = bucket_size(n)
        self.buckets_used.add(nb)
        mh_flat = np.asarray(m_h_seq, np.float32).reshape(t, 1, -1)
        host_dim = mh_flat.shape[-1]
        xs = np.zeros((t, nb, self.input_dim), np.float32)
        xs[:, :, :host_dim] = mh_flat
        xs[:, :n, host_dim:] = mt_flat
        qp = np.ones(nb, np.float32)
        qp[:n] = np.asarray(q, np.float32)
        pred = self._predict_xs(xs, qp)
        return Prediction(*(np.asarray(f)[:n] for f in pred))

    def _predict_xs(self, xs: np.ndarray, q: np.ndarray) -> Prediction:
        ab = net.predict_sequence(self.params, jnp.asarray(xs),
                                  use_pallas=self.use_pallas_cell)
        alpha, beta, thr, e_s = _pareto_tail(
            ab, jnp.asarray(q), jnp.float32(self.k),
            jnp.float32(self.beta_scale))
        return Prediction(alpha=alpha, beta=beta, threshold=thr, e_s=e_s)

    @property
    def compile_count(self) -> int:
        """Cumulative XLA compiles of the jitted network in this process
        (spanning every predictor instance — jit caches are global)."""
        return net.predict_sequence._cache_size()

    # ---------------------------- training --------------------------------

    def make_targets(self, times: jax.Array, mask: jax.Array | None = None
                     ) -> jax.Array:
        """MLE-fit (alpha, beta/beta_scale) targets from response times."""
        a, b = pareto.fit_pareto(times, mask)
        return jnp.stack([a, b / self.beta_scale], axis=-1)

    def fit(self, xs: jax.Array, targets: jax.Array, epochs: int = 50,
            lr: float = 1e-5, batch: int = 64) -> list[float]:
        """Train on (T, N, input_dim) sequences vs (N, 2) targets.

        Minibatches keep one shape: when N > batch the trailing partial
        batch is dropped (each epoch re-permutes, so all data is seen
        across epochs) instead of retracing ``train_step`` on a second
        shape; when N <= batch the single batch is the whole set.
        Records the epoch-mean loss, not the last batch's.
        """
        n = xs.shape[1]
        rng = np.random.default_rng(self.seed)
        xs = jnp.asarray(xs)           # resident on device across epochs
        targets = jnp.asarray(targets)
        for _ in range(epochs):
            order = rng.permutation(n)
            if n > batch:
                order = order[:n - (n % batch)]
            losses = []
            for s in range(0, len(order), batch):
                idx = order[s:s + batch]
                self.params, self.opt, loss = net.train_step(
                    self.params, self.opt, xs[:, idx], targets[idx], lr=lr)
                losses.append(float(loss))
            self._losses.append(float(np.mean(losses)))
        return self._losses

    @property
    def losses(self) -> list[float]:
        return self._losses
