"""Straggler Prediction module (paper Fig. 1 / Fig. 4): Encoder-LSTM -> Pareto.

Ties together feature extraction, the Encoder-LSTM network and the Pareto
expected-straggler computation, and owns network training (MSE against
MLE-fitted (alpha, beta) targets — paper §4.4).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import encoder_lstm as net
from repro.core import features, pareto


class Prediction(NamedTuple):
    alpha: jax.Array      # (...,)
    beta: jax.Array       # (...,)
    threshold: jax.Array  # K  (...,)
    e_s: jax.Array        # expected straggler count (...,)


@dataclasses.dataclass
class StragglerPredictor:
    """Owns Encoder-LSTM params + the (I, T, k) hyper-parameters.

    ``horizon`` is T/I — the number of LSTM iterations per prediction
    (paper: I = 1 s, T = 5 s -> 5 steps).
    """

    n_hosts: int
    max_tasks: int
    k: float = pareto.DEFAULT_K
    horizon: int = 5
    interval: float = 1.0
    seed: int = 0
    # beta (the Pareto scale, in seconds) is regressed in units of
    # beta_scale so the MSE loss is O(1); alpha is O(1) already
    beta_scale: float = 1.0

    def __post_init__(self):
        self.input_dim = features.input_dim(self.n_hosts, self.max_tasks)
        self.params = net.init_params(jax.random.PRNGKey(self.seed),
                                      self.input_dim)
        self.opt = net.adam_init(self.params)
        self._losses: list[float] = []

    # ---------------------------- inference -------------------------------

    def predict(self, m_h_seq: jax.Array, m_t_seq: jax.Array,
                q: jax.Array) -> Prediction:
        """Predict (alpha, beta, K, E_S) for a batch of jobs.

        Args:
            m_h_seq: (T, n_hosts, HOST_FEATURES) shared host history.
            m_t_seq: (T, jobs, max_tasks, TASK_FEATURES) per-job task history.
            q: (jobs,) true task counts.
        """
        t = m_t_seq.shape[0]
        jobs = m_t_seq.shape[1]
        mh = jnp.broadcast_to(m_h_seq[:, None], (t, jobs, *m_h_seq.shape[1:]))
        xs = features.flatten_inputs(mh, m_t_seq)  # (T, jobs, input_dim)
        ab = net.predict_sequence(self.params, xs)  # (jobs, 2)
        alpha, beta = ab[..., 0], ab[..., 1] * self.beta_scale
        thr = pareto.straggler_threshold(alpha, beta, self.k)
        e_s = pareto.expected_stragglers(q, alpha, beta, self.k)
        return Prediction(alpha=alpha, beta=beta, threshold=thr, e_s=e_s)

    # ---------------------------- training --------------------------------

    def make_targets(self, times: jax.Array, mask: jax.Array | None = None
                     ) -> jax.Array:
        """MLE-fit (alpha, beta/beta_scale) targets from response times."""
        a, b = pareto.fit_pareto(times, mask)
        return jnp.stack([a, b / self.beta_scale], axis=-1)

    def fit(self, xs: jax.Array, targets: jax.Array, epochs: int = 50,
            lr: float = 1e-5, batch: int = 64) -> list[float]:
        """Train on (T, N, input_dim) sequences vs (N, 2) targets."""
        n = xs.shape[1]
        rng = np.random.default_rng(self.seed)
        for _ in range(epochs):
            order = rng.permutation(n)
            for s in range(0, n, batch):
                idx = order[s:s + batch]
                self.params, self.opt, loss = net.train_step(
                    self.params, self.opt, xs[:, idx], targets[idx], lr=lr)
            self._losses.append(float(loss))
        return self._losses

    @property
    def losses(self) -> list[float]:
        return self._losses
