"""START controller — Algorithm 1 of the paper, runtime-agnostic.

Consumes per-interval telemetry (host matrix M_H + per-job task matrices
M_T), predicts per-job expected straggler counts E_S via the Encoder-LSTM ->
Pareto pipeline, and emits mitigation actions once a job has only floor(E_S)
tasks left ("run job till completion of q - floor(E_S) tasks", line 12).

Used by both the CloudSim-analogue simulator (repro.sim) and the distributed
training runtime (repro.distributed.straggler_runtime).
"""
from __future__ import annotations

import collections
import dataclasses
import os
from typing import Sequence

import numpy as np

from repro.core import mitigation
from repro.core.predictor import StragglerPredictor


@dataclasses.dataclass
class JobView:
    """Runtime-agnostic snapshot of one in-flight job."""

    job_id: int
    q: int                          # total tasks
    deadline_oriented: bool
    incomplete_task_ids: list[int]  # tasks still running
    task_hosts: list[int]           # host of each incomplete task
    task_matrix: np.ndarray         # (max_tasks, TASK_FEATURES)


class STARTController:
    """Algorithm-1 controller.

    ``use_fused_step`` (default on) routes the per-interval prediction
    through the predictor's fused device program: the M_H history lives in
    a donated device ring buffer and one jitted call per interval replaces
    feature re-upload plus ~10 eager dispatches.  Results are bitwise
    identical to the unfused path (tested; the determinism golden fixture
    pins it).  Set ``REPRO_DISABLE_FUSED_STEP=1`` to force the historical
    path for debugging.
    """

    def __init__(self, n_hosts: int, max_tasks: int, k: float = 1.5,
                 horizon: int = 5, seed: int = 0,
                 ma_decay: float = 0.8, beta_scale: float = 1.0,
                 use_fused_step: bool = True):
        self.predictor = StragglerPredictor(
            n_hosts=n_hosts, max_tasks=max_tasks, k=k, horizon=horizon,
            seed=seed, beta_scale=beta_scale)
        self.ma = mitigation.StragglerMovingAverage(n_hosts, decay=ma_decay)
        self.horizon = horizon
        self.use_fused_step = use_fused_step and not os.environ.get(
            "REPRO_DISABLE_FUSED_STEP")
        self._host_hist: collections.deque = collections.deque(
            maxlen=horizon)
        self._mitigated: set[int] = set()
        self._es_cache: dict[int, float] = {}

    # ------------------------------ telemetry -----------------------------

    def observe_hosts(self, m_h: np.ndarray) -> None:
        m_h = np.asarray(m_h, np.float32)
        self._host_hist.append(m_h)
        if self.use_fused_step:
            self.predictor.push_host_row(m_h)

    def observe_straggler_counts(self, counts: np.ndarray) -> None:
        self.ma.update(counts)

    def job_finished(self, job_id: int) -> None:
        self._mitigated.discard(job_id)
        self._es_cache.pop(job_id, None)

    def es_total(self, job_ids) -> float:
        """Sum of the latest per-job E_S predictions over ``job_ids``
        (jobs never predicted contribute 0) — the controller's aggregate
        straggler forecast, logged for the Fig. 9 MAPE comparison."""
        return float(sum(self._es_cache.get(j, 0.0) for j in job_ids))

    def _host_seq(self) -> np.ndarray:
        hist = list(self._host_hist)
        while len(hist) < self.horizon:  # left-pad with oldest snapshot
            hist.insert(0, hist[0])
        return np.stack(hist[-self.horizon:])

    # ------------------------------ decision ------------------------------

    def predict_es(self, jobs: Sequence[JobView]) -> np.ndarray:
        """Batched PredictStraggler (Alg. 1 lines 6-13) over current
        jobs, by JobView (compat surface; delegates to
        :meth:`predict_es_batch`)."""
        if not jobs:
            return np.zeros(0)
        return self.predict_es_batch(
            np.array([j.job_id for j in jobs], np.int64),
            np.stack([j.task_matrix for j in jobs]),
            np.array([j.q for j in jobs], np.float32))

    def predict_es_batch(self, job_ids: np.ndarray, m_t: np.ndarray,
                         q: np.ndarray) -> np.ndarray:
        """Array-native PredictStraggler over the active-job batch (the
        simulator hot path — no per-job view objects).

        Feature assembly is pure numpy; the predictor pads the job batch
        to a power-of-two bucket so the jitted network compiles once per
        bucket, never once per job count.  With the fused step enabled
        the whole pipeline (ring roll, assembly, network, Pareto tail)
        runs device-resident per interval; a repeat predict within the
        same interval (no fresh host row) falls back to the
        bitwise-identical unfused path."""
        if len(job_ids) == 0 or not self._host_hist:
            return np.zeros(len(job_ids))
        q = np.asarray(q, np.float32)
        if self.use_fused_step and self.predictor.fused_ready:
            e_s = self.predictor.predict_interval(m_t, q)
        else:
            pred = self.predictor.predict_features(self._host_seq(), m_t, q)
            e_s = np.asarray(pred.e_s)
        for j, e in zip(job_ids, e_s):
            self._es_cache[int(j)] = float(e)
        return e_s

    def decide_arrays(self, job_ids: np.ndarray, m_t: np.ndarray,
                      q: np.ndarray, open_counts: np.ndarray,
                      deadline: np.ndarray, incomplete_fn,
                      host_load: np.ndarray | None = None
                      ) -> list[mitigation.Action]:
        """Array-native Algorithm-1 main loop (bitwise-equal to
        :meth:`decide` over equivalent JobViews): the trigger compare runs
        vectorized over the whole active batch and per-job task lists are
        materialized — via ``incomplete_fn(job) -> (task_ids, hosts)`` —
        only for the (rare) jobs that actually reach the
        q - floor(E_S) completion point."""
        if len(job_ids) == 0:
            return []
        e_s = self.predict_es_batch(job_ids, m_t, q)
        n_mit = np.floor(e_s)
        trig = (n_mit >= 1.0) & (open_counts <= n_mit)
        actions: list[mitigation.Action] = []
        for idx in np.nonzero(trig)[0]:
            job = int(job_ids[idx])
            if job in self._mitigated:
                continue
            tids, hosts = incomplete_fn(job)
            actions.extend(mitigation.plan_mitigation(
                job, tids, hosts, bool(deadline[idx]), self.ma,
                load=host_load))
            self._mitigated.add(job)
        return actions

    def decide(self, jobs: Sequence[JobView],
               host_load: np.ndarray | None = None
               ) -> list[mitigation.Action]:
        """Algorithm 1 main loop: emit mitigation actions for jobs that have
        reached the q - floor(E_S) completion point."""
        if not jobs:
            return []
        e_s = self.predict_es(jobs)
        actions: list[mitigation.Action] = []
        for job, es in zip(jobs, e_s):
            n_mit = int(np.floor(es))
            if n_mit <= 0 or job.job_id in self._mitigated:
                continue  # normal job (J_n) or already handled
            if len(job.incomplete_task_ids) <= n_mit:
                # only the expected stragglers remain -> mitigate them now
                actions.extend(mitigation.plan_mitigation(
                    job.job_id, job.incomplete_task_ids, job.task_hosts,
                    job.deadline_oriented, self.ma, load=host_load))
                self._mitigated.add(job.job_id)
        return actions
