"""START controller — Algorithm 1 of the paper, runtime-agnostic.

Consumes per-interval telemetry (host matrix M_H + per-job task matrices
M_T), predicts per-job expected straggler counts E_S via the Encoder-LSTM ->
Pareto pipeline, and emits mitigation actions once a job has only floor(E_S)
tasks left ("run job till completion of q - floor(E_S) tasks", line 12).

Used by both the CloudSim-analogue simulator (repro.sim) and the distributed
training runtime (repro.distributed.straggler_runtime).
"""
from __future__ import annotations

import collections
import dataclasses
import os
from typing import Sequence

import numpy as np

from repro.core import mitigation
from repro.core.predictor import StragglerPredictor


@dataclasses.dataclass
class JobView:
    """Runtime-agnostic snapshot of one in-flight job."""

    job_id: int
    q: int                          # total tasks
    deadline_oriented: bool
    incomplete_task_ids: list[int]  # tasks still running
    task_hosts: list[int]           # host of each incomplete task
    task_matrix: np.ndarray         # (max_tasks, TASK_FEATURES)


class STARTController:
    """Algorithm-1 controller.

    ``use_fused_step`` (default on) routes the per-interval prediction
    through the predictor's fused device program: the M_H history lives in
    a donated device ring buffer and one jitted call per interval replaces
    feature re-upload plus ~10 eager dispatches.  Results are bitwise
    identical to the unfused path (tested; the determinism golden fixture
    pins it).  Set ``REPRO_DISABLE_FUSED_STEP=1`` to force the historical
    path for debugging.
    """

    def __init__(self, n_hosts: int, max_tasks: int, k: float = 1.5,
                 horizon: int = 5, seed: int = 0,
                 ma_decay: float = 0.8, beta_scale: float = 1.0,
                 use_fused_step: bool = True, trigger: str = "milestone",
                 score_on: float = 0.0, hysteresis: int = 2,
                 cooldown: int = 5,
                 predictor: StragglerPredictor | None = None):
        if trigger not in ("milestone", "per_task"):
            raise ValueError(f"unknown trigger mode {trigger!r}")
        # an injected predictor lets many controllers share one
        # device-resident model (the serving daemon's per-tenant
        # controllers); its hyper-parameters win over the ctor's
        if predictor is not None:
            k, horizon = predictor.k, predictor.horizon
        self.predictor = predictor if predictor is not None \
            else StragglerPredictor(
                n_hosts=n_hosts, max_tasks=max_tasks, k=k, horizon=horizon,
                seed=seed, beta_scale=beta_scale)
        self.ma = mitigation.StragglerMovingAverage(n_hosts, decay=ma_decay)
        self.horizon = horizon
        self.use_fused_step = use_fused_step and not os.environ.get(
            "REPRO_DISABLE_FUSED_STEP")
        #: "milestone" — Algorithm 1 verbatim: act once a job is down to
        #: floor(E_S) open tasks.  "per_task" — act as soon as the
        #: predicted straggler set is nonempty: each interval the
        #: top-floor(E_S) incomplete tasks by per-task score (>=
        #: ``score_on``) form the set; a task fires after ``hysteresis``
        #: consecutive intervals in the set and then rests ``cooldown``
        #: intervals, so scores flapping across intervals cannot spam
        #: speculate/rerun actions.
        self.trigger = trigger
        self.score_on = score_on
        self.hysteresis = hysteresis
        self.cooldown = cooldown
        self._host_hist: collections.deque = collections.deque(
            maxlen=horizon)
        self._mitigated: set[int] = set()
        self._es_cache: dict[int, float] = {}
        self._tick = 0                       # decide_arrays intervals seen
        self._streak: dict[int, int] = {}    # task -> consecutive in-set
        self._cool: dict[int, int] = {}      # task -> tick cooldown expires

    # ------------------------------ telemetry -----------------------------

    def observe_hosts(self, m_h: np.ndarray) -> None:
        m_h = np.asarray(m_h, np.float32)
        self._host_hist.append(m_h)
        if self.use_fused_step:
            self.predictor.push_host_row(m_h)

    def observe_straggler_counts(self, counts: np.ndarray) -> None:
        self.ma.update(counts)

    def job_finished(self, job_id: int) -> None:
        self._mitigated.discard(job_id)
        self._es_cache.pop(job_id, None)

    def es_total(self, job_ids) -> float:
        """Sum of the latest per-job E_S predictions over ``job_ids``
        (jobs never predicted contribute 0) — the controller's aggregate
        straggler forecast, logged for the Fig. 9 MAPE comparison."""
        return float(sum(self._es_cache.get(j, 0.0) for j in job_ids))

    def _host_seq(self) -> np.ndarray:
        hist = list(self._host_hist)
        while len(hist) < self.horizon:  # left-pad with oldest snapshot
            hist.insert(0, hist[0])
        return np.stack(hist[-self.horizon:])

    # ------------------------------ decision ------------------------------

    def predict_es(self, jobs: Sequence[JobView]) -> np.ndarray:
        """Batched PredictStraggler (Alg. 1 lines 6-13) over current
        jobs, by JobView (compat surface; delegates to
        :meth:`predict_es_batch`)."""
        if not jobs:
            return np.zeros(0)
        return self.predict_es_batch(
            np.array([j.job_id for j in jobs], np.int64),
            np.stack([j.task_matrix for j in jobs]),
            np.array([j.q for j in jobs], np.float32))

    @staticmethod
    def _sanitize_es(e_s: np.ndarray, q: np.ndarray) -> np.ndarray:
        """Guard the trigger against a degenerate network output: a
        non-finite E_S (alpha <= 1 makes the Pareto mean blow up) would
        flow into ``np.floor`` and either permanently force-fire (inf)
        or silently disable (NaN compares false) the trigger for that
        job.  Non-finite maps to 0 (no predicted stragglers — mitigating
        on garbage is worse than waiting) and finite values clamp to the
        only meaningful range, [0, q]."""
        e_s = np.asarray(e_s)
        e_s = np.where(np.isfinite(e_s), e_s, 0.0)
        return np.clip(e_s, 0.0, np.asarray(q, e_s.dtype))

    def predict_es_batch(self, job_ids: np.ndarray, m_t: np.ndarray,
                         q: np.ndarray) -> np.ndarray:
        """Array-native PredictStraggler over the active-job batch (the
        simulator hot path — no per-job view objects).

        Feature assembly is pure numpy; the predictor pads the job batch
        to a power-of-two bucket so the jitted network compiles once per
        bucket, never once per job count.  With the fused step enabled
        the whole pipeline (ring roll, assembly, network, Pareto tail)
        runs device-resident per interval; a repeat predict within the
        same interval (no fresh host row) falls back to the
        bitwise-identical unfused path."""
        if len(job_ids) == 0 or not self._host_hist:
            return np.zeros(len(job_ids))
        q = np.asarray(q, np.float32)
        if self.use_fused_step and self.predictor.fused_ready:
            e_s = self.predictor.predict_interval(m_t, q)
        else:
            pred = self.predictor.predict_features(self._host_seq(), m_t, q)
            e_s = np.asarray(pred.e_s)
        e_s = self._sanitize_es(e_s, q)
        for j, e in zip(job_ids, e_s):
            self._es_cache[int(j)] = float(e)
        return e_s

    def predict_scores_batch(self, job_ids: np.ndarray, m_t: np.ndarray,
                             q: np.ndarray
                             ) -> tuple[np.ndarray, np.ndarray]:
        """Per-task PredictStraggler: ``(e_s, scores)`` with ``scores``
        of shape (jobs, max_tasks) — E_S decomposed across each job's
        M_T rows by relative resource demand (scores over a job's real
        tasks sum to its E_S).  Same fused-step routing and E_S
        sanitization as :meth:`predict_es_batch`."""
        if len(job_ids) == 0 or not self._host_hist:
            return (np.zeros(len(job_ids)),
                    np.zeros((len(job_ids), self.predictor.max_tasks)))
        q = np.asarray(q, np.float32)
        if self.use_fused_step and self.predictor.fused_ready:
            e_s, scores = self.predictor.predict_interval(
                m_t, q, per_task=True)
        else:
            e_s, scores = self.predictor.predict_features(
                self._host_seq(), m_t, q, per_task=True)
        e_s = self._sanitize_es(e_s, q)
        scores = np.where(np.isfinite(scores), scores, 0.0)
        for j, e in zip(job_ids, e_s):
            self._es_cache[int(j)] = float(e)
        return e_s, scores

    def decide_arrays(self, job_ids: np.ndarray, m_t: np.ndarray,
                      q: np.ndarray, open_counts: np.ndarray,
                      deadline: np.ndarray, incomplete_fn,
                      host_load: np.ndarray | None = None
                      ) -> list[mitigation.Action]:
        """Array-native Algorithm-1 main loop (bitwise-equal to
        :meth:`decide` over equivalent JobViews): the trigger compare runs
        vectorized over the whole active batch and per-job task lists are
        materialized — via ``incomplete_fn(job) -> (task_ids, hosts)`` —
        only for the (rare) jobs that actually reach the
        q - floor(E_S) completion point.

        With ``trigger="per_task"`` the milestone wait is dropped:
        mitigation starts as soon as a job's predicted straggler set is
        nonempty (:meth:`_decide_per_task`).  In that mode
        ``incomplete_fn`` must return a third element — each task's slot
        index into the job's M_T rows — so per-task scores can be
        aligned with open tasks; a trailing element from milestone-mode
        callers is ignored."""
        if len(job_ids) == 0:
            return []
        if self.trigger == "per_task":
            return self._decide_per_task(job_ids, m_t, q, deadline,
                                         incomplete_fn, host_load)
        e_s = self.predict_es_batch(job_ids, m_t, q)
        return self.apply_milestone(job_ids, e_s, open_counts, deadline,
                                    incomplete_fn, host_load)

    def apply_milestone(self, job_ids: np.ndarray, e_s: np.ndarray,
                        open_counts: np.ndarray, deadline: np.ndarray,
                        incomplete_fn,
                        host_load: np.ndarray | None = None
                        ) -> list[mitigation.Action]:
        """Milestone-trigger tail of :meth:`decide_arrays` over an
        externally supplied (already sanitized) E_S batch — the serving
        daemon predicts for many tenants in one dispatch and applies
        each tenant's trigger through this seam."""
        n_mit = np.floor(e_s)
        trig = (n_mit >= 1.0) & (open_counts <= n_mit)
        actions: list[mitigation.Action] = []
        for idx in np.nonzero(trig)[0]:
            job = int(job_ids[idx])
            if job in self._mitigated:
                continue
            tids, hosts = incomplete_fn(job)[:2]
            actions.extend(mitigation.plan_mitigation(
                job, tids, hosts, bool(deadline[idx]), self.ma,
                load=host_load))
            self._mitigated.add(job)
        return actions

    def _decide_per_task(self, job_ids: np.ndarray, m_t: np.ndarray,
                         q: np.ndarray, deadline: np.ndarray,
                         incomplete_fn,
                         host_load: np.ndarray | None = None
                         ) -> list[mitigation.Action]:
        """Per-task trigger: mitigate predicted stragglers the moment the
        prediction says there are some, instead of waiting for the
        q - floor(E_S) completion milestone.

        Each interval, a job with floor(E_S) >= 1 contributes its
        top-floor(E_S) *incomplete* tasks by per-task score (subject to
        the absolute ``score_on`` floor) to the predicted straggler set.
        A task must stay in the set ``hysteresis`` consecutive intervals
        before it fires (one flapping interval resets its streak), and a
        fired task cannot fire again for ``cooldown`` intervals — the
        engine dedups concurrent copies, but the cooldown keeps the
        controller from even proposing spam.

        When ``host_load`` is given, a set member only fires while its
        current host carries at-or-above-median load: a predicted
        straggler on an uncontended host mostly resolves itself, and in
        saturated regimes every premature copy/rerun competes with real
        work — acting early pays precisely where the prediction points
        at a contended host (its streak keeps building meanwhile, so the
        fire is deferred, not forgotten)."""
        e_s, scores = self.predict_scores_batch(job_ids, m_t, q)
        return self.apply_per_task(job_ids, e_s, scores, deadline,
                                   incomplete_fn, host_load)

    def apply_per_task(self, job_ids: np.ndarray, e_s: np.ndarray,
                       scores: np.ndarray, deadline: np.ndarray,
                       incomplete_fn,
                       host_load: np.ndarray | None = None
                       ) -> list[mitigation.Action]:
        """Per-task-trigger tail of :meth:`_decide_per_task` over an
        externally supplied (already sanitized) prediction batch — the
        serving-daemon seam; see :meth:`apply_milestone`."""
        self._tick += 1
        actions: list[mitigation.Action] = []
        in_set: set[int] = set()
        load_med = (np.median(host_load) if host_load is not None
                    else None)
        for idx in range(len(job_ids)):
            n_pred = int(np.floor(e_s[idx]))
            if n_pred < 1:
                continue
            job = int(job_ids[idx])
            tids, hosts, slots = incomplete_fn(job)
            if len(tids) == 0:
                continue
            tids = np.asarray(tids, np.int64)
            s = scores[idx][np.asarray(slots, np.int64)]
            order = np.argsort(-s, kind="stable")[:n_pred]
            fire_t: list[int] = []
            fire_h: list[int] = []
            for i in order:
                if s[i] < self.score_on:
                    continue
                tid = int(tids[i])
                in_set.add(tid)
                streak = self._streak.get(tid, 0) + 1
                self._streak[tid] = streak
                if streak < self.hysteresis \
                        or self._cool.get(tid, 0) > self._tick:
                    continue
                src = int(hosts[i])
                if load_med is not None and src >= 0 \
                        and host_load[src] < load_med:
                    continue
                fire_t.append(tid)
                fire_h.append(src)
                self._cool[tid] = self._tick + self.cooldown
                self._streak[tid] = 0
            if fire_t:
                actions.extend(mitigation.plan_mitigation(
                    job, fire_t, fire_h, bool(deadline[idx]), self.ma,
                    load=host_load))
        # a task that dropped out of the predicted set loses its streak
        for tid in [t for t in self._streak if t not in in_set]:
            del self._streak[tid]
        return actions

    def forget_tasks(self, task_ids) -> None:
        """Drop per-task trigger state (streaks, cooldowns) for recycled
        task ids — substrates that reuse ids across work units (the pod
        runtime's per-window synthetic tasks) call this at the boundary."""
        for t in task_ids:
            t = int(t)
            self._streak.pop(t, None)
            self._cool.pop(t, None)

    def decide(self, jobs: Sequence[JobView],
               host_load: np.ndarray | None = None
               ) -> list[mitigation.Action]:
        """Algorithm 1 main loop: emit mitigation actions for jobs that have
        reached the q - floor(E_S) completion point.

        The JobView path is milestone-only (a JobView carries no slot
        mapping into its task matrix); per-task triggering lives in
        :meth:`decide_arrays`."""
        if not jobs:
            return []
        e_s = self.predict_es(jobs)
        actions: list[mitigation.Action] = []
        for job, es in zip(jobs, e_s):
            n_mit = int(np.floor(es))
            if n_mit <= 0 or job.job_id in self._mitigated:
                continue  # normal job (J_n) or already handled
            if len(job.incomplete_task_ids) <= n_mit:
                # only the expected stragglers remain -> mitigate them now
                actions.extend(mitigation.plan_mitigation(
                    job.job_id, job.incomplete_task_ids, job.task_hosts,
                    job.deadline_oriented, self.ma, load=host_load))
                self._mitigated.add(job.job_id)
        return actions
