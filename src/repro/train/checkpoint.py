"""Distributed checkpointing: save/restore param+optimizer pytrees.

Design for 1000+ nodes (DESIGN.md §3):
  * per-leaf .npy shards under step directories, manifest.json index;
  * writes go to a staging dir then atomic-rename (a torn checkpoint can
    never be loaded);
  * async: a background thread drains a queue of (step, host-copied trees),
    so the training loop blocks only for device->host copy;
  * retention: keep the last ``keep`` steps;
  * restore places leaves onto the current mesh via device_put with the
    caller's shardings — this is the re-shard path used by elastic scaling
    (checkpoint written on N hosts, restored on M).
"""
from __future__ import annotations

import json
import os
import queue
import shutil
import threading

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save(path: str, step: int, tree, keep: int = 3) -> str:
    """Synchronous save. Returns the final step directory."""
    os.makedirs(path, exist_ok=True)
    stage = os.path.join(path, f".tmp-{step}")
    final = os.path.join(path, f"step_{step:08d}")
    if os.path.exists(stage):
        shutil.rmtree(stage)
    os.makedirs(stage)
    leaves, treedef = _flatten(tree)
    manifest = {"step": step, "n_leaves": len(leaves),
                "treedef": str(treedef), "dtypes": []}
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        manifest["dtypes"].append(str(arr.dtype))
        if arr.dtype.name == "bfloat16":  # npy can't store ml_dtypes
            arr = arr.view(np.uint16)
        np.save(os.path.join(stage, f"leaf_{i:05d}.npy"), arr,
                allow_pickle=False)
    with open(os.path.join(stage, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(stage, final)
    _fsync_dir(path)
    _retain(path, keep)
    return final


def _fsync_dir(path: str) -> None:
    """fsync a directory so a rename inside it survives power loss —
    best-effort (not every filesystem lets you open a directory)."""
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)


def _retain(path: str, keep: int) -> None:
    steps = sorted(d for d in os.listdir(path) if d.startswith("step_"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(path, d))


def latest_step(path: str) -> int | None:
    if not os.path.isdir(path):
        return None
    steps = sorted(d for d in os.listdir(path) if d.startswith("step_"))
    if not steps:
        return None
    return int(steps[-1].split("_")[1])


def restore(path: str, step: int, like_tree, shardings=None):
    """Load a checkpoint into the structure of ``like_tree``; if
    ``shardings`` (same pytree of NamedSharding) is given, leaves are
    device_put onto the current mesh — the elastic re-shard path."""
    d = os.path.join(path, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    leaves, treedef = _flatten(like_tree)
    assert manifest["n_leaves"] == len(leaves), \
        (manifest["n_leaves"], len(leaves))
    import ml_dtypes
    out = []
    dtypes = manifest.get("dtypes", [None] * len(leaves))
    for i, like in enumerate(leaves):
        arr = np.load(os.path.join(d, f"leaf_{i:05d}.npy"),
                      allow_pickle=False)
        if dtypes[i] == "bfloat16":
            arr = arr.view(ml_dtypes.bfloat16)
        out.append(arr)
    tree = jax.tree_util.tree_unflatten(treedef, out)
    if shardings is not None:
        tree = jax.tree_util.tree_map(
            lambda x, s: jax.device_put(x, s), tree, shardings)
    return tree


class VersionStore:
    """Versioned model checkpoints with promote / rollback semantics.

    Built on :func:`save` / :func:`restore` (per-leaf ``.npy`` shards,
    staging dir + atomic rename), so a torn version can never load.  On
    top of the step directories it keeps a ``CURRENT`` json pointer —
    ``{"current": v, "history": [...]}`` written via tmp + rename — that
    records which version is *serving* and the promotion trail.  The
    pointer is fsynced before the rename (and the directory after), and
    a torn/garbage pointer recovers to the newest intact version — see
    :meth:`_read_ptr`.  A
    version number is the ``save()`` step; saving never changes what is
    served until :meth:`promote` flips the pointer, and
    :meth:`rollback` flips it back to the previous history entry.

    Retention keeps the last ``keep`` saved versions but never deletes
    a version still on the promotion history (rollback must always have
    somewhere to land).
    """

    _PTR = "CURRENT"

    def __init__(self, path: str, keep: int = 4):
        self.path = path
        self.keep = keep
        os.makedirs(path, exist_ok=True)

    # -- pointer ----------------------------------------------------
    def _read_ptr(self) -> dict:
        """Read the pointer; a torn or garbage ``CURRENT`` (power loss
        mid-write on a filesystem that reordered the rename past the
        data blocks) falls back to the newest *intact* saved version
        instead of raising — the service comes back serving something
        real rather than refusing to start."""
        p = os.path.join(self.path, self._PTR)
        if not os.path.exists(p):
            return {"current": None, "history": []}
        try:
            with open(p) as f:
                ptr = json.load(f)
            if (not isinstance(ptr, dict) or "current" not in ptr
                    or not isinstance(ptr.get("history"), list)):
                raise ValueError(f"malformed pointer {ptr!r}")
            return ptr
        except (ValueError, OSError):
            return self._recover_ptr()

    def _recover_ptr(self) -> dict:
        """Newest intact version wins; history is unrecoverable (the
        trail lived only in the pointer) so rollback starts empty.  The
        recovered pointer is NOT persisted here — reads stay read-only;
        the next promote rewrites ``CURRENT`` durably."""
        for v in sorted(self.versions(), reverse=True):
            if self._intact(v):
                return {"current": v, "history": []}
        return {"current": None, "history": []}

    def _intact(self, version: int) -> bool:
        """Cheap integrity probe: manifest parses, every leaf file is
        present with a readable ``.npy`` header."""
        d = os.path.join(self.path, f"step_{version:08d}")
        try:
            with open(os.path.join(d, "manifest.json")) as f:
                manifest = json.load(f)
            for i in range(int(manifest["n_leaves"])):
                np.load(os.path.join(d, f"leaf_{i:05d}.npy"),
                        mmap_mode="r", allow_pickle=False)
            return True
        except Exception:
            return False

    def _write_ptr(self, ptr: dict) -> None:
        tmp = os.path.join(self.path, f".{self._PTR}.tmp")
        with open(tmp, "w") as f:
            json.dump(ptr, f)
            f.flush()
            os.fsync(f.fileno())     # data durable BEFORE the rename
        os.replace(tmp, os.path.join(self.path, self._PTR))
        _fsync_dir(self.path)        # ...and the rename itself durable

    def current(self) -> int | None:
        return self._read_ptr()["current"]

    def history(self) -> list[int]:
        return list(self._read_ptr()["history"])

    # -- versions ---------------------------------------------------
    def save_version(self, version: int, tree) -> str:
        """Persist a candidate. Does NOT change what is served."""
        out = save(self.path, version, tree, keep=10 ** 9)
        self._retain()
        return out

    def load_version(self, version: int, like_tree):
        return restore(self.path, version, like_tree)

    def promote(self, version: int) -> None:
        """Flip the serving pointer to ``version`` (must be saved)."""
        if not os.path.isdir(
                os.path.join(self.path, f"step_{version:08d}")):
            raise FileNotFoundError(f"version {version} not saved")
        ptr = self._read_ptr()
        if ptr["current"] is not None and ptr["current"] != version:
            ptr["history"].append(ptr["current"])
        ptr["current"] = version
        self._write_ptr(ptr)
        self._retain()

    def rollback(self) -> int | None:
        """Demote current to its predecessor; returns the new current
        version, or ``None`` if there is no history to land on."""
        ptr = self._read_ptr()
        if not ptr["history"]:
            return None
        ptr["current"] = ptr["history"].pop()
        self._write_ptr(ptr)
        return ptr["current"]

    def versions(self) -> list[int]:
        return sorted(int(d.split("_")[1])
                      for d in os.listdir(self.path)
                      if d.startswith("step_"))

    def _retain(self) -> None:
        ptr = self._read_ptr()
        pinned = set(ptr["history"])
        if ptr["current"] is not None:
            pinned.add(ptr["current"])
        vs = self.versions()
        for v in vs[:-self.keep] if len(vs) > self.keep else []:
            if v not in pinned:
                shutil.rmtree(
                    os.path.join(self.path, f"step_{v:08d}"))


class AsyncCheckpointer:
    """Background-thread writer; the step loop only pays device->host."""

    def __init__(self, path: str, keep: int = 3):
        self.path = path
        self.keep = keep
        self._q: queue.Queue = queue.Queue(maxsize=2)
        self._err: Exception | None = None
        self._t = threading.Thread(target=self._run, daemon=True)
        self._t.start()

    def _run(self):
        while True:
            item = self._q.get()
            try:
                if item is None:
                    return
                step, tree = item
                save(self.path, step, tree, keep=self.keep)
            except Exception as e:  # surfaced on next submit/flush/close
                self._err = e
            finally:
                self._q.task_done()

    def submit(self, step: int, tree) -> None:
        if self._err:
            raise self._err
        host_tree = jax.tree_util.tree_map(
            lambda x: np.asarray(jax.device_get(x)), tree)
        self._q.put((step, host_tree))

    def flush(self) -> None:
        """Block until every submitted checkpoint is durably on disk."""
        self._q.join()
        if self._err:
            raise self._err

    def close(self) -> None:
        self._q.put(None)
        self._t.join()
        if self._err:
            raise self._err
