"""Deterministic synthetic LM data pipeline.

Tokens follow a noisy affine recurrence t_{i+1} = (a*t_i + b) mod V with
epsilon-uniform corruption — structured enough that a model visibly learns
(loss drops well below log V), fully deterministic per (seed, step, shard),
and generable on every host independently (no host-to-host data traffic:
each data shard derives its slice from its shard index, the standard
trick for synthetic scale tests).
"""
from __future__ import annotations

import dataclasses

import numpy as np

import jax.numpy as jnp


@dataclasses.dataclass
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    noise: float = 0.1
    a: int = 31
    b: int = 7


class SyntheticLM:
    def __init__(self, cfg: DataConfig, shard_index: int = 0,
                 shard_count: int = 1):
        assert cfg.global_batch % shard_count == 0
        self.cfg = cfg
        self.shard_index = shard_index
        self.shard_count = shard_count
        self.local_batch = cfg.global_batch // shard_count

    def batch(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed, step, self.shard_index))
        b, s = self.local_batch, cfg.seq_len
        toks = np.empty((b, s + 1), np.int64)
        toks[:, 0] = rng.integers(0, cfg.vocab, b)
        noise = rng.random((b, s)) < cfg.noise
        rand = rng.integers(0, cfg.vocab, (b, s))
        for i in range(s):
            nxt = (cfg.a * toks[:, i] + cfg.b) % cfg.vocab
            toks[:, i + 1] = np.where(noise[:, i], rand[:, i], nxt)
        return {
            "tokens": jnp.asarray(toks[:, :-1], jnp.int32),
            "labels": jnp.asarray(toks[:, 1:], jnp.int32),
        }

    def batches(self, start: int, n: int):
        for step in range(start, start + n):
            yield self.batch(step)
