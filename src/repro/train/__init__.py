from repro.train import checkpoint, data, optimizer, trainer

__all__ = ["checkpoint", "data", "optimizer", "trainer"]
