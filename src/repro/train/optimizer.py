"""Optimizers: AdamW (fp32 moments) and a factored-second-moment variant
("adafactor" mode) for the 398B/671B configs where full Adam state cannot
fit a single pod. Pure pytree functions; state sharding mirrors params.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class OptConfig:
    kind: str = "adamw"            # adamw | adafactor
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: str = "float32"  # adafactor mode uses bf16 first moment


class OptState(NamedTuple):
    step: jax.Array
    m: Any            # first moment (adamw + adafactor)
    v: Any            # second moment (adamw) | None
    v_row: Any        # factored second moment (adafactor) | None
    v_col: Any


def schedule(cfg: OptConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay to min_lr_frac."""
    s = step.astype(jnp.float32)
    warm = s / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((s - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) \
        * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(s < cfg.warmup_steps, warm, cos)


def _factored(shape) -> bool:
    return len(shape) >= 2 and shape[-1] > 1 and shape[-2] > 1


def init(cfg: OptConfig, params: Any) -> OptState:
    mdt = jnp.dtype(cfg.moment_dtype)
    if cfg.kind == "adamw":
        z = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, mdt), params)
        return OptState(jnp.zeros((), jnp.int32), z,
                        jax.tree_util.tree_map(
                            lambda p: jnp.zeros(p.shape, mdt), params),
                        None, None)
    # adafactor: bf16 m; factored fp32 v for matrices, full fp32 for vectors
    m = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.bfloat16), params)
    v_row = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape[:-1], jnp.float32)
        if _factored(p.shape) else jnp.zeros((1,), jnp.float32), params)
    v_col = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
        if _factored(p.shape) else jnp.zeros(p.shape, jnp.float32), params)
    return OptState(jnp.zeros((), jnp.int32), m, None, v_row, v_col)


def _global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree_util.tree_leaves(tree)))


def update(cfg: OptConfig, grads: Any, state: OptState, params: Any
           ) -> tuple[Any, OptState, dict]:
    step = state.step + 1
    lr = schedule(cfg, step)
    gnorm = _global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    grads = jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale), grads)
    tf = step.astype(jnp.float32)
    bc1 = 1 - cfg.b1 ** tf
    bc2 = 1 - cfg.b2 ** tf

    if cfg.kind == "adamw":
        m = jax.tree_util.tree_map(
            lambda m_, g: (cfg.b1 * m_.astype(jnp.float32)
                           + (1 - cfg.b1) * g).astype(m_.dtype),
            state.m, grads)
        v = jax.tree_util.tree_map(
            lambda v_, g: (cfg.b2 * v_.astype(jnp.float32)
                           + (1 - cfg.b2) * g * g).astype(v_.dtype),
            state.v, grads)

        def upd(p, m_, v_):
            mh = m_.astype(jnp.float32) / bc1
            vh = v_.astype(jnp.float32) / bc2
            step_ = mh / (jnp.sqrt(vh) + cfg.eps)
            if p.ndim >= 2:  # decoupled weight decay on matrices only
                step_ = step_ + cfg.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * step_).astype(p.dtype)

        new_params = jax.tree_util.tree_map(upd, params, m, v)
        new_state = OptState(step, m, v, None, None)
    else:  # adafactor-style
        m = jax.tree_util.tree_map(
            lambda m_, g: (cfg.b1 * m_.astype(jnp.float32)
                           + (1 - cfg.b1) * g).astype(jnp.bfloat16),
            state.m, grads)

        def vrow_up(vr, g):
            if _factored(g.shape):
                return cfg.b2 * vr + (1 - cfg.b2) * jnp.mean(g * g, -1)
            return vr

        def vcol_up(vc, g):
            if _factored(g.shape):
                return cfg.b2 * vc + (1 - cfg.b2) * jnp.mean(g * g, -2)
            return cfg.b2 * vc + (1 - cfg.b2) * g * g

        v_row = jax.tree_util.tree_map(vrow_up, state.v_row, grads)
        v_col = jax.tree_util.tree_map(vcol_up, state.v_col, grads)

        def upd(p, m_, vr, vc, g):
            if _factored(g.shape):
                r = vr / bc2            # (..., rows)
                c = vc / bc2            # (..., cols)
                denom = jnp.sqrt(
                    r[..., :, None] * c[..., None, :]
                    / jnp.maximum(jnp.mean(r, -1, keepdims=True)
                                  [..., None], 1e-30)) + cfg.eps
            else:
                denom = jnp.sqrt(vc / bc2) + cfg.eps
            step_ = (m_.astype(jnp.float32) / bc1) / denom
            if p.ndim >= 2:
                step_ = step_ + cfg.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * step_).astype(p.dtype)

        new_params = jax.tree_util.tree_map(upd, params, m, v_row, v_col,
                                            grads)
        new_state = OptState(step, m, None, v_row, v_col)
    return new_params, new_state, {"lr": lr, "grad_norm": gnorm}


# ------------------------- state sharding specs -----------------------------


def opt_specs(cfg: OptConfig, param_spec_tree: Any, params_sds: Any
              ) -> OptState:
    """PartitionSpec pytree for the optimizer state: moments mirror the
    param spec; factored vectors drop the corresponding dim."""
    if cfg.kind == "adamw":
        return OptState(P(), param_spec_tree, param_spec_tree, None, None)

    def row_spec(spec, p):
        if _factored(p.shape):
            return P(*tuple(spec)[:-1]) if len(tuple(spec)) else P()
        return P()

    def col_spec(spec, p):
        t = tuple(spec)
        if _factored(p.shape):
            return P(*(t[:-2] + t[-1:])) if len(t) >= 2 else P()
        return spec

    v_row = jax.tree_util.tree_map(row_spec, param_spec_tree, params_sds)
    v_col = jax.tree_util.tree_map(col_spec, param_spec_tree, params_sds)
    return OptState(P(), param_spec_tree, None, v_row, v_col)
