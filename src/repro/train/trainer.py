"""Training step construction: microbatched grad accumulation, sharded
optimizer update, metrics — one jit-compiled function per (model, mesh).

Memory shape: the scan over microbatches bounds live logits to one
microbatch (essential for 200k+ vocab configs); gradients accumulate in
fp32 (bf16 option for the 671B config). GSPMD inserts the FSDP
all-gathers / reduce-scatters and the data-axis gradient reduction from
the in_shardings alone.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.distributed import sharding as Sh
from repro.models.lm import Model
from repro.train import optimizer as Opt


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    n_micro: int = 1
    accum_dtype: str = "float32"   # bf16 for the 671B config
    remat: bool = True             # layer remat lives in the model scan


def auto_n_micro(global_batch: int, seq: int, vocab: int, n_data: int,
                 n_model: int = 1, n_layers: int = 32,
                 d_model: int = 4096,
                 budget_bytes: float = 4e9) -> int:
    """Smallest microbatch count whose per-device live memory fits.

    Memory model per device per microbatch:
      logits  = tokens_loc * (vocab / n_model) * 6  (f32 logits + bf16
                one-hot; vocab is TP-sharded since iteration 0c)
      remat   = n_layers * tokens_loc * d_model * 2 (scan carries)
    Fewer microbatches = fewer FSDP weight regathers (iteration 1), so we
    take the SMALLEST feasible n.

    Hard cap: each microbatch must still cover every data shard
    (global_batch/n >= n_data), otherwise GSPMD replicates the batch and
    every device silently computes the whole microbatch (measured 3.5x
    per-device FLOPs — see EXPERIMENTS.md §Perf iteration 0)."""
    cap = max(global_batch // max(n_data, 1), 1)
    n = 1
    while n < cap:
        tokens_loc = global_batch * seq / max(n_data, 1) / n
        logits = tokens_loc * (vocab / max(n_model, 1)) * 6
        remat = n_layers * tokens_loc * d_model * 2
        if logits + remat <= budget_bytes:
            break
        n *= 2
    return min(n, cap)


def make_train_step(model: Model, opt_cfg: Opt.OptConfig,
                    tcfg: TrainConfig = TrainConfig(), mesh=None,
                    dp_axes: tuple | None = None, grad_specs=None):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state,
    metrics). Pure; jit/lower outside.

    ``mesh``/``dp_axes``: microbatch slices are sharding-constrained to the
    dp axes so the scan reshape can't lose the batch sharding.
    ``grad_specs``: per-microbatch grads are constrained to the param
    sharding, turning the gradient reduction into reduce-scatter instead
    of all-reduce-then-slice (EXPERIMENTS.md §Perf iteration 3)."""
    adt = jnp.dtype(tcfg.accum_dtype)
    mb_sharding = None
    if mesh is not None:
        dp = dp_axes if dp_axes is not None else Sh.dp_axes(mesh)
        mb_sharding = lambda x: NamedSharding(  # noqa: E731
            mesh, P(dp, *([None] * (x.ndim - 1))))

    def constrain_grads(g):
        if grad_specs is None or mesh is None:
            return g
        return jax.tree_util.tree_map(
            lambda x, sp: jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, sp)), g, grad_specs)

    def train_step(params, opt_state, batch):
        n_micro = tcfg.n_micro

        if n_micro == 1:
            loss, grads = jax.value_and_grad(model.loss_fn)(params, batch)
            grads = constrain_grads(grads)
        else:
            micro = jax.tree_util.tree_map(
                lambda x: x.reshape(n_micro, x.shape[0] // n_micro,
                                    *x.shape[1:]), batch)

            def body(g_acc, mb):
                if mb_sharding is not None:
                    mb = jax.tree_util.tree_map(
                        lambda x: jax.lax.with_sharding_constraint(
                            x, mb_sharding(x)), mb)
                loss, g = jax.value_and_grad(model.loss_fn)(params, mb)
                g = constrain_grads(g)
                g_acc = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(adt), g_acc, g)
                return g_acc, loss

            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, adt), params)
            g_acc, losses = jax.lax.scan(body, g0, micro)
            grads = jax.tree_util.tree_map(lambda g: g / n_micro, g_acc)
            loss = losses.mean()

        params, opt_state, om = Opt.update(opt_cfg, grads, opt_state,
                                           params)
        metrics = {"loss": loss.astype(jnp.float32), **om}
        return params, opt_state, metrics

    return train_step


@dataclasses.dataclass
class Trainer:
    """Binds a model to a mesh: sharded init, compiled step, checkpoint
    hooks, and the straggler-runtime callback point."""

    model: Model
    mesh: Any
    opt_cfg: Opt.OptConfig = Opt.OptConfig()
    tcfg: TrainConfig = TrainConfig()
    donate: bool = True

    def __post_init__(self):
        self.param_spec = None
        self.step_fn = None

    # -------- spec derivation (works from ShapeDtypeStructs, no alloc) -----

    def specs(self, batch_like):
        m = self.mesh
        params_sds = jax.eval_shape(
            lambda: self.model.init(jax.random.PRNGKey(0)))
        pspec = Sh.param_specs(params_sds, m)
        ospec = Opt.opt_specs(self.opt_cfg, pspec, params_sds)
        bspec = Sh.batch_specs_tree(batch_like, m)
        return params_sds, pspec, ospec, bspec

    def lower(self, batch_like):
        """Lower (no compile) the train step for the given batch specs."""
        params_sds, pspec, ospec, bspec = self.specs(batch_like)
        opt_sds = jax.eval_shape(
            functools.partial(Opt.init, self.opt_cfg), params_sds)
        fn = make_train_step(self.model, self.opt_cfg, self.tcfg,
                             mesh=self.mesh)
        ns = lambda s: jax.tree_util.tree_map(  # noqa: E731
            lambda sp: NamedSharding(self.mesh, sp), s,
            is_leaf=lambda x: isinstance(x, P))
        jfn = jax.jit(
            fn,
            in_shardings=(ns(pspec), ns(ospec), ns(bspec)),
            out_shardings=(ns(pspec), ns(ospec), None),
            donate_argnums=(0, 1) if self.donate else ())
        return jfn.lower(params_sds, opt_sds, batch_like)

    # ------------------------- concrete execution --------------------------

    def init_state(self, seed: int = 0):
        params = self.model.init(jax.random.PRNGKey(seed))
        opt_state = Opt.init(self.opt_cfg, params)
        if self.mesh is not None and len(self.mesh.devices.flatten()) > 1:
            pspec = Sh.param_specs(params, self.mesh)
            params = jax.tree_util.tree_map(
                lambda x, s: jax.device_put(
                    x, NamedSharding(self.mesh, s)), params, pspec)
        return params, opt_state

    def compile_step(self):
        fn = make_train_step(self.model, self.opt_cfg, self.tcfg)
        self.step_fn = jax.jit(fn, donate_argnums=(0, 1)
                               if self.donate else ())
        return self.step_fn
