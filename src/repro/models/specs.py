"""ShapeDtypeStruct input specs for every (arch x shape) cell.

The dry-run lowers against these stand-ins — weak-type-correct, shardable,
zero device allocation. Decode cache specs are derived with jax.eval_shape
of the model's own prefill, so they always match the real cache pytree.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.shapes import ShapeSpec
from repro.models.config import ModelConfig
from repro.models.lm import Model

SDS = jax.ShapeDtypeStruct


def batch_specs(cfg: ModelConfig, batch: int, seq: int,
                with_labels: bool) -> dict:
    d: dict = {}
    if cfg.family == "encdec":
        d["frame_embeds"] = SDS((batch, cfg.frontend_tokens, cfg.d_model),
                                jnp.bfloat16)
        d["tokens"] = SDS((batch, seq), jnp.int32)
    elif cfg.family == "vlm":
        d["patch_embeds"] = SDS((batch, cfg.frontend_tokens, cfg.d_model),
                                jnp.bfloat16)
        d["tokens"] = SDS((batch, seq - cfg.frontend_tokens), jnp.int32)
    else:
        d["tokens"] = SDS((batch, seq), jnp.int32)
    if with_labels:
        d["labels"] = SDS(d["tokens"].shape, jnp.int32)
    return d


def params_specs(model: Model, seed: int = 0):
    return jax.eval_shape(lambda: model.init(jax.random.PRNGKey(seed)))


def input_specs(model: Model, shape: ShapeSpec) -> dict:
    """Returns {mode-specific inputs} for lowering, keyed per shape.kind:
      train   -> {batch}
      prefill -> {batch}
      decode  -> {caches, tokens, pos} (cache specs via eval_shape(prefill))
    """
    cfg = model.cfg
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        return {"batch": batch_specs(cfg, b, s, True)}
    if shape.kind == "prefill":
        return {"batch": batch_specs(cfg, b, s, False)}
    # decode: caches sized for a seq_len context
    pb = batch_specs(cfg, b, s, False)
    ps = params_specs(model)
    _, caches = jax.eval_shape(model.prefill, ps, pb)
    return {
        "caches": caches,
        "tokens": SDS((b, 1), jnp.int32),
        "pos": SDS((), jnp.int32),
    }
