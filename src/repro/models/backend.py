"""Kernel backend dispatch.

Pallas kernels lower only on TPU; on CPU (tests, dry-run) the pure-jnp
oracles run under jit and XLA fuses them. ``use_pallas(True)`` switches the
hot paths to the Pallas kernels (the TPU deployment default); kernels are
also validated in interpret mode by tests/test_kernels.py.
"""
from __future__ import annotations

import jax

from repro.kernels.decode_attention.ops import (decode_attention as
                                                _decode_pallas)
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.flash_attention.ops import (flash_attention as
                                               _flash_pallas)
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.mamba_scan.ops import mamba_scan as _scan_pallas
from repro.kernels.mamba_scan.ref import mamba_scan_ref
from repro.kernels.moe_router.ops import moe_router as _router_pallas
from repro.kernels.moe_router.ref import moe_router_ref

_USE_PALLAS = False


def use_pallas(on: bool = True) -> None:
    global _USE_PALLAS
    _USE_PALLAS = on


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def attention(q, k, v, *, causal: bool = True):
    if _USE_PALLAS:
        return _flash_pallas(q, k, v, causal, None, 128, 128,
                             not _on_tpu())
    return attention_ref(q, k, v, causal=causal)


def decode_attention(q, k, v, *, kv_len=None):
    if _USE_PALLAS and isinstance(kv_len, int):
        return _decode_pallas(q, k, v, kv_len=kv_len,
                              interpret=not _on_tpu())
    return decode_attention_ref(q, k, v, kv_len=kv_len)


def mamba_scan(u, delta, a, b, c, skip):
    if _USE_PALLAS:
        return _scan_pallas(u, delta, a, b, c, skip,
                            interpret=not _on_tpu())
    return mamba_scan_ref(u, delta, a, b, c, skip)


def moe_router(logits, k: int):
    if _USE_PALLAS:
        return _router_pallas(logits, k, interpret=not _on_tpu())
    return moe_router_ref(logits, k)
