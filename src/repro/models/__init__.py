"""Model zoo: composable JAX definitions of the 10 assigned architectures."""
from repro.models.config import ModelConfig
from repro.models.lm import EPSetup, Model

__all__ = ["ModelConfig", "Model", "EPSetup"]
