"""Model configuration shared by all 10 assigned architectures."""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int = 0
    n_kv_heads: int = 0
    d_ff: int = 0
    vocab: int = 32000
    head_dim: Optional[int] = None   # default d_model // n_heads

    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0            # expert hidden dim (0 -> use d_ff)
    first_dense_layers: int = 0  # deepseek-v3: first k layers are dense
    dense_d_ff: int = 0          # ff dim of those dense layers
    moe_every: int = 1           # jamba: MoE on every `moe_every`-th layer
    capacity_factor: float = 1.25

    # --- MLA (deepseek-v3) ---
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # --- SSM (mamba-1) ---
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_dt_rank: int = 0         # 0 -> d_model // 16

    # --- hybrid (jamba) ---
    attn_period: int = 0         # 1 attention layer per `attn_period` layers

    # --- enc-dec (seamless) ---
    encoder_layers: int = 0      # >0 -> encoder-decoder

    # --- modality frontend stubs ---
    frontend: Optional[str] = None  # "vit" | "audio"
    frontend_tokens: int = 0        # precomputed embedding tokens (stub)

    # --- misc ---
    rope_theta: float = 1e4
    norm_eps: float = 1e-5
    param_dtype: str = "bfloat16"
    tie_embeddings: bool = False

    @property
    def hd(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to a TP-friendly multiple (Megatron-style):
        2048 = 16-way model parallel x 128 lanes. Odd vocab sizes
        (92553, 256206) would otherwise leave the logits unsharded —
        measured 4x temp memory on seamless (EXPERIMENTS.md §Perf).
        Reduced/smoke configs (< 8192) are left unpadded."""
        if self.vocab < 8192 or self.vocab % 2048 == 0:
            return self.vocab
        return ((self.vocab + 2047) // 2048) * 2048

    @property
    def dtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def dt_rank(self) -> int:
        return self.ssm_dt_rank or max(self.d_model // 16, 1)

    @property
    def expert_ff(self) -> int:
        return self.moe_d_ff or self.d_ff

    def is_attention_layer(self, i: int) -> bool:
        if self.family == "ssm":
            return False
        if self.family == "hybrid" and self.attn_period:
            return i % self.attn_period == self.attn_period - 1
        return True

    def is_moe_layer(self, i: int) -> bool:
        if self.n_experts == 0:
            return False
        if i < self.first_dense_layers:
            return False
        return (i % self.moe_every) == (self.moe_every - 1) \
            if self.moe_every > 1 else True

    # ------------------------- parameter counting --------------------------

    def param_count(self) -> int:
        """Analytic parameter count (used for 6*N*D roofline numbers)."""
        d, hd = self.d_model, self.hd
        n = 0
        n += self.padded_vocab * d               # embed
        if not self.tie_embeddings:
            n += self.padded_vocab * d           # lm head
        enc_layers = self.encoder_layers
        for i in range(self.n_layers + enc_layers):
            dec_i = i - enc_layers
            is_enc = i < enc_layers
            li = i if is_enc else dec_i
            if is_enc or self.is_attention_layer(li):
                if self.use_mla:
                    n += d * self.q_lora_rank
                    n += self.q_lora_rank * self.n_heads * (
                        self.qk_nope_dim + self.qk_rope_dim)
                    n += d * (self.kv_lora_rank + self.qk_rope_dim)
                    n += self.kv_lora_rank * self.n_heads * (
                        self.qk_nope_dim + self.v_head_dim)
                    n += self.n_heads * self.v_head_dim * d
                else:
                    n += d * self.n_heads * hd            # wq
                    n += 2 * d * self.n_kv_heads * hd     # wk, wv
                    n += self.n_heads * hd * d            # wo
                if not is_enc and enc_layers:             # cross attention
                    n += d * self.n_heads * hd
                    n += 2 * d * self.n_kv_heads * hd
                    n += self.n_heads * hd * d
            elif self.family in ("ssm", "hybrid"):
                di, dn = self.d_inner, self.ssm_state
                n += d * 2 * di                 # in_proj
                n += di * self.ssm_conv         # depthwise conv
                n += di * (self.dt_rank + 2 * dn)  # x_proj
                n += self.dt_rank * di          # dt_proj
                n += di * dn + di               # A_log, D
                n += di * d                     # out_proj
            if is_enc or not self.is_moe_layer(li):
                ff = self.dense_d_ff or self.d_ff
                if ff and self.family != "ssm":
                    n += 3 * d * ff             # swiglu
            else:
                n += d * self.n_experts         # router
                n += self.n_experts * 3 * d * self.expert_ff
                n += self.n_shared_experts * 3 * d * self.expert_ff
            n += 2 * d                          # norms
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k + shared experts only)."""
        if self.n_experts == 0:
            return self.param_count()
        full = self.param_count()
        n_moe_layers = sum(self.is_moe_layer(i)
                           for i in range(self.n_layers))
        inactive = n_moe_layers * (self.n_experts - self.top_k) \
            * 3 * self.d_model * self.expert_ff
        return full - inactive
