"""Shared transformer layers: RMSNorm, RoPE, SwiGLU, GQA attention, MLA.

Pure-functional: ``init_*`` builds param dicts, ``*_apply`` consumes them.
Attention has three entry points per variant: train (full causal), prefill
(causal, returns KV cache), decode (single token against a cache). The
Pallas kernels are the TPU fast path; on CPU / in the dry-run the jnp
oracle runs (kernels lower only on TPU) — see repro.models.backend.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.flash_attention.ref import attention_ref
from repro.models import backend
from repro.models.config import ModelConfig


def norm_init(d: int) -> dict:
    return {"w": jnp.ones((d,), jnp.float32)}


def rms_norm(p: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * p["w"]).astype(x.dtype)


def dense_init(key, n_in: int, n_out: int, dtype) -> jax.Array:
    scale = n_in ** -0.5
    return (jax.random.normal(key, (n_in, n_out), jnp.float32)
            * scale).astype(dtype)


# --------------------------------- RoPE ------------------------------------


def rope_table(seq: int, dim: int, theta: float = 1e4
               ) -> tuple[jax.Array, jax.Array]:
    """(seq, dim/2) cos/sin tables."""
    inv = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    t = jnp.arange(seq, dtype=jnp.float32)
    freqs = jnp.outer(t, inv)
    return jnp.cos(freqs), jnp.sin(freqs)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (..., S, H, D) rotated pairwise; cos/sin: (S, D/2)."""
    d2 = x.shape[-1] // 2
    x1, x2 = x[..., :d2], x[..., d2:]
    cs = cos[None, :, None, :]
    sn = sin[None, :, None, :]
    return jnp.concatenate([x1 * cs - x2 * sn, x2 * cs + x1 * sn],
                           axis=-1).astype(x.dtype)


# -------------------------------- SwiGLU -----------------------------------


def mlp_init(key, d: int, ff: int, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {"wg": dense_init(k1, d, ff, dtype),
            "wu": dense_init(k2, d, ff, dtype),
            "wd": dense_init(k3, ff, d, dtype)}


def mlp_apply(p: dict, x: jax.Array) -> jax.Array:
    h = jax.nn.silu(x @ p["wg"]) * (x @ p["wu"])
    return h @ p["wd"]


# ----------------------------- GQA attention -------------------------------


def attn_init(key, cfg: ModelConfig, d_kv_src: int | None = None) -> dict:
    """d_kv_src: source dim of K/V projections (cross-attention)."""
    d, hd = cfg.d_model, cfg.hd
    dkv = d_kv_src or d
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": dense_init(k1, d, cfg.n_heads * hd, cfg.dtype),
        "wk": dense_init(k2, dkv, cfg.n_kv_heads * hd, cfg.dtype),
        "wv": dense_init(k3, dkv, cfg.n_kv_heads * hd, cfg.dtype),
        "wo": dense_init(k4, cfg.n_heads * hd, d, cfg.dtype),
    }


def _qkv(p, cfg, x, kv_src=None):
    b, s, _ = x.shape
    kv_src = x if kv_src is None else kv_src
    sk = kv_src.shape[1]
    q = (x @ p["wq"]).reshape(b, s, cfg.n_heads, cfg.hd)
    k = (kv_src @ p["wk"]).reshape(b, sk, cfg.n_kv_heads, cfg.hd)
    v = (kv_src @ p["wv"]).reshape(b, sk, cfg.n_kv_heads, cfg.hd)
    return q, k, v


def attn_apply(p: dict, cfg: ModelConfig, x: jax.Array,
               cos: jax.Array, sin: jax.Array, *,
               causal: bool = True) -> jax.Array:
    """Full-sequence attention (train / encoder)."""
    b, s, _ = x.shape
    q, k, v = _qkv(p, cfg, x)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    o = backend.attention(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                          v.transpose(0, 2, 1, 3), causal=causal)
    return o.transpose(0, 2, 1, 3).reshape(b, s, -1) @ p["wo"]


def cross_attn_apply(p: dict, cfg: ModelConfig, x: jax.Array,
                     enc: jax.Array) -> jax.Array:
    """Decoder cross-attention over encoder states (no RoPE, non-causal)."""
    b, s, _ = x.shape
    q, k, v = _qkv(p, cfg, x, kv_src=enc)
    o = backend.attention(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                          v.transpose(0, 2, 1, 3), causal=False)
    return o.transpose(0, 2, 1, 3).reshape(b, s, -1) @ p["wo"]


def attn_prefill(p: dict, cfg: ModelConfig, x: jax.Array,
                 cos: jax.Array, sin: jax.Array
                 ) -> tuple[jax.Array, dict]:
    """Causal attention returning the (B, Hkv, S, hd) KV cache."""
    b, s, _ = x.shape
    q, k, v = _qkv(p, cfg, x)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    kc = k.transpose(0, 2, 1, 3)
    vc = v.transpose(0, 2, 1, 3)
    o = backend.attention(q.transpose(0, 2, 1, 3), kc, vc, causal=True)
    out = o.transpose(0, 2, 1, 3).reshape(b, s, -1) @ p["wo"]
    return out, {"k": kc, "v": vc}


def attn_decode(p: dict, cfg: ModelConfig, x: jax.Array, cache: dict,
                pos: jax.Array, cos_t: jax.Array, sin_t: jax.Array
                ) -> tuple[jax.Array, dict]:
    """One-token decode. x: (B, 1, d); cache k/v: (B, Hkv, S, hd);
    pos: () current position; cos_t/sin_t: (1, hd/2) tables at pos."""
    b = x.shape[0]
    q, k, v = _qkv(p, cfg, x)
    q = apply_rope(q, cos_t, sin_t)[:, 0]          # (B, H, hd)
    k = apply_rope(k, cos_t, sin_t)[:, 0]          # (B, Hkv, hd)
    v = v[:, 0]
    kc = jax.lax.dynamic_update_slice_in_dim(
        cache["k"], k[:, :, None, :].astype(cache["k"].dtype), pos, axis=2)
    vc = jax.lax.dynamic_update_slice_in_dim(
        cache["v"], v[:, :, None, :].astype(cache["v"].dtype), pos, axis=2)
    o = backend.decode_attention(q.transpose(0, 1, 2), kc, vc,
                                 kv_len=pos + 1)
    out = o.reshape(b, 1, -1) @ p["wo"]
    return out, {"k": kc, "v": vc}


# ------------------------ MLA (multi-head latent) ---------------------------


def mla_init(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    h = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    dq, dc = cfg.q_lora_rank, cfg.kv_lora_rank
    ks = jax.random.split(key, 6)
    return {
        "wq_a": dense_init(ks[0], d, dq, cfg.dtype),
        "q_norm": norm_init(dq),
        "wq_b": dense_init(ks[1], dq, h * (dn + dr), cfg.dtype),
        "wkv_a": dense_init(ks[2], d, dc + dr, cfg.dtype),
        "kv_norm": norm_init(dc),
        "wkv_b": dense_init(ks[3], dc, h * (dn + dv), cfg.dtype),
        "wo": dense_init(ks[4], h * dv, d, cfg.dtype),
    }


def _mla_q(p, cfg, x, cos, sin):
    b, s, _ = x.shape
    h = cfg.n_heads
    dn, dr = cfg.qk_nope_dim, cfg.qk_rope_dim
    q = rms_norm(p["q_norm"], x @ p["wq_a"], cfg.norm_eps) @ p["wq_b"]
    q = q.reshape(b, s, h, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, cos, sin)
    return q_nope, q_rope


def mla_apply(p: dict, cfg: ModelConfig, x: jax.Array,
              cos: jax.Array, sin: jax.Array) -> jax.Array:
    """Training path: expand K/V from the latent and run causal MHA."""
    b, s, _ = x.shape
    h = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    dc = cfg.kv_lora_rank
    q_nope, q_rope = _mla_q(p, cfg, x, cos, sin)
    kv = x @ p["wkv_a"]
    c_kv = rms_norm(p["kv_norm"], kv[..., :dc], cfg.norm_eps)
    k_rope = apply_rope(kv[..., None, dc:], cos, sin)      # (B,S,1,dr)
    kvup = (c_kv @ p["wkv_b"]).reshape(b, s, h, dn + dv)
    k_nope, v = kvup[..., :dn], kvup[..., dn:]
    q = jnp.concatenate([q_nope, q_rope], -1)
    k = jnp.concatenate([k_nope,
                         jnp.broadcast_to(k_rope, (b, s, h, dr))], -1)
    sm = (dn + dr) ** -0.5
    o = attention_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                      v.transpose(0, 2, 1, 3), causal=True, sm_scale=sm)
    return o.transpose(0, 2, 1, 3).reshape(b, s, -1) @ p["wo"]


def mla_prefill(p: dict, cfg: ModelConfig, x: jax.Array, cos, sin
                ) -> tuple[jax.Array, dict]:
    """Prefill storing only the compressed latent cache (MLA's memory win):
    cache = {c_kv: (B, S, dc), k_rope: (B, S, dr)}."""
    b, s, _ = x.shape
    dc = cfg.kv_lora_rank
    out = mla_apply(p, cfg, x, cos, sin)
    kv = x @ p["wkv_a"]
    c_kv = rms_norm(p["kv_norm"], kv[..., :dc], cfg.norm_eps)
    k_rope = apply_rope(kv[..., None, dc:], cos, sin)[:, :, 0]
    return out, {"c_kv": c_kv, "k_rope": k_rope}


def mla_decode(p: dict, cfg: ModelConfig, x: jax.Array, cache: dict,
               pos: jax.Array, cos_t, sin_t) -> tuple[jax.Array, dict]:
    """Absorbed-matrix decode entirely in latent space (DeepSeek-V2 §MLA):
    scores_h,s = <W_UK_h^T q_nope_h, c_s> + <q_rope_h, k_rope_s>;
    out_h = W_UV_h (sum_s p_s c_s). Cost per token: O(S*(dc+dr)) instead of
    O(S*H*(dn+dv)) — the KV cache stays (B, S, dc+dr)."""
    b = x.shape[0]
    h = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    dc = cfg.kv_lora_rank
    q_nope, q_rope = _mla_q(p, cfg, x, cos_t, sin_t)       # (B,1,H,*)
    q_nope, q_rope = q_nope[:, 0], q_rope[:, 0]            # (B,H,dn/dr)
    kv = (x @ p["wkv_a"])[:, 0]
    c_t = rms_norm(p["kv_norm"], kv[..., :dc], cfg.norm_eps)
    kr_t = apply_rope(kv[:, None, None, dc:], cos_t, sin_t)[:, 0, 0]
    c_kv = jax.lax.dynamic_update_slice_in_dim(
        cache["c_kv"], c_t[:, None].astype(cache["c_kv"].dtype), pos, axis=1)
    k_rope = jax.lax.dynamic_update_slice_in_dim(
        cache["k_rope"], kr_t[:, None].astype(cache["k_rope"].dtype),
        pos, axis=1)
    # absorb W_UK into q:  q_lat (B, H, dc)
    wkv_b = p["wkv_b"].reshape(dc, h, dn + dv)
    w_uk = wkv_b[..., :dn]                                  # (dc, H, dn)
    w_uv = wkv_b[..., dn:]                                  # (dc, H, dv)
    q_lat = jnp.einsum("bhn,chn->bhc", q_nope.astype(jnp.float32),
                       w_uk.astype(jnp.float32))
    qq = jnp.concatenate([q_lat, q_rope.astype(jnp.float32)], -1)
    kk = jnp.concatenate([c_kv, k_rope], -1)[:, None]       # (B,1,S,dc+dr)
    sm = (dn + dr) ** -0.5
    o_lat = decode_attention_ref(
        qq, kk, c_kv[:, None], sm_scale=sm, kv_len=pos + 1)  # (B,H,dc)
    out = jnp.einsum("bhc,chv->bhv", o_lat.astype(jnp.float32),
                     w_uv.astype(jnp.float32))
    out = out.reshape(b, 1, h * dv).astype(x.dtype) @ p["wo"]
    return out, {"c_kv": c_kv, "k_rope": k_rope}
