"""Mamba-1 block (falcon-mamba / jamba hybrid layers).

in_proj -> (x, z); causal depthwise conv1d + silu on x; data-dependent
(delta, B, C) from x_proj; selective scan (Pallas kernel on TPU, jnp oracle
elsewhere); gate by silu(z); out_proj. Decode path carries (conv window,
ssm state) per layer.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import backend
from repro.models.config import ModelConfig
from repro.models.layers import dense_init


def mamba_init(key, cfg: ModelConfig) -> dict:
    d, di, n = cfg.d_model, cfg.d_inner, cfg.ssm_state
    dt_rank = cfg.dt_rank
    ks = jax.random.split(key, 6)
    a_init = jnp.log(jnp.broadcast_to(
        jnp.arange(1, n + 1, dtype=jnp.float32), (di, n)))
    return {
        "in_proj": dense_init(ks[0], d, 2 * di, cfg.dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv, di), jnp.float32)
                   * (cfg.ssm_conv ** -0.5)).astype(cfg.dtype),
        "conv_b": jnp.zeros((di,), cfg.dtype),
        "x_proj": dense_init(ks[2], di, dt_rank + 2 * n, cfg.dtype),
        "dt_proj": dense_init(ks[3], dt_rank, di, cfg.dtype),
        "dt_bias": jnp.zeros((di,), jnp.float32),
        "a_log": a_init,                       # A = -exp(a_log)  (D, N)
        "skip": jnp.ones((di,), jnp.float32),  # D
        "out_proj": dense_init(ks[4], di, d, cfg.dtype),
    }


def _conv1d_causal(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv. x: (B, L, Di); w: (K, Di)."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :]
              for i in range(k))
    return out + b[None, None, :]


def _ssm_inputs(p, cfg, xc):
    """xc: (B, L, Di) post-conv activations -> (delta, B, C)."""
    n, dtr = cfg.ssm_state, cfg.dt_rank
    proj = xc @ p["x_proj"]                        # (B, L, dtr + 2N)
    dt = proj[..., :dtr] @ p["dt_proj"]            # (B, L, Di)
    delta = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    bmat = proj[..., dtr:dtr + n]
    cmat = proj[..., dtr + n:]
    return delta.astype(xc.dtype), bmat, cmat


def mamba_apply(p: dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    """Full-sequence path. x: (B, L, d)."""
    di = cfg.d_inner
    xz = x @ p["in_proj"]
    xin, z = xz[..., :di], xz[..., di:]
    xc = jax.nn.silu(_conv1d_causal(xin, p["conv_w"], p["conv_b"]))
    delta, bmat, cmat = _ssm_inputs(p, cfg, xc)
    a = -jnp.exp(p["a_log"])
    y = backend.mamba_scan(xc, delta, a, bmat, cmat, p["skip"])
    return (y * jax.nn.silu(z)) @ p["out_proj"]


def mamba_prefill(p: dict, cfg: ModelConfig, x: jax.Array
                  ) -> tuple[jax.Array, dict]:
    """Full-sequence pass that also returns the recurrent decode state."""
    di, n, k = cfg.d_inner, cfg.ssm_state, cfg.ssm_conv
    b, ell, _ = x.shape
    xz = x @ p["in_proj"]
    xin, z = xz[..., :di], xz[..., di:]
    xc = jax.nn.silu(_conv1d_causal(xin, p["conv_w"], p["conv_b"]))
    delta, bmat, cmat = _ssm_inputs(p, cfg, xc)
    a = -jnp.exp(p["a_log"])
    y, hf = _scan_with_state(xc, delta, a, bmat, cmat, p["skip"])
    out = (y * jax.nn.silu(z)) @ p["out_proj"]
    conv_state = xin[:, -(k - 1):, :] if ell >= k - 1 else jnp.pad(
        xin, ((0, 0), (k - 1 - ell, 0), (0, 0)))
    return out, {"h": hf, "conv": conv_state}


def _scan_with_state(u, delta, a, bmat, cmat, skip):
    """Single scan returning both outputs and the final recurrent state
    (avoids the 2x recompute a separate final-state pass would cost)."""

    def step(h, xs):
        u_t, dt_t, b_t, c_t = xs
        decay = jnp.exp(dt_t[..., None] * a[None])
        h = decay * h + (dt_t * u_t)[..., None] * b_t[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, c_t) + skip[None] * u_t
        return h, y

    bsz, _, d = u.shape
    n = a.shape[1]
    h0 = jnp.zeros((bsz, d, n), jnp.float32)
    args = tuple(t.astype(jnp.float32).transpose(1, 0, 2)
                 for t in (u, delta, bmat, cmat))
    hf, ys = jax.lax.scan(step, h0, (args[0], args[1], args[2], args[3]))
    return ys.transpose(1, 0, 2).astype(u.dtype), hf


def mamba_decode(p: dict, cfg: ModelConfig, x: jax.Array, state: dict
                 ) -> tuple[jax.Array, dict]:
    """Single-token recurrent step. x: (B, 1, d);
    state: {h: (B, Di, N) f32, conv: (B, K-1, Di)}."""
    di, n = cfg.d_inner, cfg.ssm_state
    xz = x @ p["in_proj"]
    xin, z = xz[..., :di], xz[..., di:]            # (B, 1, Di)
    window = jnp.concatenate([state["conv"], xin], axis=1)  # (B, K, Di)
    xc = jnp.einsum("bkd,kd->bd", window, p["conv_w"]) + p["conv_b"]
    xc = jax.nn.silu(xc)[:, None, :]               # (B, 1, Di)
    delta, bmat, cmat = _ssm_inputs(p, cfg, xc)
    a = -jnp.exp(p["a_log"])
    dt = delta[:, 0].astype(jnp.float32)           # (B, Di)
    decay = jnp.exp(dt[..., None] * a[None])
    h = decay * state["h"] + (dt * xc[:, 0])[..., None] \
        * bmat[:, 0].astype(jnp.float32)[:, None, :]
    y = jnp.einsum("bdn,bn->bd", h, cmat[:, 0].astype(jnp.float32)) \
        + p["skip"] * xc[:, 0]
    out = (y[:, None].astype(x.dtype) * jax.nn.silu(z)) @ p["out_proj"]
    return out, {"h": h, "conv": window[:, 1:]}
