"""Model assembly for all 10 assigned architectures.

A model is a sequence of homogeneous *layer groups*; each group's layers are
parameter-stacked and executed with jax.lax.scan (+ remat), keeping the HLO
size O(#groups) rather than O(#layers) — essential for 95-layer /
61-layer-MoE configs compiled for 512 devices.

Groups by family:
  dense        [attn + SwiGLU] * L
  moe          optional dense prefix + [attn|MLA + routed MoE] * L'
  ssm          [mamba] * L
  hybrid       [6x(mamba+ff/moe alternating), mamba+moe, attn+moe] * (L/8)
  vlm          dense backbone; precomputed patch embeddings prepended
  encdec       encoder [attn + ff] * Le (non-causal, stub frame embeddings)
               + decoder [self-attn + cross-attn + ff] * Ld

Three execution modes share the layer code: ``loss`` (training),
``prefill`` (returns per-layer caches), ``decode_step`` (one token against
caches). MoE layers run expert-parallel inside shard_map when an EPSetup is
provided (see models/moe.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import layers as L
from repro.models import mamba as Mb
from repro.models import moe as Moe
from repro.models.config import ModelConfig

try:  # jax >= 0.6
    from jax.shard_map import shard_map
except ImportError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map


@dataclasses.dataclass(frozen=True)
class EPSetup:
    """Mesh context for expert parallelism + data-parallel axes."""

    mesh: Any
    dp_axes: tuple
    ep_axis: str = "model"
    n_shards: int = 1


@dataclasses.dataclass(frozen=True)
class ShardCtx:
    """Activation-sharding context: constrains layer activations to stay
    batch-sharded over the dp axes. Without this, GSPMD can propagate the
    FSDP (feature-dim) weight sharding into activations and silently
    replicate the batch on every device (measured 3.2x per-device FLOPs —
    EXPERIMENTS.md §Perf)."""

    mesh: Any
    dp_axes: tuple


@dataclasses.dataclass(frozen=True)
class Group:
    kind: str          # dense | moe | ssm | hybrid | encoder | decoder_x
    n: int             # number of layers (hybrid: number of periods)
    causal: bool = True
    use_mla: bool = False
    ff: int = 0        # dense ff dim (0 -> no dense mlp)
    moe: bool = False


def _groups(cfg: ModelConfig) -> list[Group]:
    f = cfg.family
    if f in ("dense", "vlm"):
        return [Group("dense", cfg.n_layers, ff=cfg.d_ff)]
    if f == "moe":
        gs = []
        if cfg.first_dense_layers:
            gs.append(Group("dense", cfg.first_dense_layers,
                            use_mla=cfg.use_mla,
                            ff=cfg.dense_d_ff or cfg.d_ff))
        gs.append(Group("moe", cfg.n_layers - cfg.first_dense_layers,
                        use_mla=cfg.use_mla, moe=True))
        return gs
    if f == "ssm":
        return [Group("ssm", cfg.n_layers)]
    if f == "hybrid":
        assert cfg.attn_period and cfg.n_layers % cfg.attn_period == 0
        return [Group("hybrid", cfg.n_layers // cfg.attn_period, moe=True)]
    if f == "encdec":
        return [Group("encoder", cfg.encoder_layers, causal=False,
                      ff=cfg.d_ff),
                Group("decoder_x", cfg.n_layers, ff=cfg.d_ff)]
    raise ValueError(f)


# --------------------------- layer init helpers -----------------------------


def _layer_init(key, cfg: ModelConfig, g: Group) -> dict:
    ks = jax.random.split(key, 8)
    p: dict = {"ln1": L.norm_init(cfg.d_model)}
    if g.kind in ("dense", "moe", "encoder", "decoder_x"):
        p["attn"] = (L.mla_init(ks[0], cfg) if g.use_mla
                     else L.attn_init(ks[0], cfg))
        p["ln2"] = L.norm_init(cfg.d_model)
        if g.kind == "decoder_x":
            p["xattn"] = L.attn_init(ks[1], cfg)
            p["ln_x"] = L.norm_init(cfg.d_model)
        if g.moe:
            p["moe"] = Moe.moe_init(ks[2], cfg)
        if g.ff:
            p["mlp"] = L.mlp_init(ks[3], cfg.d_model, g.ff, cfg.dtype)
    elif g.kind == "ssm":
        p["mamba"] = Mb.mamba_init(ks[0], cfg)
    elif g.kind == "hybrid":
        period = cfg.attn_period
        n_mamba = period - 1
        p["mamba"] = jax.vmap(lambda k: Mb.mamba_init(k, cfg))(
            jax.random.split(ks[0], n_mamba))
        p["attn"] = L.attn_init(ks[1], cfg)
        n_moe = period // cfg.moe_every
        n_ff = period - n_moe
        p["moe"] = jax.vmap(lambda k: Moe.moe_init(k, cfg))(
            jax.random.split(ks[2], n_moe))
        if n_ff:
            p["mlp"] = jax.vmap(
                lambda k: L.mlp_init(k, cfg.d_model, cfg.d_ff, cfg.dtype))(
                jax.random.split(ks[3], n_ff))
        p["ln"] = {"w": jnp.ones((2 * period, cfg.d_model), jnp.float32)}
    return p


# ------------------------------- the model ---------------------------------


class Model:
    def __init__(self, cfg: ModelConfig, ep: Optional[EPSetup] = None,
                 shard_ctx: Optional[ShardCtx] = None):
        self.cfg = cfg
        self.ep = ep
        self.shard_ctx = shard_ctx
        self.groups = _groups(cfg)

    def _constrain(self, x: jax.Array) -> jax.Array:
        """Pin activations (B, S, d) to batch sharding over the dp axes."""
        ctx = self.shard_ctx
        if ctx is None:
            return x
        import numpy as np
        from jax.sharding import NamedSharding
        n_dp = int(np.prod([ctx.mesh.shape[a] for a in ctx.dp_axes]))
        if x.shape[0] % n_dp != 0:
            return x
        spec = P(ctx.dp_axes, *([None] * (x.ndim - 1)))
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(ctx.mesh, spec))

    # ------------------------------ init ----------------------------------

    def init(self, key) -> dict:
        cfg = self.cfg
        ks = jax.random.split(key, len(self.groups) + 3)
        params: dict = {
            "embed": (jax.random.normal(
                ks[0], (cfg.padded_vocab, cfg.d_model), jnp.float32)
                * cfg.d_model ** -0.5).astype(cfg.dtype),
            "ln_f": L.norm_init(cfg.d_model),
            "head": L.dense_init(ks[1], cfg.d_model, cfg.padded_vocab,
                                 cfg.dtype),
        }
        for gi, g in enumerate(self.groups):
            gkeys = jax.random.split(ks[2 + gi], g.n)
            params[f"g{gi}"] = jax.vmap(
                lambda k, g=g: _layer_init(k, cfg, g))(gkeys)
        return params

    # --------------------------- MoE plumbing ------------------------------

    def _routed(self, p_moe: dict, x: jax.Array,
                mode: str = "train") -> jax.Array:
        cfg, ep = self.cfg, self.ep
        inference = mode != "train"  # prefill/decode: dropless dispatch
        if ep is None or ep.n_shards == 1:
            y = Moe.moe_apply({k: v for k, v in p_moe.items()
                               if k != "shared"}, cfg, x, None,
                              inference=inference)
        else:
            espec = {"router": P(), "wg": P(ep.ep_axis, None, None),
                     "wu": P(ep.ep_axis, None, None),
                     "wd": P(ep.ep_axis, None, None)}
            import numpy as np
            n_dp = int(np.prod([ep.mesh.shape[a] for a in ep.dp_axes]))
            # batch=1 decode can't split over dp: run routing replicated
            bdim = ep.dp_axes if x.shape[0] % n_dp == 0 else None
            xspec = P(bdim, None, None)
            ctx = Moe.EPContext(axis=ep.ep_axis, n_shards=ep.n_shards)
            fn = shard_map(
                lambda pm, xl: Moe.moe_apply(pm, self.cfg, xl, ctx,
                                             inference=inference),
                mesh=ep.mesh,
                in_specs=(espec, xspec), out_specs=xspec,
                check_rep=False)
            y = fn({k: v for k, v in p_moe.items() if k != "shared"}, x)
        if "shared" in p_moe:
            y = y + L.mlp_apply(p_moe["shared"], x)
        return y

    # --------------------------- layer bodies ------------------------------

    def _attn_sublayer(self, p, x, cos, sin, mode, cache, pos, causal):
        cfg = self.cfg
        h = L.rms_norm(p["ln1"], x, cfg.norm_eps)
        if mode == "train":
            if cfg.use_mla and "wq_a" in p["attn"]:
                return x + L.mla_apply(p["attn"], cfg, h, cos, sin), None
            return x + L.attn_apply(p["attn"], cfg, h, cos, sin,
                                    causal=causal), None
        if mode == "prefill":
            if cfg.use_mla and "wq_a" in p["attn"]:
                o, c = L.mla_prefill(p["attn"], cfg, h, cos, sin)
            else:
                o, c = L.attn_prefill(p["attn"], cfg, h, cos, sin)
            return x + o, c
        # decode
        if cfg.use_mla and "wq_a" in p["attn"]:
            o, c = L.mla_decode(p["attn"], cfg, h, cache, pos, cos, sin)
        else:
            o, c = L.attn_decode(p["attn"], cfg, h, cache, pos, cos, sin)
        return x + o, c

    def _ff_sublayer(self, p, x, mode="train"):
        cfg = self.cfg
        h = L.rms_norm(p["ln2"], x, cfg.norm_eps)
        out = jnp.zeros_like(x)
        if "moe" in p:
            out = out + self._routed(p["moe"], h, mode)
        if "mlp" in p:
            out = out + L.mlp_apply(p["mlp"], h)
        return x + out

    def _std_layer(self, p, x, cos, sin, mode, cache, pos, causal,
                   enc=None):
        x, c = self._attn_sublayer(p, x, cos, sin, mode, cache, pos, causal)
        if enc is not None:  # decoder cross-attention
            hx = L.rms_norm(p["ln_x"], x, self.cfg.norm_eps)
            x = x + L.cross_attn_apply(p["xattn"], self.cfg, hx, enc)
        x = self._ff_sublayer(p, x, mode)
        return x, c

    def _ssm_layer(self, p, x, mode, cache):
        cfg = self.cfg
        h = L.rms_norm(p["ln1"], x, cfg.norm_eps)
        if mode == "train":
            return x + Mb.mamba_apply(p["mamba"], cfg, h), None
        if mode == "prefill":
            o, c = Mb.mamba_prefill(p["mamba"], cfg, h)
            return x + o, c
        o, c = Mb.mamba_decode(p["mamba"], cfg, h, cache)
        return x + o, c

    def _hybrid_period(self, p, x, cos, sin, mode, cache, pos):
        """One jamba period: (period-1) mamba layers + 1 attention layer;
        MoE on every ``moe_every``-th sublayer, dense FF otherwise."""
        cfg = self.cfg
        period = cfg.attn_period
        caches = {}
        i_moe = i_ff = 0
        for j in range(period):
            ln1 = jax.tree_util.tree_map(lambda a: a[2 * j], p["ln"])
            ln2 = jax.tree_util.tree_map(lambda a: a[2 * j + 1], p["ln"])
            is_attn = j == period - 1
            if is_attn:
                sub = {"ln1": ln1, "attn": p["attn"]}
                x, c = self._attn_sublayer(sub, x, cos, sin, mode,
                                           None if cache is None
                                           else cache["attn"], pos, True)
                caches["attn"] = c
            else:
                sub = {"ln1": ln1,
                       "mamba": jax.tree_util.tree_map(
                           lambda a, j=j: a[j], p["mamba"])}
                x, c = self._ssm_layer(sub, x, mode,
                                       None if cache is None else
                                       jax.tree_util.tree_map(
                                           lambda a, j=j: a[j],
                                           cache["mamba"]))
                if c is not None:
                    caches.setdefault("mamba_list", []).append(c)
            # ff sublayer
            h = L.rms_norm(ln2, x, cfg.norm_eps)
            if (j % cfg.moe_every) == (cfg.moe_every - 1):
                pm = jax.tree_util.tree_map(lambda a, i=i_moe: a[i],
                                            p["moe"])
                x = x + self._routed(pm, h, mode)
                i_moe += 1
            else:
                pf = jax.tree_util.tree_map(lambda a, i=i_ff: a[i],
                                            p["mlp"])
                x = x + L.mlp_apply(pf, h)
                i_ff += 1
        if mode == "prefill":
            caches["mamba"] = jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs), *caches.pop("mamba_list"))
        elif mode == "decode":
            if "mamba_list" in caches:
                caches["mamba"] = jax.tree_util.tree_map(
                    lambda *xs: jnp.stack(xs), *caches.pop("mamba_list"))
        return x, (caches if mode != "train" else None)

    # ----------------------------- group scan ------------------------------

    def _run_group(self, gi: int, g: Group, params, x, cos, sin, mode,
                   caches=None, pos=None, enc=None):
        """Scan group gi's stacked layers. Returns (x, new_caches)."""
        p_stack = params[f"g{gi}"]

        def body(x, xs):
            p_layer, cache = xs
            if g.kind == "ssm":
                out, c = self._ssm_layer(p_layer, x, mode, cache)
            elif g.kind == "hybrid":
                out, c = self._hybrid_period(p_layer, x, cos, sin, mode,
                                             cache, pos)
            else:
                out, c = self._std_layer(p_layer, x, cos, sin, mode, cache,
                                         pos, g.causal, enc=enc)
            return out, c

        if mode == "train":
            def f(x, p_layer):
                out, _ = body(self._constrain(x), (p_layer, None))
                return self._constrain(out), None
            x, _ = jax.lax.scan(jax.checkpoint(f), x, p_stack)
            return x, None
        if mode == "prefill":
            def f(x, p_layer):
                out, c = body(self._constrain(x), (p_layer, None))
                return self._constrain(out), c
            x, cs = jax.lax.scan(f, x, p_stack)
            return x, cs
        # decode: caches are scanned alongside params
        def f(x, xs):
            out, c = body(self._constrain(x), xs)
            return self._constrain(out), c
        x, cs = jax.lax.scan(f, x, (p_stack, caches))
        return x, cs

    # ------------------------------- embed ---------------------------------

    def _embed(self, params, batch) -> jax.Array:
        cfg = self.cfg
        x = params["embed"][batch["tokens"]].astype(cfg.dtype)
        if cfg.family == "vlm" and "patch_embeds" in batch:
            x = jnp.concatenate(
                [batch["patch_embeds"].astype(cfg.dtype), x], axis=1)
        return self._constrain(x)

    def _logits(self, params, x) -> jax.Array:
        return (x @ params["head"]).astype(jnp.float32)

    def _encode(self, params, batch, mode="train"):
        """Run the encoder stack on stub frame embeddings (encdec only)."""
        cfg = self.cfg
        enc = batch["frame_embeds"].astype(cfg.dtype)
        s = enc.shape[1]
        cos, sin = L.rope_table(s, cfg.hd, cfg.rope_theta)
        enc, _ = self._run_group(0, self.groups[0], params, enc, cos, sin,
                                 "train")
        return L.rms_norm(params["ln_f"], enc, cfg.norm_eps)

    # ------------------------------- modes ---------------------------------

    def loss_fn(self, params, batch) -> jax.Array:
        """Causal LM cross-entropy (vocab-sharding friendly: reductions +
        one-hot einsum, never a gather over the sharded vocab axis)."""
        cfg = self.cfg
        x = self._embed(params, batch)
        s = x.shape[1]
        cos, sin = L.rope_table(s, self._rope_dim(), cfg.rope_theta)
        enc = None
        g0 = 0
        if cfg.family == "encdec":
            enc = self._encode(params, batch)
            g0 = 1
        for gi in range(g0, len(self.groups)):
            x, _ = self._run_group(gi, self.groups[gi], params, x, cos, sin,
                                   "train", enc=enc)
        x = L.rms_norm(params["ln_f"], x, cfg.norm_eps)
        if cfg.family == "vlm" and "patch_embeds" in batch:
            x = x[:, batch["patch_embeds"].shape[1]:]

        def head_loss(head_w, xs, labels):
            logits = (xs @ head_w).astype(jnp.float32)
            m = jnp.max(logits, axis=-1, keepdims=True)
            lse = (m[..., 0]
                   + jnp.log(jnp.sum(jnp.exp(logits - m), axis=-1)))
            onehot = jax.nn.one_hot(labels, cfg.padded_vocab,
                                    dtype=jnp.bfloat16)
            label_logit = jnp.sum(logits * onehot, axis=-1)
            nll = lse - label_logit
            zloss = 1e-4 * jnp.mean(lse ** 2)  # logit drift regularizer
            return jnp.mean(nll) + zloss

        # checkpoint the head: (tokens, padded_vocab) fp32 logits are
        # recomputed in the backward instead of living across it
        return jax.checkpoint(head_loss)(params["head"], x,
                                         batch["labels"])

    def prefill(self, params, batch):
        """Returns (last-token logits, caches list per group)."""
        cfg = self.cfg
        x = self._embed(params, batch)
        s = x.shape[1]
        cos, sin = L.rope_table(s, self._rope_dim(), cfg.rope_theta)
        enc = None
        g0 = 0
        caches: list = []
        if cfg.family == "encdec":
            enc = self._encode(params, batch)
            caches.append({"enc": enc})
            g0 = 1
        for gi in range(g0, len(self.groups)):
            x, c = self._run_group(gi, self.groups[gi], params, x, cos, sin,
                                   "prefill", enc=enc)
            caches.append(c)
        x = L.rms_norm(params["ln_f"], x, cfg.norm_eps)
        return self._logits(params, x[:, -1:]), caches

    def decode_step(self, params, caches, tokens, pos):
        """tokens: (B, 1) int32; pos: scalar int32 — current position.
        Returns (logits (B, 1, V) fp32, new caches)."""
        cfg = self.cfg
        x = params["embed"][tokens].astype(cfg.dtype)
        cos_t, sin_t = self._rope_at(pos)
        enc = None
        g0 = 0
        new_caches: list = []
        if cfg.family == "encdec":
            enc = caches[0]["enc"]
            new_caches.append(caches[0])
            g0 = 1
        for gi in range(g0, len(self.groups)):
            x, c = self._run_group(gi, self.groups[gi], params, x, cos_t,
                                   sin_t, "decode", caches=caches[gi],
                                   pos=pos, enc=enc)
            new_caches.append(c)
        x = L.rms_norm(params["ln_f"], x, cfg.norm_eps)
        return self._logits(params, x), new_caches

    # ------------------------------ helpers --------------------------------

    def _rope_dim(self) -> int:
        return self.cfg.qk_rope_dim if self.cfg.use_mla else self.cfg.hd

    def _rope_at(self, pos):
        dim = self._rope_dim()
        inv = 1.0 / (self.cfg.rope_theta
                     ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
        f = pos.astype(jnp.float32) * inv
        return jnp.cos(f)[None], jnp.sin(f)[None]
