"""Mixture-of-Experts layer with expert parallelism.

Two execution paths share one grouped-FFN core:

  * local (single device / smoke tests): all experts resident, tokens
    dispatched by a sort-based capacity gather (no (T, E, C) one-hot —
    memory stays O(T·k·d)).
  * EP (production, inside shard_map): experts are sharded over the
    ``model`` mesh axis; activations arrive replicated across that axis, so
    each shard routes all local tokens, computes only the copies destined
    for its resident experts, and the partial outputs are psum-reduced.
    This is the *baseline* EP schedule (collective cost = one (T, d)
    all-reduce, like a TP MLP); the all-to-all dispatch variant is the
    §Perf hillclimb in EXPERIMENTS.md.

Router: top-k softmax with renormalization (Qwen3 semantics; DeepSeek-V3's
sigmoid+bias-corrected router reduces to the same dispatch shape — noted in
DESIGN.md deviations). Shared experts (DeepSeek-V3) are a dense SwiGLU
always-on path added outside the routed computation.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.models import backend
from repro.models.config import ModelConfig
from repro.models.layers import mlp_apply, mlp_init


@dataclasses.dataclass(frozen=True)
class EPContext:
    """How the MoE layer is placed on the mesh (None = single device)."""

    axis: str = "model"       # mesh axis holding experts
    n_shards: int = 1


def moe_init(key, cfg: ModelConfig) -> dict:
    d, ff, e = cfg.d_model, cfg.expert_ff, cfg.n_experts
    ks = jax.random.split(key, 5)
    scale = d ** -0.5
    p = {
        "router": (jax.random.normal(ks[0], (d, e), jnp.float32)
                   * scale).astype(jnp.float32),
        "wg": (jax.random.normal(ks[1], (e, d, ff), jnp.float32)
               * scale).astype(cfg.dtype),
        "wu": (jax.random.normal(ks[2], (e, d, ff), jnp.float32)
               * scale).astype(cfg.dtype),
        "wd": (jax.random.normal(ks[3], (e, ff, d), jnp.float32)
               * (ff ** -0.5)).astype(cfg.dtype),
    }
    if cfg.n_shared_experts:
        p["shared"] = mlp_init(ks[4], d, ff * cfg.n_shared_experts,
                               cfg.dtype)
    return p


def grouped_ffn(x: jax.Array, idx: jax.Array, w: jax.Array,
                valid: jax.Array, wg: jax.Array, wu: jax.Array,
                wd: jax.Array, capacity: int) -> jax.Array:
    """Sort-based capacity dispatch + grouped SwiGLU + weighted combine.

    x: (T, d); idx: (T, k) expert ids in [0, E); w: (T, k) combine weights;
    valid: (T, k) bool (invalid copies take no capacity);
    wg/wu: (E, d, f); wd: (E, f, d). Over-capacity copies are dropped.
    """
    t, d = x.shape
    k = idx.shape[1]
    e = wg.shape[0]
    c = capacity
    flat_e = jnp.where(valid, idx, e).reshape(-1)      # invalid -> expert E
    order = jnp.argsort(flat_e)                        # stable
    sorted_e = flat_e[order]
    starts = jnp.searchsorted(sorted_e, jnp.arange(e + 1))
    pos_in_e = jnp.arange(t * k) - starts[jnp.minimum(sorted_e, e)]
    keep = (pos_in_e < c) & (sorted_e < e)
    slot = jnp.where(keep, sorted_e * c + pos_in_e, e * c)
    tok = order // k
    buf = jnp.zeros((e * c + 1, d), x.dtype).at[slot].set(x[tok])
    xe = buf[:e * c].reshape(e, c, d)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, wg)) \
        * jnp.einsum("ecd,edf->ecf", xe, wu)
    ye = jnp.einsum("ecf,efd->ecd", h, wd).reshape(e * c, d)
    y_cp = jnp.where(keep[:, None],
                     ye[jnp.minimum(slot, e * c - 1)], 0.0)
    w_cp = w.reshape(-1)[order]
    out = jnp.zeros((t, d), x.dtype).at[tok].add(
        (y_cp * w_cp[:, None]).astype(x.dtype))
    return out


def _route(router_w: jax.Array, x: jax.Array, top_k: int):
    logits = x.astype(jnp.float32) @ router_w
    return backend.moe_router(logits, top_k)


def _capacity(tokens: int, n_experts: int, top_k: int, cf: float) -> int:
    c = int(math.ceil(tokens * top_k / n_experts * cf))
    return max(8, -(-c // 8) * 8)  # round up to 8 for tiling


def moe_apply(p: dict, cfg: ModelConfig, x: jax.Array,
              ep: EPContext | None = None,
              inference: bool = False) -> jax.Array:
    """x: (B, S, d) -> (B, S, d). In EP mode this function must be called
    *inside* shard_map with ``p`` holding the local expert slices and x the
    local activations (replicated over the EP axis).

    ``inference`` switches to dropless dispatch (capacity = worst-case T*k):
    capacity drops are a training-throughput tradeoff, but at inference they
    make prefill logits depend on which other tokens share the batch — the
    last prefill token's expert copy can be dropped while the same token
    decoded alone is not, breaking prefill/decode equivalence.
    """
    b, s, d = x.shape
    xt = x.reshape(b * s, d)
    w, idx = _route(p["router"], xt, cfg.top_k)
    w = w.astype(x.dtype)

    if inference:
        # dropless: the router picks distinct experts per token, so one
        # expert can receive at most T copies. Exact droplessness costs an
        # O(E*T*d) dispatch buffer, so large prefills fall back to a
        # 2x-headroom capacity — drops then need >2.5x routing imbalance
        # on an already-large batch, where they are statistically benign
        t = b * s
        cap = max(8, -(-t // 8) * 8)
        if t > 1024:
            cap = min(cap, _capacity(t, cfg.n_experts, cfg.top_k,
                                     2.0 * cfg.capacity_factor))
    else:
        cap = _capacity(b * s, cfg.n_experts, cfg.top_k,
                        cfg.capacity_factor)
    if ep is None or ep.n_shards == 1:
        y = grouped_ffn(xt, idx, w, jnp.ones_like(idx, bool),
                        p["wg"], p["wu"], p["wd"], cap)
    else:
        e_loc = cfg.n_experts // ep.n_shards
        me = jax.lax.axis_index(ep.axis)
        mine = (idx // e_loc) == me
        idx_loc = jnp.where(mine, idx - me * e_loc, 0)
        # per-expert capacity is mesh-size independent: expected tokens per
        # expert = T*k/E whether or not experts are sharded
        y = grouped_ffn(xt, idx_loc, w, mine,
                        p["wg"], p["wu"], p["wd"], cap)
        y = jax.lax.psum(y, ep.axis)

    out = y.reshape(b, s, d)
    if "shared" in p:
        out = out + mlp_apply(p["shared"], x)
    return out


def aux_load_balance_loss(p: dict, cfg: ModelConfig, x: jax.Array
                          ) -> jax.Array:
    """Switch-style load-balance auxiliary loss (mean fraction * prob)."""
    b, s, d = x.shape
    xt = x.reshape(b * s, d)
    probs = jax.nn.softmax(xt.astype(jnp.float32) @ p["router"], -1)
    _, idx = backend.moe_router(
        xt.astype(jnp.float32) @ p["router"], cfg.top_k)
    frac = jnp.mean(jax.nn.one_hot(idx, cfg.n_experts, dtype=jnp.float32),
                    axis=(0, 1))
    return cfg.n_experts * jnp.sum(frac * probs.mean(0))
