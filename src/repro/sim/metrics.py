"""QoS metrics (paper §4.1, Eqs. 6-14), recorded per interval + summarized."""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class MetricsLog:
    energy_w: list = dataclasses.field(default_factory=list)
    contention: list = dataclasses.field(default_factory=list)
    util_cpu: list = dataclasses.field(default_factory=list)
    util_ram: list = dataclasses.field(default_factory=list)
    util_disk: list = dataclasses.field(default_factory=list)
    util_bw: list = dataclasses.field(default_factory=list)
    active_tasks: list = dataclasses.field(default_factory=list)
    predicted_stragglers: list = dataclasses.field(default_factory=list)
    overhead_s: list = dataclasses.field(default_factory=list)

    def record_interval(self, cluster, contention: float,
                        active: int, predicted: float | None,
                        overhead_s: float) -> None:
        self.energy_w.append(cluster.energy())
        self.contention.append(contention)
        u = cluster.util.mean(axis=0) * 100.0
        self.util_cpu.append(float(u[0]))
        self.util_ram.append(float(u[1]))
        self.util_disk.append(float(u[2]))
        self.util_bw.append(float(u[3]))
        self.active_tasks.append(active)
        self.predicted_stragglers.append(
            float(predicted) if predicted is not None else np.nan)
        self.overhead_s.append(overhead_s)


def contention_metric(cluster, task_req: np.ndarray, task_host: np.ndarray,
                      active: np.ndarray) -> float:
    """Eq. 9: sum over hosts/tasks of req * 1(resource overloaded)."""
    if not active.any():
        return 0.0
    over = cluster.overloaded()  # (n, 4)
    hosts = task_host[active]
    reqs = task_req[active]
    return float((reqs * over[hosts]).sum())


def summarize(log: MetricsLog, tasks: "object", interval_s: float,
              restart_overhead_s: float) -> dict:
    """Summary dict with the paper's headline QoS numbers.

    ``tasks`` is the engine's TaskTable (read-only access).
    """
    n = tasks.n
    state = tasks.view("state")
    is_copy = tasks.view("is_copy")
    finish_s = tasks.view("finish_s")
    submit_s = tasks.view("submit_s")
    deadline_s = tasks.view("deadline_s")
    restarts = tasks.view("restarts")
    sla_weight = tasks.view("sla_weight")
    done = state == 2
    orig = ~is_copy
    d = done & orig
    exec_t = np.where(finish_s > 0, finish_s - submit_s, np.nan)
    # Eq. 8: avg completion-submission + restart overheads
    avg_exec = float(np.nanmean(np.where(d, exec_t, np.nan))) if d.any() \
        else 0.0
    avg_restart = float(restarts[d].mean()
                        * restart_overhead_s) if d.any() else 0.0
    # Eq. 13: weighted SLA violation rate over originals (undone past-
    # deadline tasks count as violated)
    violated = np.zeros(n, bool)
    violated[d] = exec_t[d] > deadline_s[d]
    undone = orig & ~done
    violated[undone] = True
    wsum = sla_weight[orig].sum()
    sla = float((sla_weight[orig] * violated[orig]).sum()
                / max(wsum, 1e-9))
    energy_kwh = float(np.sum(log.energy_w) * interval_s / 3.6e6)
    del n
    return {
        "tasks_done": int(d.sum()),
        "tasks_total": int(orig.sum()),
        "avg_execution_time_s": avg_exec + avg_restart,
        "energy_kwh": energy_kwh,
        "resource_contention": float(np.mean(log.contention))
        if log.contention else 0.0,
        "sla_violation_rate": sla,
        "cpu_util_pct": float(np.mean(log.util_cpu)),
        "ram_util_pct": float(np.mean(log.util_ram)),
        "disk_util_pct": float(np.mean(log.util_disk)),
        "bw_util_pct": float(np.mean(log.util_bw)),
        "avg_overhead_s": float(np.mean(log.overhead_s))
        if log.overhead_s else 0.0,
    }


def mape(actual: np.ndarray, predicted: np.ndarray) -> float:
    """Eq. 14 over intervals with nonzero actuals."""
    actual = np.asarray(actual, float)
    predicted = np.asarray(predicted, float)
    ok = np.isfinite(predicted) & (actual > 0)
    if not ok.any():
        return float("nan")
    return float(100.0 * np.mean(
        np.abs((actual[ok] - predicted[ok]) / actual[ok])))
