"""The paper's six baseline techniques (§4.6), implemented per their source
papers' core rules as policies on the unified API: each consumes only the
``repro.policy`` telemetry view and emits the shared action vocabulary.

  NearestFit [6]  — online curve-fit progress profiling -> reactive speculation
  Dolly [20]      — budgeted proactive cloning of small jobs (UCB-gated)
  GRASS [8]       — greedy resource-aware reactive speculation
  SGC [9]         — pair-wise balanced upfront redundancy
  Wrangler [17]   — learned linear straggler probability -> delayed start
  IGRU-SD [22]    — GRU resource/latency prediction -> proactive mitigation
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import encoder_lstm as nets
from repro.policy import (Action, DONE, EVENT_INTERVAL, EVENT_SUBMIT,
                          PENDING, Policy, PretrainContext, TelemetryView,
                          register)

MIN_OBS_INTERVALS = 2  # reactive methods need some progress history


def _expected_time(view: TelemetryView, i: int) -> float:
    return float(view.tasks.work[i] / view.host_ips_mean)


def _elapsed(view: TelemetryView, i: int) -> float:
    return view.now_s - float(view.tasks.start_s[i])


def _remaining_estimate(view: TelemetryView, i: int) -> float:
    """Remaining seconds at the task's observed progress rate."""
    tt = view.tasks
    el = max(_elapsed(view, i), 1.0)
    rate = float(tt.progress[i]) / el
    rem = float(tt.work[i] - tt.progress[i])
    return rem / max(rate, 1e-6)


def _pick_fast_host(view: TelemetryView, exclude: int) -> int:
    h = view.hosts
    score = np.where(h.online(), h.util[:, 0] - 0.2 * h.speed, np.inf)
    if 0 <= exclude < len(score):
        score[exclude] = np.inf
    return int(np.argmin(score))


@register("nearestfit",
          description="online curve-fit progress profiling with reactive "
                      "speculation [6]")
class NearestFit(Policy):
    """Fits t = a + b*x^c on completed (work -> time) pairs; running tasks
    whose elapsed time exceeds 1.5x the fit are stragglers -> speculate."""

    name = "nearestfit"

    def __init__(self):
        self.obs_x: list[float] = []
        self.obs_t: list[float] = []
        self.coef = None
        self._flagged: set[int] = set()

    def _fit(self):
        if len(self.obs_x) < 8:
            return
        x = np.array(self.obs_x)
        t = np.maximum(np.array(self.obs_t), 1e-3)
        # log t = log b + c log x (a ~= 0 for compute-bound tasks)
        A = np.stack([np.ones_like(x), np.log(x)], 1)
        sol, *_ = np.linalg.lstsq(A, np.log(t), rcond=None)
        self.coef = sol

    def _predict(self, view: TelemetryView, work: float) -> float:
        if self.coef is None:
            return work / view.host_ips_mean
        return float(np.exp(self.coef[0] + self.coef[1] * np.log(work)))

    def observe(self, view: TelemetryView) -> None:
        tt = view.tasks
        done = np.nonzero((tt.state == DONE) & ~tt.is_copy)[0]
        self.obs_x = [float(tt.work[i]) for i in done][-512:]
        self.obs_t = [float(tt.finish_s[i] - tt.start_s[i])
                      for i in done][-512:]
        self._fit()

    def decide(self, view: TelemetryView) -> list[Action]:
        if view.event != EVENT_INTERVAL:
            return []
        tt = view.tasks
        acts = []
        cap = max(1, int(0.02 * tt.active_mask().sum()))
        for i in np.nonzero(tt.active_mask())[0]:
            i = int(i)
            if len(acts) >= cap:
                break
            if i in self._flagged:
                continue
            if _elapsed(view, i) < MIN_OBS_INTERVALS * view.interval_seconds:
                continue
            if _elapsed(view, i) > 1.5 * self._predict(view,
                                                       float(tt.work[i])):
                self._flagged.add(i)
                acts.append(Action(
                    "speculate", i, target=_pick_fast_host(
                        view, int(tt.host[i]))))
        return acts


@register("dolly",
          description="budgeted proactive cloning of small jobs, UCB-gated "
                      "on cluster utilization [20]")
class Dolly(Policy):
    """Proactive cloning of small jobs within a 5% resource budget, gated by
    an upper-confidence-bound on cluster CPU utilization [20]."""

    name = "dolly"

    def __init__(self, budget: float = 0.05, small_job: int = 3):
        self.budget = budget
        self.small_job = small_job
        self.cloned = 0

    def decide(self, view: TelemetryView) -> list[Action]:
        if view.event != EVENT_SUBMIT:
            return []
        tt = view.tasks
        total = max(int((~tt.is_copy).sum()), 1)
        util = view.hosts.util[:, 0]
        mean, std = float(util.mean()), float(util.std())
        ucb = mean + 1.0 * std
        acts = []
        jobs: dict[int, list[int]] = {}
        for i in view.new_tasks:
            jobs.setdefault(int(tt.job_id[i]), []).append(int(i))
        for job, tids in jobs.items():
            if len(tids) > self.small_job or ucb > 0.8:
                continue
            if (self.cloned + len(tids)) / total > self.budget:
                break
            for i in tids:
                acts.append(Action("clone", i, n_clones=1))
                self.cloned += 1
        return acts


@register("grass",
          description="greedy resource-aware reactive speculation [8]")
class GRASS(Policy):
    """Greedy speculation: clone the running tasks with the largest
    (current-remaining - fresh-rerun) gain while spare capacity exists [8]."""

    name = "grass"

    def __init__(self, max_spec_frac: float = 0.05):
        self.max_spec_frac = max_spec_frac
        self._flagged: set[int] = set()

    def decide(self, view: TelemetryView) -> list[Action]:
        if view.event != EVENT_INTERVAL:
            return []
        tt = view.tasks
        spare = float(np.mean(np.clip(1.0 - view.hosts.util[:, 0], 0, 1)))
        budget = max(1, int(spare * view.config.n_hosts
                            * self.max_spec_frac * 0.5))
        cands = []
        for i in np.nonzero(tt.active_mask())[0]:
            i = int(i)
            if i in self._flagged:
                continue
            if _elapsed(view, i) < MIN_OBS_INTERVALS * view.interval_seconds:
                continue
            gain = _remaining_estimate(view, i) - _expected_time(view, i)
            if gain > 2.0 * view.interval_seconds:
                cands.append((gain, i))
        cands.sort(reverse=True)
        acts = []
        for _, i in cands[:budget]:
            self._flagged.add(i)
            acts.append(Action("speculate", i,
                               target=_pick_fast_host(
                                   view, int(tt.host[i]))))
        return acts


@register("sgc",
          description="pair-wise balanced upfront redundancy (approximate "
                      "gradient coding) [9]")
class SGC(Policy):
    """Pair-wise balanced upfront redundancy: each task is duplicated onto
    its paired host with probability p (approximate gradient coding) [9]."""

    name = "sgc"

    def __init__(self, p: float = 0.15):
        self.p = p

    def decide(self, view: TelemetryView) -> list[Action]:
        if view.event != EVENT_SUBMIT:
            return []
        acts = []
        n = view.config.n_hosts
        for i in view.new_tasks:
            if view.rng.random() < self.p:
                pair = (int(i) + n // 2) % n
                acts.append(Action("clone", int(i), target=pair,
                                   n_clones=1))
        return acts


@register("wrangler",
          description="learned linear straggler probability over host "
                      "utilization counters; unsafe placements are "
                      "delayed [17]")
class Wrangler(Policy):
    """Linear straggler-probability model on host utilization counters with
    a confidence threshold; predicted-unsafe placements are delayed [17]."""

    name = "wrangler"

    def __init__(self, threshold: float = 0.7, max_delay: int = 3):
        self.threshold = threshold
        self.max_delay = max_delay
        self.w = None           # ridge weights, set by pretraining
        self._delays: dict[int, int] = {}

    @classmethod
    def pretrain(cls, ctx: PretrainContext) -> "Wrangler":
        tech = cls(**ctx.kwargs)   # per-technique sweep knobs
        pretrain_wrangler(tech, ctx.warmup())
        return tech

    def train(self, feats: np.ndarray, labels: np.ndarray,
              l2: float = 1e-2):
        A = np.concatenate([feats, np.ones((len(feats), 1))], 1)
        self.w = np.linalg.solve(A.T @ A + l2 * np.eye(A.shape[1]),
                                 A.T @ labels)

    def _prob(self, hosts_feats: np.ndarray) -> np.ndarray:
        if self.w is None:
            return np.zeros(len(hosts_feats))
        A = np.concatenate([hosts_feats,
                            np.ones((len(hosts_feats), 1))], 1)
        return np.clip(A @ self.w, 0, 1)

    def _host_feats(self, view: TelemetryView) -> np.ndarray:
        h = view.hosts
        return np.concatenate(
            [h.util, h.speed[:, None] / h.speed.max()], 1)

    def decide(self, view: TelemetryView) -> list[Action]:
        if view.event == EVENT_SUBMIT:
            return self._maybe_delay(view, view.new_tasks)
        pend = np.nonzero(view.tasks.state == PENDING)[0]
        return self._maybe_delay(view, pend)

    def _maybe_delay(self, view: TelemetryView, idx) -> list[Action]:
        if self.w is None or len(idx) == 0:
            return []
        probs = self._prob(self._host_feats(view))
        online = view.hosts.online()
        safe_exists = bool((probs[online] < self.threshold).any()) \
            if online.any() else False
        acts = []
        for i in idx:
            i = int(i)
            if safe_exists:
                continue  # scheduler will find a safe host
            d = self._delays.get(i, 0)
            if d < self.max_delay:
                self._delays[i] = d + 1
                acts.append(Action("delay", i, delay=1))
        return acts


# ------------------------------ IGRU-SD -----------------------------------


def gru_init(key, n_in: int, hidden: int):
    k1, k2, k3 = jax.random.split(key, 3)
    s = 1.0 / np.sqrt(n_in)
    sh = 1.0 / np.sqrt(hidden)
    return {
        "wx": jax.random.normal(k1, (n_in, 3 * hidden)) * s,
        "wh": jax.random.normal(k2, (hidden, 3 * hidden)) * sh,
        "b": jnp.zeros((3 * hidden,)),
        "head": {"w": jax.random.normal(k3, (hidden, 1)) * sh,
                 "b": jnp.zeros((1,))},
    }


def gru_apply(params, xs):
    """xs: (T, B, n_in) -> (B,) predicted normalized completion time."""
    hidden = params["wh"].shape[0]

    def cell(h, x):
        z = x @ params["wx"] + h @ params["wh"] + params["b"]
        r, u, n = jnp.split(z, 3, -1)
        r, u = jax.nn.sigmoid(r), jax.nn.sigmoid(u)
        n = jnp.tanh(x @ params["wx"][:, 2 * hidden:]
                     + r * (h @ params["wh"][:, 2 * hidden:]))
        h = (1 - u) * n + u * h
        return h, None

    h0 = jnp.zeros((xs.shape[1], hidden))
    h, _ = jax.lax.scan(cell, h0, xs)
    out = h @ params["head"]["w"] + params["head"]["b"]
    return jax.nn.softplus(out[..., 0])


@jax.jit
def _gru_loss(params, xs, y):
    return jnp.mean((gru_apply(params, xs) - y) ** 2)


@jax.jit
def _gru_step(params, opt, xs, y):
    loss, g = jax.value_and_grad(_gru_loss)(params, xs, y)
    params, opt = nets.adam_update(params, g, opt, lr=1e-2)
    return params, opt, loss


@register("igru-sd", substrates=("sim", "pod"),
          epochs_knob="igru_epochs",
          description="GRU resource/latency prediction with proactive "
                      "speculate/rerun mitigation [22]; runs on both the "
                      "cloud simulator and the training-pod runtime")
class IGRUSD(Policy):
    """GRU-based resource/latency prediction + detection threshold, with the
    same speculate/rerun mitigation as START (paper §4.6 fairness note).

    Deliberately ignores host heterogeneity (the paper's criticism): its
    features are task-progress only, no host capability terms — which is
    also why it ports to the training-pod substrate unchanged: the pod
    runtime synthesizes per-host shard "tasks" whose progress/elapsed
    ratios carry the same meaning.
    """

    name = "igru-sd"

    HIST = 5
    FEATS = 3  # progress fraction, rate, elapsed/expected

    def __init__(self, seed: int = 0):
        self.params = gru_init(jax.random.PRNGKey(seed), self.FEATS, 16)
        self.hist: dict[int, list[np.ndarray]] = {}
        self._flagged: set[int] = set()
        self._last_pred: float | None = None

    @classmethod
    def pretrain(cls, ctx: PretrainContext) -> "IGRUSD":
        tech = cls(**ctx.kwargs)   # per-technique sweep knobs
        pretrain_igru(tech, ctx.warmup(),
                      epochs=200 if ctx.epochs is None else ctx.epochs)
        return tech

    def train(self, xs: np.ndarray, y: np.ndarray, epochs: int = 200):
        opt = nets.adam_init(self.params)
        for _ in range(epochs):
            self.params, opt, _ = _gru_step(
                self.params, opt, jnp.asarray(xs), jnp.asarray(y))

    def _task_feats(self, view: TelemetryView, i: int) -> np.ndarray:
        tt = view.tasks
        el = max(_elapsed(view, i), 1.0)
        exp = max(_expected_time(view, i), 1.0)
        return np.array([
            float(tt.progress[i] / max(tt.work[i], 1e-9)),
            float(tt.progress[i] / el / view.host_ips_mean),
            float(el / exp)], np.float32)

    def observe(self, view: TelemetryView) -> None:
        tt = view.tasks
        for i in np.nonzero(tt.active_mask())[0]:
            i = int(i)
            h = self.hist.setdefault(i, [])
            h.append(self._task_feats(view, i))
            del h[:-self.HIST]     # only the last HIST entries are read

    def forget_tasks(self, task_ids) -> None:
        # the rolling progress-rate history stays useful across a task
        # boundary (it describes the same host); only the once-per-task
        # mitigation flag must expire, or a chronically slow host would
        # be mitigated a single time for the whole run
        for i in task_ids:
            self._flagged.discard(int(i))

    def decide(self, view: TelemetryView) -> list[Action]:
        if view.event != EVENT_INTERVAL:
            return []
        tt = view.tasks
        run = [int(i) for i in np.nonzero(tt.active_mask())[0]]
        ready = [i for i in run if len(self.hist.get(i, [])) >= self.HIST
                 and i not in self._flagged]
        self._last_pred = 0.0
        if not ready:
            return []
        xs = np.stack([np.stack(self.hist[i][-self.HIST:]) for i in ready],
                      axis=1)
        # pad the job axis to a power of two: one jit compile per bucket
        n = xs.shape[1]
        pad = max(1 << (n - 1).bit_length(), 1) - n
        if pad:
            xs = np.concatenate(
                [xs, np.zeros((xs.shape[0], pad, xs.shape[2]),
                              xs.dtype)], axis=1)
        preds = np.asarray(gru_apply(self.params, jnp.asarray(xs)))[:n]
        acts = []
        n_strag = 0.0
        cap = max(1, int(0.02 * len(run)))
        for i, p in zip(ready, preds):
            exp = _expected_time(view, i)
            n_strag += float(p * exp > 1.5 * exp)
            if p > 1.5 and _elapsed(view, i) > exp and len(acts) < cap:
                self._flagged.add(i)
                kind = "speculate" if tt.is_deadline[i] else "rerun"
                acts.append(Action(kind, i, target=_pick_fast_host(
                    view, int(tt.host[i]))))
        self._last_pred = n_strag
        return acts

    def predicted_straggler_count(self):
        return self._last_pred


def synthetic_progress_history(work: float, total: float, expected: float,
                               ips_mean: float,
                               hist: int = IGRUSD.HIST) -> np.ndarray:
    """Idealized (hist, FEATS) progress history for a task of ``work`` MI
    that took ``total`` seconds against an ``expected`` time — the
    training-pair reconstruction shared by the warmup-sim pretrainer and
    the pod substrate's window pretrainer."""
    frac = np.linspace(0.15, 0.75, hist)
    rate = work / max(total, 1.0) / ips_mean
    el = frac * total
    return np.stack([frac, np.full_like(frac, rate), el / expected], 1)


def pretrain_igru(tech: IGRUSD, warm: TelemetryView,
                  epochs: int = 200) -> None:
    """Train the GRU on (progress-history -> completion/expected ratio) pairs
    from a finished warmup run's telemetry view."""
    tt = warm.tasks
    xs, ys = [], []
    done = np.nonzero((tt.state == DONE) & ~tt.is_copy)[0]
    for i in done:
        i = int(i)
        total = float(tt.finish_s[i] - tt.start_s[i])
        exp = float(tt.work[i] / warm.host_ips_mean)
        # reconstruct an idealized progress history at the observed rate
        xs.append(synthetic_progress_history(
            float(tt.work[i]), total, exp, warm.host_ips_mean))
        ys.append(total / exp)
    if not xs:
        return
    tech.train(np.stack(xs, axis=1).astype(np.float32),
               np.array(ys, np.float32), epochs=epochs)


def pretrain_wrangler(tech: Wrangler, warm: TelemetryView) -> None:
    """Train Wrangler's linear model on (host utilization counters at job
    completion -> was-straggler) pairs from a warmup run's view [17]."""
    feats, labels = [], []
    speed = warm.hosts.speed
    speed_n = speed / speed.max()
    hist = warm.util_history
    for rec in warm.completed_jobs:
        t = min(rec["t"] - 1, len(hist) - 1)
        if t < 0:
            continue
        util = hist[t]
        for h, s in zip(rec["hosts"], rec["straggler"]):
            if h < 0:  # finished via a copy while unplaced
                continue
            feats.append(np.concatenate([util[int(h)],
                                         [speed_n[int(h)]]]))
            labels.append(float(s))
    if feats:
        tech.train(np.array(feats), np.array(labels))
