"""The paper's six baseline techniques (§4.6), implemented per their source
papers' core rules, sharing the engine's action vocabulary.

  NearestFit [6]  — online curve-fit progress profiling -> reactive speculation
  Dolly [20]      — budgeted proactive cloning of small jobs (UCB-gated)
  GRASS [8]       — greedy resource-aware reactive speculation
  SGC [9]         — pair-wise balanced upfront redundancy
  Wrangler [17]   — learned linear straggler probability -> delayed start
  IGRU-SD [22]    — GRU resource/latency prediction -> proactive mitigation
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import encoder_lstm as nets
from repro.sim import engine as E

MIN_OBS_INTERVALS = 2  # reactive methods need some progress history


def _expected_time(sim, i) -> float:
    return float(sim.tasks.work[i] / sim.cfg.host_ips_mean)


def _elapsed(sim, i) -> float:
    return sim.now_s - float(sim.tasks.start_s[i])


def _remaining_estimate(sim, i) -> float:
    """Remaining seconds at the task's observed progress rate."""
    tt = sim.tasks
    el = max(_elapsed(sim, i), 1.0)
    rate = float(tt.progress[i]) / el
    rem = float(tt.work[i] - tt.progress[i])
    return rem / max(rate, 1e-6)


def _pick_fast_host(sim, exclude: int) -> int:
    c = sim.cluster
    score = np.where(c.online(), c.util[:, 0] - 0.2 * c.speed, np.inf)
    if 0 <= exclude < len(score):
        score[exclude] = np.inf
    return int(np.argmin(score))


class NearestFit(E.Technique):
    """Fits t = a + b*x^c on completed (work -> time) pairs; running tasks
    whose elapsed time exceeds 1.5x the fit are stragglers -> speculate."""

    name = "nearestfit"

    def __init__(self):
        self.obs_x: list[float] = []
        self.obs_t: list[float] = []
        self.coef = None
        self._flagged: set[int] = set()

    def _fit(self):
        if len(self.obs_x) < 8:
            return
        x = np.array(self.obs_x)
        t = np.maximum(np.array(self.obs_t), 1e-3)
        # log t = log b + c log x (a ~= 0 for compute-bound tasks)
        A = np.stack([np.ones_like(x), np.log(x)], 1)
        sol, *_ = np.linalg.lstsq(A, np.log(t), rcond=None)
        self.coef = sol

    def _predict(self, work: float) -> float:
        if self.coef is None:
            return work / self.sim.cfg.host_ips_mean
        return float(np.exp(self.coef[0] + self.coef[1] * np.log(work)))

    def on_interval(self):
        sim = self.sim
        tt = sim.tasks
        done = np.nonzero((tt.view("state") == E.DONE)
                          & ~tt.view("is_copy"))[0]
        self.obs_x = [float(tt.work[i]) for i in done][-512:]
        self.obs_t = [float(tt.finish_s[i] - tt.start_s[i])
                      for i in done][-512:]
        self._fit()
        acts = []
        cap = max(1, int(0.02 * tt.active_mask().sum()))
        for i in np.nonzero(tt.active_mask())[0]:
            i = int(i)
            if len(acts) >= cap:
                break
            if i in self._flagged:
                continue
            if _elapsed(sim, i) < MIN_OBS_INTERVALS * sim.cfg.interval_seconds:
                continue
            if _elapsed(sim, i) > 1.5 * self._predict(float(tt.work[i])):
                self._flagged.add(i)
                acts.append(E.SimAction(
                    "speculate", i, target=_pick_fast_host(
                        sim, int(tt.host[i]))))
        return acts


class Dolly(E.Technique):
    """Proactive cloning of small jobs within a 5% resource budget, gated by
    an upper-confidence-bound on cluster CPU utilization [20]."""

    name = "dolly"

    def __init__(self, budget: float = 0.05, small_job: int = 3):
        self.budget = budget
        self.small_job = small_job
        self.cloned = 0

    def on_submit(self, new_idx):
        sim = self.sim
        tt = sim.tasks
        total = max(int((~tt.view("is_copy")).sum()), 1)
        util = sim.cluster.util[:, 0]
        mean, std = float(util.mean()), float(util.std())
        ucb = mean + 1.0 * std
        acts = []
        jobs: dict[int, list[int]] = {}
        for i in new_idx:
            jobs.setdefault(int(tt.job_id[i]), []).append(int(i))
        for job, tids in jobs.items():
            if len(tids) > self.small_job or ucb > 0.8:
                continue
            if (self.cloned + len(tids)) / total > self.budget:
                break
            for i in tids:
                acts.append(E.SimAction("clone", i, n_clones=1))
                self.cloned += 1
        return acts


class GRASS(E.Technique):
    """Greedy speculation: clone the running tasks with the largest
    (current-remaining - fresh-rerun) gain while spare capacity exists [8]."""

    name = "grass"

    def __init__(self, max_spec_frac: float = 0.05):
        self.max_spec_frac = max_spec_frac
        self._flagged: set[int] = set()

    def on_interval(self):
        sim = self.sim
        tt = sim.tasks
        spare = float(np.mean(np.clip(1.0 - sim.cluster.util[:, 0], 0, 1)))
        budget = max(1, int(spare * sim.cfg.n_hosts
                            * self.max_spec_frac * 0.5))
        cands = []
        for i in np.nonzero(tt.active_mask())[0]:
            i = int(i)
            if i in self._flagged:
                continue
            if _elapsed(sim, i) < MIN_OBS_INTERVALS * sim.cfg.interval_seconds:
                continue
            gain = _remaining_estimate(sim, i) - _expected_time(sim, i)
            if gain > 2.0 * sim.cfg.interval_seconds:
                cands.append((gain, i))
        cands.sort(reverse=True)
        acts = []
        for _, i in cands[:budget]:
            self._flagged.add(i)
            acts.append(E.SimAction("speculate", i,
                                    target=_pick_fast_host(
                                        sim, int(tt.host[i]))))
        return acts


class SGC(E.Technique):
    """Pair-wise balanced upfront redundancy: each task is duplicated onto
    its paired host with probability p (approximate gradient coding) [9]."""

    name = "sgc"

    def __init__(self, p: float = 0.15):
        self.p = p

    def on_submit(self, new_idx):
        sim = self.sim
        acts = []
        n = sim.cfg.n_hosts
        for i in new_idx:
            if sim.rng.random() < self.p:
                pair = (int(i) + n // 2) % n
                acts.append(E.SimAction("clone", int(i), target=pair,
                                        n_clones=1))
        return acts


class Wrangler(E.Technique):
    """Linear straggler-probability model on host utilization counters with
    a confidence threshold; predicted-unsafe placements are delayed [17]."""

    name = "wrangler"

    def __init__(self, threshold: float = 0.7, max_delay: int = 3):
        self.threshold = threshold
        self.max_delay = max_delay
        self.w = None           # ridge weights, set by pretraining
        self._delays: dict[int, int] = {}

    def train(self, feats: np.ndarray, labels: np.ndarray,
              l2: float = 1e-2):
        A = np.concatenate([feats, np.ones((len(feats), 1))], 1)
        self.w = np.linalg.solve(A.T @ A + l2 * np.eye(A.shape[1]),
                                 A.T @ labels)

    def _prob(self, hosts_feats: np.ndarray) -> np.ndarray:
        if self.w is None:
            return np.zeros(len(hosts_feats))
        A = np.concatenate([hosts_feats,
                            np.ones((len(hosts_feats), 1))], 1)
        return np.clip(A @ self.w, 0, 1)

    def _host_feats(self) -> np.ndarray:
        c = self.sim.cluster
        return np.concatenate(
            [c.util, c.speed[:, None] / c.speed.max()], 1)

    def on_submit(self, new_idx):
        return self._maybe_delay(new_idx)

    def on_interval(self):
        tt = self.sim.tasks
        pend = np.nonzero(tt.view("state") == E.PENDING)[0]
        return self._maybe_delay(pend)

    def _maybe_delay(self, idx):
        if self.w is None or len(idx) == 0:
            return []
        probs = self._prob(self._host_feats())
        online = self.sim.cluster.online()
        safe_exists = bool((probs[online] < self.threshold).any()) \
            if online.any() else False
        acts = []
        for i in idx:
            i = int(i)
            if safe_exists:
                continue  # scheduler will find a safe host
            d = self._delays.get(i, 0)
            if d < self.max_delay:
                self._delays[i] = d + 1
                acts.append(E.SimAction("delay", i, delay=1))
        return acts


# ------------------------------ IGRU-SD -----------------------------------


def gru_init(key, n_in: int, hidden: int):
    k1, k2, k3 = jax.random.split(key, 3)
    s = 1.0 / np.sqrt(n_in)
    sh = 1.0 / np.sqrt(hidden)
    return {
        "wx": jax.random.normal(k1, (n_in, 3 * hidden)) * s,
        "wh": jax.random.normal(k2, (hidden, 3 * hidden)) * sh,
        "b": jnp.zeros((3 * hidden,)),
        "head": {"w": jax.random.normal(k3, (hidden, 1)) * sh,
                 "b": jnp.zeros((1,))},
    }


def gru_apply(params, xs):
    """xs: (T, B, n_in) -> (B,) predicted normalized completion time."""
    hidden = params["wh"].shape[0]

    def cell(h, x):
        z = x @ params["wx"] + h @ params["wh"] + params["b"]
        r, u, n = jnp.split(z, 3, -1)
        r, u = jax.nn.sigmoid(r), jax.nn.sigmoid(u)
        n = jnp.tanh(x @ params["wx"][:, 2 * hidden:]
                     + r * (h @ params["wh"][:, 2 * hidden:]))
        h = (1 - u) * n + u * h
        return h, None

    h0 = jnp.zeros((xs.shape[1], hidden))
    h, _ = jax.lax.scan(cell, h0, xs)
    out = h @ params["head"]["w"] + params["head"]["b"]
    return jax.nn.softplus(out[..., 0])


@jax.jit
def _gru_loss(params, xs, y):
    return jnp.mean((gru_apply(params, xs) - y) ** 2)


@jax.jit
def _gru_step(params, opt, xs, y):
    loss, g = jax.value_and_grad(_gru_loss)(params, xs, y)
    params, opt = nets.adam_update(params, g, opt, lr=1e-2)
    return params, opt, loss


class IGRUSD(E.Technique):
    """GRU-based resource/latency prediction + detection threshold, with the
    same speculate/rerun mitigation as START (paper §4.6 fairness note).

    Deliberately ignores host heterogeneity (the paper's criticism): its
    features are task-progress only, no host capability terms.
    """

    name = "igru-sd"

    HIST = 5
    FEATS = 3  # progress fraction, rate, elapsed/expected

    def __init__(self, seed: int = 0):
        self.params = gru_init(jax.random.PRNGKey(seed), self.FEATS, 16)
        self.hist: dict[int, list[np.ndarray]] = {}
        self._flagged: set[int] = set()
        self._last_pred: float | None = None

    def train(self, xs: np.ndarray, y: np.ndarray, epochs: int = 200):
        opt = nets.adam_init(self.params)
        for _ in range(epochs):
            self.params, opt, _ = _gru_step(
                self.params, opt, jnp.asarray(xs), jnp.asarray(y))

    def _task_feats(self, i: int) -> np.ndarray:
        sim = self.sim
        tt = sim.tasks
        el = max(_elapsed(sim, i), 1.0)
        exp = max(_expected_time(sim, i), 1.0)
        return np.array([
            float(tt.progress[i] / max(tt.work[i], 1e-9)),
            float(tt.progress[i] / el / sim.cfg.host_ips_mean),
            float(el / exp)], np.float32)

    def on_interval(self):
        sim = self.sim
        tt = sim.tasks
        run = [int(i) for i in np.nonzero(tt.active_mask())[0]]
        for i in run:
            self.hist.setdefault(i, []).append(self._task_feats(i))
        ready = [i for i in run if len(self.hist.get(i, [])) >= self.HIST
                 and i not in self._flagged]
        self._last_pred = 0.0
        if not ready:
            return []
        xs = np.stack([np.stack(self.hist[i][-self.HIST:]) for i in ready],
                      axis=1)
        # pad the job axis to a power of two: one jit compile per bucket
        n = xs.shape[1]
        pad = max(1 << (n - 1).bit_length(), 1) - n
        if pad:
            xs = np.concatenate(
                [xs, np.zeros((xs.shape[0], pad, xs.shape[2]),
                              xs.dtype)], axis=1)
        preds = np.asarray(gru_apply(self.params, jnp.asarray(xs)))[:n]
        acts = []
        n_strag = 0.0
        cap = max(1, int(0.02 * len(run)))
        for i, p in zip(ready, preds):
            exp = _expected_time(sim, i)
            n_strag += float(p * exp > 1.5 * exp)
            if p > 1.5 and _elapsed(sim, i) > exp and len(acts) < cap:
                self._flagged.add(i)
                kind = "speculate" if tt.is_deadline[i] else "rerun"
                acts.append(E.SimAction(kind, i, target=_pick_fast_host(
                    sim, int(tt.host[i]))))
        self._last_pred = n_strag
        return acts

    def predicted_straggler_count(self):
        return self._last_pred


def pretrain_igru(tech: IGRUSD, sim_done: E.Simulation,
                  epochs: int = 200) -> None:
    """Train the GRU on (progress-history -> completion/expected ratio) pairs
    from a finished warmup simulation."""
    tt = sim_done.tasks
    xs, ys = [], []
    done = np.nonzero((tt.view("state") == E.DONE)
                      & ~tt.view("is_copy"))[0]
    for i in done:
        i = int(i)
        total = float(tt.finish_s[i] - tt.start_s[i])
        exp = float(tt.work[i] / sim_done.cfg.host_ips_mean)
        # reconstruct an idealized progress history at the observed rate
        frac = np.linspace(0.15, 0.75, IGRUSD.HIST)
        rate = float(tt.work[i]) / max(total, 1.0) / sim_done.cfg.host_ips_mean
        el = frac * total
        feats = np.stack([frac, np.full_like(frac, rate), el / exp], 1)
        xs.append(feats)
        ys.append(total / exp)
    if not xs:
        return
    tech.train(np.stack(xs, axis=1).astype(np.float32),
               np.array(ys, np.float32), epochs=epochs)


def pretrain_wrangler(tech: Wrangler, sim_done: E.Simulation) -> None:
    """Train Wrangler's linear model on (host utilization counters at job
    completion -> was-straggler) pairs from a warmup simulation [17]."""
    feats, labels = [], []
    c = sim_done.cluster
    speed_n = c.speed / c.speed.max()
    hist = sim_done.util_history
    for rec in sim_done.completed_jobs:
        t = min(rec["t"] - 1, len(hist) - 1)
        if t < 0:
            continue
        util = hist[t]
        for h, s in zip(rec["hosts"], rec["straggler"]):
            if h < 0:  # finished via a copy while unplaced
                continue
            feats.append(np.concatenate([util[int(h)],
                                         [speed_n[int(h)]]]))
            labels.append(float(s))
    if feats:
        tech.train(np.array(feats), np.array(labels))
