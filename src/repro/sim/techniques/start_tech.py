"""START policy (paper §3 end-to-end) on the unified policy API.

Per interval: builds M_H from the host telemetry view, per-active-job M_T
from task requirements/placements, runs the Encoder-LSTM -> Pareto
pipeline and emits Algorithm-1 mitigation actions (speculate for deadline
jobs, rerun otherwise) once a job is down to its floor(E_S) predicted
stragglers.

``pretrain`` reproduces §4.4: run a random-scheduler simulation, collect
per-job (feature sequence, MLE-fitted (alpha, beta)) pairs, train with
MSE.  The class is :class:`repro.policy.Pretrainable`, so sweep runners
pretrain it through the registry entry rather than by name.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import features
from repro.core.start import STARTController
from repro.policy import (Action, EVENT_INTERVAL, Policy, PretrainContext,
                          TelemetryView, register)
from repro.sim.config import SimConfig


def _host_matrix(view: TelemetryView) -> np.ndarray:
    h = view.hosts
    return features.host_matrix_np(
        util=np.clip(h.util, 0, 2), cap=h.cap, cost=h.cost,
        power_max=h.power_max, n_tasks=h.n_tasks)


def _prev_host_feature(view: TelemetryView, tids: np.ndarray) -> np.ndarray:
    """The paper's M_T 'host of the previous interval' column: the current
    placement while a task holds one, else the host it ran on before its
    last restart/bounce (``prev_host``) — NOT -1, which read as 'never
    placed' for every restarted task."""
    tt = view.tasks
    host = tt.host[tids]
    return np.where(host >= 0, host, tt.prev_host[tids])


def _task_matrix(view: TelemetryView, tids) -> np.ndarray:
    """Single-job M_T (regression-test surface; the hot path uses
    :func:`_task_matrices`)."""
    tids = np.asarray(tids, np.int64)
    q = len(tids)
    return features.task_matrix_batch_np(
        view.tasks.req[tids], _prev_host_feature(view, tids),
        np.zeros(q, np.int64), np.arange(q), 1,
        view.config.n_hosts, view.config.max_tasks)[0]


def _task_matrices(view: TelemetryView, jobs: np.ndarray) -> np.ndarray:
    """(len(jobs), max_tasks, TASK_FEATURES) float32 task matrices for a
    set of jobs, assembled in one CSR-vectorized numpy pass (no per-job
    list comprehensions, no per-job XLA dispatch)."""
    starts = view.jobs.start[jobs]
    counts = view.jobs.count[jobs]
    rows = np.repeat(np.arange(len(jobs)), counts)
    offs = (np.arange(int(counts.sum()))
            - np.repeat(np.cumsum(counts) - counts, counts))
    tids = np.repeat(starts, counts) + offs
    return features.task_matrix_batch_np(
        view.tasks.req[tids], _prev_host_feature(view, tids),
        rows, offs, len(jobs), view.config.n_hosts,
        view.config.max_tasks)


@register("start", epochs_knob="pretrain_epochs",
          description="the paper's Encoder-LSTM -> Pareto predictor with "
                      "Algorithm-1 mitigation and a regime-adaptive "
                      "expected-benefit guard")
class START(Policy):
    """Prediction + mitigation with a utilization-adaptive benefit guard.

    A re-execution starts from zero progress, so it only helps when
    ``work/eff(target) < remaining/eff(source)`` with a safety *margin*
    for the load the migration itself adds.  The paper's CloudSim runs at
    ~7% utilization where nearly any migration pays off; at scaled-down
    load a fixed 25% margin suppressed nearly every action in the
    heavy-tail/overload regimes (START tied ``none`` there).  The margin
    is therefore a policy parameter scaling with *task-attributable*
    cluster utilization (observed CPU utilization minus the configured
    reserved floor): ``margin_lo`` at an idle cluster — negative, i.e.
    optimistic, since a losing speculative copy costs only cheap idle
    capacity while hedging against future contention/faults — rising to
    ``margin_hi`` at saturation.  RERUN kills the original task, so it
    never goes optimistic: its margin is floored at
    ``rerun_margin_floor``.  Pass ``margin=`` to pin a fixed margin for
    both kinds (0.25 reproduces the legacy fixed 25% guard bitwise).

    The paper's adaptive straggler parameter (§4.3: "we dynamically
    change the k value ... with the initial value as 1.5") follows the
    same utilization signal: ``k_lo`` when idle (flag more of the tail,
    mitigate early) up to ``k_hi`` at saturation.
    """

    name = "start"
    # START only acts at interval decision points (decide() filters on
    # EVENT_INTERVAL) — let the engine skip the submit-time view+call
    submit_hook = False

    def __init__(self, controller: STARTController | None = None,
                 seed: int = 0, margin: float | None = None,
                 margin_lo: float = -0.50, margin_hi: float = 0.60,
                 rerun_margin_floor: float = 0.10,
                 k_lo: float = 1.0, k_hi: float = 1.5,
                 use_fused_step: bool = True):
        self._controller = controller
        self.controller = controller
        self.use_fused_step = use_fused_step   # forwards to the controller
        self.seed = seed
        self.margin = margin
        self.margin_lo = margin_lo
        self.margin_hi = margin_hi
        self.rerun_margin_floor = rerun_margin_floor
        self.k_lo = k_lo
        self.k_hi = k_hi
        self._util = 0.0
        self._last_es_sum: float | None = None

    @property
    def use_fused_step(self) -> bool:
        """Whether the per-interval pipeline runs as the fused device
        program.  Forwards to the bound controller so the policy flag
        can never disagree with actual behavior — setting it at any
        point (constructor kwarg, sweep ``technique_kwargs``, or plain
        attribute assignment on a pretrained instance) takes effect."""
        if self._controller is not None:
            return self._controller.use_fused_step
        return self._use_fused_step

    @use_fused_step.setter
    def use_fused_step(self, value: bool) -> None:
        self._use_fused_step = bool(value)
        if self._controller is not None:
            self._controller.use_fused_step = bool(value)

    # ------------------------------ pretraining ----------------------------

    @classmethod
    def pretrain(cls, ctx: PretrainContext) -> "START":
        ctrl = pretrain(dataclasses.replace(ctx.config, seed=7),
                        epochs=30 if ctx.epochs is None else ctx.epochs,
                        lr=1e-3)
        # ctx.kwargs: per-technique sweep knobs (margin, k_lo, ...)
        return cls(controller=ctrl, **ctx.kwargs)

    # ------------------------------ policy api -----------------------------

    def _ensure_controller(self, view: TelemetryView) -> STARTController:
        if self._controller is None:
            cfg = view.config
            self._controller = STARTController(
                n_hosts=cfg.n_hosts, max_tasks=cfg.max_tasks,
                k=cfg.k, seed=self.seed,
                use_fused_step=self.use_fused_step)
        self.controller = self._controller
        return self._controller

    def observe(self, view: TelemetryView) -> None:
        ctrl = self._ensure_controller(view)
        # task-attributable utilization: the guard/k adaptation should
        # respond to load that mitigation competes with, not the static
        # reserved floor (overload-scenario experiments)
        raw = float(np.clip(view.hosts.util[:, 0].mean(), 0.0, 1.0))
        reserved = float(getattr(view.config, "reserved_utilization", 0.0))
        self._util = float(np.clip(raw - reserved, 0.0, 1.0))
        # adaptive straggler parameter (paper §4.3: "we dynamically change
        # the k value based on empirical results for the data up till the
        # current interval with the initial value as 1.5"): mitigate more
        # aggressively when the cluster has headroom, conservatively when
        # it is loaded.
        ctrl.predictor.k = self.k_lo + (self.k_hi - self.k_lo) * self._util
        ctrl.observe_hosts(_host_matrix(view))
        # ground-truth MA update from jobs completed so far (the engine
        # keeps the 0.8-decay moving average)
        ctrl.observe_straggler_counts(view.straggler_ma)

    def benefit_margin(self, kind: str = "speculate") -> float:
        """Migration-overhead margin for the expected-benefit guard at the
        most recently observed utilization.  RERUN margins never drop
        below ``rerun_margin_floor`` (a re-run forfeits the original's
        progress; a speculative copy does not)."""
        if self.margin is not None:
            return self.margin
        m = self.margin_lo + (self.margin_hi - self.margin_lo) * self._util
        if kind == "rerun":
            m = max(m, self.rerun_margin_floor)
        return m

    def decide(self, view: TelemetryView) -> list[Action]:
        if view.event != EVENT_INTERVAL:
            return []
        ctrl = self._ensure_controller(view)
        active = view.jobs.active()
        if len(active) == 0:
            self._last_es_sum = 0.0
            return []
        # array-native decision path: feature batch + trigger compare run
        # over the whole active set at once (an active job always has
        # open_count incomplete original tasks, so open_count IS the
        # remaining-task count the Algorithm-1 trigger compares against);
        # per-job task-id lists are built only for triggered jobs
        mts = _task_matrices(view, active)
        q = np.asarray(view.jobs.count[active], np.float32)

        def incomplete(job: int):
            # (tids, hosts, slots) — the third element maps each open
            # task to its M_T row (tid - CSR start) for the per-task
            # trigger; the milestone trigger ignores it
            inc = view.jobs.incomplete_tasks(job)
            start = int(view.jobs.start[job])
            return ([int(i) for i in inc],
                    [int(view.tasks.host[i]) for i in inc],
                    [int(i) - start for i in inc])

        # target scoring: prefer fast + idle hosts among straggler-MA ties
        h = view.hosts
        load = h.util[:, 0] - 0.5 * (h.speed / h.speed.max())
        acts = ctrl.decide_arrays(
            active, mts, q, view.jobs.open_count[active],
            view.jobs.deadline[active], incomplete, host_load=load)
        self._last_es_sum = ctrl.es_total(int(j) for j in active)
        # expected-benefit guard: a re-execution starts from zero progress,
        # so it only helps when  work/eff(target) < remaining/eff(source)
        # with the utilization-scaled, kind-aware margin (class docstring)
        eff = h.effective_speed()
        tt = view.tasks
        out = []
        for a in acts:
            src, tgt = a.source_host, a.target_host
            i = a.task_id
            kind = "speculate" if a.kind.value == "speculate" else "rerun"
            down = src >= 0 and h.downtime[src] > 0
            if not down:
                factor = 1.0 / (1.0 + self.benefit_margin(kind))
                src_eff = max(eff[src] if src >= 0 else 0.0, 1e-9)
                tgt_eff = max(eff[tgt], 1e-9)
                remaining = float(tt.work[i] - tt.progress[i])
                t_stay = remaining / src_eff
                t_move = float(tt.work[i]) / (factor * tgt_eff)
                if t_move >= t_stay:
                    continue
            out.append(Action(kind=kind, task=a.task_id,
                              target=a.target_host))
        return out

    def predicted_straggler_count(self) -> float | None:
        return self._last_es_sum


@register("start-eager", epochs_knob="pretrain_epochs",
          substrates=("sim", "pod"),
          description="START with the per-task predicted-straggler "
                      "trigger: mitigation starts as soon as the "
                      "predicted set is nonempty (hysteresis + per-task "
                      "cooldown) instead of at the q - floor(E_S) "
                      "completion milestone")
class STARTEager(START):
    """START with ``trigger="per_task"`` (the late-trigger-gap fix).

    Legacy START waits for a job to be down to its floor(E_S) open
    tasks — in saturated regimes (``overload``) that completion
    milestone arrives rarely and late, so START roughly ties ``none``.
    This variant mitigates the *predicted* stragglers directly: each
    interval the per-task score head ranks a job's open tasks, the
    top-floor(E_S) form the predicted set, and a task that stays in the
    set ``hysteresis`` consecutive intervals is speculated/rerun (then
    rests ``cooldown`` intervals).  Everything else — predictor,
    pretraining, the utilization-adaptive expected-benefit guard — is
    inherited from :class:`START`.

    On the pod substrate the same eager semantics run through
    :class:`repro.distributed.straggler_runtime.StartEagerPodPolicy`
    (per-host predicted-straggler streaks -> backup shards, chronic
    stragglers -> evict).
    """

    name = "start-eager"

    def __init__(self, controller: STARTController | None = None,
                 seed: int = 0, score_on: float = 0.10,
                 hysteresis: int = 5, cooldown: int = 30, **kw):
        super().__init__(controller=controller, seed=seed, **kw)
        self.score_on = score_on
        self.hysteresis = hysteresis
        self.cooldown = cooldown
        self._pod = None
        if self._controller is not None:
            self._configure_trigger(self._controller)

    def _configure_trigger(self, ctrl: STARTController) -> None:
        ctrl.trigger = "per_task"
        ctrl.score_on = self.score_on
        ctrl.hysteresis = self.hysteresis
        ctrl.cooldown = self.cooldown

    def _ensure_controller(self, view: TelemetryView) -> STARTController:
        ctrl = super()._ensure_controller(view)
        self._configure_trigger(ctrl)
        return ctrl

    # --------------------------- pod substrate -----------------------------

    def _pod_policy(self):
        if self._pod is None:
            from repro.distributed.straggler_runtime import \
                StartEagerPodPolicy
            self._pod = StartEagerPodPolicy(hysteresis=self.hysteresis,
                                            cooldown=self.cooldown)
        return self._pod

    def observe(self, view: TelemetryView) -> None:
        from repro.sim.techniques.replication import _on_pod
        if _on_pod(view):
            self._pod_policy().observe(view)
            return
        super().observe(view)

    def decide(self, view: TelemetryView) -> list[Action]:
        from repro.sim.techniques.replication import _on_pod
        if _on_pod(view):
            return self._pod_policy().decide(view)
        return super().decide(view)

    def forget_tasks(self, task_ids) -> None:
        if self._pod is not None:
            self._pod.forget_tasks(task_ids)
        if self._controller is not None:
            self._controller.forget_tasks(task_ids)


def collect_training_data(cfg: SimConfig, horizon: int = 5
                          ) -> tuple[np.ndarray, np.ndarray]:
    """§4.4: random-scheduler run ->
    (xs: (T, jobs, dim), targets: (jobs, 2))."""
    from repro.sim.engine import Simulation
    from repro.sim.scheduler import RandomScheduler

    sim = Simulation(cfg, technique=NoOpRecorder(horizon),
                     scheduler=RandomScheduler())
    sim.run()
    rec: NoOpRecorder = sim.technique  # type: ignore[assignment]
    return rec.dataset(sim.snapshot())


class EmptyWarmupError(RuntimeError):
    """The warmup simulation completed no jobs — nothing to fit."""


class NoOpRecorder(Policy):
    """Records host matrices + job completions to build the training set."""

    name = "recorder"

    def __init__(self, horizon: int = 5):
        self.horizon = horizon
        self.host_hist: list[np.ndarray] = []

    def observe(self, view: TelemetryView) -> None:
        self.host_hist.append(_host_matrix(view))

    def dataset(self, view: TelemetryView):
        from repro.core import pareto
        recs = view.completed_jobs
        if not recs:
            raise EmptyWarmupError("no completed jobs to train on")
        hh = np.stack(self.host_hist)  # (T_total, n, m)
        h = self.horizon
        # per-job trailing host-history windows, left-clamped to hh[0]
        # (identical data to the old per-job slice + repeat-pad loop),
        # gathered for every job at once
        t_end = np.array([min(rec["t"], len(hh)) - 1 for rec in recs])
        idx = np.maximum(
            t_end[:, None] + np.arange(-h + 1, 1)[None, :], 0)
        seqs = hh[idx].reshape(len(recs), h, -1)       # (J, h, n*m)
        jobs = np.array([rec["job"] for rec in recs], np.int64)
        mts = _task_matrices(view, jobs).reshape(len(recs), 1, -1)
        xs = np.concatenate(
            [seqs, np.repeat(mts, h, axis=1)], axis=-1)  # (J, h, dim)
        ys = []
        for rec in recs:
            a, b = pareto.fit_pareto_np(rec["times"])
            # beta regressed in interval units (predictor beta_scale)
            ys.append([float(a), float(b) / view.interval_seconds])
        return np.ascontiguousarray(xs.transpose(1, 0, 2)), \
            np.array(ys, np.float32)


def pretrain(cfg: SimConfig, epochs: int = 30, lr: float = 1e-3,
             seed: int = 0) -> STARTController:
    """Train a STARTController's predictor offline (paper §4.4).

    The paper uses lr = 1e-5 for its long offline phase; benchmarks use a
    larger lr with fewer epochs for wall-clock sanity (same optimizer).

    A saturated training regime (e.g. the overload scenario at small
    grid sizes) can complete zero jobs in the warmup horizon, leaving
    nothing to fit — in that case the arrival rate is halved (up to a
    few times, deterministically) until the warmup yields completions,
    rather than failing the whole sweep.
    """
    train_cfg = cfg
    for _ in range(4):
        try:
            xs, ys = collect_training_data(train_cfg)
            break
        except EmptyWarmupError:
            train_cfg = dataclasses.replace(
                train_cfg, arrival_rate=train_cfg.arrival_rate / 2.0)
    else:
        xs, ys = collect_training_data(train_cfg)  # raise with context
    ctrl = STARTController(n_hosts=cfg.n_hosts, max_tasks=cfg.max_tasks,
                           k=cfg.k, seed=seed,
                           beta_scale=cfg.interval_seconds)
    ctrl.predictor.fit(xs, ys, epochs=epochs, lr=lr)
    return ctrl
