"""START technique bound to the simulator (paper §3 end-to-end).

Per interval: builds M_H from cluster state, per-active-job M_T from task
requirements/placements, runs the Encoder-LSTM -> Pareto pipeline and emits
Algorithm-1 mitigation actions (speculate for deadline jobs, rerun
otherwise) once a job is down to its floor(E_S) predicted stragglers.

``pretrain`` reproduces §4.4: run a random-scheduler simulation, collect
per-job (feature sequence, MLE-fitted (alpha, beta)) pairs, train with MSE.
"""
from __future__ import annotations

import numpy as np

from repro.core import features
from repro.core.start import JobView, STARTController
from repro.sim import engine as E
from repro.sim.config import SimConfig
from repro.sim.scheduler import RandomScheduler


def _host_matrix(sim: E.Simulation) -> np.ndarray:
    c = sim.cluster
    return np.asarray(features.host_matrix(
        util=np.clip(c.util, 0, 2), cap=c.cap, cost=c.cost,
        power_max=c.power_max, n_tasks=c.n_tasks))


def _task_matrix(sim: E.Simulation, tids: list[int]) -> np.ndarray:
    tt = sim.tasks
    req = tt.req[tids] if tids else np.zeros((0, 4))
    prev = np.array([tt.host[i] for i in tids]) if tids else np.zeros(0)
    return np.asarray(features.task_matrix(
        req=req, prev_host=prev, n_hosts=sim.cfg.n_hosts,
        max_tasks=sim.cfg.max_tasks))


class START(E.Technique):
    name = "start"

    def __init__(self, controller: STARTController | None = None,
                 seed: int = 0):
        self._controller = controller
        self.seed = seed
        self._last_es_sum: float | None = None

    def bind(self, sim: E.Simulation) -> None:
        super().bind(sim)
        if self._controller is None:
            self._controller = STARTController(
                n_hosts=sim.cfg.n_hosts, max_tasks=sim.cfg.max_tasks,
                k=sim.cfg.k, seed=self.seed)
        self.controller = self._controller

    def on_interval(self) -> list[E.SimAction]:
        sim = self.sim
        # adaptive straggler parameter (paper §4.3: "we dynamically change
        # the k value based on empirical results for the data up till the
        # current interval with the initial value as 1.5"): mitigate more
        # aggressively when the cluster has headroom, conservatively when
        # it is loaded.
        util = float(np.clip(sim.cluster.util[:, 0].mean(), 0.0, 1.0))
        self.controller.predictor.k = 1.1 + 0.8 * util
        self.controller.observe_hosts(_host_matrix(sim))
        # ground-truth MA update from jobs completed so far
        self.controller.observe_straggler_counts(
            sim.straggler_ma)  # engine keeps the 0.8-decay MA
        views = []
        for job in sim.active_jobs():
            inc = sim.job_incomplete_tasks(job)
            if not inc:
                continue
            views.append(JobView(
                job_id=job, q=len(sim.job_tasks[job]),
                deadline_oriented=sim.job_deadline[job],
                incomplete_task_ids=inc,
                task_hosts=[int(sim.tasks.host[i]) for i in inc],
                task_matrix=_task_matrix(sim, sim.job_tasks[job])))
        # target scoring: prefer fast + idle hosts among straggler-MA ties
        c = sim.cluster
        load = c.util[:, 0] - 0.5 * (c.speed / c.speed.max())
        acts = self.controller.decide(views, host_load=load)
        self._last_es_sum = float(
            sum(self.controller._es_cache.get(v.job_id, 0.0)
                for v in views))
        # expected-benefit guard: a re-execution starts from zero progress,
        # so it only helps when  work/eff(target) < remaining/eff(source)
        # (with a 25% margin for the load the migration itself adds). The
        # paper's CloudSim runs at ~7% utilization where this nearly always
        # holds; at our scaled-down load the guard keeps mitigation from
        # feeding the very contention it is meant to cure (DESIGN.md).
        eff = c.effective_speed()
        tt = sim.tasks
        out = []
        for a in acts:
            src, tgt = a.source_host, a.target_host
            i = a.task_id
            down = src >= 0 and c.downtime[src] > 0
            if not down:
                src_eff = max(eff[src] if src >= 0 else 0.0, 1e-9)
                tgt_eff = max(eff[tgt], 1e-9)
                remaining = float(tt.work[i] - tt.progress[i])
                t_stay = remaining / src_eff
                t_move = float(tt.work[i]) / (0.8 * tgt_eff)
                if t_move >= t_stay:
                    continue
            kind = "speculate" if a.kind.value == "speculate" else "rerun"
            out.append(E.SimAction(kind=kind, task=a.task_id,
                                   target=a.target_host))
        return out

    def predicted_straggler_count(self) -> float | None:
        return self._last_es_sum


def collect_training_data(cfg: SimConfig, horizon: int = 5
                          ) -> tuple[np.ndarray, np.ndarray]:
    """§4.4: random-scheduler run -> (xs: (T, jobs, dim), targets: (jobs, 2))."""
    sim = E.Simulation(cfg, technique=NoOpRecorder(horizon),
                       scheduler=RandomScheduler())
    sim.run()
    rec: NoOpRecorder = sim.technique  # type: ignore[assignment]
    return rec.dataset(sim)


class NoOpRecorder(E.Technique):
    """Records host matrices + job completions to build the training set."""

    name = "recorder"

    def __init__(self, horizon: int = 5):
        self.horizon = horizon
        self.host_hist: list[np.ndarray] = []

    def on_interval(self) -> list[E.SimAction]:
        self.host_hist.append(_host_matrix(self.sim))
        return []

    def dataset(self, sim: E.Simulation):
        from repro.core import pareto
        xs, ys = [], []
        hh = np.stack(self.host_hist)  # (T_total, n, m)
        for rec in sim.completed_jobs:
            t_end = min(rec["t"], len(hh)) - 1
            lo = max(0, t_end - self.horizon + 1)
            seq = hh[lo:t_end + 1]
            if len(seq) < self.horizon:
                seq = np.concatenate(
                    [np.repeat(seq[:1], self.horizon - len(seq), 0), seq])
            mt = _task_matrix(sim, sim.job_tasks[rec["job"]])
            x = np.concatenate(
                [seq.reshape(self.horizon, -1),
                 np.repeat(mt.reshape(1, -1), self.horizon, 0)], axis=-1)
            a, b = pareto.fit_pareto_np(rec["times"])
            xs.append(x)
            # beta regressed in interval units (predictor beta_scale)
            ys.append([float(a), float(b) / sim.cfg.interval_seconds])
        if not xs:
            raise RuntimeError("no completed jobs to train on")
        return np.stack(xs, axis=1), np.array(ys, np.float32)


def pretrain(cfg: SimConfig, epochs: int = 30, lr: float = 1e-3,
             seed: int = 0) -> STARTController:
    """Train a STARTController's predictor offline (paper §4.4).

    The paper uses lr = 1e-5 for its long offline phase; benchmarks use a
    larger lr with fewer epochs for wall-clock sanity (same optimizer).
    """
    xs, ys = collect_training_data(cfg)
    ctrl = STARTController(n_hosts=cfg.n_hosts, max_tasks=cfg.max_tasks,
                           k=cfg.k, seed=seed,
                           beta_scale=cfg.interval_seconds)
    ctrl.predictor.fit(xs, ys, epochs=epochs, lr=lr)
    return ctrl
