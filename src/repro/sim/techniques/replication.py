"""Replication-timing and redundancy-level baseline families.

Two policy families from the replication literature the paper's
evaluation field draws on, as first-class registry entries on the
unified policy API (both substrates):

* **Replication timing** — Wang, Joshi & Wornell, "Efficient Straggler
  Replication in Large-scale Parallel Computing": wait until a fraction
  ``p`` of a job's work is complete, then replicate the tasks still in
  the tail (single fork).  ``single-fork`` keeps originals running
  (first result wins); ``fork-relaunch`` is the earliest-kill variant
  (kill the laggard, relaunch fresh on a new host).  The fork point is
  not a fixed delay: when ``p`` is not pinned it is chosen from the
  *empirical* execution-time tail — the existing Pareto MLE fit plus
  the fork-point quantile helper — by minimizing an approximate
  ``latency + cost_weight * cost`` objective over candidate fractions
  (``cost_weight`` is the paper's latency-vs-cost knob: 0 buys latency
  at any cost, large values replicate only when nearly free).

* **Redundancy level** — Aktas & Soljanin, "Optimizing Redundancy
  Levels in Master-Worker Compute Clusters for Straggler Mitigation":
  launch every task with ``r`` replicas up front (``redundancy-fixed``)
  — and, since their central observation is that the optimal ``r``
  flips with load, ``redundancy-adaptive`` scales ``r`` down from
  ``r_max`` toward 1 as task-attributable utilization (observed CPU
  utilization minus the configured reserved floor — the same signal
  START's regime-adaptive guard uses) approaches ``util_knee``.

Both families also run on the distributed training pod: the runtime
translates ``speculate`` to a backup shard and ``rerun`` to an eviction,
and each host's horizon-step window is one synthetic task, so the fork
trigger (window progress fraction) and the tail filter (window-elapsed
beyond the fitted fork quantile) carry over unchanged.

Decide paths are vectorized: per-interval work is numpy over the CSR
job index (segment sums over contiguous task ranges) — Python loops
touch only the handful of emitted actions, never the task table.
"""
from __future__ import annotations

import numpy as np

from repro.core import pareto
from repro.policy import (Action, DONE, EVENT_INTERVAL, EVENT_SUBMIT,
                          Policy, PretrainContext, RUNNING, TelemetryView,
                          register)

#: candidate launch fractions for the tail-adaptive fork point
P_GRID = np.linspace(0.50, 0.95, 10)
#: minimum completed-task samples before trusting the online tail fit
MIN_TAIL_SAMPLES = 8
#: nominal tail size the fork objective prices replicas against (the
#: paper's jobs have 2-10 tasks; what matters is only that forking
#: earlier replicates *more* tasks, so the constant's scale is enough)
TAIL_Q = 8.0


# ----------------------- fork-point objective (Wang) -----------------------

def _integral_grid() -> np.ndarray:
    # fixed quadrature grid in units of beta: dense below beta (where the
    # fresh replica cannot finish), log-spaced into the tail
    return np.concatenate([np.linspace(0.0, 1.0, 33)[1:],
                           np.geomspace(1.0, 256.0, 64)[1:]])


def fork_objective(alpha: float, p: np.ndarray, cost_weight: float,
                   kill: bool) -> np.ndarray:
    """Approximate normalized cost of forking at fraction ``p``.

    Scale-free (everything in units of beta, so only the tail index
    matters).  A task still running at the fork point t_p = F^{-1}(p)
    has residual R with P(R > s) = ((t_p+s)/t_p)^-alpha; a fresh
    replica Y is Pareto(alpha, 1).  Each of the m = TAIL_Q * (1-p)
    forked tasks then finishes after a further Z = min(R, Y) (no kill)
    or Z = Y (kill/relaunch), so

        J(p) = t_p + E[Z] * max(1, m)^(1/a_Z)
                   + cost_weight * m * E[replica runtime]

    where a_Z is Z's regular-variation index (2*alpha for the min of
    two alpha-tails, alpha after a kill) and m^(1/a_Z) is the standard
    Frechet growth rate of the max of m heavy-tailed residuals — the
    order-statistics term that makes early forking pay a latency *and*
    cost price for replicating more of the job.  A coarse stand-in for
    Wang et al.'s exact expressions, but it preserves what the policy
    consumes: cost_weight up -> fork later, kill variants fork later
    than no-kill ones, heavier tails fork earlier.
    """
    p = np.asarray(p, np.float64)
    t_p = pareto.pareto_quantile_np(alpha, 1.0, p)          # (P,)
    s = _integral_grid()                                     # (S,)
    surv_r = ((t_p[:, None] + s[None, :]) / t_p[:, None]) ** (-alpha)
    surv_y = np.where(s >= 1.0, s ** (-alpha), 1.0)
    trapezoid = getattr(np, "trapezoid", None) or np.trapz
    e_min = trapezoid(surv_r * surv_y[None, :], s, axis=1)   # E[min(R, Y)]
    e_y = alpha / (alpha - 1.0)                              # E[Y], alpha > 1
    m = TAIL_Q * (1.0 - p)
    if kill:
        e_z, a_z = e_y, alpha
    else:
        e_z, a_z = e_min, 2.0 * alpha
    latency = t_p + e_z * np.maximum(m, 1.0) ** (1.0 / a_z)
    return latency + cost_weight * m * e_z


def fork_fraction(alpha: float, cost_weight: float, kill: bool) -> float:
    """The launch fraction minimizing :func:`fork_objective` on ``P_GRID``."""
    return float(P_GRID[int(np.argmin(
        fork_objective(alpha, P_GRID, cost_weight, kill)))])


# -------------------- replication-timing policies (Wang) -------------------

@register("single-fork", substrates=("sim", "pod"),
          description="single-fork replication at launch fraction p, fork "
                      "point from the empirical Pareto tail; originals "
                      "keep running, first result wins [Wang et al.]")
class SingleFork(Policy):
    """Replicate a job's tail once a fraction ``p`` of its work is done.

    ``p=None`` (default) re-derives the launch fraction every interval
    from the fitted execution-time tail via :func:`fork_fraction`;
    passing ``p`` pins it.  Pretraining (generic, through the registry)
    seeds the tail estimate from a warmup run so early jobs fork
    sensibly before enough completions accumulate online.
    """

    name = "single-fork"
    kill = False

    def __init__(self, p: float | None = None, cost_weight: float = 0.5,
                 alpha0: float | None = None, beta0: float | None = None):
        self.p = p
        self.cost_weight = cost_weight
        self.alpha0 = alpha0
        self.beta0 = beta0
        self._forked: set[int] = set()

    @classmethod
    def pretrain(cls, ctx: PretrainContext) -> "SingleFork":
        warm = ctx.warmup()
        tech = cls(**ctx.kwargs)
        times = _done_original_times(warm)
        if times.size >= 2:
            a, b = pareto.fit_pareto_np(times.astype(np.float32))
            tech.alpha0, tech.beta0 = float(a), float(b)
        return tech

    def forget_tasks(self, task_ids) -> None:
        # substrate signal that task ids were rebound (the pod runtime, at
        # every horizon-window boundary): the new window is a new "job",
        # so the fork-once latch must reset with it
        self._forked.clear()

    # ----------------------------- tail model -----------------------------

    def _tail(self, view: TelemetryView) -> tuple[float, float] | None:
        times = _done_original_times(view)
        if times.size == 0 and _on_pod(view) and view.completed_jobs:
            # pod substrate ONLY: window tasks never reach DONE, so the
            # completed horizon-window records carry the per-host elapsed
            # times.  On the simulator these records hold queue-inclusive
            # sojourn times (finish - submit), which would inflate beta —
            # there the policy waits for real execution-time samples.
            times = np.concatenate(
                [np.asarray(r["times"], np.float64)
                 for r in view.completed_jobs])
        if times.size >= MIN_TAIL_SAMPLES:
            a, b = pareto.fit_pareto_np(times.astype(np.float32))
            return float(a), float(b)
        if self.alpha0 is not None and self.beta0 is not None:
            return self.alpha0, self.beta0
        return None

    # ------------------------------- decide --------------------------------

    def decide(self, view: TelemetryView) -> list[Action]:
        if view.event != EVENT_INTERVAL:
            return []
        tail = self._tail(view)
        if tail is None:
            return []
        alpha, beta = tail
        p = self.p if self.p is not None else fork_fraction(
            alpha, self.cost_weight, self.kill)
        if _on_pod(view) and view.tasks.n:
            # a pod window's progress fraction tops out one step short of
            # the horizon ((horizon-1)/horizon, work == horizon): clamp
            # the fork point strictly below that (epsilon absorbs the
            # one-ulp float gap vs the bincount-computed fraction), or a
            # late adaptive p silently never triggers on the pod
            horizon = float(np.max(view.tasks.work))
            if horizon > 1.0:
                p = min(p, 1.0 - 1.0 / horizon - 1e-9)
        jobs = view.jobs
        active = jobs.active()
        if self._forked:
            forked = np.fromiter(self._forked, np.int64,
                                 len(self._forked))
            active = active[~np.isin(active, forked)]
        if active.size == 0:
            return []
        # per-job completed work fraction over the CSR task ranges, one
        # vectorized segment mean (done tasks contribute 1.0)
        tt = view.tasks
        counts = jobs.count[active]
        rows = np.repeat(np.arange(len(active)), counts)
        offs = (np.arange(int(counts.sum()))
                - np.repeat(np.cumsum(counts) - counts, counts))
        tids = np.repeat(jobs.start[active], counts) + offs
        frac = np.clip(tt.progress[tids]
                       / np.maximum(tt.work[tids], 1e-9), 0.0, 1.0)
        done_frac = np.bincount(rows, weights=frac,
                                minlength=len(active)) / counts
        trig = done_frac >= p
        if not trig.any():
            return []
        # the fork set: running tasks of triggered jobs already past the
        # fork-point quantile of the fitted tail (under the model, every
        # task alive beyond t_p is a tail task)
        t_p = float(pareto.pareto_quantile_np(alpha, beta, p))
        cand_mask = (trig[rows]
                     & (tt.state[tids] == RUNNING)
                     & (view.now_s - tt.start_s[tids] > t_p))
        # the fork-once latch applies to jobs that actually forked; a job
        # triggered while its tail tasks are still pending/restarting
        # stays eligible, otherwise its eventual stragglers would never
        # be replicated
        self._forked.update(int(j)
                            for j in active[np.unique(rows[cand_mask])])
        cand = tids[cand_mask]
        if cand.size == 0:
            return []
        h = view.hosts
        score = np.where(h.online(), h.util[:, 0] - 0.2 * h.speed, np.inf)
        order = np.argsort(score, kind="stable")
        order = order[np.isfinite(score[order])]       # online hosts only
        if order.size == 0:
            return []
        kind = "rerun" if self.kill else "speculate"
        acts = []
        for rank, i in enumerate(cand):                # fork set only —
            i = int(i)                                 # never the task table
            tgt = int(order[rank % len(order)])
            if tgt == int(tt.host[i]) and len(order) > 1:
                tgt = int(order[(rank + 1) % len(order)])
            acts.append(Action(kind, i, target=tgt))
        return acts


@register("fork-relaunch", substrates=("sim", "pod"),
          description="earliest-kill single-fork variant: at the fork "
                      "point the tail task is killed and relaunched fresh "
                      "on a new host [Wang et al.]")
class ForkRelaunch(SingleFork):
    """Kill-and-relaunch variant: same fork clock, but the laggard is
    killed (``rerun``) instead of raced against a copy — cheaper in
    machine-time, costlier in forfeited progress, so the tail-adaptive
    objective forks it later."""

    name = "fork-relaunch"
    kill = True


def _done_original_times(view: TelemetryView) -> np.ndarray:
    """Execution times (start -> finish) of completed original tasks."""
    tt = view.tasks
    d = (tt.state == DONE) & ~tt.is_copy & (tt.finish_s > 0)
    return np.maximum((tt.finish_s[d] - tt.start_s[d]), 1e-3)


def _on_pod(view: TelemetryView) -> bool:
    """Is this the pod substrate's view?  (The runtime publishes its raw
    step times under ``extra``; the simulator never does.)"""
    return "step_times" in view.extra


# ------------------- redundancy-level policies (Aktas) ---------------------

@register("redundancy-fixed", substrates=("sim", "pod"),
          description="launch every task with r replicas up front "
                      "[Aktas & Soljanin]")
class FixedRedundancy(Policy):
    """Upfront redundancy level ``r``: every submitted task starts with
    ``r - 1`` clones (first result wins).  A fractional ``r`` is
    realized in expectation via the substrate's own RNG stream, which
    keeps sweep cells pure functions of their spec.

    On the pod substrate (no submit events) the level maps to backup
    shards: the ``round(r) - 1`` slowest online hosts of the last step
    get their shard backed up each step.
    """

    name = "redundancy-fixed"

    def __init__(self, r: float = 2.0):
        self.r = float(r)

    def _level(self, view: TelemetryView) -> float:
        return self.r

    def decide(self, view: TelemetryView) -> list[Action]:
        if view.event == EVENT_SUBMIT and len(view.new_tasks):
            return self._upfront_clones(view)
        if view.event == EVENT_INTERVAL and _on_pod(view):
            return self._pod_backups(view)
        return []

    def _upfront_clones(self, view: TelemetryView) -> list[Action]:
        r = max(self._level(view), 1.0)
        new = view.new_tasks
        extra = np.full(len(new), int(r) - 1, np.int64)
        fray = r - int(r)
        if fray > 0.0:
            extra = extra + (view.rng.random(len(new)) < fray)
        return [Action("clone", int(i), n_clones=int(e))
                for i, e in zip(new, extra) if e > 0]

    def _pod_backups(self, view: TelemetryView) -> list[Action]:
        n_back = int(round(max(self._level(view), 1.0))) - 1
        if n_back <= 0:
            return []
        last = np.asarray(view.extra["step_times"][-1], np.float64)
        online = view.hosts.online()
        slowest = [int(h) for h in np.argsort(-last) if online[h]]
        # task id == host id on the pod; the runtime translates the
        # speculate into a backup shard and picks the backup host
        return [Action("speculate", h) for h in slowest[:n_back]]


@register("redundancy-adaptive", substrates=("sim", "pod"),
          description="load-adaptive redundancy: r scales from r_max "
                      "toward 1 as task-attributable utilization rises "
                      "[Aktas & Soljanin]")
class AdaptiveRedundancy(FixedRedundancy):
    """Redundancy that backs off under load — Aktas & Soljanin's point
    that the optimal ``r`` flips with load, on the same
    task-attributable-utilization signal as START's regime-adaptive
    guard: ``r_max`` at an idle cluster, linearly down to 1 as observed
    CPU utilization (minus the reserved floor) reaches ``util_knee``."""

    name = "redundancy-adaptive"

    def __init__(self, r_max: float = 3.0, util_knee: float = 0.7):
        super().__init__(r=r_max)
        self.util_knee = util_knee

    def _level(self, view: TelemetryView) -> float:
        raw = float(np.clip(view.hosts.util[:, 0].mean(), 0.0, 1.0))
        reserved = float(getattr(view.config, "reserved_utilization", 0.0))
        u = float(np.clip(raw - reserved, 0.0, 1.0))
        return 1.0 + (self.r - 1.0) * max(0.0, 1.0 - u / self.util_knee)
