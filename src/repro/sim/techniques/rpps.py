"""RPPS [23]: ARIMA-style resource prediction and provisioning (used by the
paper only for the MAPE prediction-accuracy comparison, Fig. 9).

We implement the AR core of ARIMA: an online least-squares AR(p) model over
the per-interval observed straggler-completion counts, forecasting the next
interval's count. No mitigation (the original is a provisioning scheme)."""
from __future__ import annotations

import numpy as np

from repro.policy import (Action, EVENT_INTERVAL, Policy, TelemetryView,
                          register)


@register("rpps", description="online AR(p) forecast of straggler counts; "
                              "prediction only, no mitigation [23]")
class RPPS(Policy):
    name = "rpps"

    def __init__(self, order: int = 3):
        self.order = order
        self.history: list[float] = []
        self._last_pred: float | None = None

    def _observed_straggler_count(self, view: TelemetryView) -> float:
        """Stragglers among jobs completed in the last interval (observable
        online, one interval late)."""
        cnt = 0.0
        for rec in view.completed_jobs:
            if rec["t"] == view.t:
                cnt += float(rec["straggler"].sum())
        return cnt

    def observe(self, view: TelemetryView) -> None:
        self.history.append(self._observed_straggler_count(view))

    def decide(self, view: TelemetryView) -> list[Action]:
        if view.event != EVENT_INTERVAL:
            return []
        h = np.array(self.history, float)
        p = self.order
        if len(h) <= p + 2:
            self._last_pred = float(h.mean()) if len(h) else 0.0
            return []
        X = np.stack([h[i:len(h) - p + i] for i in range(p)], 1)
        y = h[p:]
        A = np.concatenate([X, np.ones((len(X), 1))], 1)
        sol, *_ = np.linalg.lstsq(A, y, rcond=None)
        nxt = np.concatenate([h[-p:], [1.0]])
        self._last_pred = float(max(nxt @ sol, 0.0))
        return []

    def predicted_straggler_count(self):
        return self._last_pred
