"""Straggler techniques: START + the paper's six baselines (+ RPPS).

Every technique is a :class:`repro.policy.Policy` registered with the
decorator-based registry (``repro.policy.register``); importing this
package is what populates the registry with the built-ins.  ``REGISTRY``
and ``make`` are kept as thin compatibility shims over the registry —
``make`` raises a ``ValueError`` listing the registered names for
unknown techniques.
"""
from repro import policy
from repro.sim.engine import NoMitigation
from repro.sim.techniques.baselines import (GRASS, SGC, Dolly, IGRUSD,
                                            NearestFit, Wrangler)
from repro.sim.techniques.rpps import RPPS
from repro.sim.techniques.start_tech import START

policy.register("none", description="no straggler mitigation "
                                    "(control)")(NoMitigation)

#: legacy name -> class mapping (the registry is the source of truth)
REGISTRY = {name: policy.registry.get(name).factory
            for name in policy.names("sim")}

BASELINES = ["nearestfit", "dolly", "grass", "sgc", "wrangler", "igru-sd"]


def make(name: str, **kw):
    """Instantiate a registered technique; unknown names raise a
    ``ValueError`` naming every registered technique."""
    return policy.make(name, **kw)


__all__ = ["REGISTRY", "BASELINES", "make", "START", "IGRUSD", "SGC",
           "Dolly", "GRASS", "NearestFit", "Wrangler", "RPPS",
           "NoMitigation"]
