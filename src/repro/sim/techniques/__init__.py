"""Straggler techniques: START + the paper's six baselines (+ RPPS)."""
from repro.sim.engine import NoMitigation
from repro.sim.techniques.baselines import (GRASS, SGC, Dolly, IGRUSD,
                                            NearestFit, Wrangler)
from repro.sim.techniques.rpps import RPPS
from repro.sim.techniques.start_tech import START

REGISTRY = {
    "none": NoMitigation,
    "start": START,
    "igru-sd": IGRUSD,
    "sgc": SGC,
    "dolly": Dolly,
    "grass": GRASS,
    "nearestfit": NearestFit,
    "wrangler": Wrangler,
    "rpps": RPPS,
}

BASELINES = ["nearestfit", "dolly", "grass", "sgc", "wrangler", "igru-sd"]


def make(name: str, **kw):
    return REGISTRY[name](**kw)

__all__ = ["REGISTRY", "BASELINES", "make", "START", "IGRUSD", "SGC",
           "Dolly", "GRASS", "NearestFit", "Wrangler", "RPPS",
           "NoMitigation"]
