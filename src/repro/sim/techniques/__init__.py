"""Straggler techniques: START + the paper's six baselines (+ RPPS),
plus the replication-timing (Wang et al.) and redundancy-level
(Aktas & Soljanin) families from the wider straggler literature.

Every technique is a :class:`repro.policy.Policy` registered with the
decorator-based registry (``repro.policy.register``); importing this
package is what populates the registry with the built-ins.  ``REGISTRY``
and ``make`` are kept as thin compatibility shims over the registry —
``make`` raises a ``ValueError`` listing the registered names for
unknown techniques.
"""
from repro import policy
from repro.sim.engine import NoMitigation
from repro.sim.techniques.baselines import (GRASS, SGC, Dolly, IGRUSD,
                                            NearestFit, Wrangler)
from repro.sim.techniques.replication import (AdaptiveRedundancy,
                                              FixedRedundancy,
                                              ForkRelaunch, SingleFork)
from repro.sim.techniques.rpps import RPPS
from repro.sim.techniques.start_tech import START, STARTEager

policy.register("none", description="no straggler mitigation "
                                    "(control)")(NoMitigation)

#: legacy name -> class mapping (the registry is the source of truth)
REGISTRY = {name: policy.registry.get(name).factory
            for name in policy.names("sim")}

BASELINES = ["nearestfit", "dolly", "grass", "sgc", "wrangler", "igru-sd"]

#: the replication-literature field (both substrates)
REPLICATION = ["single-fork", "fork-relaunch", "redundancy-fixed",
               "redundancy-adaptive"]

#: the full shipped simulator technique field, in canonical order — the
#: single source for the golden fixture grid (benchmarks/regen_golden),
#: the nightly Table-4 grid and the slow invariant grid, so the three
#: can't silently drift when a technique is added
FIELD = ("none", "start", "start-eager", "igru-sd", "sgc", "dolly",
         "grass", "nearestfit", "wrangler", "rpps", *REPLICATION)


def make(name: str, **kw):
    """Instantiate a registered technique; unknown names raise a
    ``ValueError`` naming every registered technique."""
    return policy.make(name, **kw)


__all__ = ["REGISTRY", "BASELINES", "REPLICATION", "FIELD", "make", "START",
           "STARTEager", "IGRUSD", "SGC", "Dolly", "GRASS", "NearestFit",
           "Wrangler", "RPPS", "NoMitigation", "SingleFork", "ForkRelaunch",
           "FixedRedundancy", "AdaptiveRedundancy"]
