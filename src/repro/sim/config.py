"""Simulation configuration (paper Tables 3-4, §4.2-4.3).

The paper simulates a CloudSim datacenter with 400 VMs built from three
physical-machine types, PlanetLab-derived workload traces (300 s scheduling
intervals, 2880-interval traces), Poisson(1.2) job arrivals of 2-10 task
jobs (50% deadline-driven), and Weibull(k=1.5, lambda=2) fault injection.
"""
from __future__ import annotations

import dataclasses
import functools

import numpy as np

INTERVAL_SECONDS = 300.0  # PlanetLab scheduling interval size (§4.2)


@dataclasses.dataclass(frozen=True)
class HostType:
    name: str
    speed: float        # relative CPU capacity (i5 = 1.0)
    cores: int
    ram_gb: float
    disk_gb: float
    bw_kbps: float
    power_min_w: float
    power_max_w: float
    cost: float         # C$ per interval (Table 4: workload cost 3-5)
    weight: int         # mix proportion (Table 3 'virtual nodes': 12/6/2)


# Table 3 physical machines; speeds scaled by core count x clock.
HOST_TYPES = (
    HostType("core2duo", speed=2 * 2.4 / (4 * 2.9), cores=2, ram_gb=6,
             disk_gb=320, bw_kbps=1.0, power_min_w=108, power_max_w=273,
             cost=3.0, weight=12),
    HostType("i5", speed=1.0, cores=4, ram_gb=4, disk_gb=160,
             bw_kbps=1.5, power_min_w=120, power_max_w=250, cost=4.0,
             weight=6),
    HostType("xeon", speed=4 * 2.2 / (4 * 2.9), cores=4, ram_gb=2,
             disk_gb=160, bw_kbps=2.0, power_min_w=130, power_max_w=240,
             cost=5.0, weight=2),
)


@dataclasses.dataclass
class SimConfig:
    n_hosts: int = 400               # Table 4: number of VMs
    n_intervals: int = 288           # 24 h of 300 s intervals (§5.1)
    arrival_rate: float = 1.2        # Poisson lambda, jobs/interval (§4.2)
    min_tasks: int = 2               # jobs have 2-10 tasks (§4.2)
    max_tasks: int = 10
    deadline_fraction: float = 0.5   # 50% deadline driven (§4.2)
    work_mean: float = 10000.0       # cloud workload size 10000 +- 3000 (T4)
    work_std: float = 3000.0
    work_pareto_tail: float = 2.2    # heavy-tail mix so times are Pareto-ish
    heavy_fraction: float = 0.15     # fraction of tasks drawn from the tail
    # flash-crowd bursts (scenario registry): while burst_period > 0 and
    # t mod burst_period < burst_width, arrivals are scaled by
    # burst_multiplier on top of the diurnal curve
    burst_period: int = 0
    burst_width: int = 0
    burst_multiplier: float = 1.0
    # Effective MI/s per unit host speed. Table 4 lists 2000 MIPS, which with
    # 10000-MI tasks gives sub-second tasks that could never straggle across
    # 300 s PlanetLab intervals; we rescale so the mean task spans ~4
    # intervals, as in the trace dataset (deviation noted in DESIGN.md).
    # A tuple means a heterogeneous fleet: values are tiled across hosts
    # (host h gets host_ips[h mod len]).
    host_ips: float | tuple = 8.33
    restart_overhead_s: float = 30.0  # R_i per restart (Eq. 8)
    deadline_slack: tuple = (1.6, 3.0)  # x expected time
    # faults (§4.3): Weibull(k=1.5, lambda=2) inter-failure, ephemeral
    fault_weibull_k: float = 1.5
    fault_weibull_lambda: float = 2.0
    fault_host_rate: float = 0.010   # per host per interval scale
    fault_task_rate: float = 0.008   # cloudlet faults
    fault_vm_creation_rate: float = 0.004
    max_downtime: int = 4            # ephemeral host faults (<= 4 intervals)
    # reserved utilization experiments block a fraction of every resource
    reserved_utilization: float = 0.0
    # straggler threshold multiple (paper k = 1.5)
    k: float = 1.5
    seed: int = 0
    total_workloads: int | None = None  # optional cap (Table 4: 5000)

    @property
    def interval_seconds(self) -> float:
        return INTERVAL_SECONDS

    @functools.cached_property
    def host_ips_mean(self) -> float:
        """Fleet-average MI/s per unit speed (scalar even when host_ips
        describes a heterogeneous fleet; averages the actual tiled
        fleet, which differs from mean(host_ips) when n_hosts is not a
        multiple of the tuple length). Cached: it sits in per-task hot
        loops, and configs are treated as immutable once a Simulation is
        built."""
        return float(self.host_ips_array().mean())

    def host_ips_array(self) -> np.ndarray:
        """(n_hosts,) per-host MI/s per unit speed."""
        return np.resize(np.asarray(self.host_ips, float).ravel(),
                         self.n_hosts)


def small(**kw) -> SimConfig:
    """Reduced config for tests/CI."""
    base = dict(n_hosts=20, n_intervals=60, arrival_rate=1.2, seed=0)
    base.update(kw)
    return SimConfig(**base)
