"""Workload model (paper §4.2): PlanetLab-like traces + Poisson job arrivals.

The real PlanetLab CoMon traces are unavailable offline; we synthesize
per-task utilization series matching the dataset's published shape: 300 s
intervals, diurnal CPU pattern plus bursty noise, heavy-tailed task service
demand (so response times are Pareto-like, the paper's §3.1 premise).
Jobs have 2-10 tasks, 50% deadline-driven, Poisson(1.2) arrivals/interval.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.sim.config import SimConfig


@dataclasses.dataclass
class JobBatch:
    """Tasks arriving this interval (struct-of-arrays)."""

    job_ids: np.ndarray      # (t,)
    req: np.ndarray          # (t, 4) resource fractions
    work: np.ndarray         # (t,) service demand (MI)
    deadline_rel: np.ndarray  # (t,) seconds from submission
    is_deadline: np.ndarray  # (t,) bool — deadline-driven job?
    sla_weight: np.ndarray   # (t,) weight w_i of each task's SLA (Eq. 13)


class WorkloadGenerator:
    def __init__(self, cfg: SimConfig, rng: np.random.Generator):
        self.cfg = cfg
        self.rng = rng
        self._next_job = 0
        self._emitted = 0
        # diurnal load curve (PlanetLab CPU has day/night structure)
        t = np.arange(cfg.n_intervals)
        day = cfg.n_intervals / 2.0
        self.diurnal = 0.75 + 0.25 * np.sin(2 * np.pi * t / max(day, 1.0))

    def burst_factor(self, t: int) -> float:
        """Flash-crowd multiplier: burst_multiplier inside burst windows."""
        cfg = self.cfg
        if cfg.burst_period > 0 and (t % cfg.burst_period) < cfg.burst_width:
            return cfg.burst_multiplier
        return 1.0

    def sample_interval(self, t: int) -> JobBatch:
        cfg, rng = self.cfg, self.rng
        lam = (cfg.arrival_rate * self.diurnal[min(t, cfg.n_intervals - 1)]
               * self.burst_factor(t))
        n_jobs = rng.poisson(lam)
        ids, reqs, works, dls, isdl, w = [], [], [], [], [], []
        for _ in range(n_jobs):
            if (cfg.total_workloads is not None
                    and self._emitted >= cfg.total_workloads):
                break
            q = rng.integers(cfg.min_tasks, cfg.max_tasks + 1)
            jid = self._next_job
            self._next_job += 1
            self._emitted += q
            deadline_job = rng.random() < cfg.deadline_fraction
            # requirements: correlated within a job, bursty across tasks
            base = rng.uniform(0.05, 0.35, size=4)
            req = np.clip(base[None] * rng.lognormal(0, 0.4, (q, 4)),
                          0.02, 0.9)
            # service demand: normal body + Pareto tail mix (heavy tail)
            body = rng.normal(cfg.work_mean, cfg.work_std, q)
            tail = cfg.work_mean * (
                rng.pareto(cfg.work_pareto_tail, q) + 1.0)
            heavy = rng.random(q) < cfg.heavy_fraction
            work = np.clip(np.where(heavy, tail, body),
                           cfg.work_mean * 0.1, cfg.work_mean * 20)
            # seconds at fleet-average effective speed (~0.6 of nominal:
            # Table-3 mix is dominated by the slow core2duo class)
            expected = work / (cfg.host_ips_mean * 0.6)
            slack = rng.uniform(*cfg.deadline_slack, q)
            ids.append(np.full(q, jid))
            reqs.append(req)
            works.append(work)
            dls.append(expected * slack)
            isdl.append(np.full(q, deadline_job))
            w.append(rng.uniform(0.5, 1.0, q))
        if not ids:
            z = np.zeros(0)
            return JobBatch(z.astype(np.int64), np.zeros((0, 4)), z, z,
                            z.astype(bool), z)
        return JobBatch(
            job_ids=np.concatenate(ids).astype(np.int64),
            req=np.concatenate(reqs),
            work=np.concatenate(works),
            deadline_rel=np.concatenate(dls),
            is_deadline=np.concatenate(isdl),
            sla_weight=np.concatenate(w),
        )
