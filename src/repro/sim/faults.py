"""Fault Injection Module (paper §4.3): Weibull-distributed fault events.

Mirrors the paper's FaultInjector/FaultEvent/FaultHandlerDatacenter: three
fault classes — host faults (ephemeral downtime <= 4 intervals; all resident
tasks restart), cloudlet faults (task must re-run), VM-creation faults
(placement fails, task re-queued). Inter-arrival times follow
Weibull(k = 1.5, lambda = 2) scaled by per-class rates (Eq. 15, refs [44],
[45]).
"""
from __future__ import annotations

import dataclasses
import enum

import numpy as np

from repro.sim.config import SimConfig


class FaultKind(enum.Enum):
    HOST = "host_failure"
    CLOUDLET = "cloudlet_failure"
    VM_CREATION = "vm_creation_failure"


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    kind: FaultKind
    host: int           # host affected (HOST / VM_CREATION)
    downtime: int       # intervals (HOST only)


class FaultInjector:
    def __init__(self, cfg: SimConfig, rng: np.random.Generator):
        self.cfg = cfg
        self.rng = rng

    def _weibull_events(self, n_entities: int, rate: float) -> np.ndarray:
        """Entities whose Weibull clock fires this interval.

        We sample a Weibull(k, lambda) horizon per entity and fire when it is
        below the per-interval rate threshold — a discretized renewal process
        equivalent in rate to the paper's event-driven injector.
        """
        k = self.cfg.fault_weibull_k
        lam = self.cfg.fault_weibull_lambda
        draws = lam * self.rng.weibull(k, size=n_entities)
        # P(fire) calibrated so mean fire prob ~= rate
        thresh = lam * rate * 1.8  # E[Weibull(1.5,2)] ~= 1.8
        return draws < thresh

    def interval_events(self) -> list[FaultEvent]:
        cfg = self.cfg
        events: list[FaultEvent] = []
        host_fail = self._weibull_events(cfg.n_hosts, cfg.fault_host_rate)
        for h in np.nonzero(host_fail)[0]:
            dt = int(self.rng.integers(1, cfg.max_downtime + 1))
            events.append(FaultEvent(FaultKind.HOST, int(h), dt))
        vm_fail = self._weibull_events(cfg.n_hosts,
                                       cfg.fault_vm_creation_rate)
        for h in np.nonzero(vm_fail)[0]:
            events.append(FaultEvent(FaultKind.VM_CREATION, int(h), 0))
        return events

    def cloudlet_faults(self, n_active: int) -> np.ndarray:
        """Boolean mask over active tasks that suffer a cloudlet fault."""
        if n_active == 0:
            return np.zeros(0, bool)
        return self._weibull_events(n_active, self.cfg.fault_task_rate)
