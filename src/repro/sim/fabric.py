"""Remote elastic sweep fabric: cross-machine unit scheduling.

The process-pool sweep (``repro.sim.sweep``) tops out at one host's
cores — the committed ``BENCH_sweep.json`` shows parallelism *losing*
on a 1-cpu container.  This module serves the exact same
``(spec, unit, payload)`` tuples the pool consumes to **node agents on
other machines**:

  * :class:`FabricCoordinator` owns the grid: it pretrains once
    (``sweep._build_payloads``), partitions cells into the same
    (technique, scenario) cache-affinity units
    (``sweep._schedule_units``), and hands units to whichever node asks
    — pull-based scheduling, so load balance across heterogeneous
    machines is automatic;
  * :class:`FabricWorker` is the per-machine agent: it connects, says
    ``hello``, pulls units, runs each cell through the very same
    ``sweep._run_unit`` the pool workers use (optionally over a local
    process pool when ``lanes > 1``), and streams each finished unit's
    results straight back — a partial grid is usable at any moment
    (:meth:`FabricCoordinator.partial_result`);
  * membership is **elastic**: nodes join (``hello``) and leave
    (``bye``) mid-grid; every message refreshes a node's lease, and a
    node that disconnects or goes silent past ``lease_s`` gets its
    in-flight units requeued — exactly as the broken-pool path reclaims
    lost units today;
  * when the queue drains, an idle node **steals** work: the
    coordinator hands it a speculative copy of the longest-outstanding
    unit still running elsewhere (cells are pure functions of the spec,
    so duplicate execution is value-neutral; first result wins and the
    duplicate is dropped) — the fabric's own straggler mitigation;
  * opt-in **cache shipping**: with ``ship_cache=True`` and
    ``REPRO_JAX_CACHE_DIR`` set on the coordinator, joining nodes
    receive the shared XLA disk cache's files with the grid and
    warm-start compilation instead of paying cold XLA compiles.

Transport is a **length-prefixed binary frame** protocol over stdlib
TCP: an 8-byte big-endian length followed by a pickle payload.  This
follows ``repro.service.protocol``'s framing *discipline* (stdlib-only
module-level encode/decode, one request -> one response per frame, a
documented op vocabulary) but not its JSON-lines encoding — fabric
payloads (pickled policies, ``CellResult`` lists, cache files) are
binary, and base64-in-JSON would double the bytes on the wire.

Determinism: every cell is a pure function of the spec wherever it
runs, results are assembled in ``spec.cells()`` order, so a fabric grid
is **bitwise-equal to serial** on ``deterministic_summary`` — the
Tier-0 guarantee, enforced by tests and the bench.

Security: frames are pickle, so the port must never accept bytes from
an untrusted peer unauthenticated.  Set ``REPRO_FABRIC_KEY`` (same
value on coordinator and every node) and each frame carries an
HMAC-SHA256 tag over the payload, verified in constant time **before**
``pickle.loads`` — a frame with a missing or invalid MAC is rejected
without ever touching the unpickler.  Without a key the port falls back
to unauthenticated frames: keep the default loopback bind or a trusted
network in that mode.

CLI::

    python -m repro.sim.fabric coordinator --spec grid.json --bind :0
    python -m repro.sim.fabric worker --connect HOST:PORT --lanes 4
"""
from __future__ import annotations

import argparse
import concurrent.futures as cf
import dataclasses
import hmac
import json
import os
import pickle
import random
import socket
import socketserver
import struct
import tempfile
import threading
import time
import uuid
from collections import deque

from repro.sim import sweep as _sweep
from repro.sim.sweep import SweepResult, SweepSpec

# ------------------------------ wire frames --------------------------------

#: 8-byte big-endian unsigned frame length, then that many pickle bytes
#: (with ``REPRO_FABRIC_KEY`` set: a 32-byte HMAC-SHA256 tag, then the
#: pickle bytes — the tag is length-counted).
_HDR = struct.Struct(">Q")
#: refuse absurd frames before allocating (corrupt header / wrong peer)
MAX_FRAME = 1 << 31
#: HMAC-SHA256 tag length prepended to authenticated frames
MAC_LEN = 32


class ProtocolError(RuntimeError):
    pass


def fabric_key(key: bytes | str | None = None) -> bytes | None:
    """The frame-authentication key: the explicit argument if given,
    else ``REPRO_FABRIC_KEY`` from the environment, else ``None``
    (unauthenticated frames — loopback/trusted networks only)."""
    if key is None:
        key = os.environ.get("REPRO_FABRIC_KEY")
    if not key:
        return None
    return key.encode() if isinstance(key, str) else bytes(key)


def send_frame(f, obj: dict, key: bytes | str | None = None) -> None:
    """Write one length-prefixed pickle frame to a binary file-like,
    HMAC-tagged when a key is configured."""
    data = pickle.dumps(obj, protocol=pickle.HIGHEST_PROTOCOL)
    k = fabric_key(key)
    if k is not None:
        data = hmac.new(k, data, "sha256").digest() + data
    f.write(_HDR.pack(len(data)))
    f.write(data)
    f.flush()


def _read_exact(f, n: int) -> bytes | None:
    buf = b""
    while len(buf) < n:
        chunk = f.read(n - len(buf))
        if not chunk:
            return None
        buf += chunk
    return buf


def recv_frame(f, key: bytes | str | None = None) -> dict | None:
    """Read one frame; ``None`` on clean EOF (peer closed).

    With a key configured the MAC is verified constant-time **before**
    ``pickle.loads`` — a missing, short, or invalid tag raises
    :class:`ProtocolError` and the untrusted bytes never reach the
    unpickler.
    """
    hdr = _read_exact(f, _HDR.size)
    if hdr is None:
        return None
    (n,) = _HDR.unpack(hdr)
    if n > MAX_FRAME:
        raise ProtocolError(f"frame length {n} exceeds MAX_FRAME")
    data = _read_exact(f, n)
    if data is None:
        raise ProtocolError("connection dropped mid-frame")
    k = fabric_key(key)
    if k is not None:
        if len(data) < MAC_LEN:
            raise ProtocolError("frame too short to carry a MAC")
        tag, data = data[:MAC_LEN], data[MAC_LEN:]
        if not hmac.compare_digest(
                tag, hmac.new(k, data, "sha256").digest()):
            raise ProtocolError("frame MAC missing or invalid")
    try:
        obj = pickle.loads(data)
    except Exception as e:   # corrupt/garbled frame, not a crash
        raise ProtocolError(
            f"undecodable frame: {type(e).__name__}: {e}") from e
    if not isinstance(obj, dict) or "op" not in obj:
        raise ProtocolError("frame must be a dict with an 'op'")
    return obj


# ------------------------------ cache shipping -----------------------------

#: don't ship caches past this (a node warm-starting from a 100-cell
#: grid's cache needs a few MB of executables, not the whole archive)
MAX_CACHE_SHIP_BYTES = 256 * 1024 * 1024


def collect_cache_files(path: str | None = None) -> dict[str, bytes]:
    """Read the shared XLA disk cache into {relpath: bytes} for shipping
    (empty when ``REPRO_JAX_CACHE_DIR`` is unset/missing)."""
    path = path or os.environ.get("REPRO_JAX_CACHE_DIR")
    if not path or not os.path.isdir(path):
        return {}
    files, total = {}, 0
    for root, _, names in os.walk(path):
        for name in sorted(names):
            full = os.path.join(root, name)
            rel = os.path.relpath(full, path)
            try:
                data = open(full, "rb").read()
            except OSError:
                continue
            total += len(data)
            if total > MAX_CACHE_SHIP_BYTES:
                return files
            files[rel] = data
    return files


def install_cache_files(files: dict[str, bytes],
                        path: str | None = None) -> str | None:
    """Materialize shipped cache files into this node's cache dir (the
    local ``REPRO_JAX_CACHE_DIR`` if set, else a fresh temp dir which
    becomes it) and point jax at it.  Existing files are never
    overwritten — local compiles win races."""
    if not files:
        return None
    path = path or os.environ.get("REPRO_JAX_CACHE_DIR")
    if not path:
        path = tempfile.mkdtemp(prefix="repro-fabric-cache-")
        os.environ["REPRO_JAX_CACHE_DIR"] = path
    for rel, data in files.items():
        full = os.path.join(path, rel)
        if os.path.exists(full):
            continue
        os.makedirs(os.path.dirname(full), exist_ok=True)
        tmp = full + ".tmp"
        with open(tmp, "wb") as f:
            f.write(data)
        os.replace(tmp, full)    # atomic: readers never see partials
    _sweep.enable_compile_cache()
    return path


# ------------------------------ coordinator --------------------------------

class _NodeInfo:
    __slots__ = ("name", "lanes", "last_seen", "inflight")

    def __init__(self, name: str, lanes: int, now: float):
        self.name = name
        self.lanes = max(1, int(lanes))
        self.last_seen = now
        self.inflight: set = set()      # unit ids leased to this node


class _Handler(socketserver.BaseRequestHandler):
    def handle(self) -> None:
        coord: FabricCoordinator = self.server.coordinator  # type: ignore
        f = self.request.makefile("rwb")
        node = None
        try:
            while True:
                try:
                    msg = recv_frame(f)
                except ProtocolError as e:
                    send_frame(f, {"op": "error", "detail": str(e)})
                    return
                if msg is None:
                    return
                node = msg.get("node", node)
                resp = coord._dispatch(msg)
                send_frame(f, resp)
                if msg.get("op") == "bye":
                    node = None       # graceful leave already reclaimed
                    return
        except (BrokenPipeError, ConnectionResetError, OSError):
            return
        finally:
            if node is not None:
                # abrupt disconnect: reclaim everything the node held
                coord._disconnect(node)
            try:
                f.close()
            except OSError:
                pass


class _Server(socketserver.ThreadingTCPServer):
    allow_reuse_address = True
    daemon_threads = True


class FabricCoordinator:
    """Serves sweep scheduling units to remote node agents.

    One coordinator serves one grid at a time but stays up across
    grids (``run_grid`` bumps an epoch; idle workers poll and pick the
    next grid up automatically — the fabric twin of the persistent
    process pool).

    Args:
        host/port: TCP bind (``port=0`` picks a free one, read
            ``.port`` back).  Loopback by default — see the module
            docstring's security note before binding wider.
        lease_s: a node silent for longer than this has its in-flight
            units reclaimed and requeued.  Must comfortably exceed the
            slowest unit's runtime (the worker heartbeats at
            ``lease_s / 3`` while computing).
        lanes_hint: how many total lanes to partition the grid for when
            scheduling units (elastic membership means the true count
            is unknowable up front; more units than lanes just means
            finer-grained balancing).
        ship_cache: include the coordinator's ``REPRO_JAX_CACHE_DIR``
            files with the grid so joining nodes warm-start XLA
            compilation (opt-in: shipping megabytes to nodes that
            share a filesystem is waste).
        max_speculate: speculative copies of an outstanding unit handed
            to idle nodes when the queue is empty (work stealing);
            0 disables stealing.
    """

    def __init__(self, host: str = "127.0.0.1", port: int = 0,
                 lease_s: float = 60.0, lanes_hint: int = 8,
                 ship_cache: bool = False, max_speculate: int = 1,
                 clock=time.monotonic):
        self.lease_s = float(lease_s)
        self.lanes_hint = int(lanes_hint)
        self.ship_cache = bool(ship_cache)
        self.max_speculate = int(max_speculate)
        self._clock = clock
        self._lock = threading.RLock()
        self._nodes: dict[str, _NodeInfo] = {}
        self._epoch = 0
        self._spec: SweepSpec | None = None
        self._payload_blob: bytes = pickle.dumps({})
        self._cache_files: dict[str, bytes] = {}
        self._units: dict[int, tuple] = {}
        self._queue: deque[int] = deque()
        #: uid -> {node: assign time} (may hold >1 assignee: stealing)
        self._assignees: dict[int, dict[str, float]] = {}
        self._done_units: set[int] = set()
        self._done_cells: dict = {}
        self._expected: list = []
        self._grid_nodes: set[str] = set()
        self._failures: dict[int, int] = {}
        self._grid_error: str | None = None
        self.max_unit_failures = 3
        self._grid_done = threading.Event()
        self._grid_done.set()           # no grid yet == nothing pending
        self._server = _Server((host, port), _Handler)
        self._server.coordinator = self           # type: ignore
        self.host, self.port = self._server.server_address[:2]
        self._thread = threading.Thread(
            target=self._server.serve_forever,
            kwargs={"poll_interval": 0.05}, daemon=True)

    # ------------------------------ lifecycle ---------------------------

    def start(self) -> "FabricCoordinator":
        self._thread.start()
        return self

    def stop(self) -> None:
        if self._thread.is_alive():
            self._server.shutdown()
        self._server.server_close()

    def __enter__(self) -> "FabricCoordinator":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # ------------------------------ grid API ----------------------------

    def run_grid(self, spec: SweepSpec,
                 timeout: float | None = None) -> SweepResult:
        """Serve ``spec``'s grid to the connected (and yet-to-join)
        nodes; blocks until every cell has landed.  Bitwise-equal to
        serial ``run()`` on ``deterministic_summary``.  ``timeout``
        bounds the wait (``TimeoutError``; ``partial_result`` still
        holds whatever landed)."""
        t0 = time.perf_counter()
        pretrain_s = self._load_grid(spec)
        # the reap loop must run even when every node went silent —
        # nobody else would requeue their leases
        deadline = (time.monotonic() + timeout) if timeout else None
        while not self._grid_done.wait(0.2):
            if deadline is not None and time.monotonic() > deadline:
                raise TimeoutError(
                    f"grid incomplete after {timeout}s "
                    f"({len(self._done_cells)}/{len(self._expected)} "
                    f"cells; partial_result() holds what landed)")
            with self._lock:
                self._reap(self._clock())
        with self._lock:
            if self._grid_error is not None:
                raise RuntimeError(self._grid_error)
            cells = [self._done_cells[c] for c in self._expected]
            n_nodes = max(1, len(self._grid_nodes))
        res = SweepResult(spec=spec, cells=cells,
                          wall_s=time.perf_counter() - t0,
                          n_workers=n_nodes, pretrain_s=pretrain_s)
        res.write_csv()
        return res

    def _load_grid(self, spec: SweepSpec) -> float:
        """Pretrain + partition ``spec`` and arm it as the current
        epoch's grid; returns the parent-side pretrain seconds."""
        _sweep.enable_compile_cache()
        tp = time.perf_counter()
        payloads = _sweep._build_payloads(spec)   # pretrain once, here
        pretrain_s = time.perf_counter() - tp
        with self._lock:
            self._epoch += 1
            self._spec = spec
            self._payload_blob = pickle.dumps(payloads,
                                              pickle.HIGHEST_PROTOCOL)
            self._cache_files = (collect_cache_files()
                                 if self.ship_cache else {})
            units = _sweep._schedule_units(spec, self.lanes_hint)
            self._units = dict(enumerate(units))
            self._queue = deque(range(len(units)))
            self._assignees = {}
            self._done_units = set()
            self._done_cells = {}
            self._expected = spec.cells()
            self._grid_nodes = set()
            self._failures = {}
            self._grid_error = None
            self._grid_done.clear()
        return pretrain_s

    def partial_result(self) -> SweepResult:
        """The grid as far as it has landed (``spec.cells()`` order,
        missing cells skipped) — incremental result streaming means a
        partial grid is usable before (or without) completion."""
        with self._lock:
            spec = self._spec
            if spec is None:
                raise RuntimeError("no grid loaded")
            cells = [self._done_cells[c] for c in self._expected
                     if c in self._done_cells]
            n_nodes = max(1, len(self._grid_nodes))
        return SweepResult(spec=spec, cells=cells, wall_s=0.0,
                           n_workers=n_nodes)

    def stats(self) -> dict:
        with self._lock:
            return {
                "epoch": self._epoch,
                "nodes": {n.name: {"lanes": n.lanes,
                                   "inflight": len(n.inflight)}
                          for n in self._nodes.values()},
                "queued_units": len(self._queue),
                "outstanding_units": len(self._assignees),
                "done_units": len(self._done_units),
                "done_cells": len(self._done_cells),
                "total_cells": len(self._expected),
            }

    # ------------------------------ scheduling --------------------------

    def _touch(self, node: str, lanes: int | None = None) -> _NodeInfo:
        """Register/refresh a node's lease (any message counts).  An
        expired-and-reaped node that speaks again simply re-registers —
        membership is elastic in both directions."""
        now = self._clock()
        info = self._nodes.get(node)
        if info is None:
            info = self._nodes[node] = _NodeInfo(node, lanes or 1, now)
        info.last_seen = now
        if lanes is not None:
            info.lanes = max(1, int(lanes))
        return info

    def _reap(self, now: float) -> None:
        """Requeue in-flight units of nodes silent past their lease."""
        for name in [n for n, i in self._nodes.items()
                     if now - i.last_seen > self.lease_s]:
            self._drop_node(name)

    def _drop_node(self, name: str) -> None:
        info = self._nodes.pop(name, None)
        if info is None:
            return
        for uid in info.inflight:
            holders = self._assignees.get(uid)
            if holders is None:
                continue
            holders.pop(name, None)
            if not holders and uid not in self._done_units:
                del self._assignees[uid]
                # reclaimed work goes to the queue front: it has been
                # waiting longest and may gate grid completion
                self._queue.appendleft(uid)

    def _disconnect(self, node: str) -> None:
        with self._lock:
            self._drop_node(node)

    def _assign(self, uid: int, info: _NodeInfo) -> dict:
        self._assignees.setdefault(uid, {})[info.name] = self._clock()
        info.inflight.add(uid)
        self._grid_nodes.add(info.name)
        return {"op": "unit", "epoch": self._epoch, "uid": uid,
                "cells": self._units[uid]}

    def _next_for(self, info: _NodeInfo) -> dict:
        if self._queue:
            return self._assign(self._queue.popleft(), info)
        # queue drained: steal — speculatively duplicate the unit that
        # has been outstanding longest on some other node (pure cells
        # make duplicates value-neutral; first result wins)
        if self.max_speculate:
            candidates = [
                (min(holders.values()), uid)
                for uid, holders in self._assignees.items()
                if uid not in self._done_units
                and info.name not in holders
                and len(holders) <= self.max_speculate]
            if candidates:
                return self._assign(min(candidates)[1], info)
        if self._grid_done.is_set():
            return {"op": "drain", "epoch": self._epoch}
        return {"op": "wait", "for_s": 0.2}

    def _record(self, node: str, uid: int, results: list) -> None:
        info = self._nodes.get(node)
        if info is not None:
            info.inflight.discard(uid)
        holders = self._assignees.pop(uid, None) or {}
        for other in holders:
            other_info = self._nodes.get(other)
            if other_info is not None:
                other_info.inflight.discard(uid)
        if uid in self._done_units:
            return                       # speculative duplicate: dropped
        self._done_units.add(uid)
        for r in results:
            self._done_cells[(r.scenario, r.technique, r.seed)] = r
        if len(self._done_cells) == len(self._expected):
            self._grid_done.set()

    def _record_failure(self, node: str, uid: int, detail: str) -> None:
        """A node ran a unit and the unit itself raised (as opposed to
        the node dying): requeue for a bounded number of attempts, then
        poison the grid — a deterministic cell error would otherwise
        bounce between nodes forever."""
        info = self._nodes.get(node)
        if info is not None:
            info.inflight.discard(uid)
        holders = self._assignees.get(uid)
        if holders is not None:
            holders.pop(node, None)
        if uid in self._done_units:
            return
        self._failures[uid] = self._failures.get(uid, 0) + 1
        if self._failures[uid] >= self.max_unit_failures:
            self._grid_error = (
                f"unit {uid} ({self._units.get(uid)}) failed "
                f"{self._failures[uid]}x across nodes; last: {detail}")
            self._grid_done.set()
            return
        if not holders and uid not in self._queue:
            self._assignees.pop(uid, None)
            self._queue.appendleft(uid)

    # ------------------------------ dispatch ----------------------------

    def _dispatch(self, msg: dict) -> dict:
        op = msg.get("op")
        node = str(msg.get("node", ""))
        with self._lock:
            self._reap(self._clock())
            if op == "hello":
                self._touch(node, msg.get("lanes"))
                return {"op": "welcome", "epoch": self._epoch,
                        "lease_s": self.lease_s}
            info = self._touch(node)
            if op == "heartbeat":
                return {"op": "ack"}
            if op == "bye":
                self._drop_node(node)
                return {"op": "ack"}
            if op == "result":
                self._record(node, int(msg["uid"]),
                             list(msg["results"]))
                return {"op": "ack"}
            if op == "failed":
                self._record_failure(node, int(msg["uid"]),
                                     str(msg.get("detail", "")))
                return {"op": "ack"}
            if op == "request":
                if self._spec is None:
                    return {"op": "wait", "for_s": 0.2}
                if int(msg.get("epoch", -1)) != self._epoch:
                    # new grid: ship spec + payloads (+ cache) once,
                    # then the node re-requests with the fresh epoch
                    return {"op": "grid", "epoch": self._epoch,
                            "spec": self._spec,
                            "payloads": self._payload_blob,
                            "cache_files": self._cache_files}
                return self._next_for(info)
        return {"op": "error", "detail": f"unknown op {op!r}"}


# ------------------------------ node agent ---------------------------------

class FabricWorker:
    """Per-machine node agent: pulls units, runs them, streams results.

    ``lanes=1`` runs cells in-process (the agent process is the lane);
    ``lanes>1`` drives a local spawned process pool, so one agent per
    machine saturates its cores.  The agent heartbeats at
    ``lease_s / 3`` while computing so long units never look like a
    dead node.

    ``run()`` returns when the coordinator goes away (after
    ``reconnect_tries`` failed reconnects) or — with
    ``exit_on_drain=True`` — when the current grid drains.  Long-lived
    agents (``exit_on_drain=False``) idle-poll and pick up the next
    grid, surviving coordinator restarts in between.
    """

    def __init__(self, host: str, port: int, node: str | None = None,
                 lanes: int = 1, exit_on_drain: bool = True,
                 reconnect_tries: int = 20, reconnect_delay_s: float = 0.5,
                 backoff_cap_s: float = 5.0, request_tries: int = 4,
                 io_timeout_s: float = 30.0):
        self.host, self.port = host, int(port)
        self.node = node or f"{socket.gethostname()}-{uuid.uuid4().hex[:8]}"
        self.lanes = max(1, int(lanes))
        self.exit_on_drain = exit_on_drain
        self.reconnect_tries = int(reconnect_tries)
        self.reconnect_delay_s = float(reconnect_delay_s)
        self.backoff_cap_s = float(backoff_cap_s)
        self.request_tries = max(1, int(request_tries))
        self.io_timeout_s = float(io_timeout_s)
        #: seeded per node name: the jittered backoff sequence replays
        #: under the chaos harness
        self._rng = random.Random(self.node)
        self._file = None
        self._io_lock = threading.Lock()
        self._stop = threading.Event()
        self._lease_s = 60.0
        self._epoch = -1
        self._spec: SweepSpec | None = None
        self._payloads: dict = {}
        self._pool: cf.ProcessPoolExecutor | None = None
        self.units_done = 0
        self.cells_done = 0

    # ------------------------------ transport ---------------------------

    def _backoff(self, attempt: int) -> float:
        """Capped exponential backoff with jitter: retry storms from a
        fleet of reconnecting nodes must not synchronize on a healing
        coordinator."""
        base = min(self.reconnect_delay_s * (2.0 ** attempt),
                   self.backoff_cap_s)
        return base * (0.5 + 0.5 * self._rng.random())

    def _drop_conn(self) -> None:
        with self._io_lock:
            if self._file is not None:
                try:
                    self._file.close()
                except OSError:
                    pass
                self._file = None

    def _connect(self) -> None:
        last = None
        for attempt in range(max(1, self.reconnect_tries)):
            try:
                sock = socket.create_connection((self.host, self.port),
                                                timeout=self.io_timeout_s)
                self._file = sock.makefile("rwb")
                resp = self._send_recv({"op": "hello", "node": self.node,
                                        "lanes": self.lanes})
                self._lease_s = float(resp.get("lease_s", 60.0))
                return
            except OSError as e:
                last = e
                self._file = None
                if self._stop.wait(self._backoff(attempt)):
                    break
        raise ConnectionError(
            f"coordinator {self.host}:{self.port} unreachable") from last

    def _send_recv(self, msg: dict) -> dict:
        # one lock around the send+recv pair: the heartbeat thread and
        # the main loop share this socket and frames must not interleave
        with self._io_lock:
            if self._file is None:
                raise ConnectionError("not connected")
            send_frame(self._file, msg)
            resp = recv_frame(self._file)
        if resp is None:
            raise ConnectionError("coordinator closed the connection")
        if resp.get("op") == "error":
            # the coordinator refused the frame (corrupt in flight, MAC
            # reject, ...) and is about to close: the stream past this
            # point is unusable, so treat it like a broken connection
            raise ProtocolError(
                f"coordinator error: {resp.get('detail', '')}")
        return resp

    def _request(self, msg: dict) -> dict:
        """One request with bounded reconnect-and-retry.

        Every fabric op is idempotent on the coordinator — duplicate
        ``result``s are dropped first-wins, re-``request``s just lease
        another unit, lost in-flight units come back via lease reclaim
        — so resending after a corrupt frame, an RST, or a lost
        response is always safe.
        """
        last: Exception | None = None
        for attempt in range(self.request_tries):
            if attempt:
                self._drop_conn()
                if self._stop.wait(self._backoff(attempt - 1)):
                    break
                self._connect()       # ConnectionError when gone for good
            try:
                return self._send_recv(msg)
            except (ConnectionError, ProtocolError, OSError) as e:
                last = e
        raise ConnectionError(
            f"request {msg.get('op')!r} failed after "
            f"{self.request_tries} attempts") from last

    def _heartbeat_loop(self) -> None:
        while not self._stop.wait(max(self._lease_s / 3.0, 0.05)):
            try:
                self._send_recv({"op": "heartbeat", "node": self.node})
            except (ConnectionError, ProtocolError, OSError):
                pass                     # main loop owns reconnection

    # ------------------------------ execution ---------------------------

    def _install_grid(self, resp: dict) -> None:
        self._epoch = int(resp["epoch"])
        self._spec = resp["spec"]
        self._payloads = pickle.loads(resp["payloads"])
        install_cache_files(resp.get("cache_files") or {})

    def _local_pool(self) -> cf.ProcessPoolExecutor:
        if self._pool is None:
            import multiprocessing
            ctx = multiprocessing.get_context("spawn")
            self._pool = cf.ProcessPoolExecutor(
                max_workers=self.lanes, mp_context=ctx,
                initializer=_sweep._worker_init,
                initargs=(ctx.Value("i", 0), False))
        return self._pool

    def _report(self, uid: int, results: list | None,
                err: str | None) -> bool:
        """Stream one unit's outcome back; False when the coordinator
        is unreachable (caller stops serving)."""
        try:
            if err is not None:
                self._request({"op": "failed", "node": self.node,
                               "uid": uid, "detail": err})
            else:
                self.units_done += 1
                self.cells_done += len(results)
                self._request({"op": "result", "node": self.node,
                               "uid": uid, "results": results})
            return True
        except ConnectionError:
            return False

    def _harvest(self, inflight: dict, block: bool) -> bool:
        """Collect finished local-pool futures, streaming each unit's
        results immediately; False on lost coordinator."""
        if block and inflight:
            cf.wait(list(inflight), timeout=0.5,
                    return_when=cf.FIRST_COMPLETED)
        for fut in [f for f in list(inflight) if f.done()]:
            uid, cells = inflight.pop(fut)
            try:
                results, err = fut.result(), None
            except cf.process.BrokenProcessPool:
                # a local lane died: respawn lazily and run the unit in
                # the agent itself — fabric-level reclaim never sees it
                if self._pool is not None:
                    self._pool.shutdown(wait=False)
                    self._pool = None
                try:
                    results, err = _sweep._run_unit(
                        self._spec, cells, self._payloads), None
                except Exception as e:
                    results, err = None, f"{type(e).__name__}: {e}"
            except Exception as e:       # the cell itself raised
                results, err = None, f"{type(e).__name__}: {e}"
            if not self._report(uid, results, err):
                return False
        return True

    def run(self) -> int:
        """Serve until drain/stop; returns the number of cells run.

        ``lanes`` units are kept in flight on the local pool at once
        (one, run inline, when ``lanes == 1``), and every finished
        unit's results stream back immediately — the coordinator's
        partial grid grows while the node keeps computing."""
        self._connect()
        hb = threading.Thread(target=self._heartbeat_loop, daemon=True)
        hb.start()
        inflight: dict = {}              # future -> (uid, cells)
        draining = False
        try:
            while not self._stop.is_set():
                if not self._harvest(inflight, block=False):
                    break
                if draining:
                    if not inflight:
                        break
                    if not self._harvest(inflight, block=True):
                        break
                    continue
                if len(inflight) >= self.lanes:
                    if not self._harvest(inflight, block=True):
                        break
                    continue
                try:
                    resp = self._request({"op": "request",
                                          "node": self.node,
                                          "epoch": self._epoch})
                except ConnectionError:
                    break                # coordinator is gone for good
                op = resp.get("op")
                if op == "grid":
                    self._install_grid(resp)
                elif op == "unit":
                    if self.lanes == 1:
                        try:
                            results, err = self._run_inline(
                                resp["cells"]), None
                        except Exception as e:
                            results = None
                            err = f"{type(e).__name__}: {e}"
                        if not self._report(resp["uid"], results, err):
                            break
                    else:
                        fut = self._local_pool().submit(
                            _sweep._run_unit_star,
                            (self._spec, resp["cells"], self._payloads))
                        inflight[fut] = (resp["uid"], resp["cells"])
                elif op == "wait":
                    if inflight:
                        self._harvest(inflight, block=True)
                    elif self._stop.wait(float(resp.get("for_s", 0.2))):
                        break
                elif op == "drain":
                    if self.exit_on_drain:
                        draining = True
                    elif self._stop.wait(0.2):
                        break
                else:
                    raise ProtocolError(f"unexpected response {resp!r}")
        finally:
            self._stop.set()
            self._say_bye()
            if self._pool is not None:
                self._pool.shutdown(wait=False)
                self._pool = None
        return self.cells_done

    def _run_inline(self, cells: tuple) -> list:
        return _sweep._run_unit(self._spec, cells, self._payloads)

    def _say_bye(self) -> None:
        try:
            if self._file is not None:
                self._send_recv({"op": "bye", "node": self.node})
                self._file.close()
        except (ConnectionError, ProtocolError, OSError):
            pass

    def stop(self) -> None:
        self._stop.set()


def worker_main(host: str, port: int, node: str | None = None,
                lanes: int = 1, exit_on_drain: bool = True) -> int:
    """Top-level node-agent entry point (picklable: benchmarks and tests
    spawn it via ``multiprocessing``)."""
    return FabricWorker(host, port, node=node, lanes=lanes,
                        exit_on_drain=exit_on_drain).run()


# ---------------------------------- CLI ------------------------------------

def _parse_bind(s: str) -> tuple[str, int]:
    host, _, port = s.rpartition(":")
    return host or "127.0.0.1", int(port)


def _spec_from_json(path: str) -> SweepSpec:
    with open(path) as f:
        fields = json.load(f)
    known = {f.name for f in dataclasses.fields(SweepSpec)}
    unknown = set(fields) - known
    if unknown:
        raise ValueError(f"unknown SweepSpec fields {sorted(unknown)}")
    for key in ("techniques", "seeds", "scenarios", "metrics"):
        if key in fields:
            fields[key] = tuple(fields[key])
    return SweepSpec(**fields)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.sim.fabric",
        description="Distributed sweep fabric: coordinator and node agent")
    sub = ap.add_subparsers(dest="cmd", required=True)
    c = sub.add_parser("coordinator",
                       help="serve a grid to remote node agents")
    c.add_argument("--spec", required=True,
                   help="SweepSpec fields as JSON")
    c.add_argument("--bind", default="127.0.0.1:0",
                   help="HOST:PORT (port 0 = pick free; set "
                        "REPRO_FABRIC_KEY on every machine to "
                        "HMAC-authenticate frames before binding "
                        "beyond loopback — frames are pickle)")
    c.add_argument("--lease", type=float, default=60.0)
    c.add_argument("--lanes-hint", type=int, default=8)
    c.add_argument("--ship-cache", action="store_true")
    w = sub.add_parser("worker", help="node agent: pull and run units")
    w.add_argument("--connect", required=True, help="HOST:PORT")
    w.add_argument("--lanes", type=int, default=os.cpu_count() or 1)
    w.add_argument("--node", default=None)
    w.add_argument("--stay", action="store_true",
                   help="idle after drain and serve later grids")
    args = ap.parse_args(argv)

    if args.cmd == "coordinator":
        spec = _spec_from_json(args.spec)
        host, port = _parse_bind(args.bind)
        coord = FabricCoordinator(host, port, lease_s=args.lease,
                                  lanes_hint=args.lanes_hint,
                                  ship_cache=args.ship_cache).start()
        print(f"fabric coordinator on {coord.host}:{coord.port} "
              f"({len(spec.cells())} cells); waiting for workers",
              flush=True)
        try:
            res = coord.run_grid(spec)
        finally:
            coord.stop()
        print(f"grid complete: {len(res.cells)} cells in "
              f"{res.wall_s:.1f}s over {res.n_workers} node(s)")
        return 0
    host, port = _parse_bind(args.connect)
    n = worker_main(host, port, node=args.node, lanes=args.lanes,
                    exit_on_drain=not args.stay)
    print(f"node agent done: {n} cells")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
