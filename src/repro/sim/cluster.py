"""Heterogeneous host cluster (paper Table 3) with utilization accounting.

Hosts are a struct-of-arrays; utilization is recomputed each interval from
the placed tasks' requirement vectors. Overload (>100% of any resource)
produces both a contention penalty on progress and a contention metric
(Eq. 9).
"""
from __future__ import annotations

import numpy as np

from repro.policy.telemetry import effective_speed as _effective_speed
from repro.sim.config import HOST_TYPES, SimConfig

RES = ("cpu", "ram", "disk", "bw")
N_RES = 4


class Cluster:
    def __init__(self, cfg: SimConfig, rng: np.random.Generator):
        self.cfg = cfg
        n = cfg.n_hosts
        mix = np.concatenate([
            np.full(ht.weight, i) for i, ht in enumerate(HOST_TYPES)])
        type_idx = mix[rng.integers(0, len(mix), size=n)]
        self.type_idx = type_idx
        self.type_names = np.array([HOST_TYPES[i].name for i in type_idx])
        self.speed = np.array([HOST_TYPES[i].speed for i in type_idx])
        # capacity vectors (cpu normalized to cores*speed; others absolute)
        self.cap = np.stack([
            np.array([HOST_TYPES[i].cores * HOST_TYPES[i].speed
                      for i in type_idx]),
            np.array([HOST_TYPES[i].ram_gb for i in type_idx]),
            np.array([HOST_TYPES[i].disk_gb for i in type_idx]),
            np.array([HOST_TYPES[i].bw_kbps for i in type_idx]),
        ], axis=1)  # (n, 4)
        self.power_min = np.array([HOST_TYPES[i].power_min_w
                                   for i in type_idx])
        self.power_max = np.array([HOST_TYPES[i].power_max_w
                                   for i in type_idx])
        self.cost = np.array([HOST_TYPES[i].cost for i in type_idx])
        # dynamic state
        self.util = np.zeros((n, N_RES))         # fraction of capacity
        self.n_tasks = np.zeros(n, np.int64)
        self.downtime = np.zeros(n, np.int64)    # intervals remaining down
        self.reserved = np.full((n, N_RES), cfg.reserved_utilization)

    @property
    def n(self) -> int:
        return self.cfg.n_hosts

    def online(self) -> np.ndarray:
        return self.downtime == 0

    def begin_interval(self) -> None:
        self.downtime = np.maximum(self.downtime - 1, 0)

    def fail_host(self, h: int, downtime: int) -> None:
        self.downtime[h] = min(downtime, self.cfg.max_downtime)

    def recompute_utilization(self, task_req: np.ndarray,
                              task_host: np.ndarray,
                              active: np.ndarray) -> None:
        """util[h] = reserved + sum of active task reqs on h (fraction)."""
        self.util = self.reserved.copy()
        self.n_tasks[:] = 0
        if active.any():
            hosts = task_host[active]
            reqs = task_req[active]
            np.add.at(self.util, hosts, reqs)
            np.add.at(self.n_tasks, hosts, 1)

    def effective_speed(self) -> np.ndarray:
        """Per-host progress rate (the paper's 'resource contention is
        the main reason for stragglers').  The formula lives in
        ``repro.policy.telemetry.effective_speed`` so policy-side host
        views compute the identical quantity."""
        return _effective_speed(self.util, self.speed, self.online())

    def overloaded(self) -> np.ndarray:
        """(n, N_RES) bool: any resource demanded above capacity."""
        return self.util > 1.0 + 1e-9

    def energy(self) -> float:
        """Eq. 7: sum_k U_k * (Emax - Emin) + Emin (per interval, in W)."""
        u = np.clip(self.util.mean(axis=1), 0.0, 1.0)
        return float(np.sum(u * (self.power_max - self.power_min)
                            + self.power_min))
