"""Scenario registry: named workload/fault regimes for the cloud simulator.

START's comparative claims (paper Figs. 6-10) are regime-sensitive: Wang et
al. show the best replication policy flips with the service-time tail, and
Aktas & Soljanin show it flips with load. The registry parameterizes
``SimConfig`` (and through it ``WorkloadGenerator``/``FaultInjector``/
``Cluster``) beyond the single PlanetLab-like default so sweeps can cover
those regimes explicitly:

  planetlab    the paper's default trace shape (diurnal + mild tail)
  flash-crowd  periodic arrival bursts (queueing spikes -> contention
               stragglers; stresses reactive speculation lag)
  heavy-tail   heavier Pareto service demand (stragglers from work skew,
               not placement; stresses prediction + cloning policies)
  hetero-fleet mixed per-host MI/s (slow-host stragglers; stresses
               placement-aware techniques vs progress-only ones)
  overload     high sustained load + reserved capacity (contention spiral;
               stresses mitigation that adds load, e.g. aggressive cloning)
  fault-storm  elevated host/cloudlet/VM-creation fault rates with longer
               downtimes (restart-dominated stragglers; stresses
               first-result-wins bookkeeping and restart overhead)

Each scenario is a set of absolute ``SimConfig`` overrides plus an
``arrival_scale`` multiplier applied to whatever base arrival rate the
caller picked (so scenarios compose with cluster-size scaling).
"""
from __future__ import annotations

import dataclasses

from repro.sim.config import SimConfig


@dataclasses.dataclass(frozen=True)
class Scenario:
    name: str
    description: str
    stresses: str
    overrides: tuple = ()        # ((field, value), ...) absolute overrides
    arrival_scale: float = 1.0   # multiplies the caller's base arrival_rate


REGISTRY: dict[str, Scenario] = {}


def _register(s: Scenario) -> Scenario:
    REGISTRY[s.name] = s
    return s


_register(Scenario(
    name="planetlab",
    description="Paper-default PlanetLab-like trace: diurnal arrivals, "
                "mild heavy-tail service demand, baseline fault rates.",
    stresses="the paper's reference regime (Figs. 6-10)",
))

_register(Scenario(
    name="flash-crowd",
    description="Periodic arrival bursts: every 24 intervals, 4 intervals "
                "of 6x arrivals on top of the diurnal curve.",
    stresses="queueing spikes and reactive-technique detection lag",
    overrides=(("burst_period", 24), ("burst_width", 4),
               ("burst_multiplier", 6.0)),
))

_register(Scenario(
    name="heavy-tail",
    description="Heavy-tail-dominated service demand: tail index 1.6 and "
                "35% of tasks drawn from the Pareto tail.",
    stresses="work-skew stragglers; prediction and cloning policies",
    overrides=(("work_pareto_tail", 1.6), ("heavy_fraction", 0.35)),
))

_register(Scenario(
    name="hetero-fleet",
    description="Heterogeneous fleet: per-host MI/s tiled from "
                "(0.5x, 1x, 2x) of the default, on top of the Table-3 "
                "speed mix.",
    stresses="slow-host stragglers; placement-aware vs progress-only "
             "techniques",
    overrides=(("host_ips", (4.17, 8.33, 16.66)),),
))

_register(Scenario(
    name="overload",
    description="Sustained high load: 2.5x arrivals with 40% of every "
                "resource reserved.",
    stresses="contention spirals; mitigation that adds load",
    overrides=(("reserved_utilization", 0.4),),
    arrival_scale=2.5,
))

_register(Scenario(
    name="fault-storm",
    description="Elevated fault regime: 8x host, 6x cloudlet and 5x "
                "VM-creation fault rates, downtimes up to 6 intervals.",
    stresses="restart-dominated stragglers; first-result-wins and "
             "restart-overhead accounting",
    overrides=(("fault_host_rate", 0.08), ("fault_task_rate", 0.05),
               ("fault_vm_creation_rate", 0.02), ("max_downtime", 6)),
))


def names() -> list[str]:
    return list(REGISTRY)


def get(name: str) -> Scenario:
    try:
        return REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown scenario {name!r}; known: {names()}")


def make_config(scenario: str, seed: int = 0, *, n_hosts: int = 32,
                n_intervals: int = 72, arrival_rate: float = 0.6,
                **extra) -> SimConfig:
    """Build a SimConfig for a named scenario.

    Base sizing (hosts/intervals/arrival rate) comes from the caller so the
    same scenario runs at test, benchmark, or paper (Table 4) scale;
    ``extra`` overrides win over scenario overrides (sweep-level knobs).
    """
    s = get(scenario)
    kw: dict = dict(n_hosts=n_hosts, n_intervals=n_intervals,
                    arrival_rate=arrival_rate * s.arrival_scale, seed=seed)
    kw.update(dict(s.overrides))
    kw.update(extra)
    return SimConfig(**kw)
