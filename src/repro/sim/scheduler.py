"""VM/task scheduling policies (paper §4.5).

The paper uses A3C-R2N2 (an RL policy) as the *common* scheduler beneath all
straggler techniques; since the scheduler is shared, any fixed policy
preserves the technique comparison. We provide a deterministic
utilization-aware scorer (stand-in, see DESIGN.md deviations) and the random
scheduler the paper uses to generate diverse training data (§4.4).
"""
from __future__ import annotations

import numpy as np

from repro.sim.cluster import Cluster


class Scheduler:
    name = "base"

    def place(self, cluster: Cluster, req: np.ndarray,
              rng: np.random.Generator,
              exclude: int | None = None) -> int:
        raise NotImplementedError


class UtilizationAwareScheduler(Scheduler):
    """Least projected-load placement with task-count tie-break."""

    name = "util-aware"

    def place(self, cluster, req, rng, exclude=None):
        online = cluster.online()
        if exclude is not None and online.sum() > 1:
            online = online.copy()
            online[exclude] = False
        proj = cluster.util + req[None, :]
        score = proj.max(axis=1) + 0.05 * cluster.n_tasks \
            - 0.1 * cluster.speed
        score = np.where(online, score, np.inf)
        best = int(np.argmin(score))
        return best


class RandomScheduler(Scheduler):
    """Uniform-random placement over online hosts (training-data generator,
    paper §4.4: 'a scheduler that selects tasks at random and schedules them
    randomly to any host using a uniform distribution')."""

    name = "random"

    def place(self, cluster, req, rng, exclude=None):
        online = np.nonzero(cluster.online())[0]
        if exclude is not None and len(online) > 1:
            online = online[online != exclude]
        if len(online) == 0:
            return int(rng.integers(0, cluster.n))
        return int(rng.choice(online))
