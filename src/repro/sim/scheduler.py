"""VM/task scheduling policies (paper §4.5).

The paper uses A3C-R2N2 (an RL policy) as the *common* scheduler beneath all
straggler techniques; since the scheduler is shared, any fixed policy
preserves the technique comparison. We provide a deterministic
utilization-aware scorer (stand-in, see DESIGN.md deviations) and the random
scheduler the paper uses to generate diverse training data (§4.4).

``place_batch`` is the engine's hot path: it places every ready task of an
interval in one call (including the down-host fallback the engine used to
apply per task) and must be *bitwise-equal* to calling ``place``
sequentially — the deterministic scorer vectorizes the loop, while the
base-class fallback preserves per-task RNG draw order for randomized
schedulers.
"""
from __future__ import annotations

import numpy as np

from repro.sim.cluster import Cluster


class Scheduler:
    name = "base"

    def place(self, cluster: Cluster, req: np.ndarray,
              rng: np.random.Generator,
              exclude: int | None = None) -> int:
        raise NotImplementedError

    def place_batch(self, cluster: Cluster, reqs: np.ndarray,
                    rng: np.random.Generator,
                    exclude: np.ndarray | None = None) -> np.ndarray:
        """Place ``reqs[i]`` for every i, in order.

        ``exclude`` is a per-task host id to avoid (-1 = none).  A task
        whose chosen host is down is immediately re-placed without the
        exclusion — the engine's historical per-task fallback — so RNG
        draw order matches the sequential loop exactly.
        """
        out = np.empty(len(reqs), np.int64)
        for i, req in enumerate(reqs):
            ex = (int(exclude[i])
                  if exclude is not None and exclude[i] >= 0 else None)
            host = self.place(cluster, req, rng, exclude=ex)
            if cluster.downtime[host] > 0:
                host = self.place(cluster, req, rng)
            out[i] = host
        return out


class UtilizationAwareScheduler(Scheduler):
    """Least projected-load placement with task-count tie-break."""

    name = "util-aware"

    def place(self, cluster, req, rng, exclude=None):
        online = cluster.online()
        if exclude is not None and online.sum() > 1:
            online = online.copy()
            online[exclude] = False
        proj = cluster.util + req[None, :]
        score = proj.max(axis=1) + 0.05 * cluster.n_tasks \
            - 0.1 * cluster.speed
        score = np.where(online, score, np.inf)
        best = int(np.argmin(score))
        return best

    def place_batch(self, cluster, reqs, rng, exclude=None):
        """Vectorized twin of the sequential loop (no RNG, no cross-task
        state): one (tasks, hosts) score matrix, per-task exclusion, and
        the down-host fallback applied as a masked second argmin."""
        if len(reqs) == 0:
            return np.zeros(0, np.int64)
        online = cluster.online()
        # identical float op order to ``place``: (max + a) - b per host
        proj = (cluster.util[None, :, :] + reqs[:, None, :]).max(axis=2)
        score = proj + 0.05 * cluster.n_tasks - 0.1 * cluster.speed
        score = np.where(online[None, :], score, np.inf)
        if exclude is not None and online.sum() > 1:
            excl_rows = np.nonzero(np.asarray(exclude) >= 0)[0]
            if excl_rows.size:
                sc = score.copy()
                sc[excl_rows, np.asarray(exclude)[excl_rows]] = np.inf
                best = np.argmin(sc, axis=1)
            else:
                best = np.argmin(score, axis=1)
        else:
            best = np.argmin(score, axis=1)
        down = cluster.downtime[best] > 0
        if down.any():  # down-host fallback: re-place without the exclusion
            best[down] = np.argmin(score[down], axis=1)
        return best.astype(np.int64)


class RandomScheduler(Scheduler):
    """Uniform-random placement over online hosts (training-data generator,
    paper §4.4: 'a scheduler that selects tasks at random and schedules them
    randomly to any host using a uniform distribution').

    Uses the base-class sequential ``place_batch``: each placement draws
    from the shared RNG stream, so batching must preserve call order.
    """

    name = "random"

    def place(self, cluster, req, rng, exclude=None):
        online = np.nonzero(cluster.online())[0]
        if exclude is not None and len(online) > 1:
            online = online[online != exclude]
        if len(online) == 0:
            return int(rng.integers(0, cluster.n))
        return int(rng.choice(online))
