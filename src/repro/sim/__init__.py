"""CloudSim-analogue simulator: the paper's evaluation substrate
in JAX/numpy."""
from repro.sim.config import SimConfig, small
from repro.sim.engine import NoMitigation, SimAction, Simulation, Technique

__all__ = ["SimConfig", "small", "Simulation", "Technique", "SimAction",
           "NoMitigation", "scenarios", "sweep"]

from repro.sim import scenarios, sweep  # noqa: E402  (registry + grid runner)
