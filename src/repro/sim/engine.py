"""Discrete-interval cloud simulation engine (CloudSim analogue, §4.3).

Semantics per scheduling interval (300 s):
  1. host downtimes tick down; new jobs arrive (Poisson);
  2. the bound Policy sees a submit-time TelemetryView (clone/delay);
  3. pending tasks are placed by the shared scheduler (VM-creation faults
     bounce placements);
  4. Weibull fault events fire (host downtime -> resident tasks restart;
     cloudlet faults -> task restarts);
  5. the Policy observes an interval TelemetryView and decides
     speculate/rerun actions;
  6. tasks progress at host effective speed (contention + heterogeneity);
     completions are interpolated within the interval;
  7. metrics are recorded; completed jobs update per-host straggler
     moving averages (ground truth via per-job Pareto-K threshold).

Policies never touch ``sim.tasks``/``sim.cluster`` directly: the
``Simulation.snapshot()`` view (``repro.policy.telemetry``) is the only
state they read, and ``repro.policy.Action`` the only way they act.

Speculative copies are first-result-wins: whichever of {original, copy}
finishes first completes the logical task and cancels the others.
"""
from __future__ import annotations

import time as _time

import numpy as np

from repro.core import pareto
from repro.policy import (Action, Policy, TelemetryView,
                          EVENT_INTERVAL, EVENT_SUBMIT)
from repro.policy.telemetry import (CANCELLED, DONE, PENDING, RUNNING,
                                    HostTelemetry, JobTelemetry,
                                    make_task_telemetry, readonly)
from repro.sim import metrics as M
from repro.sim.cluster import Cluster
from repro.sim.config import SimConfig
from repro.sim.faults import FaultInjector, FaultKind
from repro.sim.scheduler import Scheduler, UtilizationAwareScheduler
from repro.sim.workload import WorkloadGenerator

__all__ = ["PENDING", "RUNNING", "DONE", "CANCELLED", "TaskTable",
           "JobTable", "SimAction", "Technique", "NoMitigation",
           "Simulation"]


class TaskTable:
    """Struct-of-arrays task store with amortized growth."""

    _F = dict(job_id=np.int64, state=np.int8, host=np.int64,
              work=np.float64, progress=np.float64, submit_s=np.float64,
              start_s=np.float64, finish_s=np.float64, deadline_s=np.float64,
              is_deadline=bool, sla_weight=np.float64, restarts=np.int64,
              is_copy=bool, orig=np.int64, delayed_until=np.int64,
              prev_host=np.int64)

    def __init__(self, cap: int = 1024):
        self.n = 0
        self._cap = cap
        for f, dt in self._F.items():
            setattr(self, f, np.zeros(cap, dt))
        self.req = np.zeros((cap, 4))

    def _grow(self, need: int) -> None:
        if self.n + need <= self._cap:  # amortized O(1): copy only on growth
            return
        while self.n + need > self._cap:
            self._cap *= 2
        for f, dt in self._F.items():
            a = getattr(self, f)
            b = np.zeros(self._cap, dt)
            b[:len(a)] = a
            setattr(self, f, b)
        r = np.zeros((self._cap, 4))
        r[:len(self.req)] = self.req
        self.req = r

    def add(self, **kw) -> int:
        return int(self.add_batch(1, **kw)[0])

    def add_batch(self, n_new: int, **kw) -> np.ndarray:
        """Vectorized add of n_new tasks; kw values are scalars or (n_new,)
        arrays. Returns the new task indices."""
        if n_new == 0:
            return np.zeros(0, np.int64)
        self._grow(n_new)
        idx = np.arange(self.n, self.n + n_new, dtype=np.int64)
        self.n += n_new
        self.host[idx] = -1
        self.orig[idx] = -1
        self.prev_host[idx] = -1
        self.finish_s[idx] = -1.0
        for k, v in kw.items():
            getattr(self, k)[idx] = v
        return idx

    def active_mask(self) -> np.ndarray:
        return (self.state[:self.n] == RUNNING)

    def view(self, field: str) -> np.ndarray:
        return getattr(self, field)[:self.n]


class JobTable:
    """CSR job index with amortized growth.

    Job ``j``'s original tasks are the contiguous TaskTable range
    ``[start[j], start[j] + count[j])`` — arrivals append whole jobs in
    submission order and speculative copies are never job members — so
    per-job lookups are O(1) slices and the active-job scan is one
    vectorized mask over dense arrays (no dict bookkeeping).
    """

    _F = dict(start=np.int64, count=np.int64, open_count=np.int64,
              done=bool, deadline=bool)

    def __init__(self, cap: int = 256):
        self.n = 0
        self._cap = cap
        for f, dt in self._F.items():
            setattr(self, f, np.zeros(cap, dt))

    def _grow(self, need: int) -> None:
        if self.n + need <= self._cap:
            return
        while self.n + need > self._cap:
            self._cap *= 2
        for f, dt in self._F.items():
            a = getattr(self, f)
            b = np.zeros(self._cap, dt)
            b[:len(a)] = a
            setattr(self, f, b)

    def add_batch(self, first_task: np.ndarray, counts: np.ndarray,
                  deadline: np.ndarray) -> None:
        n_new = len(counts)
        if n_new == 0:
            return
        self._grow(n_new)
        idx = np.arange(self.n, self.n + n_new)
        self.n += n_new
        self.start[idx] = first_task
        self.count[idx] = counts
        self.open_count[idx] = counts
        self.deadline[idx] = deadline

    def view(self, field: str) -> np.ndarray:
        return getattr(self, field)[:self.n]

    def task_ids(self, job: int) -> np.ndarray:
        s = int(self.start[job])
        return np.arange(s, s + int(self.count[job]), dtype=np.int64)

    def active(self) -> np.ndarray:
        return np.nonzero((self.open_count[:self.n] > 0)
                          & ~self.done[:self.n])[0]


#: the simulator's historical action type — now the unified vocabulary.
#: ``SimAction("clone", i, n_clones=2)`` keeps constructing as before.
SimAction = Action


class Technique(Policy):
    """Legacy adapter for engine-coupled techniques.

    New policies subclass :class:`repro.policy.Policy` and consume only
    the :class:`TelemetryView`; this adapter keeps the old
    ``bind(sim)`` / ``on_submit`` / ``on_interval`` surface working for
    existing subclasses (tests, ad-hoc drills) by translating the
    policy-protocol calls back into the old hooks.
    """

    name = "none"
    sim: "Simulation"

    def bind(self, sim: "Simulation") -> None:
        self.sim = sim

    def on_submit(self, new_idx: np.ndarray) -> list[Action]:
        return []

    def on_interval(self) -> list[Action]:
        return []

    def decide(self, view: TelemetryView) -> list[Action]:
        if view.event == EVENT_SUBMIT:
            return self.on_submit(view.new_tasks)
        return self.on_interval()


class NoMitigation(Technique):
    name = "none"


class Simulation:
    def __init__(self, cfg: SimConfig, technique: Policy | None = None,
                 scheduler: Scheduler | None = None):
        self.cfg = cfg
        self.rng = np.random.default_rng(cfg.seed)
        self.cluster = Cluster(cfg, self.rng)
        self.workload = WorkloadGenerator(cfg, self.rng)
        self.faults = FaultInjector(cfg, self.rng)
        self.scheduler = scheduler or UtilizationAwareScheduler()
        self.technique = technique or NoMitigation()
        if hasattr(self.technique, "bind"):  # legacy Technique subclasses
            self.technique.bind(self)
        self.tasks = TaskTable()
        self.jobs = JobTable()
        self.log = M.MetricsLog()
        self.t = 0  # current interval index
        self.host_ips = cfg.host_ips_array()  # (n_hosts,) MI/s per speed
        # incremental job-completion bookkeeping (no per-interval
        # all-jobs/all-tasks scan): the JobTable's open counts, jobs that
        # hit zero this interval, and orig -> copy ids so first-result-wins
        # cancellation never scans the full task table
        self._jobs_newly_closed: list[int] = []
        self._copy_groups: dict[int, list[int]] = {}
        self.straggler_ma = np.zeros(cfg.n_hosts)
        self.host_straggler_counts = np.zeros(cfg.n_hosts)
        # per completed job: (finish interval, task times, straggler flags,
        # hosts) for ground-truth accounting
        self.completed_jobs: list[dict] = []
        self._interval_straggler_done: list[int] = []
        self.util_history: list[np.ndarray] = []  # (n_hosts, 4) per interval

    # ------------------------------ helpers -------------------------------

    @property
    def now_s(self) -> float:
        return self.t * self.cfg.interval_seconds

    def active_jobs(self) -> np.ndarray:
        return self.jobs.active()

    def job_incomplete_tasks(self, job: int) -> np.ndarray:
        t = self.jobs.task_ids(job)
        return t[self.tasks.state[t] <= RUNNING]

    def snapshot(self, event: str = EVENT_INTERVAL,
                 new_tasks: np.ndarray | None = None) -> TelemetryView:
        """Publish the policy-facing telemetry view (paper M_H/M_T inputs
        plus clocks and the job index).

        Zero-copy: every array is a read-only numpy view onto live engine
        buffers, so the view reflects engine state *at the moment a
        policy reads it* and is only valid for the current hook call.
        """
        tt, c = self.tasks, self.cluster
        return TelemetryView(
            event=event, t=self.t, now_s=self.now_s,
            interval_seconds=self.cfg.interval_seconds, config=self.cfg,
            tasks=make_task_telemetry(tt.n, tt.view, tt.req[:tt.n]),
            hosts=HostTelemetry(
                util=readonly(c.util), speed=readonly(c.speed),
                cap=readonly(c.cap), cost=readonly(c.cost),
                power_max=readonly(c.power_max),
                power_min=readonly(c.power_min),
                n_tasks=readonly(c.n_tasks),
                downtime=readonly(c.downtime),
                ips=readonly(self.host_ips)),
            jobs=JobTelemetry(
                start=readonly(self.jobs.view("start")),
                count=readonly(self.jobs.view("count")),
                open_count=readonly(self.jobs.view("open_count")),
                done=readonly(self.jobs.view("done")),
                deadline=readonly(self.jobs.view("deadline")),
                _state=tt.view("state")),
            new_tasks=(np.asarray(new_tasks, np.int64)
                       if new_tasks is not None
                       else np.zeros(0, np.int64)),
            straggler_ma=readonly(self.straggler_ma),
            completed_jobs=self.completed_jobs,
            util_history=self.util_history,
            rng=self.rng)

    def _place(self, i: int, forced: int | None = None) -> None:
        """Place task i (VM-creation faults bounce to rescheduling)."""
        tt = self.tasks
        host = forced if forced is not None else self.scheduler.place(
            self.cluster, tt.req[i], self.rng,
            exclude=int(tt.prev_host[i]) if tt.prev_host[i] >= 0 else None)
        if self.cluster.downtime[host] > 0:
            host = self.scheduler.place(self.cluster, tt.req[i], self.rng)
        tt.host[i] = host
        tt.state[i] = RUNNING
        if tt.start_s[i] == 0.0:
            tt.start_s[i] = self.now_s

    # ---------------------------- main stepping ----------------------------

    def step(self) -> None:
        cfg, tt = self.cfg, self.tasks
        self.cluster.begin_interval()
        self._interval_straggler_done = []

        # 1. arrivals (batched task insertion)
        batch = self.workload.sample_interval(self.t)
        new_idx = tt.add_batch(
            len(batch.job_ids), job_id=batch.job_ids, state=PENDING,
            work=batch.work, submit_s=self.now_s,
            deadline_s=batch.deadline_rel, is_deadline=batch.is_deadline,
            sla_weight=batch.sla_weight)
        if len(new_idx):
            tt.req[new_idx] = batch.req
            # whole jobs arrive as contiguous task blocks with dense,
            # sequential ids — register them in the CSR job table
            firsts = np.nonzero(np.r_[True,
                                      batch.job_ids[1:]
                                      != batch.job_ids[:-1]])[0]
            counts = np.diff(np.r_[firsts, len(batch.job_ids)])
            if (batch.job_ids[firsts]
                    != np.arange(self.jobs.n,
                                 self.jobs.n + len(firsts))).any():
                raise AssertionError(
                    "workload batches must emit dense, sequential job ids "
                    "with each job's tasks contiguous (CSR job index)")
            self.jobs.add_batch(new_idx[firsts], counts,
                                batch.is_deadline[firsts])

        # 2. policy submit-time decision point (clone / delay) — skipped
        # for policies that declare submit_hook=False (the view and an
        # ignoring decide() are both pure, so this is behavior-preserving)
        t0 = _time.perf_counter()
        if getattr(self.technique, "submit_hook", True):
            for act in self.technique.decide(self.snapshot(EVENT_SUBMIT,
                                                           new_idx)):
                self._apply(act)
        submit_overhead = _time.perf_counter() - t0

        # 3. schedule pending tasks whose delay has expired — one
        # place_batch call for the whole interval (bitwise-equal to the
        # old per-task loop), then bounce VM-creation-fault placements
        events = self.faults.interval_events()
        vm_fault_hosts = [e.host for e in events
                          if e.kind == FaultKind.VM_CREATION]
        ready = np.nonzero((tt.view("state") == PENDING)
                           & (tt.view("delayed_until") <= self.t))[0]
        if ready.size:
            hosts = self.scheduler.place_batch(
                self.cluster, tt.req[ready], self.rng,
                exclude=tt.prev_host[ready])
            tt.host[ready] = hosts
            tt.state[ready] = RUNNING
            fresh = ready[tt.start_s[ready] == 0.0]
            tt.start_s[fresh] = self.now_s
            if vm_fault_hosts:
                bounced = ready[np.isin(hosts, vm_fault_hosts)]
                if bounced.size:                # VM creation fault: bounce
                    tt.state[bounced] = PENDING  # to next interval; avoid
                    tt.restarts[bounced] += 1    # the host on re-place; a
                    tt.prev_host[bounced] = tt.host[bounced]  # pending task
                    tt.host[bounced] = -1        # holds no host

        # 4. fault events: host downtime restarts residents, cloudlet
        # faults restart sampled active tasks (both batched)
        failed = [ev for ev in events if ev.kind == FaultKind.HOST]
        for ev in failed:
            self.cluster.fail_host(ev.host, ev.downtime)
        if failed:
            self._restart_batch(np.nonzero(
                (tt.view("state") == RUNNING)
                & np.isin(tt.view("host"),
                          [ev.host for ev in failed]))[0])
        active = tt.active_mask()
        cl_faults = self.faults.cloudlet_faults(int(active.sum()))
        self._restart_batch(np.nonzero(active)[0][cl_faults])

        # 5. policy interval decision point (speculate / rerun): one view
        # feeds telemetry ingestion and the decision — same state, built
        # zero-copy once
        t0 = _time.perf_counter()
        view = self.snapshot(EVENT_INTERVAL)
        self.technique.observe(view)
        for act in self.technique.decide(view):
            self._apply(act)
        predicted = self.technique.predicted_straggler_count()
        interval_overhead = _time.perf_counter() - t0 + submit_overhead

        # 6. progress
        active = tt.active_mask()
        self.cluster.recompute_utilization(tt.view("req")[:, :],
                                           tt.view("host"), active)
        rate = self.cluster.effective_speed() * self.host_ips  # MI/s, per host
        run = np.nonzero(active)[0]
        inc = rate[tt.host[run]] * cfg.interval_seconds
        prog0 = tt.progress[run]
        tt.progress[run] = prog0 + inc
        finished = tt.progress[run] >= tt.work[run]
        fin_idx = run[finished]
        if fin_idx.size:
            frac = np.clip((tt.work[fin_idx] - prog0[finished])
                           / np.maximum(inc[finished], 1e-9), 0, 1)
            fins = self.now_s + frac * cfg.interval_seconds
            # first-result-wins is decided by interpolated finish time:
            # complete earliest-first and skip tasks a sibling already
            # cancelled (or completed) earlier within this interval
            order = np.argsort(fins, kind="stable")
            for i, fs in zip(fin_idx[order], fins[order]):
                if tt.state[i] == RUNNING:
                    self._complete(int(i), float(fs))

        self.util_history.append(self.cluster.util.copy())

        # 7. metrics + ground-truth straggler accounting
        cont = M.contention_metric(self.cluster, tt.view("req"),
                                   tt.view("host"), tt.active_mask())
        self.log.record_interval(self.cluster, cont,
                                 int(tt.active_mask().sum()), predicted,
                                 interval_overhead)
        self._update_job_completion()
        self.t += 1

    def run(self) -> dict:
        for _ in range(self.cfg.n_intervals):
            self.step()
        return self.summary()

    def summary(self) -> dict:
        s = M.summarize(self.log, self.tasks, self.cfg.interval_seconds,
                        self.cfg.restart_overhead_s)
        s["technique"] = self.technique.name
        s["jobs_done"] = int(self.jobs.view("done").sum())
        return s

    # ------------------------------ actions -------------------------------

    def _apply(self, act: SimAction) -> None:
        tt = self.tasks
        i = act.task
        if tt.state[i] not in (PENDING, RUNNING):
            return
        if act.kind == "delay":
            if tt.state[i] == PENDING:
                tt.delayed_until[i] = self.t + act.delay
        elif act.kind == "rerun":
            self._restart(i, target=act.target)
        elif act.kind in ("speculate", "clone"):
            for c in range(act.n_clones if act.kind == "clone" else 1):
                j = tt.add(job_id=tt.job_id[i], state=PENDING,
                           work=tt.work[i], submit_s=self.now_s,
                           deadline_s=tt.deadline_s[i],
                           is_deadline=tt.is_deadline[i],
                           sla_weight=tt.sla_weight[i], is_copy=True,
                           orig=i)
                tt.req[j] = tt.req[i]
                self._copy_groups.setdefault(int(i), []).append(j)
                self._place(j, forced=act.target)

    def _restart(self, i: int, target: int | None = None) -> None:
        tt = self.tasks
        tt.progress[i] = 0.0
        tt.restarts[i] += 1
        tt.prev_host[i] = tt.host[i]
        if target is not None:
            self._place(i, forced=target)
        else:
            tt.state[i] = PENDING
            tt.host[i] = -1

    def _restart_batch(self, idx: np.ndarray) -> None:
        """Fault-path restarts (no forced target): tasks lose progress and
        re-queue unplaced, remembering the host for re-place avoidance."""
        if idx.size == 0:
            return
        tt = self.tasks
        tt.progress[idx] = 0.0
        tt.restarts[idx] += 1
        tt.prev_host[idx] = tt.host[idx]
        tt.state[idx] = PENDING
        tt.host[idx] = -1

    def _complete(self, i: int, finish_s: float) -> None:
        tt = self.tasks
        tt.state[i] = DONE
        tt.finish_s[i] = finish_s
        # first-result-wins across the whole copy DAG: techniques may
        # speculate on running copies, so resolve the chain to the true
        # original, complete it with the winner's stamp, and cancel every
        # other member reachable from the root — a one-level cancel would
        # leave grandchild copies running (and later "completing") after
        # the logical task is done
        root = i
        while tt.is_copy[root]:
            root = int(tt.orig[root])
        if root == i:
            self._close_original(i)
        elif tt.state[root] in (PENDING, RUNNING):
            tt.state[root] = DONE
            tt.finish_s[root] = finish_s
            self._close_original(root)
        stack = [root]
        while stack:
            for g in self._copy_groups.get(stack.pop(), ()):
                if tt.state[g] != DONE:
                    tt.state[g] = CANCELLED
                stack.append(g)

    def _close_original(self, i: int) -> None:
        """Original task i reached a terminal state: update the per-job open
        count and queue the job for ground-truth accounting at zero."""
        job = int(self.tasks.job_id[i])
        self.jobs.open_count[job] -= 1
        if self.jobs.open_count[job] == 0 and not self.jobs.done[job]:
            self._jobs_newly_closed.append(job)

    # ----------------------- job-level bookkeeping ------------------------

    def _update_job_completion(self) -> None:
        """Ground-truth accounting for jobs whose last original task reached
        a terminal state this interval (tracked incrementally by
        ``_close_original`` — no all-jobs/all-tasks rescan)."""
        tt = self.tasks
        k = self.cfg.k
        counts = np.zeros(self.cfg.n_hosts)
        for job in self._jobs_newly_closed:
            tids = self.jobs.task_ids(job)
            times = np.maximum(tt.finish_s[tids] - tt.submit_s[tids], 1e-3)
            hosts = tt.host[tids].copy()
            a, b = pareto.fit_pareto_np(times)
            thr = float(pareto.straggler_threshold_np(a, b, k))
            strag = times > thr
            # a task finished via its copy while unplaced has host == -1;
            # don't let the wrap-around credit the last host
            placed = strag & (hosts >= 0)
            np.add.at(counts, hosts[placed], 1)
            self.jobs.done[job] = True
            self.completed_jobs.append(dict(
                job=job, t=self.t, times=times, straggler=strag,
                hosts=hosts, deadline=bool(self.jobs.deadline[job])))
        self._jobs_newly_closed = []
        decay = 0.8
        self.straggler_ma = decay * self.straggler_ma + (1 - decay) * counts
        self.host_straggler_counts += counts

    # ------------------ post-hoc per-interval actuals (MAPE) ---------------

    def actual_stragglers_per_interval(self) -> np.ndarray:
        """actual_t = number of straggler tasks active at interval t.

        Computable only post-hoc (a task is a straggler relative to its
        job's fitted Pareto threshold once the job completes).
        """
        out = np.zeros(self.t)
        if self.t == 0 or not self.completed_jobs:
            return out
        dt = self.cfg.interval_seconds
        tt = self.tasks
        tids = np.concatenate(
            [self.jobs.task_ids(rec["job"])
             for rec in self.completed_jobs])
        flags = np.concatenate(
            [np.asarray(rec["straggler"], bool)
             for rec in self.completed_jobs])
        tids = tids[flags]
        if tids.size == 0:
            return out
        # difference-array accumulation over [lo, hi] interval spans
        lo = (tt.submit_s[tids] // dt).astype(np.int64)
        hi = (np.maximum(tt.finish_s[tids], tt.submit_s[tids])
              // dt).astype(np.int64)
        lo = np.clip(lo, 0, self.t)
        hi_end = np.clip(np.minimum(hi + 1, self.t), 0, self.t)
        diff = np.zeros(self.t + 1)
        np.add.at(diff, lo, 1.0)
        np.add.at(diff, hi_end, -1.0)
        return np.cumsum(diff)[:self.t]
