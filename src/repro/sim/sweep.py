"""Batched scenario-sweep subsystem for the cloud simulator.

The paper's headline results (Figs. 6-10, Table 4) are comparative grids —
techniques x seeds x regimes — previously run serially through hand-rolled
loops. This module makes the grid declarative and parallel:

    spec = SweepSpec(techniques=("start", "sgc", "none"),
                     seeds=(0, 1, 2),
                     scenarios=("planetlab", "flash-crowd", "heavy-tail",
                                "fault-storm"),
                     out_dir="artifacts")
    result = run(spec)            # cells in parallel over a process pool
    result.aggregate()            # {(scenario, technique): metric -> mean/CI}

Design notes:
  * a cell = (scenario, technique, seed); each cell builds its Simulation
    from scratch inside ``run_cell`` — a pure function of the spec — so a
    parallel sweep is bitwise-equal to a serial one (modulo the wall-clock
    ``avg_overhead_s``/``wall_s`` timing fields);
  * techniques that declare pretraining (their registry entry carries a
    ``PretrainSpec`` — no technique is special-cased by name here) are
    pretrained once per (technique, base-config) with fixed seeds (7
    train / 9 warmup, matching benchmarks) and cached as pickled bytes;
    every cell deserializes a fresh instance, so no mutable technique
    state leaks between cells.  A parallel run trains in the PARENT and
    broadcasts the bytes to workers with their cells — workers never
    duplicate a warmup/training run;
  * workers are spawned (not forked): JAX runtimes do not survive fork —
    and the pool is *persistent* across ``run()`` calls, so per-worker
    pretrain/warmup caches and XLA jit caches survive between figure
    sweeps (``shutdown_pool()`` tears it down explicitly);
  * scheduling is dynamic and parent-participating: cells are grouped
    into (technique, scenario) cache-affinity units, the parent runs
    units itself while workers spawn/import, and steals back unstarted
    submissions when the queue drains — so a cold pool can never make a
    sweep slower than running it serially, and a warm W-worker pool
    gives W+1 effective lanes.
"""
from __future__ import annotations

import atexit
import collections
import concurrent.futures as cf
import csv
import dataclasses
import multiprocessing
import os
import pickle
import signal
import time
import warnings

import numpy as np

from repro.policy import Policy, PretrainContext
from repro.sim import scenarios as S
from repro.sim.config import SimConfig
from repro.sim.engine import Simulation

QOS_KEYS = ("avg_execution_time_s", "resource_contention", "energy_kwh",
            "sla_violation_rate", "cpu_util_pct", "ram_util_pct",
            "disk_util_pct", "bw_util_pct")

#: summary fields that measure host wall-clock, not simulated behaviour —
#: excluded from determinism comparisons
TIMING_KEYS = ("avg_overhead_s",)


def deterministic_summary(summary: dict) -> dict:
    """Cell summary with host-timing fields stripped — the part that must
    be bitwise-equal between serial and parallel execution."""
    return {k: v for k, v in summary.items() if k not in TIMING_KEYS}


@dataclasses.dataclass(frozen=True)
class SweepSpec:
    """Declarative grid: techniques x seeds x scenarios (+ base sizing)."""

    techniques: tuple = ("none",)
    seeds: tuple = (0,)
    scenarios: tuple = ("planetlab",)
    n_hosts: int = 32
    n_intervals: int = 72
    arrival_rate: float = 0.6
    overrides: tuple = ()          # ((SimConfig field, value), ...) per cell
    metrics: tuple = QOS_KEYS
    max_workers: int | None = None  # None -> cpu_count; <= 1 -> serial
    out_dir: str | None = None      # write CSV artifacts here when set
    csv_prefix: str = "sweep"
    pretrain_epochs: int = 8        # START encoder-LSTM pretraining epochs
    igru_epochs: int = 40           # IGRU-SD warmup-fit epochs
    # extra ((knob, value), ...) pairs for third-party Pretrainable
    # policies whose registry entry names an ``epochs_knob`` other than
    # the two built-ins above (a dict is accepted, like ``overrides``)
    pretrain_knobs: tuple = ()
    # per-technique constructor keywords, ((name, ((kw, value), ...)), ...)
    # — a dict-of-dicts is accepted: technique_kwargs={"single-fork":
    # {"p": 0.7}} sweeps a policy's own knobs without registering a
    # variant per setting; pretrained policies receive them through
    # ``PretrainContext.kwargs`` (their pretrain classmethod must
    # forward them, ``cls(..., **ctx.kwargs)`` — see the worked example
    # in ``repro.policy``)
    technique_kwargs: tuple = ()
    # pretrain on the scenario base config with only dimension-changing
    # overrides (n_hosts/max_tasks, see _PRETRAIN_KEYS) kept — so a sweep
    # over regime/QoS knobs (arrival_rate, reserved_utilization, ...)
    # shares one trained controller per scenario (the old benchmarks'
    # _prep behaviour). Set False to train inside every cell's exact
    # regime instead.
    shared_pretrain: bool = True

    def __post_init__(self):
        for f in ("overrides", "pretrain_knobs"):  # accept dict spelling
            if isinstance(getattr(self, f), dict):
                object.__setattr__(self, f,
                                   tuple(getattr(self, f).items()))
        tk = self.technique_kwargs
        if isinstance(tk, dict):
            tk = tuple(tk.items())
        object.__setattr__(self, "technique_kwargs", tuple(
            (name, tuple(sorted(kw.items())) if isinstance(kw, dict)
             else tuple(kw)) for name, kw in tk))
        for f in ("techniques", "seeds", "scenarios", "overrides",
                  "metrics", "pretrain_knobs"):
            object.__setattr__(self, f, tuple(getattr(self, f)))
        # an empty grid axis used to surface as a bare IndexError deep
        # inside warm_pool_caches (spec.seeds[0]) or a silently empty
        # CSV from run() — fail at construction, naming the field
        for f in ("techniques", "seeds", "scenarios"):
            if not getattr(self, f):
                raise ValueError(
                    f"SweepSpec.{f} must be a non-empty tuple — an "
                    f"empty {f} grid axis means zero cells")
        # fail fast, before any worker is spawned: an unknown technique
        # (ValueError listing registered names) or scenario (KeyError)
        # should abort the sweep at spec-construction time
        from repro import policy
        import repro.sim.techniques  # noqa: F401  (registers built-ins)
        policy.validate(self.techniques, substrate="sim")
        policy.validate((n for n, _ in self.technique_kwargs),
                        substrate="sim")
        for sc in self.scenarios:
            S.get(sc)

    def kwargs_for(self, technique: str) -> dict:
        """Constructor keywords declared for ``technique`` (maybe {})."""
        return dict(dict(self.technique_kwargs).get(technique, ()))

    def cells(self) -> list[tuple[str, str, int]]:
        return [(sc, tech, int(seed)) for sc in self.scenarios
                for tech in self.techniques for seed in self.seeds]

    #: overrides that change network dimensions — the only ones kept when
    #: building the shared pretraining config. Regime knobs (arrival_rate,
    #: n_intervals, QoS overrides) are dropped so a sweep over them (fig7)
    #: shares ONE pretrained controller per scenario, like the old _prep.
    _PRETRAIN_KEYS = ("n_hosts", "max_tasks")

    def cell_config(self, scenario: str, seed: int) -> SimConfig:
        # sizing keys in ``overrides`` replace the spec's base sizing
        # (before scenario arrival scaling) instead of colliding with the
        # explicit keyword arguments
        extra = dict(self.overrides)
        sizing = dict(
            n_hosts=extra.pop("n_hosts", self.n_hosts),
            n_intervals=extra.pop("n_intervals", self.n_intervals),
            arrival_rate=extra.pop("arrival_rate", self.arrival_rate))
        return S.make_config(scenario, seed=seed, **sizing, **extra)

    def pretrain_config(self, scenario: str, seed: int) -> SimConfig:
        """Shared-pretrain environment: scenario base + dimension
        overrides only (regime/QoS overrides stripped)."""
        extra = {k: v for k, v in dict(self.overrides).items()
                 if k in self._PRETRAIN_KEYS}
        return S.make_config(scenario, seed=seed,
                             n_hosts=extra.pop("n_hosts", self.n_hosts),
                             n_intervals=self.n_intervals,
                             arrival_rate=self.arrival_rate, **extra)


@dataclasses.dataclass
class CellResult:
    scenario: str
    technique: str
    seed: int
    summary: dict
    wall_s: float


# --------------------- technique construction (cached) ---------------------

_PRETRAINED: dict = {}   # (name, base-cfg key[, epochs]) -> pickled policy
_WARM_VIEWS: dict = {}   # base-cfg key -> finished warmup TelemetryView


def _base_key(cfg: SimConfig):
    return dataclasses.astuple(dataclasses.replace(cfg, seed=0))


def _warm_view(cfg: SimConfig):
    """Finished warmup run (seed 9) as a policy TelemetryView."""
    key = _base_key(cfg)
    if key not in _WARM_VIEWS:
        # keep at most one warmup resident: pretrained techniques consume
        # the same one back-to-back per base config, and the view pins a
        # full Simulation's buffers — too heavy to accumulate per distinct
        # config in a long-lived process
        _WARM_VIEWS.clear()
        warm = Simulation(dataclasses.replace(cfg, seed=9))
        warm.run()
        _WARM_VIEWS[key] = warm.snapshot()
    return _WARM_VIEWS[key]


def make_technique(name: str, cfg: SimConfig, *, pretrain_cfg=None,
                   pretrain_epochs: int = 8,
                   igru_epochs: int = 40,
                   extra_knobs: dict | None = None,
                   technique_kwargs: dict | None = None,
                   pretrained: bytes | None = None) -> Policy:
    """Fresh technique instance for one cell.

    Dispatch is fully generic: the registry entry says whether (and how)
    a technique pretrains — ``entry.pretrain.fn`` builds the trained
    instance, ``entry.pretrain.epochs_knob`` names which epoch knob
    feeds it (one of this function's two built-in keywords, or a key in
    ``extra_knobs`` — SweepSpec's ``pretrain_knobs``; an undeclared knob
    raises rather than silently training at a default).  Trained
    policies are cached pickled per (name, base config[, epochs],
    kwargs) per process on fixed seeds (7 train / 9 warmup); every call
    returns a NEW object — safe to bind to a Simulation.
    ``pretrain_cfg`` decouples the training environment from the cell
    config (shared-pretrain sweeps).  ``technique_kwargs`` are
    constructor keywords (SweepSpec's per-technique knobs); pretrained
    policies receive them via ``PretrainContext.kwargs``.
    ``pretrained`` (pickled policy bytes, as produced by
    :func:`pretrain_payload` in the sweep parent) seeds this process's
    cache instead of duplicating the whole warmup + training run —
    workers receiving a broadcast payload never train.
    """
    entry, key, pcfg, epochs, tkw = _pretrain_entry(
        name, cfg, pretrain_cfg=pretrain_cfg,
        pretrain_epochs=pretrain_epochs, igru_epochs=igru_epochs,
        extra_knobs=extra_knobs, technique_kwargs=technique_kwargs)
    if entry.pretrain is None:
        return entry.factory(**tkw)
    if key not in _PRETRAINED:
        if pretrained is not None:
            _PRETRAINED[key] = pretrained
        else:
            ctx = PretrainContext(config=pcfg, epochs=epochs,
                                  warmup=lambda: _warm_view(pcfg),
                                  kwargs=dict(tkw))
            _PRETRAINED[key] = pickle.dumps(entry.pretrain.fn(ctx))
    return pickle.loads(_PRETRAINED[key])


def _pretrain_entry(name: str, cfg: SimConfig, *, pretrain_cfg=None,
                    pretrain_epochs: int = 8, igru_epochs: int = 40,
                    extra_knobs: dict | None = None,
                    technique_kwargs: dict | None = None):
    """Resolve a technique's registry entry and its pretrain cache key —
    shared by cell-side construction and the parent's payload broadcast."""
    from repro import policy
    import repro.sim.techniques  # noqa: F401  (registers built-ins)

    entry = policy.registry.get(name)   # ValueError for unknown names
    tkw = technique_kwargs or {}
    if entry.pretrain is None:
        return entry, None, None, None, tkw
    pcfg = pretrain_cfg if pretrain_cfg is not None else cfg
    # key on the epoch knob the technique actually consumes, so an
    # irrelevant knob changing doesn't evict/duplicate a trained entry
    knobs = {"pretrain_epochs": pretrain_epochs,
             "igru_epochs": igru_epochs, **(extra_knobs or {})}
    epochs_knob = entry.pretrain.epochs_knob
    if epochs_knob is not None and epochs_knob not in knobs:
        raise ValueError(
            f"technique {name!r} declares epochs_knob={epochs_knob!r}, "
            f"which is not a built-in sweep knob ({sorted(knobs)}); pass "
            f"it via SweepSpec(pretrain_knobs={{{epochs_knob!r}: ...}}) "
            f"or make_technique(extra_knobs=...)")
    epochs = knobs.get(epochs_knob)
    key = (name, _base_key(pcfg), tuple(sorted(tkw.items()))) \
        + ((epochs,) if epochs_knob else ())
    return entry, key, pcfg, epochs, tkw


def pretrain_payload(spec: SweepSpec, scenario: str,
                     technique: str) -> bytes | None:
    """Parent-side pretraining for one (scenario, technique): returns the
    pickled trained policy (``None`` for techniques that don't pretrain).

    A parallel ``run()`` calls this once per distinct pair and ships the
    bytes to workers with their cells — previously every worker re-ran
    the identical warmup simulation + training per pair, which made cold
    pools *slower than serial* on pretrain-heavy grids.  Cached in the
    parent's ``_PRETRAINED`` (same key the workers use), so repeated
    sweeps in one process pay nothing.
    """
    cfg = spec.cell_config(scenario, int(spec.seeds[0]))
    pcfg = None
    if spec.shared_pretrain and spec.overrides:
        pcfg = spec.pretrain_config(scenario, int(spec.seeds[0]))
    entry, key, pcfg, epochs, tkw = _pretrain_entry(
        technique, cfg, pretrain_cfg=pcfg,
        pretrain_epochs=spec.pretrain_epochs, igru_epochs=spec.igru_epochs,
        extra_knobs=dict(spec.pretrain_knobs),
        technique_kwargs=spec.kwargs_for(technique))
    if entry.pretrain is None:
        return None
    if key not in _PRETRAINED:
        ctx = PretrainContext(config=pcfg, epochs=epochs,
                              warmup=lambda: _warm_view(pcfg),
                              kwargs=dict(tkw))
        _PRETRAINED[key] = pickle.dumps(entry.pretrain.fn(ctx))
    return _PRETRAINED[key]


# ------------------------------ cell runner --------------------------------

def run_cell(spec: SweepSpec, scenario: str, technique: str, seed: int,
             pretrained: bytes | None = None) -> CellResult:
    """Run one (scenario, technique, seed) cell. Pure function of the spec
    (up to wall-clock timing fields) — the parallel/serial equivalence
    guarantee lives here.  ``pretrained`` optionally carries the parent's
    broadcast policy bytes (identical to what local pretraining would
    produce, so purity is preserved)."""
    _maybe_kill_for_test(scenario, technique, seed)
    cfg = spec.cell_config(scenario, seed)
    pcfg = None
    if spec.shared_pretrain and spec.overrides:
        pcfg = spec.pretrain_config(scenario, seed)
    tech = make_technique(technique, cfg, pretrain_cfg=pcfg,
                          pretrain_epochs=spec.pretrain_epochs,
                          igru_epochs=spec.igru_epochs,
                          extra_knobs=dict(spec.pretrain_knobs),
                          technique_kwargs=spec.kwargs_for(technique),
                          pretrained=pretrained)
    t0 = time.perf_counter()
    sim = Simulation(cfg, technique=tech)
    summary = sim.run()
    return CellResult(scenario=scenario, technique=technique, seed=seed,
                      summary=summary,
                      wall_s=time.perf_counter() - t0)


def _run_unit(spec: SweepSpec, cells: tuple,
              payloads: dict) -> list[CellResult]:
    """Run a scheduling unit (cells sharing (technique, scenario) cache
    affinity) in order."""
    return [run_cell(spec, sc, tech, seed,
                     pretrained=payloads.get((sc, tech)))
            for sc, tech, seed in cells]


def _run_unit_star(args) -> list[CellResult]:
    return _run_unit(*args)


def enable_compile_cache() -> str | None:
    """Point jax at a shared on-disk compilation cache (idempotent).

    Every sweep worker compiles the same XLA programs (the fused START
    step per batch bucket, train steps, ...); a shared persistent cache
    means the first process to compile a program writes it and every
    other worker — including freshly spawned cold pools — loads the
    identical executable from disk instead of recompiling.  Executables
    are bit-identical by construction, so results are unaffected.

    Opt-in: set ``REPRO_JAX_CACHE_DIR=<path>`` (disabled by default —
    on hosts with slow/contended disks the cache's per-hit bookkeeping
    can cost more than the recompiles it saves).
    """
    path = os.environ.get("REPRO_JAX_CACHE_DIR")
    if not path or path in ("off", "0"):
        return None
    import jax
    jax.config.update("jax_compilation_cache_dir", path)
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0)
    return path


def _worker_init(worker_seq=None, pin_cores: bool = False) -> None:
    """Pool-worker initializer: optionally pin the worker to its own
    core, enable the shared compilation cache before anything traces,
    then pay the import cost (jax + simulator stack) up front — spawn
    overlaps the parent's pretraining and first locally-run units.

    Pinning applies only when workers >= physical cores: each worker's
    XLA runtime sizes its intra-op pool from the scheduling affinity, so
    unpinned workers all spawn cpu-count threads and thrash each other.
    Thread count does not change results (reductions are sharded over
    rows, and the determinism suite passes across hosts with different
    core counts); the serial == parallel bitwise assertions still cover
    every sweep."""
    if pin_cores and worker_seq is not None \
            and hasattr(os, "sched_setaffinity"):
        with worker_seq.get_lock():
            idx = worker_seq.value
            worker_seq.value += 1
        cpus = sorted(os.sched_getaffinity(0))
        os.sched_setaffinity(0, {cpus[idx % len(cpus)]})
    enable_compile_cache()
    import repro.sim.engine  # noqa: F401


def _worker_warmup() -> bool:
    """No-op readiness probe: completes once the worker finished
    ``_worker_init`` and is pulling from the call queue.  (The
    ``REPRO_TEST_FAIL_WARMUP`` escape hatch exists so tests can force
    the failed-warmup scheduling path without crashing real workers.)"""
    if os.environ.get("REPRO_TEST_FAIL_WARMUP"):
        raise RuntimeError("forced warmup failure (REPRO_TEST_FAIL_WARMUP)")
    return True


_WARMUP_WARNED = False


def _ready_lanes(warmups) -> int:
    """Count the worker lanes that are actually live: warmup futures
    that completed *successfully*.  A future whose ``_worker_warmup``
    raised (or was cancelled) is ``done()`` too — counting those as
    ready made the parent over-submit 2x deep to lanes that never
    primed.  Failed warmups surface as a one-time RuntimeWarning."""
    global _WARMUP_WARNED
    ready = failed = 0
    for f in warmups:
        if not f.done():
            continue
        if f.cancelled() or f.exception() is not None:
            failed += 1
        else:
            ready += 1
    if failed and not _WARMUP_WARNED:
        _WARMUP_WARNED = True
        warnings.warn(
            f"{failed} sweep worker warmup(s) failed or were cancelled; "
            f"submitting only to the {ready} lane(s) that primed",
            RuntimeWarning, stacklevel=2)
    return ready


def _maybe_kill_for_test(scenario: str, technique: str, seed: int) -> None:
    """Fault-injection hook for the broken-pool / fabric-reclaim tests:
    ``REPRO_TEST_KILL_CELL=scenario:technique:seed:marker_path`` makes
    the FIRST worker process to run that cell SIGKILL itself (the
    marker file arms exactly one kill, so the rerun after recovery
    completes).  Never fires in the parent process, and never in
    production (env var unset)."""
    target = os.environ.get("REPRO_TEST_KILL_CELL")
    if not target:
        return
    sc, tech, sd, marker = target.split(":", 3)
    if (sc, tech, int(sd)) != (scenario, technique, seed):
        return
    if multiprocessing.current_process().name == "MainProcess":
        return
    try:
        fd = os.open(marker, os.O_CREAT | os.O_EXCL | os.O_WRONLY)
    except FileExistsError:
        return                      # already killed once: run normally
    os.close(fd)
    os.kill(os.getpid(), signal.SIGKILL)


# ------------------------------- results -----------------------------------

@dataclasses.dataclass
class SweepResult:
    spec: SweepSpec
    cells: list
    wall_s: float
    n_workers: int
    #: parent-side pretraining time folded into wall_s (0.0 when every
    #: technique was already cached or nothing pretrains)
    pretrain_s: float = 0.0

    def cell(self, scenario: str, technique: str, seed: int) -> CellResult:
        """O(1) cell lookup (the index is built once, lazily — a Table-4
        grid is thousands of cells and figure code looks each one up)."""
        index = self.__dict__.get("_index")
        if index is None or len(index) != len(self.cells):
            index = {(c.scenario, c.technique, c.seed): c
                     for c in self.cells}
            self.__dict__["_index"] = index
        try:
            return index[(scenario, technique, int(seed))]
        except KeyError:
            raise KeyError((scenario, technique, seed)) from None

    def aggregate(self) -> dict:
        """{(scenario, technique): {metric: {mean, ci95, n}}} over seeds."""
        groups: dict = {}
        for c in self.cells:
            groups.setdefault((c.scenario, c.technique), []).append(
                c.summary)
        out = {}
        for key, sums in groups.items():
            stats = {}
            for m in self.spec.metrics:
                vals = np.array([s[m] for s in sums], float)
                n = len(vals)
                ci = (1.96 * vals.std(ddof=1) / np.sqrt(n)) if n > 1 else 0.0
                stats[m] = {"mean": float(vals.mean()), "ci95": float(ci),
                            "n": n}
            out[key] = stats
        return out

    # ------------------------------ artifacts ------------------------------

    def cell_rows(self) -> tuple[list, list]:
        header = ["scenario", "technique", "seed", "wall_s",
                  *self.spec.metrics]
        rows = [[c.scenario, c.technique, c.seed, round(c.wall_s, 4)]
                + [c.summary[m] for m in self.spec.metrics]
                for c in self.cells]
        return header, rows

    def agg_rows(self) -> tuple[list, list]:
        header = ["scenario", "technique", "n"]
        for m in self.spec.metrics:
            header += [f"{m}_mean", f"{m}_ci95"]
        rows = []
        for (sc, tech), stats in self.aggregate().items():
            row = [sc, tech, stats[self.spec.metrics[0]]["n"]]
            for m in self.spec.metrics:
                row += [stats[m]["mean"], stats[m]["ci95"]]
            rows.append(row)
        return header, rows

    def write_csv(self, out_dir: str | None = None) -> list[str]:
        out_dir = out_dir or self.spec.out_dir
        if out_dir is None:
            return []
        os.makedirs(out_dir, exist_ok=True)
        paths = []
        for suffix, (header, rows) in (("cells", self.cell_rows()),
                                       ("agg", self.agg_rows())):
            path = os.path.join(out_dir,
                                f"{self.spec.csv_prefix}_{suffix}.csv")
            with open(path, "w", newline="") as f:
                w = csv.writer(f)
                w.writerow(header)
                w.writerows(rows)
            paths.append(path)
        return paths


# --------------------------------- runner ----------------------------------

#: persistent spawned worker pool, reused across ``run()`` calls so the
#: per-process pretrain/warmup caches (and XLA jit caches) survive between
#: figure sweeps — workers are only respawned when the requested size
#: changes or a worker died
_POOL: cf.ProcessPoolExecutor | None = None
_POOL_WORKERS: int = 0
_POOL_ATEXIT_REGISTERED = False
#: warmup futures submitted at spawn — ``f.done()`` per worker is the
#: scheduler's readiness signal (work submitted before any worker is up
#: cannot be cancelled back out of the executor's call queue, so the
#: parent gates submission on this instead of submitting blind)
_POOL_READY: list = []


def _pool(n_workers: int) -> cf.ProcessPoolExecutor:
    global _POOL, _POOL_WORKERS, _POOL_ATEXIT_REGISTERED, _POOL_READY
    global _WARMUP_WARNED
    if _POOL is not None and _POOL_WORKERS != n_workers:
        _POOL.shutdown(wait=True)
        _POOL = None
    if _POOL is None:
        if not _POOL_ATEXIT_REGISTERED:
            # pool hygiene: the persistent pool outlives every run() call
            # by design, so callers that never reach shutdown_pool() (CI
            # runners, the nightly grid, aborted notebooks) must not leak
            # spawned workers — tear it down at interpreter exit
            atexit.register(shutdown_pool)
            _POOL_ATEXIT_REGISTERED = True
        ctx = multiprocessing.get_context("spawn")
        pin = n_workers >= (os.cpu_count() or 1)
        _POOL = cf.ProcessPoolExecutor(
            max_workers=n_workers, mp_context=ctx,
            initializer=_worker_init,
            initargs=(ctx.Value("i", 0), pin))
        _POOL_WORKERS = n_workers
        _POOL_READY = [_POOL.submit(_worker_warmup)
                       for _ in range(n_workers)]
        _WARMUP_WARNED = False      # fresh pool: fresh failure report
    return _POOL


def shutdown_pool() -> None:
    """Tear down the persistent worker pool (frees worker memory; the next
    parallel ``run()`` respawns cold workers)."""
    global _POOL
    if _POOL is not None:
        _POOL.shutdown(wait=True)
        _POOL = None


def warm_pool(n_workers: int) -> float:
    """Spawn the persistent pool and pay every worker's import cost now;
    returns the wall seconds it took.  Benchmarks call this so one-time
    pool bring-up is *measured separately* from grid throughput instead
    of being silently folded into the first parallel sweep's number."""
    t0 = time.perf_counter()
    _pool(n_workers)
    for f in list(_POOL_READY):
        f.result()
    return time.perf_counter() - t0


def _build_payloads(spec: SweepSpec) -> dict:
    """Parent-side pretrain bytes for every (scenario, technique) of the
    grid that declares pretraining (cached across calls)."""
    payloads = {}
    for sc in spec.scenarios:
        for tech in spec.techniques:
            b = pretrain_payload(spec, sc, tech)
            if b is not None:
                payloads[(sc, tech)] = b
    return payloads


def warm_pool_caches(spec: SweepSpec, n_workers: int) -> float:
    """Populate every worker's jit/pretrain caches for ``spec`` (each
    worker runs the first-seed cell of every technique); returns the wall
    seconds.  Like :func:`warm_pool` this moves one-time bring-up cost
    out of the first measured grid: with START-style techniques a cold
    worker otherwise spends seconds XLA-compiling the prediction
    programs per batch bucket inside the first sweep that uses it."""
    t0 = time.perf_counter()
    warm_pool(n_workers)
    payloads = _build_payloads(spec)
    # the first-seed slice of the grid covers every (scenario, technique)
    # shape — remaining seeds reuse the same compiled programs
    unit = tuple((sc, tech, int(spec.seeds[0]))
                 for sc in spec.scenarios for tech in spec.techniques)
    pool = _pool(n_workers)
    for f in [pool.submit(_run_unit_star, (spec, unit, payloads))
              for _ in range(n_workers)]:
        f.result()
    return time.perf_counter() - t0


def _schedule_units(spec: SweepSpec, n_workers: int) -> list[tuple]:
    """Partition the grid into ordered scheduling units.

    Cells are grouped by (technique, scenario) — the pretrain/jit cache
    affinity key — so one worker runs a whole group back to back and
    compiles each technique's programs once, then groups are chunked so
    there are enough units (~4 per lane, parent included) to load-balance
    dynamically."""
    groups: dict = {}
    for c in spec.cells():
        groups.setdefault((c[1], c[0]), []).append(c)
    per_unit = max(1, (len(spec.cells()) + 4 * (n_workers + 1) - 1)
                   // (4 * (n_workers + 1)))
    units = []
    for cells in groups.values():
        for s in range(0, len(cells), per_unit):
            units.append(tuple(cells[s:s + per_unit]))
    return units


def run(spec: SweepSpec, *, fabric=None) -> SweepResult:
    """Execute the sweep grid; parallel over the persistent spawned process
    pool unless ``spec.max_workers <= 1``. Cell order in the result is
    deterministic (scenario-major, as produced by ``spec.cells()``).

    ``fabric`` accepts a started :class:`repro.sim.fabric.
    FabricCoordinator`: the grid is then served to its remote node
    agents instead of the local pool — same units, same payloads, same
    bitwise guarantee (every cell stays a pure function of the spec,
    wherever it runs).

    Parallel scheduling (all bitwise-neutral — every cell is a pure
    function of the spec, wherever it runs):

      * techniques that pretrain are trained ONCE in the parent and the
        pickled policy bytes broadcast to workers with their cells (cold
        pools used to re-train identical controllers in every worker);
      * cells are grouped by (technique, scenario) so each worker's
        pretrain/jit caches are hit back to back;
      * the parent participates: while workers spawn/import (~seconds on
        a cold pool) it runs units itself, and when the queue drains it
        steals back not-yet-started submissions — a cold-pool sweep is
        never slower than running serially.
    """
    if fabric is not None:
        return fabric.run_grid(spec)
    enable_compile_cache()
    cells = spec.cells()
    n_workers = spec.max_workers
    if n_workers is None:
        n_workers = min(len(cells), os.cpu_count() or 1)
    t0 = time.perf_counter()
    pretrain_s = 0.0
    if n_workers <= 1 or len(cells) <= 1:
        results = [run_cell(spec, *c) for c in cells]
        res = SweepResult(spec=spec, cells=results,
                          wall_s=time.perf_counter() - t0, n_workers=1)
        res.write_csv()
        return res

    pool = _pool(n_workers)             # spawn starts now, in background
    tp = time.perf_counter()
    payloads = _build_payloads(spec)
    pretrain_s = time.perf_counter() - tp

    units = collections.deque(_schedule_units(spec, n_workers))
    futures: dict = {}
    done_cells: dict = {}

    def record(results: list[CellResult]) -> None:
        for r in results:
            done_cells[(r.scenario, r.technique, r.seed)] = r

    def submit(unit: tuple):
        nonlocal pool
        pay = {k: payloads[k] for k in
               {(sc, tech) for sc, tech, _ in unit} if k in payloads}
        try:
            futures[pool.submit(_run_unit_star, (spec, unit, pay))] = unit
        except cf.process.BrokenProcessPool:
            # the pool broke while the parent was busy elsewhere: run
            # this unit locally, reclaim everything in flight on the
            # dead pool (its futures will never complete; leaving them
            # in `futures` would make harvest() tear down the healthy
            # replacement too), respawn, and resubmit
            record(_run_unit(spec, unit, payloads))
            lost = list(futures.values())
            futures.clear()
            shutdown_pool()
            pool = _pool(n_workers)
            for u in lost:
                submit(u)

    def harvest(wait: bool) -> None:
        nonlocal pool
        pending = list(futures)
        if wait:
            cf.wait(pending, return_when=cf.FIRST_COMPLETED)
        for f in pending:
            if not f.done():
                continue
            unit = futures.pop(f, None)
            if unit is None:
                continue
            try:
                record(f.result())
            except cf.process.BrokenProcessPool:
                # a worker died (OOM/kill): run the lost unit in the
                # parent, respawn the pool, resubmit what it still held
                # (futures was rebuilt — stop iterating the stale list)
                record(_run_unit(spec, unit, payloads))
                lost = list(futures.values())
                futures.clear()
                shutdown_pool()
                pool = _pool(n_workers)
                for u in lost:
                    submit(u)
                break

    # the parent only runs units itself while workers are still coming up,
    # or steady-state when the host has spare cores beyond the workers —
    # on an n_workers >= cpu box a third compute lane just adds contention
    spare_cores = (os.cpu_count() or 1) > n_workers
    while units or futures:
        # readiness-gated submission: work queued before a worker is up
        # enters the executor's call queue and can never be cancelled
        # back, so only feed live workers (2x deep to avoid starvation
        # while the parent is busy with its own unit)
        ready = _ready_lanes(_POOL_READY)
        while units and ready and len(futures) < 2 * ready:
            submit(units.popleft())
        if units and (ready == 0 or spare_cores):
            record(_run_unit(spec, units.popleft(), payloads))
            harvest(wait=False)
        elif units:
            # workers own the queue; wait for one to free up
            harvest(wait=True)
        else:
            # queue drained: steal back a submission no worker started
            # yet (still importing on a cold pool) and run it here
            # rather than waiting on their spawn
            stolen = next((f for f in futures if f.cancel()), None)
            if stolen is not None:
                record(_run_unit(spec, futures.pop(stolen), payloads))
            elif futures:
                harvest(wait=True)

    results = [done_cells[c] for c in cells]
    res = SweepResult(spec=spec, cells=results,
                      wall_s=time.perf_counter() - t0, n_workers=n_workers,
                      pretrain_s=pretrain_s)
    res.write_csv()
    return res
