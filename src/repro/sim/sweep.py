"""Batched scenario-sweep subsystem for the cloud simulator.

The paper's headline results (Figs. 6-10, Table 4) are comparative grids —
techniques x seeds x regimes — previously run serially through hand-rolled
loops. This module makes the grid declarative and parallel:

    spec = SweepSpec(techniques=("start", "sgc", "none"),
                     seeds=(0, 1, 2),
                     scenarios=("planetlab", "flash-crowd", "heavy-tail",
                                "fault-storm"),
                     out_dir="artifacts")
    result = run(spec)            # cells in parallel over a process pool
    result.aggregate()            # {(scenario, technique): metric -> mean/CI}

Design notes:
  * a cell = (scenario, technique, seed); each cell builds its Simulation
    from scratch inside ``run_cell`` — a pure function of the spec — so a
    parallel sweep is bitwise-equal to a serial one (modulo the wall-clock
    ``avg_overhead_s``/``wall_s`` timing fields);
  * techniques that need pretraining (start, igru-sd, wrangler) are
    pretrained once per (technique, base-config) per process with fixed
    seeds (7 train / 9 warmup, matching benchmarks) and cached as pickled
    bytes; every cell deserializes a fresh instance, so no mutable technique
    state leaks between cells;
  * workers are spawned (not forked): JAX runtimes do not survive fork.
"""
from __future__ import annotations

import csv
import dataclasses
import multiprocessing
import os
import pickle
import time

import numpy as np

from repro.sim import scenarios as S
from repro.sim.config import SimConfig
from repro.sim.engine import Simulation, Technique

QOS_KEYS = ("avg_execution_time_s", "resource_contention", "energy_kwh",
            "sla_violation_rate", "cpu_util_pct", "ram_util_pct",
            "disk_util_pct", "bw_util_pct")

#: summary fields that measure host wall-clock, not simulated behaviour —
#: excluded from determinism comparisons
TIMING_KEYS = ("avg_overhead_s",)


def deterministic_summary(summary: dict) -> dict:
    """Cell summary with host-timing fields stripped — the part that must
    be bitwise-equal between serial and parallel execution."""
    return {k: v for k, v in summary.items() if k not in TIMING_KEYS}


@dataclasses.dataclass(frozen=True)
class SweepSpec:
    """Declarative grid: techniques x seeds x scenarios (+ base sizing)."""

    techniques: tuple = ("none",)
    seeds: tuple = (0,)
    scenarios: tuple = ("planetlab",)
    n_hosts: int = 32
    n_intervals: int = 72
    arrival_rate: float = 0.6
    overrides: tuple = ()          # ((SimConfig field, value), ...) per cell
    metrics: tuple = QOS_KEYS
    max_workers: int | None = None  # None -> cpu_count; <= 1 -> serial
    out_dir: str | None = None      # write CSV artifacts here when set
    csv_prefix: str = "sweep"
    pretrain_epochs: int = 8        # START encoder-LSTM pretraining epochs
    igru_epochs: int = 40           # IGRU-SD warmup-fit epochs
    # pretrain on the scenario base config with only dimension-changing
    # overrides (n_hosts/max_tasks, see _PRETRAIN_KEYS) kept — so a sweep
    # over regime/QoS knobs (arrival_rate, reserved_utilization, ...)
    # shares one trained controller per scenario (the old benchmarks'
    # _prep behaviour). Set False to train inside every cell's exact
    # regime instead.
    shared_pretrain: bool = True

    def __post_init__(self):
        if isinstance(self.overrides, dict):  # accept the natural spelling
            object.__setattr__(self, "overrides",
                               tuple(self.overrides.items()))
        for f in ("techniques", "seeds", "scenarios", "overrides",
                  "metrics"):
            object.__setattr__(self, f, tuple(getattr(self, f)))

    def cells(self) -> list[tuple[str, str, int]]:
        return [(sc, tech, int(seed)) for sc in self.scenarios
                for tech in self.techniques for seed in self.seeds]

    #: overrides that change network dimensions — the only ones kept when
    #: building the shared pretraining config. Regime knobs (arrival_rate,
    #: n_intervals, QoS overrides) are dropped so a sweep over them (fig7)
    #: shares ONE pretrained controller per scenario, like the old _prep.
    _PRETRAIN_KEYS = ("n_hosts", "max_tasks")

    def cell_config(self, scenario: str, seed: int) -> SimConfig:
        # sizing keys in ``overrides`` replace the spec's base sizing
        # (before scenario arrival scaling) instead of colliding with the
        # explicit keyword arguments
        extra = dict(self.overrides)
        sizing = dict(
            n_hosts=extra.pop("n_hosts", self.n_hosts),
            n_intervals=extra.pop("n_intervals", self.n_intervals),
            arrival_rate=extra.pop("arrival_rate", self.arrival_rate))
        return S.make_config(scenario, seed=seed, **sizing, **extra)

    def pretrain_config(self, scenario: str, seed: int) -> SimConfig:
        """Shared-pretrain environment: scenario base + dimension
        overrides only (regime/QoS overrides stripped)."""
        extra = {k: v for k, v in dict(self.overrides).items()
                 if k in self._PRETRAIN_KEYS}
        return S.make_config(scenario, seed=seed,
                             n_hosts=extra.pop("n_hosts", self.n_hosts),
                             n_intervals=self.n_intervals,
                             arrival_rate=self.arrival_rate, **extra)


@dataclasses.dataclass
class CellResult:
    scenario: str
    technique: str
    seed: int
    summary: dict
    wall_s: float


# --------------------- technique construction (cached) ---------------------

_PRETRAINED: dict = {}   # (name, base-cfg key) -> pickled technique bytes
_WARM_SIMS: dict = {}    # base-cfg key -> completed warmup Simulation


def _base_key(cfg: SimConfig):
    return dataclasses.astuple(dataclasses.replace(cfg, seed=0))


def _warm_sim(cfg: SimConfig) -> Simulation:
    key = _base_key(cfg)
    if key not in _WARM_SIMS:
        # keep at most one completed warmup sim resident: IGRU-SD and
        # Wrangler consume the same one back-to-back per base config, and
        # a full Simulation (task table + util history) is too heavy to
        # accumulate per distinct config in a long-lived process
        _WARM_SIMS.clear()
        warm = Simulation(dataclasses.replace(cfg, seed=9))
        warm.run()
        _WARM_SIMS[key] = warm
    return _WARM_SIMS[key]


def make_technique(name: str, cfg: SimConfig, *, pretrain_cfg=None,
                   pretrain_epochs: int = 8,
                   igru_epochs: int = 40) -> Technique:
    """Fresh technique instance for one cell.

    Pretrained techniques are trained once per (name, base config) per
    process on fixed seeds (7 train / 9 warmup) and cached pickled; other
    techniques are built directly. ``pretrain_cfg`` decouples the training
    environment from the cell config (shared-pretrain sweeps). Always
    returns a NEW object — safe to bind to a Simulation.
    """
    from repro.sim.techniques import REGISTRY, make
    from repro.sim.techniques.baselines import (IGRUSD, Wrangler,
                                                pretrain_igru,
                                                pretrain_wrangler)
    from repro.sim.techniques.start_tech import START, pretrain

    if name not in REGISTRY:
        raise KeyError(f"unknown technique {name!r}; known: "
                       f"{sorted(REGISTRY)}")
    needs_pretrain = name in ("start", "igru-sd", "wrangler")
    if not needs_pretrain:
        return make(name)
    pcfg = pretrain_cfg if pretrain_cfg is not None else cfg
    # key on the epoch knob each technique actually consumes, so an
    # irrelevant knob changing doesn't evict/duplicate a trained entry
    epochs = ((pretrain_epochs,) if name == "start"
              else (igru_epochs,) if name == "igru-sd" else ())
    key = (name, _base_key(pcfg)) + epochs
    if key not in _PRETRAINED:
        if name == "start":
            ctrl = pretrain(dataclasses.replace(pcfg, seed=7),
                            epochs=pretrain_epochs, lr=1e-3)
            tech: Technique = START(controller=ctrl)
        elif name == "igru-sd":
            tech = IGRUSD()
            pretrain_igru(tech, _warm_sim(pcfg), epochs=igru_epochs)
        else:
            tech = Wrangler()
            pretrain_wrangler(tech, _warm_sim(pcfg))
        _PRETRAINED[key] = pickle.dumps(tech)
    return pickle.loads(_PRETRAINED[key])


# ------------------------------ cell runner --------------------------------

def run_cell(spec: SweepSpec, scenario: str, technique: str,
             seed: int) -> CellResult:
    """Run one (scenario, technique, seed) cell. Pure function of the spec
    (up to wall-clock timing fields) — the parallel/serial equivalence
    guarantee lives here."""
    cfg = spec.cell_config(scenario, seed)
    pcfg = None
    if spec.shared_pretrain and spec.overrides:
        pcfg = spec.pretrain_config(scenario, seed)
    tech = make_technique(technique, cfg, pretrain_cfg=pcfg,
                          pretrain_epochs=spec.pretrain_epochs,
                          igru_epochs=spec.igru_epochs)
    t0 = time.perf_counter()
    sim = Simulation(cfg, technique=tech)
    summary = sim.run()
    return CellResult(scenario=scenario, technique=technique, seed=seed,
                      summary=summary,
                      wall_s=time.perf_counter() - t0)


def _run_cell_star(args) -> CellResult:
    return run_cell(*args)


# ------------------------------- results -----------------------------------

@dataclasses.dataclass
class SweepResult:
    spec: SweepSpec
    cells: list
    wall_s: float
    n_workers: int

    def cell(self, scenario: str, technique: str, seed: int) -> CellResult:
        for c in self.cells:
            if (c.scenario, c.technique, c.seed) == (scenario, technique,
                                                     int(seed)):
                return c
        raise KeyError((scenario, technique, seed))

    def aggregate(self) -> dict:
        """{(scenario, technique): {metric: {mean, ci95, n}}} over seeds."""
        groups: dict = {}
        for c in self.cells:
            groups.setdefault((c.scenario, c.technique), []).append(
                c.summary)
        out = {}
        for key, sums in groups.items():
            stats = {}
            for m in self.spec.metrics:
                vals = np.array([s[m] for s in sums], float)
                n = len(vals)
                ci = (1.96 * vals.std(ddof=1) / np.sqrt(n)) if n > 1 else 0.0
                stats[m] = {"mean": float(vals.mean()), "ci95": float(ci),
                            "n": n}
            out[key] = stats
        return out

    # ------------------------------ artifacts ------------------------------

    def cell_rows(self) -> tuple[list, list]:
        header = ["scenario", "technique", "seed", "wall_s",
                  *self.spec.metrics]
        rows = [[c.scenario, c.technique, c.seed, round(c.wall_s, 4)]
                + [c.summary[m] for m in self.spec.metrics]
                for c in self.cells]
        return header, rows

    def agg_rows(self) -> tuple[list, list]:
        header = ["scenario", "technique", "n"]
        for m in self.spec.metrics:
            header += [f"{m}_mean", f"{m}_ci95"]
        rows = []
        for (sc, tech), stats in self.aggregate().items():
            row = [sc, tech, stats[self.spec.metrics[0]]["n"]]
            for m in self.spec.metrics:
                row += [stats[m]["mean"], stats[m]["ci95"]]
            rows.append(row)
        return header, rows

    def write_csv(self, out_dir: str | None = None) -> list[str]:
        out_dir = out_dir or self.spec.out_dir
        if out_dir is None:
            return []
        os.makedirs(out_dir, exist_ok=True)
        paths = []
        for suffix, (header, rows) in (("cells", self.cell_rows()),
                                       ("agg", self.agg_rows())):
            path = os.path.join(out_dir,
                                f"{self.spec.csv_prefix}_{suffix}.csv")
            with open(path, "w", newline="") as f:
                w = csv.writer(f)
                w.writerow(header)
                w.writerows(rows)
            paths.append(path)
        return paths


# --------------------------------- runner ----------------------------------

def run(spec: SweepSpec) -> SweepResult:
    """Execute the sweep grid; parallel over a spawned process pool unless
    ``spec.max_workers <= 1``. Cell order in the result is deterministic
    (scenario-major, as produced by ``spec.cells()``)."""
    cells = spec.cells()
    n_workers = spec.max_workers
    if n_workers is None:
        n_workers = min(len(cells), os.cpu_count() or 1)
    t0 = time.perf_counter()
    if n_workers <= 1 or len(cells) <= 1:
        results = [run_cell(spec, *c) for c in cells]
        n_workers = 1
    else:
        import concurrent.futures as cf
        ctx = multiprocessing.get_context("spawn")
        with cf.ProcessPoolExecutor(max_workers=n_workers,
                                    mp_context=ctx) as ex:
            results = list(ex.map(_run_cell_star,
                                  [(spec, *c) for c in cells]))
    res = SweepResult(spec=spec, cells=results,
                      wall_s=time.perf_counter() - t0, n_workers=n_workers)
    res.write_csv()
    return res
