"""Injectable monotonic clock with controllable skew.

Every timer on the distributed surfaces already takes a ``clock``
callable (``FabricCoordinator(clock=...)``,
``RetrainScheduler(clock=...)``), so chaos tests can make a lease
expire or a retrain period elapse *instantly* instead of sleeping
through it — and, symmetrically, freeze time so nothing expires while
a drill arranges its next failure.
"""
from __future__ import annotations

import threading
import time


class SkewClock:
    """A monotonic clock whose reading can be skewed forward or frozen.

    ``advance(s)`` adds ``s`` seconds of skew — to every component
    reading this clock it looks exactly like ``s`` seconds of silence
    passed, which is how the drills trigger lease reclaim and
    wall-clock retrains deterministically.  ``freeze()`` pins the
    reading (skew still applies) until ``thaw()``; the clock never goes
    backwards.
    """

    def __init__(self, base=time.monotonic, offset: float = 0.0):
        self._base = base
        self._offset = float(offset)
        self._frozen: float | None = None
        self._lock = threading.Lock()

    def __call__(self) -> float:
        with self._lock:
            t = self._frozen if self._frozen is not None else self._base()
            return t + self._offset

    def advance(self, seconds: float) -> None:
        """Skew the clock forward; negative skew is refused (monotonic)."""
        if seconds < 0:
            raise ValueError(f"clock must stay monotonic; got {seconds}")
        with self._lock:
            self._offset += float(seconds)

    def freeze(self) -> None:
        with self._lock:
            if self._frozen is None:
                self._frozen = self._base()

    def thaw(self) -> None:
        with self._lock:
            if self._frozen is not None:
                # keep monotonicity across the frozen window: fold the
                # time that really passed while frozen into the offset
                self._offset -= self._base() - self._frozen
                self._frozen = None
