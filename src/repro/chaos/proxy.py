"""In-process TCP chaos proxy: seeded, per-direction fault injection.

The proxy accepts client connections, dials the real upstream, and
pumps bytes both ways; every forwarded chunk first passes a
:class:`FaultPlan` which may

  * ``drop``      — discard the chunk (the peer stalls until its own
                    read timeout, then recovers by reconnecting);
  * ``delay``     — hold the chunk for a sampled interval;
  * ``duplicate`` — forward it twice (stresses idempotence: retried
                    results, replayed requests);
  * ``truncate``  — forward only the first half (desyncs the stream:
                    the next frame decode fails and forces a reconnect);
  * ``corrupt``   — flip bytes (an authenticated receiver must reject
                    the frame *before* deserializing it);
  * ``reset``     — forward half the chunk, then hard-close both sides
                    with ``SO_LINGER(0)`` so the peer sees an RST
                    mid-frame;
  * ``stall``     — a one-shot long hold (``stall_after``/``stall_s``),
                    claimed by the first stream to reach the trigger
                    chunk — how the drills make exactly one node go
                    silent past its lease.

Determinism: each (connection, direction) stream draws its decisions
from its own ``random.Random`` seeded with ``(seed, conn, direction)``,
so a stream's fault sequence replays exactly for a given seed and
connection order; ``FaultPlan.script`` pins faults to exact per-stream
chunk indexes when a test needs "reset at frame 3" rather than a rate.
Either way the proxy records the *realized* schedule — every injected
fault with its stream, chunk index and detail — and
:meth:`ChaosProxy.dump_artifact` writes it as JSON, which is what the
nightly chaos lane uploads when a drill reproduces a failure.

Fault budgets: ``max_faults`` bounds total injections across the plan
(streams created after the budget is spent pass bytes through
untouched), so a drill is guaranteed to quiesce and the system-level
invariant — grid bitwise-equal to serial, no snapshot double-applied —
can be asserted after recovery.  ``skip_first`` lets per-stream
handshakes (hello / grid shipping) through before injection starts.
"""
from __future__ import annotations

import dataclasses
import json
import random
import socket
import socketserver
import struct
import threading
import time

_FAULT_KINDS = ("drop", "delay", "duplicate", "truncate", "corrupt",
                "reset")


@dataclasses.dataclass
class FaultPlan:
    """Per-direction fault rates and scripts (shared by every stream in
    that direction; counters live on the plan, RNGs on the stream)."""

    drop: float = 0.0
    delay: float = 0.0
    duplicate: float = 0.0
    truncate: float = 0.0
    corrupt: float = 0.0
    reset: float = 0.0
    #: sampled uniformly for each injected delay
    delay_s: tuple[float, float] = (0.005, 0.05)
    #: one-shot stall: the first stream whose chunk counter reaches
    #: ``stall_after`` holds traffic for ``stall_s`` seconds (make it
    #: longer than the lease to trigger reclaim of a live node)
    stall_after: int | None = None
    stall_s: float = 0.0
    #: total injections across all streams of this plan; ``None`` =
    #: unbounded.  A bounded budget guarantees the drill quiesces.
    max_faults: int | None = None
    #: per-stream chunks passed through before any injection
    skip_first: int = 0
    #: exact schedule: {chunk_index: (kind, param)} applied before (and
    #: regardless of) the stochastic rates.  Each entry is **one-shot**
    #: and claimed by the first stream whose chunk counter reaches it —
    #: otherwise every post-reset reconnect would replay the script and
    #: a scripted ``reset`` could livelock the drill forever.
    script: dict = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        self._lock = threading.Lock()
        self._faults = 0
        self._stall_claimed = False

    def faults_injected(self) -> int:
        with self._lock:
            return self._faults

    def _charge(self) -> bool:
        """Reserve one unit of fault budget (caller holds the lock)."""
        if self.max_faults is not None and self._faults >= self.max_faults:
            return False
        self._faults += 1
        return True

    def decide(self, rng: random.Random, chunk_i: int) -> tuple:
        """The fault decision for one forwarded chunk: ``(kind, param)``
        where kind is ``"pass"`` or one of the fault kinds."""
        with self._lock:
            if chunk_i in self.script:
                kind, param = self.script.pop(chunk_i)   # one-shot
                if kind == "stall" and not self._stall_claimed:
                    self._stall_claimed = True
                self._faults += 1
                return (kind, param)
            if (self.stall_after is not None and not self._stall_claimed
                    and chunk_i >= self.stall_after):
                self._stall_claimed = True
                self._faults += 1
                return ("stall", self.stall_s)
            if chunk_i < self.skip_first:
                return ("pass", None)
            u = rng.random()
            for kind in _FAULT_KINDS:
                p = getattr(self, kind)
                if u < p:
                    if not self._charge():
                        return ("pass", None)
                    if kind == "delay":
                        return ("delay", rng.uniform(*self.delay_s))
                    if kind == "corrupt":
                        # corruption positions come from their own
                        # seeded stream so the flipped bytes replay too
                        return ("corrupt", rng.randrange(1 << 30))
                    return (kind, None)
                u -= p
            return ("pass", None)

    def summary(self) -> dict:
        return {k: getattr(self, k) for k in
                (*_FAULT_KINDS, "stall_after", "stall_s", "max_faults",
                 "skip_first")}


def _corrupted(data: bytes, seed: int) -> bytes:
    rng = random.Random(seed)
    b = bytearray(data)
    for _ in range(1 + rng.randrange(3)):
        b[rng.randrange(len(b))] ^= 0xFF
    return bytes(b)


def _hard_reset(sock: socket.socket) -> None:
    """Close with SO_LINGER(0): the peer sees an RST, not a FIN."""
    try:
        sock.setsockopt(socket.SOL_SOCKET, socket.SO_LINGER,
                        struct.pack("ii", 1, 0))
    except OSError:
        pass
    try:
        sock.close()
    except OSError:
        pass


class _Handler(socketserver.BaseRequestHandler):
    def handle(self) -> None:
        proxy: ChaosProxy = self.server.proxy          # type: ignore
        conn = proxy._next_conn()
        try:
            upstream = socket.create_connection(proxy.upstream,
                                                timeout=30.0)
        except OSError:
            return                   # upstream down: client sees EOF
        pumps = [
            threading.Thread(
                target=proxy._pump, daemon=True,
                args=(self.request, upstream, proxy.c2s,
                      conn, "c2s")),
            threading.Thread(
                target=proxy._pump, daemon=True,
                args=(upstream, self.request, proxy.s2c,
                      conn, "s2c")),
        ]
        for t in pumps:
            t.start()
        for t in pumps:
            t.join()
        for s in (upstream, self.request):
            try:
                s.close()
            except OSError:
                pass


class _Server(socketserver.ThreadingTCPServer):
    allow_reuse_address = True
    daemon_threads = True


class ChaosProxy:
    """TCP proxy injecting a seeded fault schedule between a client and
    ``upstream``; see the module docstring for semantics.

    Args:
        upstream: ``(host, port)`` of the real server.
        seed: seeds every stream's decision RNG.
        c2s / s2c: per-direction :class:`FaultPlan` (default:
            pass-through).
        host/port: proxy bind (``port=0`` picks a free one; read
            ``.port`` back and point the client at it).
    """

    def __init__(self, upstream: tuple[str, int], seed: int = 0,
                 c2s: FaultPlan | None = None,
                 s2c: FaultPlan | None = None,
                 host: str = "127.0.0.1", port: int = 0):
        self.upstream = (upstream[0], int(upstream[1]))
        self.seed = int(seed)
        self.c2s = c2s or FaultPlan()
        self.s2c = s2c or FaultPlan()
        self.events: list[dict] = []
        self._t0 = time.monotonic()
        self._lock = threading.Lock()
        self._conns = 0
        self._server = _Server((host, port), _Handler)
        self._server.proxy = self                      # type: ignore
        self.host, self.port = self._server.server_address[:2]
        self._thread = threading.Thread(
            target=self._server.serve_forever,
            kwargs={"poll_interval": 0.05}, daemon=True)

    # ------------------------------ lifecycle ---------------------------

    def start(self) -> "ChaosProxy":
        self._thread.start()
        return self

    def stop(self) -> None:
        if self._thread.is_alive():
            self._server.shutdown()
        self._server.server_close()

    def __enter__(self) -> "ChaosProxy":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    def quiesce(self) -> None:
        """Stop injecting (existing and future streams pass through) —
        drills call this before asserting post-recovery invariants."""
        for plan in (self.c2s, self.s2c):
            with plan._lock:
                plan.max_faults = plan._faults

    # ------------------------------ internals ---------------------------

    def _next_conn(self) -> int:
        with self._lock:
            self._conns += 1
            return self._conns - 1

    def _record(self, conn: int, direction: str, chunk_i: int,
                kind: str, param, n_bytes: int) -> None:
        with self._lock:
            self.events.append({
                "t": round(time.monotonic() - self._t0, 6),
                "conn": conn, "dir": direction, "chunk": chunk_i,
                "fault": kind, "param": param, "bytes": n_bytes})

    def _pump(self, src: socket.socket, dst: socket.socket,
              plan: FaultPlan, conn: int, direction: str) -> None:
        rng = random.Random(f"{self.seed}/{conn}/{direction}")
        chunk_i = 0
        try:
            while True:
                data = src.recv(65536)
                if not data:
                    break
                kind, param = plan.decide(rng, chunk_i)
                if kind != "pass":
                    self._record(conn, direction, chunk_i, kind, param,
                                 len(data))
                if kind == "drop":
                    pass
                elif kind == "delay" or kind == "stall":
                    time.sleep(float(param or 0.0))
                    dst.sendall(data)
                elif kind == "duplicate":
                    dst.sendall(data)
                    dst.sendall(data)
                elif kind == "truncate":
                    dst.sendall(data[:max(1, len(data) // 2)])
                elif kind == "corrupt":
                    dst.sendall(_corrupted(data, int(param)))
                elif kind == "reset":
                    try:
                        dst.sendall(data[:max(1, len(data) // 2)])
                    except OSError:
                        pass
                    _hard_reset(dst)
                    _hard_reset(src)
                    return
                else:
                    dst.sendall(data)
                chunk_i += 1
        except OSError:
            pass
        finally:
            # half-close so the peer's pending read sees EOF
            for s in (dst, src):
                try:
                    s.shutdown(socket.SHUT_RDWR)
                except OSError:
                    pass

    # ------------------------------ artifact ----------------------------

    def artifact(self) -> dict:
        """The realized fault schedule (JSON-serializable)."""
        with self._lock:
            return {
                "seed": self.seed,
                "upstream": list(self.upstream),
                "plans": {"c2s": self.c2s.summary(),
                          "s2c": self.s2c.summary()},
                "connections": self._conns,
                "events": list(self.events),
            }

    def dump_artifact(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.artifact(), f, indent=1)
        return path
