"""Deterministic fault injection for the distributed surfaces.

START's thesis is that distributed systems must anticipate slow and
failed components; this package lets the repo prove its *own* two
distributed stacks do — by injecting the failures on purpose, from a
seeded schedule, and asserting the system-level invariants survive:

  * :class:`~repro.chaos.proxy.ChaosProxy` — an in-process TCP proxy
    that sits between a client and an upstream server and injects
    drop / delay / duplicate / truncate / corrupt / reset-mid-frame
    faults per direction, driven by seeded per-stream RNGs (plus
    optional exact per-chunk scripts), recording the realized fault
    schedule as a JSON artifact for replay and bug reports;
  * :class:`~repro.chaos.clock.SkewClock` — an injectable monotonic
    clock with controllable skew, for driving lease expiry
    (``FabricCoordinator(clock=...)``) and wall-clock retrain timers
    (``RetrainScheduler(clock=...)``) without real sleeps.

The chaos drills in ``tests/test_chaos.py`` and the standalone driver
``benchmarks/chaos_drill.py`` use both to enforce the headline
invariants: a fabric grid stays bitwise-equal to serial under frame
corruption, mid-frame resets, a node SIGKILL and a longer-than-lease
stall; a service tenant survives a daemon kill-and-restart mid-stream
with no snapshot applied twice.
"""
from repro.chaos.clock import SkewClock
from repro.chaos.proxy import ChaosProxy, FaultPlan

__all__ = ["ChaosProxy", "FaultPlan", "SkewClock"]
