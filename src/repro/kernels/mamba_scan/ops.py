"""jit'd wrapper for the selective scan (padding + backend dispatch +
custom VJP via the oracle's recomputed backward)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.mamba_scan.mamba_scan import mamba_scan_pallas
from repro.kernels.mamba_scan.ref import mamba_scan_ref


@functools.partial(jax.custom_vjp, nondiff_argnums=(6, 7, 8))
def mamba_scan(u, delta, a, b, c, skip, block_d=128, chunk=64,
               interpret=True):
    """Public API. u, delta: (B, L, D); a: (D, N); b, c: (B, L, N)."""
    return _impl(u, delta, a, b, c, skip, block_d, chunk, interpret)


def _impl(u, delta, a, b, c, skip, block_d, chunk, interpret):
    bsz, ell, d = u.shape
    bd = min(block_d, max(8, 1 << (d - 1).bit_length()))
    cl = min(chunk, max(8, 1 << (ell - 1).bit_length()))
    pad_d = (-d) % bd
    pad_l = (-ell) % cl
    if pad_d or pad_l:
        u = jnp.pad(u, ((0, 0), (0, pad_l), (0, pad_d)))
        delta = jnp.pad(delta, ((0, 0), (0, pad_l), (0, pad_d)))
        a = jnp.pad(a, ((0, pad_d), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad_l), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad_l), (0, 0)))
        skip = jnp.pad(skip, (0, pad_d))
    out = mamba_scan_pallas(u, delta, a, b, c, skip, block_d=bd, chunk=cl,
                            interpret=interpret)
    return out[:, :ell, :d]


def _fwd(u, delta, a, b, c, skip, block_d, chunk, interpret):
    return _impl(u, delta, a, b, c, skip, block_d, chunk, interpret), \
        (u, delta, a, b, c, skip)


def _bwd(block_d, chunk, interpret, res, g):
    _, vjp = jax.vjp(mamba_scan_ref, *res)
    return vjp(g)


mamba_scan.defvjp(_fwd, _bwd)


@jax.jit
def mamba_scan_xla(u, delta, a, b, c, skip):
    """XLA (oracle) path used on non-TPU backends and in the dry-run."""
    return mamba_scan_ref(u, delta, a, b, c, skip)
