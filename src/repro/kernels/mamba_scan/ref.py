"""Pure-jnp oracle for the Mamba-1 selective scan."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def mamba_scan_ref(u: jax.Array, delta: jax.Array, a: jax.Array,
                   b: jax.Array, c: jax.Array, skip: jax.Array,
                   h0: jax.Array | None = None) -> jax.Array:
    """u, delta: (B, L, D); a: (D, N); b, c: (B, L, N); skip: (D,)."""
    bsz, ell, d = u.shape
    n = a.shape[1]
    uf = u.astype(jnp.float32)
    df = delta.astype(jnp.float32)
    af = a.astype(jnp.float32)
    bf = b.astype(jnp.float32)
    cf = c.astype(jnp.float32)

    def step(h, xs):
        u_t, dt_t, b_t, c_t = xs          # (B,D) (B,D) (B,N) (B,N)
        decay = jnp.exp(dt_t[..., None] * af[None])      # (B, D, N)
        h = decay * h + (dt_t * u_t)[..., None] * b_t[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, c_t) + skip[None] * u_t
        return h, y

    h = jnp.zeros((bsz, d, n), jnp.float32) if h0 is None else h0
    _, ys = jax.lax.scan(
        step, h,
        (uf.transpose(1, 0, 2), df.transpose(1, 0, 2),
         bf.transpose(1, 0, 2), cf.transpose(1, 0, 2)))
    return ys.transpose(1, 0, 2).astype(u.dtype)
