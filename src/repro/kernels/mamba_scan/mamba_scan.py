"""Selective-scan (Mamba-1) Pallas kernel, chunked for TPU.

Recurrence per channel d with state size N:
    h_t = exp(delta_t[d] * A[d]) * h_{t-1} + (delta_t[d] * u_t[d]) * B_t
    y_t[d] = <C_t, h_t> + D[d] * u_t[d]

TPU adaptation (the original is a CUDA kernel with warp-level scans):
  * grid = (batch, d_blocks, l_chunks); the time dimension is innermost and
    sequential — the (block_d, N) state h persists in VMEM scratch across
    chunks, so the recurrence never leaves VMEM.
  * channels are blocked to the 128-lane register width; the per-step math
    is (block_d, N) elementwise FMAs + an N-reduction, which the VPU
    vectorizes across the channel block (no MXU needed — the op is
    bandwidth-bound, so the win is VMEM residency, not systolic compute).
  * within a chunk we iterate timesteps with fori_loop + dynamic stores
    (a chunk-parallel associative scan is a further optimization documented
    in EXPERIMENTS.md).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams


def _scan_kernel(u_ref, dt_ref, a_ref, b_ref, c_ref, skip_ref, o_ref,
                 h_scr, *, chunk: int):
    il = pl.program_id(2)

    @pl.when(il == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    a = a_ref[...].astype(jnp.float32)          # (bd, N)
    skip = skip_ref[...].astype(jnp.float32)    # (1, bd)

    def step(t, h):
        # dynamic time index via pl.dslice: int indices on refs are not
        # portable across jax versions (0.4.x NDIndexer rejects them)
        row = (slice(None), pl.dslice(t, 1), slice(None))
        u_t = pl.load(u_ref, row)[0, 0].astype(jnp.float32)   # (bd,)
        dt_t = pl.load(dt_ref, row)[0, 0].astype(jnp.float32)  # (bd,)
        b_t = pl.load(b_ref, row)[0, 0].astype(jnp.float32)    # (N,)
        c_t = pl.load(c_ref, row)[0, 0].astype(jnp.float32)    # (N,)
        decay = jnp.exp(dt_t[:, None] * a)          # (bd, N)
        h = decay * h + (dt_t * u_t)[:, None] * b_t[None, :]
        y = jnp.sum(h * c_t[None, :], axis=1) + skip[0] * u_t  # (bd,)
        pl.store(o_ref, row, y[None, None].astype(o_ref.dtype))
        return h

    h_scr[...] = jax.lax.fori_loop(0, chunk, step, h_scr[...])


def mamba_scan_pallas(u: jax.Array, delta: jax.Array, a: jax.Array,
                      b: jax.Array, c: jax.Array, skip: jax.Array, *,
                      block_d: int = 128, chunk: int = 64,
                      interpret: bool = True) -> jax.Array:
    """u, delta: (B, L, D); a: (D, N); b, c: (B, L, N); skip: (D,).

    L must divide by ``chunk`` and D by ``block_d`` (ops.py pads).
    """
    bsz, ell, d = u.shape
    n = a.shape[1]
    assert ell % chunk == 0 and d % block_d == 0
    nd, nl = d // block_d, ell // chunk
    skip2 = skip.reshape(1, d)

    kernel = functools.partial(_scan_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=(bsz, nd, nl),
        in_specs=[
            pl.BlockSpec((1, chunk, block_d),
                         lambda b_, id_, il: (b_, il, id_)),
            pl.BlockSpec((1, chunk, block_d),
                         lambda b_, id_, il: (b_, il, id_)),
            pl.BlockSpec((block_d, n), lambda b_, id_, il: (id_, 0)),
            pl.BlockSpec((1, chunk, n), lambda b_, id_, il: (b_, il, 0)),
            pl.BlockSpec((1, chunk, n), lambda b_, id_, il: (b_, il, 0)),
            pl.BlockSpec((1, block_d), lambda b_, id_, il: (0, id_)),
        ],
        out_specs=pl.BlockSpec((1, chunk, block_d),
                               lambda b_, id_, il: (b_, il, id_)),
        out_shape=jax.ShapeDtypeStruct((bsz, ell, d), u.dtype),
        scratch_shapes=[pltpu.VMEM((block_d, n), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(u, delta, a, b, c, skip2)
