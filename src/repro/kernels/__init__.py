"""Pallas TPU kernels for the framework's compute hot-spots.

Each kernel subpackage ships: <name>.py (pl.pallas_call + BlockSpec VMEM
tiling), ops.py (jit'd public wrapper), ref.py (pure-jnp oracle used by the
per-kernel sweep tests and as the XLA path on non-TPU backends).
"""
