"""Pure-jnp oracle for flash attention (GQA + causal + padded-key masking).

Distribution-friendly formulation: GQA is a grouped einsum on the
(B, Hkv, G, ...) view of q — K/V are never materialized at H heads.
(jnp.repeat(k, group) forced GSPMD to reshard seq-sharded KV to
head-sharded, fully replicating the tensor: +2.1 GiB/layer collectives in
decode, see EXPERIMENTS.md §Perf iteration 2.)

``chunk_q``: queries are processed in blocks via lax.map so live score
memory is O(chunk x S) instead of O(S^2) — exact same math (each row
still sees its full softmax), 32x less temp memory at 32k prefill.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _attn_block(q: jax.Array, k: jax.Array, v: jax.Array, q_off,
                sm_scale: float, causal: bool, kv_len) -> jax.Array:
    """q: (B, Hkv, G, Sq, D); k, v: (B, Hkv, Sk, D). q_off: scalar offset
    of this query block for causal masking."""
    sq = q.shape[3]
    sk = k.shape[2]
    s = jnp.einsum("bhgqd,bhkd->bhgqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * sm_scale
    kpos = jnp.arange(sk)
    mask = (kpos < kv_len)[None, :]
    if causal:
        qpos = q_off + jnp.arange(sq)
        mask = mask & (qpos[:, None] >= kpos[None, :])
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    s = s - jax.lax.stop_gradient(s.max(-1, keepdims=True))
    p = jnp.exp(s)
    p = p / jnp.maximum(p.sum(-1, keepdims=True), 1e-30)
    return jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32))


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, sm_scale: float | None = None,
                  kv_len: int | None = None,
                  chunk_q: int | None = 2048) -> jax.Array:
    """q: (B, H, Sq, D); k, v: (B, Hkv, Sk, D). fp32 softmax,
    output q.dtype."""
    b, h, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    g = h // hkv
    sm_scale = sm_scale if sm_scale is not None else d ** -0.5
    kv_len = kv_len if kv_len is not None else sk
    qg = q.reshape(b, hkv, g, sq, d)
    if chunk_q is None or sq <= chunk_q or sq % chunk_q != 0:
        out = _attn_block(qg, k, v, 0, sm_scale, causal, kv_len)
    else:
        n = sq // chunk_q
        qc = jnp.moveaxis(
            qg.reshape(b, hkv, g, n, chunk_q, d), 3, 0)  # (n, b,hkv,g,c,d)
        offs = jnp.arange(n) * chunk_q
        fn = functools.partial(_attn_block, k=k, v=v, sm_scale=sm_scale,
                               causal=causal, kv_len=kv_len)
        # remat each chunk: without it lax.map's backward stacks every
        # chunk's (.., chunk, S) score matrix — the full S^2 again
        # (EXPERIMENTS.md §Perf iteration 4)
        body = jax.checkpoint(lambda args: fn(args[0], q_off=args[1]))
        out = jax.lax.map(body, (qc, offs))
        out = jnp.moveaxis(out, 0, 3).reshape(b, hkv, g, sq, -1)
    # v's head dim may differ from q's (MLA trains with dv != dq)
    return out.reshape(b, h, sq, -1).astype(q.dtype)
