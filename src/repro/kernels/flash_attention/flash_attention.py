"""Blocked online-softmax (flash) attention for TPU via Pallas.

TPU-native design (see DESIGN.md §6):
  * grid = (batch, q_heads, q_blocks, kv_blocks); the kv dimension is
    innermost and sequential ("arbitrary"), so the (m, l, acc) running
    softmax state lives in VMEM scratch across kv iterations — the classic
    TPU flash layout (state never round-trips to HBM).
  * BlockSpecs tile Q/K/V into (block_q|block_k, head_dim) VMEM tiles;
    head_dim and block sizes are MXU-aligned (multiples of 128 / the fp32
    (8,128) tile).
  * GQA: the K/V index_map divides the query-head index by the group size,
    so a KV block is fetched once per group and reused from VMEM.
  * Causal masking skips fully-masked kv blocks via pl.when (a production
    grid would also shrink the kv extent per q block; we keep the full grid
    and predicate, as jax's reference TPU kernel does).

Scratch (m, l) are kept (block_q, LANES)-shaped: TPU vector registers are
(8, 128) tiles, so a (block_q,) vector would be padded anyway; broadcasting
across lanes keeps every op tile-aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams

LANES = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  sm_scale: float, causal: bool, block_q: int, block_k: int,
                  kv_len: int, num_k_blocks: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # skip kv blocks entirely in the causal future of this q block
    if causal:
        run = (iq + 1) * block_q > ik * block_k
    else:
        run = True

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)          # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)          # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)          # (bk, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * sm_scale  # (bq, bk)
        kpos = ik * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        mask = kpos < kv_len                          # padded keys
        if causal:
            qpos = iq * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            mask = mask & (qpos >= kpos)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...][:, :1]                    # (bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)     # (bq, 1)
        m_next = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_next)              # (bq, 1)
        p = jnp.exp(s - m_next)                       # (bq, bk)
        p = jnp.where(mask, p, 0.0)
        l_prev = l_scr[...][:, :1]
        l_next = alpha * l_prev + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = jnp.broadcast_to(m_next, m_scr.shape)
        l_scr[...] = jnp.broadcast_to(l_next, l_scr.shape)

    @pl.when(ik == num_k_blocks - 1)
    def _finalize():
        l = l_scr[...][:, :1]
        out = acc_scr[...] / jnp.maximum(l, 1e-30)
        o_ref[0, 0] = out.astype(o_ref.dtype)


def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, sm_scale: float | None = None,
                           block_q: int = 128, block_k: int = 128,
                           kv_len: int | None = None,
                           interpret: bool = True) -> jax.Array:
    """q: (B, H, Sq, D); k, v: (B, Hkv, Sk, D) with H % Hkv == 0.

    Sq/Sk must be multiples of block_q/block_k (ops.py pads); ``kv_len``
    masks out padded keys.
    """
    b, h, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    assert h % hkv == 0, (h, hkv)
    group = h // hkv
    assert sq % block_q == 0 and sk % block_k == 0
    sm_scale = sm_scale if sm_scale is not None else d ** -0.5
    kv_len = kv_len if kv_len is not None else sk
    nq, nk = sq // block_q, sk // block_k

    kernel = functools.partial(
        _flash_kernel, sm_scale=sm_scale, causal=causal, block_q=block_q,
        block_k=block_k, kv_len=kv_len, num_k_blocks=nk)

    return pl.pallas_call(
        kernel,
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda b_, h_, iq, ik: (b_, h_, iq, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, h_, iq, ik, g=group: (b_, h_ // g, ik, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, h_, iq, ik, g=group: (b_, h_ // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda b_, h_, iq, ik: (b_, h_, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, LANES), jnp.float32),
            pltpu.VMEM((block_q, LANES), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
