"""jit'd public wrapper for flash attention.

Handles padding to block multiples, dtype plumbing, the CPU/TPU dispatch
(Pallas kernels lower only on TPU; on CPU the oracle runs under jit and XLA
fuses it), and a custom VJP so the kernel is differentiable (backward uses
the oracle's VJP with recomputation — a dedicated backward kernel is listed
as future work in DESIGN.md).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import (
    flash_attention_pallas)
from repro.kernels.flash_attention.ref import attention_ref


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    s = x.shape[axis]
    pad = (-s) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.custom_vjp,
                   nondiff_argnums=(3, 4, 5, 6, 7))
def flash_attention(q, k, v, causal=True, sm_scale=None, block_q=128,
                    block_k=128, interpret=True):
    """Public API. q: (B, H, Sq, D); k, v: (B, Hkv, Sk, D)."""
    return _fwd_impl(q, k, v, causal, sm_scale, block_q, block_k, interpret)


def _fwd_impl(q, k, v, causal, sm_scale, block_q, block_k, interpret):
    sq, sk = q.shape[2], k.shape[2]
    bq = min(block_q, max(8, 1 << (sq - 1).bit_length()))
    bk = min(block_k, max(8, 1 << (sk - 1).bit_length()))
    qp = _pad_to(q, 2, bq)
    kp = _pad_to(k, 2, bk)
    vp = _pad_to(v, 2, bk)
    out = flash_attention_pallas(
        qp, kp, vp, causal=causal, sm_scale=sm_scale, block_q=bq,
        block_k=bk, kv_len=sk, interpret=interpret)
    return out[:, :, :sq]


def _fwd(q, k, v, causal, sm_scale, block_q, block_k, interpret):
    return _fwd_impl(q, k, v, causal, sm_scale, block_q, block_k,
                     interpret), (q, k, v)


def _bwd(causal, sm_scale, block_q, block_k, interpret, res, g):
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q_, k_, v_: attention_ref(q_, k_, v_, causal=causal,
                                         sm_scale=sm_scale), q, k, v)
    return vjp(g)


flash_attention.defvjp(_fwd, _bwd)


@functools.partial(jax.jit, static_argnames=("causal", "sm_scale"))
def attention_xla(q, k, v, causal=True, sm_scale=None):
    """XLA (oracle) path used on non-TPU backends and in the dry-run."""
    return attention_ref(q, k, v, causal=causal, sm_scale=sm_scale)
