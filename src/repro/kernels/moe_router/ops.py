"""jit'd wrapper for the MoE router (padding + dispatch)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.moe_router.moe_router import moe_router_pallas
from repro.kernels.moe_router.ref import moe_router_ref


def moe_router(logits, k, block_t=256, interpret=True):
    """Public API; pads token count to the block size."""
    t = logits.shape[0]
    bt = min(block_t, max(8, 1 << (t - 1).bit_length()))
    pad = (-t) % bt
    if pad:
        logits = jnp.pad(logits, ((0, pad), (0, 0)))
    w, idx = moe_router_pallas(logits, k, block_t=bt, interpret=interpret)
    return w[:t], idx[:t]


@functools.partial(jax.jit, static_argnames=("k",))
def moe_router_xla(logits, k):
    """XLA (oracle) path used on non-TPU backends and in the dry-run."""
    return moe_router_ref(logits, k)
