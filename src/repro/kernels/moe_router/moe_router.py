"""Fused top-k softmax MoE router kernel.

Per token-block: row softmax over E experts (fp32, max-subtracted), then k
sequential argmax+mask passes selecting the top-k experts, renormalizing
the selected probabilities (Qwen3 `norm_topk_prob` semantics; DeepSeek-V3's
sigmoid+bias variant shares the same dispatch shape — see models/moe.py).

grid = (token_blocks,); block (block_t, E) fits VMEM for E <= 512 at
block_t = 256. Outputs: weights (T, k) fp32 and indices (T, k) int32 —
the int32 index matrix feeds the all-to-all dispatch in the EP runtime.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from repro.kernels._compat import CompilerParams

NEG_INF = -1e30


def _router_kernel(logits_ref, w_ref, idx_ref, *, k: int):
    x = logits_ref[...].astype(jnp.float32)           # (bt, E)
    m = jnp.max(x, axis=1, keepdims=True)
    p = jnp.exp(x - m)
    p = p / jnp.sum(p, axis=1, keepdims=True)          # softmax
    bt, e = p.shape
    cols = jax.lax.broadcasted_iota(jnp.int32, (bt, e), 1)
    masked = p
    for j in range(k):
        best = jnp.argmax(masked, axis=1).astype(jnp.int32)   # (bt,)
        wj = jnp.max(masked, axis=1)                           # (bt,)
        idx_ref[:, j] = best
        w_ref[:, j] = wj
        masked = jnp.where(cols == best[:, None], NEG_INF, masked)
    # renormalize the selected top-k weights
    total = jnp.zeros((bt,), jnp.float32)
    for j in range(k):
        total = total + w_ref[:, j]
    for j in range(k):
        w_ref[:, j] = w_ref[:, j] / jnp.maximum(total, 1e-20)


def moe_router_pallas(logits: jax.Array, k: int, *, block_t: int = 256,
                      interpret: bool = True
                      ) -> tuple[jax.Array, jax.Array]:
    """logits: (T, E) -> (weights (T, k) f32, indices (T, k) i32)."""
    t, e = logits.shape
    assert t % block_t == 0
    nt = t // block_t
    kernel = functools.partial(_router_kernel, k=k)
    return pl.pallas_call(
        kernel,
        grid=(nt,),
        in_specs=[pl.BlockSpec((block_t, e), lambda it: (it, 0))],
        out_specs=(pl.BlockSpec((block_t, k), lambda it: (it, 0)),
                   pl.BlockSpec((block_t, k), lambda it: (it, 0))),
        out_shape=(jax.ShapeDtypeStruct((t, k), jnp.float32),
                   jax.ShapeDtypeStruct((t, k), jnp.int32)),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(logits)
