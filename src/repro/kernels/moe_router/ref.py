"""Pure-jnp oracle for the top-k softmax router."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def moe_router_ref(logits: jax.Array, k: int
                   ) -> tuple[jax.Array, jax.Array]:
    """logits: (T, E) -> (weights (T, k) f32 renormalized, indices i32)."""
    p = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    w, idx = jax.lax.top_k(p, k)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-20)
    return w.astype(jnp.float32), idx.astype(jnp.int32)
