"""Version-compat shims for ``jax.experimental.pallas.tpu``.

The TPU compiler-params dataclass was renamed across jax releases
(``TPUCompilerParams`` in jax 0.4.x, ``CompilerParams`` in newer jax).
All kernels import the name from here so they run on either version.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

if hasattr(pltpu, "CompilerParams"):
    CompilerParams = pltpu.CompilerParams
else:
    CompilerParams = pltpu.TPUCompilerParams

__all__ = ["CompilerParams"]
