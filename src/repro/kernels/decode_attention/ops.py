"""jit'd wrapper for decode attention (padding + backend dispatch)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.decode_attention import (
    decode_attention_pallas)
from repro.kernels.decode_attention.ref import decode_attention_ref


def decode_attention(q, k, v, kv_len=None, sm_scale=None, block_k=512,
                     interpret=True):
    """Public API: q (B, H, D); k, v (B, Hkv, S, D). Pads S to block_k."""
    sk = k.shape[2]
    bk = min(block_k, max(128, 1 << (sk - 1).bit_length()))
    pad = (-sk) % bk
    if pad:
        widths = ((0, 0), (0, 0), (0, pad), (0, 0))
        k = jnp.pad(k, widths)
        v = jnp.pad(v, widths)
    return decode_attention_pallas(
        q, k, v, sm_scale=sm_scale, block_k=bk,
        kv_len=kv_len if kv_len is not None else sk, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("sm_scale",))
def decode_attention_xla(q, k, v, sm_scale=None):
    """XLA (oracle) path used on non-TPU backends and in the dry-run."""
    return decode_attention_ref(q, k, v, sm_scale=sm_scale)
