"""Flash-decode attention kernel: one new token vs. a long KV cache.

TPU-native layout: queries are reshaped (B, H, D) -> (B, Hkv, G, D) so each
grid cell computes a (G x block_k) score matrix on the MXU for one KV head's
whole GQA group (G = H/Hkv query heads share the KV block already resident
in VMEM). grid = (B, Hkv, kv_blocks) with the kv dimension sequential; the
online-softmax state (m, l, acc) persists in VMEM scratch across kv blocks.
This is the serving hot loop for decode_32k / long_500k shapes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams

LANES = 128
NEG_INF = -1e30


def _decode_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                   sm_scale: float, block_k: int, kv_len: int,
                   num_k_blocks: int):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)               # (G, d)
    k = k_ref[0, 0].astype(jnp.float32)               # (bk, d)
    v = v_ref[0, 0].astype(jnp.float32)               # (bk, d)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * sm_scale
    kpos = ik * block_k + jax.lax.broadcasted_iota(
        jnp.int32, s.shape, 1)
    s = jnp.where(kpos < kv_len, s, NEG_INF)

    m_prev = m_scr[...][:, :1]
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_next = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_next)
    p = jnp.exp(s - m_next)
    p = jnp.where(kpos < kv_len, p, 0.0)
    l_scr[...] = jnp.broadcast_to(
        alpha * l_scr[...][:, :1] + jnp.sum(p, axis=1, keepdims=True),
        l_scr.shape)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_scr[...] = jnp.broadcast_to(m_next, m_scr.shape)

    @pl.when(ik == num_k_blocks - 1)
    def _finalize():
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l_scr[...][:, :1], 1e-30)
                       ).astype(o_ref.dtype)


def decode_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                            sm_scale: float | None = None,
                            block_k: int = 512, kv_len: int | None = None,
                            interpret: bool = True) -> jax.Array:
    """q: (B, H, D); k, v: (B, Hkv, S, D). Returns (B, H, D)."""
    b, h, d = q.shape
    _, hkv, sk, _ = k.shape
    assert h % hkv == 0
    g = h // hkv
    assert sk % block_k == 0
    sm_scale = sm_scale if sm_scale is not None else d ** -0.5
    kv_len = kv_len if kv_len is not None else sk
    nk = sk // block_k
    qg = q.reshape(b, hkv, g, d)

    kernel = functools.partial(_decode_kernel, sm_scale=sm_scale,
                               block_k=block_k, kv_len=kv_len,
                               num_k_blocks=nk)
    out = pl.pallas_call(
        kernel,
        grid=(b, hkv, nk),
        in_specs=[
            pl.BlockSpec((1, 1, g, d), lambda b_, hk, ik: (b_, hk, 0, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, hk, ik: (b_, hk, ik, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, hk, ik: (b_, hk, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d),
                               lambda b_, hk, ik: (b_, hk, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, LANES), jnp.float32),
            pltpu.VMEM((g, LANES), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qg, k, v)
    return out.reshape(b, h, d)
