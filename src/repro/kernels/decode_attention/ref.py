"""Pure-jnp oracle for single-token decode attention.

Grouped-GQA einsum (no jnp.repeat): K/V keep their sharding (seq-parallel
flash-decode under GSPMD — the contractions over the sharded seq axis
become partial sums + a small (B, Hkv, G[, D]) all-reduce instead of a
full cache all-gather). See EXPERIMENTS.md §Perf iteration 2.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def decode_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                         sm_scale: float | None = None,
                         kv_len: int | None = None) -> jax.Array:
    """q: (B, H, Dq); k: (B, Hkv, S, Dq); v: (B, Hkv, S, Dv) -> (B, H, Dv).

    Dq may differ from Dv (MLA latent decode uses 576-d keys, 512-d
    values)."""
    b, h, dq = q.shape
    _, hkv, sk, _ = k.shape
    g = h // hkv
    sm_scale = sm_scale if sm_scale is not None else dq ** -0.5
    kv_len = kv_len if kv_len is not None else sk
    qg = q.reshape(b, hkv, g, dq)
    s = jnp.einsum("bhgd,bhkd->bhgk", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * sm_scale
    mask = jnp.arange(sk)[None, None, None, :] < kv_len
    s = jnp.where(mask, s, NEG_INF)
    s = s - jax.lax.stop_gradient(s.max(-1, keepdims=True))
    p = jnp.exp(s)
    p = p / jnp.maximum(p.sum(-1, keepdims=True), 1e-30)
    out = jnp.einsum("bhgk,bhkd->bhgd", p, v.astype(jnp.float32))
    return out.reshape(b, h, -1).astype(q.dtype)
