"""jit'd wrapper for the fused LSTM cell (batch padding + dispatch)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.lstm_cell.lstm_cell import lstm_cell_pallas
from repro.kernels.lstm_cell.ref import lstm_cell_ref


def lstm_cell(x, h, c, wx, wh, b, block_b=128, interpret=True):
    """Public API; pads batch to the block size and unpads outputs."""
    bsz = x.shape[0]
    bb = min(block_b, max(8, 1 << (bsz - 1).bit_length()))
    pad = (-bsz) % bb
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
        h = jnp.pad(h, ((0, pad), (0, 0)))
        c = jnp.pad(c, ((0, pad), (0, 0)))
    h2, c2 = lstm_cell_pallas(x, h, c, wx, wh, b, block_b=bb,
                              interpret=interpret)
    return h2[:bsz], c2[:bsz]


__all__ = ["lstm_cell", "lstm_cell_ref"]
