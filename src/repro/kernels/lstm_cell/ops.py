"""jit'd wrapper for the fused LSTM cell (batch padding + dispatch).

``lstm_cell`` is differentiable: ``pallas_call`` defines no AD rule, so
the public op carries a ``custom_vjp`` whose forward runs the fused
kernel and whose backward rematerializes the reference cell and applies
jax's own VJP to it.  Because the kernel's forward is bitwise-equal to
the reference (tested), the resulting gradients are *bitwise identical*
to differentiating the reference cell — training routed through the
Pallas cell reproduces reference training exactly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.lstm_cell.lstm_cell import lstm_cell_pallas
from repro.kernels.lstm_cell.ref import lstm_cell_ref


def _lstm_cell_fwd_impl(x, h, c, wx, wh, b, block_b=128, interpret=True):
    """Pad batch to the block size, run the fused kernel, unpad."""
    bsz = x.shape[0]
    bb = min(block_b, max(8, 1 << (bsz - 1).bit_length()))
    pad = (-bsz) % bb
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
        h = jnp.pad(h, ((0, pad), (0, 0)))
        c = jnp.pad(c, ((0, pad), (0, 0)))
    h2, c2 = lstm_cell_pallas(x, h, c, wx, wh, b, block_b=bb,
                              interpret=interpret)
    return h2[:bsz], c2[:bsz]


@jax.custom_vjp
def lstm_cell(x, h, c, wx, wh, b):
    """Public API; pads batch to the block size and unpads outputs."""
    return _lstm_cell_fwd_impl(x, h, c, wx, wh, b)


def _lstm_cell_fwd(x, h, c, wx, wh, b):
    return _lstm_cell_fwd_impl(x, h, c, wx, wh, b), (x, h, c, wx, wh, b)


def _lstm_cell_bwd(residuals, cotangents):
    # rematerialize the reference graph and use jax's own VJP of it — the
    # kernel's forward is bitwise-equal to the reference, so these are
    # exactly the gradients of the reference cell
    _, vjp = jax.vjp(lstm_cell_ref, *residuals)
    return vjp(cotangents)


lstm_cell.defvjp(_lstm_cell_fwd, _lstm_cell_bwd)


__all__ = ["lstm_cell", "lstm_cell_ref"]
