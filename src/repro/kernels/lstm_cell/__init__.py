from repro.kernels.lstm_cell.ops import lstm_cell
from repro.kernels.lstm_cell.ref import lstm_cell_ref

__all__ = ["lstm_cell", "lstm_cell_ref"]
