"""Pure-jnp oracle for the fused LSTM cell: must match
repro.core.encoder_lstm.lstm_cell_apply exactly."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def lstm_cell_ref(x, h, c, wx, wh, b):
    z = x @ wx + h @ wh + b
    i, f, g, o = jnp.split(z, 4, axis=-1)
    c_new = jax.nn.sigmoid(f) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
    h_new = jax.nn.sigmoid(o) * jnp.tanh(c_new)
    return h_new, c_new
