"""Fused Encoder-LSTM cell kernel — the paper's own compute hot-spot.

START runs Encoder-LSTM inference for EVERY active job EVERY interval
(thousands of jobs x T steps). Unfused, one LSTM cell step is ~12 XLA ops
(2 matmuls, add, bias, 4 splits, 3 sigmoids, 2 tanh, 2 FMAs) each
round-tripping HBM. This kernel fuses the whole cell for a batch block:

    z = x @ Wx + h @ Wh + b ;  i,f,g,o = split(z)
    c' = sigma(f)*c + sigma(i)*tanh(g) ;  h' = sigma(o)*tanh(c')

grid = (batch_blocks,); weights are broadcast into VMEM once per block
(index_map pins them to block 0); gate width 4H = 128 for the paper's
H = 32 — exactly one MXU tile. fp32 accumulation, I/O in input dtype.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from repro.kernels._compat import CompilerParams


def _lstm_kernel(x_ref, h_ref, c_ref, wx_ref, wh_ref, b_ref, h_out, c_out):
    x = x_ref[...].astype(jnp.float32)
    h = h_ref[...].astype(jnp.float32)
    c = c_ref[...].astype(jnp.float32)
    z = (jax.lax.dot(x, wx_ref[...].astype(jnp.float32),
                     preferred_element_type=jnp.float32)
         + jax.lax.dot(h, wh_ref[...].astype(jnp.float32),
                       preferred_element_type=jnp.float32)
         + b_ref[...].astype(jnp.float32))
    hid = h.shape[-1]
    i = jax.nn.sigmoid(z[:, :hid])
    f = jax.nn.sigmoid(z[:, hid:2 * hid])
    g = jnp.tanh(z[:, 2 * hid:3 * hid])
    o = jax.nn.sigmoid(z[:, 3 * hid:])
    c_new = f * c + i * g
    h_new = o * jnp.tanh(c_new)
    h_out[...] = h_new.astype(h_out.dtype)
    c_out[...] = c_new.astype(c_out.dtype)


def lstm_cell_pallas(x: jax.Array, h: jax.Array, c: jax.Array,
                     wx: jax.Array, wh: jax.Array, b: jax.Array, *,
                     block_b: int = 128,
                     interpret: bool = True) -> tuple[jax.Array, jax.Array]:
    """x: (B, In); h, c: (B, H); wx: (In, 4H); wh: (H, 4H); b: (4H,)."""
    bsz, n_in = x.shape
    hid = h.shape[1]
    assert wx.shape == (n_in, 4 * hid) and wh.shape == (hid, 4 * hid)
    assert bsz % block_b == 0
    nb = bsz // block_b
    b2 = b.reshape(1, 4 * hid)

    out_shape = (jax.ShapeDtypeStruct((bsz, hid), h.dtype),
                 jax.ShapeDtypeStruct((bsz, hid), c.dtype))
    h_new, c_new = pl.pallas_call(
        _lstm_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block_b, n_in), lambda ib: (ib, 0)),
            pl.BlockSpec((block_b, hid), lambda ib: (ib, 0)),
            pl.BlockSpec((block_b, hid), lambda ib: (ib, 0)),
            pl.BlockSpec((n_in, 4 * hid), lambda ib: (0, 0)),
            pl.BlockSpec((hid, 4 * hid), lambda ib: (0, 0)),
            pl.BlockSpec((1, 4 * hid), lambda ib: (0, 0)),
        ],
        out_specs=(pl.BlockSpec((block_b, hid), lambda ib: (ib, 0)),
                   pl.BlockSpec((block_b, hid), lambda ib: (ib, 0))),
        out_shape=out_shape,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(x, h, c, wx, wh, b2)
    return h_new, c_new
