"""Self-describing policy registry.

Each entry carries everything a runner needs to *use* the policy — the
factory, which substrates it supports, and (when applicable) how to
pretrain it — so runners like ``repro.sim.sweep`` dispatch generically
instead of hardcoding per-name special cases.

Registering a policy is one decorator::

    from repro import policy

    @policy.register("my-tech", description="...")
    class MyTech(policy.Policy):
        def decide(self, view):
            ...

Pretraining is declared, not special-cased: a class that implements the
:class:`~repro.policy.base.Pretrainable` protocol (a ``pretrain(ctx)``
classmethod) gets a :class:`PretrainSpec` attached automatically;
``epochs_knob`` names the sweep-spec attribute that feeds
``ctx.epochs`` (e.g. ``"pretrain_epochs"``), so different policies can
consume different training-budget knobs without the runner knowing any
of them by name.
"""
from __future__ import annotations

import dataclasses
import inspect
from typing import Any, Callable

from repro.policy.base import Policy


@dataclasses.dataclass
class PretrainContext:
    """Environment handed to ``Policy.pretrain``.

    ``config`` is the substrate configuration to train for (a
    ``SimConfig`` for simulator sweeps).  ``warmup`` lazily yields a
    finished warmup run as a ``TelemetryView`` (runners cache it so
    several policies can share one warmup).  ``epochs`` is the value of
    the entry's ``epochs_knob`` (``None`` when the entry declares no
    knob — the policy falls back to its own default).  ``kwargs`` are
    constructor keywords the runner wants the trained instance built
    with (``SweepSpec.technique_kwargs``): pretrain classmethods forward
    them — ``cls(..., **ctx.kwargs)`` — so a policy's knobs stay
    sweepable even on the pretrained path.
    """

    config: Any
    epochs: int | None = None
    warmup: Callable[[], Any] | None = None
    kwargs: dict = dataclasses.field(default_factory=dict)


@dataclasses.dataclass(frozen=True)
class PretrainSpec:
    """How to build a trained instance of a registered policy."""

    fn: Callable[[PretrainContext], Policy]
    epochs_knob: str | None = None   # runner attribute feeding ctx.epochs


@dataclasses.dataclass(frozen=True)
class PolicyEntry:
    name: str
    factory: Callable[..., Policy]
    pretrain: PretrainSpec | None = None
    substrates: tuple = ("sim",)     # which runtimes can execute it
    description: str = ""


_REGISTRY: dict[str, PolicyEntry] = {}


class UnknownPolicyError(ValueError):
    """Raised for a name no policy was registered under."""

    def __init__(self, name: str, substrate: str | None = None):
        known = sorted(n for n, e in _REGISTRY.items()
                       if substrate is None or substrate in e.substrates)
        what = f"for substrate {substrate!r} " if substrate else ""
        super().__init__(
            f"unknown technique {name!r} {what}— registered techniques: "
            f"{', '.join(known) or '(none)'}")
        self.name = name


def register(name: str, *, substrates: tuple = ("sim",),
             description: str = "",
             pretrain: Callable[[PretrainContext], Policy] | None = None,
             epochs_knob: str | None = None) -> Callable[[type], type]:
    """Class decorator: add a policy to the registry under ``name``.

    The decorated class's ``pretrain`` classmethod (the ``Pretrainable``
    protocol) is used when no explicit ``pretrain`` callable is given.
    Re-registering a name replaces the entry (latest wins), so plugins
    and tests can shadow built-ins.
    """

    def deco(cls: type) -> type:
        fn = pretrain
        if fn is None:
            fn = inspect.getattr_static(cls, "pretrain", None)
            if fn is not None:
                fn = getattr(cls, "pretrain")  # bound classmethod
        spec = (PretrainSpec(fn=fn, epochs_knob=epochs_knob)
                if fn is not None else None)
        cls.name = name
        _REGISTRY[name] = PolicyEntry(
            name=name, factory=cls, pretrain=spec,
            substrates=tuple(substrates), description=description)
        return cls

    return deco


def unregister(name: str) -> None:
    """Remove an entry (primarily for tests/plugins shadowing names)."""
    _REGISTRY.pop(name, None)


def names(substrate: str | None = None) -> list[str]:
    """Registered names, optionally filtered to one substrate."""
    return sorted(n for n, e in _REGISTRY.items()
                  if substrate is None or substrate in e.substrates)


def get(name: str) -> PolicyEntry:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise UnknownPolicyError(name) from None


def make(name: str, **kw: Any) -> Policy:
    """Instantiate a registered policy (untrained)."""
    return get(name).factory(**kw)


def validate(names_: Any, substrate: str | None = None) -> None:
    """Raise :class:`UnknownPolicyError` for the first unknown name —
    called by runners up front so a grid fails before spawning workers."""
    for n in names_:
        entry = _REGISTRY.get(n)
        if entry is None or (substrate is not None
                             and substrate not in entry.substrates):
            raise UnknownPolicyError(n, substrate)
