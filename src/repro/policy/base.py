"""Policy protocol: the decision-logic side of the seam.

A policy never touches a substrate's internals — it reads a
:class:`~repro.policy.telemetry.TelemetryView` and returns
:class:`~repro.policy.actions.Action`s.  The same policy object can then
run on the cloud simulator (``repro.sim``) or the distributed training
runtime (``repro.distributed.straggler_runtime``): one model, one API,
two substrates.
"""
from __future__ import annotations

from typing import TYPE_CHECKING, Protocol, runtime_checkable

if TYPE_CHECKING:  # pragma: no cover - typing only
    from repro.policy.actions import Action
    from repro.policy.registry import PretrainContext
    from repro.policy.telemetry import TelemetryView


class Policy:
    """Base class for straggler prediction/mitigation policies.

    Substrates call, per interval (simulator) or per step (pod runtime):

    * ``observe(view)`` — once, before any decision, with an
      ``EVENT_INTERVAL`` view: ingest telemetry, update internal models.
    * ``decide(view)`` — at every decision point (the simulator also
      publishes an ``EVENT_SUBMIT`` view right after arrivals): return
      mitigation actions.  Policies that only act at one decision point
      filter on ``view.event``.
    """

    name = "policy"

    #: Set False on policies that never act at submit time: the simulator
    #: then skips building the EVENT_SUBMIT view (and the decide call)
    #: entirely — the view is pure and an ignoring decide() is pure, so
    #: skipping is behavior-preserving and saves per-interval overhead.
    submit_hook = True

    def observe(self, view: "TelemetryView") -> None:
        """Ingest one interval/step of telemetry."""

    def decide(self, view: "TelemetryView") -> "list[Action]":
        """Return mitigation actions for this decision point."""
        return []

    def predicted_straggler_count(self) -> float | None:
        """Latest predicted straggler count, for MAPE accounting (Fig 9);
        ``None`` when the policy does not predict."""
        return None

    def forget_tasks(self, task_ids) -> None:
        """Substrate signal: these task ids no longer refer to the work
        previously observed — drop any per-task state (histories,
        once-only mitigation flags).  The simulator never reuses ids, so
        it never calls this; the pod runtime reuses one id per host each
        horizon window and calls it at every window boundary."""


@runtime_checkable
class Pretrainable(Protocol):
    """Optional protocol: policies that need offline pretraining.

    A class implementing ``pretrain`` (normally a classmethod) is picked
    up automatically by :func:`repro.policy.registry.register`, and sweep
    runners call it through the registry entry — no per-name dispatch
    anywhere.
    """

    @classmethod
    def pretrain(cls, ctx: "PretrainContext") -> "Policy":
        """Build a trained policy instance for ``ctx.config``."""
        ...
