"""Unified policy API: one decision seam, two execution substrates.

Straggler techniques are *policies*: they read a frozen
:class:`TelemetryView` snapshot (tasks, hosts, jobs, clocks — never
engine internals) and emit :class:`Action`s from one shared vocabulary.
The cloud simulator (``repro.sim``) and the distributed training runtime
(``repro.distributed.straggler_runtime``) both publish views and execute
actions, so a technique written once runs on either substrate.

Worked example — a complete, sweep-ready technique in ~25 lines::

    import numpy as np
    from repro import policy

    @policy.register(
        "slow-host-clone",
        description="clone tasks stuck on hosts below median speed")
    class SlowHostClone(policy.Policy):
        def decide(self, view):
            if view.event != policy.EVENT_INTERVAL:
                return []          # act once per interval, not at submit
            eff = view.hosts.effective_speed()
            slow = eff < np.median(eff[view.hosts.online()])
            acts = []
            for i in np.nonzero(view.tasks.active_mask())[0][:8]:
                if slow[view.tasks.host[i]] and not view.tasks.is_copy[i]:
                    acts.append(policy.Action(
                        policy.ActionKind.SPECULATE, task=int(i),
                        target=int(np.argmax(eff))))
            return acts

    # the registry makes it a first-class technique everywhere:
    from repro.sim import sweep
    res = sweep.run(sweep.SweepSpec(
        techniques=("none", "slow-host-clone"), seeds=(0, 1),
        scenarios=("planetlab", "heavy-tail")))

Policies that need offline training implement the
:class:`Pretrainable` protocol — a ``pretrain(ctx)`` classmethod —
and the registry entry carries it, so sweep runners pretrain (and cache
per process) without knowing any technique by name.  Forward
``ctx.kwargs`` to the constructor: that is how per-technique sweep
knobs (``SweepSpec.technique_kwargs``) reach a pretrained instance —
a classmethod that drops them silently pins the policy to its
defaults for every sweep cell::

    @policy.register("learned", epochs_knob="pretrain_epochs")
    class Learned(policy.Policy):
        def __init__(self, model=None, threshold=0.5):
            self.model = model
            self.threshold = threshold

        @classmethod
        def pretrain(cls, ctx):
            warm = ctx.warmup()          # finished warmup TelemetryView
            model = fit(warm.completed_jobs, epochs=ctx.epochs or 10)
            return cls(model=model, **ctx.kwargs)
"""
from repro.policy.actions import (Action, ActionKind, HOST_KINDS,
                                  TASK_KINDS, host_action)
from repro.policy.base import Policy, Pretrainable
from repro.policy import registry
from repro.policy.registry import (PolicyEntry, PretrainContext,
                                   PretrainSpec, UnknownPolicyError,
                                   get, make, names, register,
                                   unregister, validate)
from repro.policy.telemetry import (CANCELLED, DONE, EVENT_INTERVAL,
                                    EVENT_SUBMIT, PENDING, RUNNING,
                                    HostTelemetry, JobTelemetry,
                                    TaskTelemetry, TelemetryView,
                                    effective_speed, readonly)

__all__ = [
    "Action", "ActionKind", "HOST_KINDS", "TASK_KINDS", "host_action",
    "Policy", "Pretrainable",
    "PolicyEntry", "PretrainContext", "PretrainSpec",
    "UnknownPolicyError", "get", "make", "names", "register",
    "unregister", "validate", "registry",
    "PENDING", "RUNNING", "DONE", "CANCELLED",
    "EVENT_SUBMIT", "EVENT_INTERVAL",
    "TaskTelemetry", "HostTelemetry", "JobTelemetry", "TelemetryView",
    "effective_speed", "readonly",
]
