"""Unified mitigation-action vocabulary (one grammar, two substrates).

The cloud simulator historically spoke ``SimAction`` (speculate / rerun /
clone / delay on *tasks*) and the distributed training runtime spoke
``HostAction`` (backup-shard / evict on *hosts*).  ``Action`` merges both:
a policy emits one vocabulary and each substrate executes the kinds it
understands (the pod runtime additionally *translates* task kinds — a
speculate on host h's shard becomes a backup shard, a rerun becomes an
eviction; see ``repro.distributed.straggler_runtime``).

``ActionKind`` is a str-enum so existing code comparing ``act.kind`` to
plain strings ("speculate", "rerun", ...) keeps working unchanged.
"""
from __future__ import annotations

import dataclasses
import enum


class ActionKind(str, enum.Enum):
    """Every mitigation verb either substrate can execute."""

    # task-level verbs (cloud simulator semantics)
    SPECULATE = "speculate"      # run a copy, first result wins
    RERUN = "rerun"              # kill and restart on a new node
    CLONE = "clone"              # proactive upfront copies
    DELAY = "delay"              # hold a pending task back
    # host-level verbs (distributed training-pod semantics)
    BACKUP_SHARD = "backup_shard"  # a healthy host also computes the shard
    EVICT = "evict"                # drop the host and remesh

    def __str__(self) -> str:  # log-friendly ("speculate", not the repr)
        return self.value


#: kinds the cloud simulator executes directly
TASK_KINDS = frozenset((ActionKind.SPECULATE, ActionKind.RERUN,
                        ActionKind.CLONE, ActionKind.DELAY))
#: kinds the distributed runtime executes directly
HOST_KINDS = frozenset((ActionKind.BACKUP_SHARD, ActionKind.EVICT))


@dataclasses.dataclass(frozen=True)
class Action:
    """One mitigation decision.

    ``task``/``target``/``delay``/``n_clones`` carry the task-level verbs;
    ``host`` (with ``target`` as the backup host) carries the host-level
    verbs.  ``kind`` may be an :class:`ActionKind` or its string value.
    """

    kind: ActionKind | str
    task: int = -1               # task id (simulator vocabulary)
    target: int | None = None    # target / backup host
    delay: int = 1               # intervals to hold a DELAY'd task
    n_clones: int = 1            # copies for CLONE
    host: int = -1               # host id (distributed vocabulary)

    @property
    def backup(self) -> int | None:
        """Distributed-runtime spelling of ``target``."""
        return self.target


def host_action(kind: ActionKind, host: int,
                backup: int | None = None) -> Action:
    """Build a host-level action (the old ``HostAction`` constructor)."""
    return Action(kind=kind, host=host, target=backup)
