"""Service-facing serialization of the policy vocabulary.

The prediction service (``repro.service``) speaks JSON-lines over TCP;
its responses carry :class:`~repro.policy.actions.Action`s and its
requests carry telemetry snapshots.  This module owns the mapping
between those dataclasses and plain JSON-safe dicts, so the wire format
lives next to the vocabulary it encodes (a new ``ActionKind`` is a
one-file change) and both substrates — the cloud simulator and the pod
runtime acting as a service client — serialize identically.
"""
from __future__ import annotations

import numpy as np

from repro.policy.actions import Action, ActionKind

#: wire fields, in the order they are emitted (defaults omitted)
_ACTION_FIELDS = ("task", "target", "delay", "n_clones", "host")
_ACTION_DEFAULTS = {"task": -1, "target": None, "delay": 1,
                    "n_clones": 1, "host": -1}


def action_to_wire(action: Action) -> dict:
    """``Action`` -> JSON-safe dict; default-valued fields are omitted
    so the common speculate/rerun messages stay one-line small."""
    out: dict = {"kind": str(ActionKind(action.kind))}
    for f in _ACTION_FIELDS:
        v = getattr(action, f)
        if v != _ACTION_DEFAULTS[f]:
            out[f] = int(v) if v is not None else None
    return out


def action_from_wire(obj: dict) -> Action:
    """Inverse of :func:`action_to_wire`; unknown keys are rejected so a
    version-skewed peer fails loudly instead of silently dropping
    semantics."""
    extra = set(obj) - {"kind", *_ACTION_FIELDS}
    if extra:
        raise ValueError(f"unknown Action wire fields {sorted(extra)}")
    kw = {f: obj.get(f, _ACTION_DEFAULTS[f]) for f in _ACTION_FIELDS}
    return Action(kind=ActionKind(obj["kind"]), **kw)


def job_to_wire(job_id: int, q: int, m_t: np.ndarray,
                open_count: int | None = None, deadline: bool = False,
                tasks: list[tuple[int, int, int]] | None = None) -> dict:
    """One job entry of a telemetry snapshot.

    Args:
        job_id: tenant-scoped job identifier.
        q: true task count (1..max_tasks).
        m_t: (max_tasks, TASK_FEATURES) task matrix (padded rows zero).
        open_count: incomplete original tasks (defaults to ``q``).
        tasks: per open task ``(task_id, host, slot)`` — ``slot`` is the
            task's row in ``m_t``; required for the service to emit
            mitigation actions, optional for predict-only use.
    """
    out = {
        "id": int(job_id), "q": int(q),
        "m_t": np.asarray(m_t, np.float32).reshape(-1).tolist(),
        "open": int(q if open_count is None else open_count),
        "deadline": bool(deadline),
    }
    if tasks is not None:
        out["tasks"] = [[int(t), int(h), int(s)] for t, h, s in tasks]
    return out


def snapshot_to_wire(tenant: str, seq: int, m_h: np.ndarray,
                     jobs: list[dict] | None = None,
                     done: list[dict] | None = None) -> dict:
    """One per-interval telemetry snapshot request.

    Args:
        m_h: (n_hosts, HOST_FEATURES) current host matrix.
        jobs: entries from :func:`job_to_wire`.
        done: completed-job records ``{"id": job_id, "times": [...]}``
            feeding the service's continuous-retraining buffer.
    """
    return {
        "op": "snapshot", "tenant": str(tenant), "seq": int(seq),
        "m_h": np.asarray(m_h, np.float32).reshape(-1).tolist(),
        "jobs": list(jobs or ()),
        "done": [{"id": int(d["id"]),
                  "times": [float(x) for x in d["times"]]}
                 for d in (done or ())],
    }
