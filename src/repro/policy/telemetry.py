"""Frozen telemetry snapshots — the only cluster state policies may read.

A substrate (the cloud simulator, the distributed training runtime)
publishes a :class:`TelemetryView` at every decision point; policies
consume the view and emit :class:`~repro.policy.actions.Action`s.  Views
are built **zero-copy**: every array field is a read-only numpy view onto
the substrate's live buffers, so taking a snapshot costs a few dataclass
allocations, never an O(tasks) copy.  A view is therefore only valid for
the duration of the hook call it was passed to — policies that need
history must copy what they keep (`.copy()` re-enables writing).

Task-state constants live here (not in the engine) so policies can test
``view.tasks.state == RUNNING`` without importing simulator internals.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Mapping, Sequence

import numpy as np

# task lifecycle states (shared by the engine's TaskTable and every view)
PENDING, RUNNING, DONE, CANCELLED = 0, 1, 2, 3

#: submit-time decision point (new tasks just arrived, none placed yet)
EVENT_SUBMIT = "submit"
#: interval decision point (faults applied, placements done, pre-progress)
EVENT_INTERVAL = "interval"


def readonly(a: np.ndarray) -> np.ndarray:
    """Read-only view of ``a`` (zero-copy; the base stays writable)."""
    v = a.view()
    v.flags.writeable = False
    return v


def effective_speed(util: np.ndarray, speed: np.ndarray,
                    online: np.ndarray) -> np.ndarray:
    """Per-host progress rate from utilization: base speed degraded by
    (a) CPU overload (processor sharing: capacity share = 1/overload) and
    (b) interference once any resource runs hot (>70% — cache/IO
    contention), zero while the host is down.  Shared by the simulator's
    ``Cluster`` and every :class:`HostTelemetry` so both substrates agree
    on what "effective speed" means."""
    over = np.maximum(util[:, 0], 1.0)
    hot = np.clip((util.max(axis=1) - 0.7) / 0.3, 0.0, 1.0)
    interference = 1.0 - 0.4 * hot
    return np.where(online, speed * interference / over, 0.0)


@dataclasses.dataclass(frozen=True)
class TaskTelemetry:
    """Struct-of-arrays snapshot of every task the substrate tracks.

    All arrays have length ``n`` and are read-only views; ``req`` is
    ``(n, 4)`` normalized resource requirements (cpu/ram/disk/bw).
    """

    n: int
    job_id: np.ndarray
    state: np.ndarray
    host: np.ndarray            # -1 while unplaced
    work: np.ndarray            # MI (sim) / normalized work units (pod)
    progress: np.ndarray
    submit_s: np.ndarray
    start_s: np.ndarray
    finish_s: np.ndarray        # -1 until done
    deadline_s: np.ndarray      # relative to submit
    is_deadline: np.ndarray
    sla_weight: np.ndarray
    restarts: np.ndarray
    is_copy: np.ndarray
    orig: np.ndarray            # original task id for copies, else -1
    delayed_until: np.ndarray   # interval index a DELAY holds until
    prev_host: np.ndarray       # host before the last restart/bounce, -1
    req: np.ndarray

    def active_mask(self) -> np.ndarray:
        return self.state == RUNNING

    def originals_mask(self) -> np.ndarray:
        return ~self.is_copy


@dataclasses.dataclass(frozen=True)
class HostTelemetry:
    """Per-host capacity and load counters (read-only views)."""

    util: np.ndarray            # (n_hosts, 4) fraction of capacity
    speed: np.ndarray           # relative CPU capacity
    cap: np.ndarray             # (n_hosts, 4) absolute capacities
    cost: np.ndarray
    power_max: np.ndarray
    power_min: np.ndarray
    n_tasks: np.ndarray
    downtime: np.ndarray        # intervals of outage remaining (0 = up)
    ips: np.ndarray             # MI/s per unit speed

    def online(self) -> np.ndarray:
        return self.downtime == 0

    def effective_speed(self) -> np.ndarray:
        return effective_speed(self.util, self.speed, self.online())


@dataclasses.dataclass(frozen=True)
class JobTelemetry:
    """CSR job -> task index plus per-job flags.

    Jobs are dense integer ids; job ``j``'s original tasks occupy the
    contiguous task-id range ``[start[j], start[j] + count[j])`` (the
    substrate appends whole jobs in submission order, and speculative
    copies are tracked separately).  Every field is an array indexed by
    job id, so ``active()`` and per-job lookups are O(1) array slices,
    never per-interval Python scans over a dict.
    """

    start: np.ndarray        # (n_jobs,) first original-task id
    count: np.ndarray        # (n_jobs,) original-task count (the paper's q)
    open_count: np.ndarray   # (n_jobs,) non-terminal original count
    done: np.ndarray         # (n_jobs,) bool: fully accounted
    deadline: np.ndarray     # (n_jobs,) bool: deadline-oriented?
    _state: np.ndarray       # task state array (shared with tasks)

    @property
    def n_jobs(self) -> int:
        return len(self.start)

    def task_ids(self, job: int) -> np.ndarray:
        """Original-task ids of ``job`` (contiguous CSR range)."""
        s = int(self.start[job])
        return np.arange(s, s + int(self.count[job]), dtype=np.int64)

    def active(self) -> np.ndarray:
        """Jobs with at least one non-terminal original task."""
        return np.nonzero((self.open_count > 0) & ~self.done)[0]

    def incomplete_tasks(self, job: int) -> np.ndarray:
        t = self.task_ids(job)
        # PENDING/RUNNING are the two non-terminal states (0 and 1)
        return t[self._state[t] <= RUNNING]


@dataclasses.dataclass(frozen=True)
class TelemetryView:
    """Everything a policy may observe, at one decision point.

    ``event`` distinguishes the simulator's two decision points
    (:data:`EVENT_SUBMIT` with ``new_tasks`` populated, and
    :data:`EVENT_INTERVAL`); the distributed runtime publishes one
    :data:`EVENT_INTERVAL` view per training step.  ``config`` is the
    substrate's (frozen-by-convention) configuration object —
    ``SimConfig`` for the simulator, ``RuntimeConfig`` for the pod.

    ``rng`` is the substrate's *live* generator: randomized policies draw
    from the same stream the engine uses, which is what keeps a sweep
    cell a pure function of its spec.

    ``extra`` carries substrate-specific telemetry (e.g. the pod
    runtime's raw per-step times); portable policies should not rely on
    its contents.
    """

    event: str
    t: int                         # interval / step index
    now_s: float
    interval_seconds: float
    config: Any
    tasks: TaskTelemetry
    hosts: HostTelemetry
    jobs: JobTelemetry
    new_tasks: np.ndarray          # task ids submitted this event
    straggler_ma: np.ndarray       # per-host straggler moving average
    completed_jobs: Sequence[Mapping]  # ground-truth job records
    util_history: Sequence[np.ndarray]
    rng: np.random.Generator | None = None
    extra: Mapping[str, Any] = dataclasses.field(default_factory=dict)

    # convenience passthroughs (the fields policies reach for constantly)

    @property
    def n_hosts(self) -> int:
        return len(self.hosts.speed)

    @property
    def host_ips_mean(self) -> float:
        return float(self.config.host_ips_mean)


def make_task_telemetry(n: int, fields: Callable[[str], np.ndarray],
                        req: np.ndarray) -> TaskTelemetry:
    """Assemble a :class:`TaskTelemetry` from a field accessor (the
    engine passes its TaskTable's ``view``), wrapping each array
    read-only."""
    return TaskTelemetry(
        n=n, req=readonly(req),
        **{f: readonly(fields(f)) for f in (
            "job_id", "state", "host", "work", "progress", "submit_s",
            "start_s", "finish_s", "deadline_s", "is_deadline",
            "sla_weight", "restarts", "is_copy", "orig", "delayed_until",
            "prev_host")})
