"""repro — START (Tuli et al. 2021) straggler prediction/mitigation,
reproduced faithfully and integrated as a first-class service of a
multi-pod JAX training/serving framework."""

__version__ = "0.1.0"
