"""Multi-tenant prediction service over the fused START decision step.

One service process serves one :class:`~repro.service.protocol.Profile`
(one compiled program family) to many tenants.  Every tenant gets its
own :class:`~repro.core.predictor.StragglerPredictor` — the per-tenant
state (M_H device ring, host history, trigger streaks) is cheap — but
all of them share ONE ``params`` pytree by reference, so a promotion
swaps the serving model for every tenant with a single assignment under
the service lock and the device holds one copy of the weights.

Dispatch per batch tick:

  * exactly one tenant queued -> that tenant's fused
    ``predict_interval`` path, bitwise-equal to calling the predictor
    in-process (the acceptance criterion);
  * several tenants queued -> one combined
    ``StragglerPredictor.predict_tenants`` dispatch: per-tenant host
    blocks, all jobs coalesced into one power-of-two bucket, zero warm
    retraces because every bucket/shape was compiled by the first tick
    that used it.

Backpressure is shed-oldest per tenant: each tenant may hold at most
``queue_depth`` unanswered snapshots; the oldest is resolved with an
``overload`` error to make room.  Admission control rejects tenants
past ``max_tenants`` or with an incompatible profile.

Degraded mode (serving model failed to load): answers fall back to the
jitted ``_pareto_tail`` over an MLE Pareto fit of the tenant's own
recently completed durations — no Encoder-LSTM, but still a live E_S
estimate — and carry ``"degraded": true``.
"""
from __future__ import annotations

import dataclasses
import hmac
import threading
from collections import deque

import numpy as np

from repro.core.pareto import fit_pareto_np
from repro.core.predictor import StragglerPredictor, _pareto_tail, \
    bucket_size
from repro.core.start import STARTController
from repro.policy.actions import Action, ActionKind
from repro.policy.wire import action_to_wire
from repro.service import retrain as rt
from repro.service.protocol import Profile, error
from repro.service.sanitize import TelemetryError, sanitize_snapshot
from repro.train.checkpoint import VersionStore


@dataclasses.dataclass
class ServiceConfig:
    profile: Profile
    max_tenants: int = 16
    queue_depth: int = 4         # unanswered snapshots per tenant
    max_batch: int = 64          # tenants coalesced per tick
    sanitize: str = "clamp"      # "clamp" | "reject"
    ckpt_dir: str | None = None  # VersionStore root (None = in-memory)
    buffer_cap: int = 4096       # replay-buffer pairs
    eval_holdback: int = 32      # newest pairs held back for shadow eval
    min_train_pairs: int = 64    # don't retrain below this
    promote_tol: float = 1.05    # candidate MSE <= tol * champion MSE
    train_epochs: int = 20
    train_lr: float = 1e-4
    retrain_every: int = 0       # snapshots between auto retrains (0=off)
    #: cron-style wall-clock retrain period in seconds (0 = off): the
    #: daemon's scheduler thread flags a retrain due every
    #: ``retrain_interval_s`` of *monotonic* time even when snapshot
    #: volume alone would never reach ``retrain_every`` — slow tenants
    #: still get periodically refreshed models.  Missed periods (e.g. a
    #: long fit) coalesce into one firing, never a backlog burst.
    retrain_interval_s: float = 0.0
    #: shared-secret admission token (None = open).  When set, a
    #: ``hello`` must carry ``token`` equal to it or admission fails
    #: with ``auth-failed`` — the JSON-lines mirror of the fabric's
    #: ``REPRO_FABRIC_KEY`` frame auth.  The daemon defaults this from
    #: ``REPRO_SERVICE_TOKEN``.
    auth_token: str | None = None
    seed: int = 0
    use_pallas: bool = False


class Pending:
    """One queued snapshot awaiting its batch tick."""

    __slots__ = ("tenant", "snap", "event", "result")

    def __init__(self, tenant: str, snap: dict):
        self.tenant = tenant
        self.snap = snap
        self.event = threading.Event()
        self.result: dict | None = None

    def resolve(self, result: dict) -> None:
        self.result = result
        self.event.set()


class TenantState:
    def __init__(self, name: str, cfg: ServiceConfig, params) -> None:
        p = cfg.profile
        self.name = name
        self.predictor = StragglerPredictor(
            n_hosts=p.n_hosts, max_tasks=p.max_tasks, k=p.k,
            horizon=p.horizon, beta_scale=p.beta_scale, seed=cfg.seed,
            use_pallas_cell=cfg.use_pallas)
        self.predictor.params = params      # shared serving pytree
        self.controller = STARTController(
            p.n_hosts, p.max_tasks, trigger=p.trigger,
            score_on=p.score_on, hysteresis=p.hysteresis,
            cooldown=p.cooldown, predictor=self.predictor)
        self.last_seq = float("-inf")
        #: ``(seq, answer)`` of the last resolved snapshot: a client
        #: that lost the connection mid-reply resends the same seq and
        #: gets this cached answer back instead of a second application
        self.last_answer: tuple[float, dict] | None = None
        self.mt_cache: dict[int, np.ndarray] = {}  # job -> true M_T rows
        self.durations: deque = deque(maxlen=512)  # degraded-mode MLE
        self.snapshots = 0
        self.shed = 0


def _mit_to_wire(act) -> dict:
    """``repro.core.mitigation.Action`` -> policy wire dict."""
    return action_to_wire(Action(
        kind=ActionKind(act.kind.value), task=int(act.task_id),
        target=int(act.target_host), host=int(act.source_host)))


class PredictionService:
    """The in-process serving core; transports live in ``daemon``."""

    def __init__(self, cfg: ServiceConfig):
        self.cfg = cfg
        self.profile = cfg.profile
        self.lock = threading.RLock()
        self.tenants: dict[str, TenantState] = {}
        self.pending: deque[Pending] = deque()
        self.buffer = rt.ReplayBuffer(cfg.buffer_cap, cfg.eval_holdback)
        self.model = StragglerPredictor(
            n_hosts=cfg.profile.n_hosts, max_tasks=cfg.profile.max_tasks,
            k=cfg.profile.k, horizon=cfg.profile.horizon,
            beta_scale=cfg.profile.beta_scale, seed=cfg.seed,
            use_pallas_cell=cfg.use_pallas)
        self.params = self.model.params
        self.model_version = 0
        self.degraded = False
        self._prev: list[tuple[int, object]] = []  # in-memory history
        self._retrain_due = False
        self._since_retrain = 0
        self.stats_counters = {
            "snapshots": 0, "ticks": 0, "batch_rows": 0, "sheds": 0,
            "rejected": 0, "degraded_answers": 0, "retrains": 0,
            "promotions": 0, "rollbacks": 0, "candidates_rejected": 0,
            "retrain_failures": 0, "resends": 0, "auth_failures": 0,
        }
        self.last_retrain_error: str | None = None
        self.store = None
        if cfg.ckpt_dir:
            self.store = VersionStore(cfg.ckpt_dir)
            cur = self.store.current()
            if cur is None:
                self.store.save_version(0, self.params)
                self.store.promote(0)
            else:
                self.load_current()

    # ------------------------------ model lifecycle --------------------

    def load_current(self) -> bool:
        """(Re)load the promoted version; on failure enter degraded mode
        (the champion keeps its last good params if it ever had any)."""
        try:
            cur = self.store.current()
            if cur is None:
                raise FileNotFoundError("no promoted version")
            params = self.store.load_version(cur, self.params)
            with self.lock:
                self._install(params, cur)
                self.degraded = False
            return True
        except Exception:
            self.degraded = True
            return False

    def _install(self, params, version: int) -> None:
        """Swap the shared serving pytree (callers hold the lock)."""
        self.params = params
        self.model.params = params
        for t in self.tenants.values():
            t.predictor.params = params
        self.model_version = version

    def retrain_now(self) -> dict:
        """One retrain -> shadow-eval -> promote/reject cycle.

        The fit runs OUTSIDE the service lock (ticks keep answering on
        the champion); only the final install takes it.
        """
        with self.lock:
            if len(self.buffer) < self.cfg.min_train_pairs:
                return {"ok": True, "promoted": False,
                        "reason": f"only {len(self.buffer)} pairs "
                                  f"(< {self.cfg.min_train_pairs})"}
            (tx, ty), (ex, ey) = self.buffer.split()
            if tx.shape[1] == 0:
                return {"ok": True, "promoted": False,
                        "reason": "all pairs inside the eval holdback"}
            champion = self.params
            version = self.model_version
            self._retrain_due = False
            self._since_retrain = 0
        self.stats_counters["retrains"] += 1
        cand, losses = rt.fit_candidate(
            self.model, tx, ty, epochs=self.cfg.train_epochs,
            lr=self.cfg.train_lr)
        champ_loss = rt.shadow_loss(champion, ex, ey,
                                    use_pallas=self.cfg.use_pallas)
        cand_loss = rt.shadow_loss(cand, ex, ey,
                                   use_pallas=self.cfg.use_pallas)
        report = {"ok": True, "train_pairs": int(tx.shape[1]),
                  "eval_pairs": int(ex.shape[1]),
                  "champion_loss": champ_loss,
                  "candidate_loss": cand_loss,
                  "final_train_loss": losses[-1] if losses else None}
        if not rt.should_promote(cand_loss, champ_loss,
                                 self.cfg.promote_tol):
            self.stats_counters["candidates_rejected"] += 1
            report.update(promoted=False, version=version,
                          reason="shadow eval: candidate worse than "
                                 "champion")
            return report
        new_version = version + 1
        if self.store is not None:
            self.store.save_version(new_version, cand)
            self.store.promote(new_version)
        with self.lock:
            self._prev.append((self.model_version, self.params))
            self._install(cand, new_version)
            self.degraded = False
        self.stats_counters["promotions"] += 1
        report.update(promoted=True, version=new_version)
        return report

    def note_retrain_failure(self, exc: BaseException) -> None:
        """Record a retrain cycle that raised: a poisoned replay buffer
        (or any fit/eval crash) used to clear ``_retrain_due`` and
        vanish without a trace — now it shows up in ``stats()`` as
        ``retrain_failures`` + ``last_retrain_error`` while the
        retrainer thread keeps running."""
        with self.lock:
            self.stats_counters["retrain_failures"] += 1
            self.last_retrain_error = f"{type(exc).__name__}: {exc}"
            self._retrain_due = False

    def rollback_now(self) -> dict:
        """Instant rollback to the previous promoted version."""
        with self.lock:
            if self.store is not None:
                prev = self.store.rollback()
                if prev is None:
                    return error("no-history", "nothing to roll back to")
                params = self.store.load_version(prev, self.params)
                self._install(params, prev)
            else:
                if not self._prev:
                    return error("no-history", "nothing to roll back to")
                prev, params = self._prev.pop()
                self._install(params, prev)
            self.degraded = False
            self.stats_counters["rollbacks"] += 1
            return {"ok": True, "version": prev}

    # ------------------------------ admission --------------------------

    def hello(self, tenant: str, profile_wire: dict,
              token: str | None = None) -> dict:
        if self.cfg.auth_token is not None:
            if not (isinstance(token, str) and hmac.compare_digest(
                    token, self.cfg.auth_token)):
                self.stats_counters["auth_failures"] += 1
                return error("auth-failed",
                             "missing or wrong admission token")
        try:
            prof = Profile.from_wire(profile_wire)
        except (TypeError, ValueError) as e:
            return error("bad-profile", str(e))
        with self.lock:
            if tenant in self.tenants:
                return {"ok": True, "tenant": tenant, "rejoined": True,
                        "version": self.model_version}
            if not self.profile.compatible(prof):
                return error(
                    "incompatible-profile",
                    f"service profile {self.profile.to_wire()} != "
                    f"tenant profile {prof.to_wire()}")
            if len(self.tenants) >= self.cfg.max_tenants:
                return error("at-capacity",
                             f"max_tenants={self.cfg.max_tenants}")
            self.tenants[tenant] = TenantState(tenant, self.cfg,
                                               self.params)
            return {"ok": True, "tenant": tenant, "rejoined": False,
                    "version": self.model_version}

    def bye(self, tenant: str) -> dict:
        with self.lock:
            t = self.tenants.pop(tenant, None)
            for p in [p for p in self.pending if p.tenant == tenant]:
                self.pending.remove(p)
                p.resolve(error("gone", "tenant said bye"))
            return {"ok": True, "dropped": t is not None}

    # ------------------------------ ingest ------------------------------

    def submit(self, tenant: str, snap: dict) -> Pending:
        """Sanitize + enqueue one snapshot; never raises — a malformed
        snapshot resolves immediately with its error and touches no
        shared state."""
        p = Pending(tenant, snap)
        with self.lock:
            t = self.tenants.get(tenant)
            if t is None:
                p.resolve(error("not-admitted",
                                f"unknown tenant {tenant!r}; hello first"))
                return p
            # resend dedupe (checked before the sanitizer, whose
            # out-of-order rule would reject the repeated seq): a client
            # that lost the connection after the server applied its
            # snapshot but before the reply landed resends the same seq
            # — answer from the cache / the in-flight entry so the rows
            # are never ingested twice.
            seq = snap.get("seq")
            if isinstance(seq, (int, float)) and not isinstance(seq, bool):
                if (t.last_answer is not None
                        and float(seq) == t.last_answer[0]):
                    self.stats_counters["resends"] += 1
                    p.resolve({**t.last_answer[1], "resent": True})
                    return p
                for q in self.pending:
                    if (q.tenant == tenant
                            and isinstance(q.snap.get("seq"), (int, float))
                            and float(q.snap["seq"]) == float(seq)):
                        # still queued: ride the in-flight entry
                        self.stats_counters["resends"] += 1
                        return q
            try:
                clean = sanitize_snapshot(snap, self.profile, t.last_seq,
                                          mode=self.cfg.sanitize)
            except TelemetryError as e:
                self.stats_counters["rejected"] += 1
                p.resolve(error(e.code, str(e)))
                return p
            t.last_seq = clean["seq"]
            p.snap = clean
            mine = [q for q in self.pending if q.tenant == tenant]
            if len(mine) >= self.cfg.queue_depth:
                oldest = mine[0]
                self.pending.remove(oldest)
                oldest.resolve(error(
                    "overload", "queue full; oldest snapshot shed"))
                t.shed += 1
                self.stats_counters["sheds"] += 1
            self.pending.append(p)
        return p

    # ------------------------------ batch tick --------------------------

    def tick(self) -> int:
        """Answer queued snapshots: at most one per tenant, all tenants
        coalesced into one dispatch.  Returns entries answered."""
        with self.lock:
            batch: list[Pending] = []
            seen: set[str] = set()
            keep: deque[Pending] = deque()
            while self.pending and len(batch) < self.cfg.max_batch:
                p = self.pending.popleft()
                if p.tenant in seen:    # later interval: next tick
                    keep.append(p)
                else:
                    seen.add(p.tenant)
                    batch.append(p)
            keep.extend(self.pending)
            self.pending = keep
            if not batch:
                return 0
            self.stats_counters["ticks"] += 1
            for p in batch:
                self._ingest(self.tenants[p.tenant], p.snap)
            results = self._answer(batch)
            for p, res in zip(batch, results):
                t = self.tenants.get(p.tenant)
                if t is not None:
                    t.last_answer = (p.snap["seq"], res)
                p.resolve(res)
            self._since_retrain += len(batch)
            if (self.cfg.retrain_every
                    and self._since_retrain >= self.cfg.retrain_every):
                self._retrain_due = True
            return len(batch)

    def _ingest(self, t: TenantState, clean: dict) -> None:
        t.snapshots += 1
        self.stats_counters["snapshots"] += 1
        t.controller.observe_hosts(clean["m_h"])
        for j in clean["jobs"]:
            t.mt_cache[j["id"]] = j["m_t"]
        for d in clean["done"]:
            times = d["times"]
            t.durations.extend(float(x) for x in times)
            m_t = t.mt_cache.pop(d["id"], None)
            t.controller.job_finished(d["id"])
            if m_t is not None and not self.degraded:
                host_seq = t.controller._host_seq().reshape(
                    self.profile.horizon, -1)
                self.buffer.add_job(host_seq, m_t, times,
                                    self.profile.beta_scale)

    def _answer(self, batch: list[Pending]) -> list[dict]:
        per_task = self.profile.trigger == "per_task"
        live = [(p, self.tenants[p.tenant]) for p in batch]
        with_jobs = [(p, t) for p, t in live if p.snap["jobs"]]
        preds: dict[str, tuple] = {}
        if self.degraded:
            for p, t in with_jobs:
                self.stats_counters["degraded_answers"] += 1
                preds[p.tenant] = self._degraded_predict(t, p.snap)
        elif len(with_jobs) == 1:
            # single tenant: the tenant's own fused path — bitwise-equal
            # to an in-process predict_interval call
            p, t = with_jobs[0]
            preds[p.tenant] = self._predict_single(t, p.snap, per_task)
        elif with_jobs:
            self._predict_many(with_jobs, per_task, preds)
        out = []
        for p, t in live:
            jobs_out = []
            if p.snap["jobs"]:
                e_s, scores, actions = preds[p.tenant]
                for i, j in enumerate(p.snap["jobs"]):
                    entry = {"id": j["id"], "e_s": float(e_s[i])}
                    if scores is not None:
                        entry["scores"] = [
                            float(x)
                            for x in scores[i][:int(j["q"])]]
                    entry["actions"] = [
                        _mit_to_wire(a) for a in actions
                        if a.job_id == j["id"]]
                    jobs_out.append(entry)
            self.stats_counters["batch_rows"] += len(jobs_out)
            out.append({"ok": True, "seq": p.snap["seq"],
                        "version": self.model_version,
                        "degraded": bool(self.degraded),
                        "sanitized": p.snap["issues"],
                        "jobs": jobs_out})
        return out

    @staticmethod
    def _incomplete_fn(snap: dict):
        by_id = {j["id"]: j["tasks"] for j in snap["jobs"]}

        def fn(job_id: int):
            return by_id[job_id]
        return fn

    def _apply(self, t: TenantState, snap: dict, ids, e_s, scores,
               per_task: bool):
        """Run the tenant's trigger over sanitized predictions."""
        ctrl = t.controller
        deadline = np.array([j["deadline"] for j in snap["jobs"]])
        fn = self._incomplete_fn(snap)
        if per_task:
            return ctrl.apply_per_task(ids, e_s, scores, deadline, fn)
        open_counts = np.array([j["open"] for j in snap["jobs"]],
                               np.float64)
        return ctrl.apply_milestone(ids, e_s, open_counts, deadline, fn)

    def _predict_single(self, t: TenantState, snap: dict,
                        per_task: bool):
        jobs = snap["jobs"]
        ids = np.array([j["id"] for j in jobs], np.int64)
        m_t = np.stack([j["m_t"] for j in jobs])
        q = np.array([j["q"] for j in jobs], np.float32)
        ctrl = t.controller
        if per_task:
            e_s, scores = ctrl.predict_scores_batch(ids, m_t, q)
        else:
            e_s = ctrl.predict_es_batch(ids, m_t, q)
            scores = None
        actions = self._apply(t, snap, ids, e_s, scores, per_task)
        return e_s, scores, actions

    def _predict_many(self, with_jobs: list, per_task: bool,
                      preds: dict) -> None:
        """One combined dispatch over every queued tenant's jobs."""
        host_seqs, mt_list, q_list, metas = [], [], [], []
        for p, t in with_jobs:
            jobs = p.snap["jobs"]
            host_seqs.append(t.controller._host_seq().reshape(
                self.profile.horizon, -1))
            mt_list.append(np.stack([j["m_t"] for j in jobs]).reshape(
                len(jobs), -1))
            q_list.append(np.array([j["q"] for j in jobs], np.float32))
            metas.append((p, t, np.array([j["id"] for j in jobs],
                                         np.int64)))
        res = self.model.predict_tenants(host_seqs, mt_list, q_list,
                                         per_task=per_task)
        for (p, t, ids), q, r in zip(metas, q_list, res):
            if per_task:
                e_s, scores = r
                scores = np.where(np.isfinite(scores), scores, 0.0)
            else:
                e_s, scores = r, None
            e_s = STARTController._sanitize_es(e_s, q)
            for j, e in zip(ids, e_s):
                t.controller._es_cache[int(j)] = float(e)
            actions = self._apply(t, p.snap, ids, e_s, scores, per_task)
            preds[p.tenant] = (e_s, scores, actions)

    def _degraded_predict(self, t: TenantState, snap: dict):
        """No model: jitted ``_pareto_tail`` over the tenant's own MLE
        duration fit (uniform per-task split)."""
        jobs = snap["jobs"]
        n = len(jobs)
        q = np.array([j["q"] for j in jobs], np.float32)
        ids = np.array([j["id"] for j in jobs], np.int64)
        per_task = self.profile.trigger == "per_task"
        if len(t.durations) >= 2:
            alpha, beta = fit_pareto_np(
                np.asarray(t.durations, np.float32).reshape(1, -1))
            nb = bucket_size(n)
            ab = np.broadcast_to(
                np.array([float(alpha[0]),
                          float(beta[0]) / self.profile.beta_scale],
                         np.float32), (nb, 2))
            qp = np.ones(nb, np.float32)
            qp[:n] = q
            _, _, _, e_s = _pareto_tail(
                ab, qp, np.float32(self.profile.k),
                np.float32(self.profile.beta_scale))
            e_s = np.asarray(e_s)[:n]
        else:
            e_s = np.zeros(n)
        e_s = STARTController._sanitize_es(e_s, q)
        scores = None
        if per_task:
            scores = np.zeros((n, self.profile.max_tasks), np.float32)
            for i in range(n):
                scores[i, :int(q[i])] = e_s[i] / max(q[i], 1.0)
        for j, e in zip(ids, e_s):
            t.controller._es_cache[int(j)] = float(e)
        actions = self._apply(t, snap, ids, e_s, scores, per_task)
        return e_s, scores, actions

    # ------------------------------ dispatch ----------------------------

    def stats(self) -> dict:
        with self.lock:
            return {
                "ok": True, "version": self.model_version,
                "degraded": bool(self.degraded),
                "tenants": len(self.tenants),
                "pending": len(self.pending),
                "buffer_pairs": len(self.buffer),
                "buckets": sorted(self.model.buckets_used | set().union(
                    *(t.predictor.buckets_used
                      for t in self.tenants.values()), set())),
                "compile_count": self.model.compile_count,
                "last_retrain_error": self.last_retrain_error,
                **self.stats_counters,
            }

    def handle(self, msg: dict, auto_tick: bool = True,
               timeout: float = 30.0) -> dict:
        """One request -> one response (transport-agnostic dispatcher).

        ``auto_tick=True`` (in-process / single-threaded use) answers a
        snapshot by ticking immediately; the daemon passes ``False`` and
        lets its batch loop resolve the pending entry.
        """
        op = msg.get("op")
        if op == "hello":
            return self.hello(str(msg.get("tenant", "")),
                              msg.get("profile") or {},
                              token=msg.get("token"))
        if op == "snapshot":
            p = self.submit(str(msg.get("tenant", "")), msg)
            if auto_tick and not p.event.is_set():
                self.tick()
            p.event.wait(timeout)
            return p.result if p.result is not None else error(
                "timeout", "tick did not answer in time")
        if op == "stats":
            return self.stats()
        if op == "retrain":
            return self.retrain_now()
        if op == "rollback":
            return self.rollback_now()
        if op == "bye":
            return self.bye(str(msg.get("tenant", "")))
        return error("bad-op", f"unknown op {op!r}")
