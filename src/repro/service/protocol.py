"""Wire protocol for the prediction service.

Transport is JSON-lines: one JSON object per ``\\n``-terminated line,
UTF-8, over TCP or any file-like pair.  This is deliberately stdlib-only
(``json`` + ``socket``) — the service must not pull in dependencies the
simulator does not already have.

Requests carry an ``op``:

  * ``hello``    — admission: tenant id + :class:`Profile`; the server
    accepts iff the profile is compatible with the one it serves.
  * ``snapshot`` — one telemetry interval (see
    :func:`repro.policy.wire.snapshot_to_wire`); answered with E_S per
    job, per-task scores (eager profiles), mitigation actions, and the
    serving model version.
  * ``stats``    — server counters (tenants, ticks, sheds, retraces...).
  * ``retrain``  — force one retrain/shadow-eval/promote cycle now.
  * ``rollback`` — demote the current model version to its predecessor.
  * ``bye``      — drop the tenant's server-side state.

Responses are ``{"ok": true, ...}`` or
``{"ok": false, "error": code, "detail": msg}``.

``json.dumps`` keeps Python's ``allow_nan`` default on purpose: tenants
*can* transmit NaN/Infinity telemetry, and rejecting or repairing it is
the sanitizer's job on the server side, not the transport's.  Finite
float32 values survive the float64 JSON round trip losslessly, which is
what makes the single-tenant bitwise guarantee hold over TCP.
"""
from __future__ import annotations

import dataclasses
import json

#: profile fields that must match exactly between tenant and server —
#: they select the compiled program family and the Pareto constants.
_STRICT = ("n_hosts", "max_tasks", "horizon", "k", "beta_scale",
           "trigger", "score_on", "hysteresis", "cooldown")


@dataclasses.dataclass(frozen=True)
class Profile:
    """The model/controller shape a tenant expects the service to run.

    A service process serves exactly one profile (one compiled program
    family, one shared parameter pytree); admission control rejects a
    tenant whose profile disagrees, because batching its rows into the
    shared dispatch would silently answer with the wrong model.
    """

    n_hosts: int
    max_tasks: int
    horizon: int = 5
    k: float = 1.5
    beta_scale: float = 1.0
    trigger: str = "milestone"       # "milestone" | "per_task"
    score_on: float = 0.0            # per-task trigger knobs (PR 6)
    hysteresis: int = 2
    cooldown: int = 5

    def to_wire(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_wire(cls, obj: dict) -> "Profile":
        known = {f.name for f in dataclasses.fields(cls)}
        extra = set(obj) - known
        if extra:
            raise ValueError(f"unknown Profile fields {sorted(extra)}")
        return cls(**obj)

    def compatible(self, other: "Profile") -> bool:
        return all(getattr(self, f) == getattr(other, f)
                   for f in _STRICT)


def encode(obj: dict) -> bytes:
    """One wire frame: compact JSON + newline."""
    return (json.dumps(obj, separators=(",", ":")) + "\n").encode()


def decode(line: bytes | str) -> dict:
    obj = json.loads(line)
    if not isinstance(obj, dict):
        raise ValueError("wire frame must be a JSON object")
    return obj


def error(code: str, detail: str) -> dict:
    return {"ok": False, "error": code, "detail": detail}


#: hard cap on one JSON line: a peer that never sends ``\n`` must not
#: grow the read buffer without bound (the JSON-lines mirror of the
#: fabric's ``MAX_FRAME`` discipline).  Generous for real snapshots —
#: a 64-host x 64-task per-task profile is well under 1 MiB.
MAX_LINE = 1 << 20


class _Oversize:
    """Sentinel yielded by :func:`recv_lines` for a line that exceeded
    ``MAX_LINE`` without a newline: the stream position is now
    mid-garbage, so the caller must answer with a protocol error and
    drop the connection (resynchronizing is impossible)."""

    def __repr__(self) -> str:            # pragma: no cover - debug aid
        return "<protocol.OVERSIZE>"


OVERSIZE = _Oversize()


def recv_lines(sock_file, max_line: int = MAX_LINE):
    """Yield decoded frames from a file-like until EOF.

    A syntactically bad frame yields ``None`` (the caller answers with
    a protocol error and keeps the connection); a line longer than
    ``max_line`` with no newline yields :data:`OVERSIZE` and stops —
    the caller must drop the connection after answering.
    """
    nl = None
    while True:
        raw = sock_file.readline(max_line + 1)
        if not raw:
            return
        if nl is None:
            nl = b"\n" if isinstance(raw, bytes) else "\n"
        if len(raw) > max_line and not raw.endswith(nl):
            yield OVERSIZE
            return
        if not raw.strip():
            continue
        try:
            yield decode(raw)
        except ValueError:
            yield None
