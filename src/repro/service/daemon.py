"""Transports for the prediction service: threads, TCP, clients.

``ServiceDaemon`` owns a :class:`PredictionService` plus

  * a **batch worker** thread: waits up to ``batch_window`` seconds for
    snapshots to queue, then runs one ``tick()`` — many tenants arriving
    within a window share one device dispatch;
  * a **stdlib TCP server** (``socketserver.ThreadingTCPServer``)
    speaking JSON-lines — one connection per tenant, requests answered
    in order on that connection;
  * an optional **retrain** thread that runs a
    retrain/shadow-eval/promote cycle whenever the service flags one due
    (``retrain_every`` snapshots) or the cron-style wall-clock scheduler
    (:class:`RetrainScheduler`, ``retrain_interval_s`` seconds of
    monotonic time) fires — slow tenants still get periodically
    refreshed models.

``LocalClient`` drives the same service in-process with zero transport
(the simulator / tests path); ``ServiceClient`` is the TCP twin with an
identical surface, so swapping transports is a one-line change.
"""
from __future__ import annotations

import dataclasses
import os
import random
import socket
import socketserver
import sys
import threading
import time

from repro.service import protocol
from repro.service.core import PredictionService, ServiceConfig


class RetrainScheduler:
    """Cron-style wall-clock retrain trigger.

    Marks a retrain due every ``interval_s`` seconds of **monotonic**
    time (never the wall calendar — NTP steps and suspend/resume must
    not double- or never-fire).  Missed periods coalesce: if a slow fit
    (or a suspended laptop) swallows three periods, the next
    :meth:`due` poll fires once and re-arms ``interval_s`` from *now*,
    so there is never a catch-up burst of back-to-back retrains.

    The clock is injectable so tests drive it deterministically with a
    fake; production uses :func:`time.monotonic`.
    """

    def __init__(self, interval_s: float, clock=time.monotonic):
        self.interval_s = float(interval_s)
        self.clock = clock
        self._next = (self.clock() + self.interval_s
                      if self.interval_s > 0 else None)

    @property
    def enabled(self) -> bool:
        return self._next is not None

    def due(self) -> bool:
        """Poll: True exactly once per elapsed period, then re-arm."""
        if self._next is None:
            return False
        now = self.clock()
        if now < self._next:
            return False
        self._next = now + self.interval_s
        return True


class _Handler(socketserver.StreamRequestHandler):
    def handle(self) -> None:
        svc: PredictionService = self.server.service  # type: ignore
        self.server.track(self.connection)            # type: ignore
        for msg in protocol.recv_lines(self.rfile):
            if msg is protocol.OVERSIZE:
                # a peer that never sends \n: answer once and drop the
                # connection — the stream cannot be resynchronized
                try:
                    self.wfile.write(protocol.encode(protocol.error(
                        "frame-too-long",
                        f"line exceeded {protocol.MAX_LINE} bytes")))
                    self.wfile.flush()
                except (BrokenPipeError, ConnectionResetError):
                    pass
                return
            if msg is None:
                resp = protocol.error("bad-frame", "not a JSON object")
            else:
                # enqueue only; the shared batch worker resolves it —
                # that is what coalesces concurrent tenants into one
                # dispatch
                resp = svc.handle(msg, auto_tick=False,
                                  timeout=self.server.timeout_s)
            try:
                self.wfile.write(protocol.encode(resp))
                self.wfile.flush()
            except (BrokenPipeError, ConnectionResetError):
                return
            if msg is not None and msg.get("op") == "bye":
                return


class _Server(socketserver.ThreadingTCPServer):
    allow_reuse_address = True
    daemon_threads = True

    def handle_error(self, request, client_address):
        # A peer that vanishes mid-request (crash, injected RST) is an
        # expected event for a long-running daemon, not a bug worth a
        # traceback on stderr; everything else keeps the default dump.
        exc = sys.exc_info()[1]
        if isinstance(exc, (ConnectionError, BrokenPipeError)):
            return
        super().handle_error(request, client_address)

    def __init__(self, *args, **kw):
        super().__init__(*args, **kw)
        self._conns: set = set()
        self._conns_lock = threading.Lock()

    def track(self, sock) -> None:
        with self._conns_lock:
            self._conns.add(sock)

    def close_all_connections(self) -> None:
        """Sever live client connections so a stopping daemon looks
        dead to its tenants immediately — reconnecting clients fail
        over to the restarted instance instead of hanging on a socket
        whose handler thread will never answer again."""
        with self._conns_lock:
            conns, self._conns = self._conns, set()
        for sock in conns:
            try:
                sock.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            try:
                sock.close()
            except OSError:
                pass


class ServiceDaemon:
    """Long-running serving process (in one Python process).

    Args:
        cfg: service configuration (profile, queues, retraining).
        host/port: TCP bind address; ``port=0`` picks a free port
            (read it back from ``.port``).  ``port=None`` disables the
            TCP listener (in-process only).
        batch_window: seconds the batch worker waits for more tenants
            before dispatching a tick.
        retrain_clock: monotonic clock the wall-clock retrain scheduler
            reads (tests inject a fake; ``None`` = ``time.monotonic``).
    """

    def __init__(self, cfg: ServiceConfig, host: str = "127.0.0.1",
                 port: int | None = 0, batch_window: float = 0.002,
                 timeout_s: float = 30.0, retrain_clock=None):
        if cfg.auth_token is None:
            token = os.environ.get("REPRO_SERVICE_TOKEN")
            if token:
                cfg = dataclasses.replace(cfg, auth_token=token)
        self.service = PredictionService(cfg)
        self.retrain_scheduler = RetrainScheduler(
            getattr(cfg, "retrain_interval_s", 0.0),
            clock=retrain_clock or time.monotonic)
        self.batch_window = batch_window
        self._stop = threading.Event()
        self._kick = threading.Event()
        self._worker = threading.Thread(target=self._run_worker,
                                        daemon=True)
        self._retrainer = threading.Thread(target=self._run_retrainer,
                                           daemon=True)
        self._server = None
        self._server_thread = None
        self.host, self.port = host, None
        if port is not None:
            self._server = _Server((host, port), _Handler)
            self._server.service = self.service       # type: ignore
            self._server.timeout_s = timeout_s        # type: ignore
            self.port = self._server.server_address[1]
            self._server_thread = threading.Thread(
                target=self._server.serve_forever,
                kwargs={"poll_interval": 0.05}, daemon=True)
        # submissions kick the worker so an idle service answers within
        # one batch window, not one polling period
        _orig_submit = self.service.submit

        def _submit(tenant, snap):
            p = _orig_submit(tenant, snap)
            self._kick.set()
            return p
        self.service.submit = _submit                 # type: ignore

    # ------------------------------ lifecycle ---------------------------

    def start(self) -> "ServiceDaemon":
        self._worker.start()
        self._retrainer.start()
        if self._server_thread is not None:
            self._server_thread.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        self._kick.set()
        if self._server is not None:
            self._server.shutdown()
            self._server.close_all_connections()
            self._server.server_close()
        self._worker.join(timeout=5)
        self._retrainer.join(timeout=5)
        # resolve anything still queued so no client hangs
        with self.service.lock:
            while self.service.pending:
                self.service.pending.popleft().resolve(
                    protocol.error("shutdown", "daemon stopping"))

    def __enter__(self) -> "ServiceDaemon":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # ------------------------------ threads -----------------------------

    def _run_worker(self) -> None:
        while not self._stop.is_set():
            self._kick.wait(timeout=0.25)
            self._kick.clear()
            if self._stop.is_set():
                return
            # batch window: let concurrent tenants pile in, then one tick
            if self.batch_window:
                self._stop.wait(self.batch_window)
            while self.service.tick():
                pass

    def _run_retrainer(self) -> None:
        while not self._stop.wait(0.05):
            # the wall-clock scheduler latches the same due-flag the
            # snapshot-count trigger uses, so both routes share one
            # retrain/shadow-eval/promote pipeline (and its guards:
            # min_train_pairs, eval holdback, promotion tolerance)
            if self.retrain_scheduler.due():
                self.service._retrain_due = True
            if self.service._retrain_due:
                try:
                    self.service.retrain_now()
                except Exception as e:
                    # never kill the retrainer thread — but never
                    # swallow the failure either: it lands in stats()
                    # (retrain_failures + last_retrain_error) and the
                    # due-flag clears so a poisoned buffer can't spin
                    self.service.note_retrain_failure(e)

    # ------------------------------ convenience -------------------------

    def local_client(self, tenant: str) -> "LocalClient":
        return LocalClient(self.service, tenant)

    def tcp_client(self, tenant: str) -> "ServiceClient":
        if self.port is None:
            raise RuntimeError("daemon started without a TCP listener")
        return ServiceClient(self.host, self.port, tenant)


class LocalClient:
    """In-process handle: same request surface as the TCP client, no
    transport.  ``auto_tick`` answers synchronously when no daemon
    worker is running (plain ``PredictionService`` use)."""

    def __init__(self, service: PredictionService, tenant: str,
                 auto_tick: bool | None = None,
                 token: str | None = None):
        self.service = service
        self.tenant = tenant
        self.token = (token if token is not None
                      else os.environ.get("REPRO_SERVICE_TOKEN"))
        if auto_tick is None:
            # a daemon replaces service.submit with a kicking wrapper
            # (a plain function, not a bound method); its batch worker
            # then owns the ticking
            auto_tick = getattr(service.submit, "__func__",
                                None) is PredictionService.submit
        self.auto_tick = auto_tick

    def request(self, msg: dict, timeout: float = 30.0) -> dict:
        return self.service.handle(msg, auto_tick=self.auto_tick,
                                   timeout=timeout)

    def hello(self, profile) -> dict:
        msg = {"op": "hello", "tenant": self.tenant,
               "profile": profile.to_wire()}
        if self.token is not None:
            msg["token"] = self.token
        return self.request(msg)

    def snapshot(self, snap: dict) -> dict:
        snap = dict(snap)
        snap["op"] = "snapshot"
        snap["tenant"] = self.tenant
        return self.request(snap)

    def stats(self) -> dict:
        return self.request({"op": "stats"})

    def retrain(self) -> dict:
        return self.request({"op": "retrain"})

    def rollback(self) -> dict:
        return self.request({"op": "rollback"})

    def bye(self) -> dict:
        return self.request({"op": "bye", "tenant": self.tenant})

    def close(self) -> None:
        pass


#: ops the client may safely resend after a transport failure: hello is
#: a rejoin, snapshots are seq-deduped server-side (a retried snapshot
#: is answered from the cached response, never applied twice), stats
#: and bye are read-only/terminal.  retrain and rollback are NOT here —
#: resending either could run the state machine twice.
_RETRY_SAFE = frozenset({"hello", "snapshot", "stats", "bye"})

#: server answers that mean "your request never arrived intact" — safe
#: to resend a retry-safe op on the same connection
_TRANSPORT_ERRORS = frozenset({"bad-frame", "frame-too-long"})


class ServiceClient:
    """Reconnecting JSON-lines TCP client (one socket, ordered replies).

    Transport failures — connection reset, EOF, an undecodable reply, a
    server-side ``bad-frame`` answer — are healed transparently for
    retry-safe ops: the client redials with capped exponential backoff
    plus jitter, replays its ``hello`` (the server treats it as a
    rejoin), and resends the request.  Snapshots are tagged with the
    tenant's ``seq``, and the server caches its last answer per tenant,
    so a resend of an already-applied snapshot returns the cached
    answer instead of being applied twice.  ``retrain``/``rollback``
    are never resent; a failure there surfaces as ``ConnectionError``.

    ``request(timeout=...)`` applies a **per-request socket timeout**;
    on expiry the connection is dropped (a late reply would desync the
    stream) and ``TimeoutError`` is raised.
    """

    def __init__(self, host: str, port: int, tenant: str,
                 timeout: float = 30.0, token: str | None = None,
                 retries: int = 3, backoff_s: float = 0.1,
                 backoff_cap_s: float = 2.0):
        self.host, self.port = host, int(port)
        self.tenant = tenant
        self.token = (token if token is not None
                      else os.environ.get("REPRO_SERVICE_TOKEN"))
        self.timeout = float(timeout)
        self.retries = max(1, int(retries))
        self.backoff_s = float(backoff_s)
        self.backoff_cap_s = float(backoff_cap_s)
        self._rng = random.Random(f"{tenant}@{host}:{port}")
        self._profile_wire: dict | None = None
        self._sock = None
        self._file = None
        self._dial()

    # ------------------------------ transport ---------------------------

    def _dial(self) -> None:
        self._sock = socket.create_connection((self.host, self.port),
                                              timeout=self.timeout)
        self._file = self._sock.makefile("rwb")

    def _drop(self) -> None:
        for o in (self._file, self._sock):
            try:
                if o is not None:
                    o.close()
            except OSError:
                pass
        self._file = self._sock = None

    def _backoff(self, attempt: int) -> float:
        base = min(self.backoff_s * (2.0 ** attempt), self.backoff_cap_s)
        return base * (0.5 + 0.5 * self._rng.random())

    def _reconnect(self) -> None:
        last: Exception | None = None
        for attempt in range(self.retries):
            try:
                self._dial()
                if self._profile_wire is not None:
                    # rejoin before resuming traffic: a restarted daemon
                    # has no tenant state until it sees our hello again
                    resp = self._roundtrip(self._hello_msg(), None)
                    if not resp.get("ok"):
                        raise ConnectionError(
                            f"rejoin refused: {resp.get('error')}")
                return
            except (OSError, ValueError) as e:
                last = e
                self._drop()
                time.sleep(self._backoff(attempt))
        raise ConnectionError(
            f"service {self.host}:{self.port} unreachable") from last

    def _roundtrip(self, msg: dict, timeout: float | None) -> dict:
        if self._file is None:
            raise ConnectionError("not connected")
        if timeout is not None:
            self._sock.settimeout(timeout)
        try:
            self._file.write(protocol.encode(msg))
            self._file.flush()
            line = self._file.readline(protocol.MAX_LINE + 1)
        finally:
            if timeout is not None and self._sock is not None:
                try:
                    self._sock.settimeout(self.timeout)
                except OSError:
                    pass
        if not line:
            raise ConnectionError("service closed the connection")
        return protocol.decode(line)     # ValueError on corrupt reply

    # ------------------------------ requests ----------------------------

    def request(self, msg: dict, timeout: float | None = None) -> dict:
        retry_safe = msg.get("op") in _RETRY_SAFE
        tries = self.retries if retry_safe else 1
        last: Exception | None = None
        for attempt in range(tries):
            if self._file is None:
                self._reconnect()
            try:
                resp = self._roundtrip(msg, timeout)
            except TimeoutError:
                # the reply may still arrive later and desync every
                # following request on this stream: drop the connection
                self._drop()
                raise
            except (ConnectionError, ValueError, OSError) as e:
                last = e
                self._drop()
                if attempt == tries - 1:
                    break
                continue
            if (retry_safe and not resp.get("ok", True)
                    and resp.get("error") in _TRANSPORT_ERRORS
                    and attempt < tries - 1):
                # our frame got mangled in flight; the server never
                # applied it — resend (frame-too-long also dropped the
                # connection server-side, the next loop redials)
                if resp.get("error") == "frame-too-long":
                    self._drop()
                continue
            return resp
        raise ConnectionError(
            f"request {msg.get('op')!r} failed after {tries} "
            f"attempts") from last

    def _hello_msg(self) -> dict:
        msg = {"op": "hello", "tenant": self.tenant,
               "profile": self._profile_wire}
        if self.token is not None:
            msg["token"] = self.token
        return msg

    def hello(self, profile) -> dict:
        self._profile_wire = profile.to_wire()
        return self.request(self._hello_msg())

    def snapshot(self, snap: dict) -> dict:
        snap = dict(snap)
        snap["op"] = "snapshot"
        snap["tenant"] = self.tenant
        return self.request(snap)

    def stats(self) -> dict:
        return self.request({"op": "stats"})

    def retrain(self) -> dict:
        return self.request({"op": "retrain"})

    def rollback(self) -> dict:
        return self.request({"op": "rollback"})

    def bye(self) -> dict:
        try:
            return self.request({"op": "bye", "tenant": self.tenant})
        finally:
            self.close()

    def close(self) -> None:
        self._drop()
