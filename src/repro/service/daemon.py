"""Transports for the prediction service: threads, TCP, clients.

``ServiceDaemon`` owns a :class:`PredictionService` plus

  * a **batch worker** thread: waits up to ``batch_window`` seconds for
    snapshots to queue, then runs one ``tick()`` — many tenants arriving
    within a window share one device dispatch;
  * a **stdlib TCP server** (``socketserver.ThreadingTCPServer``)
    speaking JSON-lines — one connection per tenant, requests answered
    in order on that connection;
  * an optional **retrain** thread that runs a
    retrain/shadow-eval/promote cycle whenever the service flags one due
    (``retrain_every`` snapshots) or the cron-style wall-clock scheduler
    (:class:`RetrainScheduler`, ``retrain_interval_s`` seconds of
    monotonic time) fires — slow tenants still get periodically
    refreshed models.

``LocalClient`` drives the same service in-process with zero transport
(the simulator / tests path); ``ServiceClient`` is the TCP twin with an
identical surface, so swapping transports is a one-line change.
"""
from __future__ import annotations

import socket
import socketserver
import threading
import time

from repro.service import protocol
from repro.service.core import PredictionService, ServiceConfig


class RetrainScheduler:
    """Cron-style wall-clock retrain trigger.

    Marks a retrain due every ``interval_s`` seconds of **monotonic**
    time (never the wall calendar — NTP steps and suspend/resume must
    not double- or never-fire).  Missed periods coalesce: if a slow fit
    (or a suspended laptop) swallows three periods, the next
    :meth:`due` poll fires once and re-arms ``interval_s`` from *now*,
    so there is never a catch-up burst of back-to-back retrains.

    The clock is injectable so tests drive it deterministically with a
    fake; production uses :func:`time.monotonic`.
    """

    def __init__(self, interval_s: float, clock=time.monotonic):
        self.interval_s = float(interval_s)
        self.clock = clock
        self._next = (self.clock() + self.interval_s
                      if self.interval_s > 0 else None)

    @property
    def enabled(self) -> bool:
        return self._next is not None

    def due(self) -> bool:
        """Poll: True exactly once per elapsed period, then re-arm."""
        if self._next is None:
            return False
        now = self.clock()
        if now < self._next:
            return False
        self._next = now + self.interval_s
        return True


class _Handler(socketserver.StreamRequestHandler):
    def handle(self) -> None:
        svc: PredictionService = self.server.service  # type: ignore
        for msg in protocol.recv_lines(self.rfile):
            if msg is None:
                resp = protocol.error("bad-frame", "not a JSON object")
            else:
                # enqueue only; the shared batch worker resolves it —
                # that is what coalesces concurrent tenants into one
                # dispatch
                resp = svc.handle(msg, auto_tick=False,
                                  timeout=self.server.timeout_s)
            try:
                self.wfile.write(protocol.encode(resp))
                self.wfile.flush()
            except (BrokenPipeError, ConnectionResetError):
                return
            if msg is not None and msg.get("op") == "bye":
                return


class _Server(socketserver.ThreadingTCPServer):
    allow_reuse_address = True
    daemon_threads = True


class ServiceDaemon:
    """Long-running serving process (in one Python process).

    Args:
        cfg: service configuration (profile, queues, retraining).
        host/port: TCP bind address; ``port=0`` picks a free port
            (read it back from ``.port``).  ``port=None`` disables the
            TCP listener (in-process only).
        batch_window: seconds the batch worker waits for more tenants
            before dispatching a tick.
        retrain_clock: monotonic clock the wall-clock retrain scheduler
            reads (tests inject a fake; ``None`` = ``time.monotonic``).
    """

    def __init__(self, cfg: ServiceConfig, host: str = "127.0.0.1",
                 port: int | None = 0, batch_window: float = 0.002,
                 timeout_s: float = 30.0, retrain_clock=None):
        self.service = PredictionService(cfg)
        self.retrain_scheduler = RetrainScheduler(
            getattr(cfg, "retrain_interval_s", 0.0),
            clock=retrain_clock or time.monotonic)
        self.batch_window = batch_window
        self._stop = threading.Event()
        self._kick = threading.Event()
        self._worker = threading.Thread(target=self._run_worker,
                                        daemon=True)
        self._retrainer = threading.Thread(target=self._run_retrainer,
                                           daemon=True)
        self._server = None
        self._server_thread = None
        self.host, self.port = host, None
        if port is not None:
            self._server = _Server((host, port), _Handler)
            self._server.service = self.service       # type: ignore
            self._server.timeout_s = timeout_s        # type: ignore
            self.port = self._server.server_address[1]
            self._server_thread = threading.Thread(
                target=self._server.serve_forever,
                kwargs={"poll_interval": 0.05}, daemon=True)
        # submissions kick the worker so an idle service answers within
        # one batch window, not one polling period
        _orig_submit = self.service.submit

        def _submit(tenant, snap):
            p = _orig_submit(tenant, snap)
            self._kick.set()
            return p
        self.service.submit = _submit                 # type: ignore

    # ------------------------------ lifecycle ---------------------------

    def start(self) -> "ServiceDaemon":
        self._worker.start()
        self._retrainer.start()
        if self._server_thread is not None:
            self._server_thread.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        self._kick.set()
        if self._server is not None:
            self._server.shutdown()
            self._server.server_close()
        self._worker.join(timeout=5)
        self._retrainer.join(timeout=5)
        # resolve anything still queued so no client hangs
        with self.service.lock:
            while self.service.pending:
                self.service.pending.popleft().resolve(
                    protocol.error("shutdown", "daemon stopping"))

    def __enter__(self) -> "ServiceDaemon":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # ------------------------------ threads -----------------------------

    def _run_worker(self) -> None:
        while not self._stop.is_set():
            self._kick.wait(timeout=0.25)
            self._kick.clear()
            if self._stop.is_set():
                return
            # batch window: let concurrent tenants pile in, then one tick
            if self.batch_window:
                self._stop.wait(self.batch_window)
            while self.service.tick():
                pass

    def _run_retrainer(self) -> None:
        while not self._stop.wait(0.05):
            # the wall-clock scheduler latches the same due-flag the
            # snapshot-count trigger uses, so both routes share one
            # retrain/shadow-eval/promote pipeline (and its guards:
            # min_train_pairs, eval holdback, promotion tolerance)
            if self.retrain_scheduler.due():
                self.service._retrain_due = True
            if self.service._retrain_due:
                try:
                    self.service.retrain_now()
                except Exception as e:
                    # never kill the retrainer thread — but never
                    # swallow the failure either: it lands in stats()
                    # (retrain_failures + last_retrain_error) and the
                    # due-flag clears so a poisoned buffer can't spin
                    self.service.note_retrain_failure(e)

    # ------------------------------ convenience -------------------------

    def local_client(self, tenant: str) -> "LocalClient":
        return LocalClient(self.service, tenant)

    def tcp_client(self, tenant: str) -> "ServiceClient":
        if self.port is None:
            raise RuntimeError("daemon started without a TCP listener")
        return ServiceClient(self.host, self.port, tenant)


class LocalClient:
    """In-process handle: same request surface as the TCP client, no
    transport.  ``auto_tick`` answers synchronously when no daemon
    worker is running (plain ``PredictionService`` use)."""

    def __init__(self, service: PredictionService, tenant: str,
                 auto_tick: bool | None = None):
        self.service = service
        self.tenant = tenant
        if auto_tick is None:
            # a daemon replaces service.submit with a kicking wrapper
            # (a plain function, not a bound method); its batch worker
            # then owns the ticking
            auto_tick = getattr(service.submit, "__func__",
                                None) is PredictionService.submit
        self.auto_tick = auto_tick

    def request(self, msg: dict, timeout: float = 30.0) -> dict:
        return self.service.handle(msg, auto_tick=self.auto_tick,
                                   timeout=timeout)

    def hello(self, profile) -> dict:
        return self.request({"op": "hello", "tenant": self.tenant,
                             "profile": profile.to_wire()})

    def snapshot(self, snap: dict) -> dict:
        snap = dict(snap)
        snap["op"] = "snapshot"
        snap["tenant"] = self.tenant
        return self.request(snap)

    def stats(self) -> dict:
        return self.request({"op": "stats"})

    def retrain(self) -> dict:
        return self.request({"op": "retrain"})

    def rollback(self) -> dict:
        return self.request({"op": "rollback"})

    def bye(self) -> dict:
        return self.request({"op": "bye", "tenant": self.tenant})

    def close(self) -> None:
        pass


class ServiceClient:
    """Blocking JSON-lines TCP client (one socket, ordered replies)."""

    def __init__(self, host: str, port: int, tenant: str,
                 timeout: float = 30.0):
        self.tenant = tenant
        self._sock = socket.create_connection((host, port),
                                              timeout=timeout)
        self._file = self._sock.makefile("rwb")

    def request(self, msg: dict, timeout: float | None = None) -> dict:
        self._file.write(protocol.encode(msg))
        self._file.flush()
        line = self._file.readline()
        if not line:
            raise ConnectionError("service closed the connection")
        return protocol.decode(line)

    def hello(self, profile) -> dict:
        return self.request({"op": "hello", "tenant": self.tenant,
                             "profile": profile.to_wire()})

    def snapshot(self, snap: dict) -> dict:
        snap = dict(snap)
        snap["op"] = "snapshot"
        snap["tenant"] = self.tenant
        return self.request(snap)

    def stats(self) -> dict:
        return self.request({"op": "stats"})

    def retrain(self) -> dict:
        return self.request({"op": "retrain"})

    def rollback(self) -> dict:
        return self.request({"op": "rollback"})

    def bye(self) -> dict:
        try:
            return self.request({"op": "bye", "tenant": self.tenant})
        finally:
            self.close()

    def close(self) -> None:
        try:
            self._file.close()
            self._sock.close()
        except OSError:
            pass
