"""Service-boundary telemetry sanitizer.

A multi-tenant daemon shares one batch (and one process) across tenants,
so one malformed snapshot must never poison another tenant's answers or
crash the tick loop.  This is the network-boundary mirror of the
controller-side ``STARTController._sanitize_es`` guard (PR 6): that one
protects the trigger from a degenerate *prediction*; this one protects
the predictor from degenerate *telemetry*.

Two modes, chosen per server (``ServiceConfig.sanitize``):

  * ``"clamp"`` (default): non-finite features -> 0.0 and magnitudes
    clipped to ``FEATURE_CLIP``; non-positive / non-finite durations are
    dropped from ``done`` records.  The snapshot is answered normally
    and the response lists what was repaired under ``"sanitized"``.
  * ``"reject"``: the same conditions fail the snapshot with a
    :class:`TelemetryError` instead of repairing it.

Structural violations — wrong matrix shapes, q outside [1, max_tasks],
task slots outside the matrix, a non-monotonic interval stamp — are
rejected in BOTH modes: there is no meaningful repair, and silently
reordering a tenant's timeline would corrupt its server-side history.
"""
from __future__ import annotations

import math

import numpy as np

from repro.core import features

#: clamp bound for repaired feature magnitudes (normalized features are
#: O(1); anything huge is garbage but must not overflow float32 math)
FEATURE_CLIP = 1e6


class TelemetryError(ValueError):
    """A snapshot the service refuses to process; ``code`` is the wire
    error code the tenant gets back."""

    def __init__(self, code: str, message: str):
        super().__init__(message)
        self.code = code


def _clean_block(arr, shape: tuple, what: str, mode: str,
                 issues: list[str]) -> np.ndarray:
    """Shape-check + finite-check one feature block."""
    a = np.asarray(arr, dtype=np.float32)
    if a.size != int(np.prod(shape)):
        raise TelemetryError(
            "bad-shape", f"{what}: expected {shape} "
            f"({int(np.prod(shape))} values), got {a.size}")
    a = a.reshape(shape)
    bad = ~np.isfinite(a)
    if bad.any():
        if mode == "reject":
            raise TelemetryError(
                "bad-telemetry", f"{what}: {int(bad.sum())} non-finite "
                f"feature(s)")
        a = np.where(bad, np.float32(0.0), a)
        issues.append(f"{what}: zeroed {int(bad.sum())} non-finite")
    big = np.abs(a) > FEATURE_CLIP
    if big.any():
        if mode == "reject":
            raise TelemetryError(
                "bad-telemetry", f"{what}: {int(big.sum())} feature(s) "
                f"beyond +-{FEATURE_CLIP:g}")
        a = np.clip(a, -FEATURE_CLIP, FEATURE_CLIP)
        issues.append(f"{what}: clipped {int(big.sum())} oversized")
    return a


def sanitize_snapshot(snap: dict, profile, last_seq: float,
                      mode: str = "clamp") -> dict:
    """Validate + repair one snapshot request against a tenant profile.

    Returns ``{"seq", "m_h", "jobs", "done", "issues"}`` with numpy
    feature blocks, or raises :class:`TelemetryError`.  ``jobs`` entries
    are ``{"id", "q", "m_t", "open", "deadline", "tasks"}`` with
    ``tasks`` as ``(tids, hosts, slots)`` int arrays.
    """
    issues: list[str] = []
    seq = snap.get("seq")
    if not isinstance(seq, (int, float)) or isinstance(seq, bool) \
            or not math.isfinite(seq):
        raise TelemetryError("bad-seq", f"non-numeric seq {seq!r}")
    if seq <= last_seq:
        raise TelemetryError(
            "out-of-order", f"seq {seq} <= last processed {last_seq}")
    m_h = _clean_block(snap.get("m_h", ()),
                       (profile.n_hosts, features.HOST_FEATURES),
                       "m_h", mode, issues)
    jobs = []
    for j in snap.get("jobs") or ():
        jid = j.get("id")
        if not isinstance(jid, int) or isinstance(jid, bool):
            raise TelemetryError("bad-job", f"non-integer job id {jid!r}")
        q = j.get("q")
        if not isinstance(q, (int, float)) or isinstance(q, bool) \
                or not math.isfinite(q) or not 1 <= q <= profile.max_tasks:
            raise TelemetryError(
                "bad-job", f"job {jid}: q={q!r} outside "
                f"[1, {profile.max_tasks}]")
        m_t = _clean_block(j.get("m_t", ()),
                           (profile.max_tasks, features.TASK_FEATURES),
                           f"job {jid} m_t", mode, issues)
        tids, hosts, slots = [], [], []
        for ent in j.get("tasks") or ():
            t, h, s = (int(ent[0]), int(ent[1]), int(ent[2]))
            if not 0 <= s < profile.max_tasks:
                raise TelemetryError(
                    "bad-job", f"job {jid}: task {t} slot {s} outside "
                    f"[0, {profile.max_tasks})")
            tids.append(t)
            hosts.append(h)
            slots.append(s)
        open_count = j.get("open", int(q))
        if not isinstance(open_count, int) or isinstance(open_count, bool):
            raise TelemetryError(
                "bad-job", f"job {jid}: non-integer open {open_count!r}")
        jobs.append({
            "id": int(jid), "q": float(q), "m_t": m_t,
            "open": max(0, open_count),
            "deadline": bool(j.get("deadline", False)),
            "tasks": (np.asarray(tids, np.int64),
                      np.asarray(hosts, np.int64),
                      np.asarray(slots, np.int64)),
        })
    done = []
    for d in snap.get("done") or ():
        did = d.get("id")
        if not isinstance(did, int) or isinstance(did, bool):
            raise TelemetryError("bad-done",
                                 f"non-integer done id {did!r}")
        times = np.asarray(d.get("times", ()), np.float32)
        bad = (~np.isfinite(times)) | (times <= 0.0)
        if bad.any():
            if mode == "reject":
                raise TelemetryError(
                    "bad-telemetry", f"done {did}: {int(bad.sum())} "
                    f"non-positive/non-finite duration(s)")
            issues.append(f"done {did}: dropped {int(bad.sum())} "
                          f"bad duration(s)")
            times = times[~bad]
        if times.size:
            done.append({"id": int(did), "times": times})
        elif mode == "clamp":
            issues.append(f"done {did}: dropped (no valid durations)")
    return {"seq": float(seq), "m_h": m_h, "jobs": jobs, "done": done,
            "issues": issues}
