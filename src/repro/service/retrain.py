"""Continuous retraining: replay buffer, candidate fit, shadow eval.

The service turns completed-job telemetry into (sequence, target) pairs
shaped exactly like the simulator's pretraining set
(``NoOpRecorder.dataset``): ``xs`` is the trailing ``horizon`` host-row
sequence broadcast against the job's task matrix, ``ys`` is the MLE
Pareto fit of the job's observed durations, ``[alpha, beta/beta_scale]``
(the same normalization ``fit()`` trains against everywhere else).

Promotion is gated by a **shadow evaluation**: the newest pairs are held
back from training and the candidate must score a finite MSE on them no
worse than ``promote_tol`` x the champion's MSE on the same holdback.  A
corrupted or diverged candidate therefore never becomes the serving
version — the champion keeps answering and the failed candidate is
recorded in stats.
"""
from __future__ import annotations

from collections import deque

import numpy as np

from repro.core import encoder_lstm as net
from repro.core.pareto import fit_pareto_np


class ReplayBuffer:
    """Bounded FIFO of training pairs with a newest-N eval holdback.

    One pair per completed job, shaped exactly like the simulator's
    offline set: ``x`` is (T, host_dim + task_dim) — the trailing host
    window with the job's full padded M_T repeated across time — and
    ``y`` is ``[alpha, beta / beta_scale]``.
    """

    def __init__(self, cap: int = 4096, holdback: int = 32):
        self.xs: deque = deque(maxlen=cap)
        self.ys: deque = deque(maxlen=cap)
        self.holdback = int(holdback)
        self.added = 0

    def __len__(self) -> int:
        return len(self.xs)

    def add_job(self, host_seq: np.ndarray, m_t: np.ndarray,
                times: np.ndarray, beta_scale: float) -> int:
        """One completed job -> one training pair.

        Args:
            host_seq: (T, host_dim) trailing host-feature rows.
            m_t: (max_tasks, TASK_FEATURES) the job's full task matrix
                (padded rows zero).
            times: (n_obs,) observed positive durations.
        """
        alpha, beta = fit_pareto_np(times.reshape(1, -1))
        y = np.array([float(alpha[0]), float(beta[0]) / beta_scale],
                     np.float32)
        t = host_seq.shape[0]
        flat = np.asarray(m_t, np.float32).reshape(-1)
        x = np.concatenate(
            [host_seq, np.broadcast_to(flat, (t, flat.size))],
            axis=1).astype(np.float32)
        self.xs.append(x)
        self.ys.append(y)
        self.added += 1
        return 1

    def split(self) -> tuple[tuple, tuple]:
        """-> ((train_xs, train_ys), (eval_xs, eval_ys)) as stacked
        arrays; eval is the newest ``holdback`` pairs (empty train if
        everything fits in the holdback)."""
        n = len(self.xs)
        h = min(self.holdback, n)
        xs = np.stack(list(self.xs), axis=1)      # (T, n, input_dim)
        ys = np.stack(list(self.ys), axis=0)      # (n, 2)
        cut = n - h
        return ((xs[:, :cut], ys[:cut]), (xs[:, cut:], ys[cut:]))


def shadow_loss(params, eval_xs: np.ndarray, eval_ys: np.ndarray,
                use_pallas: bool = False) -> float:
    """Replay held-back telemetry through a parameter set -> MSE."""
    if eval_xs.shape[1] == 0:
        return float("nan")
    return float(net.mse_loss(params, eval_xs, eval_ys,
                              use_pallas=use_pallas))


def fit_candidate(champion, train_xs: np.ndarray, train_ys: np.ndarray,
                  epochs: int = 20, lr: float = 1e-4):
    """Fine-tune a scratch predictor seeded from the champion params.

    The scratch instance keeps training state (Adam moments, ring
    buffers, jit caches) away from the serving predictor entirely; only
    the resulting ``params`` pytree crosses back, and only if shadow
    eval promotes it.
    """
    from repro.core.predictor import StragglerPredictor

    scratch = StragglerPredictor(
        n_hosts=champion.n_hosts, max_tasks=champion.max_tasks,
        horizon=champion.horizon, k=champion.k,
        beta_scale=champion.beta_scale, seed=champion.seed,
        use_pallas_cell=champion.use_pallas_cell)
    scratch.params = champion.params
    losses = scratch.fit(train_xs, train_ys, epochs=epochs, lr=lr)
    return scratch.params, losses


def should_promote(cand_loss: float, champ_loss: float,
                   tol: float = 1.05) -> bool:
    """Gate: candidate must be finite and no worse than tol x champion.

    A NaN champion loss (e.g. empty holdback) promotes any finite
    candidate — there is nothing to regress against.
    """
    if not np.isfinite(cand_loss):
        return False
    if not np.isfinite(champ_loss):
        return True
    return bool(cand_loss <= champ_loss * tol)
