"""Prediction-as-a-service over the fused START decision step.

The repo's third substrate (after the cloud simulator and the
distributed pod runtime) and its first network-facing surface: a
long-running daemon that answers telemetry snapshots with E_S
predictions, per-task straggler scores and mitigation actions, batching
many small tenant clusters into one device dispatch, with versioned
continuous retraining gated by shadow evaluation.
"""
from repro.service.core import (PredictionService, ServiceConfig,
                                TenantState)
from repro.service.daemon import (LocalClient, ServiceClient,
                                  ServiceDaemon)
from repro.service.protocol import Profile
from repro.service.sanitize import TelemetryError, sanitize_snapshot

__all__ = [
    "PredictionService", "ServiceConfig", "TenantState",
    "ServiceDaemon", "LocalClient", "ServiceClient",
    "Profile", "TelemetryError", "sanitize_snapshot",
]
