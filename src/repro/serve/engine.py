"""Batched serving engine with continuous batching + START-driven
straggler re-dispatch.

The engine runs a fixed-batch decode loop (slots). Requests queue in;
free slots are prefilled (length-bucketed) and join the decode batch.
START integration: per-slot decode latency telemetry feeds the same
Encoder-LSTM -> Pareto predictor used in training; slots whose host
(replica) is a predicted straggler are speculatively re-dispatched to the
healthiest replica (first finished response wins) — the serving analogue
of Algorithm 1's SPECULATION branch.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.lm import Model
from repro.serve.kv_cache import SlotManager, pad_to_length


@dataclasses.dataclass
class Request:
    req_id: int
    tokens: np.ndarray          # prompt
    max_new: int = 16
    out: list = dataclasses.field(default_factory=list)
    submit_t: float = 0.0
    finish_t: float = 0.0


@dataclasses.dataclass
class EngineConfig:
    n_slots: int = 4
    max_len: int = 256
    greedy: bool = True
    temperature: float = 1.0


class Engine:
    def __init__(self, model: Model, params, cfg: EngineConfig,
                 on_step: Optional[Callable] = None):
        self.model = model
        self.params = params
        self.cfg = cfg
        self.slots = SlotManager(cfg.n_slots)
        self.queue: deque[Request] = deque()
        self.done: list[Request] = []
        self._state: dict[int, dict] = {}  # slot -> {caches?, pos, req}
        self.on_step = on_step
        self._decode = jax.jit(model.decode_step)

    # ------------------------------ intake --------------------------------

    def submit(self, req: Request) -> None:
        req.submit_t = time.perf_counter()
        self.queue.append(req)

    def _admit(self) -> None:
        while self.queue and self.slots.free_slots():
            req = self.queue.popleft()
            slot = self.slots.assign(req.req_id)
            toks = jnp.asarray(req.tokens, jnp.int32)[None]
            logits, caches = self.model.prefill(
                self.params, {"tokens": toks})
            caches = pad_to_length(caches, self.cfg.max_len)
            nxt = self._sample(logits)
            req.out.append(int(nxt[0, 0]))
            self._state[slot] = {
                "caches": caches, "pos": len(req.tokens), "req": req,
                "last": nxt}

    def _sample(self, logits):
        if self.cfg.greedy:
            return jnp.argmax(logits[:, -1], axis=-1)[:, None]
        raise NotImplementedError

    # ------------------------------ stepping -------------------------------

    def step(self) -> int:
        """One engine iteration: admit, decode every active slot once,
        retire finished requests. Returns #active slots."""
        self._admit()
        active = list(self._state.items())
        for slot, st in active:
            t0 = time.perf_counter()
            logits, caches = self._decode(
                self.params, st["caches"],
                jnp.asarray(st["last"], jnp.int32).reshape(1, 1),
                jnp.asarray(st["pos"], jnp.int32))
            st["caches"] = caches
            st["pos"] += 1
            nxt = self._sample(logits)
            st["last"] = nxt
            req: Request = st["req"]
            req.out.append(int(nxt[0, 0]))
            if self.on_step:
                self.on_step(slot, time.perf_counter() - t0)
            if len(req.out) >= req.max_new \
                    or st["pos"] >= self.cfg.max_len - 1:
                req.finish_t = time.perf_counter()
                self.done.append(req)
                self.slots.release(slot)
                del self._state[slot]
        return len(self._state)

    def run(self, max_iters: int = 10_000) -> list[Request]:
        it = 0
        while (self.queue or self._state) and it < max_iters:
            self.step()
            it += 1
        return self.done


# --------------------- START-driven replica re-dispatch ---------------------


class ReplicaDispatcher:
    """Serving-cluster view for START: R replicas, per-replica latency
    telemetry; predicted straggler replicas have their in-flight requests
    speculatively duplicated onto the healthiest replica (first wins)."""

    def __init__(self, n_replicas: int, controller=None, k: float = 1.5):
        from repro.core.start import STARTController
        self.n = n_replicas
        self.controller = controller or STARTController(
            n_hosts=n_replicas, max_tasks=8, k=k)
        self.latency: list[list[float]] = [[] for _ in range(n_replicas)]
        self.assignments: dict[int, int] = {}   # req -> replica
        self.duplicated: set[int] = set()

    def assign(self, req_id: int) -> int:
        loads = [sum(1 for r in self.assignments.values() if r == i)
                 for i in range(self.n)]
        rep = int(np.argmin(loads))
        self.assignments[req_id] = rep
        return rep

    def observe(self, replica: int, latency_s: float) -> None:
        self.latency[replica].append(latency_s)

    def decide_redispatch(self) -> list[tuple[int, int]]:
        """Returns [(req_id, target_replica)] speculative duplicates for
        requests on replicas whose latency tail is predicted Pareto-heavy."""
        out = []
        means = np.array([np.mean(lat[-16:]) if lat else 0.0
                          for lat in self.latency])
        if means.max() <= 0:
            return out
        lat_all = np.concatenate(
            [np.asarray(lat[-16:]) for lat in self.latency if lat]) \
            if any(self.latency) else np.zeros(1)
        if len(lat_all) < 4:
            return out
        # K = k x Pareto mean; plug in the empirical mean (the MLE mean
        # alpha*beta/(alpha-1) degenerates as alpha -> 1 on mixed fleets)
        thr = self.controller.predictor.k * float(np.mean(lat_all))
        slow = [i for i in range(self.n)
                if self.latency[i] and np.mean(self.latency[i][-4:]) > thr]
        if not slow:
            return out
        healthy = int(np.argmin(means + (means == 0) * 1e9))
        for req, rep in list(self.assignments.items()):
            if rep in slow and req not in self.duplicated:
                self.duplicated.add(req)
                out.append((req, healthy))
        return out
