"""KV-cache utilities for the serving engine.

Caches are the model-defined pytrees (per layer group, stacked over
layers). This module provides allocation at a fixed max length (decode
writes in place via dynamic_update_slice), plus the slot bookkeeping for
continuous batching: each batch row is a slot that can be re-assigned to a
new request when its sequence finishes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def alloc_like(cache_specs, batch: int | None = None):
    """Zero caches matching eval_shape'd specs (optionally re-batched)."""

    def f(sds):
        shape = sds.shape
        if batch is not None:
            # batch dim is the one after the layer-stack dim by convention
            shape = (shape[0], batch) + shape[2:] \
                if len(shape) > 1 else shape
        return jnp.zeros(shape, sds.dtype)

    return jax.tree_util.tree_map(f, cache_specs)


def pad_to_length(caches, target_len: int):
    """Right-pad every attention cache's seq axis to target_len."""

    def walk(node):
        if isinstance(node, dict):
            out = {}
            for k, v in node.items():
                if k in ("k", "v", "c_kv", "k_rope") and hasattr(v, "ndim"):
                    ax = v.ndim - 2
                    pad = target_len - v.shape[ax]
                    if pad > 0:
                        w = [(0, 0)] * v.ndim
                        w[ax] = (0, pad)
                        v = jnp.pad(v, w)
                    out[k] = v
                else:
                    out[k] = walk(v)
            return out
        return node

    return [walk(c) for c in caches]


class SlotManager:
    """Continuous-batching slot table: request id per batch row."""

    def __init__(self, n_slots: int):
        self.n_slots = n_slots
        self.slots: list[int | None] = [None] * n_slots

    def free_slots(self) -> list[int]:
        return [i for i, s in enumerate(self.slots) if s is None]

    def assign(self, req_id: int) -> int:
        i = self.free_slots()[0]
        self.slots[i] = req_id
        return i

    def release(self, slot: int) -> None:
        self.slots[slot] = None

    def active(self) -> dict[int, int]:
        return {i: r for i, r in enumerate(self.slots) if r is not None}
