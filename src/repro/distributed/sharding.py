"""Sharding rules: param-tree paths -> PartitionSpec on the production mesh.

Layout (DESIGN.md §7):
  * TP over "model": attention head projections, FFN hidden, vocab head,
    MoE experts (EP), mamba inner channels.
  * FSDP (ZeRO-3) over "data": every large matrix additionally sharded on a
    non-TP dimension when divisible.
  * "pod" stays pure data-parallel (batch) so cross-pod traffic is a single
    gradient reduce — the cheapest thing to send over DCI.

Specs are right-aligned: rules name the trailing dims; leading layer-stack
dims are padded with None. The FSDP axis is applied opportunistically (only
where the dim divides evenly) — embed tables with odd vocab sizes simply
stay replicated along that axis.
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

MODEL_AXIS = "model"
DATA_AXIS = "data"


def _path_names(path) -> list[str]:
    out = []
    for k in path:
        if hasattr(k, "key"):
            out.append(str(k.key))
        elif hasattr(k, "idx"):
            out.append(str(k.idx))
        else:
            out.append(str(k))
    return out


# rules: leaf-name -> (tp_dim_from_right, fsdp_dim_from_right) or None
# dims are negative indices into the array shape (right-aligned)
_RULES: dict[str, tuple[int | None, int | None]] = {
    # attention
    "wq": (-1, -2), "wk": (-1, -2), "wv": (-1, -2), "wo": (-2, -1),
    # MLA
    "wq_a": (-1, -2), "wq_b": (-1, -2), "wkv_a": (None, -2),
    "wkv_b": (-1, -2),
    # mlp
    "wg": (-1, -2), "wu": (-1, -2), "wd": (-2, -1),
    # embedding / head
    "embed": (-1, -2), "head": (-1, -2),
    # mamba
    "in_proj": (-1, -2), "conv_w": (-1, None), "conv_b": (-1, None),
    "x_proj": (-2, -1), "dt_proj": (-1, -2), "dt_bias": (-1, None),
    "a_log": (-2, None), "skip": (-1, None),
    "out_proj": (-2, -1),
    # router: replicated
    "router": (None, None),
}

# MoE expert tensors: expert dim is third-from-right -> EP over model
_EXPERT_LEAVES = {"wg", "wu", "wd"}


def _spec_for(path_names: list[str], shape: tuple, mesh,
              fsdp: bool = True, tp: bool = True,
              fsdp_axes: tuple | None = None) -> P:
    name = path_names[-1]
    nd = len(shape)
    axes: list = [None] * nd
    n_model = int(np.prod([mesh.shape[a] for a in mesh.axis_names
                           if a == MODEL_AXIS])) if tp else 1
    fsdp_axes = fsdp_axes or (DATA_AXIS,)
    n_data = int(np.prod([mesh.shape[a] for a in fsdp_axes]))
    fsdp_spec = fsdp_axes if len(fsdp_axes) > 1 else fsdp_axes[0]
    is_expert = name in _EXPERT_LEAVES and ("moe" in path_names
                                            or "shared" not in path_names
                                            and nd >= 3 and name in
                                            _EXPERT_LEAVES and
                                            "mlp" not in path_names)
    # MoE expert weights: detect by an enclosing "moe" key
    if name in _EXPERT_LEAVES and "moe" in path_names \
            and "shared" not in path_names:
        # (..., E, d, f) or (..., E, f, d): EP on E; FSDP on d if divisible
        e_dim = nd - 3
        axes[e_dim] = MODEL_AXIS
        d_dim = nd - 2 if name in ("wg", "wu") else nd - 1
        if fsdp and shape[d_dim] % n_data == 0:
            axes[d_dim] = fsdp_spec
        return P(*axes)
    del is_expert
    rule = _RULES.get(name)
    if rule is None:
        return P()  # norms, biases, scalars: replicated
    tdim, fdim = rule
    if tp and tdim is not None and shape[nd + tdim] % n_model == 0:
        axes[nd + tdim] = MODEL_AXIS
    if fsdp and fdim is not None and nd + fdim >= 0 \
            and shape[nd + fdim] % n_data == 0 \
            and axes[nd + fdim] is None:
        axes[nd + fdim] = fsdp_spec
    return P(*axes)


def param_specs(params: Any, mesh, fsdp: bool = True, tp: bool = True,
                fsdp_axes: tuple | None = None) -> Any:
    """PartitionSpec pytree matching ``params`` (works on ShapeDtypeStructs
    or concrete arrays).

    Layouts (EXPERIMENTS.md §Perf iterations 1-2):
      tp=True,  fsdp=True   ZeRO-3 + TP (default; big MoE)
      tp=True,  fsdp=False  pure TP (state fits n_model shards)
      tp=False, fsdp=True, fsdp_axes=("data","model")
                            pure ZeRO-3 over the whole pod — no TP
                            activation all-reduces at all; best for dense
                            archs whose sharded state fits (the model axis
                            carries FSDP+batch instead of tensor splits)."""

    def f(path, leaf):
        return _spec_for(_path_names(path), leaf.shape, mesh, fsdp=fsdp,
                         tp=tp, fsdp_axes=fsdp_axes)

    return jax.tree_util.tree_map_with_path(f, params)


def param_shardings(params: Any, mesh) -> Any:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), param_specs(params, mesh))


def dp_axes(mesh) -> tuple:
    """Batch-sharding axes: ('pod', 'data') when the pod axis exists."""
    return tuple(a for a in ("pod", DATA_AXIS) if a in mesh.axis_names)


def _dp_if_divisible(dim: int, mesh) -> Any:
    """dp axes (possibly a prefix of them) that evenly divide ``dim``."""
    axes = []
    prod = 1
    for a in dp_axes(mesh):
        if dim % (prod * mesh.shape[a]) == 0:
            axes.append(a)
            prod *= mesh.shape[a]
    return tuple(axes) if axes else None


def batch_specs_tree(batch: Any, mesh) -> Any:
    """Shard the leading (batch) dim of every batch leaf over dp axes
    (skipping axes that don't divide — e.g. global_batch=1 decode)."""

    def f(leaf):
        return P(_dp_if_divisible(leaf.shape[0], mesh),
                 *([None] * (len(leaf.shape) - 1)))

    return jax.tree_util.tree_map(f, batch)


def cache_specs_tree(caches: Any, mesh, seq_axis_sharding: bool = True
                     ) -> Any:
    """KV-cache sharding for serving.

    Default: batch over dp axes and *sequence* over the model axis
    (sequence-parallel flash-decode: XLA turns the softmax/contraction
    reductions into small all-reduces — the right layout when n_kv_heads <
    model-axis size, which holds for most assigned archs). Mamba recurrent
    state h (L, B, Di, N) shards Di over model.
    """
    n_model = mesh.shape.get(MODEL_AXIS, 1)

    def f(path, leaf):
        names = _path_names(path)
        name = names[-1]
        nd = len(leaf.shape)
        axes: list = [None] * nd
        if name in ("k", "v"):            # (L, B, Hkv, S, hd)
            axes[nd - 4] = _dp_if_divisible(leaf.shape[nd - 4], mesh)
            if seq_axis_sharding and leaf.shape[nd - 2] % n_model == 0:
                axes[nd - 2] = MODEL_AXIS
            elif leaf.shape[nd - 3] % n_model == 0:
                axes[nd - 3] = MODEL_AXIS  # fall back to kv-head sharding
            return P(*axes)
        if name in ("c_kv", "k_rope"):    # (L, B, S, d) MLA latents
            axes[nd - 3] = _dp_if_divisible(leaf.shape[nd - 3], mesh)
            if seq_axis_sharding and leaf.shape[nd - 2] % n_model == 0:
                axes[nd - 2] = MODEL_AXIS
            return P(*axes)
        if name == "h":                   # (L, B, Di, N) mamba state
            axes[nd - 3] = _dp_if_divisible(leaf.shape[nd - 3], mesh)
            if leaf.shape[nd - 2] % n_model == 0:
                axes[nd - 2] = MODEL_AXIS
            return P(*axes)
        if name == "conv":                # (L, B, K-1, Di)
            axes[nd - 3] = _dp_if_divisible(leaf.shape[nd - 3], mesh)
            if leaf.shape[nd - 1] % n_model == 0:
                axes[nd - 1] = MODEL_AXIS
            return P(*axes)
        if name == "enc":                 # (B, S_enc, d) encoder states
            axes[0] = _dp_if_divisible(leaf.shape[0], mesh)
            return P(*axes)
        return P(*axes)

    return jax.tree_util.tree_map_with_path(f, caches)
