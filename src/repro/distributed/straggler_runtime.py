"""START applied to distributed training pods (the beyond-paper layer).

In synchronous SPMD training every collective waits for the slowest host,
so one straggler host taxes the whole step. Prior systems detect this
reactively (timeout, then restart); START's insight — predict the latency
*tail* from host+work features with an Encoder-LSTM over a Pareto model —
transfers directly:

  M_H  <- per-host telemetry (step time, mem/net utilization, restart count)
  M_T  <- per-shard work descriptors (microbatches, token counts)
  E_S  <- expected number of straggler hosts this interval (Eq. 4)

Mitigation (Algorithm 1 mapped to pod semantics — DESIGN.md §6):
  * SPECULATE -> backup shards: the lowest-MA healthy host also computes
    the predicted straggler's microbatch; at the gradient reduce a
    first-done-wins mask keeps exactly one contribution (gradient-exact).
  * RERUN -> evict-and-remesh: chronic stragglers are dropped at a step
    boundary; repro.distributed.elastic rebuilds the mesh and state is
    restored from the latest checkpoint.

This module is runtime-agnostic: it consumes step-time observations (real
timers on hardware; simulated Pareto latencies in tests/examples) and
emits actions. The decision core is the same STARTController the cloud
simulator uses — one model, two substrates.
"""
from __future__ import annotations

import dataclasses
import enum

import numpy as np

from repro.core import features, pareto
from repro.core.predictor import StragglerPredictor


class ActionKind(enum.Enum):
    BACKUP_SHARD = "backup_shard"   # speculation analogue
    EVICT = "evict"                 # re-run analogue (remesh without host)


@dataclasses.dataclass(frozen=True)
class HostAction:
    kind: ActionKind
    host: int
    backup: int | None = None       # host that also computes the shard


@dataclasses.dataclass
class RuntimeConfig:
    n_hosts: int
    horizon: int = 5
    k: float = 1.5
    evict_after: int = 3        # consecutive straggler intervals -> evict
    ma_decay: float = 0.8
    seed: int = 0


class StragglerRuntime:
    """Per-step telemetry in, mitigation actions out."""

    def __init__(self, cfg: RuntimeConfig):
        self.cfg = cfg
        self.predictor = StragglerPredictor(
            n_hosts=cfg.n_hosts, max_tasks=cfg.n_hosts, k=cfg.k,
            horizon=cfg.horizon, seed=cfg.seed)
        self.hist: list[np.ndarray] = []      # per-interval host features
        self.step_times: list[np.ndarray] = []
        self.chronic = np.zeros(cfg.n_hosts, np.int64)
        self.ma = np.zeros(cfg.n_hosts)
        self.evicted: set[int] = set()

    # ------------------------------ telemetry ------------------------------

    def observe_step(self, step_times_s: np.ndarray,
                     mem_util: np.ndarray | None = None,
                     net_util: np.ndarray | None = None) -> None:
        n = self.cfg.n_hosts
        st = np.asarray(step_times_s, float)
        self.step_times.append(st)
        med = np.median(st[st > 0]) if (st > 0).any() else 1.0
        rel = st / max(med, 1e-9)
        mem = mem_util if mem_util is not None else np.zeros(n)
        net = net_util if net_util is not None else np.zeros(n)
        m_h = np.asarray(features.host_matrix(
            util=np.stack([np.clip(rel - 1, 0, 2), mem, net,
                           np.zeros(n)], 1),
            cap=np.ones((n, 4)), cost=np.ones(n), power_max=np.ones(n),
            n_tasks=np.ones(n)))
        self.hist.append(m_h)
        self.ma = self.cfg.ma_decay * self.ma \
            + (1 - self.cfg.ma_decay) * (rel > self.cfg.k)
        self.chronic = np.where(rel > self.cfg.k, self.chronic + 1, 0)

    # ------------------------------ decision -------------------------------

    def fitted_tail(self) -> tuple[float, float]:
        """MLE Pareto fit over the recent per-host step times."""
        recent = np.concatenate(self.step_times[-self.cfg.horizon:])
        recent = recent[recent > 0]
        a, b = pareto.fit_pareto(np.asarray(recent, np.float32))
        return float(a), float(b)

    def expected_stragglers(self) -> float:
        """E_S from the *predicted* tail (Encoder-LSTM when trained, MLE
        fallback before training — same Pareto math either way)."""
        if not self.step_times:
            return 0.0
        a, b = self.fitted_tail()
        return float(pareto.expected_stragglers(
            float(self.cfg.n_hosts), a, b, self.cfg.k))

    def decide(self) -> list[HostAction]:
        """Algorithm 1 per training interval.

        Chronic stragglers are evicted unconditionally (a host that is slow
        ``evict_after`` intervals in a row delays every step regardless of
        the tail estimate); E_S sizes the *speculative* backup set, exactly
        as floor(E_S) sizes the mitigation set in the paper."""
        if not self.step_times:
            return []
        actions: list[HostAction] = []
        for h in np.nonzero(self.chronic >= self.cfg.evict_after)[0]:
            h = int(h)
            if h not in self.evicted:
                actions.append(HostAction(ActionKind.EVICT, h))
                self.evicted.add(h)
        e_s = self.expected_stragglers()
        n_mit = int(np.floor(e_s))
        if n_mit <= 0:
            return actions
        last = self.step_times[-1]
        order = np.argsort(-last)  # slowest first
        healthy = [int(h) for h in np.argsort(self.ma)
                   if h not in self.evicted]
        hi = 0
        acted = {a.host for a in actions}
        for h in order[:n_mit]:
            h = int(h)
            if h in self.evicted or h in acted:
                continue
            while hi < len(healthy) and healthy[hi] == h:
                hi += 1
            backup = healthy[hi % len(healthy)] if healthy else h
            hi += 1
            actions.append(HostAction(ActionKind.BACKUP_SHARD, h,
                                      backup=backup))
        return actions


def backup_mask(n_hosts: int, actions: list[HostAction],
                finished_in_time: np.ndarray) -> np.ndarray:
    """First-done-wins combine weights for the gradient reduce.

    finished_in_time[h] — did host h's primary shard meet the deadline.
    Returns (n_hosts,) weights: owner 1.0 if on time, else its backup 1.0;
    exactly one contribution per shard so the gradient stays exact.
    """
    w = np.asarray(finished_in_time, float).copy()
    for a in actions:
        if a.kind is ActionKind.BACKUP_SHARD and a.backup is not None:
            if not finished_in_time[a.host]:
                w[a.host] = 0.0  # backup host contributes this shard
    return w
